from repro.sharding.rules import (LogicalAxisRules, default_rules,
                                  spec_for_shape, tree_specs)  # noqa: F401
