"""Logical-axis sharding rules (MaxText-style), with divisibility-aware
greedy resolution.

Every parameter/activation carries a tuple of *logical* axis names; rules
map each logical name to an ordered preference list of mesh axes. Spec
resolution walks dims in a global priority order, assigning the first mesh
axis that (a) is not already used by another dim of the same tensor and
(b) divides the dim size. Non-divisible or exhausted dims replicate.

This is what lets one model zoo serve meshes (16,16) and (2,16,16) and
archs whose head counts (56, 10, 1...) don't always divide the model axis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


MeshAxes = Tuple[str, ...]


@dataclasses.dataclass
class LogicalAxisRules:
    # logical name -> ordered mesh-axis preference (each entry is a mesh axis
    # name or a tuple of axes to use jointly)
    rules: Dict[str, List[object]]
    # resolution priority: earlier names grab mesh axes first
    priority: List[str]

    def axis_prefs(self, name: str) -> List[object]:
        return self.rules.get(name, [])


def default_rules(head_dim_fallback: bool = False) -> LogicalAxisRules:
    """head_dim_fallback: shard head_dim over `model` when head counts
    don't divide it. MEASURED HARMFUL (EXPERIMENTS.md §Perf iteration 1):
    XLA SPMD cannot propagate head_dim-sharded attention cleanly and falls
    back to full rematerialization copies — arctic-480b prefill collective
    term 378s -> 3.8s (99x) with replicated heads. Default off."""
    return LogicalAxisRules(
        rules={
            "batch": [("pod", "data"), "data"],
            "experts": ["model"],
            "heads": ["model"],
            "kv_heads": ["model"],
            "vocab": ["model"],
            "mlp": ["model"],
            "q_lora": ["model"],
            "kv_lora": ["model"],
            "head_dim": (["model"] if head_dim_fallback else []),
            "embed": ["data"],          # FSDP axis for weights
            "embed_repl": [],
            "seq": [],                  # sequence kept unsharded by default
            "layers": [],
            "conv": [],
            "state": [],
        },
        priority=["experts", "heads", "kv_heads", "vocab", "mlp", "q_lora",
                  "kv_lora", "batch", "head_dim", "embed", "seq"],
    )


def _axes_of(entry) -> Tuple[str, ...]:
    return entry if isinstance(entry, tuple) else (entry,)


def spec_for_shape(mesh: Mesh, logical: Sequence[Optional[str]],
                   shape: Sequence[int],
                   rules: Optional[LogicalAxisRules] = None) -> P:
    """Resolve a PartitionSpec for one tensor."""
    rules = rules or default_rules()
    mesh_sizes = dict(mesh.shape)  # works for Mesh and AbstractMesh
    n = len(shape)
    assert len(logical) == n, (logical, shape)
    assignment: List[Optional[object]] = [None] * n
    used: set = set()
    order = sorted(
        range(n),
        key=lambda i: (rules.priority.index(logical[i])
                       if logical[i] in rules.priority else len(rules.priority)))
    for i in order:
        name = logical[i]
        if name is None:
            continue
        for pref in rules.axis_prefs(name):
            axes = _axes_of(pref)
            if any(a not in mesh_sizes for a in axes):
                continue
            if any(a in used for a in axes):
                continue
            total = 1
            for a in axes:
                total *= mesh_sizes[a]
            if shape[i] % total != 0:
                continue
            assignment[i] = pref
            used.update(axes)
            break
    return P(*assignment)


def tree_specs(mesh: Mesh, params_logical, params_shapes,
               rules: Optional[LogicalAxisRules] = None):
    """Map matching pytrees of logical-axis tuples and shapes -> NamedShardings."""
    rules = rules or default_rules()

    def one(logical, shape):
        return NamedSharding(mesh, spec_for_shape(mesh, logical, shape, rules))

    return jax.tree.map(one, params_logical, params_shapes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def serving_rules(replicate_weights_over_data: bool = False,
                  shard_cache_seq: bool = True) -> LogicalAxisRules:
    """Decode-path rules (EXPERIMENTS.md §Perf iterations 2/2b).

    Iteration 2 (REFUTED): replicating weights over `data` to avoid
    per-step FSDP gathers made qwen3 decode WORSE (coll 0.68s -> 1.55s;
    all-gather 32 -> 74 GiB): the decode collective term is dominated by
    KV-CACHE all-gathers (kv_heads=8 < model=16 leaves the cache
    model-replicated and SPMD re-gathers it around the per-step update),
    not by weight gathers.

    Iteration 2b (CONFIRMED): shard the cache SEQUENCE dim over `model`
    (context-parallel decode attention; the S-contraction becomes a psum).
    """
    r = default_rules()
    rules = dict(r.rules)
    if replicate_weights_over_data:
        rules["embed"] = []
    if shard_cache_seq:
        rules["seq"] = ["model"]
    return LogicalAxisRules(rules=rules, priority=r.priority)
