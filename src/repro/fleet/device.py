"""`Device` — one simulated FHE accelerator inside a fleet.

A device owns the full single-server serving stack the
`PipelinedExecutor` established (admission queue → slot batcher → key
cache → compile cache → backend) plus discrete-event state
(``busy_until``) so a `FleetScheduler` can interleave N of them on one
virtual clock. The backend is any `resolve_backend` name — the
discrete-event `PimBackend` and `AnalyticBackend` make multi-device
simulation cheap; wall-clock backends (mesh/ciphertext) work too but
serve batches atomically.

Two execution paths per batch:

* **atomic** — `backend.execute` end to end, float-identical to
  `PipelinedExecutor._execute_batch` (the fleet(N=1) ≡ single-executor
  regression anchor).
* **stepped** — a `Flight`: the batch streams round by round
  (`backend.round_seconds`), and between rounds the device can
  **refill** free slot rows with newly queued requests of the same
  workload (continuous slot batching) or be **preempted** by a
  deadline-bearing batch (SLO scheduling). A row that joins at a round
  boundary trails the lead wave through the pipeline — the load-save
  pipeline frees a round's partitions once the wave passes — so it
  rides the next `R` round-steps regardless of entry phase; each
  round-step is billed at the batch occupancy current when it issues.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Set

from repro.core.params import CkksParams
from repro.core.pipeline import (MemoryModel, PipelineSchedule,
                                 generate_load_save_pipeline)
from repro.obs.tracer import ExecObs
from repro.runtime.batcher import Batch, BatchPolicy, SlotBatcher
from repro.runtime.compile_cache import CompileCache
from repro.runtime.executor import record_request_completion
from repro.runtime.keycache import KeyCache
from repro.runtime.metrics import MetricsRegistry
from repro.runtime.queue import AdmissionQueue, Request, RequestStatus


class Flight:
    """An in-flight batch streamed round by round with mutable
    membership. ``rounds_left[rid]`` counts the round-steps request
    ``rid`` still has to ride; a joiner enters with the full round
    count and wraps behind the lead wave."""

    def __init__(self, batch: Batch, schedule: PipelineSchedule,
                 slots_per_ct: int, now: float):
        self.workload = batch.workload
        self.schedule = schedule
        self.n_rounds = max(1, len(schedule.rounds))
        self.groups: List[List[Request]] = batch.slot_groups
        self.free: List[int] = [
            slots_per_ct - sum(r.slots_needed for r in g)
            for g in self.groups]
        self.members: Dict[int, Request] = {
            r.request_id: r for r in batch.requests}
        self.rounds_left: Dict[int, int] = {
            rid: self.n_rounds for rid in self.members}
        self.service_start: Dict[int, float] = {
            rid: now for rid in self.members}
        self.cursor = 0            # next round index to execute
        self.step_dt = 0.0         # duration of the step in service
        self.total_service = 0.0
        self.span: Optional[int] = None   # open batch span (tracing on)
        self.obs: Optional[ExecObs] = None
        self.n_refills = 0

    @property
    def occupancy(self) -> int:
        return max(1, sum(1 for g in self.groups if g))

    def best_effort(self) -> bool:
        """Preemptable iff no member carries a deadline."""
        return all(r.deadline_s is None for r in self.members.values())

    def min_rounds_left(self) -> int:
        return min(self.rounds_left.values()) if self.rounds_left else 0

    def absorb(self, joined: List[Request], now: float) -> None:
        for r in joined:
            self.members[r.request_id] = r
            self.rounds_left[r.request_id] = self.n_rounds
            self.service_start[r.request_id] = now

    def finish_step(self, now: float,
                    metrics: MetricsRegistry) -> List[Request]:
        """Account the step that just ended: advance the round cursor,
        decrement every rider, complete members that have seen all
        rounds (freeing their slot rows for refill)."""
        self.total_service += self.step_dt
        self.cursor = (self.cursor + 1) % self.n_rounds
        done: List[Request] = []
        for rid in list(self.rounds_left):
            self.rounds_left[rid] -= 1
            if self.rounds_left[rid] == 0:
                done.append(self.members.pop(rid))
                del self.rounds_left[rid]
        for r in done:
            record_request_completion(metrics, r, now,
                                      self.service_start.pop(r.request_id),
                                      batch_span=self.span)
        if done:
            gone = {r.request_id for r in done}
            for i, g in enumerate(self.groups):
                kept = [r for r in g if r.request_id not in gone]
                if len(kept) != len(g):
                    self.free[i] += sum(r.slots_needed for r in g
                                        if r.request_id in gone)
                    self.groups[i] = kept
        return done

    def evacuate(self) -> List[Request]:
        """Preemption: hand back every unfinished member (progress is
        lost — the wasted rounds already hit the occupancy meters)."""
        out = list(self.members.values())
        self.members.clear()
        self.rounds_left.clear()
        self.service_start.clear()
        for g in self.groups:
            g.clear()
        return out


class Device:
    """One fleet device: private queue/batcher/caches/backend plus the
    ``busy_until`` clock the scheduler sequences."""

    def __init__(self, device_id: int, params: CkksParams,
                 mem: MemoryModel, backend, policy: BatchPolicy,
                 metrics: MetricsRegistry,
                 key_cache: Optional[KeyCache] = None,
                 max_depth_per_tenant: int = 256,
                 mapper: Callable[..., PipelineSchedule]
                 = generate_load_save_pipeline,
                 pass_config=None,
                 continuous_batching: bool = False,
                 preempt: bool = False,
                 verify: bool = False):
        self.device_id = device_id
        self.params = params
        self.mem = mem
        self.backend = backend
        self.policy = policy
        self.metrics = metrics
        self.queue = AdmissionQueue(max_depth_per_tenant, metrics)
        self.queue.owner = str(device_id)   # queue-depth series label
        self.batcher = SlotBatcher(self.queue, self.policy, metrics)
        self.key_cache = key_cache
        if key_cache is not None:
            key_cache.metrics = metrics
        self.compile_cache = CompileCache(metrics, verify=verify)
        self.mapper = mapper
        self.pass_config = pass_config
        self.continuous_batching = continuous_batching
        self.preempt = preempt
        if getattr(self.backend, "pad_batch_to", 0) is None:
            self.backend.pad_batch_to = self.policy.max_batch
        self.busy_until = 0.0
        self.flight: Optional[Flight] = None
        self._atomic_in_service = False
        self.compiled: Set[str] = set()

    # -- state queries (router/scheduler) ------------------------------------

    def busy(self) -> bool:
        return self.flight is not None or self._atomic_in_service

    def load_slots(self, now: float) -> int:
        """Backlog in slots: queued demand plus in-flight residency —
        the least-loaded router's comparison key."""
        queued = 0
        for w in self.queue.pending_workloads(now):
            queued += self.queue.pending_demand(now, w)[1]
        inflight = 0
        if self.flight is not None:
            inflight = sum(r.slots_needed
                           for r in self.flight.members.values())
        elif self._atomic_in_service:
            inflight = self.policy.slots_per_ct   # opaque atomic batch
        return queued + inflight

    def is_warm(self, workload: str) -> bool:
        """Cache-affinity signal: stage constants of this workload are
        resident in the device's key cache (admission-time placement
        steers followers here); with no key cache, fall back to the
        compile cache."""
        if self.key_cache is not None:
            return self.key_cache.has_prefix((workload,))
        return workload in self.compiled

    # -- admission -----------------------------------------------------------

    def admit(self, req: Request) -> None:
        """Mirror of PipelinedExecutor._admit: reject what can never
        fit one ciphertext at the door."""
        if req.slots_needed > self.policy.slots_per_ct:
            req.status = RequestStatus.REJECTED
            self.metrics.incr("requests_oversized")
            tr, log = self.metrics.tracer, self.metrics.event_log
            if tr is not None:
                tr.close_root(req, req.arrival_s, "rejected",
                              reason="oversized")
            if log is not None:
                log.emit("rejected", req.arrival_s, req, reason="oversized")
        else:
            self.queue.submit(req)

    # -- compile -------------------------------------------------------------

    def schedule_for(self, workload: str, trace,
                     obs: Optional[ExecObs] = None) -> PipelineSchedule:
        sched = self.compile_cache.get_schedule(
            trace, self.params, self.mem, self.mapper,
            pass_config=self.pass_config, obs=obs)
        self.compiled.add(workload)
        return sched

    # -- event handling ------------------------------------------------------

    def _poll_order(self, now: float) -> Optional[List[str]]:
        """Earliest-deadline-first workload order when the fleet is
        SLO-aware; None keeps the batcher's first-arrival order."""
        if not self.preempt:
            return None
        ws = self.queue.pending_workloads(now)

        def key(w):
            dl = self.queue.earliest_deadline(now, w)
            return (0, dl) if dl is not None else (1, 0.0)
        return sorted(ws, key=key)

    def on_idle(self, now: float, workloads: Dict[str, object]) -> bool:
        """Called by the scheduler whenever ``busy_until <= now``.
        Returns True iff the device changed state (completed work or
        started new work)."""
        progressed = False
        if self._atomic_in_service:
            # completions were recorded at dispatch; just free the slot
            self._atomic_in_service = False
            tel = self.metrics.telemetry
            if tel is not None:
                tel.gauge("fhe_device_inflight_occupancy",
                          device=self.device_id).set(now, 0.0)
            progressed = True
        if self.flight is not None:
            self._flight_boundary(now)
            progressed = True
        if self.flight is None and not self._atomic_in_service:
            batch = self.batcher.poll(now, order=self._poll_order(now))
            if batch is not None:
                self._start_batch(batch, now, workloads)
                progressed = True
        return progressed

    def _start_batch(self, batch: Batch, now: float,
                     workloads: Dict[str, object]) -> None:
        trace = workloads[batch.workload].trace
        tr = self.metrics.tracer
        tel = self.metrics.telemetry
        track = f"device:{self.device_id}"
        bspan = obs = None
        if tr is not None:
            bspan = tr.begin(f"batch:{batch.workload}", now, track=track,
                             workload=batch.workload,
                             n_requests=len(batch.requests),
                             n_ciphertexts=batch.n_ciphertexts,
                             device=self.device_id)
        if tr is not None or tel is not None:
            # telemetry alone still needs the DES timeline origin
            # threaded into round_seconds; spans stay off
            obs = ExecObs(tr, bspan, now, track)
        if tel is not None:
            tel.gauge("fhe_device_queue_depth",
                      device=self.device_id).set(now, len(self.queue))
            tel.gauge("fhe_device_inflight_occupancy",
                      device=self.device_id).set(
                          now, batch.n_ciphertexts
                          / max(1, self.policy.max_batch))
        sched = self.schedule_for(batch.workload, trace, obs=obs)
        stepped = ((self.continuous_batching or self.preempt)
                   and hasattr(self.backend, "round_seconds")
                   and len(sched.rounds) > 0)
        if not stepped:
            # float-identical to PipelinedExecutor._execute_batch —
            # the fleet(N=1) regression anchor
            service_s = self.backend.execute(
                sched, batch, key_cache=self.key_cache,
                metrics=self.metrics, workload=batch.workload, obs=obs)
            done = now + service_s
            if tr is not None:
                tr.end(bspan, done)
            for r in batch.requests:
                record_request_completion(self.metrics, r, done,
                                          service_start_s=now,
                                          batch_span=bspan)
            self.metrics.batch_service.observe(service_s)
            self.metrics.add_device_busy(self.device_id, service_s)
            self.busy_until = done
            self._atomic_in_service = True
            return
        self.flight = Flight(batch, sched, self.policy.slots_per_ct, now)
        self.flight.span = bspan
        self.flight.obs = obs
        self._begin_step(now)

    def _begin_step(self, now: float) -> None:
        f = self.flight
        tel = self.metrics.telemetry
        if tel is not None:
            # in-flight occupancy at every round boundary: the stepped
            # path's membership changes between rounds (refill /
            # completion), which is exactly what this series shows
            tel.gauge("fhe_device_inflight_occupancy",
                      device=self.device_id).set(
                          now, f.occupancy
                          / max(1, self.policy.max_batch))
        dt = self.backend.round_seconds(
            f.schedule, f.schedule.rounds[f.cursor], f.occupancy,
            key_cache=self.key_cache, metrics=self.metrics,
            workload=f.workload,
            obs=f.obs.at(now) if f.obs is not None else None)
        f.step_dt = dt
        self.metrics.add_device_busy(self.device_id, dt)
        self.busy_until = now + dt

    def _flight_boundary(self, now: float) -> None:
        """A round-step just ended: complete finished riders, then —
        in order — preempt for a firing deadline batch, refill free
        slot rows, or issue the next round-step."""
        f = self.flight
        tr, log = self.metrics.tracer, self.metrics.event_log
        tel = self.metrics.telemetry
        f.finish_step(now, self.metrics)
        if not f.members:
            self.metrics.batch_service.observe(f.total_service)
            if tr is not None and f.span is not None:
                tr.end(f.span, now, n_refills=f.n_refills)
            if tel is not None:
                tel.gauge("fhe_device_inflight_occupancy",
                          device=self.device_id).set(now, 0.0)
            self.flight = None
            return
        if self.preempt and f.best_effort() and f.min_rounds_left() > 1 \
                and self._deadline_batch_ready(now):
            evicted = f.evacuate()
            # front-requeue latest-arrival first so each tenant queue
            # stays in arrival order (same convention as the batcher's
            # overflow path); lost rounds were already billed
            for r in sorted(evicted, key=lambda r: r.arrival_s,
                            reverse=True):
                self.queue.requeue(r)
            self.metrics.incr("preemptions")
            self.metrics.incr("requests_preempted", len(evicted))
            self.metrics.batch_service.observe(f.total_service)
            if tr is not None:
                for r in evicted:
                    tr.instant("preempt", now, parent=tr.ensure_root(r),
                               track=f"tenant:{r.tenant}",
                               request_id=r.request_id,
                               device=self.device_id)
                if f.span is not None:
                    tr.end(f.span, now, preempted=True,
                           n_evicted=len(evicted), n_refills=f.n_refills)
            if log is not None:
                for r in evicted:
                    log.emit("preempted", now, r, device=self.device_id)
            if tel is not None:
                tel.gauge("fhe_device_inflight_occupancy",
                          device=self.device_id).set(now, 0.0)
            self.flight = None
            return
        if self.continuous_batching:
            joined = self.batcher.refill(
                now, f.workload, f.groups, f.free, self.policy.max_batch)
            if joined:
                f.absorb(joined, now)
                f.n_refills += 1
        self._begin_step(now)

    def _deadline_batch_ready(self, now: float) -> bool:
        """Is a deadline-bearing workload's batch ready to fire on this
        device right now? (The preemption trigger.)"""
        for w in self.queue.pending_workloads(now):
            if self.queue.earliest_deadline(now, w) is None:
                continue
            if self.batcher.should_fire(now, w):
                return True
        return False

    # -- warmup --------------------------------------------------------------

    def warmup(self, workloads: Dict[str, object],
               scratch: MetricsRegistry,
               preload_keys: bool = True) -> None:
        """Deploy-time compile (+ optional stage-constant preload)
        against a scratch registry so serving hit rates stay clean —
        the per-device mirror of PipelinedExecutor.warmup."""
        saved_cc, self.compile_cache.metrics = \
            self.compile_cache.metrics, scratch
        saved_kc = None
        if self.key_cache is not None:
            saved_kc, self.key_cache.metrics = \
                self.key_cache.metrics, scratch
        try:
            for name, w in workloads.items():
                sched = self.schedule_for(name, w.trace)
                if preload_keys:
                    self.backend.execute(
                        sched, Batch(name, [], [[]], 0.0),
                        key_cache=self.key_cache, metrics=scratch,
                        workload=name)
        finally:
            self.compile_cache.metrics = saved_cc
            if saved_kc is not None:
                self.key_cache.metrics = saved_kc
