"""Admission-time request routing across fleet devices.

Three pluggable policies (``serve_fhe --router ...``):

* ``round_robin``   — cycle devices; the baseline every ablation is
                      measured against.
* ``least_loaded``  — steer to the device with the smallest backlog
                      (queued slots + in-flight residency, tie-broken
                      round-robin so idle fleets still spread).
* ``cache_affinity``— steer a workload to devices whose key/compile
                      caches are already warm (admission-time
                      placement): followers land where the stage
                      constants — evk, rotation keys, plaintext
                      weights — are resident, so the per-round load
                      term stays zero instead of re-streaming on every
                      device the workload touches. Cold workloads get
                      a sticky least-loaded placement; once warm, the
                      residency signal itself governs. Affinity is a
                      preference, not a pin: when the warmest
                      candidate's backlog exceeds the globally
                      least-loaded device by more than one full batch
                      of slots, the request spills there instead —
                      warming a second replica — so a hot workload
                      widens its footprint rather than melting one
                      device (affinity without spillover loses to
                      round_robin the moment load skews).

Every routing decision records whether it landed on a warm device
(``routing_hits``/``routing_misses`` → ``MetricsRegistry.hit_rate
("routing")``), which is the fig20 ablation's routing-hit-rate column.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.fleet.device import Device
from repro.runtime.metrics import MetricsRegistry
from repro.runtime.queue import Request

POLICIES = ("round_robin", "least_loaded", "cache_affinity")


class Router:
    def __init__(self, policy: str, devices: List[Device],
                 metrics: Optional[MetricsRegistry] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown router policy {policy!r} "
                             f"(expected one of {', '.join(POLICIES)})")
        self.policy = policy
        self.devices = devices
        self.metrics = metrics or MetricsRegistry()
        self._rr = 0
        # cache_affinity: sticky placement for not-yet-warm workloads,
        # so a burst of a cold workload doesn't splatter across devices
        # before the first batch has a chance to warm one cache
        self._placement: Dict[str, Device] = {}

    def route(self, req: Request, now: float) -> Device:
        if self.policy == "round_robin":
            dev = self.devices[self._rr % len(self.devices)]
            self._rr += 1
        elif self.policy == "least_loaded":
            dev = self._least_loaded(self.devices, now)
        else:
            dev = self._affinity(req.workload, now)
        warm = dev.is_warm(req.workload)
        self.metrics.incr("routing_hits" if warm else "routing_misses")
        tr, log = self.metrics.tracer, self.metrics.event_log
        if tr is not None:
            # the router touches a request before any queue: this
            # materializes the root span, with the placement decision
            # as its first child
            tr.instant("route", now, parent=tr.ensure_root(req),
                       track=f"tenant:{req.tenant}",
                       request_id=req.request_id, device=dev.device_id,
                       policy=self.policy, warm=warm)
        if log is not None:
            log.emit("routed", now, req, device=dev.device_id,
                     policy=self.policy, warm=warm)
        return dev

    def _least_loaded(self, candidates: List[Device],
                      now: float) -> Device:
        n = len(self.devices)
        start = self._rr % n
        self._rr += 1
        best, best_key = None, None
        for d in candidates:
            key = (d.load_slots(now),
                   (d.device_id - start) % n)   # rotate tie-breaks
            if best_key is None or key < best_key:
                best, best_key = d, key
        return best

    def _affinity(self, workload: str, now: float) -> Device:
        warm = [d for d in self.devices if d.is_warm(workload)]
        if warm:
            dev = self._least_loaded(warm, now)
            coldest = self._least_loaded(self.devices, now)
            # spillover: re-streaming constants on a fresh device beats
            # queueing a full extra batch behind the warm one
            if dev.load_slots(now) > coldest.load_slots(now) + \
                    dev.policy.capacity_slots:
                dev = coldest
            self._placement[workload] = dev
            return dev
        dev = self._placement.get(workload)
        if dev is None:
            dev = self._least_loaded(self.devices, now)
            self._placement[workload] = dev
        return dev
