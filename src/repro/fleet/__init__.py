"""repro.fleet — simulated fleet of FHE serving devices.

Generalizes the single `PipelinedExecutor` to N devices, each wrapping
any `resolve_backend` backend with its own key/compile cache and
discrete-event clock, under one admission-time `Router` and an
SLO-aware `FleetScheduler` (deadline priority, round-boundary
preemption, continuous slot batching). See DESIGN.md §11.
"""
from repro.fleet.device import Device, Flight
from repro.fleet.router import POLICIES, Router
from repro.fleet.scheduler import FleetScheduler, build_fleet

__all__ = ["Device", "Flight", "Router", "POLICIES",
           "FleetScheduler", "build_fleet"]
