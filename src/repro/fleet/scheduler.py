"""`FleetScheduler` — the multi-device generalization of
`PipelinedExecutor.serve`: one virtual clock sequencing N `Device`s,
with admission-time routing (repro.fleet.router), SLO-aware
deadline priority with preemption at round boundaries, and continuous
slot batching (repro.fleet.device.Flight).

Invariant the whole layer hangs on: a fleet of ONE device with
``router="round_robin"``, ``continuous_batching=False`` and
``preempt=False`` reproduces the single `PipelinedExecutor` — same
batches at the same virtual times, float-identical latency and
throughput (regression-tested in tests/test_fleet.py). Everything the
fleet adds is opt-in on top of that anchor.

Event loop semantics: requests are routed to a device at admission
(routing is placement, not work stealing — a queued request never
migrates; FHE payloads are encrypted under device-resident keys, so
migration would re-pay the key/constant streaming the router exists
to avoid). Each device serves its own queue one batch at a time;
the scheduler advances the shared clock to the next event (arrival,
device completion/round boundary, or batcher fire time).
"""
from __future__ import annotations

import itertools
import math
from typing import Callable, Dict, List, Optional, Sequence

from repro.compiler import PassConfig
from repro.core.params import CkksParams
from repro.core.pipeline import (MemoryModel, PipelineSchedule,
                                 generate_load_save_pipeline)
from repro.core.trace import (FheTrace, LevelBudgetExhausted, infer_levels,
                              trace_program)
from repro.fleet.device import Device
from repro.fleet.router import POLICIES, Router
from repro.runtime.batcher import BatchPolicy
from repro.runtime.executor import Workload, resolve_backend
from repro.runtime.keycache import KeyCache
from repro.runtime.metrics import MetricsRegistry
from repro.runtime.queue import Request


class FleetScheduler:
    """N devices, one clock, one metrics scoreboard.

    ``backend`` is a `resolve_backend` name (each device gets its OWN
    instance — private lowering memos, jit caches, serving keys) or a
    zero-arg factory returning a backend instance per device.
    """

    def __init__(self, params: CkksParams, mem: MemoryModel,
                 n_devices: int = 1, backend="analytic",
                 router: str = "round_robin",
                 policy: Optional[BatchPolicy] = None,
                 cache_bytes: int = 0,
                 max_depth_per_tenant: int = 256,
                 mapper: Callable[..., PipelineSchedule]
                 = generate_load_save_pipeline,
                 pass_config: Optional[PassConfig] = None,
                 continuous_batching: bool = False,
                 preempt: bool = False,
                 latency_reservoir: Optional[int] = None,
                 verify: bool = False):
        assert n_devices >= 1
        self.params = params
        self.mem = mem
        # latency_reservoir bounds the latency accumulators' memory on
        # fig20-scale sweeps (None = exact, unbounded)
        self.metrics = MetricsRegistry(n_partitions=mem.n_partitions,
                                       latency_reservoir=latency_reservoir)
        self.policy = policy or BatchPolicy(slots_per_ct=params.slots)
        self.pass_config = pass_config
        self.continuous_batching = continuous_batching
        self.preempt = preempt

        def make_backend():
            if isinstance(backend, str):
                return resolve_backend(backend, params, mem)
            return backend()

        self.devices: List[Device] = []
        for i in range(n_devices):
            kc = (KeyCache(cache_bytes, load_bw=mem.load_bw)
                  if cache_bytes > 0 else None)
            self.devices.append(Device(
                i, params, mem, make_backend(), self.policy, self.metrics,
                key_cache=kc, max_depth_per_tenant=max_depth_per_tenant,
                mapper=mapper, pass_config=pass_config,
                continuous_batching=continuous_batching, preempt=preempt,
                verify=verify))
            self.metrics.device_busy_s.setdefault(i, 0.0)
        self.router = Router(router, self.devices, self.metrics)
        self.workloads: Dict[str, Workload] = {}
        self._id = itertools.count()

    # -- workload registry (mirrors PipelinedExecutor) -----------------------

    def register(self, name: str, fn: Callable, n_inputs: int,
                 const_names: Sequence[str] = (),
                 start_level: int = 10) -> Workload:
        trace = trace_program(fn, n_inputs, const_names)
        try:
            infer_levels(trace, start_level=start_level)
        except LevelBudgetExhausted:
            if not (self.pass_config and self.pass_config.bootstrap):
                raise
        w = Workload(name, trace)
        self.workloads[name] = w
        return w

    def register_trace(self, name: str, trace: FheTrace) -> Workload:
        w = Workload(name, trace)
        self.workloads[name] = w
        return w

    # -- request path --------------------------------------------------------

    def next_request_id(self) -> int:
        return next(self._id)

    def submit(self, tenant: str, workload: str, now: float,
               slots_needed: int = 1, deadline_s: Optional[float] = None,
               payload=None) -> Request:
        assert workload in self.workloads, f"unregistered workload {workload}"
        req = Request(self.next_request_id(), tenant, workload,
                      arrival_s=now, slots_needed=slots_needed,
                      deadline_s=deadline_s, payload=payload)
        self._route_and_admit(req, now)
        return req

    def _route_and_admit(self, req: Request, now: float) -> None:
        self.router.route(req, now).admit(req)

    def warmup(self, preload_keys: bool = True) -> None:
        """Deploy-time compile (and optionally key preload) on every
        device, against a scratch registry so serving-time hit rates
        stay clean. ``preload_keys=False`` leaves every key cache cold
        — the regime where cache-affinity routing earns its keep
        (warmth then comes only from serving traffic)."""
        scratch = MetricsRegistry(self.mem.n_partitions)
        for dev in self.devices:
            dev.warmup(self.workloads, scratch, preload_keys=preload_keys)

    # -- event loop ----------------------------------------------------------

    def _work_remains(self, now: float) -> bool:
        if any(d.busy() for d in self.devices):
            return True
        return any(len(d.queue) for d in self.devices)

    def serve(self, arrivals: List[Request],
              start_s: float = 0.0) -> MetricsRegistry:
        """Drain a pre-generated arrival schedule (sorted by
        arrival_s) across the fleet. Multi-server semantics: each
        device serves one batch (or one flight round-step) at a time;
        the clock jumps to the earliest pending event."""
        pending = sorted(arrivals, key=lambda r: r.arrival_s)
        i = 0
        now = start_s
        while True:
            while i < len(pending) and pending[i].arrival_s <= now:
                self._route_and_admit(pending[i], now)
                i += 1
            progressed = False
            for dev in self.devices:
                if dev.busy_until <= now:
                    progressed |= dev.on_idle(now, self.workloads)
            if progressed:
                continue
            # idle: jump to the next event
            events = []
            if i < len(pending):
                events.append(pending[i].arrival_s)
            for dev in self.devices:
                if dev.busy():
                    events.append(dev.busy_until)
                else:
                    t_fire = dev.batcher.next_fire_time(now)
                    if t_fire is not None:
                        events.append(t_fire)
            if not events:
                break              # only expired/unservable work left
            now = max(math.nextafter(now, math.inf), min(events))
        self.metrics.elapsed_s = max(self.metrics.elapsed_s, now - start_s)
        if self.metrics.tracer is not None:
            self.metrics.tracer.close_open(now)
        return self.metrics


def build_fleet(params: CkksParams, mem: MemoryModel, *, n_devices: int,
                backend: str = "analytic", router: str = "round_robin",
                policy: Optional[BatchPolicy] = None, cache_bytes: int = 0,
                pass_config: Optional[PassConfig] = None,
                continuous_batching: bool = False,
                preempt: bool = False,
                latency_reservoir: Optional[int] = None) -> FleetScheduler:
    """Keyword-armored convenience constructor (the serve_fhe/fig20
    entry point)."""
    return FleetScheduler(
        params, mem, n_devices=n_devices, backend=backend, router=router,
        policy=policy, cache_bytes=cache_bytes, pass_config=pass_config,
        continuous_batching=continuous_batching, preempt=preempt,
        latency_reservoir=latency_reservoir)


__all__ = ["FleetScheduler", "build_fleet", "POLICIES"]
