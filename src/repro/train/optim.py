"""AdamW with pytree states. Optimizer states inherit the parameters'
shardings (FSDP'd params => ZeRO-sharded optimizer states for free)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

F32 = jnp.float32


def adamw_init(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_adamw_state(params_abstract):
    return {
        "m": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, F32),
                          params_abstract),
        "v": jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, F32),
                          params_abstract),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def adamw_state_specs(param_specs, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return {
        "m": param_specs,
        "v": param_specs,
        "step": NamedSharding(mesh, P()),
    }


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(F32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        grads), gn


def adamw_update(params, grads, state, lr: float = 3e-4, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, wd: float = 0.1):
    step = state["step"] + 1
    t = step.astype(F32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(F32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        pf = p.astype(F32) - lr * (update + wd * pf_wd(p))
        return pf.astype(p.dtype), m, v

    def pf_wd(p):
        # no weight decay on 1-D (norm/bias) params
        return p.astype(F32) if p.ndim > 1 else jnp.zeros_like(p, F32)

    flat_p, td = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (jax.tree.unflatten(td, new_p),
            {"m": jax.tree.unflatten(td, new_m),
             "v": jax.tree.unflatten(td, new_v),
             "step": step})
