"""Sharded checkpointing with elastic resharding and async writes.

Format: one .npz per checkpoint step (flat key -> array) + a msgpack
manifest (step, tree structure, shapes, dtypes, fsync'd last). Restore
device_puts each leaf with the TARGET mesh's shardings — the source and
target meshes are independent, giving elastic reshard (N-device -> M-device
restarts, the slice-level remedy for lost pods/slices).

At 1000+ node scale the same layout shards the .npz by host
(`host_shard`/`n_host_shards` naming hooks are in place); on this
single-host container everything lands in one file.
"""
from __future__ import annotations

import io
import os
import threading
import time
from typing import Any, Dict, Optional, Tuple

import msgpack
import numpy as np
import jax


SEP = "__"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree, *,
                    keep: int = 3) -> str:
    """Blocking save. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    with open(path + ".npz.tmp", "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.rename(path + ".npz.tmp", path + ".npz")
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "time": time.time(),
    }
    with open(path + ".manifest.tmp", "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())
    os.rename(path + ".manifest.tmp", path + ".manifest")
    _gc_old(ckpt_dir, keep)
    return path


def _gc_old(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        for ext in (".npz", ".manifest"):
            p = os.path.join(ckpt_dir, f"ckpt_{s:08d}{ext}")
            if os.path.exists(p):
                os.remove(p)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for f in os.listdir(ckpt_dir):
        if f.endswith(".manifest"):
            out.append(int(f[len("ckpt_"):-len(".manifest")]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like, *, step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, int]:
    """Restore into the structure of `tree_like`. If `shardings` (a matching
    pytree of NamedShardings for the CURRENT mesh) is given, leaves are
    device_put with them — elastic reshard across mesh sizes."""
    step = latest_step(ckpt_dir) if step is None else step
    assert step is not None, f"no checkpoints in {ckpt_dir}"
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}")
    with open(path + ".manifest", "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(path + ".npz")
    flat_keys = sorted(_flatten(tree_like))
    assert flat_keys == manifest["keys"], (
        "checkpoint/model structure mismatch: "
        f"{set(flat_keys) ^ set(manifest['keys'])}")
    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    flat_shard = (_flatten(shardings) if shardings is not None else None)
    out = {}
    for k in flat_keys:
        arr = data[k]
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[k])
        out[k] = arr
    # rebuild in tree order
    keys_in_order = list(_flatten(tree_like))
    rebuilt = [out[k] for k in keys_in_order]
    return jax.tree_util.tree_unflatten(treedef, rebuilt), step


class AsyncCheckpointer:
    """Snapshot on the step boundary (device->host copy only blocks),
    background thread does the serialization + fsync."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, tree):
        self.wait()
        snapshot = {k: np.asarray(jax.device_get(v))
                    for k, v in _flatten(tree).items()}
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            try:
                keys = list(_flatten(tree))
                rebuilt = jax.tree_util.tree_unflatten(
                    treedef, [snapshot[k] for k in keys])
                save_checkpoint(self.ckpt_dir, step, rebuilt, keep=self.keep)
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            raise self.last_error
