"""Gradient compression for the slow (`pod`/DCN) axis: int8 quantization
with error feedback.

Bandwidth hierarchy (DESIGN.md §8): ICI reductions (`data`, `model`) stay
full precision; only the cross-pod all-reduce is compressed (4x fewer DCN
bytes in bf16->int8). Error feedback carries the quantization residual into
the next step, preserving convergence (Karimireddy et al.).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

F32 = jnp.float32


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x.astype(F32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(F32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(F32) * scale


def compressed_psum_body(g, err, *, axis: str):
    """shard_map body: int8 all-reduce over `axis` with error feedback.

    g, err: (1, ...) — this pod's partial gradient + carried residual.
    Returns (reduced_mean (...), new_err (1, ...)).

    Per-pod scales can't be summed directly; the global max scale is agreed
    with one scalar pmax, payloads are requantized against it, and the int8
    payload is summed exactly in int32 — only ~1/4 of the bf16 bytes cross
    the DCN."""
    from repro.compat import axis_size
    n = axis_size(axis)
    corrected = g[0].astype(F32) + err[0]
    _, scale = quantize_int8(corrected)
    gmax = jax.lax.pmax(scale, axis)
    q = jnp.clip(jnp.round(corrected / gmax), -127, 127).astype(jnp.int8)
    new_err = corrected - q.astype(F32) * gmax
    summed = jax.lax.psum(q.astype(jnp.int32), axis)
    return (summed.astype(F32) * gmax / n).astype(g.dtype), new_err[None]


def compressed_pod_mean(per_pod_grads, err_tree, mesh: Mesh,
                        axis: str = "pod"):
    """Compressed all-reduce-mean over `axis`.

    Each leaf of `per_pod_grads` carries a LEADING pod dimension (the
    per-pod partial gradients — what exists physically after each pod's
    internal data/model reduction); err leaves match. Returns
    (mean_grads without the pod dim, new_err_tree with it)."""
    def one(g, e):
        from repro.compat import shard_map
        fn = shard_map(
            partial(compressed_psum_body, axis=axis),
            mesh,
            (P(axis, *([None] * (g.ndim - 1))),
             P(axis, *([None] * (g.ndim - 1)))),
            (P(*([None] * (g.ndim - 1))),
             P(axis, *([None] * (g.ndim - 1)))))
        return fn(g, e)

    flat_g, td = jax.tree_util.tree_flatten(per_pod_grads)
    flat_e = jax.tree_util.tree_leaves(err_tree)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(td, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(td, [o[1] for o in outs]))


def init_error_feedback(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, F32), grads_like)
