"""Fault tolerance: supervised stepping with checkpoint/replay and
straggler detection.

Policy (1000+ node design, DESIGN.md §8):
* every `ckpt_every` steps an async checkpoint is cut;
* a step raising a device/runtime error triggers restore-from-latest and
  replay (deterministic data keyed by step index makes replay exact);
* per-step wall time is tracked with an EMA; steps slower than
  `straggler_k` x EMA raise a StragglerEvent (on real pods the remedy is
  re-slicing — simulated here by elastic restore onto a smaller mesh).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ema: float


@dataclasses.dataclass
class FailureEvent:
    step: int
    error: str
    restored_step: int


class Supervisor:
    """Wraps a jitted train step with checkpoint/replay + straggler watch."""

    def __init__(self, step_fn: Callable, ckpt_dir: str, *,
                 ckpt_every: int = 50, straggler_k: float = 3.0,
                 ema_alpha: float = 0.2, shardings=None,
                 fail_injector: Optional[Callable[[int], None]] = None):
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.straggler_k = straggler_k
        self.ema_alpha = ema_alpha
        self.shardings = shardings
        self.fail_injector = fail_injector
        self.checkpointer = ckpt.AsyncCheckpointer(ckpt_dir)
        self.ema: Optional[float] = None
        self.events: List[Any] = []

    def run(self, state, make_batch: Callable[[int], Any], n_steps: int,
            start_step: int = 0):
        """state: (params, opt_state). make_batch(step) -> batch (replay-
        deterministic). Returns (state, metrics_history)."""
        history: List[Dict] = []
        step = start_step
        while step < n_steps:
            try:
                if self.fail_injector is not None:
                    self.fail_injector(step)
                t0 = time.time()
                batch = make_batch(step)
                params, opt_state, metrics = self.step_fn(*state, batch)
                import jax
                jax.block_until_ready(metrics)
                dt = time.time() - t0
                state = (params, opt_state)
                self._watch_stragglers(step, dt)
                history.append({k: float(v) for k, v in metrics.items()})
                if (step + 1) % self.ckpt_every == 0:
                    self.checkpointer.save(step + 1, {"params": state[0],
                                                      "opt": state[1]})
                step += 1
            except (RuntimeError, ValueError, OSError) as e:
                restored = ckpt.latest_step(self.ckpt_dir)
                if restored is None:
                    raise  # nothing to restore from — fatal
                tree, _ = ckpt.restore_checkpoint(
                    self.ckpt_dir,
                    {"params": state[0], "opt": state[1]},
                    step=restored, shardings=self.shardings)
                state = (tree["params"], tree["opt"])
                self.events.append(FailureEvent(step, repr(e), restored))
                step = restored
        self.checkpointer.wait()
        return state, history

    def _watch_stragglers(self, step: int, dt: float):
        if self.ema is None:
            self.ema = dt
            return
        if dt > self.straggler_k * self.ema and step > 3:
            self.events.append(StragglerEvent(step, dt, self.ema))
        self.ema = (1 - self.ema_alpha) * self.ema + self.ema_alpha * dt
