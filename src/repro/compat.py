"""jax version-compatibility shims.

The codebase targets current jax (jax.shard_map with check_vma,
jax.set_mesh, jax.make_mesh axis_types); CI and some containers carry
jax 0.4.x where those APIs live elsewhere or don't exist. Every
version-sensitive call site routes through here.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh across versions: AxisType landed after 0.4.x."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def shard_map(f, mesh, in_specs, out_specs):
    """Stable jax.shard_map (check_vma) vs jax.experimental.shard_map
    (check_rep), with replication checking off either way."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def axis_size(axis):
    """jax.lax.axis_size inside a shard_map/pmap body; on 0.4.x it
    doesn't exist — psum of 1 over the axis is the standard spelling."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def set_mesh(mesh):
    """Context manager activating `mesh`: jax.set_mesh on current jax;
    on 0.4.x the Mesh object itself is the context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def abstract_mesh(sizes, names):
    """Device-less mesh for spec resolution (tests, dry-runs): current
    jax takes ``AbstractMesh(shape_tuple, axis_names)``; 0.4.x wants one
    tuple of (name, size) pairs."""
    try:
        return jax.sharding.AbstractMesh(tuple(sizes), tuple(names))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))
