"""Hazard analyzer over lowered `PimProgram` instruction streams.

The lowerer (repro.pim.lower) emits each stage's instructions in a
fixed discipline: the constant LOAD first, then per-op ROWOP/NTT/XFER
blocks in SSA dataflow order, then the STORE that ships the stage
output. The bank executes a stage's stream in order, so any violation
of that discipline is a real hazard, not a style issue:

* ``M-ORDER``       RAW — a consumer's rows are computed before its
                    producer's rows exist in the bank.
* ``M-LOAD-ORDER``  rows multiplied against constants still in flight
                    on the load channel.
* ``M-STORE-ORDER`` WAR — the STORE shipped output rows that later
                    instructions of the same stage still mutate.
* ``M-ORPHAN``      LOAD/STORE present without matching stage
                    const/output bytes (or missing when required).
* ``M-PLACE``/``M-CAP`` — the layout invariants repro.pim.layout
                    promises (exactly-once limb placement, per-
                    (round, generation) subarray capacity), rechecked
                    independently of the planner.
* ``M-BAL``         (warn) bank utilization imbalance within one
                    pipeline round — resident stages run concurrently,
                    so a hot bank is wasted parallel hardware.

This is the static precondition for the ROADMAP's movement-aware
rotation scheduling: once the compiler starts reordering XFERs against
ROWOPs, this analyzer is the gate that keeps the reordering honest.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

from repro.analysis.findings import Report
from repro.core.pipeline import PipelineSchedule
from repro.pim.arch import PimArch
from repro.pim.isa import OPCODES, PimInstr, PimProgram
from repro.pim.layout import LayoutPlan, _stage_limbs


def _locus(i: int, ins: PimInstr) -> str:
    return f"instr {i} ({ins.opcode} stage {ins.stage})"


def _structural(rep: Report, program: PimProgram) -> None:
    for i, ins in enumerate(program.instrs):
        if ins.opcode not in OPCODES:
            rep.add("M-OPCODE", _locus(i, ins),
                    f"unknown opcode {ins.opcode!r}",
                    f"known: {', '.join(OPCODES)}", instr=i)
        if not 0 <= ins.stage < program.n_stages:
            rep.add("M-OPCODE", _locus(i, ins),
                    f"stage {ins.stage} outside "
                    f"[0, {program.n_stages})", instr=i)
        if ins.cycles < 0 or ins.nbytes < 0 or ins.rows < 0:
            rep.add("M-OPCODE", _locus(i, ins),
                    f"negative accounting: cycles={ins.cycles} "
                    f"nbytes={ins.nbytes} rows={ins.rows}", instr=i)


def _stage_streams(program: PimProgram) -> Dict[int, List[Tuple[int,
                                                                PimInstr]]]:
    out: Dict[int, List[Tuple[int, PimInstr]]] = {}
    for i, ins in enumerate(program.instrs):
        out.setdefault(ins.stage, []).append((i, ins))
    return out


def _ordering(rep: Report, program: PimProgram,
              schedule: Optional[PipelineSchedule]) -> None:
    """M-ORDER / M-LOAD-ORDER / M-STORE-ORDER / M-ORPHAN over each
    stage's instruction stream."""
    args_of = {}
    if schedule is not None and schedule.trace is not None:
        args_of = {op.idx: op.args for op in schedule.trace.ops}
    streams = _stage_streams(program)
    stages = schedule.stages if schedule is not None else None
    for sidx, stream in sorted(streams.items()):
        load_pos = [k for k, (_, ins) in enumerate(stream)
                    if ins.opcode == "LOAD"]
        store_pos = [k for k, (_, ins) in enumerate(stream)
                     if ins.opcode == "STORE"]
        # LOAD must precede every working instruction of the stage
        if load_pos:
            for i, ins in stream[:load_pos[0]]:
                rep.add("M-LOAD-ORDER", _locus(i, ins),
                        f"issues before the stage's constant LOAD "
                        f"(stream slot {load_pos[0]})",
                        "constants must be resident before any row op",
                        instr=i, stage=sidx)
        # STORE must come last: later work mutates shipped rows
        if store_pos:
            for i, ins in stream[store_pos[-1] + 1:]:
                rep.add("M-STORE-ORDER", _locus(i, ins),
                        "issues after the stage's STORE shipped the "
                        "output rows",
                        "move the STORE to the end of the stage",
                        instr=i, stage=sidx)
        # per-op RAW ordering from trace dataflow
        first: Dict[int, int] = {}
        last: Dict[int, int] = {}
        for k, (_, ins) in enumerate(stream):
            if ins.op_idx >= 0:
                first.setdefault(ins.op_idx, k)
                last[ins.op_idx] = k
        for op_idx, f0 in first.items():
            for a in args_of.get(op_idx, ()):
                if a in last and last[a] > f0:
                    i, ins = stream[f0]
                    rep.add("M-ORDER", _locus(i, ins),
                            f"op {op_idx} issues at stream slot {f0} "
                            f"before its producer op {a} finishes "
                            f"(slot {last[a]})",
                            "emit per-op blocks in SSA dataflow order",
                            instr=i, stage=sidx)
        # orphaned / missing stage-level instructions
        if stages is not None and 0 <= sidx < len(stages):
            st = stages[sidx]
            if st.const_bytes and not load_pos:
                rep.add("M-ORPHAN", f"stage {sidx}",
                        f"const_bytes={st.const_bytes} but no LOAD",
                        "the stage's constants are never streamed in",
                        stage=sidx)
            if load_pos and not st.const_bytes:
                i, ins = stream[load_pos[0]]
                rep.add("M-ORPHAN", _locus(i, ins),
                        "LOAD with const_bytes=0 on the stage",
                        instr=i, stage=sidx)
            if load_pos and st.const_bytes:
                i, ins = stream[load_pos[0]]
                if ins.nbytes != st.const_bytes:
                    rep.add("M-ORPHAN", _locus(i, ins),
                            f"LOAD nbytes={ins.nbytes} != stage "
                            f"const_bytes={st.const_bytes}",
                            instr=i, stage=sidx)
            if st.out_bytes and not store_pos:
                rep.add("M-ORPHAN", f"stage {sidx}",
                        f"out_bytes={st.out_bytes} but no STORE",
                        "the stage output never reaches the next bank",
                        stage=sidx)
            if store_pos and not st.out_bytes:
                i, ins = stream[store_pos[-1]]
                rep.add("M-ORPHAN", _locus(i, ins),
                        "STORE with out_bytes=0 on the stage",
                        instr=i, stage=sidx)


def _layout(rep: Report, schedule: PipelineSchedule, arch: PimArch,
            layout: LayoutPlan) -> None:
    """M-PLACE / M-CAP: recheck the layout invariants independently of
    the planner (same contract repro.pim.layout documents)."""
    n = schedule.params.n
    for st in schedule.stages:
        sl = layout.stage(st.idx)
        expected: Dict[Tuple[int, int, int], int] = {}
        for op_idx, poly, limb, nbytes in _stage_limbs(st, n):
            expected[(op_idx, poly, limb)] = nbytes
        seen: Dict[Tuple[int, int, int], int] = {}
        for p in sl.placements:
            seen[(p.op_idx, p.poly, p.limb)] = \
                seen.get((p.op_idx, p.poly, p.limb), 0) + 1
        missing = [k for k in expected if k not in seen]
        dups = [k for k, c in seen.items() if c > 1]
        extra = [k for k in seen if k not in expected]
        if missing:
            rep.add("M-PLACE", f"stage {st.idx}",
                    f"{len(missing)} limb row(s) never placed; first: "
                    f"(op,poly,limb)={missing[0]}", stage=st.idx)
        if dups:
            rep.add("M-PLACE", f"stage {st.idx}",
                    f"{len(dups)} limb row(s) placed more than once; "
                    f"first: (op,poly,limb)={dups[0]}", stage=st.idx)
        if extra:
            rep.add("M-PLACE", f"stage {st.idx}",
                    f"{len(extra)} placement(s) for limbs the stage "
                    f"does not own; first: (op,poly,limb)={extra[0]}",
                    stage=st.idx)
    # capacity per (round, generation, subarray)
    for ri, rnd in enumerate(schedule.rounds):
        used: Dict[Tuple[int, int, int, int], int] = {}
        for st in rnd:
            if not 0 <= st.idx < len(layout.stages):
                continue
            for p in layout.stage(st.idx).placements:
                key = (p.generation, p.channel, p.bank, p.subarray)
                used[key] = used.get(key, 0) + p.nbytes
        for (gen, ch, bk, sa), nbytes in sorted(used.items()):
            if nbytes > arch.subarray_bytes:
                rep.add("M-CAP",
                        f"round {ri} gen {gen} subarray "
                        f"({ch},{bk},{sa})",
                        f"{nbytes} bytes > subarray_bytes="
                        f"{arch.subarray_bytes}",
                        "the layout planner must open a new residency "
                        "generation")


def _imbalance(rep: Report, program: PimProgram,
               schedule: PipelineSchedule, ratio: float) -> None:
    """M-BAL: within one round, resident banks run concurrently — a
    bank busier than `ratio`x the mean of the round's OTHER active
    banks is a utilization lint (threshold sits above the natural
    variance of the registered workloads; seeded mutations exceed it
    by construction)."""
    streams = _stage_streams(program)
    for ri, rnd in enumerate(schedule.rounds):
        # bootstrap rounds are known-unbalanced (one stage carries the
        # whole refresh); flagging them would drown the signal
        if any(op.kind == "bootstrap" for st in rnd for op in st.ops):
            continue
        busy: Dict[Tuple[int, int], float] = {}
        for st in rnd:
            for _, ins in streams.get(st.idx, ()):
                key = (ins.channel, ins.bank)
                busy[key] = busy.get(key, 0.0) + ins.cycles
        active = {k: v for k, v in busy.items() if v > 0}
        if len(active) < 2:
            continue
        worst_bank, worst = max(active.items(), key=lambda kv: kv[1])
        rest = [v for k, v in active.items() if k != worst_bank]
        mean_rest = sum(rest) / len(rest)
        if mean_rest > 0 and worst > ratio * mean_rest:
            rep.add("M-BAL", f"round {ri}",
                    f"bank {worst_bank} busy {worst:.0f} cycles vs "
                    f"{mean_rest:.0f} mean across the round's other "
                    f"banks ({worst / mean_rest:.0f}x > {ratio:.0f}x)",
                    "rebalance stage splitting or placement")


def analyze_program(program: PimProgram,
                    schedule: Optional[PipelineSchedule] = None,
                    arch: Optional[PimArch] = None,
                    layout: Optional[LayoutPlan] = None, *,
                    imbalance_ratio: float = 1000.0,
                    subject: str = "") -> Report:
    """Static hazard sweep over one lowered program. `schedule`
    unlocks the dataflow/orphan rules, `arch` + `layout` the placement
    and capacity rules — pass everything the call site has."""
    rep = Report("pim", subject)
    t0 = time.perf_counter()
    _structural(rep, program)
    _ordering(rep, program, schedule)
    if schedule is not None and arch is not None and layout is not None:
        _layout(rep, schedule, arch, layout)
    if schedule is not None:
        _imbalance(rep, program, schedule, imbalance_ratio)
    rep.wall_s = time.perf_counter() - t0
    return rep


__all__ = ["analyze_program"]
