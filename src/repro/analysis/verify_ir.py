"""Static trace-IR verifier: structural SSA checks plus level/scale
inference, with no ciphertext math.

The structural rules (def-before-use, dense indices, known kinds and
arities, interface lists) make the IR safe for the dict-free
index-walk style every pass and mapper uses. The semantic rules rerun
`core.trace.infer_levels`' level rules *without raising*, so a trace
that would die with `LevelBudgetExhausted` at runtime is reported as
a `T-BUDGET` finding naming the earliest failing op and the
latest-legal bootstrap cut — the same cut `BootstrapInsertion`
(repro.compiler.passes) would pick: the deepest (minimum-level)
operand of the failing op. The scale-width rules enforce the lazy-
rescale discipline DESIGN.md §7 states informally: lazy products
carry double-width scale and must never meet single-width values in
an add, and no chain may exceed double width before a rescale.
"""
from __future__ import annotations

import time
from typing import Optional, Set

from repro.analysis.findings import Report
from repro.core.trace import FheOp, FheTrace

# operand counts per kind (None = structural source, no operands)
ARITY = {
    "input": 0, "const": 0,
    "hmul": 2, "hadd": 2, "hsub": 2,
    "pmul": 1, "padd": 1,
    "rotate": 1, "conjugate": 1, "rescale": 1, "bootstrap": 1,
}

# meta keys a kind cannot function without; pmul/padd accept either a
# plain const binding or a derived constant expression (compiler/ir.py)
_REQUIRED_META = {
    "rotate": (("step",),),
    "pmul": (("const", "cexpr"),),
    "padd": (("const", "cexpr"),),
}


def _op_locus(i: int, op: FheOp) -> str:
    return f"op {i} ({op.kind})"


def _structural(rep: Report, trace: FheTrace) -> bool:
    """Rules T-INDEX/T-KIND/T-ARITY/T-META/T-DEF-USE/T-IFACE. Returns
    True when the trace is sound enough for semantic inference.

    Single pass over the ops (this runs once per applied pass under
    `optimize_trace(verify=True)`, so it is the verifier's hot loop):
    source-op positions are collected inline and reconciled against the
    interface lists afterwards with set algebra instead of a rescan."""
    ok = True
    add = rep.add
    arity = ARITY
    req_meta = _REQUIRED_META
    src_pos = {"input": set(), "const": set()}
    for i, op in enumerate(trace.ops):
        kind = op.kind
        if op.idx != i:
            add("T-INDEX", _op_locus(i, op),
                f"op.idx={op.idx} at position {i}",
                "renumber via repro.compiler.ir.finish", op_idx=i)
            ok = False
        want = arity.get(kind)
        if want is None:
            add("T-KIND", _op_locus(i, op),
                f"unknown kind {kind!r}",
                f"known kinds: {', '.join(sorted(arity))}", op_idx=i)
            ok = False
            continue
        if kind in src_pos:
            src_pos[kind].add(i)
        if len(op.args) != want:
            add("T-ARITY", _op_locus(i, op),
                f"{kind} takes {want} operand(s), "
                f"got {len(op.args)}", op_idx=i)
            ok = False
        if kind in req_meta:
            for keysets in req_meta[kind]:
                if not any(k in op.meta for k in keysets):
                    add("T-META", _op_locus(i, op),
                        f"{kind} missing meta "
                        f"{' or '.join(repr(k) for k in keysets)}",
                        op_idx=i)
                    ok = False
        for a in op.args:
            if not (type(a) is int and 0 <= a < i):
                add("T-DEF-USE", _op_locus(i, op),
                    f"operand {a!r} is not an earlier op "
                    f"(positions 0..{i - 1})",
                    "args must reference already-defined values "
                    "(SSA order)", op_idx=i)
                ok = False
    n = len(trace.ops)
    for name, idxs, kind in (("inputs", trace.inputs, "input"),
                             ("consts", trace.consts, "const")):
        declared = set()
        for x in idxs:
            if not isinstance(x, int) or x < 0 or x >= n:
                rep.add("T-IFACE", f"{name} list",
                        f"entry {x!r} out of range [0, {n})")
                ok = False
                continue
            declared.add(x)
            if trace.ops[x].kind != kind:
                rep.add("T-IFACE", _op_locus(x, trace.ops[x]),
                        f"listed in {name} but kind is "
                        f"{trace.ops[x].kind!r}", op_idx=x)
                ok = False
        for i in sorted(src_pos[kind] - declared):
            rep.add("T-IFACE", _op_locus(i, trace.ops[i]),
                    f"{kind} op missing from the {name} list",
                    op_idx=i)
            ok = False
    if not trace.outputs:
        rep.add("T-IFACE", "outputs list", "trace declares no outputs")
        ok = False
    for x in trace.outputs:
        if not isinstance(x, int) or x < 0 or x >= n:
            rep.add("T-IFACE", "outputs list",
                    f"entry {x!r} out of range [0, {n})")
            ok = False
    return ok


def resolve_start_level(trace: FheTrace,
                        start_level: Optional[int]) -> Optional[int]:
    """Same resolution order as PassConfig.resolve_start_level, minus
    the params fallback: explicit argument, else the first annotated
    input. None = levels unknowable, budget checks are skipped."""
    if start_level is not None:
        return start_level
    for i in trace.inputs:
        if 0 <= i < len(trace.ops) and trace.ops[i].level is not None:
            return trace.ops[i].level
    return None


def _levels(rep: Report, trace: FheTrace, start: int,
            bootstrap_to: Optional[int], check_annotations: bool) -> None:
    """Non-raising mirror of core.trace.infer_levels: T-LEVEL on
    annotation drift, T-BUDGET (earliest failure + latest-legal
    bootstrap cut) on exhaustion."""
    # structural rules passed, so idx == position and args are earlier:
    # a dense list beats a dict in this per-op loop
    lv: list = []
    reported_budget = False
    for op in trace.ops:
        kind = op.kind
        if kind in ("input", "const"):
            exp = start
        elif kind in ("hmul", "pmul"):
            base = min(lv[a] for a in op.args)
            exp = base if op.meta.get("lazy") else base - 1
        elif kind in ("hadd", "hsub", "padd"):
            exp = min(lv[a] for a in op.args)
        elif kind in ("rotate", "conjugate"):
            exp = lv[op.args[0]]
        elif kind == "rescale":
            exp = lv[op.args[0]] - 1
        else:  # bootstrap
            exp = bootstrap_to if bootstrap_to is not None else start
        lv.append(exp)
        if exp < 0 and not reported_budget:
            reported_budget = True
            cut_val, cut_lv = None, None
            if op.args:
                cut_lv, cut_val = min((lv[a], a) for a in op.args)
            hint = ("enable the compiler's bootstrap pass, or insert "
                    ".bootstrap() " +
                    (f"on value {cut_val} (level {cut_lv}) — the "
                     f"latest-legal cut" if cut_val is not None
                     else "upstream"))
            rep.add("T-BUDGET", _op_locus(op.idx, op),
                    f"level {exp} < 0 with start level {start}: the "
                    f"program is deeper than the modulus chain",
                    hint, op_idx=op.idx)
        if check_annotations and op.level is not None and op.level != exp:
            rep.add("T-LEVEL", _op_locus(op.idx, op),
                    f"annotated level {op.level}, static inference "
                    f"gives {exp}",
                    "re-run core.trace.infer_levels after rewriting",
                    op_idx=op.idx)


def _scales(rep: Report, trace: FheTrace) -> None:
    """Scale-width discipline (T-SCALE / T-OVERFLOW). Width counts the
    scale's exponent in units of the working scale Δ: fresh values are
    width 1, a lazy product is width 2, an eager product rescales back
    to its operands' width, rescale subtracts one."""
    # dense list, same justification as _levels
    w: list = []
    for op in trace.ops:
        kind = op.kind
        if kind in ("input", "const", "bootstrap"):
            w.append(1)
            continue
        if kind == "hmul":
            prod = w[op.args[0]] + w[op.args[1]]
        elif kind == "pmul":
            prod = w[op.args[0]] + 1
        elif kind in ("hadd", "hsub"):
            wa, wb = w[op.args[0]], w[op.args[1]]
            if wa != wb:
                rep.add("T-SCALE", _op_locus(op.idx, op),
                        f"operands at scale widths {wa} vs {wb}",
                        "rescale the lazy partial (or mark both "
                        "operands lazy) before adding", op_idx=op.idx)
            w.append(wa if wa >= wb else wb)
            continue
        elif kind in ("padd", "rotate", "conjugate"):
            w.append(w[op.args[0]])
            continue
        elif kind == "rescale":
            nw = w[op.args[0]] - 1
            if nw < 1:
                rep.add("T-OVERFLOW", _op_locus(op.idx, op),
                        f"rescale takes scale width "
                        f"{w[op.args[0]]} below the working scale",
                        "drop the redundant rescale", op_idx=op.idx)
                nw = 1
            w.append(nw)
            continue
        else:
            w.append(1)
            continue
        # product kinds land here with their raw tensored width
        if not op.meta.get("lazy"):
            prod -= 1                       # fused rescale
        if prod > 2:
            rep.add("T-OVERFLOW", _op_locus(op.idx, op),
                    f"scale width {prod} > 2: product chain missed a "
                    f"rescale",
                    "insert a rescale (or let the lazy-rescale pass "
                    "place one) before multiplying again",
                    op_idx=op.idx)
            prod = 2                        # clamp: report once per chain
        w.append(prod)


def _liveness(rep: Report, trace: FheTrace) -> None:
    """T-DEAD / T-UNUSED-IN lints via backward reachability."""
    reach: Set[int] = set()
    stack = [x for x in trace.outputs]
    while stack:
        i = stack.pop()
        if i in reach:
            continue
        reach.add(i)
        stack.extend(trace.ops[i].args)
    for op in trace.ops:
        if op.idx in reach:
            continue
        if op.kind == "input":
            rep.add("T-UNUSED-IN", _op_locus(op.idx, op),
                    f"input (slot {op.meta.get('slot')}) never consumed",
                    "drop the input or use it", op_idx=op.idx)
        elif op.kind != "const":
            rep.add("T-DEAD", _op_locus(op.idx, op),
                    "unreachable from the outputs",
                    "run the DCE pass", op_idx=op.idx)


def verify_trace(trace: FheTrace, *, start_level: Optional[int] = None,
                 bootstrap_to: Optional[int] = None,
                 check_budget: bool = True,
                 structural_only: bool = False,
                 subject: str = "") -> Report:
    """Full static verification of one `FheTrace`.

    ``check_budget=False`` skips the level rules (T-LEVEL/T-BUDGET) —
    the right mode for mid-pipeline traces that a later bootstrap pass
    will legalize and whose annotations are stale. ``structural_only``
    additionally skips the scale and liveness sweeps: the cheap mode
    `verify_pass` uses after every applied pass, where those semantic
    properties are re-established by the final full verification
    anyway (they are whole-pipeline invariants, not per-pass ones).
    """
    rep = Report("trace", subject)
    t0 = time.perf_counter()
    if _structural(rep, trace) and not structural_only:
        start = resolve_start_level(trace, start_level)
        if check_budget and start is not None:
            _levels(rep, trace, start, bootstrap_to,
                    check_annotations=True)
        _scales(rep, trace)
        _liveness(rep, trace)
    rep.wall_s = time.perf_counter() - t0
    return rep


__all__ = ["ARITY", "resolve_start_level", "verify_trace"]
