"""Repo lint gate: sweep every registered workload through every pass
configuration and PIM preset, verifying each artifact the compile
produces — trace IR, pipeline schedule, layout and lowered instruction
stream — and exit non-zero on any error finding.

    PYTHONPATH=src python -m repro.analysis.lint --smoke
    PYTHONPATH=src python -m repro.analysis.lint --smoke --prove
    PYTHONPATH=src python -m repro.analysis.lint --jsonl lint.jsonl

``--prove`` additionally runs the mutation harness: every rule in the
catalogue is seeded with a known-bad artifact and must fire with
exactly its own rule id — a verifier rule that cannot fire is itself a
lint failure.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Tuple

from repro.analysis.findings import RULES, Report
from repro.analysis.mutate import (PASS_MUTATIONS, PIM_MUTATIONS,
                                   SCHEDULE_MUTATIONS, TRACE_MUTATIONS,
                                   CorruptingPass, make_clean_artifacts)
from repro.analysis.pim_hazards import analyze_program
from repro.analysis.verify_ir import verify_trace
from repro.analysis.verify_schedule import verify_schedule
from repro.compiler import PassConfig, optimize_trace
from repro.core.params import paper_params_bootstrap, test_params
from repro.core.pipeline import (generate_load_save_pipeline,
                                 generate_naive_pipeline)
from repro.core.trace import trace_program
from repro.pim.arch import PRESETS, get_arch, memory_model
from repro.pim.layout import plan_layout
from repro.pim.lower import lower_schedule


def _workload_table():
    from repro.runtime import workloads as wl
    return {
        "helr": (wl.make_helr_iter(), 2, wl.HELR_CONSTS),
        "lola": (wl.lola_infer, 1, wl.LOLA_CONSTS),
        "matvec": (wl.make_matvec(16), 1, wl.matvec_consts(16)),
        "poly": (wl.make_poly_eval(12), 1, wl.poly_consts(12)),
    }


# pass-config axis: the optimizing default and the verbatim-serving
# no-opt path (bootstrap stays on so deep workloads remain feasible)
def _configs(start_level: int) -> List[Tuple[str, PassConfig]]:
    return [
        ("opt", PassConfig(start_level=start_level)),
        ("noopt", PassConfig(start_level=start_level).with_passes(
            ["bootstrap"])),
    ]


def sweep(params, start_level: int, *, workloads=None, presets=None,
          verbose: bool = False) -> List[Report]:
    """workloads x pass configs x pim presets -> one Report per
    verified artifact."""
    table = _workload_table()
    names = workloads or sorted(table)
    prs = presets or sorted(PRESETS)
    reports: List[Report] = []
    for wname in names:
        fn, n_in, consts = table[wname]
        base = trace_program(fn, n_in, consts)
        for cname, config in _configs(start_level):
            subject = f"{wname}/{cname}"
            opt, _ = optimize_trace(base, params, config, verify=True)
            reports.append(verify_trace(opt, start_level=start_level,
                                        bootstrap_to=config.bootstrap_to,
                                        subject=subject))
            for preset in prs:
                mem = memory_model(preset)
                arch = get_arch(preset)
                for mname, mapper in (
                        ("loadsave", generate_load_save_pipeline),
                        ("naive", generate_naive_pipeline)):
                    subj = f"{subject}/{preset}/{mname}"
                    sched = mapper(opt, params, mem)
                    reports.append(verify_schedule(
                        sched, start_level=start_level,
                        bootstrap_to=config.bootstrap_to,
                        include_trace=False, subject=subj))
                    layout = plan_layout(sched, arch)
                    program = lower_schedule(sched, arch, layout)
                    reports.append(analyze_program(
                        program, sched, arch, layout, subject=subj))
    if verbose:
        for r in reports:
            print(r.format_table())
    return reports


def prove(workload: str = "matvec",
          preset: str = "fhemem") -> List[str]:
    """Seed one known-bad artifact per rule; return the rule ids that
    FAILED to fire (empty list = every rule proven live)."""
    from repro.analysis.findings import PassVerificationError
    art = make_clean_artifacts(workload, preset)
    failed: List[str] = []
    for rule, fn in TRACE_MUTATIONS.items():
        rep = verify_trace(fn(art.trace), start_level=art.start_level)
        if rule not in rep.rule_ids():
            failed.append(rule)
    for rule in PASS_MUTATIONS:
        try:
            optimize_trace(art.trace, art.params,
                           PassConfig(start_level=art.start_level),
                           verify=True, passes=[CorruptingPass(rule)])
            failed.append(rule)
        except PassVerificationError as e:
            if rule not in e.report.rule_ids():
                failed.append(rule)
    for rule, fn in SCHEDULE_MUTATIONS.items():
        rep = verify_schedule(fn(art.schedule),
                              start_level=art.start_level,
                              include_trace=False)
        if rule not in rep.rule_ids():
            failed.append(rule)
    for rule, fn in PIM_MUTATIONS.items():
        prog, layout = fn(art.program, art.schedule, art.layout, art.arch)
        rep = analyze_program(prog, art.schedule, art.arch, layout)
        if rule not in rep.rule_ids():
            failed.append(rule)
    return failed


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--smoke", action="store_true",
                    help="small parameter point (log_n=10, 8 levels)")
    ap.add_argument("--workloads", nargs="*", default=None)
    ap.add_argument("--presets", nargs="*", default=None,
                    choices=sorted(PRESETS))
    ap.add_argument("--jsonl", default=None,
                    help="append one json line per artifact report")
    ap.add_argument("--prove", action="store_true",
                    help="also prove every rule fires on a seeded "
                         "mutation")
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.smoke:
        params = test_params(log_n=10, n_levels=8, dnum=2)
        start_level = params.n_levels - 1
    else:
        params = paper_params_bootstrap()
        start_level = params.n_levels - 1

    reports = sweep(params, start_level, workloads=args.workloads,
                    presets=args.presets, verbose=args.verbose)
    n_err = sum(len(r.errors) for r in reports)
    n_warn = sum(len(r.warnings) for r in reports)
    wall = sum(r.wall_s for r in reports)
    print(f"lint: {len(reports)} artifacts, {n_err} errors, "
          f"{n_warn} warnings ({wall * 1e3:.1f} ms verify wall)")
    for r in reports:
        if r.findings:
            print(r.format_table())

    if args.jsonl:
        with open(args.jsonl, "a") as fh:
            for r in reports:
                fh.write(json.dumps(r.to_jsonable()) + "\n")

    rc = 1 if n_err else 0
    if args.prove:
        failed = prove()
        proven = len(RULES) - len(failed)
        print(f"prove: {proven}/{len(RULES)} rules fire on seeded "
              f"mutations")
        if failed:
            print("  rules that did NOT fire: " + ", ".join(failed))
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
