"""Static verification layer: trace/schedule verifiers and the PIM
hazard analyzer (DESIGN.md §14).

Everything here runs WITHOUT ciphertext math — pure walks over the
artifacts the compile pipeline already produces:

* ``verify_trace``     — SSA/interface structure, level-budget and
                         scale-width inference, liveness lints
* ``verify_schedule``  — stage coverage, cross-stage topological
                         order, round/partition shape, cost recheck
* ``verify_pass``      — per-pass semantic diff (interface + constant
                         provenance), used by
                         ``optimize_trace(..., verify=True)``
* ``analyze_program``  — RAW/WAR hazards, orphaned LOAD/STOREs,
                         placement/capacity invariants, bank balance

Reporting is shared (`Finding`/`Report`, catalogue in `RULES`);
`VerificationError` carries a report across the verify-on-miss and
``--verify`` flows. The mutation harness (`repro.analysis.mutate`)
and lint gate (`python -m repro.analysis.lint`) are leaf modules —
import them directly.
"""
from repro.analysis.findings import (ERROR, RULES, WARN, Finding,
                                     PassVerificationError, Report, Rule,
                                     VerificationError)
from repro.analysis.pim_hazards import analyze_program
from repro.analysis.verify_ir import verify_trace
from repro.analysis.verify_schedule import verify_pass, verify_schedule

__all__ = [
    "ERROR", "WARN", "RULES", "Rule", "Finding", "Report",
    "VerificationError", "PassVerificationError",
    "verify_trace", "verify_schedule", "verify_pass", "analyze_program",
]
