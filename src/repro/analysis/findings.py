"""Shared reporting structure for the static verification layer.

Every verifier in `repro.analysis` (trace IR, schedule/pass
invariants, PIM hazards) reports through one vocabulary: a `Finding`
names the violated rule, its severity, the locus (op / stage / instr)
and a fix hint; a `Report` collects the findings of one artifact
sweep. The rule catalogue (`RULES`) is the single source of truth for
rule ids and severities — the mutation harness (`repro.analysis
.mutate`) iterates it to prove every rule can fire, and DESIGN.md §14
documents it.

Severity model:

* ``error`` — the artifact violates an invariant the runtime relies
  on; serving it would produce wrong results or crash later. The lint
  CLI exits non-zero and verify-on-miss raises `VerificationError`.
* ``warn``  — legal but suspicious (dead code, cost drift, bank
  imbalance); surfaced, never fatal.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


ERROR = "error"
WARN = "warn"
_RANK = {ERROR: 0, WARN: 1}


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    severity: str
    summary: str


_CATALOGUE = [
    # -- trace IR (repro.analysis.verify_ir) -----------------------------
    Rule("T-DEF-USE", ERROR,
         "operand references a later or out-of-range op (SSA def-before-"
         "use; with dense indices this also guarantees acyclicity)"),
    Rule("T-INDEX", ERROR, "op.idx does not match its position"),
    Rule("T-KIND", ERROR, "unknown op kind"),
    Rule("T-ARITY", ERROR, "wrong operand count for the op kind"),
    Rule("T-META", ERROR,
         "required meta key missing (rotate.step, pmul/padd const)"),
    Rule("T-IFACE", ERROR,
         "inputs/outputs/consts interface lists inconsistent with the ops"),
    Rule("T-LEVEL", ERROR,
         "annotated level inconsistent with static inference "
         "(core.trace.infer_levels rules)"),
    Rule("T-BUDGET", ERROR,
         "level budget exhausted: the program is deeper than the modulus "
         "chain (reports the earliest failing op and the latest-legal "
         "bootstrap cut)"),
    Rule("T-SCALE", ERROR,
         "add/sub operands at mismatched scale width (a lazy double-"
         "width partial meets a single-width value)"),
    Rule("T-OVERFLOW", ERROR,
         "scale width leaves [1, 2]: a product chain missed its rescale "
         "(overflow) or rescaled below working scale (underflow)"),
    Rule("T-DEAD", WARN, "compute op unreachable from the outputs"),
    Rule("T-UNUSED-IN", WARN, "declared input is never consumed"),
    # -- schedule (repro.analysis.verify_schedule) -----------------------
    Rule("S-COVER", ERROR, "trace compute op not covered by any stage"),
    Rule("S-DUP", ERROR, "op covered by more than one stage slot"),
    Rule("S-ORDER", ERROR,
         "consumer scheduled before its producer across the stage order"),
    Rule("S-ROUND", ERROR,
         "rounds do not partition the stage list in order, or a round "
         "exceeds n_partitions stages"),
    Rule("S-PART", ERROR, "stage partition outside [0, n_partitions)"),
    Rule("S-COST", WARN,
         "stage cost fields diverge from the OpCost recomputation"),
    # -- per-pass semantic diff (repro.analysis.verify_schedule) ---------
    Rule("P-IFACE", ERROR,
         "pass changed the trace interface (input/output arity or input "
         "slot bindings)"),
    Rule("P-CONST", ERROR,
         "pass introduced a constant expression over an unknown base "
         "constant"),
    # -- PIM instruction stream (repro.analysis.pim_hazards) -------------
    Rule("M-OPCODE", ERROR,
         "unknown opcode, out-of-range stage, or negative cycle/byte/row "
         "count"),
    Rule("M-ORDER", ERROR,
         "RAW hazard: a consumer's instructions issue before its "
         "producer's within the stage stream"),
    Rule("M-LOAD-ORDER", ERROR,
         "instruction issues before the stage's constant LOAD (operating "
         "on rows whose constants are still in flight)"),
    Rule("M-STORE-ORDER", ERROR,
         "WAR hazard: work issues after the stage's STORE shipped the "
         "output rows"),
    Rule("M-ORPHAN", ERROR,
         "orphaned or missing LOAD/STORE relative to the stage's "
         "const/output bytes"),
    Rule("M-PLACE", ERROR,
         "exactly-once limb placement violated (a limb row placed never "
         "or more than once)"),
    Rule("M-CAP", ERROR,
         "subarray over capacity within one (round, generation)"),
    Rule("M-BAL", WARN,
         "per-bank utilization imbalance inside one pipeline round"),
]

RULES: Dict[str, Rule] = {r.id: r for r in _CATALOGUE}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    severity: str
    locus: str                       # "op 12 (hmul)" | "stage 3" | "instr 7"
    message: str
    hint: str = ""
    op_idx: Optional[int] = None
    stage: Optional[int] = None
    instr: Optional[int] = None

    def format(self) -> str:
        s = f"{self.severity:<5} {self.rule:<13} @ {self.locus}: {self.message}"
        if self.hint:
            s += f"  [hint: {self.hint}]"
        return s

    def to_jsonable(self) -> dict:
        d = {"rule": self.rule, "severity": self.severity,
             "locus": self.locus, "message": self.message}
        if self.hint:
            d["hint"] = self.hint
        for k in ("op_idx", "stage", "instr"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        return d


@dataclasses.dataclass
class Report:
    """Findings of one verifier run over one artifact."""
    artifact: str                    # trace | schedule | pass | pim
    subject: str = ""                # workload / pass name / preset
    findings: List[Finding] = dataclasses.field(default_factory=list)
    wall_s: float = 0.0

    def add(self, rule: str, locus: str, message: str, hint: str = "",
            **locus_ids) -> Finding:
        f = Finding(rule, RULES[rule].severity, locus, message, hint,
                    **locus_ids)
        self.findings.append(f)
        return f

    def extend(self, other: "Report") -> None:
        self.findings.extend(other.findings)
        self.wall_s += other.wall_s

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == WARN]

    @property
    def ok(self) -> bool:
        return not self.errors

    def rule_ids(self) -> List[str]:
        return sorted({f.rule for f in self.findings})

    def format_table(self) -> str:
        head = (f"{self.artifact}" +
                (f" [{self.subject}]" if self.subject else "") +
                f": {len(self.errors)} errors, "
                f"{len(self.warnings)} warnings")
        lines = [head]
        for f in sorted(self.findings, key=lambda f: _RANK[f.severity]):
            lines.append("  " + f.format())
        return "\n".join(lines)

    def to_jsonable(self) -> dict:
        return {"artifact": self.artifact, "subject": self.subject,
                "n_errors": len(self.errors),
                "n_warnings": len(self.warnings),
                "wall_s": round(self.wall_s, 6),
                "findings": [f.to_jsonable() for f in self.findings]}


class VerificationError(Exception):
    """An error-severity finding in a verify-on-miss / --verify flow.
    Carries the report so callers can render or persist it."""

    def __init__(self, report: Report, context: str = ""):
        self.report = report
        self.context = context
        first = report.errors[0] if report.errors else None
        msg = (f"{context + ': ' if context else ''}"
               f"{len(report.errors)} error finding(s) in "
               f"{report.artifact}"
               f"{' [' + report.subject + ']' if report.subject else ''}")
        if first is not None:
            msg += f"; first: {first.format()}"
        super().__init__(msg)


class PassVerificationError(VerificationError):
    """`PassManager(verify=True)` caught a pass breaking an invariant;
    `pass_name` attributes the first violation to the pass that
    introduced it."""

    def __init__(self, pass_name: str, report: Report):
        self.pass_name = pass_name
        super().__init__(report, context=f"pass {pass_name!r}")
