"""Mutation harness: seed one known-bad artifact per analyzer rule.

Each rule in `repro.analysis.findings.RULES` has a mutator here that
takes a CLEAN artifact (trace / schedule / lowered PIM program) and
corrupts it in exactly the way the rule exists to catch. The
negative-path tests (tests/test_analysis.py) and the lint CLI's
``--prove`` mode iterate these registries to prove every rule fires —
a verifier rule without a firing mutation is dead code.

Mutators never modify their input: traces are cloned through
`compiler.ir.clone_ops`, schedules rebuilt with cloned ops (stage ops
keep sharing the cloned trace's op objects, like real schedules),
programs/layouts rebuilt with fresh instruction/placement lists.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

from repro.compiler.ir import clone_ops
from repro.core.pipeline import PipelineSchedule, Stage
from repro.core.trace import FheOp, FheTrace
from repro.pim.arch import PimArch
from repro.pim.isa import PimInstr, PimProgram
from repro.pim.layout import LayoutPlan, Placement, StageLayout


# ---------------------------------------------------------------------------
# deep-copy helpers (schedules share op objects with their trace — the
# clones must too, or index-based checks would pass vacuously)
# ---------------------------------------------------------------------------

def clone_trace(trace: FheTrace) -> FheTrace:
    return FheTrace(clone_ops(trace), list(trace.inputs),
                    list(trace.outputs), list(trace.consts))


def clone_schedule(schedule: PipelineSchedule) -> PipelineSchedule:
    trace = clone_trace(schedule.trace) if schedule.trace is not None \
        else None
    by_idx = {op.idx: op for op in trace.ops} if trace is not None else {}
    stages = [Stage(st.idx,
                    [by_idx.get(o.idx, o) for o in st.ops],
                    st.partition, st.const_bytes, st.compute_s,
                    st.out_bytes)
              for st in schedule.stages]
    stage_by_idx = {st.idx: st for st in stages}
    rounds = [[stage_by_idx[st.idx] for st in rnd]
              for rnd in schedule.rounds]
    return PipelineSchedule(stages, rounds, schedule.params, schedule.mem,
                            reload_per_op=schedule.reload_per_op,
                            trace=trace)


def clone_program(program: PimProgram) -> PimProgram:
    return PimProgram(program.arch_name, program.freq_hz,
                      list(program.instrs), program.n_stages)


def clone_layout(layout: LayoutPlan) -> LayoutPlan:
    return LayoutPlan(layout.arch,
                      [StageLayout(sl.stage_idx, sl.home_channel,
                                   sl.home_bank, list(sl.placements),
                                   sl.spill_bytes_bank,
                                   sl.spill_bytes_channel)
                       for sl in layout.stages])


def _pick(ops, pred, what: str) -> FheOp:
    for op in ops:
        if pred(op):
            return op
    raise AssertionError(
        f"mutation harness needs a clean artifact containing {what}")


# ---------------------------------------------------------------------------
# trace mutators (T-*)
# ---------------------------------------------------------------------------

def _mut_def_use(trace: FheTrace) -> FheTrace:
    t = clone_trace(trace)
    op = _pick(t.ops, lambda o: o.args, "an op with operands")
    op.args = (op.idx,) + op.args[1:]          # self-reference
    return t


def _mut_index(trace: FheTrace) -> FheTrace:
    t = clone_trace(trace)
    op = _pick(t.ops, lambda o: o.kind not in ("input", "const"),
               "a compute op")
    op.idx += 1
    return t


def _mut_kind(trace: FheTrace) -> FheTrace:
    t = clone_trace(trace)
    op = _pick(t.ops, lambda o: o.kind not in ("input", "const"),
               "a compute op")
    op.kind = "frobnicate"
    return t


def _mut_arity(trace: FheTrace) -> FheTrace:
    t = clone_trace(trace)
    op = _pick(t.ops, lambda o: o.kind in ("hmul", "hadd", "hsub"),
               "a binary op")
    op.args = op.args[:1]
    return t


def _mut_meta(trace: FheTrace) -> FheTrace:
    t = clone_trace(trace)
    op = _pick(t.ops,
               lambda o: o.kind == "rotate" or
               (o.kind in ("pmul", "padd") and
                ("const" in o.meta or "cexpr" in o.meta)),
               "a rotate or pmul/padd op")
    if op.kind == "rotate":
        op.meta.pop("step", None)
    else:
        op.meta.pop("const", None)
        op.meta.pop("cexpr", None)
    return t


def _mut_iface(trace: FheTrace) -> FheTrace:
    t = clone_trace(trace)
    t.outputs.append(len(t.ops) + 7)           # dangling output
    return t


def _mut_level(trace: FheTrace) -> FheTrace:
    t = clone_trace(trace)
    op = _pick(t.ops,
               lambda o: o.kind not in ("input", "const")
               and o.level is not None, "a level-annotated compute op")
    op.level += 1
    return t


def _mut_budget(trace: FheTrace) -> FheTrace:
    # graft a 64-deep eager-product chain onto the first output: one
    # level burned per hmul exhausts any realistic modulus chain
    t = clone_trace(trace)
    src = t.outputs[0]
    for _ in range(64):
        t.ops.append(FheOp(len(t.ops), "hmul", (src, src), {}))
        src = len(t.ops) - 1
    t.outputs = [src]
    return t


def _mut_scale(trace: FheTrace) -> FheTrace:
    # synthetic seed: a lazy double-width product meets a single-width
    # value in an hadd
    ops = [FheOp(0, "input", (), {"slot": 0}),
           FheOp(1, "input", (), {"slot": 1}),
           FheOp(2, "hmul", (0, 1), {"lazy": True}),
           FheOp(3, "hadd", (2, 0), {})]
    return FheTrace(ops, inputs=[0, 1], outputs=[3], consts=[])


def _mut_overflow(trace: FheTrace) -> FheTrace:
    # synthetic seed: lazy product of lazy products — width 4, no
    # rescale anywhere
    ops = [FheOp(0, "input", (), {"slot": 0}),
           FheOp(1, "input", (), {"slot": 1}),
           FheOp(2, "hmul", (0, 1), {"lazy": True}),
           FheOp(3, "hmul", (2, 2), {"lazy": True})]
    return FheTrace(ops, inputs=[0, 1], outputs=[3], consts=[])


def _mut_dead(trace: FheTrace) -> FheTrace:
    t = clone_trace(trace)
    src = t.inputs[0]
    t.ops.append(FheOp(len(t.ops), "hadd", (src, src), {}))
    return t


def _mut_unused_in(trace: FheTrace) -> FheTrace:
    t = clone_trace(trace)
    t.ops.append(FheOp(len(t.ops), "input", (), {"slot": 99}))
    t.inputs.append(len(t.ops) - 1)
    return t


TRACE_MUTATIONS: Dict[str, Callable[[FheTrace], FheTrace]] = {
    "T-DEF-USE": _mut_def_use,
    "T-INDEX": _mut_index,
    "T-KIND": _mut_kind,
    "T-ARITY": _mut_arity,
    "T-META": _mut_meta,
    "T-IFACE": _mut_iface,
    "T-LEVEL": _mut_level,
    "T-BUDGET": _mut_budget,
    "T-SCALE": _mut_scale,
    "T-OVERFLOW": _mut_overflow,
    "T-DEAD": _mut_dead,
    "T-UNUSED-IN": _mut_unused_in,
}


# ---------------------------------------------------------------------------
# pass-level corruptions (P-*) — applied THROUGH the pass pipeline via
# CorruptingPass so PassManager(verify=True) attribution is exercised
# ---------------------------------------------------------------------------

def _mut_pass_iface(trace: FheTrace) -> FheTrace:
    t = clone_trace(trace)
    t.outputs = t.outputs[:-1]                  # drop an output
    return t


def _mut_pass_const(trace: FheTrace) -> FheTrace:
    t = clone_trace(trace)
    op = _pick(t.ops, lambda o: "const" in o.meta or "cexpr" in o.meta,
               "a const-bearing op")
    op.meta.pop("cexpr", None)
    op.meta["const"] = "__phantom_const__"
    return t


PASS_MUTATIONS: Dict[str, Callable[[FheTrace], FheTrace]] = {
    "P-IFACE": _mut_pass_iface,
    "P-CONST": _mut_pass_const,
}


class CorruptingPass:
    """A pass-pipeline stage that applies a seeded corruption — drop it
    into `optimize_trace(..., passes=[...])` to prove
    `PassManager(verify=True)` attributes the violation to it."""

    may_increase_cost = True        # exempt from the cost-revert guard

    def __init__(self, rule: str, name: str = "corrupt"):
        self.rule = rule
        self.name = name
        self._fn = (PASS_MUTATIONS.get(rule) or TRACE_MUTATIONS[rule])

    def run(self, trace: FheTrace, params, config) -> FheTrace:
        return self._fn(trace)


# ---------------------------------------------------------------------------
# schedule mutators (S-*)
# ---------------------------------------------------------------------------

def _smut_cover(schedule: PipelineSchedule) -> PipelineSchedule:
    s = clone_schedule(schedule)
    st = max(s.stages, key=lambda st: len(st.ops))
    st.ops.pop()
    return s


def _smut_dup(schedule: PipelineSchedule) -> PipelineSchedule:
    s = clone_schedule(schedule)
    s.stages[-1].ops.append(s.stages[0].ops[0])
    return s


def _smut_order(schedule: PipelineSchedule) -> PipelineSchedule:
    s = clone_schedule(schedule)
    compute_idx = {o.idx for o in s.trace.compute_ops()}
    for st in reversed(s.stages):
        for op in reversed(st.ops):
            if any(a in compute_idx for a in op.args):
                st.ops.remove(op)
                s.stages[0].ops.insert(0, op)   # consumer before producer
                return s
    raise AssertionError("mutation harness needs a schedule with a "
                         "compute-to-compute dataflow edge")


def _smut_round(schedule: PipelineSchedule) -> PipelineSchedule:
    s = clone_schedule(schedule)
    s.rounds = s.rounds[:-1]
    return s


def _smut_part(schedule: PipelineSchedule) -> PipelineSchedule:
    s = clone_schedule(schedule)
    s.stages[0].partition = s.mem.n_partitions + 1
    return s


def _smut_cost(schedule: PipelineSchedule) -> PipelineSchedule:
    s = clone_schedule(schedule)
    s.stages[0].const_bytes += 987654321
    return s


SCHEDULE_MUTATIONS: Dict[str, Callable[[PipelineSchedule],
                                       PipelineSchedule]] = {
    "S-COVER": _smut_cover,
    "S-DUP": _smut_dup,
    "S-ORDER": _smut_order,
    "S-ROUND": _smut_round,
    "S-PART": _smut_part,
    "S-COST": _smut_cost,
}


# ---------------------------------------------------------------------------
# PIM program/layout mutators (M-*)
# ---------------------------------------------------------------------------

_PimMut = Callable[[PimProgram, PipelineSchedule, LayoutPlan, PimArch],
                   Tuple[PimProgram, LayoutPlan]]


def _pmut_opcode(prog, schedule, layout, arch):
    p = clone_program(prog)
    p.instrs[0] = dataclasses.replace(p.instrs[0], opcode="JMP")
    return p, layout


def _find_dep_pair(schedule: PipelineSchedule):
    """(stage_idx, producer_idx, consumer_idx) with both ops in one
    stage and a dataflow edge between them."""
    for st in schedule.stages:
        in_stage = {o.idx for o in st.ops}
        for op in st.ops:
            for a in op.args:
                if a in in_stage and a != op.idx:
                    return st.idx, a, op.idx
    raise AssertionError("mutation harness needs a stage containing a "
                         "dataflow-dependent op pair")


def _pmut_order(prog, schedule, layout, arch):
    p = clone_program(prog)
    sidx, producer, consumer = _find_dep_pair(schedule)
    # identity-based split: frozen PimInstrs compare by value, and
    # distinct instructions can be equal
    prod_ids = {id(i) for i in p.instrs
                if i.stage == sidx and i.op_idx == producer}
    prod = [i for i in p.instrs if id(i) in prod_ids]
    rest = [i for i in p.instrs if id(i) not in prod_ids]
    # reinsert the producer's block right after the consumer's last instr
    last_cons = max(k for k, i in enumerate(rest)
                    if i.stage == sidx and i.op_idx == consumer)
    p.instrs = rest[:last_cons + 1] + prod + rest[last_cons + 1:]
    return p, layout


def _pmut_load_order(prog, schedule, layout, arch):
    p = clone_program(prog)
    for k, ins in enumerate(p.instrs):
        if ins.opcode == "LOAD":
            nxt = [j for j, x in enumerate(p.instrs)
                   if x.stage == ins.stage and j > k
                   and x.opcode in ("ROWOP", "NTT")]
            if nxt:
                j = nxt[0]
                p.instrs[k], p.instrs[j] = p.instrs[j], p.instrs[k]
                return p, layout
    raise AssertionError("mutation harness needs a stage with a LOAD "
                         "followed by compute")


def _pmut_store_order(prog, schedule, layout, arch):
    p = clone_program(prog)
    for k in range(len(p.instrs) - 1, 0, -1):
        ins = p.instrs[k]
        prev = p.instrs[k - 1]
        if ins.opcode == "STORE" and prev.stage == ins.stage \
                and prev.opcode != "STORE":
            p.instrs[k], p.instrs[k - 1] = prev, ins
            return p, layout
    raise AssertionError("mutation harness needs a STORE preceded by "
                         "same-stage work")


def _pmut_orphan(prog, schedule, layout, arch):
    p = clone_program(prog)
    for k, ins in enumerate(p.instrs):
        if ins.opcode == "STORE" \
                and schedule.stages[ins.stage].out_bytes:
            del p.instrs[k]
            return p, layout
    raise AssertionError("mutation harness needs a STORE for a stage "
                         "with output bytes")


def _pmut_place(prog, schedule, layout, arch):
    lay = clone_layout(layout)
    for sl in lay.stages:
        if sl.placements:
            sl.placements.pop(0)
            return prog, lay
    raise AssertionError("mutation harness needs a layout with "
                         "placements")


def _pmut_cap(prog, schedule, layout, arch):
    lay = clone_layout(layout)
    for sl in lay.stages:
        if sl.placements:
            p0: Placement = sl.placements[0]
            sl.placements[0] = dataclasses.replace(
                p0, nbytes=arch.subarray_bytes + 1)
            return prog, lay
    raise AssertionError("mutation harness needs a layout with "
                         "placements")


def _pmut_bal(prog, schedule, layout, arch):
    p = clone_program(prog)
    # pick a non-bootstrap round with >= 2 stages and inflate its
    # busiest stage far past the analyzer's imbalance ratio
    for rnd in schedule.rounds:
        if len(rnd) < 2 or any(op.kind == "bootstrap"
                               for st in rnd for op in st.ops):
            continue
        stage_cycles = {st.idx: sum(i.cycles for i in p.instrs
                                    if i.stage == st.idx) for st in rnd}
        hot = max(stage_cycles, key=stage_cycles.get)
        p.instrs = [dataclasses.replace(i, cycles=i.cycles * 1e7)
                    if i.stage == hot else i for i in p.instrs]
        return p, layout
    raise AssertionError("mutation harness needs a bootstrap-free "
                         "round with >= 2 stages")


PIM_MUTATIONS: Dict[str, _PimMut] = {
    "M-OPCODE": _pmut_opcode,
    "M-ORDER": _pmut_order,
    "M-LOAD-ORDER": _pmut_load_order,
    "M-STORE-ORDER": _pmut_store_order,
    "M-ORPHAN": _pmut_orphan,
    "M-PLACE": _pmut_place,
    "M-CAP": _pmut_cap,
    "M-BAL": _pmut_bal,
}


ALL_MUTATIONS: List[str] = (list(TRACE_MUTATIONS) + list(PASS_MUTATIONS)
                            + list(SCHEDULE_MUTATIONS)
                            + list(PIM_MUTATIONS))


# ---------------------------------------------------------------------------
# clean artifact bundle for tests and `lint --prove`
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Artifacts:
    """One consistent (trace -> schedule -> layout -> program) chain on
    the smoke parameter point — verifies clean, mutates dirty."""
    params: object
    mem: object
    arch: PimArch
    start_level: int
    trace: FheTrace
    schedule: PipelineSchedule
    layout: LayoutPlan
    program: PimProgram


def make_clean_artifacts(workload: str = "matvec",
                         preset: str = "fhemem", *,
                         optimize: bool = True,
                         const_budget_frac: float = 0.005) -> Artifacts:
    # const_budget_frac deliberately tiny: the smoke point's constants
    # are small, and the harness needs MULTI-stage schedules (rounds
    # with >= 2 resident banks) so the ordering/balance mutations have
    # something to corrupt
    """Trace, compile, map, place and lower one registered workload on
    the smoke parameter point (same point serve_fhe --smoke uses).
    Deferred imports keep `repro.analysis.mutate` importable without
    the runtime stack."""
    from repro.compiler import PassConfig, optimize_trace
    from repro.core.params import test_params
    from repro.core.pipeline import generate_load_save_pipeline
    from repro.core.trace import infer_levels, trace_program
    from repro.pim.arch import get_arch, memory_model
    from repro.pim.layout import plan_layout
    from repro.pim.lower import lower_schedule
    from repro.runtime import workloads as wl

    table = {
        "helr": (wl.make_helr_iter(), 2, wl.HELR_CONSTS),
        "lola": (wl.lola_infer, 1, wl.LOLA_CONSTS),
        "matvec": (wl.make_matvec(16), 1, wl.matvec_consts(16)),
        "poly": (wl.make_poly_eval(12), 1, wl.poly_consts(12)),
    }
    fn, n_in, consts = table[workload]
    params = test_params(log_n=10, n_levels=8, dnum=2)
    start = params.n_levels - 1
    trace = trace_program(fn, n_in, consts)
    if optimize:
        trace.ops[trace.inputs[0]].level = start   # record the start
        trace, _ = optimize_trace(
            trace, params, PassConfig(start_level=start))
    else:
        infer_levels(trace, start_level=start)
    mem = memory_model(preset)
    schedule = generate_load_save_pipeline(trace, params, mem,
                                           const_budget_frac)
    arch = get_arch(preset)
    layout = plan_layout(schedule, arch)
    program = lower_schedule(schedule, arch, layout)
    return Artifacts(params, mem, arch, start, trace, schedule, layout,
                     program)


__all__ = ["TRACE_MUTATIONS", "PASS_MUTATIONS", "SCHEDULE_MUTATIONS",
           "PIM_MUTATIONS", "ALL_MUTATIONS", "CorruptingPass",
           "Artifacts", "make_clean_artifacts",
           "clone_trace", "clone_schedule", "clone_program",
           "clone_layout"]
