"""Static verifier for `PipelineSchedule`s and per-pass semantic diffs.

Schedule rules: every trace compute op covered exactly once across the
stages (S-COVER/S-DUP), dataflow topological order respected across
stage boundaries (S-ORDER — the mapper schedules in SSA order, and the
executor's wave semantics depend on it), rounds partitioning the stage
list with at most `n_partitions` resident stages (S-ROUND), partition
assignments in range (S-PART), and the stage cost fields agreeing with
an independent `OpCost` recomputation (S-COST, warn — cost drift makes
the latency model lie, it does not corrupt results). The schedule's
trace is re-verified through `verify_ir` (rescale-before-overflow and
the rest of the T-rules ride along).

Per-pass diffing (`verify_pass`): called by `optimize_trace(...,
verify=True)` / `PassManager(verify=True)` after every applied pass,
so the first invariant violation is attributed to the pass that
introduced it (P-IFACE/P-CONST plus the structural T-rule sweep on
the pass's output; the semantic rules — level budget, scale widths,
liveness — are whole-pipeline invariants deferred to the final full
verification, keeping per-pass overhead inside fig17's <5%-of-
compile-wall gate).
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Set, Tuple

from repro.analysis.findings import Report
from repro.analysis.verify_ir import verify_trace
from repro.core.pipeline import PipelineSchedule
from repro.core.trace import FheTrace, evk_bytes, op_cost

_KS_KINDS = ("hmul", "rotate", "conjugate")


def _recompute_stage(params, mem, ops) -> Tuple[int, int, float, int]:
    """(raw_const_bytes, evk_shared_const_bytes, compute_s, out_bytes) —
    mirrors core.pipeline._stage_cost plus the load-save mapper's
    shared-evk correction, so either mapper's stages verify clean."""
    const_b, comp, out_b = 0, 0.0, 0
    n_ks = 0
    for o in ops:
        c = op_cost(params, o)
        const_b += c.const_bytes
        comp += mem.compute_seconds(c, params.n)
        out_b = c.out_bytes
        if o.kind in _KS_KINDS:
            n_ks += 1
    shared = const_b
    if n_ks > 1:
        shared -= (n_ks - 1) * evk_bytes(params)
    return const_b, shared, comp, out_b


def verify_schedule(schedule: PipelineSchedule, *,
                    start_level: Optional[int] = None,
                    bootstrap_to: Optional[int] = None,
                    include_trace: bool = True,
                    subject: str = "") -> Report:
    rep = Report("schedule", subject)
    t0 = time.perf_counter()
    trace = schedule.trace
    if include_trace and trace is not None:
        rep.extend(verify_trace(trace, start_level=start_level,
                                bootstrap_to=bootstrap_to,
                                subject=subject))

    mem = schedule.mem
    # coverage: exactly one stage slot per trace compute op
    pos: Dict[int, int] = {}
    flat = 0
    for st in schedule.stages:
        for op in st.ops:
            if op.idx in pos:
                rep.add("S-DUP", f"stage {st.idx}",
                        f"op {op.idx} ({op.kind}) already scheduled "
                        f"earlier in the stage order",
                        "each op must run exactly once",
                        op_idx=op.idx, stage=st.idx)
            else:
                pos[op.idx] = flat
            flat += 1
    if trace is not None:
        for op in trace.compute_ops():
            if op.idx not in pos:
                rep.add("S-COVER", f"op {op.idx} ({op.kind})",
                        "not covered by any stage",
                        "re-map the trace", op_idx=op.idx)
        compute_idx = {o.idx for o in trace.compute_ops()}
        # topological order across stage boundaries
        for st in schedule.stages:
            for op in st.ops:
                for a in op.args:
                    if a in compute_idx and a in pos \
                            and pos[a] >= pos.get(op.idx, -1) >= 0:
                        rep.add(
                            "S-ORDER", f"stage {st.idx}",
                            f"op {op.idx} ({op.kind}) consumes op {a} "
                            f"scheduled at or after it",
                            "stages must respect SSA dataflow order",
                            op_idx=op.idx, stage=st.idx)

    # rounds partition the stage list, in order, bounded by n_partitions
    flat_rounds = [st for rnd in schedule.rounds for st in rnd]
    if [st.idx for st in flat_rounds] != [st.idx for st in schedule.stages]:
        rep.add("S-ROUND", "rounds",
                f"rounds flatten to stages "
                f"{[st.idx for st in flat_rounds]} != "
                f"{[st.idx for st in schedule.stages]}",
                "rounds must partition the stage list in order")
    for ri, rnd in enumerate(schedule.rounds):
        if len(rnd) > mem.n_partitions:
            rep.add("S-ROUND", f"round {ri}",
                    f"{len(rnd)} resident stages > n_partitions="
                    f"{mem.n_partitions}",
                    "a round cannot hold more stages than partitions")

    for st in schedule.stages:
        if not 0 <= st.partition < mem.n_partitions:
            rep.add("S-PART", f"stage {st.idx}",
                    f"partition {st.partition} outside "
                    f"[0, {mem.n_partitions})", stage=st.idx)
        raw, shared, comp, out_b = _recompute_stage(
            schedule.params, mem, st.ops)
        if st.const_bytes not in (raw, shared):
            rep.add("S-COST", f"stage {st.idx}",
                    f"const_bytes={st.const_bytes} matches neither the "
                    f"raw ({raw}) nor evk-shared ({shared}) "
                    f"recomputation", stage=st.idx)
        if abs(st.compute_s - comp) > 1e-6 * max(abs(comp), 1e-30):
            rep.add("S-COST", f"stage {st.idx}",
                    f"compute_s={st.compute_s:.6e} vs recomputed "
                    f"{comp:.6e}", stage=st.idx)
        if st.out_bytes != out_b:
            rep.add("S-COST", f"stage {st.idx}",
                    f"out_bytes={st.out_bytes} vs recomputed {out_b}",
                    stage=st.idx)
    rep.wall_s = time.perf_counter() - t0
    return rep


# ---------------------------------------------------------------------------
# per-pass semantic diffing
# ---------------------------------------------------------------------------

def _base_const_refs(trace: FheTrace) -> Set[str]:
    """Base plaintext-constant names a trace references: plain
    ``meta['const']`` bindings plus the ``ref`` leaves of derived
    constant expressions (compiler/ir.py cexpr grammar)."""
    names: Set[str] = set()
    stack = []
    for op in trace.ops:
        meta = op.meta
        if "cexpr" in meta:
            stack.append(meta["cexpr"])
        elif "const" in meta:
            names.add(meta["const"])
    while stack:                    # iterative: runs twice per pass diff
        e = stack.pop()
        if not isinstance(e, tuple) or not e:
            continue
        if e[0] == "ref":
            names.add(e[1])
        elif e[0] == "rot":
            stack.append(e[1])
        else:                       # ("mul"|"add", a, b)
            stack.append(e[1])
            stack.append(e[2])
    return names


def _input_slots(trace: FheTrace):
    return sorted(trace.ops[i].meta.get("slot")
                  for i in trace.inputs
                  if 0 <= i < len(trace.ops))


def verify_pass(before: FheTrace, after: FheTrace, *,
                check_budget: bool = False,
                start_level: Optional[int] = None,
                bootstrap_to: Optional[int] = None,
                subject: str = "") -> Report:
    """Diff one pass application: interface preservation (P-IFACE),
    constant provenance (P-CONST), and a trace-IR sweep on the output.
    ``check_budget`` defaults off — mid-pipeline traces may be legally
    deeper than the chain until bootstrap insertion runs — and in that
    mode the sweep is structural-only: scale/liveness are whole-
    pipeline invariants the final full verification re-checks, so
    rerunning them after every pass would only inflate the verify
    overhead that fig17's gate bounds."""
    rep = Report("pass", subject)
    t0 = time.perf_counter()
    if len(after.inputs) != len(before.inputs):
        rep.add("P-IFACE", "inputs",
                f"{len(before.inputs)} inputs -> {len(after.inputs)}",
                "passes must not add or drop program inputs")
    elif _input_slots(after) != _input_slots(before):
        rep.add("P-IFACE", "inputs",
                f"input slot bindings changed: "
                f"{_input_slots(before)} -> {_input_slots(after)}")
    if len(after.outputs) != len(before.outputs):
        rep.add("P-IFACE", "outputs",
                f"{len(before.outputs)} outputs -> "
                f"{len(after.outputs)}",
                "passes must preserve the output arity")
    new_refs = _base_const_refs(after) - _base_const_refs(before)
    if new_refs:
        rep.add("P-CONST", "consts",
                f"references unknown base constant(s) "
                f"{sorted(new_refs)}",
                "derived constants must be expressions over the "
                "input trace's names")
    rep.extend(verify_trace(after, check_budget=check_budget,
                            structural_only=not check_budget,
                            start_level=start_level,
                            bootstrap_to=bootstrap_to, subject=subject))
    rep.wall_s = time.perf_counter() - t0
    return rep


__all__ = ["verify_schedule", "verify_pass"]
