"""Hierarchical FHEmem hardware model (paper §III-IV): channels → banks
→ subarrays → mats, with a bit-serial long-bitwidth modmul cycle model
and scope-dependent data-movement bandwidths.

The flat 16-partition `MemoryModel` in core/pipeline.py is the
*degenerate case* of this hierarchy: `PimArch.to_memory_model()`
projects an arch onto the flat model (banks become partitions, the
bank-level lane throughput becomes `modmul_throughput`, the
inter-bank permutation network becomes `transfer_bw`), and the
``flat`` preset round-trips to the MemoryModel defaults exactly — the
regression `tests/test_pim.py` pins. The analytic serving backend and
the PIM discrete-event backend therefore share ONE preset registry
(`serve_fhe --mem-profile`, `benchmarks/common.mem_profile`): no
duplicated magic constants.

Three presets:

* ``fhemem`` — the paper's configuration: many banks of bit-serial
  subarray/mat compute (one element per bit-line column, a w-bit
  modmul costs O(w²) row activations) joined by an inter-bank
  permutation network for NTT/rotation data movement.
* ``hbm2`` — an HBM2-PIM-like point (Aquabolt-XL style): wide SIMD
  units near the bank IO (no bit-serial in-mat compute), weaker
  per-bank throughput, and no permutation network — inter-bank data
  rides the channel bus.
* ``flat`` — the degenerate preset reproducing `MemoryModel()`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core.pipeline import MemoryModel

WORD = 8   # stored bytes per coefficient (core/trace.py WORD)

# data-movement scopes, innermost to outermost
SCOPES = ("intra", "bank", "channel", "load")


@dataclasses.dataclass(frozen=True)
class PimArch:
    """One FHEmem hardware point. Frozen so archs can key caches."""
    name: str
    # -- hierarchy geometry --------------------------------------------------
    n_channels: int = 8
    banks_per_channel: int = 16
    subarrays_per_bank: int = 64
    mats_per_subarray: int = 32
    mat_rows: int = 512                  # DRAM rows per mat
    mat_cols: int = 2048                 # bit-line columns = bit-serial lanes
    # -- timing --------------------------------------------------------------
    freq_hz: float = 1e9                 # internal command clock
    t_row_cycles: float = 2.0            # activate+precharge per row command
    limb_bits: int = 32                  # coefficient bitwidth (word32 mode)
    add_cycles: float = 1.0              # cycles per bit-serial add step
    mod_hamming_weight: int = 3          # Solinas popcount h: reduction adds
    # -- bandwidths (bytes/s) ------------------------------------------------
    load_bw: float = 64e9                # off-chip constants into a bank
    intra_bank_bw: float = 2e12          # subarray<->subarray row copies
    #                                      (LISA-style full-row moves: the
    #                                      movement PIM makes nearly free)
    inter_bank_bw: float = 1e12          # permutation network, banks/channel
    #                                      (per-bank links, far above the
    #                                      shared-bus 256e9 the flat model
    #                                      assumes — the paper's §IV-C/D
    #                                      NTT/rotation movement fabric)
    inter_channel_bw: float = 512e9      # across channels (TSV bundles)
    # -- cost-model knobs (shared with MemoryModel) --------------------------
    ntt_row_cost: float = 1.0
    ks_modmul_weight: float = 1.25
    # NTT butterfly passes shuffle operands between mats (the paper's
    # vertical/horizontal inter-mat phases); billed per pass against
    # intra_bank_bw when True (hierarchy presets) — the flat/wide
    # presets keep the MemoryModel convention of compute-only NTTs
    ntt_inter_mat_shuffle: bool = False
    # degenerate override: bill modmul rows at this flat elementwise
    # throughput (elements/s per bank) instead of the bit-serial model
    flat_modmul_throughput: Optional[float] = None
    # degenerate archs bill EXACTLY like the flat MemoryModel: one
    # transfer link, no layout-scope distinctions, no spill traffic —
    # the regression anchor tying the PIM backend to the analytic one
    degenerate: bool = False

    # -- derived geometry ----------------------------------------------------

    @property
    def n_banks(self) -> int:
        return self.n_channels * self.banks_per_channel

    @property
    def mat_bytes(self) -> int:
        return self.mat_rows * self.mat_cols // 8

    @property
    def subarray_bytes(self) -> int:
        return self.mats_per_subarray * self.mat_bytes

    @property
    def bank_bytes(self) -> int:
        return self.subarrays_per_bank * self.subarray_bytes

    @property
    def total_bytes(self) -> int:
        return self.n_banks * self.bank_bytes

    @property
    def lanes_per_bank(self) -> int:
        """Bit-serial lanes: one element per bit-line column."""
        return (self.subarrays_per_bank * self.mats_per_subarray
                * self.mat_cols)

    # -- cycle model ---------------------------------------------------------

    def modmul_cycles(self, bits: Optional[int] = None) -> float:
        """Cycles for ONE bit-serial modular multiply in a lane: w
        shift-add partial products of w bits each, h·w reduction adds
        (Solinas fold, paper §IV-B), plus row activate/precharge for
        streaming the three w-bit operands through the sense amps."""
        w = bits if bits is not None else self.limb_bits
        return (w * (w + self.mod_hamming_weight) * self.add_cycles
                + 3 * w * self.t_row_cycles)

    def rows_seconds(self, row_equiv: float, n: int) -> float:
        """Seconds for `row_equiv` N-element modmul-row equivalents on
        one bank. Bit-serial hierarchy: the bank's lanes chew
        ``lanes_per_bank`` elements per wave of `modmul_cycles()`, so
        many rows of a small ring run concurrently (limb-parallel
        modmul — the layout spreads limbs across subarrays for exactly
        this). Degenerate/wide presets bill the flat throughput."""
        if self.flat_modmul_throughput is not None:
            return row_equiv * n / self.flat_modmul_throughput
        waves = math.ceil(row_equiv * n / self.lanes_per_bank)
        return waves * self.modmul_cycles() / self.freq_hz

    def modmul_row_seconds(self, n: int) -> float:
        """Seconds for one N-element modmul row on one bank."""
        return self.rows_seconds(1, n)

    def ntt_pass_seconds(self, n: int) -> float:
        """One full N-point NTT pass over one limb (butterfly compute
        only; the inter-mat shuffle traffic is a separate XFER the
        lowerer emits when ``ntt_inter_mat_shuffle``)."""
        return self.rows_seconds(
            self.ntt_row_cost * math.log2(max(n, 2)), n)

    def ntt_shuffle_bytes(self, n: int) -> int:
        """Bytes one NTT pass moves between mats: butterfly strides
        wider than a mat's column count reposition the full limb (the
        paper's vertical/horizontal inter-mat phases); smaller strides
        stay inside the mat's sense amps and move nothing."""
        if not self.ntt_inter_mat_shuffle:
            return 0
        stages_crossing = max(0, int(math.log2(max(n, 2)))
                              - int(math.log2(self.mat_cols)))
        return stages_crossing * n * WORD

    def scope_bw(self, scope: str) -> float:
        """Bytes/s available to a transfer of the given scope."""
        return {"intra": self.intra_bank_bw,
                "bank": self.inter_bank_bw,
                "channel": self.inter_channel_bw,
                "load": self.load_bw}[scope]

    def xfer_seconds(self, nbytes: int, scope: str) -> float:
        return nbytes / self.scope_bw(scope) if nbytes else 0.0

    def bank_coords(self, partition: int) -> tuple:
        """(channel, bank-in-channel) of a pipeline partition (stages
        are homed round-robin over the global bank space)."""
        g = partition % self.n_banks
        return g // self.banks_per_channel, g % self.banks_per_channel

    def transfer_scope(self, partition_a: int, partition_b: int) -> str:
        """Scope of a ciphertext hop between two partitions' banks."""
        ca, ba = self.bank_coords(partition_a)
        cb, bb = self.bank_coords(partition_b)
        if (ca, ba) == (cb, bb):
            return "intra"
        return "bank" if ca == cb else "channel"

    # -- flat-model adapter --------------------------------------------------

    def elems_per_second(self) -> float:
        """Aggregate elementwise modmul throughput of ONE bank."""
        if self.flat_modmul_throughput is not None:
            return self.flat_modmul_throughput
        return self.lanes_per_bank * self.freq_hz / self.modmul_cycles()

    def to_memory_model(self) -> MemoryModel:
        """Project the hierarchy onto the flat MemoryModel: banks are
        partitions, bank lane throughput is `modmul_throughput`, the
        permutation network is `transfer_bw`. The analytic backend and
        the mapper consume this; the PIM backend consumes the arch —
        one registry, two fidelities."""
        return MemoryModel(
            n_partitions=self.n_banks,
            partition_bytes=self.bank_bytes,
            load_bw=self.load_bw,
            modmul_throughput=self.elems_per_second(),
            ntt_row_cost=self.ntt_row_cost,
            transfer_bw=self.inter_bank_bw,
            ks_modmul_weight=self.ks_modmul_weight)


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------

# the paper's FHEmem point: 128 banks of bit-serial subarray compute
# (~4M lanes/bank, 256 MiB banks) + inter-bank permutation network
FHEMEM = PimArch(name="fhemem", ntt_inter_mat_shuffle=True)

# HBM2-PIM-like (Aquabolt-XL): wide SIMD units at the bank IO, so far
# fewer "lanes" than in-mat bit-serial; no permutation network —
# inter-bank traffic rides the (much slower) channel bus. 8 GiB device.
HBM2 = PimArch(
    name="hbm2",
    n_channels=8, banks_per_channel=16,
    subarrays_per_bank=32, mats_per_subarray=32,
    mat_rows=512, mat_cols=1024,          # 64 MiB banks
    freq_hz=1.2e9,
    load_bw=32e9,
    intra_bank_bw=128e9,
    inter_bank_bw=25.6e9,                 # pseudo-channel bus
    inter_channel_bw=25.6e9,
    flat_modmul_throughput=1.5e11)        # wide units, not bit-serial

# degenerate preset == MemoryModel() defaults: 16 banks x 64 MiB,
# 2e12 elems/s, one 256e9 transfer scope, compute-only NTTs
FLAT = PimArch(
    name="flat",
    n_channels=4, banks_per_channel=4,
    subarrays_per_bank=16, mats_per_subarray=16,
    mat_rows=512, mat_cols=4096,          # 64 MiB banks
    freq_hz=1e9,
    load_bw=64e9,
    intra_bank_bw=256e9,
    inter_bank_bw=256e9,
    inter_channel_bw=256e9,
    flat_modmul_throughput=2.0e12,
    degenerate=True)

PRESETS: Dict[str, PimArch] = {a.name: a for a in (FHEMEM, HBM2, FLAT)}


def get_arch(name: str) -> PimArch:
    try:
        return PRESETS[name]
    except KeyError:
        raise ValueError(f"unknown pim preset {name!r} "
                         f"(expected one of {sorted(PRESETS)})") from None


def memory_model(name: str) -> MemoryModel:
    """The shared preset registry's flat-model side: what `serve_fhe
    --mem-profile` and `benchmarks.common.mem_profile` hand to the
    analytic backend / pipeline mapper."""
    return get_arch(name).to_memory_model()


def flat_arch_from_memory_model(mem: MemoryModel,
                                name: str = "flat-custom") -> PimArch:
    """Wrap an arbitrary flat MemoryModel in a degenerate arch billing
    EXACTLY like it (the adapter direction the PIM backend uses when
    handed a mem that matches no preset, e.g. the serving smoke's tiny
    4-partition model). Geometry is synthesized to tile the partition
    capacity; all transfer scopes collapse to `transfer_bw`."""
    subarrays, mats, rows = 16, 16, 512
    cols = max(8, mem.partition_bytes * 8 // (subarrays * mats * rows))
    return PimArch(
        name=name,
        n_channels=1, banks_per_channel=mem.n_partitions,
        subarrays_per_bank=subarrays, mats_per_subarray=mats,
        mat_rows=rows, mat_cols=cols,
        freq_hz=1e9,
        load_bw=mem.load_bw,
        intra_bank_bw=mem.transfer_bw,
        inter_bank_bw=mem.transfer_bw,
        inter_channel_bw=mem.transfer_bw,
        ntt_row_cost=mem.ntt_row_cost,
        ks_modmul_weight=mem.ks_modmul_weight,
        flat_modmul_throughput=mem.modmul_throughput,
        degenerate=True)


def arch_for_memory_model(mem: MemoryModel) -> PimArch:
    """Recover the arch a MemoryModel came from: a preset whose
    projection equals `mem`, else a degenerate wrap of `mem`."""
    for arch in PRESETS.values():
        if arch.to_memory_model() == mem:
            return arch
    return flat_arch_from_memory_model(mem)
