"""`PimBackend` — discrete-event simulation of a lowered PIM
instruction stream behind the runtime's backend contract
(``execute(schedule, batch, ...) -> seconds``, DESIGN.md §9/§10).

Schedules are lowered once (layout + instruction stream memoized per
schedule object — schedules themselves live in the CompileCache, so
steady-state serving never re-lowers) and every batch replays the
stream on a virtual clock with the same round semantics as the
analytic backend: within a round, a stage's busy time is its constant
LOAD (KeyCache-aware: a resident stage loads nothing) plus
max(compute+movement, output transfer) scaled by the batch; the round
costs its worst stage plus pipeline fill. With a ``degenerate`` arch
the per-stage buckets equal `PipelineSchedule.stage_times` to float
precision, so AnalyticBackend and PimBackend(flat) agree within 1% —
the regression that anchors the hierarchy model to the flat one.

Per-workload compute/movement/load breakdowns of the last executed
batch are kept on the backend (`last_breakdown`) for
benchmarks/fig19_pim.py.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.pipeline import PipelineSchedule
from repro.pim.arch import PimArch, arch_for_memory_model, get_arch
from repro.pim.isa import PimProgram
from repro.pim.layout import LayoutPlan, plan_layout
from repro.pim.lower import lower_schedule


class PimBackend:
    """Hierarchical-hardware sibling of AnalyticBackend: same contract,
    same virtual clock, but every second is accounted instruction by
    instruction on a `PimArch` instead of the flat MemoryModel."""

    def __init__(self, arch: Optional[PimArch] = None,
                 preset: str = "fhemem", verify: bool = False):
        self.arch = arch if arch is not None else get_arch(preset)
        # verify=True runs the static hazard analyzer
        # (repro.analysis.pim_hazards) over every freshly lowered
        # program; an error finding raises VerificationError before the
        # stream can execute
        self.verify = verify
        # keyed by id(schedule); the schedule reference is retained so
        # a recycled id can never alias a dead schedule
        self._lowered: Dict[int, Tuple[PipelineSchedule, LayoutPlan,
                                       PimProgram]] = {}
        # workload -> per-stage {stage, load_s, compute_s, move_s} of
        # the most recent batch (fig19's breakdown source)
        self.last_breakdown: Dict[str, List[dict]] = {}
        # verify-on-lower accounting, aggregated by serve_fhe --verify
        self.verify_wall_s = 0.0
        self.verify_findings = 0

    def program_for(self, schedule: PipelineSchedule) -> PimProgram:
        key = id(schedule)
        hit = self._lowered.get(key)
        if hit is None or hit[0] is not schedule:
            layout = plan_layout(schedule, self.arch)
            prog = lower_schedule(schedule, self.arch, layout)
            if self.verify:
                from repro.analysis.findings import VerificationError
                from repro.analysis.pim_hazards import analyze_program
                rep = analyze_program(prog, schedule, self.arch, layout)
                self.verify_wall_s += rep.wall_s
                self.verify_findings += len(rep.findings)
                if not rep.ok:
                    raise VerificationError(rep, context="pim lower")
            self._lowered[key] = (schedule, layout, prog)
            return prog
        return hit[2]

    def layout_for(self, schedule: PipelineSchedule) -> LayoutPlan:
        self.program_for(schedule)
        return self._lowered[id(schedule)][1]

    def round_seconds(self, schedule: PipelineSchedule, rnd, b: int, *,
                      key_cache, metrics, workload: str,
                      breakdown: Optional[List[dict]] = None,
                      obs=None) -> float:
        """One pipeline round of the lowered instruction stream at batch
        occupancy ``b`` — the simulation unit the fleet's
        continuous-batching path steps (same contract as
        AnalyticBackend.round_seconds).

        With ``obs`` (repro.obs.ExecObs) carrying a tracer, the round
        emits a ``round`` span plus per-stage ``stage`` spans
        attributed all the way down to the lowered ISA: per
        instruction-class (LOAD/ROWOP/NTT/XFER/STORE) and per-bank
        cycle counts from the instruction stream — the trace-view
        analogue of fig19's breakdown. With ``metrics.telemetry``
        armed (obs supplies the timeline origin even when its tracer
        is None), the round also steps the bank-utilization and
        movement-bandwidth time series (`_emit_telemetry`)."""
        prog = self.program_for(schedule)
        round_times = []
        rows = []
        for st in rnd:
            load_s, comp_s, move_s, out_s = prog.stage_seconds(st.idx)
            if schedule.reload_per_op:
                # constants overflow the bank: every input re-streams
                load_s *= b
            elif key_cache is not None:
                _, _, load_s = key_cache.get_or_load(
                    (workload, "stage", st.idx), st.const_bytes)
            exec_s = b * (comp_s + move_s)
            xfer_s = b * out_s
            busy = load_s + max(exec_s, xfer_s)
            metrics.occupancy.add(st.partition, busy)
            round_times.append((busy, exec_s, xfer_s))
            row = {"stage": st.idx, "partition": st.partition,
                   "load_s": load_s, "compute_s": b * comp_s,
                   "move_s": b * move_s + xfer_s, "busy_s": busy}
            rows.append(row)
            if breakdown is not None:
                breakdown.append(row)
        worst = max(t[0] for t in round_times)
        fill = sum(max(e, x) / b for (_, e, x) in round_times)
        tel = metrics.telemetry
        if tel is not None and obs is not None:
            self._emit_telemetry(tel, prog, rnd, rows, b,
                                 obs.t0, worst + fill)
        if obs is not None and obs.tracer is not None:
            rspan = obs.tracer.begin("round", obs.t0, parent=obs.parent,
                                     track=obs.track, n_stages=len(rnd),
                                     b=b)
            for st, row in zip(rnd, rows):
                obs.tracer.span(
                    "stage", obs.t0, obs.t0 + row["busy_s"], parent=rspan,
                    track=obs.track, stage=st.idx,
                    partition=st.partition, load_s=row["load_s"],
                    compute_s=row["compute_s"], move_s=row["move_s"],
                    isa_cycles={k: round(v, 4) for k, v in
                                prog.stage_class_cycles(st.idx).items()},
                    bank_cycles={str(k): round(v, 4) for k, v in
                                 prog.stage_bank_cycles(st.idx).items()})
            obs.tracer.end(rspan, obs.t0 + worst + fill)
        return worst + fill

    @staticmethod
    def stage_phase(prog: PimProgram, stage: int) -> str:
        """Dominant ISA class of a lowered stage — the ``phase`` label
        on the utilization series ("what was the fabric doing"):
        ntt / modmul / move / load by argmax cycle share."""
        cls = prog.stage_class_cycles(stage)
        groups = (("ntt", cls["NTT"]), ("modmul", cls["ROWOP"]),
                  ("move", cls["XFER"] + cls["STORE"]),
                  ("load", cls["LOAD"]))
        return max(groups, key=lambda kv: kv[1])[0]

    def _emit_telemetry(self, tel, prog: PimProgram, rnd, rows,
                        b: int, t0: float, round_s: float) -> None:
        """Per-round series points, stamped at the round's end on the
        DES timeline: per-bank busy seconds/cycles and utilization
        (busy over the round's wall — strictly < 1 whenever any other
        stage contributes fill), and per-scope movement bytes
        normalized against the arch's peak link bandwidth so presets
        are directly comparable."""
        t_end = t0 + round_s
        arch = self.arch
        for st, row in zip(rnd, rows):
            ch, bk = arch.bank_coords(st.partition)
            phase = self.stage_phase(prog, st.idx)
            cls = prog.stage_class_cycles(st.idx)
            exec_cycles = b * (cls["ROWOP"] + cls["NTT"] + cls["XFER"]
                               + cls["STORE"])
            tel.counter("fhe_pim_bank_busy_seconds",
                        channel=ch, bank=bk).inc(t_end, row["busy_s"])
            tel.counter("fhe_pim_bank_busy_cycles", channel=ch, bank=bk,
                        phase=phase).inc(t_end, exec_cycles)
            tel.gauge("fhe_pim_bank_utilization", channel=ch, bank=bk,
                      phase=phase).set(t_end, row["busy_s"] / round_s)
            for scope, nbytes in sorted(
                    prog.stage_scope_bytes(st.idx).items()):
                moved = b * nbytes
                tel.counter("fhe_pim_move_bytes", scope=scope).inc(
                    t_end, moved)
                tel.gauge("fhe_pim_move_bw_frac", scope=scope).set(
                    t_end, (moved / round_s) / arch.scope_bw(scope))

    def execute(self, schedule: PipelineSchedule, batch, *,
                key_cache, metrics, workload: str, obs=None) -> float:
        b = max(1, batch.n_ciphertexts)
        breakdown: List[dict] = []
        total = 0.0
        for rnd in schedule.rounds:
            total += self.round_seconds(
                schedule, rnd, b, key_cache=key_cache, metrics=metrics,
                workload=workload, breakdown=breakdown,
                obs=obs.at(obs.t0 + total) if obs is not None else None)
        self.last_breakdown[workload] = breakdown
        return total


def resolve_pim_backend(mem, verify: bool = False) -> PimBackend:
    """Backend for `resolve_backend("pim", ...)`: recover the arch the
    MemoryModel was projected from (preset match), else wrap the mem in
    a degenerate arch that bills identically to AnalyticBackend."""
    return PimBackend(arch=arch_for_memory_model(mem), verify=verify)
