"""Hierarchical FHEmem hardware model: arch presets, data layout,
bank-level ISA + lowering, and the discrete-event `PimBackend`.

The paper's headline contribution is the hardware — channels → banks →
subarrays → mats doing bit-serial long-bitwidth modmuls in place, plus
an inter-bank permutation network for NTT/rotation movement. This
package models that hierarchy explicitly and plugs it into the serving
runtime as a fourth execution backend (`serve_fhe --backend pim`):

* ``arch``    parameterized hierarchy + cycle model; presets
              ``fhemem`` / ``hbm2`` / ``flat`` (degenerate =
              core/pipeline.MemoryModel), shared with the analytic
              side via ``memory_model(name)`` — one preset registry
* ``layout``  ciphertext limbs → subarrays under capacity, with
              spill accounting (the movement the paper optimizes)
* ``isa``     LOAD/ROWOP/NTT/XFER/STORE instruction stream with
              fractional-cycle accounting
* ``lower``   PipelineSchedule → instruction stream
* ``backend`` discrete-event executor satisfying the runtime backend
              contract; flat preset reproduces AnalyticBackend ≤1%

See DESIGN.md §10.
"""
from repro.pim.arch import (FHEMEM, FLAT, HBM2, PRESETS, PimArch,
                            arch_for_memory_model,
                            flat_arch_from_memory_model, get_arch,
                            memory_model)
from repro.pim.backend import PimBackend, resolve_pim_backend
from repro.pim.isa import PimInstr, PimProgram
from repro.pim.layout import (LayoutError, LayoutPlan, Placement,
                              StageLayout, plan_layout)
from repro.pim.lower import lower_schedule

__all__ = [
    "PimArch", "PRESETS", "FHEMEM", "HBM2", "FLAT",
    "get_arch", "memory_model", "arch_for_memory_model",
    "flat_arch_from_memory_model",
    "Placement", "StageLayout", "LayoutPlan", "LayoutError", "plan_layout",
    "PimInstr", "PimProgram", "lower_schedule",
    "PimBackend", "resolve_pim_backend",
]
