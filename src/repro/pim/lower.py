"""Lower a compiled `PipelineSchedule` into a bank-level PIM
instruction stream (repro.pim.isa) under a data layout
(repro.pim.layout), with per-instruction cycle accounting from the
arch's cycle model (repro.pim.arch).

Per compute op the lowerer consumes the same `OpCost` channels the
analytic model bills — plain modmul rows, keyswitch digit-
decomposition rows (weighted ``ks_modmul_weight``), NTT passes, and
the op's ``move_bytes`` data-movement channel — and emits ROWOP / NTT
/ XFER instructions on the stage's home bank. Hierarchy presets
additionally pay NTT inter-mat shuffles and spilled-limb traffic; a
``degenerate`` arch bills exactly the flat MemoryModel formula, so
summing a lowered stream reproduces `PipelineSchedule.stage_times` to
float precision (regression-tested in tests/test_pim.py).
"""
from __future__ import annotations

import math
from typing import List, Optional

from repro.core.pipeline import PipelineSchedule
from repro.core.trace import ct_bytes, op_cost
from repro.pim.arch import PimArch
from repro.pim.isa import PimInstr, PimProgram
from repro.pim.layout import LayoutPlan, plan_layout


def lower_schedule(schedule: PipelineSchedule, arch: PimArch,
                   layout: Optional[LayoutPlan] = None) -> PimProgram:
    """Pure function of (schedule, arch[, layout]) — lowering twice
    yields identical streams (property-tested)."""
    if layout is None:
        layout = plan_layout(schedule, arch)
    params = schedule.params
    n = params.n
    f = arch.freq_hz
    instrs: List[PimInstr] = []
    stages = schedule.stages
    for st in stages:
        sl = layout.stage(st.idx)
        ch, bk = sl.home_channel, sl.home_bank

        # stage constants stream in once per round (load-save property)
        if st.const_bytes:
            instrs.append(PimInstr(
                "LOAD", st.idx, -1, ch, bk, nbytes=st.const_bytes,
                scope="load",
                cycles=arch.xfer_seconds(st.const_bytes, "load") * f))

        # spilled limbs: every execution of the stage reaches across
        # the bank boundary for them. Generation>0 limbs overflowed the
        # whole device: bill the off-chip round-trip (write back the
        # previous residents, stream these in) — the streaming regime
        # must not be free. (A degenerate arch bills neither: the flat
        # model has no layout semantics by definition, and its naive
        # overflow regime is already priced by reload_per_op loads.)
        if not arch.degenerate:
            stream_b = 2 * sl.streamed_bytes       # write-back + refill
            for nbytes, scope in ((sl.spill_bytes_bank, "bank"),
                                  (sl.spill_bytes_channel, "channel"),
                                  (stream_b, "load")):
                if nbytes:
                    instrs.append(PimInstr(
                        "XFER", st.idx, -1, ch, bk, nbytes=nbytes,
                        scope=scope,
                        cycles=arch.xfer_seconds(nbytes, scope) * f))

        for op in st.ops:
            c = op_cost(params, op)
            rows = c.modmuls + c.ks_modmuls
            if rows:
                weighted = c.modmuls + arch.ks_modmul_weight * c.ks_modmuls
                instrs.append(PimInstr(
                    "ROWOP", st.idx, op.idx, ch, bk, rows=rows,
                    cycles=arch.rows_seconds(weighted, n) * f,
                    op_kind=op.kind))
            if c.ntts:
                instrs.append(PimInstr(
                    "NTT", st.idx, op.idx, ch, bk, rows=c.ntts,
                    cycles=arch.rows_seconds(
                        c.ntts * arch.ntt_row_cost
                        * math.log2(max(n, 2)), n) * f,
                    op_kind=op.kind))
                shuffle_b = c.ntts * arch.ntt_shuffle_bytes(n)
                if shuffle_b:
                    instrs.append(PimInstr(
                        "XFER", st.idx, op.idx, ch, bk, nbytes=shuffle_b,
                        scope="intra",
                        cycles=arch.xfer_seconds(shuffle_b, "intra") * f,
                        op_kind=op.kind))
            if c.move_bytes:
                # ModUp/ModDown limb distribution stays bank-local; only
                # the automorphism's slot permutation (the ciphertext
                # itself, for rotate/conjugate) rides the inter-bank
                # permutation network
                perm_b = 0
                if op.kind in ("rotate", "conjugate"):
                    perm_b = min(c.move_bytes,
                                 ct_bytes(params, op.level
                                          if op.level is not None
                                          else params.n_levels))
                intra_b = c.move_bytes - perm_b
                if intra_b:
                    instrs.append(PimInstr(
                        "XFER", st.idx, op.idx, ch, bk, nbytes=intra_b,
                        scope="intra",
                        cycles=arch.xfer_seconds(intra_b, "intra") * f,
                        op_kind=op.kind))
                if perm_b:
                    instrs.append(PimInstr(
                        "XFER", st.idx, op.idx, ch, bk, nbytes=perm_b,
                        scope="bank",
                        cycles=arch.xfer_seconds(perm_b, "bank") * f,
                        op_kind=op.kind))

        # stage output hops to the next stage's bank
        if st.out_bytes:
            nxt = stages[st.idx + 1] if st.idx + 1 < len(stages) else st
            scope = arch.transfer_scope(st.partition, nxt.partition)
            if arch.degenerate:
                scope = "bank"     # the flat model's single transfer link
            instrs.append(PimInstr(
                "STORE", st.idx, -1, ch, bk, nbytes=st.out_bytes,
                scope=scope,
                cycles=arch.xfer_seconds(st.out_bytes, scope) * f))

    return PimProgram(arch.name, f, instrs, len(stages))


def program_movement_profile(prog: PimProgram,
                             arch: PimArch) -> List[dict]:
    """Static movement profile of a lowered stream: per interconnect
    scope, the total XFER+STORE bytes and the seconds the arch's peak
    link bandwidth would need for them — the lowering-time counterpart
    of the runtime telemetry's ``fhe_pim_move_bytes`` /
    ``fhe_pim_move_bw_frac`` series (fig22 reports both sides)."""
    by_scope = {}
    for stage in range(prog.n_stages):
        for scope, nbytes in prog.stage_scope_bytes(stage).items():
            by_scope[scope] = by_scope.get(scope, 0) + nbytes
    return [{"scope": scope, "bytes": nbytes,
             "peak_bw": arch.scope_bw(scope),
             "seconds_at_peak": nbytes / arch.scope_bw(scope)}
            for scope, nbytes in sorted(by_scope.items())]
