"""Bank-level PIM instruction set with per-instruction cycle accounting.

Five opcodes cover everything the hierarchy executes:

* ``LOAD``  — stream a stage's constants (evk / plaintexts) off-chip
              into its home bank (once per pipeline round).
* ``ROWOP`` — N-element modular-multiply rows in the bank's bit-serial
              lanes. ``rows`` is the raw row count (plain + keyswitch
              digit-decomposition rows); the ``ks_modmul_weight``
              surcharge on the latter lands in ``cycles`` only.
* ``NTT``   — butterfly passes of an (i)NTT over resident limbs.
* ``XFER``  — op-internal data movement: rotation slot permutations
              over the inter-bank network, ModUp/ModDown limb
              distribution, NTT inter-mat shuffles, spilled-limb
              traffic. ``scope`` names the link it rides.
* ``STORE`` — the stage's output ciphertext hopping to the next
              stage's bank.

``cycles`` is fractional (float) on the arch's internal clock: the
model prices sub-cycle work exactly rather than rounding per
instruction, so summing a stream reproduces the analytic model to
float precision (the flat-preset regression depends on this).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

OPCODES = ("LOAD", "ROWOP", "NTT", "XFER", "STORE")


@dataclasses.dataclass(frozen=True)
class PimInstr:
    opcode: str
    stage: int
    op_idx: int          # trace op index; -1 for stage-level instructions
    channel: int
    bank: int
    rows: int = 0        # N-element rows (ROWOP) / NTT passes (NTT)
    nbytes: int = 0      # bytes streamed (LOAD) or moved (XFER/STORE)
    scope: str = ""      # intra|bank|channel|load for XFER/STORE/LOAD
    cycles: float = 0.0
    op_kind: str = ""    # source trace-op kind ("mul", "rotate", ...);
    #                      observability only — deliberately NOT in
    #                      to_jsonable, so the pim_streams goldens are
    #                      insensitive to it

    def to_jsonable(self) -> dict:
        d = {"opcode": self.opcode, "stage": self.stage,
             "op": self.op_idx, "channel": self.channel, "bank": self.bank,
             # cycles rounded so goldens are insensitive to float repr
             "cycles": round(self.cycles, 4)}
        if self.rows:
            d["rows"] = self.rows
        if self.nbytes:
            d["nbytes"] = self.nbytes
        if self.scope:
            d["scope"] = self.scope
        return d


@dataclasses.dataclass
class PimProgram:
    """A lowered PipelineSchedule: the flat instruction stream plus the
    per-stage second buckets the discrete-event backend consumes."""
    arch_name: str
    freq_hz: float
    instrs: List[PimInstr]
    n_stages: int
    # per-stage (load, comp, move, out) cycle buckets, built once — the
    # serving loop reads them per batch, so it must not rescan the
    # stream every time (instrs are immutable after lowering)
    _buckets: List[List[float]] = dataclasses.field(
        default=None, repr=False, compare=False)

    def __len__(self) -> int:
        return len(self.instrs)

    def total_cycles(self) -> float:
        return sum(i.cycles for i in self.instrs)

    def stage_instrs(self, stage: int) -> List[PimInstr]:
        return [i for i in self.instrs if i.stage == stage]

    _BUCKET = {"LOAD": 0, "ROWOP": 1, "NTT": 1, "XFER": 2, "STORE": 3}

    def stage_seconds(self, stage: int) -> Tuple[float, float, float, float]:
        """(load_s, compute_s, move_s, out_s) for one batch element:
        LOAD | ROWOP+NTT | XFER | STORE cycle sums over freq."""
        if self._buckets is None:
            buckets = [[0.0] * 4 for _ in range(self.n_stages)]
            for i in self.instrs:
                buckets[i.stage][self._BUCKET[i.opcode]] += i.cycles
            self._buckets = buckets
        f = self.freq_hz
        load, comp, move, out = self._buckets[stage]
        return load / f, comp / f, move / f, out / f

    def stage_class_cycles(self, stage: int) -> Dict[str, float]:
        """Per instruction-class cycle totals for one stage
        ({opcode: cycles}, every opcode present) — the PIM backend
        attributes execute spans down to these."""
        self._class_index()
        return dict(self._by_class[stage])

    def stage_bank_cycles(self, stage: int) -> Dict[int, float]:
        """Per-bank cycle totals for one stage ({bank: cycles})."""
        self._class_index()
        return dict(self._by_bank[stage])

    def stage_scope_bytes(self, stage: int) -> Dict[str, int]:
        """Bytes MOVED per interconnect scope in one stage — XFER plus
        STORE traffic ({scope: bytes}; constant LOAD streaming is a
        separate phenomenon and deliberately excluded). This is the
        movement side of the telemetry's bandwidth series: bytes here
        over the round's wall time, normalized by `PimArch.scope_bw`,
        is the link's utilization fraction."""
        if getattr(self, "_by_scope", None) is None:
            by_scope: List[Dict[str, int]] = [
                {} for _ in range(self.n_stages)]
            for i in self.instrs:
                if i.opcode in ("XFER", "STORE") and i.nbytes:
                    d = by_scope[i.stage]
                    d[i.scope] = d.get(i.scope, 0) + i.nbytes
            self._by_scope = by_scope
        return dict(self._by_scope[stage])

    def _class_index(self) -> None:
        if getattr(self, "_by_class", None) is None:
            by_class = [{op: 0.0 for op in OPCODES}
                        for _ in range(self.n_stages)]
            by_bank: List[Dict[int, float]] = [
                {} for _ in range(self.n_stages)]
            for i in self.instrs:
                by_class[i.stage][i.opcode] += i.cycles
                bb = by_bank[i.stage]
                bb[i.bank] = bb.get(i.bank, 0.0) + i.cycles
            self._by_class = by_class
            self._by_bank = by_bank

    def summary(self) -> Dict[str, float]:
        by_op: Dict[str, int] = {}
        cyc: Dict[str, float] = {}
        for i in self.instrs:
            by_op[i.opcode] = by_op.get(i.opcode, 0) + 1
            cyc[i.opcode] = cyc.get(i.opcode, 0.0) + i.cycles
        return {"n_instrs": len(self.instrs),
                "total_cycles": self.total_cycles(),
                **{f"n_{k.lower()}": v for k, v in sorted(by_op.items())},
                **{f"cycles_{k.lower()}": round(v, 4)
                   for k, v in sorted(cyc.items())}}

    def to_jsonable(self) -> dict:
        return {"arch": self.arch_name, "freq_hz": self.freq_hz,
                "n_stages": self.n_stages,
                "summary": self.summary(),
                "instrs": [i.to_jsonable() for i in self.instrs]}
