"""Data-layout mapper: ciphertext limbs of each PipelineSchedule stage
→ subarrays of the stage's home bank, under per-subarray capacity.

The load-save mapper (core/pipeline.py) decides WHICH bank (partition)
a stage lives on; this module decides WHERE IN the bank its data
lives. Each compute op's output ciphertext is 2·(level+1) limb rows of
N coefficients; limbs are spread round-robin across the home bank's
subarrays — the layout that makes modmul limb-parallel (every limb's
row op runs in its own subarray simultaneously) and that the paper's
NTT/rotation phases permute between. A stage whose working set
overflows its home bank spills whole limbs to the following banks
(same channel first), and the lowerer bills the spilled bytes as
inter-bank traffic every time the stage runs.

Stages of one pipeline *round* are resident simultaneously, so
capacity is tracked per round: stage i and stage i+n_partitions share
a home bank but never coexist, exactly like the mapper's round
semantics. A round whose working set exceeds the whole device (the
naive mapper's reload-per-op regime) streams: placement continues in
a fresh residency *generation* (Placement.generation), earlier
generations having been written back.

Invariants (property-tested in tests/test_pim.py): every (op, poly,
limb) is placed exactly once; no subarray's bytes within one
(round, generation) exceed ``arch.subarray_bytes``; planning is
deterministic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

from repro.core.pipeline import PipelineSchedule, Stage
from repro.pim.arch import WORD, PimArch


class LayoutError(Exception):
    """A single limb is larger than every subarray — unplaceable."""


@dataclasses.dataclass(frozen=True)
class Placement:
    """One ciphertext limb row pinned to one subarray."""
    op_idx: int          # trace op producing the ciphertext
    poly: int            # 0 = b component, 1 = a component
    limb: int            # RNS limb index
    channel: int
    bank: int            # bank within the channel
    subarray: int
    nbytes: int
    generation: int = 0  # residency generation: a round whose working
    #                      set exceeds the device streams — earlier
    #                      generations are written back before later
    #                      ones load (the naive/reload regime). Capacity
    #                      holds per (generation, subarray).


@dataclasses.dataclass
class StageLayout:
    stage_idx: int
    home_channel: int
    home_bank: int
    placements: List[Placement]
    spill_bytes_bank: int = 0      # limbs homed on other banks, same channel
    spill_bytes_channel: int = 0   # limbs pushed across channels

    @property
    def total_bytes(self) -> int:
        return sum(p.nbytes for p in self.placements)

    @property
    def streamed_bytes(self) -> int:
        """Bytes placed after a device flush (generation > 0): the
        round wrote earlier residents back and re-streamed these, so
        the lowerer bills them as off-chip round-trips."""
        return sum(p.nbytes for p in self.placements if p.generation > 0)


@dataclasses.dataclass
class LayoutPlan:
    arch: PimArch
    stages: List[StageLayout]

    def stage(self, idx: int) -> StageLayout:
        return self.stages[idx]


def _bank_order(arch: PimArch, home_channel: int,
                home_bank: int) -> Iterator[Tuple[int, int]]:
    """Deterministic candidate banks: home first, then the rest of the
    home channel, then the other channels round-robin."""
    for b in range(arch.banks_per_channel):
        yield home_channel, (home_bank + b) % arch.banks_per_channel
    for c in range(1, arch.n_channels):
        ch = (home_channel + c) % arch.n_channels
        for b in range(arch.banks_per_channel):
            yield ch, (home_bank + b) % arch.banks_per_channel


def _stage_limbs(stage: Stage, n: int) -> Iterator[Tuple[int, int, int, int]]:
    """(op_idx, poly, limb, nbytes) for every limb row the stage's
    output ciphertexts occupy (level-annotated ops; unannotated ops
    contribute nothing — they never reach a mapped schedule)."""
    limb_b = n * WORD
    for op in stage.ops:
        if op.level is None:
            continue
        for poly in (0, 1):
            for limb in range(op.level + 1):
                yield op.idx, poly, limb, limb_b


def plan_layout(schedule: PipelineSchedule, arch: PimArch) -> LayoutPlan:
    """Place every stage's limbs. Pure function of (schedule, arch)."""
    n = schedule.params.n
    out: List[StageLayout] = [None] * len(schedule.stages)  # type: ignore
    for rnd in schedule.rounds:
        # per-round residency: (channel, bank, subarray) -> used bytes
        used: Dict[Tuple[int, int, int], int] = {}
        gen = 0
        for st in rnd:
            ch, bk = arch.bank_coords(st.partition)
            sl = StageLayout(st.idx, ch, bk, [])
            rr = 0  # round-robin subarray cursor, per stage
            for op_idx, poly, limb, nbytes in _stage_limbs(st, n):
                if nbytes > arch.subarray_bytes:
                    raise LayoutError(
                        f"limb of {nbytes} bytes exceeds a subarray "
                        f"({arch.name}: {arch.subarray_bytes} bytes)")
                while True:
                    placed = False
                    for c, b in _bank_order(arch, ch, bk):
                        # probe the bank's subarrays from the cursor
                        for probe in range(arch.subarrays_per_bank):
                            s = (rr + probe) % arch.subarrays_per_bank
                            key = (c, b, s)
                            if used.get(key, 0) + nbytes \
                                    <= arch.subarray_bytes:
                                used[key] = used.get(key, 0) + nbytes
                                sl.placements.append(Placement(
                                    op_idx, poly, limb, c, b, s, nbytes,
                                    generation=gen))
                                rr = (s + 1) % arch.subarrays_per_bank
                                if (c, b) != (ch, bk):
                                    if c == ch:
                                        sl.spill_bytes_bank += nbytes
                                    else:
                                        sl.spill_bytes_channel += nbytes
                                placed = True
                                break
                        if placed:
                            break
                    if placed:
                        break
                    # device exhausted: the round streams — retire the
                    # current residency generation and start the next
                    gen += 1
                    used = {}
            out[st.idx] = sl
    return LayoutPlan(arch, out)
