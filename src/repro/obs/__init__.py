"""End-to-end request tracing & profiling for the serving stack.

Span trees from fleet admission down to PIM instruction streams, with
Chrome/Perfetto ``trace_event`` export and an in-process store tests
and the critical-path analyzer query directly.

Enable by attaching a `Tracer` (and optionally a `JsonEventLog`) to
the run's shared `MetricsRegistry`::

    metrics.tracer = Tracer()
    ex.serve(...)
    write_trace(metrics.tracer.store, "trace.json")

Absence of a tracer is the disabled state — every emission site in the
runtime guards on ``metrics.tracer is None``, so a run without one is
bit-for-bit identical to a build without this package (regression-
tested against a metrics golden).

Time-series telemetry (repro.obs.telemetry) rides the same contract on
``metrics.telemetry``: bounded counter/gauge/histogram series on the
caller's clock, exported as OpenMetrics text (repro.obs.openmetrics)
or Perfetto counter tracks merged into the trace JSON.
"""
from repro.obs.span import Span, SpanStore
from repro.obs.tracer import ExecObs, Tracer
from repro.obs.log import EVENTS, JsonEventLog
from repro.obs.perfetto import (to_trace_events, validate, validate_file,
                                write_trace)
from repro.obs.critical_path import (Segment, critical_path, request_chain,
                                     workload_breakdown)
from repro.obs.telemetry import (HistogramSeries, Series, SloBurnRate,
                                 Telemetry)
from repro.obs.openmetrics import render as render_openmetrics
from repro.obs.openmetrics import parse as parse_openmetrics
from repro.obs.openmetrics import write_metrics

__all__ = [
    "Span", "SpanStore", "Tracer", "ExecObs", "JsonEventLog", "EVENTS",
    "to_trace_events", "write_trace", "validate", "validate_file",
    "Segment", "critical_path", "request_chain", "workload_breakdown",
    "Telemetry", "Series", "HistogramSeries", "SloBurnRate",
    "render_openmetrics", "parse_openmetrics", "write_metrics",
]
