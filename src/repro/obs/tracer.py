"""`Tracer` — the per-run span emitter the serving stack threads.

Wiring: the tracer hangs off the one `MetricsRegistry` already shared
by every layer (``metrics.tracer``), so queue, batcher, router,
devices, compile cache and backends all reach it without signature
churn. Disabled tracing is the *absence* of a tracer: every emission
site guards with ``tr = metrics.tracer`` / ``if tr is not None`` — one
attribute read and a None test, which is the zero-overhead-when-
disabled contract the bit-for-bit metrics regression pins down.

The tracer never reads a clock of its own. Every emission passes the
caller's current time — the executor's virtual DES ``now`` or the
wall-clock loop time — so spans land exactly inside the scheduler's
timeline (the root ``request`` span's duration IS the request's
recorded latency, to float precision; tested).

Request roots are opened lazily (`ensure_root`): the first layer to
touch a request — router at admission, queue on submit — materializes
its root span, and `close_root` stamps the terminal status
(completed / deadline_miss / dropped_expired / rejected / unfinished).

`ExecObs` is the small context handed down into a backend's
``execute``/``round_seconds`` (tracer, parent span, timeline origin,
device track) so per-round and per-stage spans parent correctly
without the backend knowing about requests at all.
"""
from __future__ import annotations

import itertools
from typing import Dict, NamedTuple, Optional

from repro.obs.span import Span, SpanStore

# requests are duck-typed (runtime.queue.Request) — importing the
# runtime here would cycle: runtime.executor imports obs.tracer for
# ExecObs, and runtime/__init__ eagerly loads executor


class Tracer:
    def __init__(self, store: Optional[SpanStore] = None):
        self.store = store if store is not None else SpanStore()
        self._ids = itertools.count(1)
        self._roots: Dict[int, int] = {}        # request_id -> root span id

    # -- primitive emission --------------------------------------------------

    def begin(self, name: str, t: float, parent: Optional[int] = None,
              track: str = "runtime", request_id: Optional[int] = None,
              **attrs) -> int:
        sid = next(self._ids)
        self.store.add(Span(sid, parent, name, t, None, track,
                            request_id, attrs))
        return sid

    def end(self, span_id: int, t: float, **attrs) -> None:
        s = self.store.get(span_id)
        if s is None:
            return
        s.end_s = t
        if attrs:
            s.attrs.update(attrs)

    def span(self, name: str, start_s: float, end_s: float,
             parent: Optional[int] = None, track: str = "runtime",
             request_id: Optional[int] = None, **attrs) -> int:
        sid = next(self._ids)
        self.store.add(Span(sid, parent, name, start_s, end_s, track,
                            request_id, attrs))
        return sid

    def instant(self, name: str, t: float, parent: Optional[int] = None,
                track: str = "runtime", request_id: Optional[int] = None,
                **attrs) -> int:
        return self.span(name, t, t, parent, track, request_id, **attrs)

    # -- request lifecycle ---------------------------------------------------

    def ensure_root(self, req) -> int:
        """Root ``request`` span on the tenant track, opened at arrival.
        Idempotent — the first touching layer (router or queue) wins."""
        sid = self._roots.get(req.request_id)
        if sid is None:
            sid = self.begin("request", req.arrival_s,
                             track=f"tenant:{req.tenant}",
                             request_id=req.request_id,
                             tenant=req.tenant, workload=req.workload,
                             slots=req.slots_needed,
                             deadline_s=req.deadline_s)
            self._roots[req.request_id] = sid
        return sid

    def root_id(self, request_id: int) -> Optional[int]:
        return self._roots.get(request_id)

    def close_root(self, req, t: float, status: str,
                   **attrs) -> None:
        sid = self._roots.get(req.request_id)
        if sid is None:
            sid = self.ensure_root(req)
        s = self.store.get(sid)
        if s is not None and s.end_s is None:
            self.end(sid, t, status=status, **attrs)

    def close_open(self, t: float) -> None:
        """Finalize: close any span still open (requests left queued
        when the serve window ends, flights cut mid-stream). Stamped
        ``unfinished`` so analyzers and the exporter never see
        half-open intervals."""
        for s in self.store.open_spans():
            s.end_s = max(t, s.start_s)
            s.attrs.setdefault("status", "unfinished")


class ExecObs(NamedTuple):
    """Execution-scope observability context handed into backends.

    ``tracer`` may be None when only telemetry (metrics.telemetry) is
    armed: the backend still needs the timeline origin ``t0`` to stamp
    its series points, so callers construct an ExecObs whenever EITHER
    observer is attached and backends guard span emission on
    ``obs.tracer is not None``."""
    tracer: Optional[Tracer]
    parent: Optional[int]      # the batch/flight span
    t0: float                  # timeline time execution starts
    track: str                 # device track, e.g. "device:0"

    def at(self, t0: float, parent: Optional[int] = None) -> "ExecObs":
        return self._replace(t0=t0,
                             parent=self.parent if parent is None
                             else parent)
