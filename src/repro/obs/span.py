"""Span records and the in-process `SpanStore`.

A `Span` is one named interval on the serving timeline — the clock is
whatever the executor that emitted it runs on (virtual DES seconds for
the analytic/pim/fleet paths, wall seconds for mesh/ciphertext), so
spans nest exactly inside the scheduler's own event times rather than
in a second, skewed clock domain.

Spans form two families of trees:

* **request trees** (``request_id`` set, ``track="tenant:<t>"``) — one
  root ``request`` span per request (arrival → completion/drop) with
  ``queue_wait`` / ``route`` / ``service`` children;
* **batch trees** (``track="device:<i>"``) — one root per executed
  batch or flight, with ``compile`` / ``round`` / ``stage`` children.

A request's ``service`` span links to the batch that carried it via
``attrs["batch_span"]`` (many requests ride one batch, so the batch
subtree is shared, not duplicated per request).

The `SpanStore` is the queryable in-process sink: tests and the
critical-path analyzer (repro.obs.critical_path) read it directly; the
Perfetto exporter (repro.obs.perfetto) serializes it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass(slots=True)
class Span:
    span_id: int
    parent_id: Optional[int]
    name: str
    start_s: float
    end_s: Optional[float]        # None while open
    track: str = "runtime"        # "device:<i>" | "tenant:<t>" | "runtime"
    request_id: Optional[int] = None
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return (self.end_s - self.start_s) if self.end_s is not None else 0.0

    def to_jsonable(self) -> dict:
        d = {"span_id": self.span_id, "parent_id": self.parent_id,
             "name": self.name, "start_s": self.start_s,
             "end_s": self.end_s, "track": self.track}
        if self.request_id is not None:
            d["request_id"] = self.request_id
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        return d


class SpanStore:
    """Append-only span sink with id / parent / request indexes.

    Indexes are rebuilt lazily: emission (the hot path — once per span)
    is a list append plus one dict write; queries (tests, analyzers,
    export) pay the indexing.
    """

    def __init__(self):
        self.spans: List[Span] = []
        self._by_id: Dict[int, Span] = {}
        self._children: Optional[Dict[Optional[int], List[Span]]] = None

    def __len__(self) -> int:
        return len(self.spans)

    def add(self, span: Span) -> None:
        self.spans.append(span)
        self._by_id[span.span_id] = span
        if self._children is not None:
            self._children = None

    def get(self, span_id: int) -> Optional[Span]:
        return self._by_id.get(span_id)

    def _index(self) -> Dict[Optional[int], List[Span]]:
        if self._children is None:
            idx: Dict[Optional[int], List[Span]] = {}
            for s in self.spans:
                idx.setdefault(s.parent_id, []).append(s)
            self._children = idx
        return self._children

    def children(self, span_id: Optional[int]) -> List[Span]:
        return list(self._index().get(span_id, ()))

    def roots(self) -> List[Span]:
        return self.children(None)

    def by_request(self, request_id: int) -> List[Span]:
        return [s for s in self.spans if s.request_id == request_id]

    def request_root(self, request_id: int) -> Optional[Span]:
        for s in self.spans:
            if s.request_id == request_id and s.name == "request":
                return s
        return None

    def by_name(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def subtree(self, span_id: int) -> List[Span]:
        """The span plus all descendants (preorder)."""
        root = self.get(span_id)
        if root is None:
            return []
        out, stack = [], [root]
        while stack:
            s = stack.pop()
            out.append(s)
            stack.extend(reversed(self.children(s.span_id)))
        return out

    def open_spans(self) -> List[Span]:
        return [s for s in self.spans if s.end_s is None]

    def to_jsonable(self) -> List[dict]:
        return [s.to_jsonable() for s in self.spans]
