"""Chrome/Perfetto ``trace_event`` JSON export of a `SpanStore`.

The emitted object follows the Trace Event Format (the JSON flavor
Perfetto's legacy importer and chrome://tracing both load): a
``traceEvents`` list of complete (``ph:"X"``) events, one per span,
plus ``M`` metadata events naming processes and threads. Tracks map
as:

* ``device:<i>``  -> pid 1 ("devices"),  one tid per device — batch /
                     flight / compile / round / stage spans;
* ``tenant:<t>``  -> pid 2 ("tenants"),  one tid per tenant — request
                     roots with queue_wait / route / service children;
* anything else   -> pid 3 ("runtime");
* telemetry       -> pid 4 ("telemetry"), counter tracks (``ph:"C"``)
                     merged from a `Telemetry` snapshot — one stepped
                     graph per labeled series (bank utilization, queue
                     depth, burn rate ...).

Timestamps are the serving timeline (virtual DES or wall seconds)
converted to microseconds — Perfetto renders either; the clock domain
is recorded in ``otherData.clock``. Span attrs land in ``args`` so a
click shows tenant/workload/status, per-pass compile wall times, and
(pim backend) per-bank ISA cycle-class counts.

``validate(obj)`` is the schema gate CI runs on every emitted trace
(`python -m repro.obs.perfetto validate trace.json`).
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional

from repro.obs.span import SpanStore

_GROUPS = (("device:", 1, "devices"), ("tenant:", 2, "tenants"))


def _group(track: str):
    for prefix, pid, pname in _GROUPS:
        if track.startswith(prefix):
            return pid, pname, track[len(prefix):]
    return 3, "runtime", track


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return repr(v)


_TELEMETRY_PID = 4


def _counter_events(telemetry) -> List[dict]:
    """Perfetto counter-track (``ph:"C"``) events from a telemetry
    snapshot: one track per labeled series, one event per retained
    point, so utilization / queue depth / burn rate render as stepped
    graphs above the span tracks. Histograms export their observation
    count (the time-resolved part of a histogram series)."""
    events: List[dict] = [
        {"ph": "M", "name": "process_name", "pid": _TELEMETRY_PID,
         "tid": 0, "args": {"name": "telemetry"}}]
    for tid, s in enumerate(telemetry.series(), start=1):
        label = s.name + "".join(f"[{k}={v}]" for k, v in s.labels)
        events.append({"ph": "M", "name": "thread_name",
                       "pid": _TELEMETRY_PID, "tid": tid,
                       "args": {"name": label}})
        for t, v in s.points:
            events.append({"ph": "C", "name": label,
                           "pid": _TELEMETRY_PID, "tid": tid,
                           "ts": t * 1e6, "args": {"value": v}})
    return events


def to_trace_events(store: SpanStore, clock: str = "virtual",
                    telemetry=None) -> dict:
    """Serialize every (closed) span; open spans are exported with zero
    duration and ``status: open`` so a crash dump still loads. With
    ``telemetry`` (repro.obs.Telemetry), its series are merged in as
    counter tracks under a dedicated "telemetry" process."""
    tids: Dict[str, int] = {}
    events: List[dict] = []
    seen_procs = set()
    for track in sorted({s.track for s in store.spans}):
        pid, pname, tname = _group(track)
        tid = tids[track] = len(tids) + 1
        if pid not in seen_procs:
            seen_procs.add(pid)
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "args": {"name": pname}})
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": tname}})
    for s in store.spans:
        pid, _, _ = _group(s.track)
        end = s.end_s if s.end_s is not None else s.start_s
        args = {k: _jsonable(v) for k, v in s.attrs.items()}
        args["span_id"] = s.span_id
        if s.parent_id is not None:
            args["parent_span_id"] = s.parent_id
        if s.request_id is not None:
            args["request_id"] = s.request_id
        if s.end_s is None:
            args["status"] = "open"
        events.append({
            "ph": "X", "name": s.name, "cat": s.track.split(":")[0],
            "pid": pid, "tid": tids[s.track],
            "ts": s.start_s * 1e6, "dur": (end - s.start_s) * 1e6,
            "args": args,
        })
    other = {"generator": "repro.obs", "clock": clock,
             "n_spans": len(store.spans)}
    if telemetry is not None:
        events.extend(_counter_events(telemetry))
        other["n_series"] = len(telemetry)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other}


def write_trace(store: SpanStore, path: str,
                clock: str = "virtual", telemetry=None) -> dict:
    obj = to_trace_events(store, clock=clock, telemetry=telemetry)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


# ---------------------------------------------------------------------------
# schema validation (the CI gate)
# ---------------------------------------------------------------------------

def validate(obj) -> List[str]:
    """Structural check of a trace_event JSON object. Returns a list of
    human-readable problems; empty means valid."""
    errs: List[str] = []
    if not isinstance(obj, dict):
        return [f"top level must be an object, got {type(obj).__name__}"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["missing/invalid 'traceEvents' list"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "M", "i", "B", "E", "C"):
            errs.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errs.append(f"{where}: missing name")
        if not isinstance(ev.get("pid"), int) \
                or not isinstance(ev.get("tid"), int):
            errs.append(f"{where}: pid/tid must be ints")
        if "args" in ev and not isinstance(ev["args"], dict):
            errs.append(f"{where}: args must be an object")
        if ph == "X":
            ts, dur = ev.get("ts"), ev.get("dur")
            if not isinstance(ts, (int, float)):
                errs.append(f"{where}: X event missing numeric ts")
            if not isinstance(dur, (int, float)) or (
                    isinstance(dur, (int, float)) and dur < 0):
                errs.append(f"{where}: X event needs dur >= 0")
        if ph == "C":
            if not isinstance(ev.get("ts"), (int, float)):
                errs.append(f"{where}: C event missing numeric ts")
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) and
                    not isinstance(v, bool) for v in args.values()):
                errs.append(f"{where}: C event args must be a non-empty "
                            f"object of numeric counter values")
        if len(errs) >= 20:
            errs.append("... (truncated)")
            break
    return errs


def validate_file(path: str) -> List[str]:
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable trace JSON: {e}"]
    return validate(obj)


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if len(argv) != 2 or argv[0] != "validate":
        print("usage: python -m repro.obs.perfetto validate TRACE.json",
              file=sys.stderr)
        return 2
    errs = validate_file(argv[1])
    if errs:
        for e in errs:
            print(f"INVALID {e}", file=sys.stderr)
        return 1
    with open(argv[1]) as f:
        n = len(json.load(f).get("traceEvents", []))
    print(f"OK {argv[1]}: {n} events")
    return 0


if __name__ == "__main__":
    sys.exit(main())
