"""Sampled time-series telemetry for the serving stack.

The tracer (repro.obs.tracer) answers "what happened to THIS request";
this module answers "what was the SYSTEM doing at time t": bank
utilization during the NTT phase, bytes/s on the inter-bank network,
queue depth per device, goodput, SLO burn rate. Counters, gauges and
histograms accumulate ring-buffered ``(t, value)`` points on the
**caller's own clock** — the DES virtual timeline for the analytic /
pim / fleet paths, wall seconds for the ciphertext backend — exactly
the discipline the tracer established: telemetry never reads a clock
of its own.

Wiring follows the tracer's contract verbatim. A `Telemetry` hangs off
the run's shared `MetricsRegistry` (``metrics.telemetry``); absence is
the disabled state, every emission site guards with one attribute read
and a None test, and a run without telemetry is bit-for-bit identical
to a run without this module (pinned by the same metrics golden the
tracer regression uses).

Memory is bounded by construction: each series keeps at most
``max_points`` points (a ring), and points closer together than
``resolution`` seconds coalesce into the newest one, so a million-round
fleet sweep degrades gracefully into a coarser series instead of an
unbounded list.

`SloBurnRate` is the alerting side: a multi-window burn-rate monitor
(SRE-style fast + slow windows over the deadline-miss rate vs an error
budget) fed by the same completion/drop sites that do goodput
accounting. When both windows burn hot it records an alert — an
instant in the span store, an ``slo_alert`` event-log line, and a
telemetry gauge step — with hysteresis so a sustained overload fires
once, not per miss.

Export: OpenMetrics text via repro.obs.openmetrics, Perfetto counter
tracks (``ph:"C"``) merged into the trace JSON via repro.obs.perfetto.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

CLOCKS = ("virtual", "wall")

# default histogram bucket bounds (seconds-flavored, Prometheus-style)
DEFAULT_BUCKETS = (1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 1e-1, 5e-1, 1.0)


def _label_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Series:
    """One named, labeled time series of ``(t, value)`` points.

    ``kind`` fixes the update verb: a ``counter`` only moves up
    (``inc`` appends the new cumulative total), a ``gauge`` is set to
    the observed level. Points land in a bounded ring; updates within
    ``resolution`` seconds of the newest point coalesce into it."""

    __slots__ = ("name", "labels", "kind", "clock", "points",
                 "resolution", "_total")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 kind: str, clock: str, max_points: int,
                 resolution: float):
        self.name = name
        self.labels = labels
        self.kind = kind
        self.clock = clock
        self.points: Deque[Tuple[float, float]] = deque(maxlen=max_points)
        self.resolution = resolution
        self._total = 0.0

    # -- updates -------------------------------------------------------------

    def _push(self, t: float, v: float) -> None:
        pts = self.points
        if pts and t - pts[-1][0] < self.resolution:
            pts[-1] = (max(t, pts[-1][0]), v)
        else:
            pts.append((t, v))

    def inc(self, t: float, delta: float = 1.0) -> None:
        assert self.kind == "counter", f"{self.name} is a {self.kind}"
        if delta < 0:
            raise ValueError(f"counter {self.name}: negative inc {delta}")
        self._total += delta
        self._push(t, self._total)

    def set(self, t: float, value: float) -> None:
        assert self.kind == "gauge", f"{self.name} is a {self.kind}"
        self._total = float(value)
        self._push(t, self._total)

    # -- queries -------------------------------------------------------------

    @property
    def value(self) -> float:
        """Latest level: cumulative total (counter) / last set (gauge)."""
        return self._total

    def value_at(self, t: float) -> float:
        """Step interpolation: value of the last point at or before
        ``t`` (0.0 before the first retained point)."""
        v = 0.0
        for pt, pv in self.points:
            if pt > t:
                break
            v = pv
        return v

    def rate(self, t0: Optional[float] = None,
             t1: Optional[float] = None) -> float:
        """Counter increase per second over [t0, t1] (defaults to the
        retained window)."""
        assert self.kind == "counter"
        if len(self.points) < 2:
            return 0.0
        lo = self.points[0][0] if t0 is None else t0
        hi = self.points[-1][0] if t1 is None else t1
        if hi <= lo:
            return 0.0
        return (self.value_at(hi) - self.value_at(lo)) / (hi - lo)

    def to_jsonable(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "kind": self.kind, "clock": self.clock,
                "value": self._total,
                "points": [[t, v] for t, v in self.points]}


class HistogramSeries:
    """Prometheus-shape histogram: cumulative bucket counts + sum +
    count, with a bounded ring of ``(t, count)`` steps so the observe
    cadence survives as a time series too."""

    __slots__ = ("name", "labels", "clock", "buckets", "bucket_counts",
                 "sum", "count", "points", "resolution")
    kind = "histogram"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 clock: str, buckets: Tuple[float, ...], max_points: int,
                 resolution: float):
        if list(buckets) != sorted(buckets) or len(set(buckets)) != \
                len(buckets):
            raise ValueError(f"histogram {name}: buckets must be "
                             f"strictly increasing, got {buckets}")
        self.name = name
        self.labels = labels
        self.clock = clock
        self.buckets = tuple(float(b) for b in buckets)
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # +inf bucket
        self.sum = 0.0
        self.count = 0
        self.points: Deque[Tuple[float, float]] = deque(maxlen=max_points)
        self.resolution = resolution

    def observe(self, t: float, value: float) -> None:
        self.sum += value
        self.count += 1
        for i, le in enumerate(self.buckets):
            if value <= le:
                self.bucket_counts[i] += 1
                break
        else:
            self.bucket_counts[-1] += 1
        pts = self.points
        if pts and t - pts[-1][0] < self.resolution:
            pts[-1] = (max(t, pts[-1][0]), float(self.count))
        else:
            pts.append((t, float(self.count)))

    def cumulative_buckets(self) -> List[Tuple[float, int]]:
        """[(le, cumulative_count)...] ending with (+inf, count) — the
        OpenMetrics exposition shape."""
        out, acc = [], 0
        for le, c in zip(self.buckets, self.bucket_counts):
            acc += c
            out.append((le, acc))
        out.append((float("inf"), self.count))
        return out

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    @property
    def value(self) -> float:
        """Observation count — the histogram's counter-like face, so
        hub aggregation and counter tracks treat it uniformly."""
        return float(self.count)

    def value_at(self, t: float) -> float:
        v = 0.0
        for pt, pv in self.points:
            if pt > t:
                break
            v = pv
        return v

    def to_jsonable(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "kind": "histogram", "clock": self.clock,
                "sum": self.sum, "count": self.count,
                "buckets": [[le, c] for le, c in
                            self.cumulative_buckets()]}


class Telemetry:
    """Registry of series for one run, on one clock domain.

    ``counter`` / ``gauge`` / ``histogram`` are memoized by
    ``(name, labels)`` so emission sites can call them in the hot loop:
    after the first call a lookup is one dict probe. Series creation
    order is preserved (export order is deterministic)."""

    def __init__(self, clock: str = "virtual", max_points: int = 4096,
                 resolution: float = 0.0):
        if clock not in CLOCKS:
            raise ValueError(f"clock must be one of {CLOCKS}, "
                             f"got {clock!r}")
        self.clock = clock
        self.max_points = max_points
        self.resolution = resolution
        self._series: Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                           object] = {}

    # -- series constructors -------------------------------------------------

    def _get(self, cls, kind: str, name: str, labels: Dict[str, object],
             **kw):
        key = (name, _label_key(labels))
        s = self._series.get(key)
        if s is None:
            if cls is Series:
                s = Series(name, key[1], kind, self.clock,
                           self.max_points, self.resolution)
            else:
                s = HistogramSeries(name, key[1], self.clock,
                                    kw.get("buckets", DEFAULT_BUCKETS),
                                    self.max_points, self.resolution)
            self._series[key] = s
        elif s.kind != kind:
            raise ValueError(f"series {name}{dict(key[1])} already "
                             f"registered as {s.kind}, not {kind}")
        return s

    def counter(self, name: str, **labels) -> Series:
        return self._get(Series, "counter", name, labels)

    def gauge(self, name: str, **labels) -> Series:
        return self._get(Series, "gauge", name, labels)

    def histogram(self, name: str,
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels) -> HistogramSeries:
        return self._get(HistogramSeries, "histogram", name, labels,
                         buckets=buckets)

    # -- queries -------------------------------------------------------------

    def series(self) -> List[object]:
        return list(self._series.values())

    def find(self, name: str) -> List[object]:
        return [s for (n, _), s in self._series.items() if n == name]

    def get(self, name: str, **labels):
        return self._series.get((name, _label_key(labels)))

    def __len__(self) -> int:
        return len(self._series)

    def n_points(self) -> int:
        return sum(len(s.points) for s in self._series.values())

    def to_jsonable(self) -> dict:
        return {"clock": self.clock,
                "series": [s.to_jsonable() for s in self.series()]}


# ---------------------------------------------------------------------------
# SLO burn-rate monitor
# ---------------------------------------------------------------------------

class SloBurnRate:
    """Multi-window burn-rate alerting over the deadline-miss rate.

    ``budget`` is the tolerated miss fraction (the error budget, e.g.
    0.02 = 2% of requests may miss their deadline). The burn rate of a
    window is ``miss_rate / budget`` — 1.0 means the budget is being
    consumed exactly at its sustainable pace. An alert fires when the
    FAST window (page-quickly signal) and the SLOW window (ignore
    blips) both exceed their thresholds with at least ``min_events``
    outcomes observed in the fast window — the standard two-window
    guard against paging on a single unlucky request.

    Hysteresis: while firing, no further alerts; the monitor re-arms
    only after both windows fall below half their thresholds (an
    ``slo_recovered`` mark is recorded so the alert has an extent).

    ``record`` is called from the two sites that already do goodput
    accounting (request completion and expired-at-dequeue drops), on
    the caller's clock; the optional ``metrics`` registry routes the
    alert into the span store (instant on the ``runtime`` track), the
    JSON event log, and a burn-rate gauge pair in the telemetry."""

    def __init__(self, budget: float = 0.02,
                 fast_window_s: float = 0.005, slow_window_s: float = 0.05,
                 fast_burn: float = 10.0, slow_burn: float = 4.0,
                 min_events: int = 8):
        if budget <= 0:
            raise ValueError("budget must be > 0")
        if fast_window_s >= slow_window_s:
            raise ValueError("fast window must be shorter than slow")
        self.budget = budget
        self.fast_window_s = fast_window_s
        self.slow_window_s = slow_window_s
        self.fast_burn = fast_burn
        self.slow_burn = slow_burn
        self.min_events = min_events
        self._events: Deque[Tuple[float, bool]] = deque()
        self.firing = False
        self.alerts: List[dict] = []
        self.recoveries: List[dict] = []

    def _window(self, now: float, w: float) -> Tuple[int, int]:
        lo = now - w
        total = miss = 0
        for t, is_miss in reversed(self._events):
            if t < lo:
                break
            total += 1
            miss += is_miss
        return total, miss

    def burn(self, now: float, window_s: float) -> Tuple[float, int]:
        """(burn rate, events observed) over [now - window_s, now]."""
        total, miss = self._window(now, window_s)
        if total == 0:
            return 0.0, 0
        return (miss / total) / self.budget, total

    def record(self, now: float, miss: bool, metrics=None) -> None:
        ev = self._events
        ev.append((now, bool(miss)))
        lo = now - self.slow_window_s
        while ev and ev[0][0] < lo:
            ev.popleft()
        fast, n_fast = self.burn(now, self.fast_window_s)
        slow, _ = self.burn(now, self.slow_window_s)
        tel = getattr(metrics, "telemetry", None) if metrics is not None \
            else None
        if tel is not None:
            tel.gauge("fhe_slo_burn_rate", window="fast").set(now, fast)
            tel.gauge("fhe_slo_burn_rate", window="slow").set(now, slow)
        if not self.firing:
            if (fast >= self.fast_burn and slow >= self.slow_burn
                    and n_fast >= self.min_events):
                self.firing = True
                alert = {"t": now, "fast_burn": fast, "slow_burn": slow,
                         "budget": self.budget}
                self.alerts.append(alert)
                self._emit(metrics, "slo_alert", now,
                           fast_burn=fast, slow_burn=slow,
                           budget=self.budget)
        elif fast < self.fast_burn / 2 and slow < self.slow_burn / 2:
            self.firing = False
            self.recoveries.append({"t": now, "fast_burn": fast,
                                    "slow_burn": slow})
            self._emit(metrics, "slo_recovered", now,
                       fast_burn=fast, slow_burn=slow)

    @staticmethod
    def _emit(metrics, name: str, now: float, **fields) -> None:
        if metrics is None:
            return
        tr = getattr(metrics, "tracer", None)
        if tr is not None:
            tr.instant(name, now, track="runtime", **fields)
        log = getattr(metrics, "event_log", None)
        if log is not None:
            log.emit(name, now, **fields)
