"""Structured one-line-JSON event log (``serve_fhe --log-json``).

Machine-readable sibling of `MetricsRegistry.format_table`: one JSON
object per line per request lifecycle event, emitted as the event
happens (timeline order), so a serving run can be tailed, grepped, or
replayed without parsing the human table.

Events and their emitters:

* ``accepted`` / ``rejected``   — admission (queue / executor door)
* ``routed``                    — fleet router placement decision
* ``preempted``                 — flight eviction at a round boundary
* ``completed`` / ``deadline_miss`` — request left the system
* ``dropped``                   — expired at dequeue, never served
* ``slo_alert`` / ``slo_recovered`` — burn-rate monitor transitions
                                  (repro.obs.telemetry.SloBurnRate);
                                  no request in scope

Every record carries ``ts`` (timeline seconds — virtual or wall,
matching the backend's clock), ``event``, and, when a request is in
scope, ``request_id`` / ``tenant`` / ``workload`` / ``deadline_slack_s``
(deadline minus ts; negative = already late; null = best-effort).

Like the tracer, the log hangs off the shared registry
(``metrics.event_log``) and absence means disabled.
"""
from __future__ import annotations

import json
from typing import IO

# requests are duck-typed (runtime.queue.Request) to avoid importing
# the runtime package from obs (see tracer.py)

EVENTS = ("accepted", "rejected", "routed", "preempted", "completed",
          "deadline_miss", "dropped", "slo_alert", "slo_recovered")


class JsonEventLog:
    def __init__(self, stream: IO[str]):
        self.stream = stream
        self.n_events = 0

    def emit(self, event: str, t: float,
             request=None, **fields) -> None:
        rec = {"ts": t, "event": event}
        if request is not None:
            rec["request_id"] = request.request_id
            rec["tenant"] = request.tenant
            rec["workload"] = request.workload
            rec["deadline_slack_s"] = (
                request.deadline_s - t
                if request.deadline_s is not None else None)
        rec.update(fields)
        self.stream.write(json.dumps(rec) + "\n")
        self.n_events += 1
