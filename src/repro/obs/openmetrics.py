"""OpenMetrics / Prometheus text exposition of a `Telemetry` snapshot.

``render`` serializes every telemetry series (plus, optionally, the
`MetricsRegistry`'s end-of-run aggregates) in the OpenMetrics text
format: ``# TYPE`` / ``# HELP`` metadata per family, one sample line
per labeled series, counters suffixed ``_total``, histograms exploded
into ``_bucket{le=...}`` / ``_sum`` / ``_count``, terminated by
``# EOF``. The output loads into any Prometheus-compatible scraper —
and into ``parse`` below, the strict self-parser CI runs over every
emitted file (``python -m repro.obs.openmetrics validate FILE``), so a
formatting regression fails the build instead of a dashboard.

Timestamps are deliberately omitted from sample lines: the serving
timeline is virtual for the DES backends and OpenMetrics timestamps
are wall-epoch by convention; the time-resolved view lives in the
Perfetto counter tracks (repro.obs.perfetto), this file is the
"current levels" snapshot.
"""
from __future__ import annotations

import math
import re
import sys
from typing import Dict, List, Optional, Tuple

from repro.obs.telemetry import HistogramSeries, Telemetry

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
# sample line: name{labels} value   (no timestamp — see module doc)
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>\S+)$")
_LABEL_PAIR_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')

HELP: Dict[str, str] = {
    "fhe_pim_bank_busy_seconds":
        "busy seconds accumulated per PIM bank (load + max(exec, xfer))",
    "fhe_pim_bank_busy_cycles":
        "ISA cycles retired per PIM bank, by dominant stage phase",
    "fhe_pim_bank_utilization":
        "per-bank busy fraction of the pipeline round, by stage phase",
    "fhe_pim_move_bytes":
        "bytes moved per interconnect scope (XFER + STORE traffic)",
    "fhe_pim_move_bw_frac":
        "movement bandwidth as a fraction of the scope's PimArch peak",
    "fhe_partition_busy_seconds":
        "busy seconds accumulated per pipeline partition",
    "fhe_partition_utilization":
        "per-partition busy fraction of the pipeline round",
    "fhe_stage_wall_seconds":
        "measured wall seconds per pipeline stage (ciphertext backend)",
    "fhe_device_queue_depth": "queued requests per fleet device",
    "fhe_device_inflight_occupancy":
        "occupied fraction of a device's in-flight batch slots",
    "fhe_requests_finished": "requests that left the system, by status",
    "fhe_goodput_requests": "deadline-bearing requests completed in time",
    "fhe_slo_burn_rate":
        "deadline-miss rate over the window as a multiple of the budget",
}


def _fmt_value(v: float) -> str:
    if v != v:
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _fmt_labels(labels: Tuple[Tuple[str, str], ...],
                extra: Optional[Tuple[str, str]] = None) -> str:
    items = list(labels) + ([extra] if extra is not None else [])
    if not items:
        return ""
    body = ",".join(
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in items)
    return "{" + body + "}"


def registry_families(metrics) -> List[Tuple[str, str, List[Tuple[Tuple, float]]]]:
    """(name, type, [(labels, value)...]) families distilled from a
    `MetricsRegistry` — the end-of-run aggregates exposed next to the
    time-series so one scrape carries both."""
    fams: List[Tuple[str, str, List[Tuple[Tuple, float]]]] = []
    counters = [((("name", k),), float(v))
                for k, v in sorted(metrics.counters.items())]
    if counters:
        fams.append(("fhe_runtime_events", "counter", counters))
    fams.append(("fhe_elapsed_seconds", "gauge",
                 [((), float(metrics.elapsed_s))]))
    lat = metrics.request_latency
    if lat.count:
        fams.append(("fhe_request_latency_seconds", "summary", [
            ((("quantile", "0.5"),), lat.p50),
            ((("quantile", "0.95"),), lat.p95),
            ((("quantile", "0.99"),), lat.p99),
        ]))
    occ = metrics.device_occupancy()
    if occ:
        fams.append(("fhe_device_occupancy", "gauge",
                     [((("device", str(d)),), float(f))
                      for d, f in occ.items()]))
    return fams


def render(telemetry: Optional[Telemetry],
           metrics=None) -> str:
    """OpenMetrics text for a telemetry snapshot (and optionally the
    registry aggregates). Families are grouped (one # TYPE block per
    metric name), label sets keep series-creation order."""
    lines: List[str] = []
    by_name: Dict[str, List] = {}
    if telemetry is not None:
        for s in telemetry.series():
            by_name.setdefault(s.name, []).append(s)
    for name, group in by_name.items():
        kind = group[0].kind
        lines.append(f"# TYPE {name} {kind}")
        help_text = HELP.get(name)
        if help_text:
            lines.append(f"# HELP {name} {help_text}")
        # clock-domain annotation (our extension; parsers skip unknown
        # comment lines) — virtual DES seconds vs wall seconds
        lines.append(f"# CLOCK {name} {group[0].clock}")
        for s in group:
            if isinstance(s, HistogramSeries):
                for le, c in s.cumulative_buckets():
                    lines.append(
                        f"{name}_bucket"
                        f"{_fmt_labels(s.labels, ('le', _fmt_value(le)))}"
                        f" {_fmt_value(c)}")
                lines.append(f"{name}_sum{_fmt_labels(s.labels)} "
                             f"{_fmt_value(s.sum)}")
                lines.append(f"{name}_count{_fmt_labels(s.labels)} "
                             f"{_fmt_value(s.count)}")
            elif s.kind == "counter":
                lines.append(f"{name}_total{_fmt_labels(s.labels)} "
                             f"{_fmt_value(s.value)}")
            else:
                lines.append(f"{name}{_fmt_labels(s.labels)} "
                             f"{_fmt_value(s.value)}")
    if metrics is not None:
        for name, kind, samples in registry_families(metrics):
            lines.append(f"# TYPE {name} {kind}")
            suffix = "_total" if kind == "counter" else ""
            for labels, value in samples:
                lines.append(f"{name}{suffix}{_fmt_labels(tuple(labels))} "
                             f"{_fmt_value(value)}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# strict self-parser (the CI gate)
# ---------------------------------------------------------------------------

class ParsedMetric:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name, labels, value):
        self.name, self.labels, self.value = name, labels, value


def _parse_value(tok: str) -> float:
    if tok == "+Inf":
        return math.inf
    if tok == "-Inf":
        return -math.inf
    if tok == "NaN":
        return math.nan
    return float(tok)   # raises ValueError on garbage


def parse(text: str) -> Tuple[List[ParsedMetric], List[str]]:
    """Parse OpenMetrics text strictly. Returns (samples, errors);
    an empty error list means the document is valid.

    Enforced: every sample's family has a prior ``# TYPE``; metric and
    label names match the spec charset; counter samples end in
    ``_total``; histogram ``le`` bounds are sorted with a ``+Inf``
    bucket whose count equals ``_count``; values parse as floats; no
    duplicate (name, labels) sample; ``# EOF`` present, last, unique."""
    errs: List[str] = []
    samples: List[ParsedMetric] = []
    types: Dict[str, str] = {}
    seen = set()
    hist: Dict[Tuple[str, Tuple], List[Tuple[float, float]]] = {}
    hist_count: Dict[Tuple[str, Tuple], float] = {}
    eof_at = None
    lines = text.split("\n")
    if lines and lines[-1] == "":
        lines.pop()
    for ln, line in enumerate(lines, 1):
        if eof_at is not None:
            errs.append(f"line {ln}: content after # EOF")
            break
        if line == "# EOF":
            eof_at = ln
            continue
        if line.startswith("#"):
            parts = line.split(" ", 3)
            if len(parts) >= 3 and parts[1] == "TYPE":
                name, kind = parts[2], (parts[3] if len(parts) > 3 else "")
                if not _NAME_RE.match(name):
                    errs.append(f"line {ln}: bad metric name {name!r}")
                if kind not in ("counter", "gauge", "histogram",
                                "summary", "untyped", "info"):
                    errs.append(f"line {ln}: bad type {kind!r}")
                if name in types:
                    errs.append(f"line {ln}: duplicate TYPE for {name}")
                types[name] = kind
            continue
        if not line.strip():
            errs.append(f"line {ln}: blank line")
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            errs.append(f"line {ln}: unparseable sample {line!r}")
            continue
        name, raw_labels = m.group("name"), m.group("labels")
        labels: List[Tuple[str, str]] = []
        if raw_labels:
            matched = _LABEL_PAIR_RE.findall(raw_labels)
            rebuilt = ",".join(f'{k}="{v}"' for k, v in matched)
            if rebuilt != raw_labels:
                errs.append(f"line {ln}: malformed labels "
                            f"{{{raw_labels}}}")
                continue
            for k, _v in matched:
                if not _LABEL_RE.match(k):
                    errs.append(f"line {ln}: bad label name {k!r}")
            labels = matched
        try:
            value = _parse_value(m.group("value"))
        except ValueError:
            errs.append(f"line {ln}: bad value {m.group('value')!r}")
            continue
        # resolve the family this sample belongs to
        family = None
        for base, kind in types.items():
            if name == base:
                family = (base, kind, "")
            elif name.startswith(base + "_"):
                suf = name[len(base):]
                if suf in ("_total", "_bucket", "_sum", "_count"):
                    cand = (base, kind, suf)
                    if family is None or len(base) > len(family[0]):
                        family = cand
        if family is None:
            errs.append(f"line {ln}: sample {name!r} has no # TYPE")
            continue
        base, kind, suf = family
        if kind == "counter" and suf != "_total":
            errs.append(f"line {ln}: counter sample {name!r} must "
                        f"end in _total")
        if kind == "gauge" and suf != "":
            errs.append(f"line {ln}: gauge sample {name!r} must not "
                        f"carry a suffix")
        if kind == "histogram" and suf not in ("_bucket", "_sum",
                                               "_count"):
            errs.append(f"line {ln}: histogram sample {name!r} needs "
                        f"a _bucket/_sum/_count suffix")
        key = (name, tuple(sorted(labels)))
        if key in seen:
            errs.append(f"line {ln}: duplicate sample {name}"
                        f"{dict(labels)}")
        seen.add(key)
        if kind == "histogram" and suf == "_bucket":
            le = dict(labels).get("le")
            if le is None:
                errs.append(f"line {ln}: _bucket without le label")
            else:
                hkey = (base, tuple(sorted(
                    (k, v) for k, v in labels if k != "le")))
                hist.setdefault(hkey, []).append(
                    (_parse_value(le), value))
        if kind == "histogram" and suf == "_count":
            hist_count[(base, tuple(sorted(labels)))] = value
        samples.append(ParsedMetric(name, dict(labels), value))
    if eof_at is None:
        errs.append("missing # EOF terminator")
    for (base, labels), buckets in hist.items():
        les = [le for le, _ in buckets]
        if les != sorted(les):
            errs.append(f"{base}{dict(labels)}: le bounds not sorted")
        if not les or les[-1] != math.inf:
            errs.append(f"{base}{dict(labels)}: missing +Inf bucket")
        counts = [c for _, c in buckets]
        if counts != sorted(counts):
            errs.append(f"{base}{dict(labels)}: bucket counts "
                        f"not monotone")
        total = hist_count.get((base, labels))
        if total is not None and counts and counts[-1] != total:
            errs.append(f"{base}{dict(labels)}: +Inf bucket "
                        f"{counts[-1]} != _count {total}")
    return samples, errs


def validate_text(text: str) -> List[str]:
    return parse(text)[1]


def write_metrics(path: str, telemetry: Optional[Telemetry],
                  metrics=None) -> str:
    text = render(telemetry, metrics)
    errs = validate_text(text)
    if errs:   # render/parse must round-trip by construction
        raise AssertionError(f"emitted invalid OpenMetrics: {errs[:3]}")
    with open(path, "w") as f:
        f.write(text)
    return text


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if len(argv) != 2 or argv[0] != "validate":
        print("usage: python -m repro.obs.openmetrics validate FILE",
              file=sys.stderr)
        return 2
    try:
        with open(argv[1]) as f:
            text = f.read()
    except OSError as e:
        print(f"INVALID {argv[1]}: {e}", file=sys.stderr)
        return 1
    samples, errs = parse(text)
    if errs:
        for e in errs[:20]:
            print(f"INVALID {e}", file=sys.stderr)
        return 1
    print(f"OK {argv[1]}: {len(samples)} samples")
    return 0


if __name__ == "__main__":
    sys.exit(main())
