"""Critical-path analysis over a `SpanStore`.

Answers "where did this request's latency go?" structurally instead of
by post-hoc subtraction of aggregate percentiles:

* `critical_path(store, request_id, k)` — walk the request's span tree
  from the root, at each node descending into the child covering the
  most of the node's window (following the ``service -> batch_span``
  link into the shared batch tree, clipped to the request's service
  window), and report the top-k chain nodes by **exclusive
  contribution** — the part of the node's window its chosen child does
  not explain. Contributions along the chain telescope: they sum to
  the root duration (= the request's recorded latency), so the output
  is a complete attribution, not a sample.

* `workload_breakdown(store)` — fleet-wide aggregation for the fig21
  table: per workload, latency split into queueing (arrival → service
  start) and service, with service further attributed to the
  load / compute / movement buckets the executed stages' spans carry
  (the same OpCost channels the analytic and PIM cost models bill).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.obs.span import Span, SpanStore


@dataclasses.dataclass
class Segment:
    name: str
    contribution_s: float        # window time not explained by the child
    start_s: float
    end_s: float
    track: str
    span_id: int

    def to_jsonable(self) -> dict:
        return dataclasses.asdict(self)


def _overlap(s: Span, lo: float, hi: float) -> float:
    end = s.end_s if s.end_s is not None else s.start_s
    return max(0.0, min(end, hi) - max(s.start_s, lo))


def _candidates(store: SpanStore, node: Span) -> List[Span]:
    """Children of ``node`` plus any batch tree its attrs link to."""
    out = store.children(node.span_id)
    link = node.attrs.get("batch_span")
    if link is not None:
        linked = store.get(link)
        if linked is not None:
            out = out + [linked]
    return out


def request_chain(store: SpanStore, request_id: int) -> List[Span]:
    """Root-to-leaf chain following the dominant child at each level."""
    root = store.request_root(request_id)
    if root is None:
        return []
    chain = [root]
    lo, hi = root.start_s, root.end_s if root.end_s is not None \
        else root.start_s
    node = root
    while True:
        kids = _candidates(store, node)
        if not kids:
            break
        best = max(kids, key=lambda s: _overlap(s, lo, hi))
        if _overlap(best, lo, hi) <= 0.0:
            break
        chain.append(best)
        lo = max(lo, best.start_s)
        hi = min(hi, best.end_s if best.end_s is not None else best.start_s)
        node = best
    return chain


def critical_path(store: SpanStore, request_id: int,
                  k: int = 5) -> List[Segment]:
    root = store.request_root(request_id)
    if root is None or root.end_s is None:
        return []
    chain = request_chain(store, request_id)
    lo, hi = root.start_s, root.end_s
    segs: List[Segment] = []
    for i, node in enumerate(chain):
        lo = max(lo, node.start_s)
        hi = min(hi, node.end_s if node.end_s is not None else node.start_s)
        window = max(0.0, hi - lo)
        child_cover = (_overlap(chain[i + 1], lo, hi)
                       if i + 1 < len(chain) else 0.0)
        segs.append(Segment(node.name, window - child_cover,
                            lo, hi, node.track, node.span_id))
    segs.sort(key=lambda s: -s.contribution_s)
    return segs[:k]


# ---------------------------------------------------------------------------
# fleet-wide attribution (the fig21 table)
# ---------------------------------------------------------------------------

_BUCKETS = ("queue_s", "load_s", "compute_s", "move_s", "other_s")


def _stage_weights(store: SpanStore, batch_id: Optional[int]):
    """(load, compute, move) second-weights summed over the batch
    subtree's stage spans; None when the batch carries no stage data."""
    if batch_id is None:
        return None
    tot = [0.0, 0.0, 0.0]
    found = False
    for s in store.subtree(batch_id):
        if s.name != "stage":
            continue
        found = True
        tot[0] += float(s.attrs.get("load_s", 0.0))
        tot[1] += float(s.attrs.get("compute_s", 0.0))
        tot[2] += float(s.attrs.get("move_s", 0.0))
    return tot if found and sum(tot) > 0 else None


def workload_breakdown(store: SpanStore) -> Dict[str, Dict[str, float]]:
    """Per-workload mean latency attribution over completed requests.

    Returns ``{workload: {n, latency_s, queue_s, load_s, compute_s,
    move_s, other_s}}`` where the last five are mean seconds per
    request and sum to ``latency_s``. Service time is split across
    load/compute/move proportionally to the executed stages' billed
    seconds (exact for the analytic/pim virtual-clock backends, which
    bill from the same buckets); service with no stage data (e.g. mesh
    placeholder stages) lands in ``other_s``.
    """
    acc: Dict[str, Dict[str, float]] = {}
    for root in store.by_name("request"):
        if root.end_s is None or root.attrs.get("status") not in (
                "completed", "deadline_miss"):
            continue
        w = str(root.attrs.get("workload", "?"))
        a = acc.setdefault(w, {"n": 0, "latency_s": 0.0,
                               **{b: 0.0 for b in _BUCKETS}})
        a["n"] += 1
        latency = root.end_s - root.start_s
        a["latency_s"] += latency
        service = None
        for c in store.children(root.span_id):
            if c.name == "service":
                service = c
        if service is None or service.end_s is None:
            a["other_s"] += latency
            continue
        queue = max(0.0, service.start_s - root.start_s)
        svc = max(0.0, service.end_s - service.start_s)
        a["queue_s"] += queue
        a["other_s"] += max(0.0, latency - queue - svc)
        weights = _stage_weights(store, service.attrs.get("batch_span"))
        if weights is None:
            a["other_s"] += svc
            continue
        wsum = sum(weights)
        a["load_s"] += svc * weights[0] / wsum
        a["compute_s"] += svc * weights[1] / wsum
        a["move_s"] += svc * weights[2] / wsum
    for a in acc.values():
        n = max(1, a["n"])
        for k in ("latency_s",) + _BUCKETS:
            a[k] /= n
    return acc
