"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax
device state. Single pod: 16x16 = 256 chips (data, model). Multi-pod:
2 pods x 256 = 512 chips with a leading `pod` axis (the slow/DCN axis —
grad-compression and pure-DP only cross it).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over local devices (smoke tests / examples)."""
    return _make_mesh((data, model), ("data", "model"))
