import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines, before any jax-importing module: jax locks the
#   device count on first init. Only the dry-run sees 512 placeholder
#   devices; smoke tests and benches see the 1 real CPU device.

"""Multi-pod dry-run driver.

For every (architecture x input shape) cell, lower + compile the step on
the production mesh (single-pod 16x16 and multi-pod 2x16x16), record
memory_analysis / cost_analysis / per-collective byte totals parsed from
the compiled HLO, and append to benchmarks/results/dryrun.jsonl.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # 512-chip pass
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
from repro.compat import set_mesh as compat_set_mesh  # noqa: E402

from repro.configs import list_archs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import SHAPES, build_cell  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results")

COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|u64|u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")

DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
               "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "pred": 1}
for _k in list(DTYPE_BYTES):
    if _k.startswith("f8"):
        DTYPE_BYTES[_k] = 1


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt if not dt.startswith("f8") else dt, 1)
    return total


def collective_bytes(hlo_text: str):
    """Sum result bytes of every collective op, by kind (per-device)."""
    out = {}
    for type_str, kind in COLLECTIVE_RE.findall(hlo_text):
        b = _shape_bytes(type_str)
        if kind.endswith("-start"):
            kind = kind[:-6]
        out[kind] = out.get(kind, 0) + b
    return out


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": 512 if multi_pod else 256}
    cell = build_cell(arch, shape, mesh)
    if cell["skip"]:
        rec.update(status="skipped", reason=cell["reason"])
        return rec
    t0 = time.time()
    try:
        with compat_set_mesh(mesh):
            jitted = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                             out_shardings=cell["out_shardings"])
            lowered = jitted.lower(*cell["args"])
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            flops_per_device=ca.get("flops", 0.0),
            bytes_per_device=ca.get("bytes accessed", 0.0),
            collective_bytes=coll,
            collective_total=sum(coll.values()),
            argument_bytes=getattr(ma, "argument_size_in_bytes", None),
            output_bytes=getattr(ma, "output_size_in_bytes", None),
            temp_bytes=getattr(ma, "temp_size_in_bytes", None),
            hlo_chars=len(hlo),
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   tb=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = args.out or os.path.join(RESULTS_DIR, "dryrun.jsonl")
    archs = [args.arch] if args.arch else [
        a.replace("_", "-") for a in list_archs()]
    # canonical dashed names
    from repro.configs import DASHED
    archs = [next(k for k, v in DASHED.items()
                  if v == a.replace("-", "_")) if a.replace("-", "_") in
             DASHED.values() else a for a in archs]
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_skip = n_err = 0
    with open(out_path, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    rec = run_cell(arch, shape, mp)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    tag = rec["status"]
                    n_ok += tag == "ok"
                    n_skip += tag == "skipped"
                    n_err += tag == "error"
                    msg = (f"[{tag:7s}] {arch:24s} {shape:12s} "
                           f"{rec['mesh']:8s}")
                    if tag == "ok":
                        msg += (f" compile={rec['compile_s']:7.1f}s "
                                f"flops/dev={rec['flops_per_device']:.3e} "
                                f"coll={rec['collective_total']/2**20:.1f}MiB")
                    elif tag == "error":
                        msg += " " + rec["error"][:120]
                    print(msg, flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err} -> {out_path}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
