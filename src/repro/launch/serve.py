"""Serving driver: batched prefill + decode with KV cache for any zoo arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
from repro.compat import set_mesh as compat_set_mesh
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    b = args.batch
    s_max = args.prompt_len + args.gen

    with compat_set_mesh(mesh):
        serve = jax.jit(M.make_serve_step(cfg, mesh))
        cache = M.init_cache(cfg, b, s_max)
        if cfg.enc_dec:
            cache["memory"] = jnp.asarray(
                rng.normal(size=(b, 4096, cfg.d_model)), jnp.bfloat16)
        if cfg.xattn_period:
            cache["images"] = jnp.asarray(
                rng.normal(size=(b, cfg.n_img_tokens, cfg.d_model)),
                jnp.bfloat16)
        prompt = rng.integers(0, cfg.vocab, (b, args.prompt_len))
        # prefill by stepping (robust across cache families)
        tok = jnp.asarray(prompt[:, 0], jnp.int32)
        t0 = time.time()
        for i in range(args.prompt_len - 1):
            _, cache = serve(params, cache, jnp.asarray(prompt[:, i],
                                                        jnp.int32),
                             jnp.int32(i))
        outs = []
        tok = jnp.asarray(prompt[:, -1], jnp.int32)
        for i in range(args.gen):
            tok, cache = serve(params, cache, tok,
                               jnp.int32(args.prompt_len - 1 + i))
            outs.append(np.asarray(tok))
        jax.block_until_ready(tok)
        dt = time.time() - t0
        gen = np.stack(outs, axis=1)
        print(f"arch={cfg.name} generated {gen.shape} tokens")
        print(gen[:, :16])
        steps = args.prompt_len - 1 + args.gen
        print(f"{steps} serve steps in {dt:.2f}s -> "
              f"{b * steps / dt:.1f} tok/s (batch={b})")


if __name__ == "__main__":
    main()
