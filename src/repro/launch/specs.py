"""Per-(arch x shape) dry-run cell construction: abstract inputs
(ShapeDtypeStruct — weak-type-correct, shardable, no allocation),
in/out shardings, and the step function to lower.

Shapes (assignment):
    train_4k     seq=4096    global_batch=256   train_step
    prefill_32k  seq=32768   global_batch=32    prefill_step
    decode_32k   seq=32768   global_batch=128   serve_step (1 new token)
    long_500k    seq=524288  global_batch=1     serve_step; sub-quadratic
                 archs only (rwkv6, recurrentgemma) — full-attention archs
                 skip (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.train.optim import abstract_adamw_state, adamw_state_specs

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def cell_applicable(cfg: ArchConfig, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: O(S^2) at 524288 is out of "
                       "scope per assignment (sub-quadratic archs only)")
    return True, ""


def _batch_abstract(cfg: ArchConfig, b: int, s: int, with_labels: bool):
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.xattn_period:
        out["images"] = jax.ShapeDtypeStruct(
            (b, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.enc_dec:
        out["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    return out


def _batch_specs(cfg: ArchConfig, batch_abs, mesh: Mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def spec(x):
        return NamedSharding(mesh, P(dp, *([None] * (len(x.shape) - 1))))

    return jax.tree.map(spec, batch_abs)


def _repl(mesh):
    return NamedSharding(mesh, P())


def build_cell(arch_name: str, shape_name: str, mesh: Mesh,
               cfg_override=None) -> Dict[str, Any]:
    """Returns dict(fn, args, in_shardings, out_shardings, meta) ready for
    jax.jit(fn, in_shardings=..., out_shardings=...).lower(*args).
    `cfg_override` substitutes a modified ArchConfig (roofline depth knobs)."""
    cfg = cfg_override if cfg_override is not None else get_config(arch_name)
    sh = SHAPES[shape_name]
    b, s, kind = sh["batch"], sh["seq"], sh["kind"]
    ok, why = cell_applicable(cfg, shape_name)
    if not ok:
        return {"skip": True, "reason": why, "cfg": cfg}

    params_abs = M.abstract_params(cfg)
    pspecs = M.param_specs(cfg, mesh)
    meta = {"arch": cfg.name, "shape": shape_name, "kind": kind,
            "batch": b, "seq": s}

    if kind == "train":
        batch_abs = _batch_abstract(cfg, b, s, with_labels=True)
        opt_abs = abstract_adamw_state(params_abs)
        ospecs = adamw_state_specs(pspecs, mesh)
        step = M.make_train_step(cfg, mesh)
        metric_names = ["ce", "loss", "grad_norm"] + (
            ["aux"] if cfg.n_experts else []) + (
            ["mtp_ce"] if cfg.mtp else [])
        out_shardings = (pspecs, ospecs, {k: _repl(mesh)
                                          for k in metric_names})
        return dict(skip=False, fn=step,
                    args=(params_abs, opt_abs, batch_abs),
                    in_shardings=(pspecs, ospecs,
                                  _batch_specs(cfg, batch_abs, mesh)),
                    out_shardings=out_shardings, meta=meta, cfg=cfg)

    if kind == "prefill":
        batch_abs = _batch_abstract(cfg, b, s, with_labels=False)
        step = M.make_prefill_step(cfg, mesh)
        return dict(skip=False, fn=step, args=(params_abs, batch_abs),
                    in_shardings=(pspecs, _batch_specs(cfg, batch_abs, mesh)),
                    out_shardings=None, meta=meta, cfg=cfg)

    # decode — serving rules: TP-only weights (no per-step FSDP gathers)
    from repro.sharding.rules import serving_rules
    rules = serving_rules()
    params_abs = M.abstract_params(cfg)
    pspecs = M.param_specs(cfg, mesh, rules)
    cache_abs = M.abstract_cache(cfg, b, s)
    cspecs = M.cache_specs(cfg, mesh, b, s, rules)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tok_abs = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    dp_n = _axes_prod(mesh, dp)
    tok_spec = (NamedSharding(mesh, P(dp))
                if dp and b % dp_n == 0 else _repl(mesh))
    step = M.make_serve_step(cfg, mesh)
    return dict(skip=False, fn=step,
                args=(params_abs, cache_abs, tok_abs, pos_abs),
                in_shardings=(pspecs, cspecs, tok_spec, _repl(mesh)),
                out_shardings=(tok_spec, cspecs), meta=meta, cfg=cfg)


def _axes_prod(mesh: Mesh, axes) -> int:
    import numpy as np
    return int(np.prod([dict(zip(mesh.axis_names,
                                 mesh.devices.shape))[a] for a in axes]))
