"""Multi-tenant FHE serving driver over repro.runtime.

Synthetic tenants submit encrypted requests against registered FHE
workloads; the runtime batches them into slot groups, keeps stage
constants resident in the key cache, and drains them through the
load-save pipeline. Reports latency percentiles, throughput, cache
hit rates, and (on the ciphertext backend) per-workload decrypt
accuracy.

Backends: ``analytic`` (MemoryModel cost model, virtual clock),
``mesh`` (distributed placeholder stages, wall clock), ``ciphertext``
(REAL encrypted execution through the batched CKKS engine — the run
fails if any workload's max |decrypt error| exceeds the parameter
set's CKKS tolerance), ``pim`` (discrete-event simulation of the
hierarchical FHEmem hardware model, repro.pim; pick the hardware
point with ``--pim-preset``).

``--mem-profile {flat,fhemem,hbm2}`` selects the memory model the
mapper and analytic backend price against from the SAME preset
registry the pim backend's hardware points come from
(repro.pim.arch) — with ``--backend pim`` it defaults to the pim
preset, so both sides of the fig19 comparison share one set of
constants.

    PYTHONPATH=src python -m repro.launch.serve_fhe --smoke
    PYTHONPATH=src python -m repro.launch.serve_fhe --smoke \
        --backend ciphertext
    PYTHONPATH=src python -m repro.launch.serve_fhe --smoke \
        --backend pim --pim-preset fhemem
    PYTHONPATH=src python -m repro.launch.serve_fhe --backend mesh \
        --tenants 4 --requests 64 --rate 2000
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.compiler import PassConfig
from repro.core.params import CkksParams, test_params
from repro.core.pipeline import MemoryModel
from repro.core.trace import LevelBudgetExhausted
from repro.pim.arch import PRESETS as PIM_PRESETS
from repro.pim.arch import memory_model as pim_memory_model
from repro.fleet.router import POLICIES as ROUTER_POLICIES
from repro.runtime import (BatchPolicy, KeyCache, PipelinedExecutor,
                           Request)


from repro.runtime.workloads import (HELR_CONSTS, LOLA_CONSTS, lola_infer,
                                     make_helr_iter, make_matvec,
                                     make_poly_eval, matvec_consts,
                                     poly_consts)

WORKLOADS = {
    "helr": (make_helr_iter(), 2, HELR_CONSTS),
    "lola": (lola_infer, 1, LOLA_CONSTS),
    # rotation-heavy: the compiler's BSGS + lazy-rescale showcase
    "matvec": (make_matvec(16), 1, matvec_consts(16)),
    # deeper than the smoke start level: needs bootstrap insertion
    "poly": (make_poly_eval(12), 1, poly_consts(12)),
}


def build_executor(params: CkksParams, mem: MemoryModel, *,
                   backend_name: str, max_batch: int, max_wait_s: float,
                   cache_bytes: int, start_level: int,
                   opt: bool = True,
                   use_kernels: bool = None,
                   verify: bool = False) -> PipelinedExecutor:
    from repro.runtime.executor import resolve_backend
    policy = BatchPolicy(slots_per_ct=params.slots, max_batch=max_batch,
                         max_wait_s=max_wait_s)
    key_cache = (KeyCache(cache_bytes, load_bw=mem.load_bw)
                 if cache_bytes > 0 else None)
    backend = resolve_backend(backend_name, params, mem,
                              use_kernels=use_kernels, verify=verify)
    ex = PipelinedExecutor(params, mem, backend=backend, policy=policy,
                           key_cache=key_cache,
                           pass_config=PassConfig() if opt else None,
                           verify=verify)
    for name, (fn, n_in, consts) in WORKLOADS.items():
        try:
            ex.register(name, fn, n_in, const_names=consts,
                        start_level=start_level)
        except LevelBudgetExhausted:
            print(f"skipping workload {name!r}: deeper than "
                  f"start_level={start_level} and --no-opt disables "
                  f"automatic bootstrap insertion")
    return ex


def build_fleet_scheduler(params: CkksParams, mem: MemoryModel, *,
                          n_devices: int, backend_name: str, router: str,
                          max_batch: int, max_wait_s: float,
                          cache_bytes: int, start_level: int,
                          opt: bool = True, continuous_batching: bool = False,
                          preempt: bool = False, use_kernels: bool = None,
                          verify: bool = False):
    """Fleet-mode mirror of build_executor: N devices (each with its own
    backend instance and caches), one router, one scheduler."""
    from repro.fleet import FleetScheduler
    from repro.runtime.executor import resolve_backend
    policy = BatchPolicy(slots_per_ct=params.slots, max_batch=max_batch,
                         max_wait_s=max_wait_s)

    def backend_factory():
        return resolve_backend(backend_name, params, mem,
                               use_kernels=use_kernels, verify=verify)
    fleet = FleetScheduler(
        params, mem, n_devices=n_devices, backend=backend_factory,
        router=router, policy=policy, cache_bytes=cache_bytes,
        pass_config=PassConfig() if opt else None,
        continuous_batching=continuous_batching, preempt=preempt,
        verify=verify)
    for name, (fn, n_in, consts) in WORKLOADS.items():
        try:
            fleet.register(name, fn, n_in, const_names=consts,
                           start_level=start_level)
        except LevelBudgetExhausted:
            print(f"skipping workload {name!r}: deeper than "
                  f"start_level={start_level} and --no-opt disables "
                  f"automatic bootstrap insertion")
    return fleet


def synth_arrivals(ex, *, n_tenants: int, n_requests: int,
                   rate_rps: float, seed: int, deadline_s: float,
                   encrypt: bool, max_slots: int) -> list:
    """Poisson arrivals from round-robin tenants, alternating workloads.

    With ``encrypt``, each request carries a REAL CKKS ciphertext
    (public-key encryption of a random slot vector on a small
    parameter set) — the runtime never sees plaintext payloads.
    """
    enc = None
    if encrypt:
        from repro.core.context import CkksContext
        from repro.core.encoder import CkksEncoder
        from repro.core.encryptor import CkksEncryptor
        from repro.core.ciphertext import Plaintext
        p_enc = test_params(log_n=8, n_levels=2, dnum=1)
        ctx = CkksContext(p_enc)
        encoder = CkksEncoder(ctx)
        encryptor = CkksEncryptor(ctx, seed=seed)
        sk = encryptor.keygen()
        pk = encryptor.public_keygen(sk)
        scale = float(2 ** p_enc.log_scale)

        def enc(vals):
            pt = Plaintext(encoder.encode(vals, scale, level=1), 1, scale)
            return encryptor.encrypt_pk(pt, pk)

    rng = np.random.default_rng(seed)
    names = list(ex.workloads)
    arrivals = []
    t = 0.0
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        slots = int(rng.integers(1, max_slots + 1))
        # bounded payload values keep deep Horner ladders (poly) inside
        # the first-modulus headroom on the real ciphertext backend
        vals = rng.uniform(-0.8, 0.8, size=min(slots, 128))
        payload = vals
        if enc is not None:
            payload = enc(vals)
        arrivals.append(Request(
            ex.next_request_id(),
            tenant=f"tenant{i % n_tenants}",
            workload=names[i % len(names)],
            arrival_s=t, slots_needed=slots,
            deadline_s=t + deadline_s if deadline_s > 0 else None,
            payload=payload))
    return arrivals


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small params, few requests, fast end-to-end check")
    ap.add_argument("--backend",
                    choices=("analytic", "mesh", "ciphertext", "pim"),
                    default="analytic")
    ap.add_argument("--pim-preset", choices=sorted(PIM_PRESETS),
                    default="fhemem",
                    help="hardware point for --backend pim "
                         "(repro.pim.arch presets)")
    ap.add_argument("--mem-profile", choices=sorted(PIM_PRESETS),
                    default=None,
                    help="price the pipeline against this preset's "
                         "memory model instead of the built-in "
                         "defaults (shared registry with the pim "
                         "backend; defaults to --pim-preset when "
                         "--backend pim)")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve on a simulated fleet of N devices "
                         "(repro.fleet), each wrapping its own "
                         "--backend instance; 0 = single executor")
    ap.add_argument("--router", choices=ROUTER_POLICIES,
                    default="round_robin",
                    help="fleet admission-time placement policy")
    ap.add_argument("--continuous-batching", action="store_true",
                    help="fleet: refill free slot rows of in-flight "
                         "batches between pipeline rounds")
    ap.add_argument("--preempt", action="store_true",
                    help="fleet: preempt best-effort batches at round "
                         "boundaries when a deadline batch is ready")
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--rate", type=float, default=5000.0,
                    help="offered load, requests/s (aggregate)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--deadline-ms", type=float, default=200.0,
                    help="per-request deadline; 0 disables")
    ap.add_argument("--cache-mb", type=int, default=256,
                    help="key cache capacity; 0 disables the cache")
    ap.add_argument("--no-encrypt", action="store_true",
                    help="skip real CKKS payload encryption at ingest")
    ap.add_argument("--use-kernels", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="(--backend ciphertext) route keyswitch + modmul "
                         "through the fused Pallas kernels "
                         "(repro.kernels.keyswitch; bit-exact vs the "
                         "library path, compiled on TPU / interpret mode "
                         "on CPU); default: on iff running on TPU")
    ap.add_argument("--verify", action="store_true",
                    help="static verification (repro.analysis): sweep "
                         "every freshly compiled schedule (per-pass "
                         "diffs, trace/schedule invariants) and — with "
                         "--backend pim — hazard-analyze every lowered "
                         "instruction stream; an error finding aborts "
                         "instead of serving a corrupt artifact")
    ap.add_argument("--opt", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="run the optimizing trace compiler "
                         "(repro.compiler) before pipeline mapping; "
                         "--no-opt serves every trace verbatim")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="record per-request span trees (repro.obs) and "
                         "write a Chrome/Perfetto trace_event JSON here "
                         "(load in https://ui.perfetto.dev or "
                         "chrome://tracing); one track per device, one "
                         "per tenant")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="sample time-series telemetry (repro.obs."
                         "telemetry: per-bank PIM utilization, queue "
                         "depths, goodput, SLO burn rates) during the "
                         "serve and write an OpenMetrics/Prometheus "
                         "exposition here (self-validated; inspect "
                         "with any promtool-compatible reader)")
    ap.add_argument("--log-json", action="store_true",
                    help="emit one JSON line per request lifecycle "
                         "event (accepted/routed/preempted/completed/"
                         "dropped...) to stdout as it happens")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.smoke:
        args.requests = min(args.requests, 60)
        params = test_params(log_n=10, n_levels=8, dnum=2)
        start_level = 7
        mem = MemoryModel(n_partitions=4, partition_bytes=8 * 2 ** 20)
        if args.backend == "ciphertext":
            # real homomorphic execution on CPU: fewer requests, a
            # smaller ring, and no deadlines (wall-clock batches would
            # expire a 200ms budget spuriously on slow runners)
            args.requests = min(args.requests, 24)
            params = test_params(log_n=8, n_levels=8, dnum=2)
            args.deadline_ms = 0.0
    else:
        from repro.core.params import paper_params_bootstrap
        params = paper_params_bootstrap()
        start_level = 20
        mem = MemoryModel(n_partitions=16, partition_bytes=96 * 2 ** 20)

    # shared preset registry (repro.pim.arch): the pim backend recovers
    # its arch from the mem via resolve_backend, so pricing and DES use
    # the same hardware point by construction — which also means the
    # two flags cannot name different points
    profile = args.mem_profile
    if args.backend == "pim":
        if profile is not None and profile != args.pim_preset:
            ap.error(f"--backend pim derives its hardware point from "
                     f"the memory model, so --mem-profile {profile!r} "
                     f"would silently override --pim-preset "
                     f"{args.pim_preset!r}; pass one of them")
        profile = args.pim_preset
    if profile is not None:
        mem = pim_memory_model(profile)

    # the ciphertext backend owns the ingress encryptor (payload values
    # are encrypted under the serving keys at pack time), so the
    # synthetic foreign-key ciphertext wrapping is redundant there
    encrypt = not args.no_encrypt and args.backend != "ciphertext"
    if args.fleet > 0:
        ex = build_fleet_scheduler(
            params, mem, n_devices=args.fleet, backend_name=args.backend,
            router=args.router, max_batch=args.max_batch,
            max_wait_s=args.max_wait_ms * 1e-3,
            cache_bytes=args.cache_mb * 2 ** 20,
            start_level=start_level, opt=args.opt,
            continuous_batching=args.continuous_batching,
            preempt=args.preempt, use_kernels=args.use_kernels,
            verify=args.verify)
    else:
        ex = build_executor(params, mem, backend_name=args.backend,
                            max_batch=args.max_batch,
                            max_wait_s=args.max_wait_ms * 1e-3,
                            cache_bytes=args.cache_mb * 2 ** 20,
                            start_level=start_level, opt=args.opt,
                            use_kernels=args.use_kernels,
                            verify=args.verify)
    arrivals = synth_arrivals(
        ex, n_tenants=args.tenants, n_requests=args.requests,
        rate_rps=args.rate, seed=args.seed,
        deadline_s=args.deadline_ms * 1e-3,
        encrypt=encrypt, max_slots=min(128, params.slots))

    cache_tag = "off" if args.cache_mb <= 0 else f"{args.cache_mb}MiB"
    fleet_tag = (f"fleet of {args.fleet} ({args.router} router"
                 f"{', continuous batching' if args.continuous_batching else ''}"
                 f"{', preemption' if args.preempt else ''}), "
                 if args.fleet > 0 else "")
    print(f"serving {len(arrivals)} requests from {args.tenants} tenants "
          f"({fleet_tag}{args.backend} backend, key cache {cache_tag}, "
          f"compiler {'on' if args.opt else 'off'})")
    import time as _time
    t0 = _time.perf_counter()
    ex.warmup()
    print(f"warmup (compile + key preload): "
          f"{_time.perf_counter() - t0:.2f} s")
    # observability: the tracer/event log hang off the shared registry
    # (fleet devices all share ex.metrics), attached after warmup so
    # deploy-time work stays out of the serving trace
    tracer = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = ex.metrics.tracer = Tracer()
    if args.log_json:
        from repro.obs import JsonEventLog
        ex.metrics.event_log = JsonEventLog(sys.stdout)
    telemetry = None
    if args.metrics_out:
        from repro.obs import SloBurnRate, Telemetry
        wall = args.backend in ("mesh", "ciphertext")
        telemetry = ex.metrics.telemetry = Telemetry(
            clock="wall" if wall else "virtual")
        if args.deadline_ms > 0:
            ex.metrics.slo = SloBurnRate()
    m = ex.serve(arrivals)
    print(m.format_table())
    if args.verify:
        # warmup compiles point metrics at a scratch registry, so the
        # durable record is the one riding the cached schedules (and,
        # for pim, the backend's lower-time counters)
        caches = ([d.compile_cache for d in ex.devices]
                  if args.fleet > 0 else [ex.compile_cache])
        backends = ([d.backend for d in ex.devices]
                    if args.fleet > 0 else [ex.backend])
        scheds = [s for c in caches for s in c._cache.values()]
        v_wall = sum(getattr(s, "_verify_wall_s", 0.0) for s in scheds)
        v_find = sum(len(s.verify_report.findings) for s in scheds
                     if getattr(s, "verify_report", None) is not None)
        v_wall += sum(getattr(b, "verify_wall_s", 0.0) for b in backends)
        v_find += sum(getattr(b, "verify_findings", 0) for b in backends)
        print(f"verify: {len(scheds)} schedule(s) + "
              f"{sum(len(getattr(b, '_lowered', ())) for b in backends)} "
              f"lowered program(s) swept, {v_find} finding(s), "
              f"{v_wall * 1e3:.1f} ms wall")
    if tracer is not None:
        from repro.obs import write_trace
        wall = args.backend in ("mesh", "ciphertext")
        obj = write_trace(tracer.store, args.trace_out,
                          clock="wall" if wall else "virtual",
                          telemetry=telemetry)
        print(f"trace: {len(tracer.store)} spans "
              f"({len(obj['traceEvents'])} events"
              + (f", {len(telemetry)} counter tracks"
                 if telemetry is not None else "")
              + f") -> {args.trace_out}")
    if telemetry is not None:
        from repro.obs import parse_openmetrics, write_metrics
        text = write_metrics(args.metrics_out, telemetry, ex.metrics)
        n = len(parse_openmetrics(text)[0])
        slo = ex.metrics.slo
        slo_tag = (f", {len(slo.alerts)} SLO alert(s)"
                   if slo is not None else "")
        print(f"metrics: {len(telemetry)} series "
              f"({telemetry.n_points()} points, {n} samples, "
              f"{telemetry.clock} clock{slo_tag}) -> {args.metrics_out}")

    if args.backend == "ciphertext":
        tol = (ex.devices[0].backend if args.fleet > 0
               else ex.backend).tolerance
        failed = False
        for w in ex.workloads:
            err = m.decrypt_error.get(w)
            if err is None:
                print(f"accuracy {w:<12} no batch served")
                continue
            ok = err <= tol
            failed |= not ok
            print(f"accuracy {w:<12} max|err|={err:.3e} "
                  f"tol={tol:.3e} {'OK' if ok else 'FAIL'}")
        if failed:
            sys.exit(1)


if __name__ == "__main__":
    main()
