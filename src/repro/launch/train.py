"""Training driver: any zoo arch on the local mesh (or production mesh
under the dry-run device flag), with checkpoint/restart, straggler watch,
and deterministic replay.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
        --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import os
import time

import jax
from repro.compat import set_mesh as compat_set_mesh

from repro.configs import get_config
from repro.data.pipeline import SyntheticLMDataset, shard_batch
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.train.fault import Supervisor
from repro.train.optim import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    ds = SyntheticLMDataset(cfg, args.batch, args.seq)

    with compat_set_mesh(mesh):
        step_fn = jax.jit(M.make_train_step(cfg, mesh,
                                            learning_rate=args.lr))
        start = 0
        if args.resume:
            from repro.train import checkpoint as ckpt
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest:
                tree, start = ckpt.restore_checkpoint(
                    args.ckpt_dir, {"params": params, "opt": opt_state})
                params, opt_state = tree["params"], tree["opt"]
                print(f"resumed from step {start}")

        def make_batch(step):
            return shard_batch(ds.batch_at(step), mesh)

        sup = Supervisor(step_fn, args.ckpt_dir, ckpt_every=args.ckpt_every)
        t0 = time.time()
        (params, opt_state), history = sup.run(
            (params, opt_state), make_batch, args.steps, start_step=start)
        dt = time.time() - t0
        for i, h in enumerate(history):
            if i % args.log_every == 0 or i == len(history) - 1:
                print(f"step {start + i:5d} loss={h['loss']:.4f} "
                      f"ce={h['ce']:.4f} gnorm={h['grad_norm']:.3f}")
        n = max(len(history), 1)
        toks = args.batch * args.seq * n
        print(f"done: {n} steps in {dt:.1f}s "
              f"({toks / dt:.0f} tok/s); events={sup.events}")


if __name__ == "__main__":
    main()
