import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ first lines — see dryrun.py. Roofline runs on the single-pod 16x16 mesh.

"""Roofline driver (EXPERIMENTS.md §Roofline).

XLA's cost_analysis does not multiply while-loop bodies by trip count, so
full-depth scanned models under-report FLOPs/bytes/collectives. We instead
lower UNROLLED reduced-depth configs at two depth knobs (k=1, 2), take the
per-layer slope, and extrapolate linearly to the full depth — exact for
homogeneous stacks, and the recurrence (time-axis) scans that cannot be
unrolled get small documented analytic corrections.

Terms per (arch x shape) on the 16x16 production mesh (v5e numbers):
    compute_s    = flops_per_device / 197e12
    memory_s     = bytes_per_device / 819e9
    collective_s = collective_bytes_per_device / 50e9     (per-link ICI)
    MODEL_FLOPS  = 6*N_active*tokens (train) / 2*N_active*tokens (inference)
    useful ratio = MODEL_FLOPS / (flops_per_device * n_devices)
    roofline fraction = useful-compute-time / max(term)
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
from repro.compat import set_mesh as compat_set_mesh  # noqa: E402

from repro.configs import get_config, DASHED  # noqa: E402
from repro.launch.dryrun import RESULTS_DIR, collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import SHAPES, build_cell, cell_applicable  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.models import layers as L  # noqa: E402

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

CANONICAL = [k for k in DASHED if "_" not in k]


def scaled_cfgs(arch: str, knob: int):
    """Return [(tag, cfg, knob_units)] lowered at this depth knob."""
    cfg = get_config(arch)
    out = []
    if cfg.enc_dec:
        out.append(("encdec", dataclasses.replace(
            cfg, n_layers=knob, n_enc_layers=knob), knob))
    elif cfg.xattn_period:
        per = cfg.xattn_period + 1
        out.append(("superblock", dataclasses.replace(
            cfg, n_layers=per * knob), knob))
    elif cfg.rglru:
        per = len(cfg.block_pattern or ("rglru", "rglru", "attn"))
        out.append(("superblock", dataclasses.replace(
            cfg, n_layers=per * knob), knob))
    elif cfg.n_experts and cfg.first_k_dense:
        out.append(("moe", dataclasses.replace(
            cfg, n_layers=knob, first_k_dense=0), knob))
        out.append(("dense", dataclasses.replace(
            cfg, n_layers=knob, first_k_dense=0, n_experts=0,
            n_shared_experts=0, mtp=False), knob))
    else:
        out.append(("layer", dataclasses.replace(cfg, n_layers=knob), knob))
    return out


def full_knobs(arch: str):
    """(units per tag) at full depth, matching scaled_cfgs tags."""
    cfg = get_config(arch)
    if cfg.enc_dec:
        return {"encdec": cfg.n_layers}
    if cfg.xattn_period:
        return {"superblock": cfg.n_layers // (cfg.xattn_period + 1)}
    if cfg.rglru:
        per = len(cfg.block_pattern or ("rglru", "rglru", "attn"))
        return {"superblock": cfg.n_layers / per}   # 26/3: tail ~ 2/3 sb
    if cfg.n_experts and cfg.first_k_dense:
        return {"moe": cfg.n_layers - cfg.first_k_dense,
                "dense": cfg.first_k_dense}
    return {"layer": cfg.n_layers}


def _measure(cfg, shape: str, mesh) -> dict:
    """Lower+compile one unrolled config; return flops/bytes/collectives."""
    cell = build_cell(cfg.name, shape, mesh, cfg_override=cfg)
    assert not cell["skip"], cell.get("reason")
    with compat_set_mesh(mesh):
        jitted = jax.jit(cell["fn"], in_shardings=cell["in_shardings"],
                         out_shardings=cell["out_shardings"])
        lowered = jitted.lower(*cell["args"])
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll, "coll_total": float(sum(coll.values()))}


def recurrence_correction(arch: str, shape: str) -> float:
    """Analytic per-device FLOPs for time-axis scans (not unrollable).

    RWKV6 state update: ~4 ops x H x dh x dh per token per layer;
    RG-LRU: ~8 ops x width per token per layer (2/3 of layers).
    Train counts fwd + bwd + remat-refwd (x4); inference x1.
    """
    cfg = get_config(arch)
    sh = SHAPES[shape]
    tokens = sh["batch"] * (1 if sh["kind"] == "decode" else sh["seq"])
    factor = 4.0 if sh["kind"] == "train" else 1.0
    if cfg.rwkv:
        h = cfg.d_model // 64
        per_tok_layer = 4 * h * 64 * 64
        total = per_tok_layer * cfg.n_layers * tokens * factor
    elif cfg.rglru:
        w = cfg.lru_width or cfg.d_model
        per_tok_layer = 8 * w
        total = per_tok_layer * (cfg.n_layers * 2 / 3) * tokens * factor
    else:
        return 0.0
    return total / 256   # per device on the 16x16 mesh


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    sh = SHAPES[shape]
    n_active = cfg.active_param_count()
    tokens = sh["batch"] * (1 if sh["kind"] == "decode" else sh["seq"])
    mult = 6.0 if sh["kind"] == "train" else 2.0
    return mult * n_active * tokens


def run_cell(arch: str, shape: str, mesh, k1=1, k2=2) -> dict:
    rec = {"arch": arch, "shape": shape, "mesh": "16x16", "n_devices": 256}
    cfg = get_config(arch)
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    try:
        M.SCAN_UNROLL = True
        L.FLASH_UNROLL = True
        L.FLASH_CHUNK = 4096
        totals = {"flops": 0.0, "bytes": 0.0, "coll_total": 0.0}
        coll_kinds = {}
        fk = full_knobs(arch)
        t0 = time.time()
        for (tag, c1, u1), (_, c2, u2) in zip(scaled_cfgs(arch, k1),
                                              scaled_cfgs(arch, k2)):
            m1 = _measure(c1, shape, mesh)
            m2 = _measure(c2, shape, mesh)
            units = fk[tag]
            for key in ("flops", "bytes", "coll_total"):
                slope = (m2[key] - m1[key]) / (u2 - u1)
                base = m1[key] - slope * u1
                contrib = base + slope * units
                if tag == "dense":       # dense pair: slope only (outer
                    contrib = slope * units   # terms already in the moe pair)
                else:
                    # depth-monotone floor: full depth >= depth-2 measurement
                    # (guards small-cell extrapolation noise)
                    contrib = max(contrib, m2[key])
                totals[key] += contrib
            kinds = set(m1["coll"]) | set(m2["coll"])
            for kk in kinds:
                a, b = m1["coll"].get(kk, 0), m2["coll"].get(kk, 0)
                slope = (b - a) / (u2 - u1)
                base = a - slope * u1
                contrib = (slope * units if tag == "dense"
                           else max(base + slope * units, b))
                coll_kinds[kk] = coll_kinds.get(kk, 0.0) + contrib
        totals["flops"] += recurrence_correction(arch, shape)
        mf = model_flops(arch, shape)
        compute_s = totals["flops"] / PEAK_FLOPS
        memory_s = totals["bytes"] / HBM_BW
        coll_s = totals["coll_total"] / ICI_BW
        terms = {"compute_s": compute_s, "memory_s": memory_s,
                 "collective_s": coll_s}
        dom = max(terms, key=terms.get)
        useful_ratio = mf / max(totals["flops"] * 256, 1.0)
        useful_time = mf / (256 * PEAK_FLOPS)
        rec.update(
            status="ok", measure_s=round(time.time() - t0, 1),
            flops_per_device=totals["flops"],
            bytes_per_device=totals["bytes"],
            collective_bytes_per_device=totals["coll_total"],
            collective_by_kind={k: float(v) for k, v in coll_kinds.items()},
            **{k: float(v) for k, v in terms.items()},
            dominant=dom,
            model_flops=mf,
            useful_flops_ratio=float(useful_ratio),
            roofline_fraction=float(useful_time / max(terms.values())),
        )
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   tb=traceback.format_exc()[-1500:])
    finally:
        M.SCAN_UNROLL = False
        L.FLASH_UNROLL = False
        L.FLASH_CHUNK = 0
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = args.out or os.path.join(RESULTS_DIR, "roofline.jsonl")
    mesh = make_production_mesh(multi_pod=False)
    archs = [args.arch] if args.arch else CANONICAL
    shapes = [args.shape] if args.shape else list(SHAPES)
    with open(out_path, "a") as f:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh)
                f.write(json.dumps(rec) + "\n")
                f.flush()
                if rec["status"] == "ok":
                    print(f"[ok] {arch:24s} {shape:12s} "
                          f"comp={rec['compute_s']*1e3:9.3f}ms "
                          f"mem={rec['memory_s']*1e3:9.3f}ms "
                          f"coll={rec['collective_s']*1e3:9.3f}ms "
                          f"dom={rec['dominant'][:-2]:10s} "
                          f"rf={rec['roofline_fraction']:.3f}", flush=True)
                else:
                    print(f"[{rec['status']}] {arch} {shape} "
                          f"{rec.get('error', rec.get('reason', ''))[:120]}",
                          flush=True)


if __name__ == "__main__":
    main()
