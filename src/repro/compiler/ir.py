"""Rewrite substrate for `FheTrace` transforms.

Passes (repro.compiler.passes) never mutate a trace in place. They walk
the op list, collect a value substitution (old SSA idx -> replacement
idx) and/or emit new ops, then funnel through `finish()`, which resolves
substitution chains, renumbers densely, and prunes everything not
reachable from the outputs. That single funnel keeps every pass output
canonical: args always precede uses, ids are dense, and dead code never
survives a rewrite (so per-pass cost accounting in the manager compares
like with like).

Derived plaintext constants ("const expressions") are how passes fold or
pre-rotate named constants without access to their values: an op's
``meta["cexpr"]`` is a nested tuple over base names —

    ("ref", name)          the named constant itself
    ("mul", a, b)          elementwise product of two expressions
    ("add", a, b)          elementwise sum
    ("rot", a, step)       slots rotated by `step` (same convention as
                           TraceVar.rotate: out[i] = in[i + step])

The interpreter (repro.compiler.interp) resolves these against the base
const bindings at execution time; the cost model sees them as ordinary
plaintext constants (same footprint as any other diag/mask).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.trace import FheOp, FheTrace

CExpr = Tuple  # ("ref", name) | ("mul", a, b) | ("add", a, b) | ("rot", a, k)


def const_expr(op: FheOp) -> CExpr:
    """The const expression an op multiplies/adds with (pmul/padd only)."""
    return op.meta.get("cexpr", ("ref", op.meta["const"]))


def cexpr_name(e: CExpr) -> str:
    """Compact human/fingerprint-stable name for a const expression."""
    tag = e[0]
    if tag == "ref":
        return e[1]
    if tag == "rot":
        return f"{cexpr_name(e[1])}@r{e[2]}"
    sym = "*" if tag == "mul" else "+"
    return f"({cexpr_name(e[1])}{sym}{cexpr_name(e[2])})"


def clone_ops(trace: FheTrace) -> List[FheOp]:
    return [FheOp(o.idx, o.kind, tuple(o.args), dict(o.meta), o.level)
            for o in trace.ops]


def use_counts(trace: FheTrace) -> Dict[int, int]:
    """References per value: arg uses plus one per appearance in outputs."""
    uses = {o.idx: 0 for o in trace.ops}
    for o in trace.ops:
        for a in o.args:
            uses[a] += 1
    for out in trace.outputs:
        uses[out] += 1
    return uses


def consumers(trace: FheTrace) -> Dict[int, List[int]]:
    cons: Dict[int, List[int]] = {o.idx: [] for o in trace.ops}
    for o in trace.ops:
        for a in o.args:
            cons[a].append(o.idx)
    return cons


def _resolve(subst: Dict[int, int], i: int) -> int:
    """Follow substitution chains (a->b, b->c  =>  a->c)."""
    seen = []
    while i in subst:
        seen.append(i)
        i = subst[i]
    for s in seen:           # path compression
        subst[s] = i
    return i


def finish(ops: Sequence[FheOp], inputs: Iterable[int],
           outputs: Iterable[int],
           subst: Optional[Dict[int, int]] = None) -> FheTrace:
    """Canonicalize a rewritten op list into a fresh FheTrace.

    `ops` is any program-ordered list whose args refer to `idx` values of
    earlier entries (ids need not be dense — rewrites mint fresh ids past
    the old maximum). Applies `subst`, prunes ops unreachable from the
    (substituted) outputs — inputs are always kept, the executor feeds
    them positionally — and renumbers densely.
    """
    subst = dict(subst or {})
    by_id = {o.idx: o for o in ops}
    out_ids = [_resolve(subst, i) for i in outputs]
    in_ids = [_resolve(subst, i) for i in inputs]
    live = set(in_ids)
    stack = list(out_ids)
    while stack:
        i = stack.pop()
        if i in live:
            continue
        live.add(i)
        stack.extend(_resolve(subst, a) for a in by_id[i].args)
    new_ops: List[FheOp] = []
    remap: Dict[int, int] = {}
    for o in ops:
        if o.idx not in live or o.idx in remap:
            continue
        args = tuple(remap[_resolve(subst, a)] for a in o.args)
        remap[o.idx] = len(new_ops)
        new_ops.append(FheOp(len(new_ops), o.kind, args, dict(o.meta),
                             o.level))
    return FheTrace(ops=new_ops,
                    inputs=[remap[i] for i in in_ids],
                    outputs=[remap[i] for i in out_ids],
                    consts=[o.idx for o in new_ops if o.kind == "const"])


class Emitter:
    """Mints fresh ops with ids past a trace's maximum, for passes that
    insert code (BSGS, lazy rescale, bootstrap insertion)."""

    def __init__(self, start_id: int):
        self._next = start_id

    def op(self, kind: str, args: Tuple[int, ...] = (), **meta) -> FheOp:
        o = FheOp(self._next, kind, args, meta)
        self._next += 1
        return o


def flatten_add_tree(trace: FheTrace, uses: Dict[int, int],
                     root: int) -> List[int]:
    """Leaves of the maximal hadd tree rooted at `root`: interior hadd
    nodes are expanded only while they have a single consumer (a shared
    partial sum is an opaque leaf — it must keep existing)."""
    ops = trace.ops
    terms: List[int] = []
    stack = [root]
    while stack:
        i = stack.pop()
        if ops[i].kind == "hadd" and (i == root or uses[i] == 1):
            stack.extend(ops[i].args)
        else:
            terms.append(i)
    return terms


def add_tree_roots(trace: FheTrace, uses: Dict[int, int]) -> List[int]:
    """hadd nodes that head a maximal tree: not themselves absorbed into
    a single-consumer parent hadd."""
    cons = consumers(trace)
    roots = []
    for o in trace.ops:
        if o.kind != "hadd":
            continue
        cs = cons[o.idx]
        absorbed = (uses[o.idx] == 1 and len(cs) == 1
                    and trace.ops[cs[0]].kind == "hadd")
        if not absorbed:
            roots.append(o.idx)
    return roots
