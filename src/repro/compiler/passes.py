"""Optimizing passes over the SSA `FheTrace` IR (paper §IV-F's
"optimized end-to-end processing flow", realized as a compiler).

Classic cleanups (DCE / CSE / plaintext constant folding) plus the
FHE-specific transforms that decide end-to-end cost on a memory-bound
accelerator:

* RotationOpt — rotation reuse: compose nested rotations, drop identity
  rotations, and factor large "sum of pmul(rotate(x, s), diag_s)"
  add-trees baby-step/giant-step so n rotations (each a full ModUp/evk/
  ModDown key switch) become ~2*sqrt(n). The homomorphic identity is the
  same one `core/linalg.matvec_bsgs` uses at the ciphertext layer:
  pmul(rot(x, b+q), c) == rot(pmul(rot(x, b), rot(c, -q)), q), with the
  diagonal pre-rotation folded into a derived const expression.
* LazyRescale — EVA-style waterline: products feeding a sum keep their
  double-width scale (``meta["lazy"]``) and the whole sum is rescaled
  once, replacing n rescales (each 2(l+1) NTT passes) with one.
* BootstrapInsertion — a trace that exhausts its level budget is
  rewritten, not rejected: catch `LevelBudgetExhausted`, place a
  `bootstrap` op on the deepest operand of the failing op, repeat. The
  as-late-as-possible cut point maximizes levels consumed per refresh,
  which minimizes the number of bootstraps for any straight-line chain.

Every pass is functional (fresh trace out) and funnels through
`ir.finish`, so outputs are canonical and never carry dead code. The
manager (repro.compiler.manager) re-costs the trace after each pass and
reverts any non-exempt pass that fails the never-more-expensive check.
"""
from __future__ import annotations

import math
from collections import Counter
from typing import Dict, List, Tuple

from repro.core.params import CkksParams
from repro.core.trace import (FheOp, FheTrace, LevelBudgetExhausted,
                              infer_levels)
from repro.compiler.ir import (Emitter, add_tree_roots, cexpr_name,
                               clone_ops, const_expr, finish,
                               flatten_add_tree, use_counts)

_COMMUTATIVE = ("hadd", "hmul")


class Pass:
    name = "?"
    # set on passes allowed to grow OpCost totals (only bootstrap
    # insertion: it buys *feasibility*, not speed)
    may_increase_cost = False

    def run(self, trace: FheTrace, params: CkksParams,
            config) -> FheTrace:
        raise NotImplementedError


class DeadCodeElimination(Pass):
    """Drop ops unreachable from the outputs (inputs always survive)."""
    name = "dce"

    def run(self, trace, params, config):
        return finish(clone_ops(trace), trace.inputs, trace.outputs)


class CommonSubexpr(Pass):
    """Value-number ops on (kind, canonical args, meta); later duplicates
    collapse onto the first occurrence. hadd/hmul are commutative, so
    their args are order-normalized — and because every op here is pure,
    merging is always sound. The headline win is rotation reuse: two
    `rotate(x, k)` of the same source share one key switch."""
    name = "cse"

    def run(self, trace, params, config):
        subst: Dict[int, int] = {}
        table: Dict[Tuple, int] = {}
        for op in trace.ops:
            if op.kind in ("input", "const"):
                continue
            args = tuple(subst.get(a, a) for a in op.args)
            if op.kind in _COMMUTATIVE:
                args = tuple(sorted(args))
            key = (op.kind, args,
                   tuple(sorted((k, repr(v)) for k, v in op.meta.items())))
            if key in table:
                subst[op.idx] = table[key]
            else:
                table[key] = op.idx
        return finish(clone_ops(trace), trace.inputs, trace.outputs, subst)


class ConstantFold(Pass):
    """Fold chained plaintext ops into one derived constant:
    pmul(pmul(x, a), b) -> pmul(x, a*b) and padd(padd(x, a), b) ->
    padd(x, a+b) whenever the inner op has no other consumer. Each fold
    deletes a whole plaintext op (for pmul: including its rescale) and
    returns a level to the budget."""
    name = "fold"

    def run(self, trace, params, config):
        uses = use_counts(trace)
        ops = clone_ops(trace)
        for op in ops:
            if op.kind not in ("pmul", "padd") or op.meta.get("lazy"):
                continue
            inner = ops[op.args[0]]
            if (inner.kind == op.kind and not inner.meta.get("lazy")
                    and uses[inner.idx] == 1):
                tag = "mul" if op.kind == "pmul" else "add"
                ce = (tag, const_expr(inner), const_expr(op))
                op.args = (inner.args[0],)
                op.meta = {"const": cexpr_name(ce), "cexpr": ce}
        return finish(ops, trace.inputs, trace.outputs)


class RotationOpt(Pass):
    """Rotation reuse/hoisting: (1) compose nested rotations and drop
    identities; (2) baby-step/giant-step factor rotation-sum trees."""
    name = "rotation"

    def run(self, trace, params, config):
        t = self._compose(trace, params)
        return self._bsgs(t, params, config)

    # -- (1) composition ----------------------------------------------------

    def _compose(self, trace, params):
        slots = params.slots
        ops = clone_ops(trace)
        subst: Dict[int, int] = {}
        for op in ops:
            if op.kind != "rotate":
                continue
            op.meta["step"] %= slots
            inner = ops[subst.get(op.args[0], op.args[0])]
            if inner.kind == "rotate":
                # rotate(rotate(x, a), b) == rotate(x, a+b): even when the
                # inner stays live for other uses this is never worse, and
                # it unlocks identity elimination + CSE merges
                op.args = (inner.args[0],)
                op.meta["step"] = (op.meta["step"] + inner.meta["step"]) \
                    % slots
            if op.meta["step"] == 0:
                subst[op.idx] = op.args[0]
        return finish(ops, trace.inputs, trace.outputs, subst)

    # -- (2) baby-step / giant-step -----------------------------------------

    def _bsgs(self, trace, params, config):
        slots = params.slots
        uses = use_counts(trace)
        ops = trace.ops
        plans = {}
        for root in add_tree_roots(trace, uses):
            plan = self._plan(trace, uses, root, slots, config)
            if plan is not None:
                plans[root] = plan
        if not plans:
            return trace
        em = Emitter(len(ops))
        out: List[FheOp] = clone_ops(trace)
        new_list: List[FheOp] = []
        subst: Dict[int, int] = {}
        for op in out:
            new_list.append(op)
            if op.idx in plans:
                self._emit(new_list, em, subst, op.idx, plans[op.idx],
                           trace)
        return finish(new_list, trace.inputs, trace.outputs, subst)

    def _plan(self, trace, uses, root, slots, config):
        """A tree qualifies when >= bsgs_min_terms single-use
        pmul(rotate(x, s), const) leaves share one source x with distinct
        steps, and the BSGS factoring strictly reduces rotation count."""
        ops = trace.ops
        terms = flatten_add_tree(trace, uses, root)
        cands, others = [], []
        for t in terms:
            o = ops[t]
            if (o.kind == "pmul" and not o.meta.get("lazy")
                    and uses[t] == 1 and "const" in o.meta):
                a = ops[o.args[0]]
                if a.kind == "rotate" and uses[a.idx] == 1:
                    cands.append((a.meta["step"] % slots, a.args[0], t))
                    continue
                cands.append((0, o.args[0], t))
                continue
            others.append(t)
        if not cands:
            return None
        base, _ = Counter(b for _, b, _ in cands).most_common(1)[0]
        chosen, seen = [], set()
        for s, b, t in cands:
            if b == base and s not in seen:
                seen.add(s)
                chosen.append((s, t))
            else:
                others.append(t)
        if len(chosen) < config.bsgs_min_terms:
            return None
        g = max(1, int(round(math.sqrt(len(chosen)))))
        babies = {s % g for s, _ in chosen}
        giants = {s - s % g for s, _ in chosen}
        n_old = sum(1 for s, _ in chosen if s != 0)
        n_new = len(babies - {0}) + len(giants - {0})
        if n_new >= n_old:
            return None
        return base, g, chosen, others

    def _emit(self, out, em, subst, root, plan, trace):
        base, g, chosen, others = plan

        def push(kind, args, **meta):
            o = em.op(kind, tuple(args), **meta)
            out.append(o)
            return o.idx

        baby = {}
        for b in sorted({s % g for s, _ in chosen}):
            baby[b] = base if b == 0 else push("rotate", (base,), step=b)
        total = None
        for q in sorted({s - s % g for s, _ in chosen}):
            inner = None
            for s, t in sorted(chosen):
                if s - s % g != q:
                    continue
                ce = const_expr(trace.ops[t])
                if q:
                    # pmul(rot(x, b+q), c) == rot(pmul(rot(x, b),
                    # rot(c, -q)), q): pre-rotate the diagonal so the
                    # giant rotation re-aligns it
                    ce = ("rot", ce, -q)
                m = push("pmul", (baby[s % g],), const=cexpr_name(ce),
                         cexpr=ce)
                inner = m if inner is None else push("hadd", (inner, m))
            if q:
                inner = push("rotate", (inner,), step=q)
            total = inner if total is None else push("hadd", (total, inner))
        for t in others:
            total = push("hadd", (total, t))
        subst[root] = total


class LazyRescale(Pass):
    """Defer rescales past adds (EVA-style waterline): when an add-tree
    sums >= 2 single-use eager products at one common level, mark the
    products ``lazy`` (they keep their double-width scale), sum first,
    and rescale the sum once. Non-product leaves are re-added after the
    rescale — they live at single-width scale and must never meet the
    lazy partials. Needs levels, so it runs after bootstrap insertion."""
    name = "lazy_rescale"

    def run(self, trace, params, config):
        try:
            self._ensure_levels(trace, params, config)
        except LevelBudgetExhausted:
            return trace     # infeasible without bootstrap insertion
        uses = use_counts(trace)
        ops = trace.ops
        plans = {}
        for root in add_tree_roots(trace, uses):
            terms = flatten_add_tree(trace, uses, root)
            elig = [t for t in terms
                    if ops[t].kind in ("pmul", "hmul")
                    and not ops[t].meta.get("lazy") and uses[t] == 1]
            others = [t for t in terms if t not in elig]
            if not elig:
                continue
            # one uniform level per lazy group keeps the deferred scales
            # structurally identical (same rescale path)
            lv, n = Counter(ops[t].level for t in elig).most_common(1)[0]
            if n < 2:
                continue
            others += [t for t in elig if ops[t].level != lv]
            plans[root] = ([t for t in elig if ops[t].level == lv], others)
        if not plans:
            return trace
        em = Emitter(len(ops))
        out = clone_ops(trace)
        lazied = {t for group, _ in plans.values() for t in group}
        for t in lazied:
            out[t].meta["lazy"] = True
        new_list: List[FheOp] = []
        subst: Dict[int, int] = {}
        for op in out:
            new_list.append(op)
            if op.idx in plans:
                group, others = plans[op.idx]
                acc = group[0]
                for t in group[1:]:
                    o = em.op("hadd", (acc, t))
                    new_list.append(o)
                    acc = o.idx
                r = em.op("rescale", (acc,))
                new_list.append(r)
                acc = r.idx
                for t in others:
                    o = em.op("hadd", (acc, t))
                    new_list.append(o)
                    acc = o.idx
                subst[op.idx] = acc
        return finish(new_list, trace.inputs, trace.outputs, subst)

    @staticmethod
    def _ensure_levels(trace, params, config):
        start = config.resolve_start_level(trace, params)
        infer_levels(trace, start, config.bootstrap_to)


class BootstrapInsertion(Pass):
    """Turn `LevelBudgetExhausted` into placed `bootstrap` ops. On each
    failure, the deepest (minimum-level) operand of the failing op is
    refreshed immediately before it — the latest legal cut point, which
    consumes the whole remaining budget per refresh and therefore needs
    the fewest refreshes. Uses from *before* the cut keep the original
    value (their levels were already proven feasible); every use at or
    after the cut reads the refreshed one."""
    name = "bootstrap"
    may_increase_cost = True

    def run(self, trace, params, config):
        start = config.resolve_start_level(trace, params)
        boot_to = config.bootstrap_to if config.bootstrap_to is not None \
            else start
        t = trace
        last_fixed = None
        for _ in range(len(trace.ops) + 8):
            try:
                infer_levels(t, start, config.bootstrap_to)
                return t
            except LevelBudgetExhausted as e:
                fail = t.ops[e.op_index]
                args_lv = [(t.ops[a].level, a) for a in fail.args]
                _, arg = min(args_lv)
                if t.ops[arg].kind == "bootstrap" or boot_to < 1:
                    raise LevelBudgetExhausted(e.op_index, e.kind, e.level)
                if (e.op_index, arg) == last_fixed:
                    raise LevelBudgetExhausted(e.op_index, e.kind, e.level)
                last_fixed = (e.op_index, arg)
                t = self._insert(t, fail.idx, arg)
        raise LevelBudgetExhausted(-1, "bootstrap", -1)

    @staticmethod
    def _insert(trace, at, arg):
        em = Emitter(len(trace.ops))
        boot = em.op("bootstrap", (arg,))
        new_list: List[FheOp] = []
        for op in clone_ops(trace):
            if op.idx == at:
                new_list.append(boot)
            if op.idx >= at:
                op.args = tuple(boot.idx if a == arg else a
                                for a in op.args)
            new_list.append(op)
        return finish(new_list, trace.inputs, trace.outputs)


PASS_ORDER: Tuple[Pass, ...] = (
    DeadCodeElimination(), ConstantFold(), RotationOpt(), CommonSubexpr(),
    BootstrapInsertion(), LazyRescale(),
)
