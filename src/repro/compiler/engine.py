"""Batched schedule-evaluation engine over the real CKKS stack.

This is the execution core shared by the compiler's verification tests
(`repro.compiler.interp.CkksTraceInterpreter` is now a thin single-
sample wrapper) and the serving runtime's `CiphertextBackend`
(repro/runtime/ciphertext_backend.py): encode + encrypt slot batches,
evaluate every trace op homomorphically with genuine relinearization /
Galois keys, decrypt + decode the outputs.

Batching model
--------------
A `CtBatch` stacks B same-shaped ciphertexts as one ``(B, 2, L, N)``
uint64 array. Every homomorphic op is applied through ONE
``jax.jit(jax.vmap(...))`` dispatch over the whole stack — the batch
axis rides through the same NTT/modmul/keyswitch code (core/ops.py)
that a single ciphertext uses, so a serving batch of 8 ciphertexts
costs one XLA program launch per op, not eight. Key-switch digits are
batched the same way: the per-digit ModUp/BConv/NTT pipeline sees
``(B, |digit|, N)`` limbs in one dispatch. Compiled appliers are
memoized per (kind, batch, level, scale, knobs) so steady-state serving
never retraces.

With ``use_kernel_modmul`` the plaintext-multiply data product is
routed through the Pallas modmul kernel (repro/kernels/ops.py) with the
batch folded into the limb-row axis — literally one kernel dispatch
covering the whole batch (compiled on TPU, interpret mode elsewhere).

Plaintext constants are encoded once per (const expression, level,
scale) and memoized through a pluggable cache hook — the serving
backend plugs the runtime `KeyCache` in here, so stage constants are
encoded on first use and *reused across batches* with real residency
accounting. Galois/relin key generation reports its evk footprint
through ``on_key_load`` for the same reason.

Scale handling follows core/linalg.py exactly (see the module
docstring of repro.compiler.interp for the invariants): same-level
operands of an add have structurally identical scales; across a level
gap the deeper operand is brought down *exactly* with a compensating
unit pmul (`linalg.adjust_to` semantics, batched here).

`bootstrap` ops execute as an exact refresh (decrypt -> re-encode at
the target level -> re-encrypt): the semantic contract of
bootstrapping without the minutes-long EvalMod chain; the full
approximate pipeline lives in core/bootstrap.py and is what the cost
model bills for.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ops as hops
from repro.core.ciphertext import Ciphertext, KeySwitchKey, Plaintext
from repro.core.context import CkksContext
from repro.core.encoder import CkksEncoder
from repro.core.encryptor import CkksEncryptor
from repro.core.params import CkksParams
from repro.core.trace import FheOp, FheTrace, evk_bytes


# ---------------------------------------------------------------------------
# const expressions (derived plaintexts minted by the passes; see ir.py)
# ---------------------------------------------------------------------------

def resolve_cexpr(expr, consts: Dict[str, np.ndarray]) -> np.ndarray:
    """Evaluate a derived-const expression (see ir.py) to a slot vector."""
    tag = expr[0]
    if tag == "ref":
        return np.asarray(consts[expr[1]])
    if tag == "mul":
        return resolve_cexpr(expr[1], consts) * resolve_cexpr(expr[2], consts)
    if tag == "add":
        return resolve_cexpr(expr[1], consts) + resolve_cexpr(expr[2], consts)
    if tag == "rot":
        # rotate(step): out[i] = in[i + step]
        return np.roll(resolve_cexpr(expr[1], consts), -expr[2], axis=-1)
    raise ValueError(f"unknown const expression {expr!r}")


def op_cexpr(op: FheOp):
    """An op's const expression; a bare named const if no cexpr meta.
    (Never index ``meta['const']`` as an eager .get default — ops minted
    by passes may carry only the cexpr.)"""
    expr = op.meta.get("cexpr")
    return expr if expr is not None else ("ref", op.meta["const"])


def const_vec(op: FheOp, consts: Dict[str, np.ndarray],
              slots: int) -> np.ndarray:
    v = resolve_cexpr(op_cexpr(op), consts)
    assert v.shape[-1] == slots, f"const for op {op.idx} has {v.shape} slots"
    return v


def _const_key(op: FheOp) -> str:
    """Stable human-readable identity of an op's const expression."""
    from repro.compiler.ir import cexpr_name
    return cexpr_name(op_cexpr(op))


# ---------------------------------------------------------------------------
# batched ciphertexts
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CtBatch:
    """B stacked ciphertexts sharing one (level, scale)."""
    data: jnp.ndarray            # (B, 2, level+1, N) uint64, NTT domain
    level: int
    scale: float

    @property
    def batch(self) -> int:
        return self.data.shape[0]

    @property
    def n_limbs(self) -> int:
        return self.level + 1


def _default_cache_factory() -> Callable:
    memo: Dict = {}

    def cache(key, nbytes, loader):
        if key not in memo:
            memo[key] = loader()
        return memo[key]
    return cache


class CkksEngine:
    """Executes traces/schedules on encrypted slot batches.

    Keys (secret, relin, per-element Galois) are generated once and
    cached across runs, so verifying a workload under several pass
    configurations — or serving many batches — pays keygen once.
    """

    def __init__(self, params: CkksParams, seed: int = 7,
                 const_cache: Optional[Callable] = None,
                 on_key_load: Optional[Callable[[Tuple, int], None]] = None,
                 use_kernel_modmul: bool = False,
                 use_kernels: bool = False):
        self.params = params
        self.ctx = CkksContext(params)
        self.encoder = CkksEncoder(self.ctx)
        self.encryptor = CkksEncryptor(self.ctx, seed=seed)
        self.sk = self.encryptor.keygen()
        self.rk = self.encryptor.relin_keygen(self.sk)
        self._gks: Dict[int, KeySwitchKey] = {}
        # (kind, batch, levels, scales, knobs) -> jit(vmap(op)) applier
        self._opfns: Dict[Tuple, Callable] = {}
        self.const_cache = const_cache or _default_cache_factory()
        self.on_key_load = on_key_load
        # `use_kernels` routes every keyswitch (_hmul/_galois) through the
        # fused Pallas pipeline (kernels/keyswitch.py) AND the pmul data
        # product through the modmul kernel; `use_kernel_modmul` is the
        # narrower pre-existing switch (pmul only). Both are bit-exact
        # vs the library path, so flipping them never changes decrypts.
        self.use_kernels = use_kernels
        self.use_kernel_modmul = use_kernel_modmul or use_kernels
        self._fks = None
        if on_key_load is not None:
            on_key_load(("relin",), evk_bytes(params))

    # -- tolerance -----------------------------------------------------------

    @property
    def tolerance(self) -> float:
        """Conservative decrypt-error bound for this parameter set: the
        scheme's rounding/noise floor grows ~linearly in N and shrinks
        with the scale; the constant absorbs depth (empirically a few
        bits above observed error on the registered workloads)."""
        return 512.0 * self.params.n / 2.0 ** self.params.log_scale

    # -- keys ----------------------------------------------------------------

    def _gk(self, elt: int) -> KeySwitchKey:
        if elt not in self._gks:
            self._gks.update(self.encryptor.galois_keygen(self.sk, [elt]))
            if self.on_key_load is not None:
                self.on_key_load(("gk", elt), evk_bytes(self.params))
        return self._gks[elt]

    # -- encrypt / decode ----------------------------------------------------

    def encrypt(self, v: np.ndarray, level: int) -> Ciphertext:
        scale = 2.0 ** self.params.log_scale
        pt = Plaintext(self.encoder.encode(v, scale, level), level, scale)
        return self.encryptor.encrypt_sk(pt, self.sk)

    def encrypt_batch(self, vs: np.ndarray, level: int) -> CtBatch:
        """vs: (B, slots) complex -> one (B, 2, L, N) stack."""
        vs = np.atleast_2d(np.asarray(vs))
        cts = [self.encrypt(vs[i], level) for i in range(vs.shape[0])]
        return CtBatch(jnp.stack([c.data for c in cts]), level,
                       cts[0].scale)

    def decode(self, ct: Ciphertext) -> np.ndarray:
        pt = self.encryptor.decrypt(ct, self.sk)
        return self.encoder.decode(pt.data, ct.scale, ct.level)

    def decode_batch(self, cb: CtBatch) -> np.ndarray:
        """One batched decrypt dispatch, then per-element host decode."""
        from repro.core import modarith as ma
        idx = self.ctx.q_idx(cb.level)
        q = self.ctx.q_all[np.array(idx)][:, None]
        s = self.sk.s_ntt[np.array(idx)]
        m = ma.addmod(cb.data[:, 0], ma.mulmod(cb.data[:, 1], s, q), q)
        m = np.asarray(m)                       # (B, L, N)
        return np.stack([self.encoder.decode(jnp.asarray(m[i]), cb.scale,
                                             cb.level)
                         for i in range(m.shape[0])])

    def encode_const(self, vec: np.ndarray, scale: float, level: int,
                     key: Optional[Tuple] = None) -> Plaintext:
        """Encode (and memoize through the cache hook) one plaintext.

        The key always includes a digest of the VALUE: a caller may
        rebind the same const name to new values between runs (the old
        interpreter re-encoded every run), and a name-only key would
        silently serve the stale encoding. Identical values still hit.
        """
        nbytes = (level + 1) * self.params.n * 8
        digest = hash(np.ascontiguousarray(vec).tobytes())
        k = ("pt",) + (key or ()) + (digest, level, float(scale))
        data = self.const_cache(
            k, nbytes, lambda: self.encoder.encode(vec, scale, level))
        return Plaintext(data, level, scale)

    # -- compiled batched op appliers ---------------------------------------

    def _opfn(self, key: Tuple, build: Callable) -> Callable:
        """Memoized applier for one (kind, batch, level, ...) signature.

        `build` returns the *eager* vmapped function. The first call
        runs it un-jitted: CkksContext lazily builds NTT/BConv tables
        on first use, and those must materialize as concrete arrays —
        built inside a jit trace they would be cached as leaked tracers
        (omnistaging stages every op in a trace, concrete operands or
        not). Once warm, the jitted version is cached for every later
        call, so steady-state serving pays one XLA launch per op.
        """
        fn = self._opfns.get(key)
        if fn is not None:
            return fn
        eager = build()

        def first(*args):
            out = eager(*args)
            self._opfns[key] = jax.jit(eager)
            return out
        return first

    def _mod_switch(self, cb: CtBatch, level: int) -> CtBatch:
        assert level <= cb.level
        if level == cb.level:
            return cb
        return CtBatch(cb.data[:, :, : level + 1], level, cb.scale)

    def _adjust_to(self, cb: CtBatch, level: int, scale: float) -> CtBatch:
        """Batched linalg.adjust_to: exact (level, scale) landing via a
        unit pmul at a compensating plaintext scale."""
        assert cb.level > level
        cb = self._mod_switch(cb, level + 1)
        q_drop = self.ctx.primes[level + 1]
        pt_scale = scale * q_drop / cb.scale
        pt = self.encode_const(np.ones(self.params.slots), pt_scale,
                               level + 1, key=("unit",))
        key = ("adjust", cb.batch, cb.level, float(cb.scale), float(scale))

        def build():
            lvl, s = cb.level, cb.scale

            def f(d, ptd):
                out = hops.pmul(self.ctx, Ciphertext(d, lvl, s),
                                Plaintext(ptd, lvl, pt_scale))
                return out.data
            return jax.vmap(f, in_axes=(0, None))
        data = self._opfn(key, build)(cb.data, pt.data)
        return CtBatch(data, level, scale)       # exact by construction

    def _aligned(self, c0: CtBatch, c1: CtBatch) -> Tuple[CtBatch, CtBatch]:
        """Bring an hadd/hsub pair to one (level, scale); exact across a
        level gap, scale-tag coercion at equal level (see interp.py)."""
        lvl = min(c0.level, c1.level)

        def down(hi: CtBatch, partner_scale: float) -> CtBatch:
            if (hi.level > lvl
                    and abs(hi.scale / partner_scale - 1.0) > 1e-6):
                return self._adjust_to(hi, lvl, partner_scale)
            return self._mod_switch(hi, lvl)

        if c0.level > c1.level:
            c0 = down(c0, c1.scale)
        elif c1.level > c0.level:
            c1 = down(c1, c0.scale)
        rel = abs(c1.scale / c0.scale - 1.0)
        if rel > 1e-6:
            raise ValueError(
                f"scale-incompatible add at level {lvl}: "
                f"{c0.scale:.6e} vs {c1.scale:.6e} — the trace mixes "
                f"rescale disciplines on one add")
        if rel > 0:
            c1 = CtBatch(c1.data, c1.level, c0.scale)
        return c0, c1

    def _addsub(self, kind: str, c0: CtBatch, c1: CtBatch) -> CtBatch:
        c0, c1 = self._aligned(c0, c1)
        key = (kind, c0.batch, c0.level)

        def build():
            lvl, s = c0.level, c0.scale
            fn = hops.hadd if kind == "hadd" else hops.hsub

            def f(d0, d1):
                return fn(self.ctx, Ciphertext(d0, lvl, s),
                          Ciphertext(d1, lvl, s)).data
            return jax.vmap(f)
        return CtBatch(self._opfn(key, build)(c0.data, c1.data),
                       c0.level, c0.scale)

    # -- fused Pallas keyswitch route (kernels/keyswitch.py) -----------------

    @property
    def fused_ks(self):
        """Lazily-built FusedKeySwitch shared by every evk (relin and all
        Galois keys ride the same per-(batch, level) compiled pipeline)."""
        if self._fks is None:
            from repro.kernels.keyswitch import FusedKeySwitch
            self._fks = FusedKeySwitch(self.ctx)
        return self._fks

    def _hmul_fused(self, c0: CtBatch, c1: CtBatch, lazy: bool) -> CtBatch:
        """HMul with the relinearization keyswitch on the fused kernels:
        jitted tensor product -> 4-kernel keyswitch of the whole d2 batch
        -> jitted combine (+ rescale). Bit-identical to `_hmul`."""
        lvl = min(c0.level, c1.level)
        c0 = self._mod_switch(c0, lvl)
        c1 = self._mod_switch(c1, lvl)
        key = ("hmul_tensor", c0.batch, lvl)

        def build_tensor():
            q = self.ctx.q_all[: lvl + 1][:, None]

            def f(d0, d1):
                from repro.core import modarith as ma
                b0, a0 = d0[0], d0[1]
                b1, a1 = d1[0], d1[1]
                t0 = ma.mulmod(b0, b1, q)
                t1 = ma.addmod(ma.mulmod(a0, b1, q),
                               ma.mulmod(a1, b0, q), q)
                d2 = ma.mulmod(a0, a1, q)
                return jnp.stack([t0, t1]), d2
            return jax.vmap(f)
        d01, d2 = self._opfn(key, build_tensor)(c0.data, c1.data)
        km = self.fused_ks.ksk_mont("relin", lvl, self.rk.data)
        e0, e1 = self.fused_ks.apply(d2, lvl, km)
        ckey = ("hmul_combine", c0.batch, lvl)

        def build_combine():
            q = self.ctx.q_all[: lvl + 1][:, None]

            def f(d, e0_, e1_):
                from repro.core import modarith as ma
                return jnp.stack([ma.addmod(d[0], e0_, q),
                                  ma.addmod(d[1], e1_, q)])
            return jax.vmap(f)
        data = self._opfn(ckey, build_combine)(d01, e0, e1)
        out = CtBatch(data, lvl, c0.scale * c1.scale)
        return out if lazy else self._rescale(out)

    def _hmul(self, c0: CtBatch, c1: CtBatch, lazy: bool) -> CtBatch:
        if self.use_kernels:
            return self._hmul_fused(c0, c1, lazy)
        lvl = min(c0.level, c1.level)
        key = ("hmul", c0.batch, c0.level, c1.level, lazy)

        def build():
            l0, l1 = c0.level, c1.level
            s0, s1 = c0.scale, c1.scale

            def f(d0, d1, rkd):
                out = hops.hmul(self.ctx, Ciphertext(d0, l0, s0),
                                Ciphertext(d1, l1, s1),
                                KeySwitchKey(rkd), do_rescale=not lazy)
                return out.data
            return jax.vmap(f, in_axes=(0, 0, None))
        data = self._opfn(key, build)(c0.data, c1.data, self.rk.data)
        if lazy:
            return CtBatch(data, lvl, c0.scale * c1.scale)
        return CtBatch(data, lvl - 1,
                       c0.scale * c1.scale / self.ctx.q_primes[lvl])

    def _rescale(self, cb: CtBatch) -> CtBatch:
        key = ("rescale", cb.batch, cb.level)

        def build():
            lvl, s = cb.level, cb.scale

            def f(d):
                return hops.rescale(self.ctx,
                                    Ciphertext(d, lvl, s)).data
            return jax.vmap(f)
        return CtBatch(self._opfn(key, build)(cb.data), cb.level - 1,
                       cb.scale / self.ctx.q_primes[cb.level])

    def _pmul_kernel(self, cb: CtBatch, pt: Plaintext) -> CtBatch:
        """Plaintext-multiply data product through the Pallas modmul
        kernel: the (B, 2, L) rows fold into the kernel's limb-row axis,
        so ONE dispatch covers the whole batch."""
        from repro.kernels import ops as kops
        b, _, lp, n = cb.data.shape
        primes = [self.ctx.primes[i] for i in range(lp)] * (2 * b)
        a = cb.data.reshape(2 * b * lp, n)
        w = jnp.tile(pt.data[: lp], (2 * b, 1))
        data = kops.modmul(a, w, primes).reshape(b, 2, lp, n)
        return CtBatch(data, cb.level, cb.scale * pt.scale)

    def _pmul(self, cb: CtBatch, pt: Plaintext, lazy: bool) -> CtBatch:
        if self.use_kernel_modmul:
            out = self._pmul_kernel(cb, pt)
            return out if lazy else self._rescale(out)
        key = ("pmul", cb.batch, cb.level, lazy)

        def build():
            lvl, s, ps = cb.level, cb.scale, pt.scale

            def f(d, ptd):
                out = hops.pmul(self.ctx, Ciphertext(d, lvl, s),
                                Plaintext(ptd, lvl, ps),
                                do_rescale=not lazy)
                return out.data
            return jax.vmap(f, in_axes=(0, None))
        data = self._opfn(key, build)(cb.data, pt.data)
        if lazy:
            return CtBatch(data, cb.level, cb.scale * pt.scale)
        return CtBatch(data, cb.level - 1,
                       cb.scale * pt.scale / self.ctx.q_primes[cb.level])

    def _padd(self, cb: CtBatch, pt: Plaintext) -> CtBatch:
        key = ("padd", cb.batch, cb.level)

        def build():
            lvl, s = cb.level, cb.scale

            def f(d, ptd):
                return hops.padd(self.ctx, Ciphertext(d, lvl, s),
                                 Plaintext(ptd, lvl, s)).data
            return jax.vmap(f, in_axes=(0, None))
        return CtBatch(self._opfn(key, build)(cb.data, pt.data),
                       cb.level, cb.scale)

    def _galois_fused(self, cb: CtBatch, elt: int) -> CtBatch:
        """Galois automorphism with the keyswitch on the fused kernels:
        jitted NTT-domain permutation -> 4-kernel keyswitch of the
        rotated `a` batch -> jitted combine. Bit-identical to `_galois`."""
        gk = self._gk(elt)
        lvl = cb.level
        perm = self.ctx.eval_perm(elt)
        key = ("galois_rot", cb.batch, lvl, elt)

        def build_rot():
            def f(d):
                return d[:, :, perm]
            return jax.vmap(f)
        rot = self._opfn(key, build_rot)(cb.data)       # (B, 2, L, N)
        km = self.fused_ks.ksk_mont(("gk", elt), lvl, gk.data)
        e0, e1 = self.fused_ks.apply(rot[:, 1], lvl, km)
        ckey = ("galois_combine", cb.batch, lvl)

        def build_combine():
            q = self.ctx.q_all[: lvl + 1][:, None]

            def f(b_rot, e0_, e1_):
                from repro.core import modarith as ma
                return jnp.stack([ma.addmod(b_rot, e0_, q), e1_])
            return jax.vmap(f)
        data = self._opfn(ckey, build_combine)(rot[:, 0], e0, e1)
        return CtBatch(data, lvl, cb.scale)

    def _galois(self, cb: CtBatch, elt: int) -> CtBatch:
        if self.use_kernels:
            return self._galois_fused(cb, elt)
        gk = self._gk(elt)
        key = ("galois", cb.batch, cb.level, elt)

        def build():
            lvl, s = cb.level, cb.scale

            def f(d, gkd):
                return hops._apply_galois(self.ctx, Ciphertext(d, lvl, s),
                                          elt, KeySwitchKey(gkd)).data
            return jax.vmap(f, in_axes=(0, None))
        return CtBatch(self._opfn(key, build)(cb.data, gk.data),
                       cb.level, cb.scale)

    # -- op-by-op evaluation -------------------------------------------------

    def run_ops(self, ops: Sequence[FheOp], env: Dict[int, CtBatch],
                consts: Dict[str, np.ndarray], *, start_level: int,
                const_scope: Tuple = ()) -> List[CtBatch]:
        """Evaluate `ops` (any program-ordered slice of a trace) against
        `env`, mutating it in place. Returns the values produced (for
        completion barriers). Plaintext constants are cached under
        ``const_scope + (cexpr, level, scale)``."""
        slots = self.params.slots
        scale = 2.0 ** self.params.log_scale
        produced: List[CtBatch] = []
        for op in ops:
            if op.kind in ("input", "const"):
                continue
            a = [env[x] for x in op.args]
            lazy = bool(op.meta.get("lazy"))
            if op.kind in ("hadd", "hsub"):
                out = self._addsub(op.kind, a[0], a[1])
            elif op.kind == "hmul":
                out = self._hmul(a[0], a[1], lazy)
            elif op.kind == "pmul":
                v = const_vec(op, consts, slots)
                pt = self.encode_const(v, scale, a[0].level,
                                       key=const_scope + (_const_key(op),))
                out = self._pmul(a[0], pt, lazy)
            elif op.kind == "padd":
                v = const_vec(op, consts, slots)
                pt = self.encode_const(v, a[0].scale, a[0].level,
                                       key=const_scope + (_const_key(op),))
                out = self._padd(a[0], pt)
            elif op.kind == "rotate":
                step = op.meta["step"] % slots
                if step == 0:
                    out = a[0]
                else:
                    out = self._galois(a[0],
                                       self.ctx.rotation_element(step))
            elif op.kind == "conjugate":
                out = self._galois(a[0], self.ctx.conj_element)
            elif op.kind == "rescale":
                out = self._rescale(a[0])
            elif op.kind == "bootstrap":
                target = op.level if op.level is not None else start_level
                out = self.encrypt_batch(self.decode_batch(a[0]), target)
            else:
                raise ValueError(op.kind)
            env[op.idx] = out
            produced.append(out)
        return produced

    # -- whole-trace / whole-schedule execution ------------------------------

    @staticmethod
    def _resolve_start(trace: FheTrace, start_level: Optional[int],
                       n_levels: int) -> int:
        if start_level is not None:
            return start_level
        in_op = trace.ops[trace.inputs[0]] if trace.inputs else None
        return (in_op.level if in_op is not None
                and in_op.level is not None else n_levels)

    def run_batch(self, trace: FheTrace, inputs: Sequence[np.ndarray],
                  consts: Optional[Dict[str, np.ndarray]] = None,
                  start_level: Optional[int] = None,
                  const_scope: Tuple = ()) -> List[np.ndarray]:
        """Encrypt (B, slots) inputs, execute, return (B, slots) decodes."""
        consts = consts or {}
        start = self._resolve_start(trace, start_level,
                                    self.params.n_levels)
        env: Dict[int, CtBatch] = {}
        for i, idx in enumerate(trace.inputs):
            env[idx] = self.encrypt_batch(np.asarray(inputs[i]), start)
        self.run_ops(trace.ops, env, consts, start_level=start,
                     const_scope=const_scope)
        return [self.decode_batch(env[o]) for o in trace.outputs]

    def run(self, trace: FheTrace, inputs: Sequence[np.ndarray],
            consts: Optional[Dict[str, np.ndarray]] = None,
            start_level: Optional[int] = None) -> List[np.ndarray]:
        """Single-sample compatibility API (the old interpreter's
        contract): 1-D slot vectors in, 1-D decodes out."""
        outs = self.run_batch(trace, [np.asarray(v)[None, :]
                                      for v in inputs],
                              consts, start_level)
        return [o[0] for o in outs]

    def run_schedule(self, schedule, inputs: Sequence[np.ndarray],
                     consts: Optional[Dict[str, np.ndarray]] = None,
                     start_level: Optional[int] = None,
                     const_scope: Tuple = ()
                     ) -> Tuple[List[np.ndarray], List[float]]:
        """Execute a compiled `PipelineSchedule` stage by stage on (B,
        slots) encrypted inputs, timing each stage (completion barrier
        per stage). Returns (decoded outputs, per-stage wall seconds) —
        the measured side of the fig18 calibration table."""
        trace = schedule.trace
        assert trace is not None, \
            "schedule carries no trace (mapper predates engine support)"
        consts = consts or {}
        start = self._resolve_start(trace, start_level,
                                    self.params.n_levels)
        env: Dict[int, CtBatch] = {}
        for i, idx in enumerate(trace.inputs):
            env[idx] = self.encrypt_batch(np.asarray(inputs[i]), start)
        jax.block_until_ready([c.data for c in env.values()])
        stage_seconds: List[float] = []
        for stage in schedule.stages:
            t0 = time.perf_counter()
            produced = self.run_ops(stage.ops, env, consts,
                                    start_level=start,
                                    const_scope=const_scope)
            jax.block_until_ready([c.data for c in produced])
            stage_seconds.append(time.perf_counter() - t0)
        return ([self.decode_batch(env[o]) for o in trace.outputs],
                stage_seconds)
