"""Pass manager: runs the pass pipeline with per-pass cost accounting.

`optimize_trace` is the single entry point the runtime uses between
trace capture and the pipeline mapper (`generate_load_save_pipeline`):

    opt, report = optimize_trace(trace, params, PassConfig())
    schedule = generate_load_save_pipeline(opt, params, mem)

Cost accounting sums the same per-op `OpCost` model the mapper bills
stages with, converted to analytic seconds on a reference MemoryModel so
NTT passes, modmuls and byte movement land in one comparable unit. Two
guarantees are enforced per pass:

* never-more-expensive — a pass whose output costs more than its input
  is *reverted* (recorded in the report), and an assertion backstops the
  invariant: no applied optimization pass may increase the OpCost-derived
  analytic seconds. `BootstrapInsertion` is exempt: it adds real work to
  buy feasibility for traces that would otherwise die in `infer_levels`.
* semantic preservation is checked externally by interpreting both
  traces through the real CKKS stack (repro.compiler.interp, exercised
  by tests/test_compiler.py for every pass on every workload).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

from repro.core.params import CkksParams
from repro.core.pipeline import MemoryModel
from repro.core.trace import (FheTrace, LevelBudgetExhausted, OpCost,
                              infer_levels, op_cost)
from repro.compiler.ir import clone_ops
from repro.compiler.passes import PASS_ORDER, Pass


@dataclasses.dataclass(frozen=True)
class PassConfig:
    """Which passes run, plus their knobs. Frozen + flat so `key()` can
    participate in the compile cache key (opt and no-opt schedules must
    never collide)."""
    dce: bool = True
    fold: bool = True
    rotation: bool = True
    cse: bool = True
    bootstrap: bool = True
    lazy_rescale: bool = True
    bsgs_min_terms: int = 6
    start_level: Optional[int] = None    # default: read off the trace
    bootstrap_to: Optional[int] = None   # default: start level

    def key(self) -> Tuple:
        return dataclasses.astuple(self)

    def enabled(self) -> List[Pass]:
        return [p for p in PASS_ORDER if getattr(self, p.name)]

    def with_passes(self, names) -> "PassConfig":
        """Copy with exactly `names` enabled (knobs preserved)."""
        flags = {p.name: (p.name in names) for p in PASS_ORDER}
        return dataclasses.replace(self, **flags)

    def resolve_start_level(self, trace: FheTrace,
                            params: CkksParams) -> int:
        if self.start_level is not None:
            return self.start_level
        for i in trace.inputs:
            if trace.ops[i].level is not None:
                return trace.ops[i].level
        return params.n_levels


# reference memory model for pass-to-pass comparisons: any fixed model
# works (comparisons are relative); the default matches fig15's analytic
# baseline so report numbers line up with the benchmarks
_REF_MEM = MemoryModel()


def trace_cost(trace: FheTrace, params: CkksParams) -> OpCost:
    """Summed OpCost over compute ops (levels must be inferred)."""
    total = OpCost()
    for op in trace.compute_ops():
        total = total + op_cost(params, op)
    return total


def analytic_seconds(trace: FheTrace, params: CkksParams,
                     mem: MemoryModel = _REF_MEM) -> float:
    """Single-partition analytic latency: compute + constant streaming +
    ciphertext movement, summed per op. The mapper's pipelining divides
    this across partitions but never changes its ordering between two
    traces, so it is the right pass-comparison scalar."""
    c = trace_cost(trace, params)
    return (mem.compute_seconds(c, params.n)
            + c.const_bytes / mem.load_bw
            + c.io_bytes / mem.transfer_bw)


@dataclasses.dataclass
class PassStats:
    name: str
    n_ops_before: int
    n_ops_after: int
    seconds_before: Optional[float]   # None while levels are infeasible
    seconds_after: Optional[float]
    applied: bool
    reverted: bool = False
    wall_s: float = 0.0               # compile-time cost of the pass
                                      # itself (run + cost re-check)
    verify_wall_s: float = 0.0        # repro.analysis per-pass sweep
    verify_findings: int = 0          # findings (any severity) it raised

    @property
    def delta_ops(self) -> int:
        return self.n_ops_after - self.n_ops_before

    @property
    def speedup(self) -> Optional[float]:
        if self.seconds_before and self.seconds_after:
            return self.seconds_before / self.seconds_after
        return None


@dataclasses.dataclass
class CompileReport:
    passes: List[PassStats]
    seconds_unopt: Optional[float]
    seconds_opt: float
    n_ops_unopt: int
    n_ops_opt: int
    # static-verification accounting (repro.analysis): per-pass sweeps
    # plus the final full-budget trace verification
    verify_wall_s: float = 0.0
    verify_findings: int = 0

    @property
    def speedup(self) -> Optional[float]:
        if self.seconds_unopt is None:
            return None
        return self.seconds_unopt / self.seconds_opt

    @property
    def wall_s(self) -> float:
        """Total compile wall time across the pass pipeline."""
        return sum(s.wall_s for s in self.passes)

    def format_table(self, include_wall: bool = False) -> str:
        hdr = f"{'pass':<14}{'ops':>10}{'analytic_s':>14}{'Δ':>9}"
        rows = [hdr + (f"{'wall_ms':>10}" if include_wall else "")]
        for s in self.passes:
            sec = "-" if s.seconds_after is None else f"{s.seconds_after:.3e}"
            dlt = ("reverted" if s.reverted
                   else "-" if s.speedup is None
                   else f"{s.speedup:.2f}x")
            row = (f"{s.name:<14}{s.n_ops_before:>5}->{s.n_ops_after:<4}"
                   f"{sec:>13}{dlt:>9}")
            if include_wall:
                row += f"{s.wall_s*1e3:>10.2f}"
            rows.append(row)
        total = "-" if self.speedup is None else f"{self.speedup:.2f}x"
        last = (f"{'total':<14}{self.n_ops_unopt:>5}->"
                f"{self.n_ops_opt:<4}{self.seconds_opt:>13.3e}{total:>9}")
        if include_wall:
            last += f"{self.wall_s*1e3:>10.2f}"
        rows.append(last)
        return "\n".join(rows)


# the name the runtime uses when the report rides a compiled schedule
# (PipelineSchedule.pass_report) and compile spans
PassReport = CompileReport


def _try_seconds(trace, params, start, boot_to):
    try:
        infer_levels(trace, start, boot_to)
        return analytic_seconds(trace, params)
    except LevelBudgetExhausted:
        return None


def optimize_trace(trace: FheTrace, params: CkksParams,
                   config: Optional[PassConfig] = None, *,
                   verify: bool = False,
                   passes: Optional[List[Pass]] = None
                   ) -> Tuple[FheTrace, CompileReport]:
    """Run the enabled passes in canonical order over a private copy.

    Returns (optimized trace with levels inferred, per-pass report).
    Raises LevelBudgetExhausted only if the trace is too deep AND
    bootstrap insertion is disabled (or cannot fix it).

    ``verify=True`` runs the static verifier (repro.analysis) after
    every applied pass — an error finding raises
    `PassVerificationError` naming the offending pass — plus one full
    level-budget verification of the final trace. Per-pass sweeps skip
    the budget rules: a mid-pipeline trace may be legally deeper than
    the chain until bootstrap insertion runs.

    ``passes`` overrides the config's enabled pass list (same Pass
    protocol: .name, .may_increase_cost, .run) — the hook the mutation
    harness uses to inject a corrupting pass without touching
    PASS_ORDER.
    """
    config = config or PassConfig()
    if verify:
        # deferred import: repro.analysis imports core only, but keep
        # the compiler importable without it on the hot path anyway
        from repro.analysis.findings import (PassVerificationError,
                                             VerificationError)
        from repro.analysis.verify_ir import verify_trace
        from repro.analysis.verify_schedule import verify_pass
    start = config.resolve_start_level(trace, params)
    work = FheTrace(clone_ops(trace), list(trace.inputs),
                    list(trace.outputs), list(trace.consts))
    sec_unopt = _try_seconds(work, params, start, config.bootstrap_to)
    n_unopt = len(work.ops)
    sec = sec_unopt
    stats: List[PassStats] = []
    v_wall, v_found = 0.0, 0
    for p in (config.enabled() if passes is None else passes):
        before_ops = len(work.ops)
        t0 = time.perf_counter()
        new = p.run(work, params, config)
        sec_new = _try_seconds(new, params, start, config.bootstrap_to)
        wall = time.perf_counter() - t0
        applied, reverted = True, False
        if not p.may_increase_cost and sec is not None and (
                sec_new is None or sec_new > sec * (1 + 1e-12)):
            new, sec_new = work, sec          # never-more-expensive guard
            applied, reverted = False, True
        if applied and not p.may_increase_cost \
                and sec is not None and sec_new is not None:
            assert sec_new <= sec * (1 + 1e-9), \
                f"pass {p.name} increased analytic cost {sec} -> {sec_new}"
        st = PassStats(p.name, before_ops, len(new.ops),
                       sec, sec_new, applied, reverted, wall_s=wall)
        if verify and applied:
            rep = verify_pass(work, new, check_budget=False,
                              start_level=start,
                              bootstrap_to=config.bootstrap_to,
                              subject=p.name)
            st.verify_wall_s = rep.wall_s
            st.verify_findings = len(rep.findings)
            v_wall += rep.wall_s
            v_found += len(rep.findings)
            if not rep.ok:
                raise PassVerificationError(p.name, rep)
        stats.append(st)
        work, sec = new, sec_new
    if sec is None:
        # still infeasible: surface the structured error to the caller
        infer_levels(work, start, config.bootstrap_to)
    if verify:
        # final sweep WITH the budget rules: every pass has had its say
        rep = verify_trace(work, start_level=start,
                           bootstrap_to=config.bootstrap_to,
                           check_budget=True, subject="post-pipeline")
        v_wall += rep.wall_s
        v_found += len(rep.findings)
        if not rep.ok:
            raise VerificationError(rep, context="optimized trace")
    return work, CompileReport(stats, sec_unopt, sec, n_unopt,
                               len(work.ops), verify_wall_s=v_wall,
                               verify_findings=v_found)


class PassManager:
    """Object wrapper over `optimize_trace` for callers that configure
    once and compile many traces (the lint CLI, tests, notebooks):

        pm = PassManager(PassConfig(), verify=True)
        opt, report = pm.run(trace, params)

    `verify=True` re-verifies the trace after each applied pass and
    attributes the first invariant violation to the offending pass by
    raising `PassVerificationError(pass_name=...)`.
    """

    def __init__(self, config: Optional[PassConfig] = None, *,
                 verify: bool = False,
                 passes: Optional[List[Pass]] = None):
        self.config = config or PassConfig()
        self.verify = verify
        self.passes = passes

    def run(self, trace: FheTrace,
            params: CkksParams) -> Tuple[FheTrace, CompileReport]:
        return optimize_trace(trace, params, self.config,
                              verify=self.verify, passes=self.passes)
