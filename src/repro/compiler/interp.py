"""Trace interpreters: the compiler's semantic ground truth.

Two interpreters over the same `FheTrace` IR:

* `reference_eval` — numpy on plaintext slot vectors (batched: inputs
  may be ``(slots,)`` or ``(B, slots)``). Fast oracle for pass unit
  tests, for sanity-checking the CKKS runs below, and for the serving
  runtime's decrypt-accuracy metric.
* `CkksTraceInterpreter` — executes a trace op-by-op through the REAL
  CKKS stack. Since PR 3 this is a thin single-sample wrapper over the
  batched schedule-evaluation engine (`repro.compiler.engine
  .CkksEngine`), which is shared with the serving runtime's
  `CiphertextBackend`: encode + encrypt the inputs, run every
  homomorphic op with genuine relinearization/Galois keys, decrypt +
  decode the outputs. Pass verification asserts that an optimized
  trace and its original decode to the same values through this
  interpreter (tests/test_compiler.py), which is what "semantics
  preserved" means for a scheme whose ciphertexts are noisy by design.

Scale-handling and bootstrap-refresh semantics live in the engine now;
see repro/compiler/engine.py's module docstring for the invariants
(structurally identical scales at equal level, exact `linalg.adjust_to`
across level gaps, bootstrap as exact decrypt/re-encrypt refresh).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.compiler.engine import (CkksEngine, const_vec,  # noqa: F401
                                   resolve_cexpr)
from repro.core.params import CkksParams
from repro.core.trace import FheTrace

_const_vec = const_vec          # back-compat alias (old private name)


def reference_eval(trace: FheTrace, inputs: Sequence[np.ndarray],
                   consts: Optional[Dict[str, np.ndarray]] = None
                   ) -> List[np.ndarray]:
    """Plaintext oracle: exact slotwise arithmetic, no noise, no scales.

    Inputs may carry leading batch dimensions; slot ops act on the last
    axis.
    """
    consts = consts or {}
    slots = np.asarray(inputs[0]).shape[-1]
    env: Dict[int, np.ndarray] = {}
    for i, idx in enumerate(trace.inputs):
        env[idx] = np.asarray(inputs[i])
    for op in trace.ops:
        if op.kind in ("input", "const"):
            continue
        a = [env[x] for x in op.args]
        if op.kind == "hadd":
            env[op.idx] = a[0] + a[1]
        elif op.kind == "hsub":
            env[op.idx] = a[0] - a[1]
        elif op.kind == "hmul":
            env[op.idx] = a[0] * a[1]
        elif op.kind == "pmul":
            env[op.idx] = a[0] * const_vec(op, consts, slots)
        elif op.kind == "padd":
            env[op.idx] = a[0] + const_vec(op, consts, slots)
        elif op.kind == "rotate":
            env[op.idx] = np.roll(a[0], -op.meta["step"], axis=-1)
        elif op.kind == "conjugate":
            env[op.idx] = np.conj(a[0])
        elif op.kind in ("rescale", "bootstrap"):
            env[op.idx] = a[0]
        else:
            raise ValueError(op.kind)
    return [env[o] for o in trace.outputs]


class CkksTraceInterpreter(CkksEngine):
    """Single-sample compatibility facade over `CkksEngine`.

    Everything — key generation/caching, batched op appliers, const
    memoization — is inherited; `run` keeps the original 1-D
    vectors-in / 1-D decodes-out contract (CkksEngine.run).
    """

    def __init__(self, params: CkksParams, seed: int = 7):
        super().__init__(params, seed=seed)
