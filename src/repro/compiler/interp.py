"""Trace interpreters: the compiler's semantic ground truth.

Two interpreters over the same `FheTrace` IR:

* `reference_eval` — numpy on plaintext slot vectors. Fast oracle for
  pass unit tests and for sanity-checking the CKKS runs below.
* `CkksTraceInterpreter` — executes a trace op-by-op through the REAL
  CKKS stack (core.encoder/encryptor/ops): encode + encrypt the inputs,
  run every homomorphic op with genuine relinearization/Galois keys,
  decrypt + decode the outputs. Pass verification asserts that an
  optimized trace and its original decode to the same values through
  this interpreter (tests/test_compiler.py), which is what "semantics
  preserved" means for a scheme whose ciphertexts are noisy by design.

Scale handling mirrors the repo's existing idiom (core/linalg.py): two
operands of an hadd/hsub at the same level have structurally identical
scales (equal level means the same rescale prime path in this IR, for
eager and post-lazy-rescale values alike), so only a float-roundoff
scale-tag coercion is needed; across a level gap the deeper-budget
operand is brought to the shallower one *exactly* with
`linalg.adjust_to` (a unit pmul at a compensating plaintext scale,
spending one of the levels being dropped anyway). A same-level add with
materially different scales is an invalid trace and raises. Derived
const expressions minted by the passes (`meta["cexpr"]`) are resolved
against the base bindings here.

`bootstrap` ops execute as an exact refresh (decrypt -> re-encode at the
target level -> re-encrypt): the semantic contract of bootstrapping
(value-preserving level restoration) without the minutes-long EvalMod
chain; the full approximate pipeline lives in core/bootstrap.py and is
what the cost model bills for.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import ops as hops
from repro.core.ciphertext import Ciphertext, Plaintext
from repro.core.context import CkksContext
from repro.core.encoder import CkksEncoder
from repro.core.encryptor import CkksEncryptor
from repro.core.params import CkksParams
from repro.core.trace import FheTrace


def resolve_cexpr(expr, consts: Dict[str, np.ndarray]) -> np.ndarray:
    """Evaluate a derived-const expression (see ir.py) to a slot vector."""
    tag = expr[0]
    if tag == "ref":
        return np.asarray(consts[expr[1]])
    if tag == "mul":
        return resolve_cexpr(expr[1], consts) * resolve_cexpr(expr[2], consts)
    if tag == "add":
        return resolve_cexpr(expr[1], consts) + resolve_cexpr(expr[2], consts)
    if tag == "rot":
        # rotate(step): out[i] = in[i + step]
        return np.roll(resolve_cexpr(expr[1], consts), -expr[2])
    raise ValueError(f"unknown const expression {expr!r}")


def _const_vec(op, consts, slots: int) -> np.ndarray:
    expr = op.meta.get("cexpr", ("ref", op.meta["const"]))
    v = resolve_cexpr(expr, consts)
    assert len(v) == slots, f"const for op {op.idx} has {len(v)} slots"
    return v


def reference_eval(trace: FheTrace, inputs: Sequence[np.ndarray],
                   consts: Optional[Dict[str, np.ndarray]] = None
                   ) -> List[np.ndarray]:
    """Plaintext oracle: exact slotwise arithmetic, no noise, no scales."""
    consts = consts or {}
    slots = len(inputs[0])
    env: Dict[int, np.ndarray] = {}
    for i, idx in enumerate(trace.inputs):
        env[idx] = np.asarray(inputs[i])
    for op in trace.ops:
        if op.kind in ("input", "const"):
            continue
        a = [env[x] for x in op.args]
        if op.kind == "hadd":
            env[op.idx] = a[0] + a[1]
        elif op.kind == "hsub":
            env[op.idx] = a[0] - a[1]
        elif op.kind == "hmul":
            env[op.idx] = a[0] * a[1]
        elif op.kind == "pmul":
            env[op.idx] = a[0] * _const_vec(op, consts, slots)
        elif op.kind == "padd":
            env[op.idx] = a[0] + _const_vec(op, consts, slots)
        elif op.kind == "rotate":
            env[op.idx] = np.roll(a[0], -op.meta["step"])
        elif op.kind == "conjugate":
            env[op.idx] = np.conj(a[0])
        elif op.kind in ("rescale", "bootstrap"):
            env[op.idx] = a[0]
        else:
            raise ValueError(op.kind)
    return [env[o] for o in trace.outputs]


class CkksTraceInterpreter:
    """Executes traces through the real encrypt/eval/decrypt stack.

    Keys (secret, relin, per-element Galois) are generated once and
    cached across `run` calls, so verifying a workload under several
    pass configurations pays keygen once.
    """

    def __init__(self, params: CkksParams, seed: int = 7):
        self.params = params
        self.ctx = CkksContext(params)
        self.encoder = CkksEncoder(self.ctx)
        self.encryptor = CkksEncryptor(self.ctx, seed=seed)
        self.sk = self.encryptor.keygen()
        self.rk = self.encryptor.relin_keygen(self.sk)
        self._gks = {}

    def _gk(self, elt: int):
        if elt not in self._gks:
            self._gks.update(self.encryptor.galois_keygen(self.sk, [elt]))
        return self._gks[elt]

    # -- helpers -------------------------------------------------------------

    def _encrypt(self, v: np.ndarray, level: int) -> Ciphertext:
        scale = 2.0 ** self.params.log_scale
        pt = Plaintext(self.encoder.encode(v, scale, level), level, scale)
        return self.encryptor.encrypt_sk(pt, self.sk)

    def _decode(self, ct: Ciphertext) -> np.ndarray:
        pt = self.encryptor.decrypt(ct, self.sk)
        return self.encoder.decode(pt.data, ct.scale, ct.level)

    def _aligned(self, c0: Ciphertext, c1: Ciphertext):
        """Bring an hadd/hsub pair to one (level, scale); see module
        docstring for when alignment is exact vs structural."""
        from repro.core import linalg
        lvl = min(c0.level, c1.level)

        def down(hi: Ciphertext, partner_scale: float) -> Ciphertext:
            if (hi.level > lvl
                    and abs(hi.scale / partner_scale - 1.0) > 1e-6):
                return linalg.adjust_to(self.ctx, self.encoder, hi, lvl,
                                        partner_scale)
            return hops.mod_switch_to_level(hi, lvl)

        if c0.level > c1.level:
            c0 = down(c0, c1.scale)
        elif c1.level > c0.level:
            c1 = down(c1, c0.scale)
        rel = abs(c1.scale / c0.scale - 1.0)
        if rel > 1e-6:
            raise ValueError(
                f"scale-incompatible add at level {lvl}: "
                f"{c0.scale:.6e} vs {c1.scale:.6e} — the trace mixes "
                f"rescale disciplines on one add")
        if rel > 0:
            c1 = Ciphertext(c1.data, c1.level, c0.scale)
        return c0, c1

    # -- execution -----------------------------------------------------------

    def run(self, trace: FheTrace, inputs: Sequence[np.ndarray],
            consts: Optional[Dict[str, np.ndarray]] = None,
            start_level: Optional[int] = None) -> List[np.ndarray]:
        """Encrypt `inputs`, execute every op, return decoded outputs."""
        consts = consts or {}
        ctx, params = self.ctx, self.params
        slots = params.slots
        scale = 2.0 ** params.log_scale
        if start_level is None:
            in_op = trace.ops[trace.inputs[0]] if trace.inputs else None
            start_level = (in_op.level if in_op is not None
                           and in_op.level is not None else params.n_levels)
        env: Dict[int, Ciphertext] = {}
        for i, idx in enumerate(trace.inputs):
            env[idx] = self._encrypt(np.asarray(inputs[i]), start_level)
        for op in trace.ops:
            if op.kind in ("input", "const"):
                continue
            a = [env[x] for x in op.args]
            lazy = bool(op.meta.get("lazy"))
            if op.kind in ("hadd", "hsub"):
                lhs, rhs = self._aligned(a[0], a[1])
                fn = hops.hadd if op.kind == "hadd" else hops.hsub
                env[op.idx] = fn(ctx, lhs, rhs)
            elif op.kind == "hmul":
                env[op.idx] = hops.hmul(ctx, a[0], a[1], self.rk,
                                        do_rescale=not lazy)
            elif op.kind == "pmul":
                v = _const_vec(op, consts, slots)
                pt = Plaintext(self.encoder.encode(v, scale, a[0].level),
                               a[0].level, scale)
                env[op.idx] = hops.pmul(ctx, a[0], pt, do_rescale=not lazy)
            elif op.kind == "padd":
                v = _const_vec(op, consts, slots)
                pt = Plaintext(self.encoder.encode(v, a[0].scale,
                                                   a[0].level),
                               a[0].level, a[0].scale)
                env[op.idx] = hops.padd(ctx, a[0], pt)
            elif op.kind == "rotate":
                step = op.meta["step"] % slots
                if step == 0:
                    env[op.idx] = a[0]
                else:
                    elt = ctx.rotation_element(step)
                    env[op.idx] = hops.rotate(ctx, a[0], step, self._gk(elt))
            elif op.kind == "conjugate":
                env[op.idx] = hops.conjugate(ctx, a[0],
                                             self._gk(ctx.conj_element))
            elif op.kind == "rescale":
                env[op.idx] = hops.rescale(ctx, a[0])
            elif op.kind == "bootstrap":
                target = op.level if op.level is not None else start_level
                env[op.idx] = self._encrypt(self._decode(a[0]), target)
            else:
                raise ValueError(op.kind)
        return [self._decode(env[o]) for o in trace.outputs]
