"""Optimizing FHE trace compiler (paper §IV-F's end-to-end flow).

Sits between trace capture (core/trace.py) and the load-save pipeline
mapper (core/pipeline.py): a pass pipeline over the SSA `FheTrace` IR
with per-pass cost accounting and semantic verification through the
real CKKS stack.

* ``passes``   — DCE, CSE, plaintext constant folding, rotation
                 reuse/BSGS hoisting, lazy rescale placement, automatic
                 bootstrap insertion
* ``manager``  — `PassConfig` + `optimize_trace` with the
                 never-more-expensive guard and `CompileReport`
* ``interp``   — plaintext oracle + real-CKKS trace interpreter
* ``ir``       — rewrite substrate (substitution, pruning, renumbering,
                 derived const expressions)

Entry points: ``optimize_trace(trace, params, PassConfig())``; the
serving runtime reaches it via ``CompileCache.get_schedule(...,
pass_config=...)`` and ``repro.launch.serve_fhe --opt``.
"""
from repro.compiler.manager import (CompileReport, PassConfig, PassManager,
                                    PassReport, PassStats, analytic_seconds,
                                    optimize_trace, trace_cost)
from repro.compiler.passes import (PASS_ORDER, BootstrapInsertion,
                                   CommonSubexpr, ConstantFold,
                                   DeadCodeElimination, LazyRescale,
                                   RotationOpt)
from repro.compiler.interp import CkksTraceInterpreter, reference_eval

__all__ = [
    "CompileReport", "PassConfig", "PassManager", "PassReport", "PassStats",
    "analytic_seconds",
    "optimize_trace", "trace_cost", "PASS_ORDER", "BootstrapInsertion",
    "CommonSubexpr", "ConstantFold", "DeadCodeElimination", "LazyRescale",
    "RotationOpt", "CkksTraceInterpreter", "reference_eval",
]
