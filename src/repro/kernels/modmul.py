"""Pallas TPU kernels for elementwise modular arithmetic — the NMU analog.

Kernels use ONLY u32 ops (16-bit limb composition + Montgomery REDC,
kernels/common.py), so they lower to the TPU VPU. Block shapes put whole
(1, block_n) coefficient rows in VMEM; per-limb constants ride along as
(1, 1) blocks.

Semantics contract (see ref.py): operand `b` is pre-converted to Montgomery
form by the ops.py wrapper, so `mont_mul32(a, b_mont) == a*b mod q` exactly.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import addmod32, mont_mul32

U32 = jnp.uint32


def _pad_cols(arrs, n: int, block_n: int):
    """Zero-pad the last axis up to a multiple of block_n.

    `grid = (l, n // block_n)` silently DROPS the tail block when
    n % block_n != 0 (the trailing coefficients come back as zeros) —
    regression-tested in tests/test_kernels.py. Zero columns are inert
    for every kernel here (mont_mul32(0, b) == 0), so pad + slice is
    exact."""
    pad = (-n) % block_n
    if pad == 0:
        return arrs, n
    return [jnp.pad(a, ((0, 0), (0, pad))) for a in arrs], n + pad


def _modmul_kernel(a_ref, b_ref, q_ref, qinv_ref, o_ref):
    q = q_ref[0, 0]
    qi = qinv_ref[0, 0]
    o_ref[...] = mont_mul32(a_ref[...], b_ref[...], q, qi)


def _mulacc_kernel(a_ref, b_ref, c_ref, q_ref, qinv_ref, o_ref):
    q = q_ref[0, 0]
    qi = qinv_ref[0, 0]
    prod = mont_mul32(a_ref[...], b_ref[...], q, qi)
    o_ref[...] = addmod32(prod, c_ref[...], q)


def modmul_pallas(a, b_mont, q, qinv_neg, *, block_n: int = 512,
                  interpret: bool = True):
    """a, b_mont: (L, N) u32; q, qinv_neg: (L,) u32. Returns (a*b) mod q."""
    l, n = a.shape
    block_n = min(block_n, n)
    (a, b_mont), n_pad = _pad_cols([a, b_mont], n, block_n)
    grid = (l, n_pad // block_n)
    row = pl.BlockSpec((1, block_n), lambda i, j: (i, j))
    scal = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        _modmul_kernel,
        grid=grid,
        in_specs=[row, row, scal, scal],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((l, n_pad), U32),
        interpret=interpret,
    )(a, b_mont, q[:, None], qinv_neg[:, None])[:, :n]


def mulacc_pallas(a, b_mont, c, q, qinv_neg, *, block_n: int = 512,
                  interpret: bool = True):
    """(a*b + c) mod q — fused NMU multiply-accumulate."""
    l, n = a.shape
    block_n = min(block_n, n)
    (a, b_mont, c), n_pad = _pad_cols([a, b_mont, c], n, block_n)
    grid = (l, n_pad // block_n)
    row = pl.BlockSpec((1, block_n), lambda i, j: (i, j))
    scal = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        _mulacc_kernel,
        grid=grid,
        in_specs=[row, row, row, scal, scal],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((l, n_pad), U32),
        interpret=interpret,
    )(a, b_mont, c, q[:, None], qinv_neg[:, None])[:, :n]
