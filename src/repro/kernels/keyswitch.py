"""Fused Pallas kernels for the CKKS key-switch pipeline.

The hot loop of every homomorphic rotation and multiply is generalized
dnum key switching (core/ops.py::key_switch): per digit, ModUp =
iNTT -> BConv -> NTT, then the evk inner product, then ModDown. Run
stage-by-stage that is 7·dnum + 10 host dispatches per keyswitch (see
``keyswitch_staged``) — exactly the dispatch-granularity overhead
HE-PIM/MemFHE identify as the dominant cost of real PIM FHE. This
module collapses the whole pipeline into FOUR ``pl.pallas_call``
launches, independent of digit count, limb count, and batch size:

  A  ``_intt_scale_kernel``   grid (B, L):     fused inverse NTT with
     the n^{-1}·qhat^{-1} scale folded into one Montgomery multiply —
     the ModUp front half for every digit at once (digits partition the
     Q limbs, so "all digit limbs" is just "all limbs").
  B  ``_bconv_ntt_mulacc_kernel``  grid (B, T, digits): per target limb,
     BConv accumulation, forward NTT stages fused with their twiddle
     multiplies, and the evk multiply-accumulate for BOTH key
     components — with the DIGIT LOOP ON-CHIP: the digit grid axis is
     innermost, so the accumulator block stays resident in VMEM across
     digits (revisiting), never round-tripping to HBM.
  C1 ``_intt_scale_kernel``   grid (2B, n_p): ModDown inverse NTT of
     the special limbs of both accumulators (components folded into
     the batch axis).
  C2 ``_moddown_kernel``      grid (2B, L):   BConv P->Q fused with the
     forward NTT, the subtraction, and the P^{-1} multiply.

The digit-limb "copy" of the reference ModUp needs no special case: for
a target limb inside the source digit, every cross term of the BConv
sum vanishes (qhat_j ≡ 0 mod q_i for j ≠ i) and the diagonal term
reproduces a_i exactly, so the uniform BConv+NTT path is bit-identical
to the reference interleave. All arithmetic is the u32 Montgomery layer
of kernels/common.py (word32 RNS, moduli < 2^31), so results are
bit-for-bit equal to the u64 library path — decrypt-equality of the
fused engine route is exact, not approximate. Tested in
tests/test_keyswitch_fused.py; dispatch counts are golden-snapshotted
and compared in benchmarks/fig14_kernels.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (addmod32, mont_mul32, record_dispatch,
                                  submod32)

U32 = jnp.uint32
U64 = jnp.uint64


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# in-kernel NTT stage helpers (last-axis butterflies, Montgomery twiddles)
# ---------------------------------------------------------------------------

def _ct_stages_last(x, rp_m, q, qi):
    """Harvey CT butterflies along the last axis of x (rows, n);
    rp_m (n,) Montgomery-form twiddles in core/ntt.py bitrev layout."""
    rows, n = x.shape
    m = 1
    while m < n:
        t = n // (2 * m)
        xr = x.reshape(rows, m, 2 * t)
        w = rp_m[m:2 * m]                        # (m,)
        u = xr[:, :, :t]
        v = mont_mul32(xr[:, :, t:], w[None, :, None], q, qi)
        x = jnp.concatenate([addmod32(u, v, q), submod32(u, v, q)],
                            axis=-1).reshape(rows, n)
        m *= 2
    return x


def _gs_stages_last(x, irp_m, q, qi):
    """Gentleman-Sande inverse butterflies (no n^{-1} scale — callers
    fold it into their own final multiply)."""
    rows, n = x.shape
    m = n // 2
    while m >= 1:
        t = n // (2 * m)
        xr = x.reshape(rows, m, 2 * t)
        w = irp_m[m:2 * m]
        u = xr[:, :, :t]
        v = xr[:, :, t:]
        s = addmod32(u, v, q)
        d = mont_mul32(submod32(u, v, q), w[None, :, None], q, qi)
        x = jnp.concatenate([s, d], axis=-1).reshape(rows, n)
        m //= 2
    return x


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------

def _intt_scale_kernel(x_ref, irp_ref, q_ref, qi_ref, sc_ref, o_ref):
    """One (1, 1, N) limb row: inverse NTT fused with a per-limb scale
    (n^{-1}·qhat^{-1} — the iNTT normalization and the BConv input
    scaling as ONE Montgomery multiply)."""
    q = q_ref[0, 0]
    qi = qi_ref[0, 0]
    x = _gs_stages_last(x_ref[0], irp_ref[0], q, qi)
    o_ref[...] = mont_mul32(x, sc_ref[0, 0], q, qi)[None]


def _bconv_ntt_mulacc_kernel(v_ref, w_ref, rp_ref, q_ref, qi_ref,
                             k0_ref, k1_ref, a0_ref, a1_ref):
    """One (batch, target-limb) output row, revisited across the digit
    grid axis: BConv over the digit's (padded) source rows, forward NTT
    stages fused with their twiddle multiplies, then the evk
    multiply-accumulate for both key components. Padded source rows
    carry w = 0 so they contribute nothing."""
    q = q_ref[0, 0]
    qi = qi_ref[0, 0]
    jmax = v_ref.shape[2]
    n = v_ref.shape[3]
    acc = jnp.zeros((1, n), U32)
    for j in range(jmax):                       # adder tree, depth-1
        prod = mont_mul32(v_ref[0, 0, j, :][None, :], w_ref[0, j, 0], q, qi)
        acc = addmod32(acc, prod, q)
    raised = _ct_stages_last(acc, rp_ref[0], q, qi)
    e0 = mont_mul32(raised, k0_ref[0, 0][None, :], q, qi)
    e1 = mont_mul32(raised, k1_ref[0, 0][None, :], q, qi)
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _():
        a0_ref[...] = e0[None]
        a1_ref[...] = e1[None]

    @pl.when(d != 0)
    def _():
        a0_ref[...] = addmod32(a0_ref[...], e0[None], q)
        a1_ref[...] = addmod32(a1_ref[...], e1[None], q)


def _moddown_kernel(aq_ref, vp_ref, w_ref, rp_ref, q_ref, qi_ref,
                    pinv_ref, o_ref):
    """ModDown tail for one (batch, Q-limb) row: BConv P->Q fused with
    the forward NTT, the subtraction from a_Q, and the P^{-1} multiply."""
    q = q_ref[0, 0]
    qi = qi_ref[0, 0]
    n_p = vp_ref.shape[1]
    n = vp_ref.shape[2]
    acc = jnp.zeros((1, n), U32)
    for j in range(n_p):
        prod = mont_mul32(vp_ref[0, j, :][None, :], w_ref[j, 0], q, qi)
        acc = addmod32(acc, prod, q)
    conv = _ct_stages_last(acc, rp_ref[0], q, qi)
    diff = submod32(aq_ref[0], conv, q)
    o_ref[...] = mont_mul32(diff, pinv_ref[0, 0], q, qi)[None]


# ---------------------------------------------------------------------------
# host-precomputed per-level tables
# ---------------------------------------------------------------------------

def _mont_np(arr: np.ndarray, p: int) -> np.ndarray:
    """arr -> arr·R mod p as u32 (arr, R mod p < 2^31: no u64 overflow)."""
    rm = np.uint64((1 << 32) % p)
    return ((arr.astype(np.uint64) * rm) % np.uint64(p)).astype(np.uint32)


@dataclasses.dataclass
class _LevelTables:
    """Device tables for one (level, target basis) instance."""
    n_digits: int
    alpha: int                    # padded digit size
    n_p: int
    # stage A (Q-limb iNTT + digit-local qhat^{-1} scale)
    q_irp_m: jnp.ndarray          # (L, N)
    q_q32: jnp.ndarray            # (L,)
    q_qi32: jnp.ndarray           # (L,)
    q_scale_m: jnp.ndarray        # (L,)  n^{-1}·qhat^{-1} mont
    # stage B (target-limb BConv + NTT + evk mulacc)
    w_m: jnp.ndarray              # (D, alpha, T) mont w.r.t. target prime
    rp_m: jnp.ndarray             # (T, N) forward twiddles, mont
    t_q32: jnp.ndarray            # (T,)
    t_qi32: jnp.ndarray           # (T,)
    # ModDown
    p_irp_m: jnp.ndarray          # (n_p, N)
    p_q32: jnp.ndarray            # (n_p,)
    p_qi32: jnp.ndarray           # (n_p,)
    p_scale_m: jnp.ndarray        # (n_p,) n^{-1}·phat^{-1} mont
    wpq_m: jnp.ndarray            # (n_p, L) mont w.r.t. q
    pinv_m: jnp.ndarray           # (L,) P^{-1} mod q, mont


class FusedKeySwitch:
    """Executes the fused keyswitch pipeline against one CkksContext.

    Tables are built host-side once per level; evaluation keys are
    Montgomery-converted once per (key identity, level); the whole
    4-kernel pipeline is jitted once per (batch, level) and shared by
    every evk (relin and all Galois keys ride the same compiled fn).
    """

    DISPATCHES_PER_APPLY = 4      # pallas_call launches per keyswitch

    def __init__(self, ctx):
        self.ctx = ctx
        self._tabs: Dict[int, _LevelTables] = {}
        self._ksk_m: Dict[Tuple, jnp.ndarray] = {}
        self._fns: Dict[Tuple, callable] = {}

    # -- tables --------------------------------------------------------------

    def _tables(self, level: int) -> _LevelTables:
        t = self._tabs.get(level)
        if t is not None:
            return t
        ctx = self.ctx
        l = level + 1
        n_p = ctx.n_p
        digits = ctx.params.digit_indices(level)
        d_n = len(digits)
        alpha = ctx.params.alpha
        target = list(range(l)) + ctx.p_idx()
        t_primes = [ctx.primes[i] for i in target]
        t_n = len(target)

        rp = np.asarray(ctx.tables.root_powers)
        irp = np.asarray(ctx.tables.inv_root_powers)
        n_inv = np.asarray(ctx.tables.n_inv)

        def qinv32(p: int) -> np.uint32:
            return np.uint32((-pow(p, -1, 1 << 32)) % (1 << 32))

        # stage A: per-Q-limb inverse twiddles + fused n^{-1}·qhat^{-1}
        q_irp_m = np.stack([_mont_np(irp[j], ctx.primes[j])
                            for j in range(l)])
        q_scale = np.zeros(l, dtype=np.uint32)
        for dig in digits:
            big_qd = 1
            for j in dig:
                big_qd *= ctx.primes[j]
            for j in dig:
                qj = ctx.primes[j]
                qhat_inv = pow((big_qd // qj) % qj, -1, qj)
                sc = int(n_inv[j]) * qhat_inv % qj
                q_scale[j] = _mont_np(np.array([sc], dtype=np.uint64), qj)[0]

        # stage B: BConv weights per (digit, src, target) + fwd twiddles
        w = np.zeros((d_n, alpha, t_n), dtype=np.uint32)
        for d, dig in enumerate(digits):
            big_qd = 1
            for j in dig:
                big_qd *= ctx.primes[j]
            for jl, j in enumerate(dig):
                qhat = big_qd // ctx.primes[j]
                for ti, p in enumerate(t_primes):
                    w[d, jl, ti] = _mont_np(
                        np.array([qhat % p], dtype=np.uint64), p)[0]
        rp_m = np.stack([_mont_np(rp[g], ctx.primes[g]) for g in target])

        # ModDown: P-limb iNTT + n^{-1}·phat^{-1}, BConv P->Q, P^{-1}
        p_glob = ctx.p_idx()
        p_irp_m = np.stack([_mont_np(irp[g], ctx.primes[g]) for g in p_glob])
        big_p = ctx.big_p
        p_scale = np.zeros(n_p, dtype=np.uint32)
        wpq = np.zeros((n_p, l), dtype=np.uint32)
        for i, g in enumerate(p_glob):
            p = ctx.primes[g]
            phat = big_p // p
            sc = int(n_inv[g]) * pow(phat % p, -1, p) % p
            p_scale[i] = _mont_np(np.array([sc], dtype=np.uint64), p)[0]
            for j in range(l):
                qj = ctx.primes[j]
                wpq[i, j] = _mont_np(np.array([phat % qj],
                                              dtype=np.uint64), qj)[0]
        pinv = np.asarray(ctx.p_inv_mod_q[:l])
        pinv_m = np.array([_mont_np(pinv[j:j + 1], ctx.primes[j])[0]
                           for j in range(l)], dtype=np.uint32)

        t = _LevelTables(
            n_digits=d_n, alpha=alpha, n_p=n_p,
            q_irp_m=jnp.asarray(q_irp_m),
            q_q32=jnp.asarray(np.array(ctx.primes[:l], dtype=np.uint32)),
            q_qi32=jnp.asarray(np.array(
                [qinv32(ctx.primes[j]) for j in range(l)], dtype=np.uint32)),
            q_scale_m=jnp.asarray(q_scale),
            w_m=jnp.asarray(w),
            rp_m=jnp.asarray(rp_m),
            t_q32=jnp.asarray(np.array(t_primes, dtype=np.uint32)),
            t_qi32=jnp.asarray(np.array([qinv32(p) for p in t_primes],
                                        dtype=np.uint32)),
            p_irp_m=jnp.asarray(p_irp_m),
            p_q32=jnp.asarray(np.array([ctx.primes[g] for g in p_glob],
                                       dtype=np.uint32)),
            p_qi32=jnp.asarray(np.array(
                [qinv32(ctx.primes[g]) for g in p_glob], dtype=np.uint32)),
            p_scale_m=jnp.asarray(p_scale),
            wpq_m=jnp.asarray(wpq),
            pinv_m=jnp.asarray(pinv_m),
        )
        self._tabs[level] = t
        return t

    def ksk_mont(self, key: Tuple, level: int,
                 ksk_data: jnp.ndarray) -> jnp.ndarray:
        """Target-basis slice of an evk in Montgomery form, cached per
        (stable key identity, level): (D, 2, T, N) u32."""
        k = (key, level)
        m = self._ksk_m.get(k)
        if m is not None:
            return m
        from repro.core import modarith as ma
        ctx = self.ctx
        t = self._tables(level)
        target = np.array(list(range(level + 1)) + ctx.p_idx())
        q_t = ctx.q_all[target][:, None]
        rm = jnp.asarray(np.array(
            [(1 << 32) % ctx.primes[g] for g in target], dtype=np.uint64))
        sel = ksk_data[: t.n_digits, :, target]
        m = ma.mulmod(sel, rm[:, None], q_t).astype(U32)
        self._ksk_m[k] = m
        return m

    # -- pipeline ------------------------------------------------------------

    def _build(self, b: int, level: int, itp: bool):
        """The full 4-kernel pipeline for one (batch, level) signature."""
        t = self._tables(level)
        l = level + 1
        n = self.ctx.n
        d_n, alpha, n_p = t.n_digits, t.alpha, t.n_p
        t_n = l + n_p

        def run(d2, ksk_m):
            d2 = d2.astype(U32)
            row3 = lambda i, j: (i, j, 0)                     # noqa: E731
            limb_row = lambda i, j: (j, 0)                    # noqa: E731
            limb_scal = lambda i, j: (j, 0)                   # noqa: E731
            # A: ModUp front half for every digit limb at once
            v = pl.pallas_call(
                _intt_scale_kernel,
                grid=(b, l),
                in_specs=[pl.BlockSpec((1, 1, n), row3),
                          pl.BlockSpec((1, n), limb_row),
                          pl.BlockSpec((1, 1), limb_scal),
                          pl.BlockSpec((1, 1), limb_scal),
                          pl.BlockSpec((1, 1), limb_scal)],
                out_specs=pl.BlockSpec((1, 1, n), row3),
                out_shape=jax.ShapeDtypeStruct((b, l, n), U32),
                interpret=itp,
            )(d2, t.q_irp_m, t.q_q32[:, None], t.q_qi32[:, None],
              t.q_scale_m[:, None])
            # digits partition the Q limbs contiguously in alpha-chunks:
            # zero-pad the tail digit and fold the digit axis out
            v_pad = jnp.pad(v, ((0, 0), (0, d_n * alpha - l),
                                (0, 0))).reshape(b, d_n, alpha, n)
            # B: on-chip digit loop (digit axis innermost -> accumulator
            # blocks stay resident across digits)
            acc0, acc1 = pl.pallas_call(
                _bconv_ntt_mulacc_kernel,
                grid=(b, t_n, d_n),
                in_specs=[
                    pl.BlockSpec((1, 1, alpha, n),
                                 lambda i, j, d: (i, d, 0, 0)),
                    pl.BlockSpec((1, alpha, 1), lambda i, j, d: (d, 0, j)),
                    pl.BlockSpec((1, n), lambda i, j, d: (j, 0)),
                    pl.BlockSpec((1, 1), lambda i, j, d: (j, 0)),
                    pl.BlockSpec((1, 1), lambda i, j, d: (j, 0)),
                    pl.BlockSpec((1, 1, n), lambda i, j, d: (d, j, 0)),
                    pl.BlockSpec((1, 1, n), lambda i, j, d: (d, j, 0)),
                ],
                out_specs=[pl.BlockSpec((1, 1, n), lambda i, j, d: (i, j, 0)),
                           pl.BlockSpec((1, 1, n),
                                        lambda i, j, d: (i, j, 0))],
                out_shape=[jax.ShapeDtypeStruct((b, t_n, n), U32),
                           jax.ShapeDtypeStruct((b, t_n, n), U32)],
                interpret=itp,
            )(v_pad, t.w_m, t.rp_m, t.t_q32[:, None], t.t_qi32[:, None],
              ksk_m[:, 0], ksk_m[:, 1])
            # ModDown: both key components fold into the batch axis
            g = jnp.concatenate([acc0, acc1], axis=0)         # (2b, T, n)
            vp = pl.pallas_call(
                _intt_scale_kernel,
                grid=(2 * b, n_p),
                in_specs=[pl.BlockSpec((1, 1, n), lambda i, j: (i, l + j, 0)),
                          pl.BlockSpec((1, n), limb_row),
                          pl.BlockSpec((1, 1), limb_scal),
                          pl.BlockSpec((1, 1), limb_scal),
                          pl.BlockSpec((1, 1), limb_scal)],
                out_specs=pl.BlockSpec((1, 1, n), row3),
                out_shape=jax.ShapeDtypeStruct((2 * b, n_p, n), U32),
                interpret=itp,
            )(g, t.p_irp_m, t.p_q32[:, None], t.p_qi32[:, None],
              t.p_scale_m[:, None])
            out = pl.pallas_call(
                _moddown_kernel,
                grid=(2 * b, l),
                in_specs=[pl.BlockSpec((1, 1, n), row3),
                          pl.BlockSpec((1, n_p, n), lambda i, j: (i, 0, 0)),
                          pl.BlockSpec((n_p, 1), lambda i, j: (0, j)),
                          pl.BlockSpec((1, n), limb_row),
                          pl.BlockSpec((1, 1), limb_scal),
                          pl.BlockSpec((1, 1), limb_scal),
                          pl.BlockSpec((1, 1), limb_scal)],
                out_specs=pl.BlockSpec((1, 1, n), row3),
                out_shape=jax.ShapeDtypeStruct((2 * b, l, n), U32),
                interpret=itp,
            )(g, vp, t.wpq_m, t.rp_m, t.t_q32[:, None], t.t_qi32[:, None],
              t.pinv_m[:, None])
            return out[:b].astype(U64), out[b:].astype(U64)
        return run

    def apply(self, d2: jnp.ndarray, level: int, ksk_m: jnp.ndarray,
              interpret=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Key-switch d2 (B, level+1, N) u64 NTT-domain to the key in
        ksk_m (from ``ksk_mont``). Returns (e0, e1), each (B, level+1,
        N) u64 — bit-identical to core/ops.key_switch per batch row."""
        itp = _default_interpret() if interpret is None else interpret
        b = d2.shape[0]
        key = (b, level, itp)
        fn = self._fns.get(key)
        if fn is None:
            # first call runs un-jitted (the pallas interpreter traces
            # eagerly; tables must land as concrete arrays), then the
            # jitted pipeline is cached — the steady state is ONE fused
            # XLA program containing the 4 kernel launches
            eager = self._build(b, level, itp)

            def first(d2_, ksk_):
                out = eager(d2_, ksk_)
                self._fns[key] = jax.jit(eager)
                return out
            fn = first
        record_dispatch(self.DISPATCHES_PER_APPLY)
        return fn(d2, ksk_m)


# ---------------------------------------------------------------------------
# staged baseline: the same pipeline as one dispatch per stage
# ---------------------------------------------------------------------------

def keyswitch_staged(ctx, d2: jnp.ndarray, level: int, ksk,
                     interpret=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch-per-stage keyswitch through the standalone kernels +
    library NTT dispatches — bit-identical to core/ops.key_switch, used
    as the fig14 baseline the fused pipeline is measured against. Every
    host-side launch records itself via kernels.common.record_dispatch:
    7 per digit (iNTT, qhat^{-1} modmul, BConv, NTT, interleave, 2×evk
    mulacc) plus 10 for ModDown."""
    from repro.kernels import ops as kops
    itp = _default_interpret() if interpret is None else interpret
    idx_q = ctx.q_idx(level)
    idx_p = ctx.p_idx()
    target = idx_q + idx_p
    t_primes = [ctx.primes[i] for i in target]
    n = ctx.n
    digits = ctx.params.digit_indices(level)
    acc0 = jnp.zeros((len(target), n), dtype=U64)
    acc1 = jnp.zeros((len(target), n), dtype=U64)
    ksk_sel = ksk.data[:, :, np.array(target)]
    pos = {g: i for i, g in enumerate(target)}
    for d, dig in enumerate(digits):
        other = [i for i in target if i not in dig]
        tabs = ctx.bconv_tables(dig, other)
        record_dispatch()                                   # iNTT
        dig_c = ctx.intt(d2[np.array(dig)], dig)
        src = [ctx.primes[i] for i in dig]
        v = kops.modmul(dig_c, jnp.broadcast_to(tabs.qhat_inv[:, None],
                                                dig_c.shape), src,
                        interpret=itp)
        conv = kops.bconv(v, tabs.w, [ctx.primes[i] for i in other],
                          interpret=itp)
        record_dispatch()                                   # NTT
        conv_ntt = ctx.ntt(conv, other)
        record_dispatch()                                   # interleave
        raised = jnp.zeros((len(target), n), dtype=U64)
        raised = raised.at[np.array([pos[g] for g in dig])].set(
            d2[np.array(dig)])
        raised = raised.at[np.array([pos[g] for g in other])].set(conv_ntt)
        acc0 = kops.mulacc(raised, ksk_sel[d, 0], acc0, t_primes,
                           interpret=itp)
        acc1 = kops.mulacc(raised, ksk_sel[d, 1], acc1, t_primes,
                           interpret=itp)
    nq = len(idx_q)
    q = ctx.q_all[:nq][:, None]
    tabs = ctx.bconv_tables(idx_p, idx_q)
    outs = []
    for acc in (acc0, acc1):
        record_dispatch()                                   # iNTT (P)
        p_c = ctx.intt(acc[nq:], idx_p)
        v = kops.modmul(p_c, jnp.broadcast_to(tabs.qhat_inv[:, None],
                                              p_c.shape),
                        [ctx.primes[i] for i in idx_p], interpret=itp)
        conv = kops.bconv(v, tabs.w, [ctx.primes[i] for i in idx_q],
                          interpret=itp)
        record_dispatch()                                   # NTT
        conv_ntt = ctx.ntt(conv, idx_q)
        record_dispatch()                                   # sub + P^{-1}
        from repro.core import modarith as ma
        diff = ma.submod(acc[:nq], conv_ntt, q)
        outs.append(ma.mulmod(diff, ctx.p_inv_mod_q[:nq][:, None], q))
    return outs[0], outs[1]
