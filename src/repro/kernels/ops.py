"""jit'd public wrappers around the Pallas kernels.

Handles Montgomery-form conversion of the constant operands (host/jit-side
u64 math via core.modarith — cheap and exact), dtype casts u64<->u32, and
interpret-mode selection (interpret=True on CPU; compiled on TPU).
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import modarith as ma
from repro.kernels import bconv as bconv_k
from repro.kernels import modmul as modmul_k
from repro.kernels.common import record_dispatch
from repro.kernels.ntt import FourStepKernelTables, ntt_four_step_pallas
from repro.kernels.ref import FourStepTables


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@lru_cache(maxsize=256)
def _mont_consts(primes: tuple):
    """Per-prime-basis constants (q as u64/u32, -q^-1 mod 2^32, R mod q).

    Cached on the prime tuple: the host-side modular inverses and the
    four host->device transfers would otherwise run on EVERY wrapper
    call — eager per-call work outside the jit boundary, the same bug
    class the fused keyswitch pipeline fixed (tests guard this stays
    cached)."""
    q64 = jnp.asarray(np.array(primes, dtype=np.uint64))
    q32 = jnp.asarray(np.array(primes, dtype=np.uint32))
    qinv = jnp.asarray(np.array(
        [(-pow(int(p), -1, 1 << 32)) % (1 << 32) for p in primes],
        dtype=np.uint32))
    # R mod q: plain mulmod(b, rm) == b * 2^32 mod q (Montgomery form)
    rm = jnp.asarray(np.array([(1 << 32) % int(p) for p in primes],
                              dtype=np.uint64))
    return q64, q32, qinv, rm


def _key(primes: Sequence[int]) -> tuple:
    return tuple(int(p) for p in primes)


@partial(jax.jit, static_argnames=("interpret",))
def _modmul_impl(a, b, q64, q32, qinv, rm, interpret=True):
    b_mont = ma.mulmod(b, rm[:, None], q64[:, None]).astype(jnp.uint32)
    return modmul_k.modmul_pallas(a.astype(jnp.uint32), b_mont, q32, qinv,
                                  interpret=interpret).astype(jnp.uint64)


def modmul(a, b, primes: Sequence[int], interpret=None):
    """(a*b) mod q per limb. a, b: (L, N) u64; primes: python ints."""
    q64, q32, qinv, rm = _mont_consts(_key(primes))
    itp = _default_interpret() if interpret is None else interpret
    record_dispatch()
    return _modmul_impl(a, b, q64, q32, qinv, rm, interpret=itp)


@partial(jax.jit, static_argnames=("interpret",))
def _mulacc_impl(a, b, c, q64, q32, qinv, rm, interpret=True):
    b_mont = ma.mulmod(b, rm[:, None], q64[:, None]).astype(jnp.uint32)
    return modmul_k.mulacc_pallas(a.astype(jnp.uint32), b_mont,
                                  c.astype(jnp.uint32), q32, qinv,
                                  interpret=interpret).astype(jnp.uint64)


def mulacc(a, b, c, primes: Sequence[int], interpret=None):
    """(a*b + c) mod q per limb."""
    q64, q32, qinv, rm = _mont_consts(_key(primes))
    itp = _default_interpret() if interpret is None else interpret
    record_dispatch()
    return _mulacc_impl(a, b, c, q64, q32, qinv, rm, interpret=itp)


@partial(jax.jit, static_argnames=("lazy", "interpret"))
def _bconv_impl(v, wt, p64, p32, pinv, rm, lazy=False, interpret=True):
    # w -> Montgomery form w.r.t. each dst prime INSIDE the jit: the
    # conversion fuses into the compiled program instead of paying an
    # eager host-side dispatch (and a retrace) on every call.
    w_mont = ma.mulmod(wt % p64[:, None], rm[:, None],
                       p64[:, None]).astype(jnp.uint32)
    return bconv_k.bconv_pallas(v.astype(jnp.uint32), w_mont, p32, pinv,
                                lazy=lazy,
                                interpret=interpret).astype(jnp.uint64)


def bconv(v, w, dst_primes: Sequence[int], lazy: bool = False,
          interpret=None):
    """out[d] = sum_j v[j]*w[j,d] mod p_d. v: (S,N) u64; w: (S,D) u64."""
    p64, p32, pinv, rm64 = _mont_consts(_key(dst_primes))
    itp = _default_interpret() if interpret is None else interpret
    record_dispatch()
    return _bconv_impl(v, w.T, p64, p32, pinv, rm64, lazy=lazy,
                       interpret=itp)


class NttKernel:
    """Four-step NTT kernel bound to one modulus (tables cached)."""

    def __init__(self, q: int, psi: int, log_n: int, log_r: int):
        self.tabs = FourStepTables(q, psi, log_n, log_r)
        self.kt = FourStepKernelTables(self.tabs)

    def __call__(self, a, interpret=None, **blocks):
        itp = _default_interpret() if interpret is None else interpret
        record_dispatch(2)          # column kernel + fused row kernel
        return ntt_four_step_pallas(a.astype(jnp.uint32), self.kt,
                                    interpret=itp,
                                    **blocks).astype(jnp.uint64)
