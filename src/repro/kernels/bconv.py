"""Pallas TPU kernel for the BConv accumulation (paper §II-A eq.(1), §IV-D).

out[d, n] = sum_j v[j, n] * w[j, d]  (mod p_d)

This is FHE's all-to-all primitive: in FHEmem the partial products cross
the inter-bank chain network; on TPU each (d, n-block) program holds its
output tile in VMEM and streams the S source limbs through the VPU with a
static unrolled multiply-accumulate (the adder-tree of §IV-D, depth-1).

Two reduction schedules:
* eager: Montgomery-reduce every partial product (baseline);
* lazy  (`lazy=True`): accumulate 2^31-bounded sums in (hi, lo) u32 pairs
  and fold every 4 products — fewer REDC ops, the §Perf variant. Both are
  exact; tests compare them bit-for-bit against ref.bconv_ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import addmod32, mont_mul32

U32 = jnp.uint32


def _bconv_kernel(v_ref, w_ref, p_ref, pinv_ref, o_ref):
    """v (S, bn); w_mont (1, S); p/pinv (1, 1). out (1, bn)."""
    p = p_ref[0, 0]
    pi = pinv_ref[0, 0]
    s = v_ref.shape[0]
    acc = jnp.zeros(o_ref.shape, U32)
    for j in range(s):
        prod = mont_mul32(v_ref[j, :][None, :], w_ref[0, j], p, pi)
        acc = addmod32(acc, prod, p)
    o_ref[...] = acc


def _bconv_kernel_lazy(v_ref, w_ref, p_ref, pinv_ref, o_ref):
    """Lazy variant: defer the modular fold across groups of products.

    mont_mul32 outputs are < p < 2^31; sums of two stay < 2^32. We add
    pairs before the modular fold, halving the addmod count.
    """
    p = p_ref[0, 0]
    pi = pinv_ref[0, 0]
    s = v_ref.shape[0]
    acc = jnp.zeros(o_ref.shape, U32)
    j = 0
    while j < s:
        prod = mont_mul32(v_ref[j, :][None, :], w_ref[0, j], p, pi)
        if j + 1 < s:
            prod2 = mont_mul32(v_ref[j + 1, :][None, :], w_ref[0, j + 1],
                               p, pi)
            pair = prod + prod2                     # < 2^32, no overflow
            pair = jnp.where(pair >= p, pair - p, pair)
            pair = jnp.where(pair >= p, pair - p, pair)
            j += 2
        else:
            pair = prod
            j += 1
        acc = addmod32(acc, pair, p)
    o_ref[...] = acc


def bconv_pallas(v, w_mont, p, pinv_neg, *, block_n: int = 512,
                 lazy: bool = False, interpret: bool = True):
    """v: (S, N) u32 (source values, reduced mod their own q_j);
    w_mont: (D, S) u32 — [qhat_j]_{p_d} in Montgomery form w.r.t. p_d;
    p, pinv_neg: (D,) u32. Returns (D, N) u32."""
    s, n = v.shape
    d = w_mont.shape[0]
    block_n = min(block_n, n)
    # zero-pad the coefficient axis: `n // block_n` grids silently drop
    # the tail block on non-divisible shapes (zero columns are inert)
    pad = (-n) % block_n
    if pad:
        v = jnp.pad(v, ((0, 0), (0, pad)))
    n_pad = n + pad
    grid = (d, n_pad // block_n)
    kern = _bconv_kernel_lazy if lazy else _bconv_kernel
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((s, block_n), lambda i, j: (0, j)),
                  pl.BlockSpec((1, s), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, n_pad), U32),
        interpret=interpret,
    )(v, w_mont, p[:, None], pinv_neg[:, None])[:, :n]
