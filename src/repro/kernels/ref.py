"""Pure-jnp oracles for every Pallas kernel (exact, u64 arithmetic).

These define the semantics the kernels must match bit-for-bit; tests sweep
shapes/dtypes and assert exact equality (integer kernels — allclose becomes
array_equal).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import modarith as ma
from repro.core import ntt as nttm


def modmul_ref(a, b, q):
    """Elementwise (a*b) mod q. a,b: (L, N) u64-safe ints; q: (L,)."""
    a = a.astype(jnp.uint64)
    b = b.astype(jnp.uint64)
    q = q.astype(jnp.uint64)
    return ma.mulmod(a, b, q[:, None])


def modadd_ref(a, b, q):
    return ma.addmod(a.astype(jnp.uint64), b.astype(jnp.uint64),
                     q.astype(jnp.uint64)[:, None])


def fused_mulacc_ref(a, b, c, q):
    """(a*b + c) mod q — the NMU multiply-accumulate."""
    a = a.astype(jnp.uint64)
    b = b.astype(jnp.uint64)
    c = c.astype(jnp.uint64)
    q = q.astype(jnp.uint64)[:, None]
    return ma.addmod(ma.mulmod(a, b, q), c % q, q)


def bconv_ref(v, w, p):
    """BConv accumulation: out[d, n] = sum_j v[j, n] * w[j, d] mod p[d].

    v: (S, N), w: (S, D), p: (D,) — exact via u64 with per-term reduction.
    """
    v = v.astype(jnp.uint64)
    w = w.astype(jnp.uint64)
    p = p.astype(jnp.uint64)
    s = v.shape[0]
    acc = jnp.zeros((w.shape[1], v.shape[1]), jnp.uint64)
    for j in range(s):
        term = (v[j][None, :] * w[j][:, None]) % p[:, None]
        acc = acc + term
    return acc % p[:, None]


# ---------------------------------------------------------------------------
# four-step negacyclic NTT reference (kernel ordering)
# ---------------------------------------------------------------------------

class FourStepTables:
    """Host tables for the (R x C) four-step negacyclic NTT.

    Math (DESIGN.md §2 — the FHEmem 16x16 mat-array analogue):
        hat a_k = sum_j a_j psi^j omega^{jk},  omega = psi^2,  j = r*C + c.
    Split k = ku + R*kv:
        phase 1 (vertical / inter-mat):  column negacyclic NTT with root
            psi_col = psi^C — Harvey CT butterflies INCLUDE the psi_col^r
            twist, yielding slot u = cyclic column index brv_R(u);
        phase 2: elementwise correction T2[u,c] = psi^c * omega^{c*brv_R(u)};
        phase 3 (horizontal / intra-mat): row cyclic DFT of size C via
            negacyclic CT with root psi_row = psi^R and an inverse pre-twist
            psi_row^{-c} (cancels CT's built-in twist); slot v = brv_C(v).

    Kernel output order: out[u, v] = hat a at k = brv_R(u) + R * brv_C(v).
    """

    def __init__(self, q: int, psi: int, log_n: int, log_r: int):
        n = 1 << log_n
        r = 1 << log_r
        c = n // r
        self.q, self.n, self.r, self.c = q, n, r, c
        omega = psi * psi % q
        psi_col = pow(psi, c, q)      # 2R-th root (psi_col^R = psi^N = -1)
        psi_row = pow(psi, r, q)      # 2C-th root
        brv_r = nttm.bit_reverse_vector(r)
        brv_c = nttm.bit_reverse_vector(c)
        self.brv_r, self.brv_c = brv_r, brv_c
        self.rp_col = np.array([pow(psi_col, int(b), q) for b in brv_r],
                               dtype=np.uint64)
        self.rp_row = np.array([pow(psi_row, int(b), q) for b in brv_c],
                               dtype=np.uint64)
        t2 = np.empty((r, c), dtype=np.uint64)
        for u in range(r):
            eu = int(brv_r[u])
            for c0 in range(c):
                t2[u, c0] = pow(psi, c0, q) * pow(omega, c0 * eu, q) % q
        self.t2 = t2
        self.pre_row_inv = np.array([pow(psi_row, -i, q) for i in range(c)],
                                    dtype=np.uint64)
        # fuse T2 and the row pre-twist into one elementwise table
        self.t2_fused = (t2.astype(object)
                         * self.pre_row_inv[None, :].astype(object)) % q
        self.t2_fused = self.t2_fused.astype(np.uint64)

    def output_index_map(self):
        """k such that out.flatten()[u*C + v] = hat a_k."""
        r, c = self.r, self.c
        ks = np.empty(r * c, dtype=np.int64)
        for u in range(r):
            for v in range(c):
                ks[u * c + v] = int(self.brv_r[u]) + r * int(self.brv_c[v])
        return ks


def four_step_ntt_ref(a, tabs: FourStepTables):
    """Reference four-step negacyclic NTT (kernel ordering). a: (N,) u64."""
    q = jnp.asarray(np.array([tabs.q], dtype=np.uint64))
    r, c = tabs.r, tabs.c
    x = jnp.asarray(a).reshape(r, c).astype(jnp.uint64)
    # phase 1: column negacyclic NTT (CT includes the twist)
    xt = x.T.reshape(c, 1, r)                              # columns as rows
    y = nttm.ntt_forward(xt, jnp.asarray(tabs.rp_col)[None], q)
    y = y.reshape(c, r).T                                   # (R, C) u slots
    # phase 2: fused correction + row pre-twist
    y = ma.mulmod(y, jnp.asarray(tabs.t2_fused), q[:, None][0])
    # phase 3: row negacyclic NTT (= cyclic DFT thanks to the pre-twist)
    z = nttm.ntt_forward(y.reshape(r, 1, c), jnp.asarray(tabs.rp_row)[None], q)
    return z.reshape(r * c)


def naive_negacyclic_eval(a: np.ndarray, q: int, psi: int) -> np.ndarray:
    """hat a_k = sum_j a_j psi^{j(2k+1)} (object ints; small N only)."""
    n = len(a)
    out = np.empty(n, dtype=np.uint64)
    for k in range(n):
        base = pow(psi, 2 * k + 1, q)
        acc, p = 0, 1
        for j in range(n):
            acc = (acc + int(a[j]) * p) % q
            p = p * base % q
        out[k] = acc
    return out
