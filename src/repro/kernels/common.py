"""u32-only modular arithmetic primitives shared by the Pallas TPU kernels.

TPU vector lanes are 32-bit: there is no u64 datapath. A 32x32->64 product
is composed from four 16x16->32 partial products — the same
"compose wide multiply from narrow hardware" move as FHEmem's digit-serial
NMU (DESIGN.md §2). All helpers below use ONLY u32 ops so they lower to
TPU Pallas; in interpret mode they run exactly on CPU too.

Moduli are < 2^31 (word32 RNS mode). Montgomery radix R = 2^32.
"""
from __future__ import annotations

import jax.numpy as jnp

U32 = jnp.uint32
MASK16 = 0xFFFF  # python int: avoids captured-constant arrays in Pallas kernels

# ---------------------------------------------------------------------------
# dispatch accounting
# ---------------------------------------------------------------------------
# Every host-side kernel launch (one `pl.pallas_call` invocation, or one
# jitted library dispatch standing in for a kernel on the staged route)
# records itself here. This is the currency of the fig14 fused-vs-staged
# comparison: HE-PIM/MemFHE-style dispatch-granularity overhead is about
# how many times the host touches the device per keyswitch, so we count
# launches at the Python wrapper layer — code already captured inside an
# enclosing jit trace records at trace time only, which is exactly the
# steady-state launch count.

_dispatch_count = 0


def record_dispatch(n: int = 1) -> None:
    global _dispatch_count
    _dispatch_count += n


def dispatch_count() -> int:
    return _dispatch_count


def reset_dispatch_count() -> None:
    global _dispatch_count
    _dispatch_count = 0


def mul32_wide(a, b):
    """Full 64-bit product of u32 inputs as (hi32, lo32), u32-only ops."""
    a_lo = a & MASK16
    a_hi = a >> 16
    b_lo = b & MASK16
    b_hi = b >> 16
    ll = a_lo * b_lo
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi
    mid = (ll >> 16) + (lh & MASK16) + (hl & MASK16)     # < 3*2^16
    lo = (mid << 16) | (ll & MASK16)
    hi = hh + (lh >> 16) + (hl >> 16) + (mid >> 16)
    return hi, lo


def mont_mul32(a, b, q, qinv_neg):
    """Montgomery product a*b*R^-1 mod q (R=2^32, q<2^31 odd).

    Inputs reduced (< q). qinv_neg = -q^{-1} mod 2^32. Result < q.
    """
    hi, lo = mul32_wide(a, b)
    m = lo * qinv_neg                      # mod 2^32 (native u32 wrap)
    mq_hi, mq_lo = mul32_wide(m, q)
    # lo + mq_lo == 0 mod 2^32 by construction; carry unless both are 0
    carry = (lo != 0).astype(U32)
    t = hi + mq_hi + carry
    return jnp.where(t >= q, t - q, t)


def addmod32(a, b, q):
    r = a + b                              # < 2^32 since a,b < q < 2^31
    return jnp.where(r >= q, r - q, r)


def submod32(a, b, q):
    return jnp.where(a >= b, a - b, a + (q - b))


def to_mont32(a, q, qinv_neg, r2):
    """a -> a*R mod q given r2 = R^2 mod q."""
    return mont_mul32(a, r2, q, qinv_neg)


def from_mont32(a, q, qinv_neg):
    """a*R^-1 mod q (multiply by 1 in Montgomery space)."""
    hi = jnp.zeros_like(a)
    m = a * qinv_neg
    mq_hi, _ = mul32_wide(m, q)
    carry = (a != 0).astype(U32)
    t = hi + mq_hi + carry
    return jnp.where(t >= q, t - q, t)
