"""Pallas TPU kernels for the four-step negacyclic NTT — FHEmem's
three-phase NTT (§IV-C) mapped to VMEM tiles (DESIGN.md §2).

Phase 1 (vertical / "inter-mat"): column negacyclic NTTs. Each program
holds an (R, block_c) tile in VMEM; butterflies run along the sublane axis
with per-stage twiddles broadcast across columns (twiddle index depends
only on the row — exactly why FHEmem can drive all mats of a subarray with
one control word).

Phases 2+3 (twiddle correction + horizontal / "intra-mat"): fused kernel.
Each program holds a (block_r, C) tile, applies the fused elementwise
correction table (correction x row pre-twist, precomputed in Montgomery
form), transposes in-register, runs the C-point stages, transposes back.

All arithmetic is u32 Montgomery (kernels/common.py). Twiddle tables are
pre-converted to Montgomery form host-side, so every in-kernel multiply is
a single REDC — the "on-the-fly twiddle" trade-off the paper makes
(§IV-A.3) becomes precompute-vs-bandwidth here and is measured in
benchmarks/fig14_kernels.py.
"""
from __future__ import annotations


import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import addmod32, mont_mul32, submod32
from repro.kernels.ref import FourStepTables

U32 = jnp.uint32


def _ct_stages_axis0(x, rp_mont, q, qi):
    """Harvey CT butterflies along axis 0 of x (R, B); rp_mont (R,)."""
    r = x.shape[0]
    b = x.shape[1]
    m = 1
    while m < r:
        t = r // (2 * m)
        xr = x.reshape(m, 2 * t, b)
        w = rp_mont[m:2 * m]                       # (m,)
        u = xr[:, :t]
        v = mont_mul32(xr[:, t:], w[:, None, None], q, qi)
        x = jnp.concatenate([addmod32(u, v, q), submod32(u, v, q)],
                            axis=1).reshape(r, b)
        m *= 2
    return x


def _ntt_col_kernel(x_ref, rp_ref, q_ref, qi_ref, o_ref):
    """x (R, block_c); rp_mont (1, R); scalars (1,1)."""
    q = q_ref[0, 0]
    qi = qi_ref[0, 0]
    o_ref[...] = _ct_stages_axis0(x_ref[...], rp_ref[0, :], q, qi)


def _ntt_row_kernel(x_ref, t2_ref, rp_ref, q_ref, qi_ref, o_ref):
    """x (block_r, C); t2_mont (block_r, C); rp_mont (1, C)."""
    q = q_ref[0, 0]
    qi = qi_ref[0, 0]
    x = mont_mul32(x_ref[...], t2_ref[...], q, qi)   # phase 2 (fused)
    xt = x.T                                          # (C, block_r)
    xt = _ct_stages_axis0(xt, rp_ref[0, :], q, qi)
    o_ref[...] = xt.T


class FourStepKernelTables:
    """Montgomery-form device tables derived from ref.FourStepTables."""

    def __init__(self, tabs: FourStepTables):
        self.tabs = tabs
        q = tabs.q
        r_mont = (1 << 32) % q

        def to_mont(arr):
            return ((arr.astype(object) * r_mont) % q).astype(np.uint32)

        self.q32 = jnp.asarray(np.array([q], dtype=np.uint32))
        qinv = (-pow(q, -1, 1 << 32)) % (1 << 32)
        self.qinv32 = jnp.asarray(np.array([qinv], dtype=np.uint32))
        self.rp_col_m = jnp.asarray(to_mont(tabs.rp_col))[None, :]
        self.rp_row_m = jnp.asarray(to_mont(tabs.rp_row))[None, :]
        self.t2_m = jnp.asarray(to_mont(tabs.t2_fused))


def ntt_four_step_pallas(a, kt: FourStepKernelTables, *,
                         block_c: int = 128, block_r: int = 8,
                         interpret: bool = True):
    """a: (N,) u32 coefficients -> (N,) u32 in kernel order (see ref)."""
    tabs = kt.tabs
    r, c = tabs.r, tabs.c
    x = a.reshape(r, c)
    block_c = min(block_c, c)
    block_r = min(block_r, r)
    # `dim // block` grids silently drop the tail tile on non-divisible
    # blocks (trailing outputs would come back as zeros). r and c are
    # powers of two, so any power-of-two block divides — reject the rest.
    if c % block_c or r % block_r:
        raise ValueError(
            f"four-step NTT blocks must divide the (R, C)=({r}, {c}) tile "
            f"grid; got block_r={block_r}, block_c={block_c}")
    # phase 1: columns
    y = pl.pallas_call(
        _ntt_col_kernel,
        grid=(c // block_c,),
        in_specs=[pl.BlockSpec((r, block_c), lambda j: (0, j)),
                  pl.BlockSpec((1, r), lambda j: (0, 0)),
                  pl.BlockSpec((1, 1), lambda j: (0, 0)),
                  pl.BlockSpec((1, 1), lambda j: (0, 0))],
        out_specs=pl.BlockSpec((r, block_c), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), U32),
        interpret=interpret,
    )(x, kt.rp_col_m, kt.q32[:, None], kt.qinv32[:, None])
    # phases 2+3: correction + rows
    z = pl.pallas_call(
        _ntt_row_kernel,
        grid=(r // block_r,),
        in_specs=[pl.BlockSpec((block_r, c), lambda i: (i, 0)),
                  pl.BlockSpec((block_r, c), lambda i: (i, 0)),
                  pl.BlockSpec((1, c), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_r, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, c), U32),
        interpret=interpret,
    )(y, kt.t2_m, kt.rp_row_m, kt.q32[:, None], kt.qinv32[:, None])
    return z.reshape(-1)
