from repro.data.pipeline import SyntheticLMDataset, shard_batch  # noqa: F401
