"""Data pipeline: deterministic synthetic LM token streams (replay-exact
for failure recovery — batch contents are a pure function of the step
index) plus host->device sharding helpers.

A real deployment swaps `SyntheticLMDataset` for a tokenized shard reader
with the same `batch_at(step)` contract; everything downstream (train loop,
fault supervisor replay, dry-run specs) only depends on that contract.
"""
from __future__ import annotations

from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


class SyntheticLMDataset:
    """Markov-ish synthetic tokens with per-step determinism."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int,
                 seed: int = 1234):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed + step)
        cfg = self.cfg
        # zipfian-ish marginals so losses move like real text
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        tokens_full = (z % cfg.vocab).astype(np.int32)
        out = {"tokens": tokens_full[:, :-1],
               "labels": tokens_full[:, 1:]}
        if cfg.xattn_period:
            out["images"] = rng.normal(
                0, 1, (self.batch, cfg.n_img_tokens, cfg.d_model)
            ).astype(np.float32)
        if cfg.enc_dec:
            out["frames"] = rng.normal(
                0, 1, (self.batch, self.seq, cfg.d_model)).astype(np.float32)
        return out


def shard_batch(batch: Dict[str, np.ndarray], mesh: Mesh,
                dtype=jnp.bfloat16):
    """Host batch -> device arrays sharded over the data-parallel axes."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def put(x):
        arr = jnp.asarray(x) if x.dtype.kind in "iu" else jnp.asarray(x, dtype)
        spec = P(dp, *([None] * (arr.ndim - 1)))
        return jax.device_put(arr, NamedSharding(mesh, spec))

    return {k: put(v) for k, v in batch.items()}
