"""granite-3-8b [dense] — GQA. [hf:ibm-granite/granite-3.0]
40L d_model=4096 32H kv=8 d_ff=12800 vocab=49155. Tied embeddings."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, d_ff=12800, vocab=49155,
    n_heads=32, n_kv_heads=8, head_dim=128,
    attention="gqa", tie_embeddings=True, rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="granite-smoke", family="dense",
    n_layers=3, d_model=64, d_ff=128, vocab=512,
    n_heads=4, n_kv_heads=2, head_dim=16,
    attention="gqa", tie_embeddings=True,
)
