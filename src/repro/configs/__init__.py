"""Config registry: one module per assigned architecture.

Each module exports ARCH (the exact published config) and SMOKE (a reduced
same-family config for CPU tests). `get_config(name, smoke=...)` resolves
by arch id.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "deepseek_v3_671b",
    "arctic_480b",
    "llama_3_2_vision_90b",
    "seamless_m4t_large_v2",
    "qwen3_8b",
    "granite_3_8b",
    "codeqwen1_5_7b",
    "mistral_nemo_12b",
    "rwkv6_3b",
    "recurrentgemma_2b",
]

# canonical dashed ids from the assignment
DASHED = {i.replace("_", "-"): i for i in ARCH_IDS}
DASHED.update({
    "deepseek-v3-671b": "deepseek_v3_671b",
    "arctic-480b": "arctic_480b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen3-8b": "qwen3_8b",
    "granite-3-8b": "granite_3_8b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "rwkv6-3b": "rwkv6_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
})


def get_config(name: str, smoke: bool = False):
    mod_name = DASHED.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.ARCH


def list_archs():
    return list(ARCH_IDS)
