"""recurrentgemma-2b (Griffin) [hybrid] — RG-LRU + local attention, 1:2.
[arXiv:2402.19427] 26L d_model=2560 10H kv=1(MQA) d_ff=7680 vocab=256000."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, d_ff=7680, vocab=256000,
    n_heads=10, n_kv_heads=1, head_dim=256,
    attention="local", local_window=2048,
    rglru=True, block_pattern=("rglru", "rglru", "attn"),
    lru_width=2560, conv_width=4, tie_embeddings=True,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="recurrentgemma-smoke", family="hybrid",
    n_layers=4, d_model=64, d_ff=128, vocab=512,
    n_heads=2, n_kv_heads=1, head_dim=32,
    attention="local", local_window=32,
    rglru=True, block_pattern=("rglru", "rglru", "attn"),
    lru_width=64, conv_width=4, tie_embeddings=True,
)
