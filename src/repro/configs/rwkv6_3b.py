"""rwkv6-3b (Finch) [ssm] — attention-free, data-dependent decay.
[arXiv:2404.05892] 32L d_model=2560 d_ff=8960 vocab=65536."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, d_ff=8960, vocab=65536,
    attention="none", rwkv=True, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=128, d_ff=256, vocab=512,
    attention="none", rwkv=True, tie_embeddings=True,
)
