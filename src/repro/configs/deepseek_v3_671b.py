"""deepseek-v3-671b [moe] — MLA + 1 shared/256 routed top-8 MoE + MTP.
[arXiv:2412.19437; hf] 61L d_model=7168 128H d_ff(expert)=2048 vocab=129280."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, d_ff=18432, vocab=129280,
    n_heads=128, n_kv_heads=128, head_dim=128,
    attention="mla",
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    n_experts=256, top_k=8, d_ff_expert=2048, n_shared_experts=1,
    first_k_dense=3, mtp=True,
)

SMOKE = ArchConfig(
    name="deepseek-v3-smoke", family="moe",
    n_layers=4, d_model=64, d_ff=128, vocab=512,
    n_heads=4, n_kv_heads=4, head_dim=16,
    attention="mla",
    q_lora_rank=32, kv_lora_rank=16,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    n_experts=8, top_k=2, d_ff_expert=32, n_shared_experts=1,
    first_k_dense=1, mtp=True,
)
