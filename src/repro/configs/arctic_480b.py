"""arctic-480b [moe] — 128 experts top-2 + dense residual branch.
[hf:Snowflake/snowflake-arctic-base] 35L d_model=7168 56H kv=8 d_ff=4864 vocab=32000."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, d_ff=4864, vocab=32000,
    n_heads=56, n_kv_heads=8, head_dim=128,
    attention="gqa",
    n_experts=128, top_k=2, d_ff_expert=4864, dense_residual=True,
)

SMOKE = ArchConfig(
    name="arctic-smoke", family="moe",
    n_layers=3, d_model=64, d_ff=96, vocab=512,
    n_heads=4, n_kv_heads=2, head_dim=16,
    attention="gqa",
    n_experts=8, top_k=2, d_ff_expert=96, dense_residual=True,
)
