"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision] 100L d_model=8192 64H kv=8 d_ff=28672 vocab=128256.
Vision frontend is a STUB: input_specs provides precomputed patch embeddings."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, d_ff=28672, vocab=128256,
    n_heads=64, n_kv_heads=8, head_dim=128,
    attention="gqa", xattn_period=4, n_img_tokens=1601,
    rope_theta=5e5,
)

SMOKE = ArchConfig(
    name="llama-vision-smoke", family="vlm",
    n_layers=5, d_model=64, d_ff=128, vocab=512,
    n_heads=4, n_kv_heads=2, head_dim=16,
    attention="gqa", xattn_period=4, n_img_tokens=16,
)
