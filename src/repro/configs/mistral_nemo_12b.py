"""mistral-nemo-12b [dense] — GQA, 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407]
40L d_model=5120 32H kv=8 d_ff=14336 vocab=131072, head_dim=128."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, d_ff=14336, vocab=131072,
    n_heads=32, n_kv_heads=8, head_dim=128,
    attention="gqa", rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="nemo-smoke", family="dense",
    n_layers=3, d_model=64, d_ff=128, vocab=512,
    n_heads=4, n_kv_heads=2, head_dim=16,
    attention="gqa",
)
