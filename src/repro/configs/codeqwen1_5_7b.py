"""codeqwen1.5-7b [dense] — qwen1.5 arch, full MHA (kv=32).
[hf:Qwen/CodeQwen1.5-7B] 32L d_model=4096 32H kv=32 d_ff=13440 vocab=92416."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, d_ff=13440, vocab=92416,
    n_heads=32, n_kv_heads=32, head_dim=128,
    attention="gqa", rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="codeqwen-smoke", family="dense",
    n_layers=3, d_model=64, d_ff=128, vocab=512,
    n_heads=4, n_kv_heads=4, head_dim=16,
    attention="gqa",
)
