"""qwen3-8b [dense] — GQA with qk_norm. [hf:Qwen/Qwen3-8B]
36L d_model=4096 32H kv=8 d_ff=12288 vocab=151936."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, d_ff=12288, vocab=151936,
    n_heads=32, n_kv_heads=8, head_dim=128,
    attention="gqa", qk_norm=True, rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="qwen3-smoke", family="dense",
    n_layers=3, d_model=64, d_ff=128, vocab=512,
    n_heads=4, n_kv_heads=2, head_dim=16,
    attention="gqa", qk_norm=True,
)
