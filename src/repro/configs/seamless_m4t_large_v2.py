"""seamless-m4t-large-v2 [audio] — enc-dec multimodal backbone.
[arXiv:2308.11596] 24L d_model=1024 16H d_ff=8192 vocab=256206.
Audio frontend is a STUB: input_specs provides precomputed frame embeddings."""
from repro.models.config import ArchConfig

ARCH = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, d_ff=8192, vocab=256206,
    n_heads=16, n_kv_heads=16, head_dim=64,
    attention="gqa", enc_dec=True, n_enc_layers=24,
)

SMOKE = ArchConfig(
    name="seamless-smoke", family="audio",
    n_layers=2, d_model=64, d_ff=128, vocab=512,
    n_heads=4, n_kv_heads=4, head_dim=16,
    attention="gqa", enc_dec=True, n_enc_layers=2,
)
