"""Capacity-aware LRU cache for evk / rotation keys / plaintext constants.

The paper's load-save insight (§IV-F) is that constant movement, not
compute, bounds sustained throughput: a pipeline stage whose constants
are already resident costs nothing to "load" for the next batch. The
mapper's ``const_bytes`` accounting (core/trace.py OpCost) already sizes
each stage's resident set, so cache entries are keyed per
``(workload, stage)`` and charged exactly that footprint; eviction is
LRU under a byte capacity — the serving-time mirror of a partition's
constant budget.

Entries may carry a value (device arrays for the mesh backend) or be
pure residency markers (analytic backend, where only the load-time
accounting matters).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Hashable, Optional, Tuple

from repro.runtime.metrics import MetricsRegistry


@dataclasses.dataclass
class CacheEntry:
    key: Hashable
    nbytes: int
    value: object = None
    pinned: bool = False


class KeyCache:
    """LRU over constant footprints with a hard byte capacity.

    ``get_or_load`` returns ``(value, hit, load_seconds)`` where
    ``load_seconds`` is the analytic cost of streaming the entry's bytes
    at ``load_bw`` on a miss (0.0 on a hit). An entry larger than the
    whole capacity is loaded but never retained — every use pays the
    stream, exactly the paper's reload-per-use regime.
    """

    def __init__(self, capacity_bytes: int, load_bw: float = 64e9,
                 metrics: Optional[MetricsRegistry] = None):
        assert capacity_bytes >= 0
        self.capacity_bytes = capacity_bytes
        self.load_bw = load_bw
        self.metrics = metrics or MetricsRegistry()
        self._entries: "OrderedDict[Hashable, CacheEntry]" = OrderedDict()
        self.used_bytes = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def load_seconds(self, nbytes: int) -> float:
        return nbytes / self.load_bw if self.load_bw > 0 else 0.0

    # -- core ----------------------------------------------------------------

    def get_or_load(self, key: Hashable, nbytes: int,
                    loader: Optional[Callable[[], object]] = None,
                    pin: bool = False) -> Tuple[object, bool, float]:
        if key in self._entries:
            e = self._entries[key]
            self._entries.move_to_end(key)
            self.metrics.incr("keycache_hits")
            self.metrics.incr("keycache_hit_bytes", e.nbytes)
            return e.value, True, 0.0

        self.metrics.incr("keycache_misses")
        self.metrics.incr("keycache_loaded_bytes", nbytes)
        value = loader() if loader is not None else None
        if nbytes <= self.capacity_bytes:
            self._evict_to(self.capacity_bytes - nbytes)
            self._entries[key] = CacheEntry(key, nbytes, value, pinned=pin)
            self.used_bytes += nbytes
        else:
            self.metrics.incr("keycache_uncacheable")
        return value, False, self.load_seconds(nbytes)

    def _evict_to(self, target_bytes: int) -> None:
        while self.used_bytes > target_bytes:
            victim_key = None
            for k, e in self._entries.items():        # LRU order
                if not e.pinned:
                    victim_key = k
                    break
            if victim_key is None:
                raise RuntimeError(
                    "keycache: pinned entries exceed capacity "
                    f"({self.used_bytes}B used, want <= {target_bytes}B)")
            e = self._entries.pop(victim_key)
            self.used_bytes -= e.nbytes
            self.metrics.incr("keycache_evictions")

    # -- management ----------------------------------------------------------

    def invalidate(self, key: Hashable) -> bool:
        """Drop one entry (e.g. tenant key rotation). Returns found."""
        e = self._entries.pop(key, None)
        if e is None:
            return False
        self.used_bytes -= e.nbytes
        self.metrics.incr("keycache_invalidations")
        return True

    def has_prefix(self, prefix: Tuple) -> bool:
        """Any resident entry whose tuple-key starts with ``prefix``?
        (The fleet router's cache-affinity warmth probe — e.g.
        ``has_prefix((workload,))`` asks whether any of a workload's
        stage constants survived eviction.)"""
        return any(isinstance(k, tuple) and k[:len(prefix)] == prefix
                   for k in self._entries)

    def invalidate_prefix(self, prefix: Tuple) -> int:
        """Drop every entry whose tuple-key starts with ``prefix``
        (e.g. all stages of one workload). Returns count dropped."""
        victims = [k for k in self._entries
                   if isinstance(k, tuple) and k[:len(prefix)] == prefix]
        for k in victims:
            self.invalidate(k)
        return len(victims)

    def clear(self) -> None:
        self._entries.clear()
        self.used_bytes = 0
