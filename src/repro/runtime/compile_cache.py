"""Trace → PipelineSchedule memoization.

Mapping a trace (stage splitting + placement, core/pipeline.py) is pure
in (trace structure, CKKS params, memory model, mapper policy), so a
serving runtime should pay it once per distinct workload, not per
batch. Keys are structural fingerprints — two traces of the same
program text captured separately hash identically, so tenants sharing a
model share one compiled schedule.
"""
from __future__ import annotations

import hashlib
import time
from typing import Callable, Dict, Optional, Tuple

from repro.compiler import PassConfig, optimize_trace
from repro.core.params import CkksParams
from repro.core.pipeline import (MemoryModel, PipelineSchedule,
                                 generate_load_save_pipeline)
from repro.core.trace import FheTrace
from repro.runtime.metrics import MetricsRegistry


def trace_fingerprint(trace: FheTrace) -> str:
    """Structural hash: op kinds, dataflow edges, meta, inferred levels.

    Index-based (SSA indices are deterministic given program structure),
    so identical programs traced twice collide — by design.
    """
    h = hashlib.sha256()
    for op in trace.ops:
        meta = tuple(sorted((k, repr(v)) for k, v in op.meta.items()))
        h.update(repr((op.idx, op.kind, op.args, meta, op.level)).encode())
    h.update(repr((tuple(trace.inputs), tuple(trace.outputs),
                   tuple(trace.consts))).encode())
    return h.hexdigest()


def _params_key(params: CkksParams) -> Tuple:
    return (params.log_n, params.log_scale, params.n_levels, params.dnum,
            params.first_mod_bits, params.scale_mod_bits,
            params.special_mod_bits)


def _mem_key(mem: MemoryModel) -> Tuple:
    return (mem.n_partitions, mem.partition_bytes, mem.load_bw,
            mem.modmul_throughput, mem.ntt_row_cost, mem.transfer_bw,
            mem.ks_modmul_weight)


class CompileCache:
    """Unbounded memo of compiled schedules (schedules are small — op
    lists plus floats — and the workload universe is the registry, not
    the request stream, so no eviction policy is needed)."""

    def __init__(self, metrics: Optional[MetricsRegistry] = None,
                 verify: bool = False):
        """``verify=True`` arms verify-on-miss: every freshly compiled
        schedule is swept by the static verifier (repro.analysis) —
        per-pass when the optimizer runs, then trace + schedule — and
        an error finding raises `VerificationError` instead of caching
        a corrupt schedule. Hits skip verification (the artifact in the
        cache already passed)."""
        self.metrics = metrics or MetricsRegistry()
        self.verify = verify
        self._cache: Dict[Tuple, PipelineSchedule] = {}

    def __len__(self) -> int:
        return len(self._cache)

    def _verify_miss(self, sched: PipelineSchedule, trace: FheTrace,
                     params: CkksParams,
                     pass_config: Optional[PassConfig],
                     pass_report) -> None:
        """Static verification of a freshly compiled schedule. When the
        optimizer ran, the final trace already passed its full-budget
        sweep inside `optimize_trace(verify=True)` — only the schedule
        invariants remain; verbatim-serving misses verify both."""
        from repro.analysis.findings import VerificationError
        from repro.analysis.verify_ir import resolve_start_level
        from repro.analysis.verify_schedule import verify_schedule
        if pass_config is not None:
            start = pass_config.resolve_start_level(trace, params)
            boot_to = pass_config.bootstrap_to
        else:
            start = resolve_start_level(trace, None)
            boot_to = None
        rep = verify_schedule(sched, start_level=start,
                              bootstrap_to=boot_to,
                              include_trace=pass_config is None)
        wall = rep.wall_s + (pass_report.verify_wall_s
                             if pass_report is not None else 0.0)
        found = len(rep.findings) + (pass_report.verify_findings
                                     if pass_report is not None else 0)
        sched.verify_report = rep
        sched._verify_wall_s = wall
        self.metrics.incr("verify_findings", by=found)
        self.metrics.incr("verify_errors", by=len(rep.errors))
        if not rep.ok:
            raise VerificationError(rep, context="compile verify")

    def get_schedule(self, trace: FheTrace, params: CkksParams,
                     mem: MemoryModel,
                     mapper: Callable[..., PipelineSchedule]
                     = generate_load_save_pipeline,
                     pass_config: Optional[PassConfig] = None,
                     obs=None, **mapper_kwargs) -> PipelineSchedule:
        """Optionally run the optimizing compiler (repro.compiler) on the
        trace before mapping. `pass_config` participates in the cache
        key, so opt and no-opt schedules of one workload — or two
        different pass selections — never collide.

        ``obs`` is an optional `repro.obs.ExecObs` (an explicit kwarg —
        it must never leak into ``mapper_kwargs``, which participate in
        the cache key): with it, a ``compile`` span lands under the
        caller's batch span — zero duration on the serving timeline
        (compilation never advances the virtual clock; service time
        starts at backend.execute) but carrying the measured wall
        seconds, hit/miss, and on a miss one child span per compiler
        pass from the attached PassReport."""
        key = (trace_fingerprint(trace), _params_key(params), _mem_key(mem),
               getattr(mapper, "__name__", repr(mapper)),
               pass_config.key() if pass_config is not None else None,
               tuple(sorted(mapper_kwargs.items())))
        hit = key in self._cache
        if hit:
            self.metrics.incr("compile_hits")
        else:
            self.metrics.incr("compile_misses")
            t0 = time.perf_counter()
            report = None
            if pass_config is not None:
                trace, report = optimize_trace(trace, params, pass_config,
                                               verify=self.verify)
                self.metrics.incr("traces_optimized")
            sched = mapper(trace, params, mem, **mapper_kwargs)
            sched.pass_report = report
            if self.verify:
                self._verify_miss(sched, trace, params, pass_config,
                                  report)
            sched._compile_wall_s = time.perf_counter() - t0
            self._cache[key] = sched
        sched = self._cache[key]
        if obs is not None and obs.tracer is not None:
            c = obs.tracer.instant(
                "compile", obs.t0, parent=obs.parent, track=obs.track,
                hit=hit, wall_s=0.0 if hit
                else getattr(sched, "_compile_wall_s", 0.0),
                n_stages=len(sched.stages),
                verify_wall_s=0.0 if hit
                else getattr(sched, "_verify_wall_s", 0.0),
                verify_findings=0 if hit else (
                    len(getattr(sched, "verify_report").findings)
                    if getattr(sched, "verify_report", None) is not None
                    else 0))
            if not hit and sched.pass_report is not None:
                for s in sched.pass_report.passes:
                    obs.tracer.instant(
                        "pass:" + s.name, obs.t0, parent=c,
                        track=obs.track, wall_s=s.wall_s,
                        applied=s.applied, reverted=s.reverted,
                        ops_before=s.n_ops_before, ops_after=s.n_ops_after)
        return sched
