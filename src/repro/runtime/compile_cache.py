"""Trace → PipelineSchedule memoization.

Mapping a trace (stage splitting + placement, core/pipeline.py) is pure
in (trace structure, CKKS params, memory model, mapper policy), so a
serving runtime should pay it once per distinct workload, not per
batch. Keys are structural fingerprints — two traces of the same
program text captured separately hash identically, so tenants sharing a
model share one compiled schedule.
"""
from __future__ import annotations

import hashlib
from typing import Callable, Dict, Optional, Tuple

from repro.compiler import PassConfig, optimize_trace
from repro.core.params import CkksParams
from repro.core.pipeline import (MemoryModel, PipelineSchedule,
                                 generate_load_save_pipeline)
from repro.core.trace import FheTrace
from repro.runtime.metrics import MetricsRegistry


def trace_fingerprint(trace: FheTrace) -> str:
    """Structural hash: op kinds, dataflow edges, meta, inferred levels.

    Index-based (SSA indices are deterministic given program structure),
    so identical programs traced twice collide — by design.
    """
    h = hashlib.sha256()
    for op in trace.ops:
        meta = tuple(sorted((k, repr(v)) for k, v in op.meta.items()))
        h.update(repr((op.idx, op.kind, op.args, meta, op.level)).encode())
    h.update(repr((tuple(trace.inputs), tuple(trace.outputs),
                   tuple(trace.consts))).encode())
    return h.hexdigest()


def _params_key(params: CkksParams) -> Tuple:
    return (params.log_n, params.log_scale, params.n_levels, params.dnum,
            params.first_mod_bits, params.scale_mod_bits,
            params.special_mod_bits)


def _mem_key(mem: MemoryModel) -> Tuple:
    return (mem.n_partitions, mem.partition_bytes, mem.load_bw,
            mem.modmul_throughput, mem.ntt_row_cost, mem.transfer_bw,
            mem.ks_modmul_weight)


class CompileCache:
    """Unbounded memo of compiled schedules (schedules are small — op
    lists plus floats — and the workload universe is the registry, not
    the request stream, so no eviction policy is needed)."""

    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics or MetricsRegistry()
        self._cache: Dict[Tuple, PipelineSchedule] = {}

    def __len__(self) -> int:
        return len(self._cache)

    def get_schedule(self, trace: FheTrace, params: CkksParams,
                     mem: MemoryModel,
                     mapper: Callable[..., PipelineSchedule]
                     = generate_load_save_pipeline,
                     pass_config: Optional[PassConfig] = None,
                     **mapper_kwargs) -> PipelineSchedule:
        """Optionally run the optimizing compiler (repro.compiler) on the
        trace before mapping. `pass_config` participates in the cache
        key, so opt and no-opt schedules of one workload — or two
        different pass selections — never collide."""
        key = (trace_fingerprint(trace), _params_key(params), _mem_key(mem),
               getattr(mapper, "__name__", repr(mapper)),
               pass_config.key() if pass_config is not None else None,
               tuple(sorted(mapper_kwargs.items())))
        hit = key in self._cache
        if hit:
            self.metrics.incr("compile_hits")
        else:
            self.metrics.incr("compile_misses")
            if pass_config is not None:
                trace, _report = optimize_trace(trace, params, pass_config)
                self.metrics.incr("traces_optimized")
            self._cache[key] = mapper(trace, params, mem, **mapper_kwargs)
        return self._cache[key]
