"""Multi-tenant FHE serving runtime (request queue → slot batcher →
key cache → pipelined executor).

FHEmem's end-to-end flow (§IV-F) keeps constants (evk, rotation keys,
plaintext weights) resident while batches of encrypted inputs stream
through pipeline rounds — exactly the economics of a serving system,
where key/constant movement, not compute, dominates sustained
throughput. This package turns the offline pieces (core/trace.py,
core/pipeline.py, fhe_dist/pipeline_exec.py) into an online runtime:

* ``queue``         admission control + per-tenant request queues with
                    deadlines
* ``batcher``       packs pending requests into CKKS slot groups and the
                    load-save pipeline's input-batch dimension
                    (max-wait / max-batch policy)
* ``keycache``      capacity-aware LRU over evk / rotation-key /
                    plaintext-constant footprints, keyed by the mapper's
                    ``const_bytes`` accounting
* ``compile_cache`` trace → PipelineSchedule memoization
* ``executor``      round-based engine draining the batcher through the
                    analytic MemoryModel backend, the real pipeline_exec
                    mesh backend, or the real-CKKS ciphertext backend
* ``ciphertext_backend``  batched encrypted execution of compiled
                    schedules with per-workload decrypt-accuracy
                    metrics (DESIGN.md §9)
* ``metrics``       p50/p99 latency, throughput, cache hit rate,
                    partition occupancy

Entry point: ``python -m repro.launch.serve_fhe --smoke``.
"""
from repro.runtime.queue import AdmissionQueue, Request, RequestStatus
from repro.runtime.batcher import Batch, BatchPolicy, SlotBatcher
from repro.runtime.keycache import KeyCache
from repro.runtime.compile_cache import CompileCache, trace_fingerprint
from repro.runtime.ciphertext_backend import CiphertextBackend
from repro.runtime.executor import (AnalyticBackend, MeshBackend,
                                    PipelinedExecutor, Workload,
                                    resolve_backend)
from repro.runtime.metrics import LatencyStats, MetricsRegistry

__all__ = [
    "AdmissionQueue", "Request", "RequestStatus",
    "Batch", "BatchPolicy", "SlotBatcher",
    "KeyCache", "CompileCache", "trace_fingerprint",
    "AnalyticBackend", "CiphertextBackend", "MeshBackend",
    "PipelinedExecutor", "Workload", "resolve_backend",
    "LatencyStats", "MetricsRegistry",
]
