"""Serving metrics: latency percentiles, throughput, cache hit rates,
partition occupancy.

Pure-python accumulators (no jax) so they work identically under the
analytic (virtual-clock) and mesh (wall-clock) backends.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional


class LatencyStats:
    """Streaming latency accumulator with exact percentiles.

    Samples are kept sorted (bisect insert) — serving smoke tests and
    benchmarks see 1e2..1e5 samples, where O(n) insertion is fine and
    exactness beats a sketch.
    """

    def __init__(self, name: str = "latency"):
        self.name = name
        self._sorted: List[float] = []
        self._sum = 0.0

    def observe(self, seconds: float) -> None:
        bisect.insort(self._sorted, seconds)
        self._sum += seconds

    @property
    def count(self) -> int:
        return len(self._sorted)

    @property
    def mean(self) -> float:
        return self._sum / len(self._sorted) if self._sorted else 0.0

    def percentile(self, p: float) -> float:
        """Exact p-th percentile (0 <= p <= 100), nearest-rank."""
        if not self._sorted:
            return 0.0
        k = min(len(self._sorted) - 1,
                max(0, int(round(p / 100.0 * (len(self._sorted) - 1)))))
        return self._sorted[k]

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    @property
    def max(self) -> float:
        return self._sorted[-1] if self._sorted else 0.0

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "mean_s": self.mean,
                "p50_s": self.p50, "p95_s": self.p95, "p99_s": self.p99,
                "max_s": self.max}


@dataclasses.dataclass
class PartitionOccupancy:
    """Busy-seconds per partition vs elapsed time — how evenly the
    round-robin placement loads the banks/device-groups."""
    n_partitions: int
    busy_s: List[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.busy_s:
            self.busy_s = [0.0] * self.n_partitions

    def add(self, partition: int, seconds: float) -> None:
        self.busy_s[partition % self.n_partitions] += seconds

    def occupancy(self, elapsed_s: float) -> List[float]:
        if elapsed_s <= 0:
            return [0.0] * self.n_partitions
        return [min(1.0, b / elapsed_s) for b in self.busy_s]

    def mean_occupancy(self, elapsed_s: float) -> float:
        occ = self.occupancy(elapsed_s)
        return sum(occ) / len(occ) if occ else 0.0


class MetricsRegistry:
    """One object threaded through queue/batcher/keycache/executor."""

    def __init__(self, n_partitions: int = 1):
        self.request_latency = LatencyStats("request_latency")
        self.queue_wait = LatencyStats("queue_wait")
        self.batch_service = LatencyStats("batch_service")
        self.occupancy = PartitionOccupancy(n_partitions)
        self.counters: Dict[str, int] = {}
        # decrypt-side accuracy per workload (ciphertext backend):
        # max |decoded - reference| over every slot of every batch served
        self.decrypt_error: Dict[str, float] = {}
        self.elapsed_s = 0.0

    def observe_decrypt_error(self, workload: str, err: float) -> None:
        prev = self.decrypt_error.get(workload, 0.0)
        self.decrypt_error[workload] = max(prev, float(err))
        self.incr("accuracy_batches_checked")

    def incr(self, name: str, by: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + by

    def count(self, name: str) -> int:
        return self.counters.get(name, 0)

    def hit_rate(self, prefix: str) -> float:
        """hits / (hits + misses) for counters ``{prefix}_hits`` and
        ``{prefix}_misses``."""
        h, m = self.count(f"{prefix}_hits"), self.count(f"{prefix}_misses")
        return h / (h + m) if h + m else 0.0

    def throughput_rps(self) -> float:
        done = self.count("requests_completed")
        return done / self.elapsed_s if self.elapsed_s > 0 else 0.0

    def summary(self) -> Dict[str, object]:
        return {
            "elapsed_s": self.elapsed_s,
            "throughput_rps": self.throughput_rps(),
            "latency": self.request_latency.summary(),
            "queue_wait": self.queue_wait.summary(),
            "batch_service": self.batch_service.summary(),
            "keycache_hit_rate": self.hit_rate("keycache"),
            "compile_cache_hit_rate": self.hit_rate("compile"),
            "mean_partition_occupancy":
                self.occupancy.mean_occupancy(self.elapsed_s),
            "decrypt_error": dict(sorted(self.decrypt_error.items())),
            "counters": dict(sorted(self.counters.items())),
        }

    def format_table(self) -> str:
        s = self.summary()
        lat = s["latency"]
        lines = [
            f"elapsed               {s['elapsed_s']:.3f} s",
            f"throughput            {s['throughput_rps']:.1f} req/s",
            f"latency p50/p95/p99   {lat['p50_s']*1e3:.2f} / "
            f"{lat['p95_s']*1e3:.2f} / {lat['p99_s']*1e3:.2f} ms",
            f"queue wait p50        {self.queue_wait.p50*1e3:.2f} ms",
            f"keycache hit rate     {s['keycache_hit_rate']*100:.1f} %",
            f"compile hit rate      {s['compile_cache_hit_rate']*100:.1f} %",
            f"partition occupancy   {s['mean_partition_occupancy']*100:.1f} %",
        ]
        for w, e in s["decrypt_error"].items():
            lines.append(f"max |err| {w:<11} {e:.3e}")
        for k, v in s["counters"].items():
            lines.append(f"{k:<21} {v}")
        return "\n".join(lines)
