"""Serving metrics: latency percentiles, throughput, cache hit rates,
partition occupancy.

Pure-python accumulators (no jax) so they work identically under the
analytic (virtual-clock) and mesh (wall-clock) backends.
"""
from __future__ import annotations

import dataclasses
import random
import zlib
from typing import Dict, List, Optional, Tuple


class LatencyStats:
    """Streaming latency accumulator.

    Default mode keeps every sample and reports **exact** nearest-rank
    percentiles — serving smoke tests and benchmarks see 1e2..1e5
    samples, where that is fine and exactness beats a sketch. Samples
    are appended and sorted lazily on first query (amortized O(n log n)
    total, vs the old per-observe ``bisect.insort`` which was O(n) per
    sample and O(n^2) over a long fleet sweep).

    ``reservoir=R`` bounds memory for million-request sweeps
    (fig20-scale fleets): below R samples everything is kept and
    percentiles stay exact; above, Vitter's Algorithm R keeps a
    uniform R-sample for percentiles while ``count`` / ``mean`` /
    ``max`` remain exact always. The reservoir RNG is seeded from the
    stat's name, so runs are deterministic.
    """

    def __init__(self, name: str = "latency",
                 reservoir: Optional[int] = None):
        if reservoir is not None and reservoir < 1:
            raise ValueError("reservoir size must be >= 1")
        self.name = name
        self.reservoir = reservoir
        self._samples: List[float] = []
        self._dirty = False
        self._sum = 0.0
        self._count = 0
        self._max = 0.0
        self._rng = (random.Random(zlib.crc32(name.encode()) ^ 0x5EED)
                     if reservoir is not None else None)

    def observe(self, seconds: float) -> None:
        self._count += 1
        self._sum += seconds
        if self._count == 1 or seconds > self._max:
            self._max = seconds
        if self.reservoir is None or len(self._samples) < self.reservoir:
            self._samples.append(seconds)
            self._dirty = True
        else:
            # Algorithm R: keep each of the n samples with prob R/n
            j = self._rng.randrange(self._count)
            if j < self.reservoir:
                self._samples[j] = seconds
                self._dirty = True

    def _view(self) -> List[float]:
        if self._dirty:
            self._samples.sort()
            self._dirty = False
        return self._samples

    @property
    def count(self) -> int:
        return self._count

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def percentile(self, p: float) -> float:
        """Nearest-rank p-th percentile (0 <= p <= 100) — exact while
        all samples are retained, reservoir-estimated past the bound."""
        view = self._view()
        if not view:
            return 0.0
        k = min(len(view) - 1,
                max(0, int(round(p / 100.0 * (len(view) - 1)))))
        return view[k]

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    @property
    def max(self) -> float:
        return self._max if self._count else 0.0

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "mean_s": self.mean,
                "p50_s": self.p50, "p95_s": self.p95, "p99_s": self.p99,
                "max_s": self.max}


@dataclasses.dataclass
class PartitionOccupancy:
    """Busy-seconds per partition vs elapsed time — how evenly the
    round-robin placement loads the banks/device-groups."""
    n_partitions: int
    busy_s: List[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.busy_s:
            self.busy_s = [0.0] * self.n_partitions

    def add(self, partition: int, seconds: float) -> None:
        self.busy_s[partition % self.n_partitions] += seconds

    def occupancy(self, elapsed_s: float) -> List[float]:
        if elapsed_s <= 0:
            return [0.0] * self.n_partitions
        return [min(1.0, b / elapsed_s) for b in self.busy_s]

    def mean_occupancy(self, elapsed_s: float) -> float:
        occ = self.occupancy(elapsed_s)
        return sum(occ) / len(occ) if occ else 0.0

    def utilization(self, elapsed_s: float) -> List[float]:
        """Uncapped busy/wall fraction per partition — unlike
        `occupancy` this keeps values > 1.0 visible (a partition billed
        more busy-seconds than the wall window is oversubscribed, the
        signal the capped column hides)."""
        if elapsed_s <= 0:
            return [0.0] * self.n_partitions
        return [b / elapsed_s for b in self.busy_s]

    def active_utilization(self, elapsed_s: float) -> Tuple[float, float,
                                                            int]:
        """(mean, max, n_active) of busy/wall over partitions that did
        ANY work — the table-facing normalization: averaging the idle
        tail of a 128-bank arch into the mean made the column
        meaningless across backends with different partition counts."""
        util = [u for u in self.utilization(elapsed_s) if u > 0.0]
        if not util:
            return 0.0, 0.0, 0
        return sum(util) / len(util), max(util), len(util)


class MetricsRegistry:
    """One object threaded through queue/batcher/keycache/executor —
    and, under a fleet (repro.fleet), shared by every device so the
    registry is the single fleet-wide scoreboard: per-device busy
    seconds, routing hit rate, preemption counts, and the
    queue-delay vs service-time latency decomposition all land here
    next to the single-executor metrics."""

    def __init__(self, n_partitions: int = 1,
                 latency_reservoir: Optional[int] = None):
        r = latency_reservoir
        self.request_latency = LatencyStats("request_latency", reservoir=r)
        self.queue_wait = LatencyStats("queue_wait", reservoir=r)
        # latency decomposition: request_latency = queue_delay (arrival
        # -> service start, the batcher/scheduler's share) + service
        # time (service start -> completion, the backend's share), so
        # p99 growth under load is attributable to queueing vs compute
        self.queue_delay = LatencyStats("queue_delay", reservoir=r)
        self.service_time = LatencyStats("service_time", reservoir=r)
        self.batch_service = LatencyStats("batch_service", reservoir=r)
        self.occupancy = PartitionOccupancy(n_partitions)
        self.counters: Dict[str, int] = {}
        # per-tenant counters (deadline_misses, requests_completed):
        # goodput accounting needs every miss attributed to a tenant,
        # including drops at dequeue (queue._drop_expired)
        self.tenant_counters: Dict[str, Dict[str, int]] = {}
        # fleet: busy seconds per device id (device-level occupancy,
        # as PartitionOccupancy is bank-level within one device)
        self.device_busy_s: Dict[int, float] = {}
        # decrypt-side accuracy per workload (ciphertext backend):
        # max |decoded - reference| over every slot of every batch served
        self.decrypt_error: Dict[str, float] = {}
        self.elapsed_s = 0.0
        # observability attachment points (repro.obs). None = disabled;
        # every emission site in the stack guards on these being None,
        # so an untraced run does no work beyond the attribute read —
        # the bit-for-bit metrics regression in tests/test_obs.py pins
        # that down. Deliberately NOT part of summary().
        self.tracer = None            # Optional[repro.obs.Tracer]
        self.event_log = None         # Optional[repro.obs.JsonEventLog]
        self.telemetry = None         # Optional[repro.obs.Telemetry]
        self.slo = None               # Optional[repro.obs.SloBurnRate]

    def observe_decrypt_error(self, workload: str, err: float) -> None:
        prev = self.decrypt_error.get(workload, 0.0)
        self.decrypt_error[workload] = max(prev, float(err))
        self.incr("accuracy_batches_checked")

    def incr(self, name: str, by: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + by

    def count(self, name: str) -> int:
        return self.counters.get(name, 0)

    def incr_tenant(self, name: str, tenant: str, by: int = 1) -> None:
        d = self.tenant_counters.setdefault(tenant, {})
        d[name] = d.get(name, 0) + by

    def tenant_count(self, name: str, tenant: str) -> int:
        return self.tenant_counters.get(tenant, {}).get(name, 0)

    def add_device_busy(self, device_id: int, seconds: float) -> None:
        self.device_busy_s[device_id] = \
            self.device_busy_s.get(device_id, 0.0) + seconds

    def device_occupancy(self) -> Dict[int, float]:
        """Busy fraction per fleet device over the serve window."""
        if self.elapsed_s <= 0:
            return {d: 0.0 for d in self.device_busy_s}
        return {d: min(1.0, b / self.elapsed_s)
                for d, b in sorted(self.device_busy_s.items())}

    def hit_rate(self, prefix: str) -> float:
        """hits / (hits + misses) for counters ``{prefix}_hits`` and
        ``{prefix}_misses``."""
        h, m = self.count(f"{prefix}_hits"), self.count(f"{prefix}_misses")
        return h / (h + m) if h + m else 0.0

    def throughput_rps(self) -> float:
        done = self.count("requests_completed")
        return done / self.elapsed_s if self.elapsed_s > 0 else 0.0

    def goodput_rps(self) -> float:
        """Deadline-met throughput: completions of deadline-bearing
        requests per second (a best-effort completion doesn't count —
        goodput measures SLO-attaining work, the fig20 y-axis)."""
        done = self.count("requests_goodput")
        return done / self.elapsed_s if self.elapsed_s > 0 else 0.0

    def summary(self) -> Dict[str, object]:
        return {
            "elapsed_s": self.elapsed_s,
            "throughput_rps": self.throughput_rps(),
            "goodput_rps": self.goodput_rps(),
            "latency": self.request_latency.summary(),
            "queue_wait": self.queue_wait.summary(),
            "queue_delay": self.queue_delay.summary(),
            "service_time": self.service_time.summary(),
            "batch_service": self.batch_service.summary(),
            "keycache_hit_rate": self.hit_rate("keycache"),
            "compile_cache_hit_rate": self.hit_rate("compile"),
            "routing_hit_rate": self.hit_rate("routing"),
            "mean_partition_occupancy":
                self.occupancy.mean_occupancy(self.elapsed_s),
            "device_occupancy": self.device_occupancy(),
            "decrypt_error": dict(sorted(self.decrypt_error.items())),
            "counters": dict(sorted(self.counters.items())),
            "tenants": {t: dict(sorted(c.items())) for t, c in
                        sorted(self.tenant_counters.items())},
        }

    def format_table(self) -> str:
        s = self.summary()
        lat = s["latency"]
        lines = [
            f"elapsed               {s['elapsed_s']:.3f} s",
            f"throughput            {s['throughput_rps']:.1f} req/s",
            f"latency p50/p95/p99   {lat['p50_s']*1e3:.2f} / "
            f"{lat['p95_s']*1e3:.2f} / {lat['p99_s']*1e3:.2f} ms",
            f"queue wait p50        {self.queue_wait.p50*1e3:.2f} ms",
            f"queue delay p99       {self.queue_delay.p99*1e3:.2f} ms",
            f"service time p99      {self.service_time.p99*1e3:.2f} ms",
            f"keycache hit rate     {s['keycache_hit_rate']*100:.1f} %",
            f"compile hit rate      {s['compile_cache_hit_rate']*100:.1f} %",
        ]
        # partition utilization normalized busy/wall over partitions
        # that did work (raw busy-seconds averaged over every partition
        # of the arch — including the idle tail — made the column
        # incomparable between the 4-partition smoke model and a
        # 128-bank pim preset)
        mu, mx, n_act = self.occupancy.active_utilization(s["elapsed_s"])
        lines.append(f"partition util        {mu*100:.1f} % mean / "
                     f"{mx*100:.1f} % max "
                     f"({n_act}/{self.occupancy.n_partitions} active)")
        if self.count("requests_goodput"):
            lines.insert(2, f"goodput               "
                            f"{s['goodput_rps']:.1f} req/s")
        occ = s["device_occupancy"]
        if occ:
            lines.append("device occupancy      " + " ".join(
                f"d{d}={f*100:.0f}%" for d, f in occ.items()))
            lines.append(f"routing hit rate      "
                         f"{s['routing_hit_rate']*100:.1f} %")
        for w, e in s["decrypt_error"].items():
            lines.append(f"max |err| {w:<11} {e:.3e}")
        for k, v in s["counters"].items():
            lines.append(f"{k:<21} {v}")
        for t, c in s["tenants"].items():
            miss = c.get("deadline_misses", 0)
            if miss:
                lines.append(f"deadline misses {t:<6} {miss}")
        return "\n".join(lines)


class TelemetryHub:
    """Fleet-wide view over the run's shared telemetry
    (repro.obs.Telemetry — duck-typed here, as with the tracer, so the
    accumulator module never imports the obs package).

    Devices emit their series into ONE Telemetry with a ``device``
    label (the registry is already the fleet-wide scoreboard), so
    aggregation is a query, not a merge protocol: ``aggregate`` folds
    every series of a name across its label sets into one series
    sampled at the union of their timestamps, step-interpolating each
    input (a counter holds its last cumulative total between points;
    0 before its first) — the "whole-fleet queue depth" / "total
    goodput" view the per-device series can't show individually."""

    AGGS = ("sum", "mean", "max")

    def __init__(self, telemetry):
        self.telemetry = telemetry

    def group(self, name: str, label: str = "device") -> Dict[str, list]:
        """Series of ``name`` bucketed by one label's value (series
        without the label land under "")."""
        out: Dict[str, list] = {}
        for s in self.telemetry.find(name):
            out.setdefault(dict(s.labels).get(label, ""), []).append(s)
        return out

    def aggregate(self, name: str, agg: str = "sum",
                  label: Optional[str] = None,
                  value: Optional[str] = None) -> List[Tuple[float,
                                                             float]]:
        """Fold all series named ``name`` (optionally only those whose
        ``label`` equals ``value``) into [(t, aggregated)] samples."""
        if agg not in self.AGGS:
            raise ValueError(f"agg must be one of {self.AGGS}")
        series = self.telemetry.find(name)
        if label is not None:
            series = [s for s in series
                      if dict(s.labels).get(label) == str(value)]
        series = [s for s in series if s.points]
        if not series:
            return []
        ts = sorted({t for s in series for t, _ in s.points})
        out = []
        for t in ts:
            vals = []
            for s in series:
                if s.points[0][0] > t:
                    # not yet emitting: a counter contributes 0 to a
                    # sum; gauges are excluded (no level exists yet)
                    if s.kind == "counter" and agg == "sum":
                        vals.append(0.0)
                    continue
                vals.append(s.value_at(t))
            if not vals:
                continue
            if agg == "sum":
                out.append((t, sum(vals)))
            elif agg == "max":
                out.append((t, max(vals)))
            else:
                out.append((t, sum(vals) / len(vals)))
        return out

    def totals(self, name: str) -> Dict[str, float]:
        """Final value per label set — {rendered labels: value}."""
        return {",".join(f"{k}={v}" for k, v in s.labels): s.value
                for s in self.telemetry.find(name)}
