"""Round-based serving engine: drains the slot batcher through a
pipeline backend behind one interface.

Four backends, one contract (``execute(schedule, batch, ...) -> seconds``):

* ``AnalyticBackend`` — the MemoryModel cost model (core/pipeline.py)
  driven as a discrete-event simulation on a virtual clock. Stage
  constant loads consult the KeyCache: a resident stage costs zero load
  time for the next batch — the cross-batch extension of the paper's
  "load once per round" property (§IV-F). Deterministic; runs anywhere.
* ``MeshBackend`` — the real distributed executor
  (fhe_dist/pipeline_exec.py): batches become microbatch stacks flowing
  rank-to-rank via collective_permute, stage constants become
  device-resident arrays cached across batches, service time is wall
  clock.
* ``CiphertextBackend`` (runtime/ciphertext_backend.py) — real encrypted
  execution: batches are encrypted under the runtime's CKKS keys and
  every schedule op runs as one vmapped dispatch over the ciphertext
  stack, with decrypt-side accuracy recorded per workload. Wall clock,
  per-stage measured times (the fig18 calibration source).
* ``PimBackend`` (repro/pim/backend.py) — discrete-event simulation of
  the hierarchical FHEmem hardware model: schedules are lowered to a
  bank-level instruction stream (repro.pim.lower) and replayed on a
  virtual clock; the degenerate flat arch reproduces AnalyticBackend
  stage times exactly (DESIGN.md §10).

``PipelinedExecutor`` owns the event loop: admit arrivals → poll the
batcher → compile (memoized) → execute → record completions.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.compiler import PassConfig
from repro.core.params import CkksParams
from repro.core.pipeline import (MemoryModel, PipelineSchedule,
                                 generate_load_save_pipeline)
from repro.core.trace import (FheTrace, LevelBudgetExhausted, infer_levels,
                              trace_program)
from repro.obs.tracer import ExecObs
from repro.runtime.batcher import Batch, BatchPolicy, SlotBatcher
from repro.runtime.compile_cache import CompileCache
from repro.runtime.keycache import KeyCache
from repro.runtime.metrics import MetricsRegistry
from repro.runtime.queue import AdmissionQueue, Request, RequestStatus


@dataclasses.dataclass
class Workload:
    """A registered FHE program: traced once, compiled per (params, mem)
    via the compile cache, shared by every tenant that names it."""
    name: str
    trace: FheTrace


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

class AnalyticBackend:
    """Virtual-clock service-time model with cache-aware constant loads.

    ``round_seconds`` is the unit of simulation: one pipeline round at
    a given batch occupancy. ``execute`` sums it over the schedule's
    rounds; the fleet's continuous-batching/preemption path
    (repro.fleet.device) calls it round by round so batch membership
    can change at round boundaries.
    """

    def __init__(self, mem: MemoryModel):
        self.mem = mem

    def round_seconds(self, schedule: PipelineSchedule, rnd, b: int, *,
                      key_cache: Optional[KeyCache],
                      metrics: MetricsRegistry, workload: str,
                      obs: Optional[ExecObs] = None) -> float:
        # the schedule's own cost model is the single source of truth;
        # the key cache only substitutes the load term: a resident
        # stage streams nothing (reload_per_op stages overflow the
        # partition, so residency cannot help them by construction)
        times = schedule.stage_times(b)
        round_times = []
        for st in rnd:
            load, compute, transfer = times[st.idx]
            if key_cache is not None and not schedule.reload_per_op:
                _, _, load = key_cache.get_or_load(
                    (workload, "stage", st.idx), st.const_bytes)
            busy = load + max(compute, transfer)
            round_times.append((busy, compute, transfer))
            metrics.occupancy.add(st.partition, busy)
        # within a round stages overlap (pipelined): worst stage
        # bounds the steady state, plus pipeline fill
        worst = max(t[0] for t in round_times)
        fill = sum(max(c, t) / b for (_, c, t) in round_times)
        tel = metrics.telemetry
        if tel is not None and obs is not None:
            round_s = worst + fill
            t_end = obs.t0 + round_s
            for st, (busy, _, _) in zip(rnd, round_times):
                tel.counter("fhe_partition_busy_seconds",
                            partition=st.partition).inc(t_end, busy)
                tel.gauge("fhe_partition_utilization",
                          partition=st.partition).set(
                              t_end, busy / round_s)
        if obs is not None and obs.tracer is not None:
            # stages of one round run pipelined, so their spans share
            # the round's start and nest by containment in the viewer
            rspan = obs.tracer.begin("round", obs.t0, parent=obs.parent,
                                     track=obs.track, n_stages=len(rnd),
                                     b=b)
            for st, (busy, compute, transfer) in zip(rnd, round_times):
                obs.tracer.span(
                    "stage", obs.t0, obs.t0 + busy, parent=rspan,
                    track=obs.track, stage=st.idx, partition=st.partition,
                    load_s=busy - max(compute, transfer),
                    compute_s=compute, move_s=transfer)
            obs.tracer.end(rspan, obs.t0 + worst + fill)
        return worst + fill

    def execute(self, schedule: PipelineSchedule, batch: Batch, *,
                key_cache: Optional[KeyCache],
                metrics: MetricsRegistry, workload: str,
                obs: Optional[ExecObs] = None) -> float:
        b = max(1, batch.n_ciphertexts)
        total = 0.0
        for rnd in schedule.rounds:
            total += self.round_seconds(
                schedule, rnd, b, key_cache=key_cache, metrics=metrics,
                workload=workload,
                obs=obs.at(obs.t0 + total) if obs is not None else None)
        return total


def _identity_stage(x):
    return x


def default_stage_fn_builder(stage, const):
    """Shape-preserving placeholder stage body: an affine map with the
    stage's (cached, device-resident) constant. Real FHE stage bodies
    plug in here once core ops are wired batch-wise; the pipeline
    structure, residency, and transfer pattern are already the real
    ones."""
    import jax.numpy as jnp
    w, bias = const[0], const[1]
    def fn(x):
        return x * w + bias
    return fn


class MeshBackend:
    """Real pipelined execution on a jax mesh via
    fhe_dist.pipeline_exec.run_load_save_pipeline.

    Batches become (n_ciphertexts, slots_per_ct) float stacks (each
    request's payload written into its owned slot range); schedule
    rounds are regrouped into chunks of the mesh's data-axis size
    (identity-padded), so the same schedule runs on any device count.
    Stage constants are materialized host→device through the KeyCache:
    a hit reuses the resident device array.
    """

    def __init__(self, mesh=None, axis: str = "data",
                 slots_per_ct: int = 128,
                 stage_fn_builder: Callable = default_stage_fn_builder,
                 pad_batch_to: Optional[int] = None):
        import jax
        from repro.launch.mesh import make_host_mesh
        self.mesh = mesh if mesh is not None else make_host_mesh(
            data=jax.local_device_count(), model=1)
        self.axis = axis
        self.slots_per_ct = slots_per_ct
        self.stage_fn_builder = stage_fn_builder
        # pad every batch to this many microbatches so each workload
        # compiles exactly one XLA program (classic serving bucketing)
        self.pad_batch_to = pad_batch_to
        self._jit: Dict[Tuple, Callable] = {}

    def _make_const(self, stage_idx: int):
        import numpy as np
        import jax.numpy as jnp
        rng = np.random.default_rng(1000 + stage_idx)
        w = 1.0 - 1e-3 * rng.uniform(size=(self.slots_per_ct,))
        bias = 1e-3 * rng.standard_normal((self.slots_per_ct,))
        return jnp.asarray(np.stack([w, bias]).astype(np.float32))

    def _pack(self, batch: Batch, n_micro: int):
        import numpy as np
        import jax.numpy as jnp
        x = np.zeros((n_micro, self.slots_per_ct), dtype=np.float32)
        for ct_i, group in enumerate(batch.slot_groups):
            off = 0
            for r in group:
                n = r.slots_needed
                if r.payload is not None:
                    try:
                        v = np.asarray(r.payload,
                                       dtype=np.float32).ravel()[:n]
                    except (TypeError, ValueError):
                        v = None   # opaque payload (e.g. a Ciphertext):
                    if v is not None:  # slots stay zero, request still rides
                        x[ct_i, off:off + len(v)] = v
                off += n
        return jnp.asarray(x)

    def execute(self, schedule: PipelineSchedule, batch: Batch, *,
                key_cache: Optional[KeyCache],
                metrics: MetricsRegistry, workload: str,
                obs: Optional[ExecObs] = None) -> float:
        import jax
        from repro.fhe_dist.pipeline_exec import run_load_save_pipeline

        # residency accounting + device-resident constants (with no key
        # cache, constants are only materialized when compiling below)
        consts = None
        if key_cache is not None:
            consts = [key_cache.get_or_load(
                (workload, "stage", st.idx), st.const_bytes,
                loader=lambda i=st.idx: self._make_const(i))[0]
                for st in schedule.stages]

        # pad to the bucket size, but never below the actual batch —
        # a misconfigured pad_batch_to < max_batch must not drop groups
        n_micro = max(self.pad_batch_to or 0, batch.n_ciphertexts, 1)
        # one XLA program per (workload, stage count, bucket size);
        # _make_const is deterministic per stage idx, so a closure built
        # on the first call stays valid across keycache evictions
        key = (workload, len(schedule.stages), n_micro)
        if key not in self._jit:
            if consts is None:
                consts = [self._make_const(st.idx)
                          for st in schedule.stages]
            fns = [self.stage_fn_builder(st, c)
                   for st, c in zip(schedule.stages, consts)]
            n_dev = self.mesh.shape[self.axis]
            rounds = []
            for i in range(0, len(fns), n_dev):
                chunk = fns[i:i + n_dev]
                chunk += [_identity_stage] * (n_dev - len(chunk))
                rounds.append(chunk)
            self._jit[key] = jax.jit(
                lambda x, _r=rounds: run_load_save_pipeline(
                    _r, x, self.mesh, self.axis))

        x = self._pack(batch, n_micro)
        t0 = time.perf_counter()
        out = self._jit[key](x)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        n_rounds = max(1, len(schedule.rounds))
        for st in schedule.stages:
            metrics.occupancy.add(st.partition, dt / n_rounds)
        batch.outputs = out
        if obs is not None and obs.tracer is not None:
            # the mesh measures one fused XLA dispatch — no per-stage
            # decomposition, so a single execute span carries the total
            obs.tracer.span("xla_execute", obs.t0, obs.t0 + dt,
                            parent=obs.parent, track=obs.track,
                            n_rounds=n_rounds, n_micro=n_micro)
        return dt


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------

def record_request_completion(metrics: MetricsRegistry, r: Request,
                              done: float, service_start_s: float,
                              batch_span: Optional[int] = None) -> bool:
    """One request leaves the system: deadline check, latency +
    queue-delay/service-time decomposition, per-tenant attribution.
    Shared by the single executor and every fleet device so their
    accounting can never drift. Returns True iff completed in time.

    With tracing on, this is also the single site that completes a
    request's span tree: queue_wait and service children under the
    root, the service span linking (``batch_span``) to the batch that
    carried it, and the root closed with the terminal status — so the
    root's duration IS the recorded latency, by construction."""
    r.completion_s = done
    r.service_start_s = service_start_s
    metrics.incr("requests_served")
    tr, log = metrics.tracer, metrics.event_log
    tel, slo = metrics.telemetry, metrics.slo
    missed = r.deadline_s is not None and done > r.deadline_s
    if tel is not None:
        tel.counter("fhe_requests_finished",
                    status="deadline_miss" if missed
                    else "completed").inc(done)
        if r.deadline_s is not None and not missed:
            tel.counter("fhe_goodput_requests").inc(done)
    if slo is not None and r.deadline_s is not None:
        # the burn-rate monitor only sees SLO-bearing outcomes:
        # best-effort completions can't miss and must not dilute the
        # miss rate
        slo.record(done, missed, metrics)
    if tr is not None:
        root = tr.ensure_root(r)
        track = f"tenant:{r.tenant}"
        tr.span("queue_wait", r.arrival_s, service_start_s, parent=root,
                track=track, request_id=r.request_id)
        link = {} if batch_span is None else {"batch_span": batch_span}
        tr.span("service", service_start_s, done, parent=root,
                track=track, request_id=r.request_id, **link)
    if r.deadline_s is not None and done > r.deadline_s:
        r.status = RequestStatus.DEADLINE_MISS
        metrics.incr("deadline_misses")
        metrics.incr_tenant("deadline_misses", r.tenant)
        if tr is not None:
            tr.close_root(r, done, "deadline_miss")
        if log is not None:
            log.emit("deadline_miss", done, r)
        return False
    r.status = RequestStatus.COMPLETED
    metrics.request_latency.observe(r.latency())
    metrics.queue_delay.observe(max(0.0, service_start_s - r.arrival_s))
    metrics.service_time.observe(max(0.0, done - service_start_s))
    metrics.incr("requests_completed")
    metrics.incr_tenant("requests_completed", r.tenant)
    if r.deadline_s is not None:
        metrics.incr("requests_goodput")
    if tr is not None:
        tr.close_root(r, done, "completed", latency_s=r.latency())
    if log is not None:
        log.emit("completed", done, r, latency_s=r.latency())
    return True


BACKEND_NAMES = ("analytic", "mesh", "ciphertext", "pim")


def resolve_backend(name: str, params: CkksParams, mem: MemoryModel,
                    use_kernels: Optional[bool] = None,
                    verify: bool = False):
    """Build a backend from its CLI/ctor name: ``analytic`` (cost model),
    ``mesh`` (distributed placeholder stages), ``ciphertext`` (real
    encrypted execution via repro.compiler.engine), ``pim``
    (discrete-event simulation of the hierarchical FHEmem hardware
    model, repro.pim — the arch is recovered from `mem`: a preset
    projection maps back to its preset, anything else is wrapped in a
    degenerate arch billing exactly like AnalyticBackend).

    ``use_kernels`` (ciphertext backend only) routes keyswitch + modmul
    through the fused Pallas kernels; None keeps the backend's own
    default (on iff running on TPU).

    ``verify`` (pim backend only) arms the static hazard analyzer
    (repro.analysis.pim_hazards) over every freshly lowered instruction
    stream."""
    if name == "analytic":
        return AnalyticBackend(mem)
    if name == "mesh":
        return MeshBackend(slots_per_ct=params.slots)
    if name == "ciphertext":
        from repro.runtime.ciphertext_backend import CiphertextBackend
        return CiphertextBackend(params, use_kernels=use_kernels)
    if name == "pim":
        from repro.pim.backend import resolve_pim_backend
        return resolve_pim_backend(mem, verify=verify)
    from repro.pim.arch import PRESETS
    raise ValueError(
        f"unknown backend {name!r}: valid backends are "
        f"{', '.join(repr(n) for n in BACKEND_NAMES)}; the 'pim' "
        f"backend additionally takes a hardware preset out of "
        f"{', '.join(repr(p) for p in sorted(PRESETS))} "
        f"(serve_fhe --pim-preset / repro.pim.arch.get_arch)")


class PipelinedExecutor:
    """Admission queue → slot batcher → compile cache → backend, driven
    on a virtual clock (event times from the analytic backend) or wall
    clock deltas (mesh/ciphertext backends) — the loop is the same
    either way. `backend` may be an instance or a name
    ("analytic" | "mesh" | "ciphertext" | "pim")."""

    def __init__(self, params: CkksParams, mem: MemoryModel,
                 backend=None, policy: Optional[BatchPolicy] = None,
                 key_cache: Optional[KeyCache] = None,
                 max_depth_per_tenant: int = 256,
                 mapper: Callable[..., PipelineSchedule]
                 = generate_load_save_pipeline,
                 pass_config: Optional[PassConfig] = None,
                 verify: bool = False):
        self.params = params
        self.mem = mem
        self.metrics = MetricsRegistry(n_partitions=mem.n_partitions)
        if isinstance(backend, str):
            backend = resolve_backend(backend, params, mem)
        self.backend = backend or AnalyticBackend(mem)
        self.policy = policy or BatchPolicy(slots_per_ct=params.slots)
        self.queue = AdmissionQueue(max_depth_per_tenant, self.metrics)
        self.batcher = SlotBatcher(self.queue, self.policy, self.metrics)
        # bucket mesh batches at max_batch so warmup() pre-compiles the
        # one XLA program every serving batch will use
        if getattr(self.backend, "pad_batch_to", 0) is None:
            self.backend.pad_batch_to = self.policy.max_batch
        self.key_cache = key_cache
        if key_cache is not None:
            key_cache.metrics = self.metrics   # one registry for all parts
        # verify=True arms static verify-on-miss (repro.analysis): every
        # freshly compiled schedule is swept before it can serve
        self.compile_cache = CompileCache(self.metrics, verify=verify)
        self.mapper = mapper
        # optimizing compiler (repro.compiler) between capture and the
        # mapper; None serves every trace verbatim
        self.pass_config = pass_config
        self.workloads: Dict[str, Workload] = {}

    # -- workload registry ---------------------------------------------------

    def register(self, name: str, fn: Callable, n_inputs: int,
                 const_names: Sequence[str] = (),
                 start_level: int = 10) -> Workload:
        trace = trace_program(fn, n_inputs, const_names)
        try:
            infer_levels(trace, start_level=start_level)
        except LevelBudgetExhausted:
            # deeper than the chain: admissible only when the compiler's
            # bootstrap-insertion pass will rewrite it at compile time
            # (inputs keep their level so the compiler knows the start)
            if not (self.pass_config and self.pass_config.bootstrap):
                raise
        w = Workload(name, trace)
        self.workloads[name] = w
        return w

    def register_trace(self, name: str, trace: FheTrace) -> Workload:
        w = Workload(name, trace)
        self.workloads[name] = w
        return w

    # -- request path --------------------------------------------------------

    def next_request_id(self) -> int:
        return self.queue.next_request_id()

    def submit(self, tenant: str, workload: str, now: float,
               slots_needed: int = 1, deadline_s: Optional[float] = None,
               payload=None) -> Request:
        assert workload in self.workloads, f"unregistered workload {workload}"
        req = Request(self.queue.next_request_id(), tenant, workload,
                      arrival_s=now, slots_needed=slots_needed,
                      deadline_s=deadline_s, payload=payload)
        self._admit(req)
        return req

    def _admit(self, req: Request) -> None:
        """Admission door: a request that can never fit one ciphertext
        is rejected here, not left to starve in the queue."""
        if req.slots_needed > self.policy.slots_per_ct:
            req.status = RequestStatus.REJECTED
            self.metrics.incr("requests_oversized")
            tr, log = self.metrics.tracer, self.metrics.event_log
            if tr is not None:
                tr.close_root(req, req.arrival_s, "rejected",
                              reason="oversized")
            if log is not None:
                log.emit("rejected", req.arrival_s, req, reason="oversized")
        else:
            self.queue.submit(req)

    def warmup(self) -> float:
        """Pre-compile every registered workload and pre-load its stage
        constants (deploy-time work that must not count against request
        deadlines — on the mesh backend the first execution pays XLA
        compilation). Returns wall seconds spent."""
        t0 = time.perf_counter()
        scratch = MetricsRegistry(self.mem.n_partitions)
        # deploy-time misses must not dilute the SERVING hit rates:
        # point every cache at the scratch registry for the duration
        saved_cc, self.compile_cache.metrics = self.compile_cache.metrics, \
            scratch
        saved_kc = None
        if self.key_cache is not None:
            saved_kc, self.key_cache.metrics = self.key_cache.metrics, \
                scratch
        try:
            for name, w in self.workloads.items():
                sched = self.compile_cache.get_schedule(
                    w.trace, self.params, self.mem, self.mapper,
                    pass_config=self.pass_config)
                self.backend.execute(sched, Batch(name, [], [[]], 0.0),
                                     key_cache=self.key_cache,
                                     metrics=scratch, workload=name)
        finally:
            self.compile_cache.metrics = saved_cc
            if saved_kc is not None:
                self.key_cache.metrics = saved_kc
        return time.perf_counter() - t0

    def _execute_batch(self, batch: Batch, now: float) -> float:
        tr, tel = self.metrics.tracer, self.metrics.telemetry
        bspan = obs = None
        if tr is not None:
            bspan = tr.begin(f"batch:{batch.workload}", now,
                             track="device:0", workload=batch.workload,
                             n_requests=len(batch.requests),
                             n_ciphertexts=batch.n_ciphertexts)
        if tr is not None or tel is not None:
            # telemetry alone still needs the timeline origin threaded
            # into the backend (ExecObs.t0); span emission stays off
            obs = ExecObs(tr, bspan, now, "device:0")
        if tel is not None:
            tel.gauge("fhe_device_queue_depth",
                      device=self.queue.owner).set(now, len(self.queue))
        sched = self.compile_cache.get_schedule(
            self.workloads[batch.workload].trace, self.params, self.mem,
            self.mapper, pass_config=self.pass_config, obs=obs)
        service_s = self.backend.execute(
            sched, batch, key_cache=self.key_cache, metrics=self.metrics,
            workload=batch.workload, obs=obs)
        done = now + service_s
        if tr is not None:
            tr.end(bspan, done)
        for r in batch.requests:
            record_request_completion(self.metrics, r, done,
                                      service_start_s=now,
                                      batch_span=bspan)
        self.metrics.batch_service.observe(service_s)
        return service_s

    # -- event loop ----------------------------------------------------------

    def serve(self, arrivals: List[Request],
              start_s: float = 0.0) -> MetricsRegistry:
        """Drain a pre-generated arrival schedule (sorted by arrival_s).

        Single-server semantics: the pipeline serves one batch at a
        time; arrivals landing mid-service are admitted when it ends —
        so saturation shows up as queue growth and latency, exactly
        what the fig16 sweep measures.
        """
        pending = sorted(arrivals, key=lambda r: r.arrival_s)
        i = 0
        now = start_s
        while i < len(pending) or len(self.queue):
            while i < len(pending) and pending[i].arrival_s <= now:
                self._admit(pending[i])
                i += 1
            batch = self.batcher.poll(now)
            if batch is not None:
                now += self._execute_batch(batch, now)
                continue
            # idle: jump to the next event
            events = []
            if i < len(pending):
                events.append(pending[i].arrival_s)
            t_fire = self.batcher.next_fire_time(now)
            if t_fire is not None:
                events.append(t_fire)
            if not events:
                break                  # only expired/unservable work left
            now = max(math.nextafter(now, math.inf), min(events))
        self.metrics.elapsed_s = max(self.metrics.elapsed_s, now - start_s)
        if self.metrics.tracer is not None:
            self.metrics.tracer.close_open(now)
        return self.metrics
