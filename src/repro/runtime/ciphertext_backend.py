"""CiphertextBackend: the serving backend that actually encrypts.

Third executor backend behind the ``execute(schedule, batch, ...) ->
seconds`` contract (see runtime/executor.py): where AnalyticBackend
prices a batch on the MemoryModel and MeshBackend streams
shape-preserving placeholder stages over a device mesh, this backend
runs the compiled `PipelineSchedule` on *actually encrypted* data
through the real CKKS stack, via the batched schedule-evaluation
engine (repro/compiler/engine.py) shared with the compiler's
verification tests.

Per batch:

* requests' slot groups are packed into (B, slots) value rows exactly
  like the mesh backend packs microbatches, then encrypted under the
  engine's secret key — the runtime owns the ingress encryptor, so
  plaintext payloads never travel past this point;
* every trace op executes as ONE vmapped dispatch covering the whole
  ciphertext stack (batched key-switch digits included);
* stage constants are encoded once and reused across batches through
  the runtime `KeyCache` (real residency accounting: evk/Galois-key
  footprints are pinned, plaintext constants LRU-evictable);
* outputs are decrypted and compared against the plaintext oracle
  (`reference_eval`) on the same packed values — max |error| lands in
  ``MetricsRegistry.decrypt_error`` next to the latency percentiles;
* per-stage wall times (completion barrier per stage) accumulate in
  ``stage_stats`` — the measured side of benchmarks/fig18_calibration.

Workload inputs beyond the request payload (e.g. HELR's weight vector)
and the named plaintext constants are synthesized deterministically per
(workload, name) — they play the role of server-side model state.
"""
from __future__ import annotations

import time
import zlib
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.compiler.engine import CkksEngine, op_cexpr
from repro.compiler.interp import reference_eval
from repro.core.params import CkksParams
from repro.core.pipeline import PipelineSchedule
from repro.core.trace import FheTrace
from repro.runtime.batcher import Batch
from repro.runtime.keycache import KeyCache
from repro.runtime.metrics import MetricsRegistry


def base_const_names(trace: FheTrace) -> List[str]:
    """Named plaintext constants a trace's pmul/padd ops reference,
    including through derived const expressions (ir.py cexprs)."""
    names: Set[str] = set()

    def walk(expr):
        if expr[0] == "ref":
            names.add(expr[1])
        elif expr[0] == "rot":
            walk(expr[1])
        else:
            walk(expr[1])
            walk(expr[2])

    for op in trace.ops:
        if op.kind in ("pmul", "padd"):
            walk(op_cexpr(op))
    return sorted(names)


def _stable_rng(*parts: str) -> np.random.Generator:
    seed = zlib.crc32("/".join(parts).encode()) & 0xFFFFFFFF
    return np.random.default_rng(seed)


class _StageStat:
    """Running mean of one stage's measured wall seconds."""

    __slots__ = ("total_s", "count")

    def __init__(self):
        self.total_s = 0.0
        self.count = 0

    def add(self, seconds: float) -> None:
        self.total_s += seconds
        self.count += 1

    @property
    def mean_s(self) -> float:
        return self.total_s / self.count if self.count else 0.0


class CiphertextBackend:
    """Real encrypted execution of compiled schedules, batched."""

    def __init__(self, params: CkksParams, seed: int = 7,
                 use_kernels: Optional[bool] = None,
                 const_amplitude: float = 0.25):
        import jax
        if use_kernels is None:
            # the Pallas kernel route (fused keyswitch + modmul) compiles
            # natively on TPU; interpret mode elsewhere is correct but
            # slower than the library path
            use_kernels = jax.default_backend() == "tpu"
        self.use_kernels = bool(use_kernels)
        self._key_cache: Optional[KeyCache] = None
        self._local_consts: Dict = {}
        self._consts_memo: Dict[Tuple, Dict[str, np.ndarray]] = {}
        self._aux_memo: Dict[Tuple[str, int], np.ndarray] = {}
        self.const_amplitude = const_amplitude
        self.engine = CkksEngine(params, seed=seed,
                                 const_cache=self._cached_const,
                                 on_key_load=self._on_key_load,
                                 use_kernels=use_kernels)
        # workload -> per-stage running means of measured seconds
        self.stage_stats: Dict[str, List[_StageStat]] = {}
        self.pad_batch_to: Optional[int] = None   # bucketing (executor sets)

    # -- KeyCache integration ------------------------------------------------

    def _cached_const(self, key, nbytes: int, loader):
        """Engine const hook: memoize encoded plaintexts through the
        runtime KeyCache when one is wired, else a local dict."""
        if self._key_cache is None:
            if key not in self._local_consts:
                self._local_consts[key] = loader()
            return self._local_consts[key]
        value, _hit, _load_s = self._key_cache.get_or_load(
            key, nbytes, loader=loader)
        return value

    def _on_key_load(self, key: Tuple, nbytes: int) -> None:
        """Evaluation keys (relin / Galois) are pinned residents: a
        serving system cannot evict the evk mid-flight."""
        if self._key_cache is not None:
            self._key_cache.get_or_load(("engine",) + key, nbytes, pin=True)

    def _sync_keys(self) -> None:
        """Register evaluation keys the engine already holds into the
        wired KeyCache (pinned). Keys may have been generated before
        this cache was attached — residency accounting must not depend
        on generation timing. Only MISSING keys are loaded: pinned
        entries never leave, and re-touching them every batch would
        inflate the hit-rate metrics the serving sweeps report."""
        if self._key_cache is None:
            return
        from repro.core.trace import evk_bytes
        nb = evk_bytes(self.engine.params)
        for key in [("engine", "relin")] + [("engine", "gk", elt)
                                            for elt in self.engine._gks]:
            if key not in self._key_cache:
                self._key_cache.get_or_load(key, nb, pin=True)

    # -- deterministic server-side state -------------------------------------

    def workload_consts(self, workload: str,
                        trace: FheTrace) -> Dict[str, np.ndarray]:
        """Memoized per (workload, const-name set): each value is a pure
        function of (workload, name), so reuse across traces of one
        workload is exact — and synthesis stays out of the timed
        service window."""
        key = (workload, tuple(base_const_names(trace)))
        consts = self._consts_memo.get(key)
        if consts is None:
            slots = self.engine.params.slots
            consts = self._consts_memo[key] = {
                name: self.const_amplitude
                * _stable_rng(workload, "const", name).standard_normal(slots)
                for name in key[1]}
        return consts

    def _aux_input(self, workload: str, input_pos: int,
                   batch_size: int) -> np.ndarray:
        """Inputs past the payload slot (weights etc.): one deterministic
        vector (memoized) broadcast across the batch."""
        v = self._aux_memo.get((workload, input_pos))
        if v is None:
            slots = self.engine.params.slots
            v = self._aux_memo[(workload, input_pos)] = \
                self.const_amplitude * _stable_rng(
                    workload, "input", str(input_pos)).standard_normal(slots)
        return np.broadcast_to(v, (batch_size, len(v)))

    def _pack(self, batch: Batch, n_micro: int) -> np.ndarray:
        """Requests' payload values -> (n_micro, slots) rows, mirroring
        MeshBackend._pack (each request owns a contiguous slot range)."""
        slots = self.engine.params.slots
        x = np.zeros((n_micro, slots), dtype=np.complex128)
        for ct_i, group in enumerate(batch.slot_groups):
            off = 0
            for r in group:
                n = r.slots_needed
                if r.payload is not None:
                    try:
                        v = np.asarray(r.payload,
                                       dtype=np.complex128).ravel()[:n]
                    except (TypeError, ValueError):
                        v = None   # opaque payload: slots stay zero
                    if v is not None:
                        x[ct_i, off:off + len(v)] = v
                off += n
        return x

    # -- execution -----------------------------------------------------------

    def execute(self, schedule: PipelineSchedule, batch: Batch, *,
                key_cache: Optional[KeyCache],
                metrics: MetricsRegistry, workload: str,
                obs=None) -> float:
        trace = schedule.trace
        assert trace is not None, "mapper did not attach the trace"
        self._key_cache = key_cache
        self._sync_keys()
        n_micro = max(self.pad_batch_to or 0, batch.n_ciphertexts, 1)

        t0 = time.perf_counter()
        values = self._pack(batch, n_micro)
        inputs = [values] + [self._aux_input(workload, i, n_micro)
                             for i in range(1, len(trace.inputs))]
        consts = self.workload_consts(workload, trace)
        t_pack = time.perf_counter() - t0
        outs, stage_s = self.engine.run_schedule(
            schedule, inputs, consts, const_scope=(workload,))
        dt = time.perf_counter() - t0

        # decrypt-side accuracy vs the plaintext oracle on the very same
        # packed values (reference_eval resolves derived cexprs too)
        t_chk = time.perf_counter()
        ref = reference_eval(trace, inputs, consts)
        err = max(float(np.abs(np.asarray(d) - np.asarray(r)).max())
                  for d, r in zip(outs, ref)) if outs else 0.0
        metrics.observe_decrypt_error(workload, err)
        t_chk = time.perf_counter() - t_chk

        stats = self.stage_stats.setdefault(
            workload, [_StageStat() for _ in schedule.stages])
        if len(stats) != len(schedule.stages):   # recompiled differently
            stats = self.stage_stats[workload] = \
                [_StageStat() for _ in schedule.stages]
        for st, sec in zip(schedule.stages, stage_s):
            stats[st.idx].add(sec)
            metrics.occupancy.add(st.partition, sec)

        tel = metrics.telemetry
        if tel is not None and obs is not None:
            # wall-clock series (this backend's clock domain): measured
            # per-stage seconds laid end to end after the pack window,
            # mirroring the span decomposition below
            at = obs.t0 + t_pack
            for st, sec in zip(schedule.stages, stage_s):
                at += sec
                tel.counter("fhe_partition_busy_seconds",
                            partition=st.partition).inc(at, sec)
                tel.histogram("fhe_stage_wall_seconds",
                              stage=st.idx).observe(at, sec)
        if obs is not None and obs.tracer is not None:
            # wall-clock decomposition: pack+encrypt, then the measured
            # per-stage execution laid end to end
            tr, t = obs.tracer, obs.t0
            tr.span("encrypt_pack", t, t + t_pack, parent=obs.parent,
                    track=obs.track, n_micro=n_micro)
            at = t + t_pack
            for st, sec in zip(schedule.stages, stage_s):
                tr.span("stage", at, at + sec, parent=obs.parent,
                        track=obs.track, stage=st.idx,
                        partition=st.partition, compute_s=sec)
                at += sec
            # the oracle check runs after `dt` (outside the billed
            # service window) — an instant with its wall cost as an
            # attr keeps children inside the batch span's interval
            tr.instant("decrypt_check", t + dt, parent=obs.parent,
                       track=obs.track, wall_s=t_chk, max_err=err)
        batch.outputs = outs
        return dt

    # -- calibration hooks ---------------------------------------------------

    def measured_stage_seconds(self, workload: str) -> List[float]:
        """Mean measured wall seconds per stage (fig18's measured side)."""
        return [s.mean_s for s in self.stage_stats.get(workload, [])]

    @property
    def tolerance(self) -> float:
        return self.engine.tolerance
