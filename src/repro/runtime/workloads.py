"""Synthetic FHE workload zoo shared by the serving CLI and benchmarks.

One definition per program family; depth/width knobs parameterize the
variants so the CLI smoke and the fig16 sweep can't drift apart.
"""
from __future__ import annotations

from typing import Sequence, Tuple


def make_helr_iter(rot_steps: Sequence[int] = (1, 2, 4, 8)):
    """HELR-style logistic-regression iteration (the paper's deep
    workload family): rotation tree for the inner product + cubic
    sigmoid approximation. `rot_steps` sets the tree depth."""
    def helr_iter(x, w, consts=None):
        s = x * w
        for k in rot_steps:
            s = s + s.rotate(k)
        a = s * consts["c1"]
        b = s * s
        c = b * s
        return w + (a + c * consts["c3"]) * x
    return helr_iter


HELR_CONSTS: Tuple[str, ...] = ("c1", "c3")


def lola_infer(x, consts=None):
    """LoLa-style shallow inference: two plaintext-weight layers with a
    square activation."""
    h = x * consts["w1"]
    h = h + h.rotate(1)
    h = h * h
    return h * consts["w2"]


LOLA_CONSTS: Tuple[str, ...] = ("w1", "w2")
