"""Synthetic FHE workload zoo shared by the serving CLI and benchmarks.

One definition per program family; depth/width knobs parameterize the
variants so the CLI smoke and the fig16 sweep can't drift apart.
"""
from __future__ import annotations

from typing import Sequence, Tuple


def make_helr_iter(rot_steps: Sequence[int] = (1, 2, 4, 8)):
    """HELR-style logistic-regression iteration (the paper's deep
    workload family): rotation tree for the inner product + cubic
    sigmoid approximation. `rot_steps` sets the tree depth."""
    def helr_iter(x, w, consts=None):
        s = x * w
        for k in rot_steps:
            s = s + s.rotate(k)
        a = s * consts["c1"]
        b = s * s
        c = b * s
        return w + (a + c * consts["c3"]) * x
    return helr_iter


HELR_CONSTS: Tuple[str, ...] = ("c1", "c3")


def lola_infer(x, consts=None):
    """LoLa-style shallow inference: two plaintext-weight layers with a
    square activation."""
    h = x * consts["w1"]
    h = h + h.rotate(1)
    h = h * h
    return h * consts["w2"]


LOLA_CONSTS: Tuple[str, ...] = ("w1", "w2")


def make_matvec(dim: int = 16):
    """Encrypted matrix-vector product by the diagonal method:
    y = sum_i rotate(x, i) * diag_i  (Halevi-Shoup). One rotation — a
    full keyswitch — per nonzero diagonal, which is exactly the pattern
    the compiler's BSGS rotation pass factors down to ~2*sqrt(dim)
    rotations and its lazy-rescale pass collapses to one rescale per
    giant step. `dim` is the number of diagonals (the matrix bandwidth),
    not the slot count."""
    def matvec(x, consts=None):
        acc = x * consts["d0"]
        for i in range(1, dim):
            acc = acc + x.rotate(i) * consts[f"d{i}"]
        return acc
    return matvec


def matvec_consts(dim: int = 16) -> Tuple[str, ...]:
    return tuple(f"d{i}" for i in range(dim))


def make_poly_eval(degree: int = 12):
    """Horner-style polynomial ladder of multiplicative depth `degree`:
    acc = x*p_d; acc = acc*x + p_i for i = d-1..0. Every iteration burns
    a level, so any degree beyond the serving start level exhausts the
    modulus chain — the workload that exercises the compiler's automatic
    bootstrap insertion (without it, registration dies in
    `infer_levels`)."""
    def poly(x, consts=None):
        acc = x * consts[f"p{degree}"]
        for i in range(degree - 1, -1, -1):
            acc = acc * x
            acc = acc + consts[f"p{i}"]
        return acc
    return poly


def poly_consts(degree: int = 12) -> Tuple[str, ...]:
    return tuple(f"p{i}" for i in range(degree + 1))
