"""Slot batcher: packs pending requests into CKKS slot groups and the
load-save pipeline's input-batch dimension.

Two packing axes, mirroring the paper's batch economics (§IV-F):

* **slot axis** — a CKKS ciphertext at ring degree N carries N/2 slots;
  small requests of the same workload share one ciphertext (each request
  owns a contiguous slot range, never split across ciphertexts);
* **batch axis** — packed ciphertexts form the input batch that streams
  through one pipeline round, amortizing each stage's constant load
  across the whole batch.

Dispatch policy is the classic max-batch / max-wait tradeoff: fire when
the batch axis is full, when the oldest request has waited ``max_wait_s``,
or when an admitted deadline is about to become unmeetable.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.runtime.metrics import MetricsRegistry
from repro.runtime.queue import AdmissionQueue, Request, RequestStatus


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    slots_per_ct: int                # CKKS slots per ciphertext (params.slots)
    max_batch: int = 8               # ciphertexts per pipeline batch
    max_wait_s: float = 5e-3         # oldest-request wait before firing
    deadline_slack_s: float = 0.0    # fire early if a deadline is this close

    @property
    def capacity_slots(self) -> int:
        return self.max_batch * self.slots_per_ct


@dataclasses.dataclass
class Batch:
    workload: str
    requests: List[Request]
    slot_groups: List[List[Request]]     # one inner list per ciphertext
    formed_s: float
    outputs: object = None               # filled by the mesh backend

    @property
    def n_ciphertexts(self) -> int:
        return len(self.slot_groups)

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    def slot_utilization(self, slots_per_ct: int) -> float:
        used = sum(r.slots_needed for r in self.requests)
        return used / (self.n_ciphertexts * slots_per_ct) \
            if self.n_ciphertexts else 0.0


def pack_slot_groups(requests: List[Request], slots_per_ct: int,
                     max_groups: int,
                     groups: Optional[List[List[Request]]] = None,
                     free: Optional[List[int]] = None) -> tuple:
    """First-fit-decreasing bin packing of requests into ciphertexts.

    Returns (groups, overflow): requests that would need a group beyond
    ``max_groups`` — or that alone exceed ``slots_per_ct`` — overflow.

    ``groups``/``free`` seed the packer with an in-flight batch's
    existing ciphertext rows and their free slot capacity (continuous
    batching: new requests first-fit into free rows of a batch already
    streaming through the pipeline). Both are mutated in place.
    """
    if groups is None:
        groups = []
    if free is None:
        free = [slots_per_ct - sum(r.slots_needed for r in g)
                for g in groups]
    assert len(free) == len(groups)
    overflow: List[Request] = []
    for r in sorted(requests, key=lambda r: -r.slots_needed):
        if r.slots_needed > slots_per_ct:
            overflow.append(r)
            continue
        for i, f in enumerate(free):
            if r.slots_needed <= f:
                groups[i].append(r)
                free[i] -= r.slots_needed
                break
        else:
            if len(groups) < max_groups:
                groups.append([r])
                free.append(slots_per_ct - r.slots_needed)
            else:
                overflow.append(r)
    return groups, overflow


class SlotBatcher:
    def __init__(self, queue: AdmissionQueue, policy: BatchPolicy,
                 metrics: Optional[MetricsRegistry] = None):
        self.queue = queue
        self.policy = policy
        self.metrics = metrics or queue.metrics

    def _should_fire(self, now: float, workload: str) -> bool:
        p = self.policy
        n, slots = self.queue.pending_demand(now, workload)
        if n == 0:
            return False
        if slots >= p.capacity_slots:
            return True
        oldest = self.queue.oldest_arrival(now, workload)
        if oldest is not None and now - oldest >= p.max_wait_s:
            return True
        dl = self.queue.earliest_deadline(now, workload)
        return dl is not None and dl - now <= p.deadline_slack_s

    def should_fire(self, now: float, workload: str) -> bool:
        """Public fire predicate (the fleet scheduler's preemption
        trigger checks it without forming a batch)."""
        return self._should_fire(now, workload)

    def next_fire_time(self, now: float) -> Optional[float]:
        """Earliest future instant any workload's max-wait clock fires
        (virtual-clock executors advance to this when idle)."""
        best = None
        for w in self.queue.pending_workloads(now):
            oldest = self.queue.oldest_arrival(now, w)
            if oldest is None:
                continue
            t = oldest + self.policy.max_wait_s
            dl = self.queue.earliest_deadline(now, w)
            if dl is not None:
                t = min(t, dl - self.policy.deadline_slack_s)
            if best is None or t < best:
                best = t
        return best

    def poll(self, now: float,
             order: Optional[List[str]] = None) -> Optional[Batch]:
        """Form at most one batch. Requests of different workloads never
        share a batch (they compile to different schedules); workloads
        are served in first-arrival order unless ``order`` overrides it
        (the fleet scheduler passes an earliest-deadline-first order)."""
        if order is None:
            order = self.queue.pending_workloads(now)
        for workload in order:
            batch = self.poll_workload(now, workload)
            if batch is not None:
                return batch
        return None

    def poll_workload(self, now: float, workload: str) -> Optional[Batch]:
        """Form a batch of one workload if its fire condition holds."""
        p = self.policy
        if not self._should_fire(now, workload):
            return None
        taken = self.queue.take(now, workload,
                                max_requests=p.capacity_slots,
                                max_slots=p.capacity_slots)
        groups, overflow = pack_slot_groups(taken, p.slots_per_ct,
                                            p.max_batch)
        self._requeue_overflow(overflow)
        if not groups:
            return None
        batch = Batch(workload, [r for g in groups for r in g],
                      groups, formed_s=now)
        # wait is observed here, not in take(): a requeued overflow
        # request must be sampled once, on the batch it ships in
        for r in batch.requests:
            self.metrics.queue_wait.observe(max(0.0, now - r.arrival_s))
        self.metrics.incr("batches_formed")
        self.metrics.incr("ciphertexts_batched", batch.n_ciphertexts)
        return batch

    def _requeue_overflow(self, overflow: List[Request]) -> None:
        # requeue latest-arrival first so appendleft leaves each
        # tenant's queue in arrival order (overflow comes out of the
        # packer size-sorted, not arrival-sorted)
        p = self.policy
        for r in sorted(overflow, key=lambda r: r.arrival_s,
                        reverse=True):
            if r.slots_needed > p.slots_per_ct:
                # can never fit in one ciphertext — unservable
                r.status = RequestStatus.REJECTED
                self.metrics.incr("requests_oversized")
            else:
                self.queue.requeue(r)
                self.metrics.incr("batcher_overflow_requeued")

    def refill(self, now: float, workload: str,
               groups: List[List[Request]], free: List[int],
               max_groups: int) -> List[Request]:
        """Continuous batching: pull queued requests of ``workload``
        into the free slot rows of an in-flight batch (called between
        pipeline rounds). No fire condition — free capacity in a
        streaming batch is strictly cheaper than waiting for a new
        batch to form. Returns the joined requests; ``groups``/``free``
        are extended in place. Requests of other workloads are never
        pulled (they compile to a different schedule)."""
        budget = sum(free) + \
            max(0, max_groups - len(groups)) * self.policy.slots_per_ct
        if budget <= 0:
            return []
        taken = self.queue.take(now, workload,
                                max_requests=budget, max_slots=budget)
        if not taken:
            return []
        before = {id(r) for g in groups for r in g}
        _, overflow = pack_slot_groups(taken, self.policy.slots_per_ct,
                                       max_groups, groups=groups,
                                       free=free)
        self._requeue_overflow(overflow)
        joined = [r for g in groups for r in g if id(r) not in before]
        for r in joined:
            self.metrics.queue_wait.observe(max(0.0, now - r.arrival_s))
        if joined:
            self.metrics.incr("continuous_refills")
            self.metrics.incr("requests_refilled", len(joined))
            tr = self.metrics.tracer
            if tr is not None:
                # mark mid-flight joins on the request tree: the join
                # instant vs the later service span shows how long the
                # rider trailed the lead wave
                for r in joined:
                    tr.instant("batch_join", now,
                               parent=tr.ensure_root(r),
                               track=f"tenant:{r.tenant}",
                               request_id=r.request_id, workload=workload)
        return joined
