"""Admission control + per-tenant request queues with deadlines.

A ``Request`` names a workload (a registered FHE program — its trace is
compiled once and cached) and how many CKKS slots its encrypted payload
occupies. Admission rejects when a tenant's queue is full
(load-shedding at the door beats timing out deep in the pipeline), and
dequeue drops requests whose deadline already passed — the batcher
never wastes pipeline rounds on work nobody is waiting for.

Dequeue order is round-robin across tenants (one request per tenant
per rotation) so one heavy tenant cannot starve the rest — the
multi-tenant analogue of the paper's fair use of pipeline rounds
across the input batch.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.runtime.metrics import MetricsRegistry


class RequestStatus(enum.Enum):
    QUEUED = "queued"
    BATCHED = "batched"
    COMPLETED = "completed"
    REJECTED = "rejected"
    DEADLINE_MISS = "deadline_miss"


@dataclasses.dataclass
class Request:
    request_id: int
    tenant: str
    workload: str                    # key into the executor's workload registry
    arrival_s: float
    slots_needed: int = 1            # CKKS slots the encrypted payload occupies
    deadline_s: Optional[float] = None   # absolute; None = best-effort
    payload: object = None           # opaque ciphertext (mesh backend) or None
    status: RequestStatus = RequestStatus.QUEUED
    completion_s: Optional[float] = None
    service_start_s: Optional[float] = None   # backend execution began
    #                                           (latency = queue delay up
    #                                           to here + service after)

    def latency(self) -> float:
        assert self.completion_s is not None
        return self.completion_s - self.arrival_s

    def expired(self, now: float) -> bool:
        return self.deadline_s is not None and now > self.deadline_s


class AdmissionQueue:
    """Per-tenant FIFO queues behind one admission door."""

    def __init__(self, max_depth_per_tenant: int = 256,
                 metrics: Optional[MetricsRegistry] = None):
        self.max_depth = max_depth_per_tenant
        self.queues: Dict[str, Deque[Request]] = {}
        self.metrics = metrics or MetricsRegistry()
        # telemetry label for the queue-depth series: the fleet sets
        # this to the owning device id (Device ctor); "0" is the
        # single-executor door
        self.owner = "0"
        self._rr = itertools.count()     # tenant rotation cursor
        self._id = itertools.count()

    def next_request_id(self) -> int:
        return next(self._id)

    def __len__(self) -> int:
        return sum(len(q) for q in self.queues.values())

    # -- admission -----------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Admit or reject (tenant queue full). Returns admitted."""
        tr, log = self.metrics.tracer, self.metrics.event_log
        q = self.queues.setdefault(req.tenant, deque())
        if len(q) >= self.max_depth:
            req.status = RequestStatus.REJECTED
            self.metrics.incr("requests_rejected")
            if tr is not None:
                tr.close_root(req, req.arrival_s, "rejected",
                              reason="tenant_queue_full")
            if log is not None:
                log.emit("rejected", req.arrival_s, req,
                         reason="tenant_queue_full")
            return False
        q.append(req)
        self.metrics.incr("requests_admitted")
        if tr is not None:
            tr.ensure_root(req)
        if log is not None:
            log.emit("accepted", req.arrival_s, req,
                     queue_depth=len(q))
        tel = self.metrics.telemetry
        if tel is not None:
            tel.gauge("fhe_device_queue_depth", device=self.owner).set(
                req.arrival_s, len(self))
        return True

    # -- dequeue -------------------------------------------------------------

    def _drop_expired(self, q: Deque[Request], now: float) -> None:
        """Purge expired requests anywhere in the queue (not just the
        front) so demand accounting and take() never see — let alone
        batch — work nobody is waiting for. Every drop is attributed to
        its tenant (goodput accounting needs the miss charged somewhere,
        not silently discarded)."""
        if not any(r.expired(now) for r in q):
            return
        tr, log = self.metrics.tracer, self.metrics.event_log
        tel, slo = self.metrics.telemetry, self.metrics.slo
        live = []
        for r in q:
            if r.expired(now):
                r.status = RequestStatus.DEADLINE_MISS
                self.metrics.incr("deadline_misses")
                self.metrics.incr("deadline_misses_dequeue")
                self.metrics.incr_tenant("deadline_misses", r.tenant)
                if tr is not None:
                    tr.close_root(r, now, "dropped_expired")
                if log is not None:
                    log.emit("dropped", now, r)
                if tel is not None:
                    tel.counter("fhe_requests_finished",
                                status="dropped_expired").inc(now)
                if slo is not None:
                    # a drop is a miss the service loop never sees —
                    # it must still burn the error budget
                    slo.record(now, True, self.metrics)
            else:
                live.append(r)
        q.clear()
        q.extend(live)
        if tel is not None:
            tel.gauge("fhe_device_queue_depth", device=self.owner).set(
                now, len(self))

    def oldest_arrival(self, now: float,
                       workload: Optional[str] = None) -> Optional[float]:
        """Earliest arrival among live queued requests (batcher's max-wait
        clock), optionally restricted to one workload."""
        best = None
        for q in self.queues.values():
            self._drop_expired(q, now)
            for r in q:
                if workload is not None and r.workload != workload:
                    continue
                if best is None or r.arrival_s < best:
                    best = r.arrival_s
        return best

    def pending_workloads(self, now: float) -> List[str]:
        """Workloads with live queued requests, in first-arrival order."""
        first: Dict[str, float] = {}
        for q in self.queues.values():
            self._drop_expired(q, now)
            for r in q:
                if r.workload not in first or r.arrival_s < first[r.workload]:
                    first[r.workload] = r.arrival_s
        return sorted(first, key=first.get)

    def pending_demand(self, now: float, workload: str) -> Tuple[int, int]:
        """(live request count, total slots) queued for ``workload``."""
        n, slots = 0, 0
        for q in self.queues.values():
            self._drop_expired(q, now)
            for r in q:
                if r.workload == workload:
                    n += 1
                    slots += r.slots_needed
        return n, slots

    def earliest_deadline(self, now: float,
                          workload: str) -> Optional[float]:
        best = None
        for q in self.queues.values():
            self._drop_expired(q, now)
            for r in q:
                if r.workload == workload and r.deadline_s is not None:
                    if best is None or r.deadline_s < best:
                        best = r.deadline_s
        return best

    def requeue(self, req: Request) -> None:
        """Return a dequeued request to the FRONT of its tenant queue
        (batcher overflow — no admission check, no metrics double-count)."""
        req.status = RequestStatus.QUEUED
        self.queues.setdefault(req.tenant, deque()).appendleft(req)

    def take(self, now: float, workload: str, max_requests: int,
             max_slots: Optional[int] = None) -> List[Request]:
        """Dequeue up to ``max_requests`` live requests of ``workload``,
        round-robin across tenants, bounded by total ``max_slots``.

        A request whose ``slots_needed`` would overflow the remaining
        slot budget is left queued (never split across batches).
        """
        tenants = sorted(self.queues)
        if not tenants:
            return []
        start = next(self._rr) % len(tenants)
        order = tenants[start:] + tenants[:start]
        out: List[Request] = []
        slots_left = max_slots if max_slots is not None else float("inf")
        progressed = True
        while progressed and len(out) < max_requests:
            progressed = False
            for t in order:
                if len(out) >= max_requests:
                    break
                q = self.queues[t]
                self._drop_expired(q, now)
                # peek first matching request of this tenant
                for i, r in enumerate(q):
                    if r.workload != workload:
                        continue
                    if r.slots_needed > slots_left:
                        break              # preserve FIFO within tenant
                    del q[i]
                    r.status = RequestStatus.BATCHED
                    out.append(r)
                    slots_left -= r.slots_needed
                    progressed = True
                    break
        return out
