"""ArchConfig-driven model assembly: parameter schemas (with logical
sharding axes), forward passes (train/prefill), stateful decode, and
jit-able step builders for every assigned architecture family.

Layer stacks are `lax.scan`-ned over stacked parameters (compile-time sane
at 61-100 layers) with `jax.checkpoint` on block bodies (activation remat).
Heterogeneous stacks use scanned super-blocks plus explicit tail layers
(e.g. recurrentgemma's 26 = 8 x [rec,rec,attn] + [rec,rec]).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.config import ArchConfig
from repro.models.layers import cross_attention, rmsnorm, swiglu
from repro.sharding.rules import default_rules, spec_for_shape

F32 = jnp.float32

# When True, layer-stack scans are fully unrolled. Used by the roofline
# driver: XLA cost_analysis does not scale while-loop bodies by trip count,
# so rooflines are measured on unrolled reduced-depth configs and
# extrapolated (launch/roofline.py). Never enable for full-depth configs.
SCAN_UNROLL = False

# Remat policy for scanned blocks. 'dots' saves matmul outputs (no fwd
# recompute in backward — EXPERIMENTS.md §Perf iteration 3); 'full'
# recomputes everything (minimum memory).
REMAT_POLICY = "full"


def _remat(f):
    if REMAT_POLICY == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f)


def _scan(f, init, xs):
    return jax.lax.scan(f, init, xs, unroll=True if SCAN_UNROLL else 1)


# ---------------------------------------------------------------------------
# parameter schema
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    scale: float = 0.02


def _dense_mlp_schema(cfg, d_ff):
    d = cfg.d_model
    return {
        "w_gate": PSpec((d, d_ff), ("embed", "mlp")),
        "w_up": PSpec((d, d_ff), ("embed", "mlp")),
        "w_down": PSpec((d_ff, d), ("mlp", "embed")),
    }


def _gqa_schema(cfg):
    d, h, hkv, dh = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                     cfg.resolved_head_dim)
    s = {
        "wq": PSpec((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((h * dh, d), ("mlp", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = PSpec((dh,), ("embed_repl",), 1.0)
        s["k_norm"] = PSpec((dh,), ("embed_repl",), 1.0)
    return s


def _mla_schema(cfg):
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": PSpec((d, cfg.q_lora_rank), ("embed", "q_lora")),
        "q_norm": PSpec((cfg.q_lora_rank,), ("embed_repl",), 1.0),
        "wq_b": PSpec((cfg.q_lora_rank, h, dn + dr),
                      (None, "heads", "head_dim")),
        "wkv_a": PSpec((d, cfg.kv_lora_rank + dr), ("embed", None)),
        "kv_norm": PSpec((cfg.kv_lora_rank,), ("embed_repl",), 1.0),
        "wkv_b": PSpec((cfg.kv_lora_rank, h * (dn + dv)), (None, "mlp")),
        "wo": PSpec((h * dv, d), ("mlp", "embed")),
    }


def _moe_schema(cfg):
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    s = {
        "w_router": PSpec((d, e), ("embed", None)),
        "w_gate": PSpec((e, d, fe), ("experts", "embed", None)),
        "w_up": PSpec((e, d, fe), ("experts", "embed", None)),
        "w_down": PSpec((e, fe, d), ("experts", None, "embed")),
    }
    if cfg.n_shared_experts:
        s["shared"] = _dense_mlp_schema(cfg, cfg.d_ff_expert * cfg.n_shared_experts)
    if cfg.dense_residual:
        s["dense"] = _dense_mlp_schema(cfg, cfg.d_ff)
    return s


def _rwkv_schema(cfg):
    d = cfg.d_model
    lora_r = 64
    h = d // rec.RWKV_HEAD_DIM
    tm = {
        **{f"mu_{n}": PSpec((d,), ("embed_repl",), 0.5)
           for n in ("r", "k", "v", "g", "w")},
        "wr": PSpec((d, d), ("embed", "mlp")),
        "wk": PSpec((d, d), ("embed", "mlp")),
        "wv": PSpec((d, d), ("embed", "mlp")),
        "wg": PSpec((d, d), ("embed", "mlp")),
        "wo": PSpec((d, d), ("mlp", "embed")),
        "w_lora_a": PSpec((d, lora_r), ("embed", None)),
        "w_lora_b": PSpec((lora_r, d), (None, "embed")),
        "w0": PSpec((d,), ("embed_repl",), 0.5),
        "u_bonus": PSpec((d,), ("embed_repl",), 0.5),
        "ln_x_w": PSpec((d,), ("embed_repl",), 1.0),
    }
    cm = {
        "mu_ck": PSpec((d,), ("embed_repl",), 0.5),
        "mu_cr": PSpec((d,), ("embed_repl",), 0.5),
        "w_key": PSpec((d, cfg.d_ff), ("embed", "mlp")),
        "w_value": PSpec((cfg.d_ff, d), ("mlp", "embed")),
        "w_recept": PSpec((d, d), ("embed", "mlp")),
    }
    return {"ln1": PSpec((d,), ("embed_repl",), 1.0), "time_mix": tm,
            "ln2": PSpec((d,), ("embed_repl",), 1.0), "channel_mix": cm}


def _rglru_schema(cfg):
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    return {
        "w_in_y": PSpec((d, w), ("embed", "mlp")),
        "w_in_g": PSpec((d, w), ("embed", "mlp")),
        "conv_w": PSpec((cfg.conv_width, w), ("conv", "mlp"), 0.1),
        "w_a": PSpec((w,), ("embed_repl",), 0.1),
        "b_a": PSpec((w,), ("embed_repl",), 0.1),
        "w_x": PSpec((w,), ("embed_repl",), 0.1),
        "b_x": PSpec((w,), ("embed_repl",), 0.1),
        "lambda_p": PSpec((w,), ("embed_repl",), 0.5),
        "w_out": PSpec((w, d), ("mlp", "embed")),
    }


def _xattn_schema(cfg):
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    hkv = max(cfg.n_kv_heads, 1)
    return {
        "wq": PSpec((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((h * dh, d), ("mlp", "embed")),
        "gate": PSpec((1,), ("embed_repl",), 0.0),
    }


def _block_schema(cfg, kind: str):
    d = cfg.d_model
    base = {"attn_norm": PSpec((d,), ("embed_repl",), 1.0),
            "mlp_norm": PSpec((d,), ("embed_repl",), 1.0)}
    if kind == "dense":
        base["attn"] = (_mla_schema(cfg) if cfg.attention == "mla"
                        else _gqa_schema(cfg))
        ff = 18432 if (cfg.name.startswith("deepseek")) else cfg.d_ff
        base["mlp"] = _dense_mlp_schema(cfg, ff)
    elif kind == "moe":
        base["attn"] = (_mla_schema(cfg) if cfg.attention == "mla"
                        else _gqa_schema(cfg))
        base["moe"] = _moe_schema(cfg)
    elif kind == "xattn":
        base["attn"] = _xattn_schema(cfg)
        base["mlp"] = _dense_mlp_schema(cfg, cfg.d_ff)
    elif kind == "rwkv":
        return _rwkv_schema(cfg)
    elif kind == "rglru":
        base["attn"] = _rglru_schema(cfg)
        base["mlp"] = _dense_mlp_schema(cfg, cfg.d_ff)
    elif kind == "attn":   # recurrentgemma local-attention layer
        base["attn"] = _gqa_schema(cfg)
        base["mlp"] = _dense_mlp_schema(cfg, cfg.d_ff)
    else:
        raise ValueError(kind)
    return base


def _stack(schema, n: int):
    """Add a leading layer axis to every PSpec in a schema subtree."""
    def f(ps: PSpec):
        return PSpec((n,) + ps.shape, ("layers",) + ps.logical, ps.scale)
    return jax.tree.map(f, schema,
                        is_leaf=lambda x: isinstance(x, PSpec))


def param_schema(cfg: ArchConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.vocab
    s: Dict[str, Any] = {
        "embed": PSpec((v, d), ("vocab", "embed")),
        "final_norm": PSpec((d,), ("embed_repl",), 1.0),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = PSpec((d, v), ("embed", "vocab"))
    if cfg.enc_dec:
        s["enc_blocks"] = _stack(_block_schema(cfg, "dense"), cfg.n_enc_layers)
        dec = _block_schema(cfg, "dense")
        dec["xattn"] = _xattn_schema(cfg)
        dec["xattn_norm"] = PSpec((d,), ("embed_repl",), 1.0)
        s["dec_blocks"] = _stack(dec, cfg.n_layers)
        s["enc_final_norm"] = PSpec((d,), ("embed_repl",), 1.0)
    elif cfg.xattn_period:
        n_super = cfg.n_layers // (cfg.xattn_period + 1)
        sb = {"self": _stack(_block_schema(cfg, "dense"), cfg.xattn_period),
              "cross": _block_schema(cfg, "xattn")}
        s["superblocks"] = _stack(sb, n_super)
    elif cfg.rwkv:
        s["blocks"] = _stack(_block_schema(cfg, "rwkv"), cfg.n_layers)
    elif cfg.rglru:
        pat = cfg.block_pattern or ("rglru", "rglru", "attn")
        n_super = cfg.n_layers // len(pat)
        tail = cfg.n_layers - n_super * len(pat)
        sb = {f"l{i}_{k}": _block_schema(cfg, k) for i, k in enumerate(pat)}
        s["superblocks"] = _stack(sb, n_super)
        for i in range(tail):
            s[f"tail_{i}"] = _block_schema(cfg, pat[i])
    elif cfg.n_experts:
        if cfg.first_k_dense:
            s["dense_blocks"] = _stack(_block_schema(cfg, "dense"),
                                       cfg.first_k_dense)
        s["moe_blocks"] = _stack(_block_schema(cfg, "moe"),
                                 cfg.n_layers - cfg.first_k_dense)
    else:
        s["blocks"] = _stack(_block_schema(cfg, "dense"), cfg.n_layers)
    if cfg.mtp:
        s["mtp_block"] = _block_schema(cfg, "dense")
        s["mtp_norm"] = PSpec((d,), ("embed_repl",), 1.0)
    return s


def _is_pspec(x):
    return isinstance(x, PSpec)


def abstract_params(cfg: ArchConfig):
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(lambda ps: jax.ShapeDtypeStruct(ps.shape, dt),
                        param_schema(cfg), is_leaf=_is_pspec)


def logical_axes(cfg: ArchConfig):
    return jax.tree.map(lambda ps: ps.logical, param_schema(cfg),
                        is_leaf=_is_pspec)


def param_specs(cfg: ArchConfig, mesh: Mesh, rules=None):
    rules = rules or default_rules()
    return jax.tree.map(
        lambda ps: NamedSharding(
            mesh, spec_for_shape(mesh, ps.logical, ps.shape, rules)),
        param_schema(cfg), is_leaf=_is_pspec)


def init_params(cfg: ArchConfig, key):
    """Concrete random init (smoke tests / examples)."""
    dt = jnp.dtype(cfg.dtype)
    schema = param_schema(cfg)
    leaves, treedef = jax.tree.flatten(schema, is_leaf=_is_pspec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for ps, k in zip(leaves, keys):
        if ps.scale == 1.0 and len(ps.shape) <= 2:   # norm weights
            out.append(jnp.ones(ps.shape, dt))
        else:
            out.append(jax.random.normal(k, ps.shape, dt) * ps.scale)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def _self_attn(x, bp, cfg, positions):
    h = rmsnorm(x, bp["attn_norm"], cfg.norm_eps)
    if cfg.attention == "mla":
        o, kv = attn.mla_forward(h, bp["attn"], cfg, positions)
    else:
        o, kv = attn.gqa_forward(h, bp["attn"], cfg, positions)
    return x + o, kv


def _mlp(x, bp, cfg, d_ff=None):
    h = rmsnorm(x, bp["mlp_norm"], cfg.norm_eps)
    m = bp["mlp"]
    return x + swiglu(h, m["w_gate"], m["w_up"], m["w_down"])


def _moe_layer(x, bp, cfg, mesh, variant="auto"):
    """x (B,S,D) -> (B,S,D), aux. Chooses all_to_all when tokens split
    evenly over the model axis, else the psum schedule."""
    b, s, d = x.shape
    h = rmsnorm(x, bp["mlp_norm"], cfg.norm_eps)
    tokens = h.reshape(b * s, d)
    m = bp["moe"]
    model_n = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_n = int(np.prod([mesh.shape[a] for a in dp_axes]))
    use_a2a = (variant == "a2a" or
               (variant == "auto" and (b * s) % (dp_n * model_n) == 0
                and (b * s) // (dp_n * model_n) >= 8))
    wspec = (P("model", None, None),) * 3
    if use_a2a:
        body = partial(moe_mod.moe_all_to_all, cfg=cfg)
        tok_spec = P((*dp_axes, "model"), None)
    else:
        body = partial(moe_mod.moe_psum, cfg=cfg)
        tok_spec = P(dp_axes, None)
    from repro.compat import shard_map
    mapped = shard_map(
        lambda t, wr, wg, wu, wd: body(
            t, {"w_router": wr, "w_gate": wg, "w_up": wu, "w_down": wd}),
        mesh,
        (tok_spec, P(None, None)) + wspec,
        (tok_spec, P()))
    out, aux = mapped(tokens, m["w_router"], m["w_gate"], m["w_up"],
                      m["w_down"])
    aux = jnp.mean(aux)
    out = out.reshape(b, s, d)
    if cfg.n_shared_experts:
        sh = m["shared"]
        out = out + swiglu(h, sh["w_gate"], sh["w_up"], sh["w_down"])
    if cfg.dense_residual:
        dn = m["dense"]
        out = out + swiglu(h, dn["w_gate"], dn["w_up"], dn["w_down"])
    return x + out, aux


def _batch_constraint(x, mesh):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1)))))


def forward(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            mesh: Mesh, collect_cache: bool = False):
    """Returns (logits, aux_losses, cache_or_None).

    batch: tokens (B,S) [+ images (B,Timg,D) | frames (B,Senc,D)].
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = _batch_constraint(x, mesh)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux_total = jnp.zeros((), F32)
    caches: Dict[str, Any] = {}

    def dense_block(x, bp):
        x, kv = _self_attn(x, bp, cfg, positions)
        x = _mlp(x, bp, cfg)
        return _batch_constraint(x, mesh), kv

    if cfg.enc_dec:
        frames = batch["frames"].astype(x.dtype)
        enc_x = _batch_constraint(frames, mesh)
        enc_pos = jnp.broadcast_to(
            jnp.arange(frames.shape[1], dtype=jnp.int32),
            frames.shape[:2])

        def enc_block(h, bp):
            hn = rmsnorm(h, bp["attn_norm"], cfg.norm_eps)
            o, _ = attn.gqa_forward(hn, bp["attn"], cfg, enc_pos)
            h = h + o
            return _mlp(h, bp, cfg), None

        enc_x, _ = _scan(
            lambda h, bp: _remat(enc_block)(h, bp),
            enc_x, params["enc_blocks"])
        memory = rmsnorm(enc_x, params["enc_final_norm"], cfg.norm_eps)

        def dec_block(h, bp):
            h, kv = _self_attn(h, bp, cfg, positions)
            hx = rmsnorm(h, bp["xattn_norm"], cfg.norm_eps)
            g = jnp.tanh(bp["xattn"]["gate"].astype(F32)).astype(h.dtype)
            h = h + g * cross_attention(hx, memory, bp["xattn"], cfg)
            return _mlp(h, bp, cfg), kv

        x, kvs = _scan(
            lambda h, bp: _remat(dec_block)(h, bp),
            x, params["dec_blocks"])
        if collect_cache:
            caches = {"self_kv": kvs, "memory": memory}

    elif cfg.xattn_period:
        images = batch["images"].astype(x.dtype)

        def superblock(h, sbp):
            h, kvs = _scan(
                lambda hh, bp: _remat(dense_block)(hh, bp),
                h, sbp["self"])
            cb = sbp["cross"]
            hn = rmsnorm(h, cb["attn_norm"], cfg.norm_eps)
            g = jnp.tanh(cb["attn"]["gate"].astype(F32)).astype(h.dtype)
            h = h + g * cross_attention(hn, images, cb["attn"], cfg)
            h = h + swiglu(rmsnorm(h, cb["mlp_norm"], cfg.norm_eps),
                           cb["mlp"]["w_gate"], cb["mlp"]["w_up"],
                           cb["mlp"]["w_down"])
            return _batch_constraint(h, mesh), kvs

        x, kvs = _scan(superblock, x, params["superblocks"])
        if collect_cache:
            caches = {"self_kv": kvs, "images": images}

    elif cfg.rwkv:
        def rwkv_block(h, bp):
            o, (st, xl) = rec.rwkv_time_mix(
                rmsnorm(h, bp["ln1"], cfg.norm_eps), bp["time_mix"], cfg)
            h = h + o
            o, xl2 = rec.rwkv_channel_mix(
                rmsnorm(h, bp["ln2"], cfg.norm_eps), bp["channel_mix"], cfg)
            return h + o, (st, xl, xl2)

        x, states = _scan(
            lambda h, bp: _remat(rwkv_block)(h, bp),
            x, params["blocks"])
        if collect_cache:
            caches = {"states": states}

    elif cfg.rglru:
        pat = cfg.block_pattern or ("rglru", "rglru", "attn")

        def one_layer(h, bp, kind):
            if kind == "rglru":
                hn = rmsnorm(h, bp["attn_norm"], cfg.norm_eps)
                o, st = rec.rglru_block(hn, bp["attn"], cfg)
                h = h + o
                return _mlp(h, bp, cfg), st
            h, kv = _self_attn(h, bp, cfg, positions)
            return _mlp(h, bp, cfg), kv

        def superblock(h, sbp):
            sts = []
            for i, kind in enumerate(pat):
                h, st = _remat(partial(one_layer, kind=kind))(
                    h, sbp[f"l{i}_{kind}"])
                sts.append(st)
            return _batch_constraint(h, mesh), tuple(sts)

        x, states = _scan(superblock, x, params["superblocks"])
        tail_states = []
        n_super = cfg.n_layers // len(pat)
        for i in range(cfg.n_layers - n_super * len(pat)):
            x, st = one_layer(x, params[f"tail_{i}"], pat[i])
            tail_states.append(st)
        if collect_cache:
            caches = {"states": states, "tail_states": tuple(tail_states)}

    elif cfg.n_experts:
        kv_dense = None
        if cfg.first_k_dense:
            x, kv_dense = _scan(
                lambda h, bp: _remat(dense_block)(h, bp),
                x, params["dense_blocks"])

        def moe_block(h, bp):
            h, kv = _self_attn(h, bp, cfg, positions)
            h, aux = _moe_layer(h, bp, cfg, mesh)
            return h, (kv, aux)

        x, (kv_moe, auxes) = _scan(
            lambda h, bp: _remat(moe_block)(h, bp),
            x, params["moe_blocks"])
        aux_total = aux_total + jnp.sum(auxes)
        if collect_cache:
            caches = {"kv_dense": kv_dense, "kv_moe": kv_moe}

    else:
        x, kvs = _scan(
            lambda h, bp: _remat(dense_block)(h, bp),
            x, params["blocks"])
        if collect_cache:
            caches = {"kv": kvs}

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head)

    mtp_logits = None
    if cfg.mtp:
        h2 = rmsnorm(x, params["mtp_norm"], cfg.norm_eps)
        h2, _ = _self_attn(h2, params["mtp_block"], cfg, positions)
        h2 = _mlp(h2, params["mtp_block"], cfg)
        mtp_logits = jnp.einsum("bsd,dv->bsv", h2, head)

    return logits, mtp_logits, aux_total, (caches if collect_cache else None)


# ---------------------------------------------------------------------------
# losses / train step
# ---------------------------------------------------------------------------

def _ce(logits, labels):
    """CE without materializing (B,S,V) f32 log-probs (§Perf iteration 3b):
    gather the label logit first, reduce the logsumexp in f32 on the fly."""
    label_logit = jnp.take_along_axis(
        logits, labels[..., None], axis=-1)[..., 0].astype(F32)
    m = jnp.max(logits, axis=-1).astype(F32)
    lse = m + jnp.log(jnp.sum(
        jnp.exp(logits.astype(F32) - m[..., None]), axis=-1))
    return jnp.mean(lse - label_logit)


def loss_fn(params, cfg: ArchConfig, batch, mesh):
    logits, mtp_logits, aux, _ = forward(params, cfg, batch, mesh)
    labels = batch["labels"]
    loss = _ce(logits, labels)
    metrics = {"ce": loss}
    if cfg.n_experts:
        loss = loss + cfg.router_aux_weight * aux
        metrics["aux"] = aux
    if cfg.mtp and mtp_logits is not None:
        # MTP head predicts token t+2: shift labels one extra step left
        mtp_labels = jnp.concatenate(
            [labels[:, 1:], labels[:, -1:]], axis=1)
        mtp_loss = _ce(mtp_logits[:, :-1], mtp_labels[:, :-1])
        loss = loss + 0.3 * mtp_loss
        metrics["mtp_ce"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(cfg: ArchConfig, mesh: Mesh, learning_rate: float = 3e-4,
                    weight_decay: float = 0.1, grad_clip: float = 1.0):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).
    AdamW with ZeRO-style sharded states (same specs as params)."""
    from repro.train.optim import adamw_update, clip_by_global_norm

    def train_step(params, opt_state, batch):
        grad_fn = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, mesh), has_aux=True)
        (loss, metrics), grads = grad_fn(params)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        params, opt_state = adamw_update(params, grads, opt_state,
                                         lr=learning_rate, wd=weight_decay)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# caches (decode state) — schemas + zero init
# ---------------------------------------------------------------------------

def cache_schema(cfg: ArchConfig, batch: int, s_max: int) -> Dict[str, Any]:
    """Pytree of PSpec describing the decode cache."""
    dt = cfg.dtype
    hkv, dh = max(cfg.n_kv_heads, 1), cfg.resolved_head_dim
    kv_axes = ("layers", "batch", "kv_heads", "seq", "head_dim")

    def kv(n_layers, s=s_max):
        return {"k": PSpec((n_layers, batch, hkv, s, dh), kv_axes),
                "v": PSpec((n_layers, batch, hkv, s, dh), kv_axes)}

    if cfg.attention == "mla":
        lat = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        mla_axes = ("layers", "batch", "seq", None)
        out = {}
        if cfg.first_k_dense:
            out["dense"] = PSpec((cfg.first_k_dense, batch, s_max, lat),
                                 mla_axes)
        out["moe"] = PSpec((cfg.n_layers - cfg.first_k_dense, batch, s_max,
                            lat), mla_axes)
        return out
    if cfg.enc_dec:
        return {"self": kv(cfg.n_layers),
                "memory": PSpec((batch, 4096, cfg.d_model),
                                ("batch", "seq", "embed_repl"))}
    if cfg.xattn_period:
        n_super = cfg.n_layers // (cfg.xattn_period + 1)
        return {"self": {"k": PSpec((n_super, cfg.xattn_period, batch, hkv,
                                     s_max, dh), ("layers",) + kv_axes),
                         "v": PSpec((n_super, cfg.xattn_period, batch, hkv,
                                     s_max, dh), ("layers",) + kv_axes)},
                "images": PSpec((batch, cfg.n_img_tokens, cfg.d_model),
                                ("batch", "seq", "embed_repl"))}
    if cfg.rwkv:
        h = cfg.d_model // rec.RWKV_HEAD_DIM
        return {"wkv": PSpec((cfg.n_layers, batch, h, rec.RWKV_HEAD_DIM,
                              rec.RWKV_HEAD_DIM),
                             ("layers", "batch", "heads", None, None)),
                "x_tm": PSpec((cfg.n_layers, batch, cfg.d_model),
                              ("layers", "batch", "embed_repl")),
                "x_cm": PSpec((cfg.n_layers, batch, cfg.d_model),
                              ("layers", "batch", "embed_repl"))}
    if cfg.rglru:
        pat = cfg.block_pattern or ("rglru", "rglru", "attn")
        n_super = cfg.n_layers // len(pat)
        w = cfg.lru_width or cfg.d_model
        window = min(cfg.local_window, s_max)
        out = {}
        for i, kind in enumerate(pat):
            if kind == "rglru":
                out[f"conv_{i}"] = PSpec(
                    (n_super, batch, cfg.conv_width - 1, w),
                    ("layers", "batch", None, "mlp"))
                out[f"lru_{i}"] = PSpec((n_super, batch, w),
                                        ("layers", "batch", "mlp"))
            else:
                out[f"k_{i}"] = PSpec((n_super, batch, hkv, window, dh),
                                      kv_axes)
                out[f"v_{i}"] = PSpec((n_super, batch, hkv, window, dh),
                                      kv_axes)
                out[f"pos_{i}"] = PSpec((n_super, window),
                                        ("layers", None))
        # tail layers (pattern prefix)
        tail = cfg.n_layers - n_super * len(pat)
        for i in range(tail):
            if pat[i] == "rglru":
                out[f"tconv_{i}"] = PSpec((batch, cfg.conv_width - 1, w),
                                          ("batch", None, "mlp"))
                out[f"tlru_{i}"] = PSpec((batch, w), ("batch", "mlp"))
            else:
                out[f"tk_{i}"] = PSpec((batch, hkv, window, dh), kv_axes[1:])
                out[f"tv_{i}"] = PSpec((batch, hkv, window, dh), kv_axes[1:])
                out[f"tpos_{i}"] = PSpec((window,), (None,))
        return out
    return kv(cfg.n_layers)


def abstract_cache(cfg: ArchConfig, batch: int, s_max: int):
    def f(ps: PSpec):
        dt = jnp.int32 if "pos" in str(ps.logical) else jnp.dtype(cfg.dtype)
        return jax.ShapeDtypeStruct(ps.shape, dt)
    sch = cache_schema(cfg, batch, s_max)
    out = {}
    for k, v in sch.items():
        if isinstance(v, PSpec):
            dt = jnp.int32 if k.startswith(("pos", "tpos")) else jnp.dtype(cfg.dtype)
            out[k] = jax.ShapeDtypeStruct(v.shape, dt)
        else:
            out[k] = jax.tree.map(
                lambda ps: jax.ShapeDtypeStruct(ps.shape, jnp.dtype(cfg.dtype)),
                v, is_leaf=_is_pspec)
    return out


def cache_specs(cfg: ArchConfig, mesh: Mesh, batch: int, s_max: int,
                rules=None):
    rules = rules or default_rules()
    return jax.tree.map(
        lambda ps: NamedSharding(
            mesh, spec_for_shape(mesh, ps.logical, ps.shape, rules)),
        cache_schema(cfg, batch, s_max), is_leaf=_is_pspec)


def init_cache(cfg: ArchConfig, batch: int, s_max: int):
    ab = abstract_cache(cfg, batch, s_max)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), ab)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------

def _ring_local_decode(x, bp, cfg, k_cache, v_cache, kv_pos, pos):
    """Sliding-window decode with a ring-buffer cache (window-sized)."""
    from repro.models.layers import (apply_rope, rope_angles)
    import math as _math
    b = x.shape[0]
    h, hkv, dh = cfg.n_heads, max(cfg.n_kv_heads, 1), cfg.resolved_head_dim
    hn = rmsnorm(x, bp["attn_norm"], cfg.norm_eps)
    p = bp["attn"]
    q = jnp.einsum("bsd,dhk->bshk", hn, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", hn, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", hn, p["wv"])
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    cos, sin = rope_angles(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin).transpose(0, 2, 1, 3)
    k = apply_rope(k, cos, sin).transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    window = k_cache.shape[2]
    # int32-uniform indices: x64 mode (FHE core) must not change promotion
    slot = jnp.mod(pos, window).astype(jnp.int32)
    zero = jnp.int32(0)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (zero, zero, slot, zero))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (zero, zero, slot, zero))
    kv_pos = jax.lax.dynamic_update_slice(kv_pos, pos[None].astype(jnp.int32),
                                          (slot,))
    g, hg = hkv, h // hkv
    qg = q.reshape(b, g, hg, 1, dh)
    s = jnp.einsum("bghqd,bgkd->bghqk", qg, k_cache).astype(F32)
    s = s / _math.sqrt(dh)
    valid = (kv_pos <= pos) & (pos - kv_pos < window) & (kv_pos >= 0)
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    pattn = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bghqk,bgkd->bghqd", pattn.astype(v_cache.dtype), v_cache)
    o = o.reshape(b, h, 1, dh).transpose(0, 2, 1, 3).reshape(b, 1, h * dh)
    x = x + jnp.einsum("bse,ed->bsd", o, p["wo"])
    return _mlp(x, bp, cfg), k_cache, v_cache, kv_pos


def decode_forward(params, cfg: ArchConfig, cache, tokens, pos, mesh: Mesh):
    """One decode step. tokens (B,) int32; pos: scalar int32 (current index).
    Returns (logits (B,V), new_cache)."""
    b = tokens.shape[0]
    x = params["embed"][tokens][:, None, :].astype(jnp.dtype(cfg.dtype))
    new_cache = dict(cache)

    def dense_decode(h, bp, kc, vc):
        hn = rmsnorm(h, bp["attn_norm"], cfg.norm_eps)
        o, (kc, vc) = attn.gqa_decode(hn, bp["attn"], cfg, (kc, vc), pos)
        h = h + o
        return _mlp(h, bp, cfg), kc, vc

    if cfg.attention == "mla":
        def mla_dec(h, bp, lat):
            hn = rmsnorm(h, bp["attn_norm"], cfg.norm_eps)
            o, lat = attn.mla_decode(hn, bp["attn"], cfg, lat, pos)
            return h + o, lat

        if cfg.first_k_dense:
            def body_d(h, xs):
                bp, lat = xs
                h, lat = mla_dec(h, bp, lat)
                return _mlp(h, bp, cfg), lat
            x, new_cache["dense"] = _scan(
                body_d, x, (params["dense_blocks"], cache["dense"]))

        def body_m(h, xs):
            bp, lat = xs
            h, lat = mla_dec(h, bp, lat)
            if cfg.n_experts:
                h, _ = _moe_layer(h, bp, cfg, mesh, variant="psum")
            else:
                h = _mlp(h, bp, cfg)
            return h, lat
        blocks_key = "moe_blocks" if cfg.n_experts else "blocks"
        x, new_cache["moe"] = _scan(
            body_m, x, (params[blocks_key], cache["moe"]))

    elif cfg.enc_dec:
        memory = cache["memory"].astype(x.dtype)

        def body(h, xs):
            bp, kc, vc = xs
            h, kc, vc = dense_decode(h, bp, kc, vc)
            hx = rmsnorm(h, bp["xattn_norm"], cfg.norm_eps)
            g = jnp.tanh(bp["xattn"]["gate"].astype(F32)).astype(h.dtype)
            h = h + g * cross_attention(hx, memory, bp["xattn"], cfg)
            return h, (kc, vc)
        x, (ks, vs) = _scan(
            body, x, (params["dec_blocks"], cache["self"]["k"],
                      cache["self"]["v"]))
        new_cache["self"] = {"k": ks, "v": vs}

    elif cfg.xattn_period:
        images = cache["images"].astype(x.dtype)

        def superblock(h, xs):
            sbp, kc, vc = xs
            def inner(hh, ys):
                bp, k1, v1 = ys
                hh, k1, v1 = dense_decode(hh, bp, k1, v1)
                return hh, (k1, v1)
            h, (kc, vc) = _scan(inner, h, (sbp["self"], kc, vc))
            cb = sbp["cross"]
            hn = rmsnorm(h, cb["attn_norm"], cfg.norm_eps)
            g = jnp.tanh(cb["attn"]["gate"].astype(F32)).astype(h.dtype)
            h = h + g * cross_attention(hn, images, cb["attn"], cfg)
            h = h + swiglu(rmsnorm(h, cb["mlp_norm"], cfg.norm_eps),
                           cb["mlp"]["w_gate"], cb["mlp"]["w_up"],
                           cb["mlp"]["w_down"])
            return h, (kc, vc)
        x, (ks, vs) = _scan(
            superblock, x, (params["superblocks"], cache["self"]["k"],
                            cache["self"]["v"]))
        new_cache["self"] = {"k": ks, "v": vs}

    elif cfg.rwkv:
        def body(h, xs):
            bp, st, x_tm, x_cm = xs
            o, (st, x_tm) = rec.rwkv_time_mix(
                rmsnorm(h, bp["ln1"], cfg.norm_eps), bp["time_mix"], cfg,
                state=st, x_last=x_tm)
            h = h + o
            o, x_cm = rec.rwkv_channel_mix(
                rmsnorm(h, bp["ln2"], cfg.norm_eps), bp["channel_mix"], cfg,
                x_last=x_cm)
            return h + o, (st, x_tm[:, -1] if x_tm.ndim == 3 else x_tm, x_cm)
        x, (sts, xtms, xcms) = _scan(
            body, x, (params["blocks"], cache["wkv"].astype(F32),
                      cache["x_tm"], cache["x_cm"]))
        new_cache.update({"wkv": sts.astype(jnp.dtype(cfg.dtype)),
                          "x_tm": xtms, "x_cm": xcms})

    elif cfg.rglru:
        pat = cfg.block_pattern or ("rglru", "rglru", "attn")
        n_super = cfg.n_layers // len(pat)

        def superblock(h, xs):
            sbp = xs[0]
            new_st = []
            for i, kind in enumerate(pat):
                bp = sbp[f"l{i}_{kind}"]
                if kind == "rglru":
                    conv_st, lru_st = xs[1][f"conv_{i}"], xs[1][f"lru_{i}"]
                    hn = rmsnorm(h, bp["attn_norm"], cfg.norm_eps)
                    o, (conv_st, lru_st) = rec.rglru_block(
                        hn, bp["attn"], cfg,
                        state=(conv_st, lru_st.astype(F32)))
                    h = h + o
                    h = _mlp(h, bp, cfg)
                    new_st.append((f"conv_{i}", conv_st))
                    new_st.append((f"lru_{i}",
                                   lru_st.astype(jnp.dtype(cfg.dtype))))
                else:
                    h, kc, vc, kp = _ring_local_decode(
                        h, bp, cfg, xs[1][f"k_{i}"], xs[1][f"v_{i}"],
                        xs[1][f"pos_{i}"], pos)
                    new_st += [(f"k_{i}", kc), (f"v_{i}", vc),
                               (f"pos_{i}", kp)]
            return h, dict(new_st)

        scan_cache = {k: v for k, v in cache.items() if not k.startswith("t")}
        x, outs = _scan(superblock, x,
                               (params["superblocks"], scan_cache))
        new_cache.update(outs)
        tail = cfg.n_layers - n_super * len(pat)
        for i in range(tail):
            kind = pat[i]
            bp = params[f"tail_{i}"]
            if kind == "rglru":
                hn = rmsnorm(x, bp["attn_norm"], cfg.norm_eps)
                o, (cst, lst) = rec.rglru_block(
                    hn, bp["attn"], cfg,
                    state=(cache[f"tconv_{i}"], cache[f"tlru_{i}"].astype(F32)))
                x = x + o
                x = _mlp(x, bp, cfg)
                new_cache[f"tconv_{i}"] = cst
                new_cache[f"tlru_{i}"] = lst.astype(jnp.dtype(cfg.dtype))
            else:
                x, kc, vc, kp = _ring_local_decode(
                    x, bp, cfg, cache[f"tk_{i}"], cache[f"tv_{i}"],
                    cache[f"tpos_{i}"], pos)
                new_cache[f"tk_{i}"], new_cache[f"tv_{i}"] = kc, vc
                new_cache[f"tpos_{i}"] = kp

    elif cfg.n_experts:   # GQA MoE (arctic)
        def body(h, xs):
            bp, kc, vc = xs
            hn = rmsnorm(h, bp["attn_norm"], cfg.norm_eps)
            o, (kc, vc) = attn.gqa_decode(hn, bp["attn"], cfg, (kc, vc), pos)
            h = h + o
            h, _ = _moe_layer(h, bp, cfg, mesh, variant="psum")
            return h, (kc, vc)
        x, (ks, vs) = _scan(
            body, x, (params["moe_blocks"], cache["k"], cache["v"]))
        new_cache.update({"k": ks, "v": vs})

    else:
        def body(h, xs):
            bp, kc, vc = xs
            h, kc, vc = dense_decode(h, bp, kc, vc)
            return h, (kc, vc)
        x, (ks, vs) = _scan(
            body, x, (params["blocks"], cache["k"], cache["v"]))
        new_cache.update({"k": ks, "v": vs})

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x[:, 0:1], head)[:, 0]
    return logits, new_cache


def make_serve_step(cfg: ArchConfig, mesh: Mesh):
    def serve_step(params, cache, tokens, pos):
        logits, cache = decode_forward(params, cfg, cache, tokens, pos, mesh)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, cache
    return serve_step


def make_prefill_step(cfg: ArchConfig, mesh: Mesh):
    def prefill_step(params, batch):
        logits, _, _, caches = forward(params, cfg, batch, mesh,
                                       collect_cache=True)
        return logits[:, -1], caches
    return prefill_step
