"""Self-attention blocks: GQA (w/ qk-norm, sliding window) and MLA
(DeepSeek multi-head latent attention), with train/prefill and decode paths.

Decode caches:
* GQA/local: (k, v) each (B, Hkv, S_max, dh) — standard KV cache.
* MLA: the compressed latent (B, S_max, kv_lora + qk_rope) — 576 floats per
  token for deepseek-v3, the arch's signature memory saving.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import (apply_rope, decode_attention,
                                 flash_attention, rmsnorm, rope_angles)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_forward(x, p, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_angles(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin).transpose(0, 2, 1, 3)
    k = apply_rope(k, cos, sin).transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    window = cfg.local_window if cfg.attention == "local" else 0
    o = flash_attention(q, k, v, causal=True, chunk=min(1024, s),
                        window=window)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return jnp.einsum("bse,ed->bsd", o, p["wo"]), (k, v)


def gqa_decode(x, p, cfg: ArchConfig, cache: Tuple, pos):
    """x: (B, 1, D); cache (k,v): (B, Hkv, S, dh) with `pos` filled."""
    b = x.shape[0]
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k_cache, v_cache = cache
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    cos, sin = rope_angles(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin).transpose(0, 2, 1, 3)
    k = apply_rope(k, cos, sin).transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, pos, axis=2)
    window = cfg.local_window if cfg.attention == "local" else 0
    o = decode_attention(q, k_cache, v_cache, cur_pos=pos, window=window)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * dh)
    return jnp.einsum("bse,ed->bsd", o, p["wo"]), (k_cache, v_cache)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def _mla_qkv(x, p, cfg: ArchConfig, positions):
    """Project to per-head q (nope+rope) and latent; returns q, latent."""
    h = cfg.n_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    # q: low-rank
    q_lat = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"],
                    cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"])       # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    # kv latent + shared k_rope
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])           # (B,S,kvl+dr)
    kv_lat = rmsnorm(kv[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank:][..., None, :]       # (B,S,1,dr)
    k_rope = apply_rope(k_rope, cos, sin)[..., 0, :]        # (B,S,dr)
    latent = jnp.concatenate([kv_lat, k_rope], axis=-1)
    return jnp.concatenate([q_nope, q_rope], axis=-1), latent


def _mla_attend(q, latent, p, cfg: ArchConfig, cur_pos=None):
    """q (B,Sq,H,dn+dr); latent (B,Skv,kvl+dr) -> (B,Sq,H*dv)."""
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    kv_lat, k_rope = latent[..., :kvl], latent[..., kvl:]
    kvb = p["wkv_b"].reshape(kvl, h, dn + dv)
    k_nope = jnp.einsum("bsr,rhk->bshk", kv_lat, kvb[..., :dn])
    v = jnp.einsum("bsr,rhk->bshk", kv_lat, kvb[..., dn:])
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                k_rope.shape[:2] + (h, dr))
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    sq = q.shape[1]
    if sq == 1:
        o = decode_attention(qh, kh, vh, cur_pos=cur_pos)
    else:
        o = flash_attention(qh, kh, vh, causal=True, chunk=min(1024, sq))
    b = q.shape[0]
    return o.transpose(0, 2, 1, 3).reshape(b, sq, h * dv)


def mla_forward(x, p, cfg: ArchConfig, positions):
    q, latent = _mla_qkv(x, p, cfg, positions)
    o = _mla_attend(q, latent, p, cfg)
    return jnp.einsum("bse,ed->bsd", o, p["wo"]), latent


def mla_decode(x, p, cfg: ArchConfig, latent_cache, pos):
    """latent_cache: (B, S_max, kv_lora+qk_rope)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, latent = _mla_qkv(x, p, cfg, positions)
    latent_cache = jax.lax.dynamic_update_slice_in_dim(
        latent_cache, latent, pos, axis=1)
    o = _mla_attend(q, latent_cache, p, cfg, cur_pos=pos)
    return jnp.einsum("bse,ed->bsd", o, p["wo"]), latent_cache
