"""Token-choice top-k Mixture of Experts with expert parallelism.

Two dispatch schedules (the FHE analogy is direct — BConv's all-to-all over
limb-sharded banks maps to token dispatch over expert-sharded devices, and
the same chain-vs-channel tradeoff from paper §III-C appears here):

* `moe_psum` (baseline, works for any token count incl. decode): tokens are
  replicated across the `model` axis; each model-rank computes only its
  local experts and the partial outputs are psum-reduced. Communication =
  one all-reduce of the full activation.
* `moe_all_to_all` (optimized, training/prefill): tokens are also split
  along `model`; each device dispatches its local tokens into a per-expert
  buffer and a single all_to_all moves token-slots to the experts' owners.
  Communication = only the dispatched slice (k/E' of the activations).

Both use capacity-based dispatch (capacity_factor, overflow dropped — the
standard token-choice contract) built from sort-free cumsum ranking and
mode='drop' scatters, so everything jits with static shapes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig

F32 = jnp.float32


def router(x, w_router, top_k: int):
    """x (T, D) -> (weights (T,k), ids (T,k), aux_loss scalar, probs (T,E))."""
    logits = jnp.einsum("td,de->te", x, w_router).astype(F32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style): E * sum_e f_e * P_e
    e = probs.shape[-1]
    density = jnp.zeros((e,), F32).at[ids.reshape(-1)].add(1.0)
    density = density / ids.size
    p_mean = probs.mean(0)
    aux = e * jnp.sum(density * p_mean)
    return weights.astype(x.dtype), ids, aux, probs


def _dispatch_indices(ids, e_total: int, capacity: int):
    """Rank each (token, k-slot) within its expert. Returns flat positions
    (T*k,) in [0, capacity) and a keep mask (overflow dropped)."""
    flat = ids.reshape(-1)                                   # (T*k,)
    onehot = jax.nn.one_hot(flat, e_total, dtype=jnp.int32)  # (T*k, E)
    ranks = jnp.cumsum(onehot, axis=0) - 1                   # rank within expert
    pos = jnp.take_along_axis(ranks, flat[:, None], axis=1)[:, 0]
    keep = pos < capacity
    return pos, keep


def expert_ffn(buf, w_gate, w_up, w_down):
    """buf (E_l, C, D) x per-expert weights (E_l, D, F)."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g.astype(F32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_psum(x, p, cfg: ArchConfig, mesh_axis: str = "model"):
    """shard_map body — x (T_local, D) identical across `mesh_axis` ranks;
    expert weights sharded: p['w_gate'] (E_local, D, F) etc."""
    t, d = x.shape
    e_total = cfg.n_experts
    e_local = p["w_gate"].shape[0]
    n_ranks = e_total // e_local
    my_rank = jax.lax.axis_index(mesh_axis)
    weights, ids, aux, _ = router(x, p["w_router"], cfg.top_k)
    capacity = max(int(t * cfg.top_k * cfg.capacity_factor / e_total), 4)
    pos, keep = _dispatch_indices(ids, e_total, capacity)
    flat_ids = ids.reshape(-1)
    local_e = flat_ids - my_rank * e_local
    mine = keep & (local_e >= 0) & (local_e < e_local)
    # scatter tokens into my experts' buffers
    xk = jnp.repeat(x, cfg.top_k, axis=0)                    # (T*k, D)
    buf = jnp.zeros((e_local, capacity, d), x.dtype)
    idx_e = jnp.where(mine, local_e, e_local)                # OOB -> dropped
    buf = buf.at[idx_e, pos].set(xk, mode="drop")
    out_buf = expert_ffn(buf, p["w_gate"], p["w_up"], p["w_down"])
    # gather back + weighted combine
    gathered = out_buf.at[idx_e, pos].get(mode="fill", fill_value=0)
    gathered = jnp.where(mine[:, None], gathered, 0)
    combined = (gathered.reshape(t, cfg.top_k, d)
                * weights[..., None]).sum(axis=1)
    combined = jax.lax.psum(combined, mesh_axis)
    return combined.astype(x.dtype), aux


def moe_all_to_all(x, p, cfg: ArchConfig, mesh_axis: str = "model"):
    """shard_map body — x (T_local, D) DISTINCT per rank (tokens split over
    `mesh_axis` too). Dispatch buffers are exchanged with one all_to_all,
    experts run on their owners, and a reverse all_to_all returns outputs."""
    t, d = x.shape
    e_total = cfg.n_experts
    e_local = p["w_gate"].shape[0]
    n_ranks = e_total // e_local
    weights, ids, aux, _ = router(x, p["w_router"], cfg.top_k)
    capacity = max(int(t * cfg.top_k * cfg.capacity_factor / e_total), 4)
    pos, keep = _dispatch_indices(ids, e_total, capacity)
    flat_ids = ids.reshape(-1)
    xk = jnp.repeat(x, cfg.top_k, axis=0)
    buf = jnp.zeros((e_total, capacity, d), x.dtype)
    idx_e = jnp.where(keep, flat_ids, e_total)
    buf = buf.at[idx_e, pos].set(xk, mode="drop")
    # (E, C, D) -> split E across ranks -> (E_local, n_ranks*C, D)
    buf = jax.lax.all_to_all(buf, mesh_axis, split_axis=0, concat_axis=1,
                             tiled=True)
    out_buf = expert_ffn(buf, p["w_gate"], p["w_up"], p["w_down"])
    out_buf = jax.lax.all_to_all(out_buf, mesh_axis, split_axis=1,
                                 concat_axis=0, tiled=True)
    gathered = out_buf.at[idx_e, pos].get(mode="fill", fill_value=0)
    gathered = jnp.where(keep[:, None], gathered, 0)
    combined = (gathered.reshape(t, cfg.top_k, d)
                * weights[..., None]).sum(axis=1)
    return combined.astype(x.dtype), aux


def moe_reference(x, p_full, cfg: ArchConfig):
    """Single-device oracle: dense per-expert compute, no capacity drops.
    Used by tests to validate the distributed dispatch paths."""
    t, d = x.shape
    weights, ids, aux, _ = router(x, p_full["w_router"], cfg.top_k)
    outs = expert_ffn(jnp.broadcast_to(x, (cfg.n_experts, t, d)),
                      p_full["w_gate"], p_full["w_up"], p_full["w_down"])
    # outs (E, T, D); combine top-k
    sel = outs[ids.reshape(-1), jnp.repeat(jnp.arange(t), cfg.top_k)]
    combined = (sel.reshape(t, cfg.top_k, d) * weights[..., None]).sum(1)
    return combined.astype(x.dtype), aux
