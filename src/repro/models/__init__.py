from repro.models.config import ArchConfig  # noqa: F401
from repro.models.model import (abstract_params, init_params, logical_axes,
                                make_prefill_step, make_serve_step,
                                make_train_step)  # noqa: F401
