"""Architecture configuration for the assigned model zoo."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    n_heads: int = 0               # 0 for attention-free
    n_kv_heads: int = 0
    head_dim: int = 0              # default d_model // n_heads

    # attention flavor
    attention: str = "gqa"         # gqa | mla | none | local
    qk_norm: bool = False
    rope_theta: float = 1e6
    local_window: int = 0          # sliding-window size for local attention

    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0         # leading dense layers (deepseek: 3)
    dense_residual: bool = False   # parallel dense MLP branch (arctic)
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2

    # multi-token prediction (deepseek)
    mtp: bool = False

    # encoder-decoder (seamless)
    enc_dec: bool = False
    n_enc_layers: int = 0

    # cross-attention layers (llama-vision): 1 cross per `xattn_period` layers
    xattn_period: int = 0
    n_img_tokens: int = 1601       # stub modality frontend token count

    # recurrent families
    rwkv: bool = False             # RWKV6 time-mix blocks
    rglru: bool = False            # RecurrentGemma RG-LRU blocks
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rglru","rglru","attn")
    lru_width: int = 0
    conv_width: int = 4

    # numerics / training
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # sub-quadratic? (controls long_500k applicability)
    @property
    def subquadratic(self) -> bool:
        return self.rwkv or self.rglru or (
            self.attention == "local" and self.local_window > 0)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.n_heads:
            return self.d_model // self.n_heads
        return 0

    def param_count(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS and sanity checks)."""
        from repro.models.model import abstract_params
        import numpy as np
        tree = abstract_params(self)
        import jax
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))

    def active_param_count(self) -> int:
        """Active (per-token) params for MoE rooflines: replaces the full
        expert set with top_k + shared experts."""
        total = self.param_count()
        if not self.n_experts:
            return total
        per_expert = 3 * self.d_model * self.d_ff_expert
        n_moe_layers = self.n_layers - self.first_k_dense
        inactive = (self.n_experts - self.top_k) * per_expert * n_moe_layers
        return total - inactive
