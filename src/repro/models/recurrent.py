"""Recurrent sequence mixers: RWKV6 (Finch) time-mix and RG-LRU
(RecurrentGemma), with scan-based training and O(1)-state decode.

These are the sub-quadratic archs that make the long_500k cell meaningful:
state size is independent of context length (RWKV: (H, dh, dh) matrix
state; RG-LRU: (width,) diagonal state + a `local_window` KV cache for the
hybrid's attention layers).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

F32 = jnp.float32
RWKV_HEAD_DIM = 64


# ---------------------------------------------------------------------------
# RWKV6 time-mix (data-dependent decay — the Finch headline feature)
# ---------------------------------------------------------------------------

def _token_shift(x, last=None):
    """Shift sequence right by one; `last` supplies x_{-1} for decode."""
    if last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = last[:, None, :]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def rwkv_time_mix(x, p, cfg: ArchConfig, state=None, x_last=None):
    """x: (B, T, D). state: (B, H, dh, dh) or None (zeros).

    Returns (out, (new_state, new_x_last)).
    """
    b, t, d = x.shape
    dh = RWKV_HEAD_DIM
    h = d // dh
    xs = _token_shift(x, x_last)
    def lerp(mu):
        return x + (xs - x) * mu
    r = jnp.einsum("btd,de->bte", lerp(p["mu_r"]), p["wr"])
    k = jnp.einsum("btd,de->bte", lerp(p["mu_k"]), p["wk"])
    v = jnp.einsum("btd,de->bte", lerp(p["mu_v"]), p["wv"])
    g = jnp.einsum("btd,de->bte", lerp(p["mu_g"]), p["wg"])
    # data-dependent decay (LoRA): w = exp(-exp(w0 + tanh(xw A) B))
    xw = lerp(p["mu_w"])
    dd = jnp.einsum("btr,rd->btd", jnp.tanh(
        jnp.einsum("btd,dr->btr", xw, p["w_lora_a"])), p["w_lora_b"])
    w = jnp.exp(-jnp.exp((p["w0"] + dd).astype(F32)))        # (B,T,D) in (0,1)

    rh = r.reshape(b, t, h, dh)
    kh = k.reshape(b, t, h, dh)
    vh = v.reshape(b, t, h, dh)
    wh = w.reshape(b, t, h, dh)
    u = p["u_bonus"].reshape(h, dh)

    if state is None:
        state = jnp.zeros((b, h, dh, dh), F32)

    def step(s, inp):
        rt, kt, vt, wt = inp                                  # (B,H,dh)
        kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(F32), vt.astype(F32))
        out = jnp.einsum("bhk,bhkv->bhv", rt.astype(F32),
                         s + u[None, :, :, None] * kv)
        s = s * wt.astype(F32)[..., None] + kv
        return s, out

    xs_seq = (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
              vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3))
    new_state, outs = jax.lax.scan(step, state, xs_seq)
    out = outs.transpose(1, 0, 2, 3).reshape(b, t, d)
    out = _groupnorm(out, p["ln_x_w"], h)
    out = out * jax.nn.silu(g.astype(F32)).astype(out.dtype)
    out = jnp.einsum("btd,de->bte", out.astype(x.dtype), p["wo"])
    return out, (new_state, x[:, -1])


def _groupnorm(x, w, groups):
    b, t, d = x.shape
    xf = x.astype(F32).reshape(b, t, groups, d // groups)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(b, t, d)
    return (y * w.astype(F32)).astype(x.dtype)


def rwkv_channel_mix(x, p, cfg: ArchConfig, x_last=None):
    xs = _token_shift(x, x_last)
    xk = x + (xs - x) * p["mu_ck"]
    xr = x + (xs - x) * p["mu_cr"]
    k = jnp.einsum("btd,df->btf", xk, p["w_key"])
    k = jnp.square(jax.nn.relu(k.astype(F32))).astype(x.dtype)
    kv = jnp.einsum("btf,fd->btd", k, p["w_value"])
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["w_recept"]).astype(F32))
    return (r.astype(x.dtype) * kv), x[:, -1]


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma) + temporal conv
# ---------------------------------------------------------------------------

def _causal_conv1d(x, w, state=None):
    """Depthwise causal conv. x (B,T,W), w (K,W). state: (B,K-1,W) history."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out, xp[:, -(k - 1):]


def rglru(x, p, state=None):
    """RG-LRU recurrence. x (B,T,W) -> same; state (B,W) diagonal."""
    b, t, w_dim = x.shape
    rgate = jax.nn.sigmoid(
        jnp.einsum("btw,w->btw", x.astype(F32), p["w_a"].astype(F32))
        + p["b_a"].astype(F32))
    igate = jax.nn.sigmoid(
        jnp.einsum("btw,w->btw", x.astype(F32), p["w_x"].astype(F32))
        + p["b_x"].astype(F32))
    log_a = -8.0 * rgate * jax.nn.softplus(p["lambda_p"].astype(F32))
    a = jnp.exp(log_a)                                        # (B,T,W)
    gated_x = x.astype(F32) * igate
    multiplier = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))

    if state is None:
        state = jnp.zeros((b, w_dim), F32)

    def step(h, inp):
        at, xt, mt = inp
        h = at * h + mt * xt
        return h, h

    seq = (a.transpose(1, 0, 2), gated_x.transpose(1, 0, 2),
           multiplier.transpose(1, 0, 2))
    new_state, hs = jax.lax.scan(step, state, seq)
    return hs.transpose(1, 0, 2).astype(x.dtype), new_state


def rglru_block(x, p, cfg: ArchConfig, state=None):
    """RecurrentGemma recurrent block:
    x -> [linear -> conv1d -> RG-LRU] * gelu(linear(x)) -> linear out.
    state = (conv_state, lru_state)."""
    conv_state, lru_state = state if state is not None else (None, None)
    y = jnp.einsum("btd,dw->btw", x, p["w_in_y"])
    gate = jax.nn.gelu(
        jnp.einsum("btd,dw->btw", x, p["w_in_g"]).astype(F32)).astype(x.dtype)
    y, new_conv = _causal_conv1d(y, p["conv_w"], conv_state)
    y, new_lru = rglru(y, p, lru_state)
    out = jnp.einsum("btw,wd->btd", y * gate, p["w_out"])
    return out, (new_conv, new_lru)
