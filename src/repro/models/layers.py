"""Shared neural layers: norms, RoPE, SwiGLU MLP, flash-style attention
(chunked, causal/local/cross), GQA/MLA, decode-with-cache paths.

All functions are dtype-explicit (bf16 compute, f32 norms/softmax
accumulators) so the FHE core's global x64 flag never changes LM numerics.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

F32 = jnp.float32


def rmsnorm(x, w, eps=1e-6):
    xf = x.astype(F32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(F32)).astype(x.dtype)


def rope_angles(positions, dim: int, theta: float):
    """positions: (..., S) int32 -> (cos, sin) of shape (..., S, dim//2)."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))
    ang = positions.astype(F32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, d). cos/sin: (..., S, d//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(F32)
    s = sin[..., None, :].astype(F32)
    x1f, x2f = x1.astype(F32), x2.astype(F32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s],
                           axis=-1).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, w_down)


# ---------------------------------------------------------------------------
# chunked (flash-style) attention — memory-sane at 32k+ sequence
# ---------------------------------------------------------------------------

NEG_INF = -1e30

# roofline driver sets this True to unroll the kv-chunk loop (see
# models/model.py SCAN_UNROLL); FLASH_CHUNK overrides the chunk size
# (larger chunk = fewer unrolled iterations = smaller HLO)
FLASH_UNROLL = False
FLASH_CHUNK = 0


def _attend_chunk(q, k, v, mask, scale):
    """q (B,G,Hg,Sq,d) k/v (B,G,Skv,d) mask (Sq,Skv) -> partial softmax stats."""
    s = jnp.einsum("bghqd,bgkd->bghqk", q, k).astype(F32) * scale
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.maximum(m, -1e29)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bghqk,bgkd->bghqd", p.astype(v.dtype), v).astype(F32)
    return m, l, o


def flash_attention(q, k, v, *, causal: bool, chunk: int = 1024,
                    window: int = 0):
    """Chunked softmax attention with running max/denominator.

    q: (B, Hq, Sq, d); k, v: (B, Hkv, Skv, d); GQA via head groups.
    window > 0 limits attention to the last `window` positions (exact
    sliding window). Assumes Sq == Skv when causal (training/prefill).
    """
    b, hq, sq, d = q.shape
    dv = v.shape[-1]
    hkv = k.shape[1]
    g = hkv
    hg = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, g, hg, sq, d)
    skv = k.shape[2]
    if FLASH_CHUNK:
        chunk = FLASH_CHUNK
    chunk = min(chunk, skv)
    n_chunks = skv // chunk
    assert skv % chunk == 0, (skv, chunk)
    kc = k.reshape(b, g, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, g, n_chunks, chunk, dv).transpose(2, 0, 1, 3, 4)

    q_pos = jnp.arange(sq)

    def body(carry, inp):
        m_run, l_run, o_run = carry
        ci, kb, vb = inp
        kv_pos = ci * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window:
            mask &= q_pos[:, None] - kv_pos[None, :] < window
        m_c, l_c, o_c = _attend_chunk(qg, kb, vb, mask, scale)
        m_new = jnp.maximum(m_run, m_c)
        a1 = jnp.exp(m_run - m_new)
        a2 = jnp.exp(m_c - m_new)
        l_new = l_run * a1 + l_c * a2
        o_new = o_run * a1 + o_c * a2
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, g, hg, sq, 1), NEG_INF, dtype=F32)
    l0 = jnp.zeros((b, g, hg, sq, 1), dtype=F32)
    o0 = jnp.zeros((b, g, hg, sq, dv), dtype=F32)
    (m_f, l_f, o_f), _ = jax.lax.scan(
        body, (m0, l0, o0), (jnp.arange(n_chunks), kc, vc),
        unroll=True if FLASH_UNROLL else 1)
    out = (o_f / jnp.maximum(l_f, 1e-30)).astype(q.dtype)
    return out.reshape(b, hq, sq, dv)


def decode_attention(q, k_cache, v_cache, cur_pos=None, window: int = 0):
    """Single-token decode: q (B,Hq,1,d) over cache (B,Hkv,S,d).

    `cur_pos` (scalar) masks cache slots beyond the current position;
    `window` restricts to the trailing sliding window.
    """
    b, hq, _, d = q.shape
    hkv = k_cache.shape[1]
    g, hg = hkv, hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, g, hg, 1, d)
    s = jnp.einsum("bghqd,bgkd->bghqk", qg, k_cache).astype(F32) * scale
    skv = k_cache.shape[2]
    pos = jnp.arange(skv)
    if cur_pos is not None:
        s = jnp.where(pos <= cur_pos, s, NEG_INF)
        if window:
            s = jnp.where(cur_pos - pos < window, s, NEG_INF)
    elif window:
        s = jnp.where((skv - 1 - pos) < window, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bghqk,bgkd->bghqd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, hq, 1, v_cache.shape[-1])


def _divisor_chunk(skv: int, target: int = 1024) -> int:
    """Largest chunk <= target dividing skv (whole skv if none, e.g. 1601)."""
    for c in range(min(target, skv), 0, -1):
        if skv % c == 0:
            return c
    return skv


def cross_attention(x, memory, p, cfg: ArchConfig):
    """Non-causal attention from x to `memory` (vision/audio/encoder)."""
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    hkv = max(cfg.n_kv_heads, 1)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]).transpose(0, 2, 1, 3)
    kx = jnp.einsum("bsd,dhk->bshk", memory, p["wk"]).transpose(0, 2, 1, 3)
    vx = jnp.einsum("bsd,dhk->bshk", memory, p["wv"]).transpose(0, 2, 1, 3)
    o = flash_attention(q, kx, vx, causal=False,
                        chunk=_divisor_chunk(memory.shape[1]))
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return jnp.einsum("bse,ed->bsd", o, p["wo"])
