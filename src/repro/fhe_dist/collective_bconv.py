"""Distributed BConv: the paper's inter-bank all-to-all (§III-C, §IV-D)
as mesh collectives, in two schedules.

* `bconv_allgather` — the "channel IO" baseline (paper Base1): every
  device gathers all source limbs (one all-gather over `model`), then
  reduces its own output limbs locally. One bulk collective on the
  shared-bus analogue.
* `bconv_ring` — the "partial chain network" (the paper's contribution):
  source limbs circulate around the `model` ring via collective-permute;
  each hop's chunk is multiply-accumulated into the local output limbs
  while the next chunk is in flight. Same total bytes, but neighbor links
  only + compute/communication overlap — exactly the paper's argument for
  the chain over the bus.

Both are shard_map bodies over the `model` axis; tests (multi-device
subprocess) check bit-exactness against rns.bconv.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import modarith as ma


def _local_reduce(v_chunk, w_chunk, dst_q):
    """Accumulate w^T v for one source chunk: v (s, N), w (s, D_l) ->
    (D_l, N) reduced mod dst_q (D_l, 1)."""
    s = v_chunk.shape[0]
    acc = None
    for j in range(s):
        term = ma.mulmod(v_chunk[j][None, :], w_chunk[j][:, None], dst_q)
        acc = term if acc is None else ma.addmod(acc, term, dst_q)
    return acc


def bconv_allgather_body(v_local, qhat_inv_local, src_q_local, w_local,
                         dst_q_local, *, axis: str):
    """shard_map body. v_local (S_l, N): this device's source limbs.
    w_local (S, D_l): full source column of the weight matrix for the
    device's D_l output limbs. Returns (D_l, N)."""
    vs = ma.mulmod(v_local, qhat_inv_local[:, None], src_q_local[:, None])
    v_all = jax.lax.all_gather(vs, axis, tiled=True)          # (S, N)
    return _local_reduce(v_all, w_local, dst_q_local[:, None])


def bconv_ring_body(v_local, qhat_inv_local, src_q_local, w_local,
                    dst_q_local, *, axis: str):
    """Ring schedule: rotate the local chunk around the `model` ring,
    accumulating into the local outputs at each hop (chain network)."""
    from repro.compat import axis_size
    n_dev = axis_size(axis)
    my = jax.lax.axis_index(axis)
    vs = ma.mulmod(v_local, qhat_inv_local[:, None], src_q_local[:, None])
    s_l = vs.shape[0]
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    acc = jnp.zeros((w_local.shape[1], vs.shape[1]), jnp.uint64)
    chunk = vs
    for hop in range(n_dev):
        # chunk currently holds the limbs of device (my - hop) mod n_dev
        src_dev = (my - hop) % n_dev
        # select the matching weight rows (static per-hop dynamic slice)
        w_rows = jax.lax.dynamic_slice_in_dim(w_local, src_dev * s_l, s_l, 0)
        part = _local_reduce(chunk, w_rows, dst_q_local[:, None])
        acc = ma.addmod(acc, part, dst_q_local[:, None])
        if hop != n_dev - 1:
            chunk = jax.lax.ppermute(chunk, axis, perm)
    return acc


@partial(jax.jit, static_argnames=("mesh", "variant"))
def distributed_bconv(v, qhat_inv, src_q, w, dst_q, mesh: Mesh,
                      variant: str = "ring"):
    """v: (S, N) coeff-domain source (already reduced mod src primes);
    w: (S, D); returns (D, N). S and D must divide the `model` axis size.
    """
    body = bconv_ring_body if variant == "ring" else bconv_allgather_body
    axis = "model"
    from repro.compat import shard_map
    fn = shard_map(
        partial(body, axis=axis),
        mesh,
        (P(axis, None), P(axis), P(axis), P(None, axis), P(axis)),
        P(axis, None))
    return fn(v, qhat_inv, src_q, w, dst_q)


def bconv_tables_device(ctx, src_idx, dst_idx):
    """(qhat_inv, src_q, w, dst_q) arrays for distributed_bconv."""
    t = ctx.bconv_tables(src_idx, dst_idx)
    return t.qhat_inv, t.src_q, t.w, t.dst_q
