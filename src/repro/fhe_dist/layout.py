"""Limb-sharded data layout for distributed FHE (paper §IV-A on a mesh).

The bank↔limb mapping transfers directly: RNS limbs of each polynomial are
distributed round-robin across devices along the `model` axis (banks), the
batch of independent ciphertexts across `data` (separate pipelines), and
pods replicate keys (stack-level distribution in §V-A's 2-stack system).

Arrays:
    ciphertext  (2, L, N)        -> P(None, 'model', None)
    ct batch    (B, 2, L, N)     -> P('data', None, 'model', None)
    evk         (dnum, 2, T, N)  -> P(None, None, 'model', None)
    NTT tables  (L, N)           -> P('model', None)
"""
from __future__ import annotations

from typing import Dict

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def limb_specs(mesh: Mesh) -> Dict[str, NamedSharding]:
    m = "model" if "model" in mesh.axis_names else mesh.axis_names[-1]
    d = "data" if "data" in mesh.axis_names else mesh.axis_names[0]

    def ns(*spec):
        return NamedSharding(mesh, P(*spec))

    return {
        "ct": ns(None, m, None),
        "ct_batch": ns(d, None, m, None),
        "poly": ns(m, None),
        "evk": ns(None, None, m, None),
        "tables": ns(m, None),
        "replicated": ns(),
    }


def shardable_limbs(n_limbs: int, mesh: Mesh) -> bool:
    m = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    return n_limbs % m == 0
