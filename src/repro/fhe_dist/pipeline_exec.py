"""Distributed load-save pipeline executor (paper §IV-F on a mesh).

Maps the PipelineSchedule from core/pipeline.py onto the `data` mesh axis:
each data-rank hosts one resident stage per round (its constants stay
on-device for the whole input batch — the "load once per round" property),
and microbatches flow rank-to-rank via collective_permute, GPipe-style.

Stage bodies must be shape-preserving (ciphertexts padded to the round's
max limb count — the standard trick for level-heterogeneous pipelines; the
mapper already levels stages within a round). Heterogeneous stage programs
are dispatched with lax.switch on the rank index, so the whole round is ONE
SPMD program with a rotating ppermute — exactly the paper's Figure 11
timing structure (compute overlapped with neighbor transfer).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


from repro.compat import shard_map as _shard_map


def _round_body(x_stack, *, stage_fns: Sequence[Callable], axis: str,
                n_micro: int):
    """shard_map body over `axis`. x_stack: (n_micro, ...) microbatches,
    all resident on rank 0 conceptually; we rotate a working buffer.

    Step t: rank r applies its stage to the microbatch that has passed
    ranks 0..r-1; results shift r -> r+1 each step. After
    n_micro + n_ranks - 1 steps, rank n-1 has emitted every microbatch;
    outputs are collected by shifting them around the ring to rank 0's
    output stack (gathered at the end).
    """
    n_dev = len(stage_fns)     # == axis size; static (lax.axis_size is
    rank = jax.lax.axis_index(axis)  # not available on older jax)
    buf = jnp.zeros_like(x_stack[0])
    out_stack = jnp.zeros_like(x_stack)
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    def apply_stage(x):
        return jax.lax.switch(rank, list(stage_fns), x)

    n_steps = n_micro + n_dev - 1
    for t in range(n_steps):
        # rank 0 injects microbatch t (if any)
        inject = x_stack[jnp.minimum(t, n_micro - 1)]
        buf = jnp.where((rank == 0) & (t < n_micro), inject, buf)
        buf = apply_stage(buf)
        # collect finished microbatch from the last rank
        done_idx = t - (n_dev - 1)
        is_done = (done_idx >= 0) & (done_idx < n_micro)
        out_stack = jnp.where(
            is_done & (rank == n_dev - 1),
            out_stack.at[jnp.maximum(done_idx, 0)].set(buf), out_stack)
        if t != n_steps - 1:
            buf = jax.lax.ppermute(buf, axis, perm)
    # bring outputs to every rank (replicated result)
    return jax.lax.psum(out_stack, axis)


def run_pipeline_round(stage_fns: Sequence[Callable], x_stack, mesh: Mesh,
                       axis: str = "data"):
    """Execute one pipeline round of len(stage_fns) stages over the
    microbatch stack x_stack (n_micro, ...). len(stage_fns) must equal the
    `axis` size. Returns the processed stack (replicated)."""
    n_micro = x_stack.shape[0]
    fn = _shard_map(
        partial(_round_body, stage_fns=tuple(stage_fns), axis=axis,
                n_micro=n_micro),
        mesh, (P(),), P())
    return fn(x_stack)


def run_load_save_pipeline(rounds: List[Sequence[Callable]], x_stack,
                           mesh: Mesh, axis: str = "data"):
    """Full load-save execution: rounds run sequentially; within a round
    the batch streams through the resident stages (constants loaded once —
    they are closed over by the stage functions, i.e. device-resident)."""
    for fns in rounds:
        x_stack = run_pipeline_round(fns, x_stack, mesh, axis)
    return x_stack
