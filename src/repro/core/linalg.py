"""Homomorphic linear algebra: BSGS matrix-vector, hoisted rotations,
polynomial evaluation (power-basis and Chebyshev Paterson-Stockmeyer).

These are the building blocks of the paper's workloads (§V-B): LOLA layers,
HELR iterations, sorting comparators, and bootstrapping's CoefToSlot /
SlotToCoef / EvalMod.

Beyond-paper optimization implemented here: *hoisting* — a rotation's
dominant cost is the ModUp (digit decomposition) of the `a` component;
for k rotations of the same ciphertext, decompose once and permute the
raised digits per rotation (automorphism commutes with ModUp limb-wise).
ARK/BTS use the same trick; FHEmem itself re-runs ModUp per rotation, which
we keep as the faithful path (`use_hoisting=False`).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import modarith as ma
from repro.core import ops as hops
from repro.core.ciphertext import Ciphertext, KeySwitchKey, Plaintext
from repro.core.context import CkksContext


# ---------------------------------------------------------------------------
# hoisted rotations
# ---------------------------------------------------------------------------

def hoisted_rotations(ctx: CkksContext, ct: Ciphertext,
                      steps: Sequence[int],
                      gks: Dict[int, KeySwitchKey]) -> Dict[int, Ciphertext]:
    """Rotate `ct` by every step in `steps`, sharing one digit decomposition.

    ModUp(sigma_k(a)) == sigma_k(ModUp(a)) because the automorphism acts
    coefficient-wise (a signed permutation) and BConv is coefficient-wise.
    """
    level = ct.level
    idx_q = ctx.q_idx(level)
    idx_p = ctx.p_idx()
    target = idx_q + idx_p
    q_t = ctx.q_all[np.array(target)][:, None]
    q = ctx.q_all[: ct.n_limbs][:, None]
    digits = ctx.params.digit_indices(level)
    # hoist: raise all digits of `a` once
    raised = [hops.mod_up(ctx, ct.data[1][np.array(J)], J, target)
              for J in digits]
    out: Dict[int, Ciphertext] = {}
    for step in steps:
        if step % (ctx.n // 2) == 0:
            out[step] = ct
            continue
        elt = ctx.rotation_element(step)
        perm = ctx.eval_perm(elt)
        ksk_sel = gks[elt].data[:, :, np.array(target)]
        acc0 = jnp.zeros((len(target), ctx.n), dtype=jnp.uint64)
        acc1 = jnp.zeros((len(target), ctx.n), dtype=jnp.uint64)
        for d in range(len(digits)):
            r_rot = raised[d][:, perm]
            acc0 = ma.addmod(acc0, ma.mulmod(r_rot, ksk_sel[d, 0], q_t), q_t)
            acc1 = ma.addmod(acc1, ma.mulmod(r_rot, ksk_sel[d, 1], q_t), q_t)
        e0 = hops._mod_down(ctx, acc0, idx_q, idx_p)
        e1 = hops._mod_down(ctx, acc1, idx_q, idx_p)
        b_rot = ct.data[0][:, perm]
        out[step] = Ciphertext(jnp.stack([ma.addmod(b_rot, e0, q), e1]),
                               level, ct.scale)
    return out


# ---------------------------------------------------------------------------
# BSGS homomorphic matrix-vector multiply (diagonal method)
# ---------------------------------------------------------------------------

def matrix_diagonals(mat: np.ndarray) -> Dict[int, np.ndarray]:
    """Generalized diagonals of a (s x s) matrix: diag_d[j] = M[j, (j+d) % s].
    Zero diagonals are dropped."""
    s = mat.shape[0]
    out = {}
    for d in range(s):
        dg = np.array([mat[j, (j + d) % s] for j in range(s)])
        if np.abs(dg).max() > 1e-12:
            out[d] = dg
    return out


def bsgs_split(diag_idx: Sequence[int], s: int) -> Tuple[int, int]:
    """Pick (baby, giant) sizes: bs*gs >= s, bs ~ sqrt(#diags)."""
    n_d = max(len(diag_idx), 1)
    bs = 1 << max(0, math.ceil(math.log2(max(1.0, math.sqrt(n_d)))))
    gs = math.ceil(s / bs)
    return bs, gs


def required_rotation_steps(diags: Dict[int, np.ndarray], s: int) -> List[int]:
    bs, gs = bsgs_split(list(diags), s)
    steps = set()
    for j in range(bs):
        steps.add(j)
    for i in range(gs):
        steps.add(bs * i)
    steps.discard(0)
    return sorted(steps)


def matvec_bsgs(ctx: CkksContext, ct: Ciphertext, diags: Dict[int, np.ndarray],
                gks: Dict[int, KeySwitchKey], encoder,
                use_hoisting: bool = True,
                scale: Optional[float] = None) -> Ciphertext:
    """out = M @ v for M given by generalized diagonals.

    BSGS: M v = sum_i rot( sum_j pdiag[bs*i + j] (pre-rotated by -bs*i) * rot(v, j), bs*i )
    Baby rotations are hoisted. Consumes one level (the pmul).
    """
    s = ctx.n // 2
    scale = scale or ct.scale
    bs, gs = bsgs_split(list(diags), s)
    baby_steps = [j for j in range(bs)
                  if any((bs * i + j) % s in diags for i in range(gs))]
    if use_hoisting:
        rots = hoisted_rotations(ctx, ct, baby_steps, gks)
    else:
        rots = {j: (ct if j == 0 else
                    hops.rotate(ctx, ct, j, gks[ctx.rotation_element(j)]))
                for j in baby_steps}
    out: Optional[Ciphertext] = None
    for i in range(gs):
        inner: Optional[Ciphertext] = None
        for j in range(bs):
            d = (bs * i + j) % s
            if d not in diags:
                continue
            # pre-rotate the diagonal by -bs*i so the outer rotation aligns it
            pd = np.roll(diags[d], bs * i)
            pt = Plaintext(encoder.encode(pd, scale, ct.level),
                           ct.level, scale)
            term = hops.pmul(ctx, rots[j], pt, do_rescale=False)
            inner = term if inner is None else hops.hadd(ctx, inner, term)
        if inner is None:
            continue
        if bs * i % s != 0:
            elt = ctx.rotation_element(bs * i)
            inner = hops._apply_galois(ctx, inner, elt, gks[elt])
        out = inner if out is None else hops.hadd(ctx, out, inner)
    assert out is not None, "matrix had no nonzero diagonals"
    return hops.rescale(ctx, out)


def matvec_keys_needed(ctx: CkksContext, diags: Dict[int, np.ndarray]) -> List[int]:
    """Galois elements needed by matvec_bsgs for this diagonal set."""
    s = ctx.n // 2
    bs, gs = bsgs_split(list(diags), s)
    elts = set()
    for j in range(bs):
        if any((bs * i + j) % s in diags for i in range(gs)) and j % s:
            elts.add(ctx.rotation_element(j))
    for i in range(gs):
        if (bs * i) % s:
            elts.add(ctx.rotation_element(bs * i))
    return sorted(elts)


# ---------------------------------------------------------------------------
# polynomial evaluation
# ---------------------------------------------------------------------------

def _const_pt(ctx, encoder, value: complex, level: int, scale: float) -> Plaintext:
    v = np.full(ctx.n // 2, value, dtype=np.complex128)
    return Plaintext(encoder.encode(v, scale, level), level, scale)


def add_const(ctx, encoder, ct: Ciphertext, c: complex) -> Ciphertext:
    pt = _const_pt(ctx, encoder, c, ct.level, ct.scale)
    return hops.padd(ctx, ct, pt)


def mul_const(ctx, encoder, ct: Ciphertext, c: complex) -> Ciphertext:
    """Multiply by a scalar (costs one level)."""
    pt = _const_pt(ctx, encoder, c, ct.level, 2.0 ** ctx.params.log_scale)
    return hops.pmul(ctx, ct, pt)


def adjust_to(ctx, encoder, ct: Ciphertext, level: int,
              scale: float) -> Ciphertext:
    """Bring ct to exactly (level, scale) via a unit pmul with an exactly
    chosen plaintext scale (costs one of the levels being dropped anyway).
    Requires ct.level > level."""
    assert ct.level > level, "adjust_to needs at least one spare level"
    ct = hops.mod_switch_to_level(ct, level + 1)
    q_drop = ctx.primes[level + 1]
    pt_scale = scale * q_drop / ct.scale
    pt = _const_pt(ctx, encoder, 1.0, ct.level, pt_scale)
    out = hops.pmul(ctx, ct, pt)                   # rescale -> level
    out.scale = scale                              # exact by construction
    return out


def _linear_combination(ctx, encoder, terms: Dict[int, Ciphertext],
                        coeffs: Dict[int, complex]) -> Ciphertext:
    """sum coeffs[i]*terms[i] with exact per-term scale equalization."""
    min_level = min(t.level for t in terms.values()) - 1
    q_drop = ctx.primes[min_level + 1]
    out: Optional[Ciphertext] = None
    target_scale = None
    for i, c in coeffs.items():
        if abs(c) < 1e-15:
            continue
        base = hops.mod_switch_to_level(terms[i], min_level + 1)
        if target_scale is None:
            target_scale = base.scale * (2.0 ** ctx.params.log_scale) / q_drop
        pt_scale = target_scale * q_drop / base.scale
        pt = _const_pt(ctx, encoder, c, base.level, pt_scale)
        term = hops.pmul(ctx, base, pt)
        term.scale = target_scale                  # exact by construction
        out = term if out is None else hops.hadd(ctx, out, term)
    assert out is not None
    return out


def poly_eval_power_basis(ctx: CkksContext, ct: Ciphertext,
                          coeffs: Sequence[float], rk: KeySwitchKey,
                          encoder) -> Ciphertext:
    """Evaluate sum_i coeffs[i] x^i (low degree; Horner-free BSGS-lite).

    Builds the power basis x^1..x^deg with log-depth squarings, multiplies
    each by its coefficient and sums. Adequate for the small comparator /
    activation polynomials (deg <= ~8); EvalMod uses the Chebyshev path.
    """
    deg = len(coeffs) - 1
    assert deg >= 1
    powers: Dict[int, Ciphertext] = {1: ct}
    # binary power tree
    d = 1
    while 2 * d <= deg:
        powers[2 * d] = hops.hsquare(ctx, powers[d], rk)
        d *= 2
    for i in range(2, deg + 1):
        if i in powers:
            continue
        lo = 1 << (i.bit_length() - 1)
        powers[i] = hops.hmul(ctx, powers[lo], powers[i - lo], rk)
    out = _linear_combination(ctx, encoder, powers,
                              {i: coeffs[i] for i in range(1, deg + 1)})
    if abs(coeffs[0]) > 1e-15:
        out = add_const(ctx, encoder, out, coeffs[0])
    return out


def chebyshev_coeffs(fn, degree: int, a: float = -1.0, b: float = 1.0) -> np.ndarray:
    """Chebyshev interpolation coefficients of fn on [a, b]."""
    k = np.arange(degree + 1)
    x = np.cos(np.pi * (k + 0.5) / (degree + 1))
    y = fn((b - a) / 2 * x + (a + b) / 2)
    T = np.cos(np.outer(np.arange(degree + 1), np.pi * (k + 0.5) / (degree + 1)))
    c = 2.0 / (degree + 1) * T @ y
    c[0] /= 2
    return c


def poly_eval_chebyshev(ctx: CkksContext, ct: Ciphertext,
                        cheb_coeffs: Sequence[float], rk: KeySwitchKey,
                        encoder) -> Ciphertext:
    """Evaluate sum c_i T_i(x) for x in [-1,1] (x = the ct's slots).

    Iterative Clenshaw-free scheme: build T_1..T_deg via
    T_{m+n} = 2 T_m T_n - T_{|m-n|} using a power-of-two ladder, then a
    linear combination. Depth ~ ceil(log2 deg) + 1.
    """
    deg = len(cheb_coeffs) - 1
    ts: Dict[int, Ciphertext] = {1: ct}
    d = 1
    while 2 * d <= deg:
        t2 = hops.hsquare(ctx, ts[d], rk)          # T_{2d} = 2 T_d^2 - 1
        t2 = hops.hadd(ctx, t2, t2)
        ts[2 * d] = add_const(ctx, encoder, t2, -1.0)
        d *= 2
    for i in range(2, deg + 1):
        if i in ts:
            continue
        lo = 1 << (i.bit_length() - 1)
        hi = i - lo
        prod = hops.hmul(ctx, ts[lo], ts[hi], rk)  # T_{lo+hi} = 2 T_lo T_hi - T_{lo-hi}
        prod = hops.hadd(ctx, prod, prod)
        if ts[lo - hi].level > prod.level:
            tdiff = adjust_to(ctx, encoder, ts[lo - hi], prod.level, prod.scale)
        else:  # same level: scales match structurally (same rescale path)
            tdiff = ts[lo - hi].copy()
            tdiff.scale = prod.scale
        ts[i] = hops.hsub(ctx, prod, tdiff)
    out = _linear_combination(ctx, encoder, ts,
                              {i: cheb_coeffs[i] for i in range(1, deg + 1)})
    if abs(cheb_coeffs[0]) > 1e-15:
        out = add_const(ctx, encoder, out, cheb_coeffs[0])
    return out
