"""Load-save pipeline mapping (§IV-F): stage splitting + round-robin
placement + latency/throughput estimation.

The paper's insight: a naive n-partition pipeline forces each partition to
hold the constants (evk, plaintext weights) of a coarse program slice; when
they don't fit, every op reloads its constants. The load-save pipeline
instead splits the program into *fine-grained* stages whose constants DO
fit, assigns them round-robin across partitions, and runs a whole batch of
inputs through each *round* of resident stages — constants stream in once
per round, not once per op.

The same mapper drives (a) the analytic benchmarks (fig15 ablation: naive
vs load-save), and (b) the real distributed executor
(repro/fhe_dist/pipeline_exec.py), where partitions are devices/device
groups on the mesh instead of memory banks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.core.params import CkksParams
from repro.core.trace import FheOp, FheTrace, OpCost, op_cost


@dataclasses.dataclass
class MemoryModel:
    """Abstract partitioned memory/compute (banks in the paper, device
    groups on a TPU mesh here).

    This flat model is the degenerate case of the hierarchical FHEmem
    model in ``repro/pim/arch.py``: ``PimArch.to_memory_model()`` derives
    these rates from channel/bank/subarray geometry, and the pim
    presets' flat member reproduces these defaults exactly.
    """
    n_partitions: int = 16
    partition_bytes: int = 64 * 2 ** 20      # capacity per partition
    load_bw: float = 64e9                    # bytes/s constants into a partition
    modmul_throughput: float = 2.0e12        # N-coeff modmul rows/s equivalent
    ntt_row_cost: float = 1.0                # relative NTT pass cost vs modmul row
    transfer_bw: float = 256e9               # inter-partition bytes/s
    ks_modmul_weight: float = 1.25           # digit-decomposition modmul rows
    #                                          read gathered (non-resident)
    #                                          operands: billed heavier than
    #                                          plain rows

    def compute_seconds(self, c: OpCost, n: int) -> float:
        """Seconds of partition-local work for one op: modmul rows (plain
        + weighted keyswitch digit-decomposition rows) + NTT butterfly
        passes + the op's own inter-partition data movement (rotation
        permutations, ModUp/ModDown limb distribution) — previously the
        last two channels were folded into plain modmul rows."""
        rows = (c.modmuls + self.ks_modmul_weight * c.ks_modmuls
                + self.ntt_row_cost * c.ntts * math.log2(max(n, 2)))
        return (rows * n / self.modmul_throughput
                + c.move_bytes / self.transfer_bw)


@dataclasses.dataclass
class Stage:
    idx: int
    ops: List[FheOp]
    partition: int = -1
    const_bytes: int = 0
    compute_s: float = 0.0
    out_bytes: int = 0

    def describe(self) -> str:
        kinds = {}
        for o in self.ops:
            kinds[o.kind] = kinds.get(o.kind, 0) + 1
        return f"stage{self.idx}@p{self.partition} " + \
            ",".join(f"{k}x{v}" for k, v in sorted(kinds.items()))


@dataclasses.dataclass
class PipelineSchedule:
    stages: List[Stage]
    rounds: List[List[Stage]]
    params: CkksParams
    mem: MemoryModel
    reload_per_op: bool = False   # naive mode: constants reloaded per op
    trace: Optional[FheTrace] = None  # the mapped trace (op objects are
    #                                   shared with the stages), so real
    #                                   executors can encrypt inputs and
    #                                   decode outputs (engine.run_schedule)
    pass_report: object = dataclasses.field(
        default=None, repr=False, compare=False)
    #   repro.compiler.PassReport from the optimizing compile that
    #   produced `trace` (None when serving verbatim) — attached by
    #   CompileCache so compile spans and fig17 can surface per-pass
    #   wall time without recompiling

    # -- latency model -------------------------------------------------------

    def stage_times(self, batch: int) -> List[Tuple[float, float, float]]:
        """(load_s, compute_s, transfer_s) per stage for a batch."""
        out = []
        for st in self.stages:
            if self.reload_per_op:
                load = batch * st.const_bytes / self.mem.load_bw
            else:
                load = st.const_bytes / self.mem.load_bw   # once per round
            compute = batch * st.compute_s
            transfer = batch * st.out_bytes / self.mem.transfer_bw
            out.append((load, compute, transfer))
        return out

    def bottleneck_latency(self, batch: int) -> float:
        """Paper metric: time per input when the pipeline is full = max
        stage time / batch (§V-C 'maximum time across all pipeline stages')."""
        times = self.stage_times(batch)
        worst = max(l + max(c, t) for (l, c, t) in times)
        return worst / batch

    def total_latency(self, batch: int) -> float:
        """End-to-end: rounds are sequential; within a round stages overlap
        (pipelined), so a round costs its worst stage + fill."""
        times = self.stage_times(batch)
        total = 0.0
        i = 0
        for rnd in self.rounds:
            rt = [times[st.idx] for st in rnd]
            worst = max(l + max(c, t) for (l, c, t) in rt)
            fill = sum(max(c, t) / batch for (l, c, t) in rt)
            total += worst + fill
            i += len(rnd)
        return total

    def loads_bytes(self) -> int:
        per_stage = [st.const_bytes for st in self.stages]
        if self.reload_per_op:
            return sum(p * 1 for p in per_stage)  # scaled by batch at use site
        return sum(per_stage)


# ---------------------------------------------------------------------------
# mappers
# ---------------------------------------------------------------------------

def _stage_cost(params: CkksParams, mem: MemoryModel,
                ops: List[FheOp]) -> Tuple[int, float, int]:
    const_b, comp, out_b = 0, 0.0, 0
    for o in ops:
        c = op_cost(params, o)
        const_b += c.const_bytes
        comp += mem.compute_seconds(c, params.n)
        out_b = c.out_bytes
    return const_b, comp, out_b


def generate_load_save_pipeline(trace: FheTrace, params: CkksParams,
                                mem: MemoryModel,
                                const_budget_frac: float = 0.5
                                ) -> PipelineSchedule:
    """The paper's mapper: fine-grained stages sized so each stage's
    constants fit in `const_budget_frac` of a partition; stages assigned
    round-robin; rounds of n_partitions stages."""
    budget = int(mem.partition_bytes * const_budget_frac)
    stages: List[Stage] = []
    cur: List[FheOp] = []
    cur_const = 0
    # evk is shared by all hmul/rotate ops in a stage — count once
    def flush():
        nonlocal cur, cur_const
        if cur:
            const_b, comp, out_b = _stage_cost(params, mem, cur)
            # shared-evk correction: count evk once per stage
            from repro.core.trace import evk_bytes
            n_ks = sum(1 for o in cur if o.kind in ("hmul", "rotate", "conjugate"))
            if n_ks > 1:
                const_b -= (n_ks - 1) * evk_bytes(params)
            stages.append(Stage(len(stages), cur, -1, const_b, comp, out_b))
            cur, cur_const = [], 0

    for op in trace.compute_ops():
        c = op_cost(params, op)
        inc = c.const_bytes if op.kind not in ("hmul", "rotate", "conjugate") \
            or not any(o.kind in ("hmul", "rotate", "conjugate") for o in cur) \
            else 0
        if cur and cur_const + inc > budget:
            flush()
        cur.append(op)
        cur_const += inc
    flush()
    for i, st in enumerate(stages):
        st.partition = i % mem.n_partitions
    rounds = [stages[i:i + mem.n_partitions]
              for i in range(0, len(stages), mem.n_partitions)]
    return PipelineSchedule(stages, rounds, params, mem, reload_per_op=False,
                            trace=trace)


def generate_naive_pipeline(trace: FheTrace, params: CkksParams,
                            mem: MemoryModel) -> PipelineSchedule:
    """Base2-style mapper: split into exactly n_partitions coarse stages.
    Stages whose constants overflow the partition reload them per input."""
    ops = trace.compute_ops()
    n = mem.n_partitions
    per = math.ceil(len(ops) / n)
    stages = []
    overflow = False
    for i in range(0, len(ops), per):
        chunk = ops[i:i + per]
        const_b, comp, out_b = _stage_cost(params, mem, chunk)
        st = Stage(len(stages), chunk, len(stages) % n, const_b, comp, out_b)
        if const_b > mem.partition_bytes:
            overflow = True
        stages.append(st)
    return PipelineSchedule(stages, [stages], params, mem,
                            reload_per_op=overflow, trace=trace)
