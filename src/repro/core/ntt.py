"""Negacyclic NTT over RNS limbs, vectorized in JAX.

Layout notes (the FHEmem analogy, DESIGN.md §2): the iterative Harvey/CT
NTT's stages split naturally into *large-stride* stages (pairs live in
different rows of an (R, C) tile view — FHEmem's "vertical inter-mat"
phase), *mid-stride* stages (pairs in the same row, different tiles —
"horizontal inter-mat"), and *small-stride* stages (pairs inside one tile —
"intra-mat"). The Pallas kernels in repro/kernels/ntt.py exploit exactly
this split; this module is the canonical reference implementation and the
library path.

Conventions:
* forward NTT: natural-order input -> bit-reversed-order evaluation domain
  (evaluations of the polynomial at odd powers of psi, psi = 2N-th root);
* all elementwise ciphertext algebra happens in that bit-reversed domain;
* automorphisms in the evaluation domain are pure permutations
  (``eval_perm``), computed from the exponent map — no sign fixups needed.

Data: ``(..., L, N)`` uint64; per-limb constants ``(L,)``.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import modarith as ma
from repro.core.params import Modulus, find_2nth_root


def bit_reverse(i: int, bits: int) -> int:
    return int(bin(i + (1 << bits))[3:][::-1], 2)


def bit_reverse_vector(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    return np.array([bit_reverse(i, bits) for i in range(n)], dtype=np.int64)


# ---------------------------------------------------------------------------
# table construction (host side)
# ---------------------------------------------------------------------------

class NttTables:
    """Per-modulus-set twiddle tables for ring degree N.

    root_powers[l, i]     = psi_l^{brv(i, logN)}
    inv_root_powers[l, i] = psi_l^{-brv(i, logN)}
    """

    def __init__(self, moduli: Sequence[Modulus], log_n: int):
        self.log_n = log_n
        self.n = 1 << log_n
        self.moduli = tuple(moduli)
        two_n = 2 * self.n
        q_list, rp_list, irp_list, ninv_list, psi_list = [], [], [], [], []
        brv = bit_reverse_vector(self.n)
        for mod in moduli:
            p = mod.value
            psi = find_2nth_root(p, two_n)
            psi_inv = pow(psi, -1, p)
            # psi^i for i in 0..N-1 (then permute by brv) — O(N) host work
            pw = np.empty(self.n, dtype=np.uint64)
            ipw = np.empty(self.n, dtype=np.uint64)
            x = 1
            y = 1
            for i in range(self.n):
                pw[i] = x
                ipw[i] = y
                x = x * psi % p
                y = y * psi_inv % p
            rp_list.append(pw[brv])
            irp_list.append(ipw[brv])
            q_list.append(p)
            ninv_list.append(pow(self.n, -1, p))
            psi_list.append(psi)
        self.q = jnp.asarray(np.array(q_list, dtype=np.uint64))
        self.root_powers = jnp.asarray(np.stack(rp_list))
        self.inv_root_powers = jnp.asarray(np.stack(irp_list))
        self.n_inv = jnp.asarray(np.array(ninv_list, dtype=np.uint64))
        self.psi = tuple(psi_list)

    def slice_limbs(self, idx: Sequence[int]) -> "NttTables":
        """View of a subset of limbs (no recomputation)."""
        out = object.__new__(NttTables)
        out.log_n = self.log_n
        out.n = self.n
        idx = list(idx)
        out.moduli = tuple(self.moduli[i] for i in idx)
        ii = jnp.asarray(np.array(idx, dtype=np.int64))
        out.q = self.q[ii]
        out.root_powers = self.root_powers[ii]
        out.inv_root_powers = self.inv_root_powers[ii]
        out.n_inv = self.n_inv[ii]
        out.psi = tuple(self.psi[i] for i in idx)
        return out


# ---------------------------------------------------------------------------
# forward / inverse (vectorized over leading dims and limbs)
# ---------------------------------------------------------------------------

def ntt_forward(a: jnp.ndarray, root_powers: jnp.ndarray,
                q: jnp.ndarray) -> jnp.ndarray:
    """Cooley-Tukey DIT, natural -> bitrev. a: (..., L, N)."""
    n = a.shape[-1]
    lead = a.shape[:-1]  # (..., L)
    m = 1
    while m < n:
        t = n // (2 * m)
        a = a.reshape(*lead, m, 2 * t)
        w = root_powers[..., m:2 * m]            # (L, m)
        u = a[..., :t]
        v = ma.mulmod(a[..., t:], w[..., :, None], q[..., None, None])
        a = jnp.concatenate(
            [ma.addmod(u, v, q[..., None, None]),
             ma.submod(u, v, q[..., None, None])], axis=-1)
        m *= 2
    return a.reshape(*lead, n)


def ntt_inverse(a: jnp.ndarray, inv_root_powers: jnp.ndarray,
                q: jnp.ndarray, n_inv: jnp.ndarray) -> jnp.ndarray:
    """Gentleman-Sande DIF, bitrev -> natural (exact inverse of forward)."""
    n = a.shape[-1]
    lead = a.shape[:-1]
    m = n // 2
    while m >= 1:
        t = n // (2 * m)
        a = a.reshape(*lead, m, 2 * t)
        w = inv_root_powers[..., m:2 * m]        # (L, m)
        u = a[..., :t]
        v = a[..., t:]
        s = ma.addmod(u, v, q[..., None, None])
        d = ma.mulmod(ma.submod(u, v, q[..., None, None]),
                      w[..., :, None], q[..., None, None])
        a = jnp.concatenate([s, d], axis=-1)
        m //= 2
    a = a.reshape(*lead, n)
    return ma.mulmod(a, n_inv[..., None], q[..., None])


_ntt_forward_jit = jax.jit(ntt_forward)
_ntt_inverse_jit = jax.jit(ntt_inverse)


def ntt(a: jnp.ndarray, tables: NttTables) -> jnp.ndarray:
    return _ntt_forward_jit(a, tables.root_powers, tables.q)


def intt(a: jnp.ndarray, tables: NttTables) -> jnp.ndarray:
    return _ntt_inverse_jit(a, tables.inv_root_powers, tables.q, tables.n_inv)


# ---------------------------------------------------------------------------
# reference O(N^2) oracle (tests only)
# ---------------------------------------------------------------------------

def negacyclic_convolve_ref(a: np.ndarray, b: np.ndarray, p: int) -> np.ndarray:
    """Schoolbook product in Z_p[X]/(X^N+1); a, b: (N,) ints."""
    n = len(a)
    out = np.zeros(n, dtype=object)
    aa = a.astype(object)
    bb = b.astype(object)
    for i in range(n):
        # contribution of b[i]: shift a by i with sign wrap
        part = np.concatenate([-aa[n - i:], aa[: n - i]]) if i else aa
        out = (out + part * bb[i]) % p
    return out.astype(np.uint64)


# ---------------------------------------------------------------------------
# Galois automorphisms
# ---------------------------------------------------------------------------

def galois_element(step: int, n: int) -> int:
    """Galois element for Rotate(step) on N/2 slots: 5^step mod 2N.

    Negative steps rotate the other way; step=None conventionally means
    conjugation (element 2N-1), handled by callers.
    """
    two_n = 2 * n
    return pow(5, step % (n // 2), two_n)


CONJ_ELEMENT_OFFSET = -1  # conjugation is element 2N-1


def coeff_perm(galois_elt: int, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Coefficient-domain automorphism sigma_k: a_i -> (+/-) a'_{ik mod N}.

    Returns (src_index, negate) such that
    ``out[j] = negate[j] ? q - a[src[j]] : a[src[j]]`` (gather form).
    """
    k = galois_elt
    i = np.arange(n, dtype=np.int64)
    e = (i * k) % (2 * n)
    dest = e % n
    neg_at_dest = (e >= n)
    src = np.empty(n, dtype=np.int64)
    neg = np.empty(n, dtype=bool)
    src[dest] = i
    neg[dest] = neg_at_dest
    return src, neg


@functools.lru_cache(maxsize=None)
def _exponent_order_cached(p: int, psi: int, log_n: int) -> tuple:
    """The exponent e_i such that forward-NTT output slot i holds a(psi^{e_i})."""
    n = 1 << log_n
    # NTT of X: slot i = psi^{e_i}
    import jax.numpy as _jnp
    x_poly = np.zeros((1, n), dtype=np.uint64)
    x_poly[0, 1] = 1
    brv = bit_reverse_vector(n)
    pw = np.empty(n, dtype=np.uint64)
    x = 1
    for i in range(n):
        pw[i] = x
        x = x * psi % p
    rp = _jnp.asarray(pw[brv])[None, :]
    q = _jnp.asarray(np.array([p], dtype=np.uint64))
    vals = np.asarray(ntt_forward(_jnp.asarray(x_poly), rp, q))[0]
    val_to_exp = {pow(psi, e, p): e for e in range(1, 2 * n, 2)}
    return tuple(val_to_exp[int(v)] for v in vals)


def eval_perm(galois_elt: int, p: int, psi: int, log_n: int) -> np.ndarray:
    """Evaluation(NTT)-domain automorphism permutation.

    out_slot[i] = in_slot[perm[i]]  implements  sigma_k  in the NTT domain —
    this is the beyond-paper "NTT-domain rotation" optimization (the paper
    permutes in coefficient domain with its interleaved mat layout §IV-E;
    on TPU a static gather in the evaluation domain avoids the iNTT/NTT
    round-trip entirely).
    """
    n = 1 << log_n
    exps = _exponent_order_cached(p, psi, log_n)
    pos = {e: i for i, e in enumerate(exps)}
    k = galois_elt
    return np.array([pos[(e * k) % (2 * n)] for e in exps], dtype=np.int64)
