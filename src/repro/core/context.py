"""CkksContext: the precompute hub for a CKKS parameter set.

Owns: moduli chain, NTT tables for the full Q∪P basis, reduction constants,
cached BConv tables per (src,dst) basis pair, cached Galois permutations.
Everything host-precomputed once; runtime ops are pure jnp on these arrays.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import modarith as ma
from repro.core import ntt as nttm
from repro.core import rns
from repro.core.params import CkksParams


class CkksContext:

    def __init__(self, params: CkksParams):
        self.params = params
        self.log_n = params.log_n
        self.n = params.n
        self.moduli = params.moduli                       # Q then P
        self.n_q = params.n_q_moduli
        self.n_p = params.n_special
        self.primes: List[int] = [m.value for m in self.moduli]
        self.q_primes = self.primes[: self.n_q]
        self.p_primes = self.primes[self.n_q:]

        # NTT tables over the whole basis; limb slices are cheap views.
        self.tables = nttm.NttTables(self.moduli, self.log_n)
        self.q_all = self.tables.q                        # (n_q+n_p,)

        # reduction constants per limb
        self.barrett_mu = jnp.asarray(
            np.array([ma.barrett_mu(p) for p in self.primes], dtype=np.uint64))
        self.mont_qinv_neg = jnp.asarray(
            np.array([ma.mont_qinv_neg(p) for p in self.primes], dtype=np.uint64))
        self.mont_r2 = jnp.asarray(
            np.array([ma.mont_r2(p) for p in self.primes], dtype=np.uint64))

        # P^{-1} mod q_j (ModDown constant)
        big_p = 1
        for p in self.p_primes:
            big_p *= p
        self.big_p = big_p
        self.p_inv_mod_q = jnp.asarray(np.array(
            [pow(big_p % q, -1, q) for q in self.q_primes], dtype=np.uint64))

        # q_last^{-1} mod q_i for every rescale level: rescale from level l
        # drops prime index l; constants[l][i] = q_l^{-1} mod q_i for i<l
        self._qlast_inv: List[jnp.ndarray] = []
        for l in range(self.n_q):
            if l == 0:
                self._qlast_inv.append(jnp.zeros((0,), dtype=jnp.uint64))
            else:
                ql = self.q_primes[l]
                self._qlast_inv.append(jnp.asarray(np.array(
                    [pow(ql % qi, -1, qi) for qi in self.q_primes[:l]],
                    dtype=np.uint64)))

        self._bconv_cache: Dict[Tuple[Tuple[int, ...], Tuple[int, ...]],
                                rns.BConvTables] = {}
        self._eval_perm_cache: Dict[int, jnp.ndarray] = {}
        self._limb_tables_cache: Dict[Tuple[int, ...], nttm.NttTables] = {}

    # -- basis helpers ------------------------------------------------------

    def q_idx(self, level: int) -> List[int]:
        """Global limb indices of the active Q basis at `level`."""
        return list(range(level + 1))

    def p_idx(self) -> List[int]:
        return list(range(self.n_q, self.n_q + self.n_p))

    def limb_tables(self, idx: Sequence[int]) -> nttm.NttTables:
        key = tuple(idx)
        if key not in self._limb_tables_cache:
            self._limb_tables_cache[key] = self.tables.slice_limbs(list(key))
        return self._limb_tables_cache[key]

    def bconv_tables(self, src_idx: Sequence[int],
                     dst_idx: Sequence[int]) -> rns.BConvTables:
        key = (tuple(src_idx), tuple(dst_idx))
        if key not in self._bconv_cache:
            self._bconv_cache[key] = rns.make_bconv_tables(
                [self.primes[i] for i in key[0]],
                [self.primes[i] for i in key[1]])
        return self._bconv_cache[key]

    # -- NTT wrappers over global limb indices ------------------------------

    def ntt(self, a: jnp.ndarray, idx: Sequence[int]) -> jnp.ndarray:
        return nttm.ntt(a, self.limb_tables(idx))

    def intt(self, a: jnp.ndarray, idx: Sequence[int]) -> jnp.ndarray:
        return nttm.intt(a, self.limb_tables(idx))

    # -- Galois -------------------------------------------------------------

    def eval_perm(self, galois_elt: int) -> jnp.ndarray:
        """NTT-domain automorphism permutation (same for every limb)."""
        if galois_elt not in self._eval_perm_cache:
            perm = nttm.eval_perm(galois_elt, self.primes[0],
                                  self.tables.psi[0], self.log_n)
            self._eval_perm_cache[galois_elt] = jnp.asarray(perm)
        return self._eval_perm_cache[galois_elt]

    def rotation_element(self, step: int) -> int:
        return nttm.galois_element(step, self.n)

    @property
    def conj_element(self) -> int:
        return 2 * self.n - 1

    # -- misc ---------------------------------------------------------------

    def qlast_inv(self, level: int) -> jnp.ndarray:
        return self._qlast_inv[level]

    @functools.cached_property
    def q_products(self) -> List[int]:
        """prod(q_0..q_l) per level (python ints, for scale bookkeeping)."""
        out, acc = [], 1
        for p in self.q_primes:
            acc *= p
            out.append(acc)
        return out
