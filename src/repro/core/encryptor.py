"""Key generation, encryption, decryption for full-RNS CKKS.

Sampling conventions (standard RNS practice):
* uniform ring elements are sampled directly in the NTT domain, limb-wise
  independent (valid by the CRT isomorphism R_Q ≅ ∏_i Z_{q_i}^N);
* small elements (secret, errors) are sampled as integer coefficient
  vectors, reduced per limb, then NTT'd — the *same* small polynomial in
  every limb.

Key-switching keys implement the generalized (Han–Ki) gadget: for digit d
with modulus group Q_d,   g_d = P * Qhat_d * [Qhat_d^{-1}]_{Q_d}  (mod each
prime of Q∪P), and  ksk_d = (-a_d s + e_d + g_d * s_src , a_d).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.core import modarith as ma
from repro.core.ciphertext import (Ciphertext, KeySwitchKey, Plaintext,
                                   PublicKey, SecretKey)
from repro.core.context import CkksContext


class CkksEncryptor:

    def __init__(self, ctx: CkksContext, seed: int = 2024):
        self.ctx = ctx
        self.rng = np.random.default_rng(seed)

    # -- sampling -----------------------------------------------------------

    def _sample_uniform_ntt(self, idx: Sequence[int],
                            shape_prefix=()) -> jnp.ndarray:
        primes = np.array([self.ctx.primes[i] for i in idx], dtype=np.uint64)
        out = np.empty(shape_prefix + (len(idx), self.ctx.n), dtype=np.uint64)
        for k, p in enumerate(primes):
            out[..., k, :] = self.rng.integers(0, p, size=shape_prefix + (self.ctx.n,),
                                               dtype=np.uint64)
        return jnp.asarray(out)

    def _sample_error_coeff(self) -> np.ndarray:
        e = np.round(self.rng.normal(0.0, self.ctx.params.error_std,
                                     size=self.ctx.n)).astype(np.int64)
        return e

    def _sample_ternary_coeff(self, hamming: Optional[int] = None) -> np.ndarray:
        n = self.ctx.n
        h = hamming or self.ctx.params.hamming_weight_sk
        s = np.zeros(n, dtype=np.int64)
        pos = self.rng.choice(n, size=h, replace=False)
        s[pos] = self.rng.choice(np.array([-1, 1]), size=h)
        return s

    def _small_to_ntt(self, coeffs: np.ndarray, idx: Sequence[int]) -> jnp.ndarray:
        primes = np.array([self.ctx.primes[i] for i in idx], dtype=np.int64)
        limbs = (coeffs[None, :] % primes[:, None]).astype(np.uint64)
        return self.ctx.ntt(jnp.asarray(limbs), idx)

    # -- keygen -------------------------------------------------------------

    def keygen(self) -> SecretKey:
        s = self._sample_ternary_coeff()
        all_idx = list(range(self.ctx.n_q + self.ctx.n_p))
        return SecretKey(s_ntt=self._small_to_ntt(s, all_idx),
                         s_coeff_ternary=jnp.asarray(s.astype(np.int8)))

    def public_keygen(self, sk: SecretKey) -> PublicKey:
        idx = self.ctx.q_idx(self.ctx.params.n_levels)
        q = self.ctx.q_all[np.array(idx)]
        a = self._sample_uniform_ntt(idx)
        e = self._small_to_ntt(self._sample_error_coeff(), idx)
        s = sk.s_ntt[np.array(idx)]
        b = ma.submod(e, ma.mulmod(a, s, q[:, None]), q[:, None])
        return PublicKey(data=jnp.stack([b, a]))

    def _ksk_gen(self, sk: SecretKey, target_ntt: jnp.ndarray) -> KeySwitchKey:
        """KSK switching FROM the key whose full-basis NTT rep is target_ntt
        TO sk. target_ntt: (n_q+n_p, N)."""
        ctx = self.ctx
        all_idx = list(range(ctx.n_q + ctx.n_p))
        q = ctx.q_all
        s = sk.s_ntt
        big_p = ctx.big_p
        big_q_full = 1
        for p in ctx.q_primes:
            big_q_full *= p
        digits = ctx.params.digit_indices(ctx.params.n_levels)
        ksk = []
        for d, J in enumerate(digits):
            q_d = 1
            for j in J:
                q_d *= ctx.q_primes[j]
            qhat_d = big_q_full // q_d
            g_d = big_p * qhat_d * pow(qhat_d % q_d, -1, q_d)
            g_limbs = jnp.asarray(np.array(
                [g_d % ctx.primes[i] for i in all_idx], dtype=np.uint64))
            a = self._sample_uniform_ntt(all_idx)
            e = self._small_to_ntt(self._sample_error_coeff(), all_idx)
            body = ma.mulmod(target_ntt, g_limbs[:, None], q[:, None])
            b = ma.addmod(
                ma.submod(e, ma.mulmod(a, s, q[:, None]), q[:, None]),
                body, q[:, None])
            ksk.append(jnp.stack([b, a]))
        return KeySwitchKey(data=jnp.stack(ksk))

    def relin_keygen(self, sk: SecretKey) -> KeySwitchKey:
        q = self.ctx.q_all
        s2 = ma.mulmod(sk.s_ntt, sk.s_ntt, q[:, None])
        return self._ksk_gen(sk, s2)

    def galois_keygen(self, sk: SecretKey,
                      elements: Sequence[int]) -> Dict[int, KeySwitchKey]:
        """Keys for sigma_k(s) -> s, per Galois element k."""
        out = {}
        for k in elements:
            perm = self.ctx.eval_perm(k)
            s_rot = sk.s_ntt[:, perm]
            out[k] = self._ksk_gen(sk, s_rot)
        return out

    def rotation_keygen(self, sk: SecretKey,
                        steps: Sequence[int]) -> Dict[int, KeySwitchKey]:
        elts = sorted({self.ctx.rotation_element(st) for st in steps})
        return self.galois_keygen(sk, elts)

    # -- encrypt / decrypt ---------------------------------------------------

    def encrypt_sk(self, pt: Plaintext, sk: SecretKey) -> Ciphertext:
        idx = self.ctx.q_idx(pt.level)
        q = self.ctx.q_all[np.array(idx)]
        a = self._sample_uniform_ntt(idx)
        e = self._small_to_ntt(self._sample_error_coeff(), idx)
        s = sk.s_ntt[np.array(idx)]
        b = ma.addmod(
            ma.submod(e, ma.mulmod(a, s, q[:, None]), q[:, None]),
            pt.data, q[:, None])
        return Ciphertext(jnp.stack([b, a]), pt.level, pt.scale)

    def encrypt_pk(self, pt: Plaintext, pk: PublicKey) -> Ciphertext:
        ctx = self.ctx
        idx = ctx.q_idx(pt.level)
        q = ctx.q_all[np.array(idx)]
        n_limbs = len(idx)
        u = self._small_to_ntt(self._sample_ternary_coeff(), idx)
        e0 = self._small_to_ntt(self._sample_error_coeff(), idx)
        e1 = self._small_to_ntt(self._sample_error_coeff(), idx)
        pk0 = pk.data[0, :n_limbs]
        pk1 = pk.data[1, :n_limbs]
        b = ma.addmod(ma.addmod(ma.mulmod(pk0, u, q[:, None]), e0, q[:, None]),
                      pt.data, q[:, None])
        a = ma.addmod(ma.mulmod(pk1, u, q[:, None]), e1, q[:, None])
        return Ciphertext(jnp.stack([b, a]), pt.level, pt.scale)

    def decrypt(self, ct: Ciphertext, sk: SecretKey) -> Plaintext:
        idx = self.ctx.q_idx(ct.level)
        q = self.ctx.q_all[np.array(idx)]
        s = sk.s_ntt[np.array(idx)]
        m = ma.addmod(ct.data[0], ma.mulmod(ct.data[1], s, q[:, None]),
                      q[:, None])
        return Plaintext(m, ct.level, ct.scale)
