"""CKKS parameter sets and NTT/Montgomery-friendly prime generation.

The paper (§IV-B) selects moduli of the form ``2^b ± 2^s1 ± ... ± 1`` with
low Hamming weight h so the NMU's digit-serial multiplier issues only h
additions. We implement the same moduli-selection policy: the prime search
prefers Solinas-form primes ``2^b - 2^s + 1`` (h=3) that are NTT-friendly
(``p ≡ 1 mod 2N``), and falls back to general NTT-friendly primes (which
then use Montgomery/Barrett reduction).

Everything here is host-side Python-int math (keygen/precompute time); no
JAX arrays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# primality / roots of unity (host side, python ints)
# ---------------------------------------------------------------------------

_MR_BASES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    for p in _MR_BASES:
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for a in _MR_BASES:
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def _factorize(n: int) -> List[int]:
    fs = []
    d = 2
    while d * d <= n:
        while n % d == 0:
            if not fs or fs[-1] != d:
                fs.append(d)
            n //= d
        d += 1
    if n > 1:
        fs.append(n)
    return fs


def find_primitive_root(p: int) -> int:
    """Smallest generator of Z_p^*."""
    factors = _factorize(p - 1)
    for g in range(2, p):
        if all(pow(g, (p - 1) // f, p) != 1 for f in factors):
            return g
    raise ValueError(f"no generator for {p}")


def find_2nth_root(p: int, two_n: int) -> int:
    """A primitive 2N-th root of unity psi mod p (psi^N == -1)."""
    assert (p - 1) % two_n == 0, f"{p} not NTT-friendly for 2N={two_n}"
    g = find_primitive_root(p)
    psi = pow(g, (p - 1) // two_n, p)
    n = two_n // 2
    assert pow(psi, n, p) == p - 1, "psi^N != -1"
    return psi


# ---------------------------------------------------------------------------
# prime search
# ---------------------------------------------------------------------------

def solinas_candidates(bits: int, log_two_n: int) -> List[Tuple[int, int, int]]:
    """Solinas primes 2^b - 2^s + 1 ≡ 1 (mod 2N): needs s >= log(2N).

    Returns list of (p, b, s), largest s (fastest fold) first.
    """
    out = []
    for s in range(bits - 1, log_two_n - 1, -1):
        p = (1 << bits) - (1 << s) + 1
        if is_prime(p):
            out.append((p, bits, s))
    return out


def generic_ntt_primes(bits: int, two_n: int, count: int,
                       exclude: Sequence[int] = ()) -> List[int]:
    """Primes ≡ 1 (mod 2N) just below 2^bits, descending."""
    out: List[int] = []
    p = ((1 << bits) - 1) // two_n * two_n + 1
    excl = set(exclude)
    while len(out) < count and p > (1 << (bits - 1)):
        if p not in excl and is_prime(p):
            out.append(p)
        p -= two_n
    if len(out) < count:
        raise ValueError(f"not enough {bits}-bit NTT primes for 2N={two_n}")
    return out


@dataclasses.dataclass(frozen=True)
class Modulus:
    """One RNS modulus with its reduction metadata."""
    value: int
    solinas: Optional[Tuple[int, int]] = None  # (b, s) if 2^b - 2^s + 1

    @property
    def is_solinas(self) -> bool:
        return self.solinas is not None

    @property
    def hamming_weight(self) -> int:
        # popcount of the modulus (the paper's h; Solinas primes have h=3-ish)
        return bin(self.value).count("1")


def find_ntt_primes(bits: int, log_n: int, count: int,
                    prefer_solinas: bool = True,
                    exclude: Sequence[int] = ()) -> List[Modulus]:
    """Find `count` NTT-friendly primes of ~`bits` bits for ring degree 2^log_n.

    Solinas-form primes are preferred (paper §IV-B); distinct-bit-width
    neighbours (bits±1) are probed for extra Solinas hits before falling back
    to generic primes.
    """
    two_n = 1 << (log_n + 1)
    excl = set(exclude)
    out: List[Modulus] = []
    if prefer_solinas:
        for b in (bits, bits - 1, bits + 1):
            for p, bb, ss in solinas_candidates(b, log_n + 1):
                if p not in excl and len(out) < count:
                    out.append(Modulus(p, (bb, ss)))
                    excl.add(p)
    if len(out) < count:
        for p in generic_ntt_primes(bits, two_n, count - len(out), tuple(excl)):
            out.append(Modulus(p))
            excl.add(p)
    return out[:count]


# ---------------------------------------------------------------------------
# parameter sets
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CkksParams:
    """Full-RNS CKKS parameters.

    word32 mode: all moduli < 2^31 (DESIGN.md §2). The modulus chain is
    [q0 (first), q1..qL (scale primes)] plus `n_special` special primes P
    for key switching, grouped into `dnum` digits.
    """
    log_n: int
    log_scale: int
    n_levels: int                     # L: number of rescalings available
    dnum: int = 1
    first_mod_bits: int = 31
    scale_mod_bits: Optional[int] = None   # default: log_scale
    special_mod_bits: int = 31
    prefer_solinas: bool = True
    error_std: float = 3.2
    hamming_weight_sk: int = 64            # secret key density

    @property
    def n(self) -> int:
        return 1 << self.log_n

    @property
    def slots(self) -> int:
        return self.n // 2

    @property
    def n_q_moduli(self) -> int:
        return self.n_levels + 1

    @property
    def alpha(self) -> int:
        """Digit size: primes per key-switching digit."""
        return -(-self.n_q_moduli // self.dnum)

    @property
    def n_special(self) -> int:
        return self.alpha

    @functools.cached_property
    def moduli(self) -> Tuple[Modulus, ...]:
        """[q0, q1..qL] then [p0..p_{k-1}] special primes."""
        sbits = self.scale_mod_bits or self.log_scale
        q0 = find_ntt_primes(self.first_mod_bits, self.log_n, 1,
                             self.prefer_solinas)
        used = [q0[0].value]
        qs = find_ntt_primes(sbits, self.log_n, self.n_levels,
                             self.prefer_solinas, exclude=used)
        used += [m.value for m in qs]
        ps = find_ntt_primes(self.special_mod_bits, self.log_n, self.n_special,
                             self.prefer_solinas, exclude=used)
        return tuple(q0 + qs + ps)

    @property
    def q_moduli(self) -> Tuple[Modulus, ...]:
        return self.moduli[: self.n_q_moduli]

    @property
    def p_moduli(self) -> Tuple[Modulus, ...]:
        return self.moduli[self.n_q_moduli:]

    def digit_indices(self, level: int) -> List[List[int]]:
        """Key-switch digit grouping of q-indices at `level` (L'=level+1 primes)."""
        n_active = level + 1
        return [list(range(d * self.alpha, min((d + 1) * self.alpha, n_active)))
                for d in range(self.dnum)
                if d * self.alpha < n_active]


# Presets -------------------------------------------------------------------

def test_params(log_n: int = 10, n_levels: int = 4, dnum: int = 2,
                log_scale: int = 26) -> CkksParams:
    """Small parameters for CPU tests (NOT secure)."""
    return CkksParams(log_n=log_n, log_scale=log_scale, n_levels=n_levels,
                      dnum=dnum, first_mod_bits=30, scale_mod_bits=log_scale,
                      special_mod_bits=30)


def paper_params_bootstrap() -> CkksParams:
    """The paper's deep-workload setting (§V-C): logN=16, L=23, dnum=4.

    The paper uses 40–61-bit RNS limbs in 64-bit words; in word32 mode the
    same logQ budget is met with more, narrower limbs (DESIGN.md §2).
    logPQ here ≈ (24·28 + 31) + 7·30 ≈ 913 bits vs paper's 1556 with wide
    limbs — the *structure* (L, dnum, N) is what the pipeline exercises.
    """
    return CkksParams(log_n=16, log_scale=28, n_levels=23, dnum=4,
                      first_mod_bits=31, scale_mod_bits=28,
                      special_mod_bits=31)


def paper_params_lola() -> CkksParams:
    """The paper's shallow-workload setting (§V-C): logN=14, L=4/6."""
    return CkksParams(log_n=14, log_scale=26, n_levels=6, dnum=1,
                      first_mod_bits=30, scale_mod_bits=26,
                      special_mod_bits=30)
