"""Homomorphic ciphertext algebra: HAdd/HSub/HMul/HRot/Rescale/KeySwitch.

Implements the operations FHEmem accelerates, with the paper's structure:
HMul = tensor product + relinearization (generalized dnum key-switching:
ModUp per digit via BConv, evk multiply-accumulate, ModDown) + rescale.
Rotation = NTT-domain automorphism permutation + key switch with the Galois
key (beyond-paper: the paper permutes in coefficient domain over its
interleaved mat layout §IV-E; the eval-domain permutation avoids the
iNTT/NTT round-trip — see DESIGN.md §3 and the fig15 ablation which retains
the coeff-domain path as `rotate_coeff_domain`).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import modarith as ma
from repro.core import rns
from repro.core.ciphertext import Ciphertext, KeySwitchKey, Plaintext
from repro.core.context import CkksContext


# ---------------------------------------------------------------------------
# level / scale alignment
# ---------------------------------------------------------------------------

def mod_switch_to_level(ct: Ciphertext, level: int) -> Ciphertext:
    """Drop limbs (valid modulus reduction); scale unchanged."""
    assert level <= ct.level
    if level == ct.level:
        return ct
    return Ciphertext(ct.data[:, : level + 1], level, ct.scale)


def _align(ct0: Ciphertext, ct1: Ciphertext) -> Tuple[Ciphertext, Ciphertext]:
    lvl = min(ct0.level, ct1.level)
    return mod_switch_to_level(ct0, lvl), mod_switch_to_level(ct1, lvl)


# ---------------------------------------------------------------------------
# additive ops
# ---------------------------------------------------------------------------

def hadd(ctx: CkksContext, ct0: Ciphertext, ct1: Ciphertext) -> Ciphertext:
    ct0, ct1 = _align(ct0, ct1)
    assert abs(ct0.scale / ct1.scale - 1.0) < 1e-6, "scale mismatch in hadd"
    q = ctx.q_all[: ct0.n_limbs]
    return Ciphertext(ma.addmod(ct0.data, ct1.data, q[:, None]),
                      ct0.level, ct0.scale)


def hsub(ctx: CkksContext, ct0: Ciphertext, ct1: Ciphertext) -> Ciphertext:
    ct0, ct1 = _align(ct0, ct1)
    assert abs(ct0.scale / ct1.scale - 1.0) < 1e-6, "scale mismatch in hsub"
    q = ctx.q_all[: ct0.n_limbs]
    return Ciphertext(ma.submod(ct0.data, ct1.data, q[:, None]),
                      ct0.level, ct0.scale)


def hneg(ctx: CkksContext, ct: Ciphertext) -> Ciphertext:
    q = ctx.q_all[: ct.n_limbs]
    return Ciphertext(ma.negmod(ct.data, q[:, None]), ct.level, ct.scale)


def padd(ctx: CkksContext, ct: Ciphertext, pt: Plaintext) -> Ciphertext:
    assert pt.level >= ct.level
    assert abs(ct.scale / pt.scale - 1.0) < 1e-6, "scale mismatch in padd"
    q = ctx.q_all[: ct.n_limbs]
    b = ma.addmod(ct.data[0], pt.data[: ct.n_limbs], q[:, None])
    return Ciphertext(jnp.stack([b, ct.data[1]]), ct.level, ct.scale)


# ---------------------------------------------------------------------------
# multiplicative ops
# ---------------------------------------------------------------------------

def pmul(ctx: CkksContext, ct: Ciphertext, pt: Plaintext,
         do_rescale: bool = True) -> Ciphertext:
    """Ciphertext x plaintext."""
    assert pt.level >= ct.level
    q = ctx.q_all[: ct.n_limbs]
    data = ma.mulmod(ct.data, pt.data[None, : ct.n_limbs], q[:, None])
    out = Ciphertext(data, ct.level, ct.scale * pt.scale)
    return rescale(ctx, out) if do_rescale else out


def pmul_scalar_int(ctx: CkksContext, ct: Ciphertext, c: int) -> Ciphertext:
    """Multiply by a small exact integer (no scale change)."""
    q = ctx.q_all[: ct.n_limbs]
    cv = jnp.asarray(np.array([c % ctx.primes[i] for i in range(ct.n_limbs)],
                              dtype=np.uint64))
    return Ciphertext(ma.mulmod(ct.data, cv[None, :, None], q[:, None]),
                      ct.level, ct.scale)


def hmul(ctx: CkksContext, ct0: Ciphertext, ct1: Ciphertext,
         relin_key: KeySwitchKey, do_rescale: bool = True) -> Ciphertext:
    """Homomorphic multiply: tensor + relinearize (+ rescale)."""
    ct0, ct1 = _align(ct0, ct1)
    q = ctx.q_all[: ct0.n_limbs][:, None]
    b0, a0 = ct0.data[0], ct0.data[1]
    b1, a1 = ct1.data[0], ct1.data[1]
    d0 = ma.mulmod(b0, b1, q)
    d1 = ma.addmod(ma.mulmod(a0, b1, q), ma.mulmod(a1, b0, q), q)
    d2 = ma.mulmod(a0, a1, q)
    e0, e1 = key_switch(ctx, d2, ct0.level, relin_key)
    data = jnp.stack([ma.addmod(d0, e0, q), ma.addmod(d1, e1, q)])
    out = Ciphertext(data, ct0.level, ct0.scale * ct1.scale)
    return rescale(ctx, out) if do_rescale else out


def hsquare(ctx: CkksContext, ct: Ciphertext, relin_key: KeySwitchKey,
            do_rescale: bool = True) -> Ciphertext:
    q = ctx.q_all[: ct.n_limbs][:, None]
    b, a = ct.data[0], ct.data[1]
    d0 = ma.mulmod(b, b, q)
    ab = ma.mulmod(a, b, q)
    d1 = ma.addmod(ab, ab, q)
    d2 = ma.mulmod(a, a, q)
    e0, e1 = key_switch(ctx, d2, ct.level, relin_key)
    data = jnp.stack([ma.addmod(d0, e0, q), ma.addmod(d1, e1, q)])
    out = Ciphertext(data, ct.level, ct.scale * ct.scale)
    return rescale(ctx, out) if do_rescale else out


# ---------------------------------------------------------------------------
# rescale (divide-and-round by the last prime)
# ---------------------------------------------------------------------------

def rescale(ctx: CkksContext, ct: Ciphertext) -> Ciphertext:
    assert ct.level >= 1, "no levels left to rescale"
    lvl = ct.level
    last_idx = [lvl]
    rem_idx = ctx.q_idx(lvl - 1)
    q_rem = ctx.q_all[: lvl][:, None]
    # last limb -> coefficient domain
    c_last = ctx.intt(ct.data[:, lvl:lvl + 1, :], last_idx)   # (2,1,N)
    # broadcast into each remaining modulus (floor-divide variant)
    t = c_last % q_rem                                         # (2,L,N)
    t_ntt = ctx.ntt(t, rem_idx)
    diff = ma.submod(ct.data[:, :lvl], t_ntt, q_rem)
    out = ma.mulmod(diff, ctx.qlast_inv(lvl)[:, None], q_rem)
    new_scale = ct.scale / ctx.q_primes[lvl]
    return Ciphertext(out, lvl - 1, new_scale)


# ---------------------------------------------------------------------------
# key switching (generalized dnum digits, Han–Ki)
# ---------------------------------------------------------------------------

def mod_up(ctx: CkksContext, dig_ntt: jnp.ndarray, dig_idx: List[int],
           target_idx: List[int]) -> jnp.ndarray:
    """ModUp one digit from its own basis to target basis (NTT in/out).

    Digit limbs present in target are copied; the rest come from an
    iNTT -> BConv -> NTT round trip (the paper's §II-A flow).
    """
    other_idx = [i for i in target_idx if i not in dig_idx]
    dig_coeff = ctx.intt(dig_ntt, dig_idx)
    tabs = ctx.bconv_tables(dig_idx, other_idx)
    conv = rns.bconv(dig_coeff, tabs)
    conv_ntt = ctx.ntt(conv, other_idx)
    # interleave into target order
    n = ctx.n
    out = jnp.zeros((len(target_idx), n), dtype=jnp.uint64)
    pos = {g: i for i, g in enumerate(target_idx)}
    dig_pos = np.array([pos[g] for g in dig_idx])
    oth_pos = np.array([pos[g] for g in other_idx])
    out = out.at[dig_pos].set(dig_ntt)
    out = out.at[oth_pos].set(conv_ntt)
    return out


def key_switch(ctx: CkksContext, d2: jnp.ndarray, level: int,
               ksk: KeySwitchKey) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Switch d2 (level+1, N limbs, NTT) to the key encrypted in ksk.

    Returns (delta_b, delta_a) at level `level` (Q basis only), already
    ModDown'ed (divided by P).
    """
    idx_q = ctx.q_idx(level)
    idx_p = ctx.p_idx()
    target = idx_q + idx_p
    q_t = ctx.q_all[np.array(target)][:, None]
    digits = ctx.params.digit_indices(level)
    acc0 = jnp.zeros((len(target), ctx.n), dtype=jnp.uint64)
    acc1 = jnp.zeros((len(target), ctx.n), dtype=jnp.uint64)
    ksk_sel = ksk.data[:, :, np.array(target)]   # (dnum', 2, T, N)
    for d, J in enumerate(digits):
        raised = mod_up(ctx, d2[np.array(J)], J, target)
        acc0 = ma.addmod(acc0, ma.mulmod(raised, ksk_sel[d, 0], q_t), q_t)
        acc1 = ma.addmod(acc1, ma.mulmod(raised, ksk_sel[d, 1], q_t), q_t)
    return (_mod_down(ctx, acc0, idx_q, idx_p),
            _mod_down(ctx, acc1, idx_q, idx_p))


def _mod_down(ctx: CkksContext, a: jnp.ndarray, idx_q: List[int],
              idx_p: List[int]) -> jnp.ndarray:
    """(a_Q - BConv_{P->Q}(a_P)) * P^{-1} over Q. a: (|Q|+|P|, N) NTT."""
    nq = len(idx_q)
    a_q, a_p = a[:nq], a[nq:]
    p_coeff = ctx.intt(a_p, idx_p)
    tabs = ctx.bconv_tables(idx_p, idx_q)
    conv = rns.bconv(p_coeff, tabs)
    conv_ntt = ctx.ntt(conv, idx_q)
    q = ctx.q_all[: nq][:, None]
    return rns.mod_down_coeff(a_q, conv_ntt, ctx.p_inv_mod_q[:nq], q[:, 0])


# ---------------------------------------------------------------------------
# rotation / conjugation
# ---------------------------------------------------------------------------

def _apply_galois(ctx: CkksContext, ct: Ciphertext, elt: int,
                  gk: KeySwitchKey) -> Ciphertext:
    perm = ctx.eval_perm(elt)
    q = ctx.q_all[: ct.n_limbs][:, None]
    b_rot = ct.data[0][:, perm]
    a_rot = ct.data[1][:, perm]
    e0, e1 = key_switch(ctx, a_rot, ct.level, gk)
    return Ciphertext(jnp.stack([ma.addmod(b_rot, e0, q), e1]),
                      ct.level, ct.scale)


def rotate(ctx: CkksContext, ct: Ciphertext, step: int,
           gk: KeySwitchKey) -> Ciphertext:
    """Rotate packed slots by `step` (slot i of output = slot i+step of input)."""
    return _apply_galois(ctx, ct, ctx.rotation_element(step), gk)


def conjugate(ctx: CkksContext, ct: Ciphertext,
              gk: KeySwitchKey) -> Ciphertext:
    return _apply_galois(ctx, ct, ctx.conj_element, gk)


def rotate_coeff_domain(ctx: CkksContext, ct: Ciphertext, step: int,
                        gk: KeySwitchKey) -> Ciphertext:
    """Paper-faithful rotation: automorphism applied in coefficient domain
    (iNTT -> index-map gather with sign -> NTT), then key switch.
    Numerically identical to `rotate`; kept for the fig15-style ablation."""
    from repro.core import ntt as nttm
    elt = ctx.rotation_element(step)
    idx = ctx.q_idx(ct.level)
    q = ctx.q_all[: ct.n_limbs][:, None]
    src, neg = nttm.coeff_perm(elt, ctx.n)
    coeff = ctx.intt(ct.data, idx)
    gathered = coeff[..., src]
    rotated = jnp.where(jnp.asarray(neg)[None, None, :],
                        ma.negmod(gathered, q), gathered)
    data = ctx.ntt(rotated, idx)
    e0, e1 = key_switch(ctx, data[1], ct.level, gk)
    return Ciphertext(jnp.stack([ma.addmod(data[0], e0, q), e1]),
                      ct.level, ct.scale)
