"""CKKS bootstrapping: ModRaise -> CoefToSlot -> EvalMod -> SlotToCoef.

This is the paper's flagship deep workload (§V-B "Bootstrapping", and the
CoefToSlot pipeline of Fig. 10). Full-slot (Han-Ki style) flow:

1. ModRaise: reinterpret a level-0 ciphertext at level L; the hidden message
   becomes t = m + q0*I with small integer polynomial I (sparse secret).
2. CoefToSlot: homomorphic linear transform moving coefficients into slots,
   packed z_j = (c_j + i*c_{j+N/2})/Delta — one ciphertext.
3. EvalMod: approximate t -> t mod q0 via the scaled sine
   (q0/2pi) sin(2pi t/q0), evaluated with Chebyshev interpolation on the
   real and imaginary parts separately.
4. SlotToCoef: inverse linear transform.

Matrices are derived numerically from the canonical embedding (exact
semantics; the O(N log N) sparse FFT factorization of these matrices is a
scheduling optimization the mapping framework treats as extra pipeline
stages, not a semantic change).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np
import jax.numpy as jnp

from repro.core import linalg, ops as hops
from repro.core.ciphertext import Ciphertext, KeySwitchKey
from repro.core.context import CkksContext
from repro.core.encoder import CkksEncoder


@dataclasses.dataclass
class BootstrapConfig:
    eval_mod_degree: int = 31     # Chebyshev degree for sin
    k_range: float = 12.0         # |t/q0| bound (depends on secret hamming wt)
    cts_level_cost: int = 1
    stc_level_cost: int = 1


class Bootstrapper:

    def __init__(self, ctx: CkksContext, encoder: CkksEncoder,
                 encryptor, sk, config: Optional[BootstrapConfig] = None):
        self.ctx = ctx
        self.encoder = encoder
        self.config = config or BootstrapConfig()
        n = ctx.n
        s = n // 2
        # canonical embedding matrix V (s x n): v_j = sum_k c_k zeta^{k e_j}
        e = encoder.slot_exp.astype(np.float64)
        k = np.arange(n)
        V = np.exp(1j * np.pi * np.outer(encoder.slot_exp, k) / n)
        W = np.vstack([V, np.conj(V)])           # (n, n)
        Winv = np.linalg.inv(W)                  # c = Winv @ [v; conj v]
        P, Q = Winv[:, :s], Winv[:, s:]          # (n, s) each
        self.A_cts = P[:s] + 1j * P[s:]          # z = A v + B conj(v)
        self.B_cts = Q[:s] + 1j * Q[s:]
        V_L, V_R = V[:, :s], V[:, s:]
        self.A_stc = 0.5 * (V_L - 1j * V_R)      # v = A' z + B' conj(z)
        self.B_stc = 0.5 * (V_L + 1j * V_R)
        self.diags_A_cts = linalg.matrix_diagonals(self.A_cts)
        self.diags_B_cts = linalg.matrix_diagonals(self.B_cts)
        self.diags_A_stc = linalg.matrix_diagonals(self.A_stc)
        self.diags_B_stc = linalg.matrix_diagonals(self.B_stc)
        # keys
        elts = set()
        for dg in (self.diags_A_cts, self.diags_B_cts,
                   self.diags_A_stc, self.diags_B_stc):
            elts.update(linalg.matvec_keys_needed(ctx, dg))
        elts.add(ctx.conj_element)
        self.gks: Dict[int, KeySwitchKey] = encryptor.galois_keygen(
            sk, sorted(elts))
        self.rk: KeySwitchKey = encryptor.relin_keygen(sk)
        # Chebyshev coefficients of sin(2*pi*K*y) on y in [-1, 1]
        kk = self.config.k_range
        self.cheb = linalg.chebyshev_coeffs(
            lambda y: np.sin(2 * np.pi * kk * y), self.config.eval_mod_degree)

    # -- stages --------------------------------------------------------------

    def mod_raise(self, ct: Ciphertext, target_level: int) -> Ciphertext:
        """Level-0 ciphertext -> target_level; message becomes m + q0*I."""
        assert ct.level == 0
        ctx = self.ctx
        q0 = ctx.primes[0]
        coeff = np.asarray(ctx.intt(ct.data, [0]))[:, 0]        # (2, N)
        centered = coeff.astype(np.int64)
        centered = np.where(centered > q0 // 2, centered - q0, centered)
        idx = ctx.q_idx(target_level)
        primes = np.array([ctx.primes[i] for i in idx], dtype=np.int64)
        limbs = (centered[:, None, :] % primes[None, :, None]).astype(np.uint64)
        data = ctx.ntt(jnp.asarray(limbs), idx)
        return Ciphertext(data, target_level, ct.scale)

    def _transform(self, ct: Ciphertext, diags_a, diags_b) -> Ciphertext:
        """out = A ct + B conj(ct); B is exactly zero for the packed
        (c_low + i c_high) CtS/StC matrices — the packing makes them
        C-linear — but we keep the general form."""
        ctx, enc = self.ctx, self.encoder
        out = linalg.matvec_bsgs(ctx, ct, diags_a, self.gks, enc)
        if diags_b:
            ct_conj = hops.conjugate(ctx, ct, self.gks[ctx.conj_element])
            zb = linalg.matvec_bsgs(ctx, ct_conj, diags_b, self.gks, enc)
            zb.scale = out.scale
            out = hops.hadd(ctx, out, zb)
        return out

    def coef_to_slot(self, ct: Ciphertext) -> Ciphertext:
        return self._transform(ct, self.diags_A_cts, self.diags_B_cts)

    def slot_to_coef(self, ct: Ciphertext) -> Ciphertext:
        return self._transform(ct, self.diags_A_stc, self.diags_B_stc)

    def eval_mod(self, ct: Ciphertext, q0_over_scale: float) -> Ciphertext:
        """Input slots: t/Delta (t = m + q0 I). Output slots: ~ m/Delta."""
        ctx, enc = self.ctx, self.encoder
        kk = self.config.k_range
        # y = t / (q0 * K) in [-1, 1]
        y = linalg.mul_const(ctx, enc, ct, 1.0 / (q0_over_scale * kk))
        g = linalg.poly_eval_chebyshev(ctx, y, self.cheb, self.rk, enc)
        # m/Delta ~= (q0/Delta) * sin(2 pi t / q0) / (2 pi)
        return linalg.mul_const(ctx, enc, g, q0_over_scale / (2 * np.pi))

    # -- full pipeline ---------------------------------------------------------

    def bootstrap(self, ct: Ciphertext, target_level: int) -> Ciphertext:
        """level-0 -> refreshed ciphertext at a usable level."""
        ctx = self.ctx
        q0 = ctx.primes[0]
        raised = self.mod_raise(ct, target_level)
        z = self.coef_to_slot(raised)
        # split real/imag
        z_conj = hops.conjugate(ctx, z, self.gks[ctx.conj_element])
        z_conj.scale = z.scale
        re = hops.hadd(ctx, z, z_conj)
        re = linalg.mul_const(ctx, self.encoder, re, 0.5)
        im = hops.hsub(ctx, z, z_conj)
        im = linalg.mul_const(ctx, self.encoder, im, -0.5j)
        q0_over_scale = q0 / ct.scale
        re_m = self.eval_mod(re, q0_over_scale)
        im_m = self.eval_mod(im, q0_over_scale)
        im_i = linalg.mul_const(ctx, self.encoder, im_m, 1j)
        re_m = linalg.adjust_to(ctx, self.encoder, re_m, im_i.level, im_i.scale)
        z2 = hops.hadd(ctx, re_m, im_i)
        out = self.slot_to_coef(z2)
        return out
