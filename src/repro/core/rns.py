"""RNS basis tooling: fast base conversion (BConv), ModDown, Rescale.

BConv (paper §II-A eq.(1), §IV-D) is the all-to-all primitive of FHE:

    BConv_{Q->P}(a)_i = [ sum_j [a_j * qhat_j^{-1}]_{q_j} * [qhat_j]_{p_i} ]_{p_i}

Every output limb depends on every input limb. In FHEmem, limbs live in
different banks and this runs on the partial-chain inter-bank network; here
limbs live on different devices along the `model` mesh axis and the same
dependency becomes an all_gather/psum_scatter (repro/fhe_dist). This module
is the exact single-device reference; it operates on *coefficient-domain*
polys as the paper prescribes (an iNTT precedes BConv).

This is the "fast" (HPS-style) conversion: the result may be off by a small
multiple of Q — the standard full-RNS CKKS approximation the paper also
inherits from [24].
"""
from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import modarith as ma


class BConvTables(NamedTuple):
    """Host-precomputed constants for one (src basis -> dst basis) pair."""
    qhat_inv: jnp.ndarray   # (S,)  [qhat_j^{-1}]_{q_j}
    w: jnp.ndarray          # (S, D) [qhat_j]_{p_i}
    src_q: jnp.ndarray      # (S,)
    dst_q: jnp.ndarray      # (D,)


def make_bconv_tables(src_primes: Sequence[int],
                      dst_primes: Sequence[int]) -> BConvTables:
    src = [int(p) for p in src_primes]
    dst = [int(p) for p in dst_primes]
    big_q = 1
    for p in src:
        big_q *= p
    qhat = [big_q // p for p in src]
    qhat_inv = [pow(h % p, -1, p) for h, p in zip(qhat, src)]
    w = np.array([[h % pi for pi in dst] for h in qhat], dtype=np.uint64)
    return BConvTables(
        qhat_inv=jnp.asarray(np.array(qhat_inv, dtype=np.uint64)),
        w=jnp.asarray(w),
        src_q=jnp.asarray(np.array(src, dtype=np.uint64)),
        dst_q=jnp.asarray(np.array(dst, dtype=np.uint64)),
    )


def bconv(a: jnp.ndarray, t: BConvTables) -> jnp.ndarray:
    """Fast base conversion. a: (..., S, N) coeff domain -> (..., D, N).

    Reference schedule: reduce each partial product immediately (the
    kernels use lazy accumulation — see repro/kernels/bconv.py).
    """
    v = ma.mulmod(a, t.qhat_inv[:, None], t.src_q[:, None])   # (..., S, N)
    s = v.shape[-2]
    acc = None
    for j in range(s):
        # (D, 1) * (..., 1, N) -> (..., D, N), reduced mod dst
        term = ma.mulmod(v[..., j:j + 1, :], t.w[j][:, None], t.dst_q[:, None])
        acc = term if acc is None else acc + term   # sum of reduced < S*2^31
    return acc % t.dst_q[:, None]


def bconv_matmul(a: jnp.ndarray, t: BConvTables) -> jnp.ndarray:
    """BConv as an explicit (S,N)x(S,D) contraction — the form the Pallas
    kernel and the MXU mapping use. Exact: lazy u64 accumulation with
    periodic folding every 8 partial products (8 * 2^62-ish < 2^64 needs
    products < 2^61; v<2^31, w<2^30 in our parameter regime)."""
    v = ma.mulmod(a, t.qhat_inv[:, None], t.src_q[:, None])
    s = v.shape[-2]
    acc = jnp.zeros(a.shape[:-2] + (t.w.shape[1],) + a.shape[-1:], dtype=jnp.uint64)
    run = None
    for j in range(s):
        prod = v[..., j:j + 1, :] * t.w[j][:, None]            # < 2^61, unreduced
        run = prod if run is None else run + prod
        if (j + 1) % 4 == 0 or j == s - 1:                     # fold every 4
            acc = (acc + run % t.dst_q[:, None]) % t.dst_q[:, None]
            run = None
    return acc


# ---------------------------------------------------------------------------
# ModDown / Rescale helpers (coeff-domain cores; NTT wrapping in ops.py)
# ---------------------------------------------------------------------------

def mod_down_coeff(a_q: jnp.ndarray, a_p_converted: jnp.ndarray,
                   p_inv_mod_q: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    """(a_q - BConv_{P->Q}(a_p)) * P^{-1} mod q. All (..., L, N) coeff/NTT."""
    diff = ma.submod(a_q, a_p_converted % q[:, None], q[:, None])
    return ma.mulmod(diff, p_inv_mod_q[:, None], q[:, None])


def exact_div_by_last_coeff(a: jnp.ndarray, q_last_inv: jnp.ndarray,
                            q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rescale core: given a (..., L, N) with last limb already broadcast-
    subtracted, multiply by q_last^{-1} mod q_i. Returns (..., L-1, N)."""
    return ma.mulmod(a, q_last_inv[:, None], q[:, None])


def crt_lift_centered(limbs: np.ndarray, primes: Sequence[int]) -> np.ndarray:
    """Exact CRT reconstruction to centered Python ints (host, object array).

    limbs: (L, N) uint64. Returns (N,) object array in (-Q/2, Q/2].
    Used only for decode/decrypt validation — off the hot path.
    """
    primes = [int(p) for p in primes]
    big_q = 1
    for p in primes:
        big_q *= p
    acc = np.zeros(limbs.shape[-1], dtype=object)
    for j, p in enumerate(primes):
        qhat = big_q // p
        corr = (qhat * pow(qhat % p, -1, p))
        acc = (acc + limbs[j].astype(object) * corr) % big_q
    return np.where(acc > big_q // 2, acc - big_q, acc)
