"""FHEmem core: full-RNS CKKS in JAX.

The paper's contribution (near-mat PIM processing for FHE) is adapted to
TPU per DESIGN.md §2. This package is the *algorithmic* substrate: exact
RNS-CKKS with the paper's algorithm-level optimizations (Montgomery-friendly
moduli, three-phase/four-step NTT, interleaved automorphism layout,
load-save pipeline mapping).

64-bit integer mode is required for exact modular arithmetic with u64
intermediates; we enable it at import. Model code (repro.models) is
dtype-explicit, so x64 never changes LM numerics.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.params import CkksParams, find_ntt_primes  # noqa: E402,F401
