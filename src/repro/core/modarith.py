"""Modular arithmetic for RNS-CKKS, vectorized over (limb, coeff) arrays.

All data arrays are uint64 holding values reduced mod a <2^31 modulus
("word32" mode — the TPU-native adaptation of FHEmem's 64-bit words, see
DESIGN.md §2; CraterLake uses 28-bit and SHARP 36-bit words, so short-word
RNS is faithful to the paper's own SOTA baselines). Products of two reduced
values fit in 62 bits, so u64 intermediates are exact.

Four reduction strategies are provided, mirroring the paper's §IV-B
Montgomery-friendly moduli ablation (benchmarks/fig15):

* ``mulmod``            — generic ``(a*b) % q`` (the "oracle" path)
* ``mulmod_barrett``    — Barrett with precomputed mu (mulhi via 32-bit split)
* Montgomery (``mont_*``) — REDC with R=2^32, the digit-serial NMU analogue
* ``mulmod_solinas``    — shift-add folding for ``q = 2^b - 2^s + 1`` moduli
                          (Hamming-weight-h reduction: the paper's favored path)

Shapes: data ``(..., L, N)``; per-limb constants ``(L,)`` are broadcast by
the caller via ``q[:, None]`` (or any broadcast-compatible shape).
"""
from __future__ import annotations

import jax.numpy as jnp

U64 = jnp.uint64


def to_u64(x) -> jnp.ndarray:
    return jnp.asarray(x, dtype=U64)


# ---------------------------------------------------------------------------
# add / sub / neg
# ---------------------------------------------------------------------------

def addmod(a, b, q):
    r = a + b
    return jnp.where(r >= q, r - q, r)


def submod(a, b, q):
    return jnp.where(a >= b, a - b, a + (q - b))


def negmod(a, q):
    return jnp.where(a == 0, a, q - a)


# ---------------------------------------------------------------------------
# generic multiply (exact for q < 2^32: product < 2^64)
# ---------------------------------------------------------------------------

def mulmod(a, b, q):
    return (a * b) % q


def powmod_scalar(a: int, e: int, q: int) -> int:
    return pow(int(a), int(e), int(q))


# ---------------------------------------------------------------------------
# 32-bit-limb helpers (the "compose wide ops from narrow hardware" move that
# mirrors FHEmem's digit-serial NMU; also the exact technique the Pallas
# kernels use on TPU where only 32-bit lanes exist)
# ---------------------------------------------------------------------------

_MASK32 = U64(0xFFFFFFFF)


def mulhi64(a, b):
    """High 64 bits of the 128-bit product a*b (u64 inputs)."""
    a_lo = a & _MASK32
    a_hi = a >> U64(32)
    b_lo = b & _MASK32
    b_hi = b >> U64(32)
    ll = a_lo * b_lo
    lh = a_lo * b_hi
    hl = a_hi * b_lo
    hh = a_hi * b_hi
    # carry of the low half
    mid = (ll >> U64(32)) + (lh & _MASK32) + (hl & _MASK32)
    return hh + (lh >> U64(32)) + (hl >> U64(32)) + (mid >> U64(32))


# ---------------------------------------------------------------------------
# Barrett reduction  (q < 2^31; mu = floor(2^62 / q))
# ---------------------------------------------------------------------------

def barrett_mu(q: int) -> int:
    return (1 << 62) // int(q)


def _barrett_floor_div_2_62(t, mu):
    """floor(t*mu / 2^62) computed exactly with 32-bit splits."""
    t_lo = t & _MASK32
    t_hi = t >> U64(32)
    m_lo = mu & _MASK32
    m_hi = mu >> U64(32)
    ll = t_lo * m_lo
    lh = t_lo * m_hi
    hl = t_hi * m_lo
    hh = t_hi * m_hi
    mid = (ll >> U64(32)) + (lh & _MASK32) + (hl & _MASK32)
    hi128 = hh + (lh >> U64(32)) + (hl >> U64(32)) + (mid >> U64(32))  # bits 64+
    lo128 = (mid << U64(32)) | (ll & _MASK32)  # bits 0..63
    return (hi128 << U64(2)) | (lo128 >> U64(62))


def mulmod_barrett(a, b, q, mu):
    """(a*b) mod q via Barrett; a,b reduced, q < 2^31, mu=floor(2^62/q)."""
    t = a * b
    est = _barrett_floor_div_2_62(t, mu)
    r = t - est * q
    r = jnp.where(r >= q, r - q, r)
    r = jnp.where(r >= q, r - q, r)
    return r


# ---------------------------------------------------------------------------
# Montgomery (R = 2^32, q < 2^31 odd)
# ---------------------------------------------------------------------------

def mont_qinv_neg(q: int) -> int:
    """-q^{-1} mod 2^32."""
    return (-pow(int(q), -1, 1 << 32)) % (1 << 32)


def mont_r2(q: int) -> int:
    """R^2 mod q with R = 2^32 (for conversion into Montgomery form)."""
    return (1 << 64) % int(q)


def mont_reduce(t, q, qinv_neg):
    """REDC: t < q*2^32  →  t * 2^-32 mod q  (result < q)."""
    m = ((t & _MASK32) * qinv_neg) & _MASK32
    r = (t + m * q) >> U64(32)
    return jnp.where(r >= q, r - q, r)


def mont_mul(a, b, q, qinv_neg):
    """a*b*2^-32 mod q for a,b < q < 2^31."""
    return mont_reduce(a * b, q, qinv_neg)


def to_mont(a, q, qinv_neg, r2):
    return mont_mul(a, r2, q, qinv_neg)


def from_mont(a, q, qinv_neg):
    return mont_reduce(a, q, qinv_neg)


# ---------------------------------------------------------------------------
# Solinas / shift-add reduction for q = 2^b - 2^s + 1 (Hamming weight 3).
# 2^b ≡ 2^s - 1 (mod q), so fold high bits down with shifts and adds only —
# this is the paper's Montgomery-friendly-moduli fast path (§IV-B), where the
# NMU issues h additions instead of n.
# ---------------------------------------------------------------------------

def solinas_reduce(t, q, b: int, s: int):
    """Reduce t < 2^63 modulo q = 2^b - 2^s + 1 with shift/add folding."""
    bb = U64(b)
    mask = (U64(1) << bb) - U64(1)
    # three folds always suffice for t < 2^63, b >= 20, s <= b-8
    for _ in range(3):
        hi = t >> bb
        lo = t & mask
        # hi * (2^s - 1) = (hi << s) - hi ;  t = lo + hi*(2^s-1)
        t = lo + (hi << U64(s)) - hi
    t = jnp.where(t >= q, t - q, t)
    t = jnp.where(t >= q, t - q, t)
    return t


def mulmod_solinas(a, b_op, q, b: int, s: int):
    return solinas_reduce(a * b_op, q, b, s)
