"""SSA traces of FHE programs — the input IR of the mapping framework (§IV-F).

The paper extracts an operation trace (HMul/HAdd/HRot...) from a real FHE
program in static single-assignment form with loops unrolled. We do the
same by running the user's program on tracer values.

Also provides the per-op cost/footprint model used by the load-save
pipeline mapper and the analytic benchmarks (Fig. 1/15): for each op at a
given level, the number of (i)NTTs, modular multiplications, bytes of
constants (evk / plaintexts) and bytes of live data, derived from the CKKS
parameter set — the same accounting the paper uses to size pipeline stages.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.params import CkksParams


class LevelBudgetExhausted(Exception):
    """A trace consumed more multiplicative depth than the modulus chain
    provides. Carries the failing op so the compiler's bootstrap-insertion
    pass (repro.compiler.passes.BootstrapInsertion) — or user code — can
    catch it and rewrite instead of dying."""

    def __init__(self, op_index: int, kind: str, level: int):
        self.op_index = op_index
        self.kind = kind
        self.level = level
        super().__init__(
            f"level budget exhausted at op {op_index} ({kind}): "
            f"level {level} < 0")


@dataclasses.dataclass
class FheOp:
    idx: int
    kind: str                     # input|const|hmul|hadd|hsub|pmul|padd|
                                  #   rotate|conjugate|rescale|bootstrap
    args: Tuple[int, ...] = ()
    meta: dict = dataclasses.field(default_factory=dict)
    level: Optional[int] = None   # filled by level inference


@dataclasses.dataclass
class FheTrace:
    ops: List[FheOp]
    inputs: List[int]
    outputs: List[int]
    consts: List[int]

    def __len__(self):
        return len(self.ops)

    def compute_ops(self) -> List[FheOp]:
        return [o for o in self.ops if o.kind not in ("input", "const")]


class _Builder:
    def __init__(self):
        self.ops: List[FheOp] = []

    def add(self, kind: str, args=(), **meta) -> int:
        op = FheOp(len(self.ops), kind, tuple(args), meta)
        self.ops.append(op)
        return op.idx


class TraceVar:
    """Tracer standing in for a ciphertext during program capture."""

    def __init__(self, b: _Builder, idx: int):
        self._b = b
        self.idx = idx

    def _bin(self, kind, other):
        assert isinstance(other, TraceVar)
        return TraceVar(self._b, self._b.add(kind, (self.idx, other.idx)))

    def __add__(self, other):
        if isinstance(other, TraceConst):
            return TraceVar(self._b, self._b.add("padd", (self.idx,),
                                                 const=other.name))
        return self._bin("hadd", other)

    def __sub__(self, other):
        return self._bin("hsub", other)

    def __mul__(self, other):
        if isinstance(other, TraceConst):
            return TraceVar(self._b, self._b.add("pmul", (self.idx,),
                                                 const=other.name))
        return self._bin("hmul", other)

    def rotate(self, step: int):
        return TraceVar(self._b, self._b.add("rotate", (self.idx,), step=step))

    def conjugate(self):
        return TraceVar(self._b, self._b.add("conjugate", (self.idx,)))

    def rescale(self):
        return TraceVar(self._b, self._b.add("rescale", (self.idx,)))

    def bootstrap(self):
        return TraceVar(self._b, self._b.add("bootstrap", (self.idx,)))


@dataclasses.dataclass(frozen=True)
class TraceConst:
    """A named plaintext constant (weight diagonal, mask, twiddle...)."""
    name: str


def trace_program(fn: Callable, n_inputs: int,
                  const_names: Sequence[str] = ()) -> FheTrace:
    b = _Builder()
    inputs = [TraceVar(b, b.add("input", (), slot=i)) for i in range(n_inputs)]
    consts = {nm: TraceConst(nm) for nm in const_names}
    out = fn(*inputs, **({"consts": consts} if const_names else {}))
    outs = out if isinstance(out, (list, tuple)) else [out]
    const_ids = [o.idx for o in b.ops if o.kind == "const"]
    return FheTrace(ops=b.ops,
                    inputs=[v.idx for v in inputs],
                    outputs=[v.idx for v in outs],
                    consts=const_ids)


def infer_levels(trace: FheTrace, start_level: int,
                 bootstrap_to: Optional[int] = None) -> None:
    """Annotate each op with the level of its OUTPUT ciphertext.

    hmul/pmul include their rescale (level-1) unless marked
    ``meta["lazy"]`` (the compiler's lazy-rescale pass defers the divide
    to an explicit ``rescale`` op downstream); hadd aligns to min level.

    Raises LevelBudgetExhausted (not a bare assert) when the program is
    deeper than the chain, so bootstrap insertion can catch and rewrite.
    """
    lv: Dict[int, int] = {}
    for op in trace.ops:
        if op.kind in ("input", "const"):
            lv[op.idx] = start_level
        elif op.kind in ("hmul", "pmul"):
            base = min(lv[a] for a in op.args)
            lv[op.idx] = base if op.meta.get("lazy") else base - 1
        elif op.kind in ("hadd", "hsub", "padd"):
            lv[op.idx] = min(lv[a] for a in op.args)
        elif op.kind in ("rotate", "conjugate"):
            lv[op.idx] = lv[op.args[0]]
        elif op.kind == "rescale":
            lv[op.idx] = lv[op.args[0]] - 1
        elif op.kind == "bootstrap":
            lv[op.idx] = (bootstrap_to if bootstrap_to is not None
                          else start_level)
        else:
            raise ValueError(op.kind)
        op.level = lv[op.idx]
        if op.level < 0:
            raise LevelBudgetExhausted(op.idx, op.kind, op.level)


# ---------------------------------------------------------------------------
# per-op cost / footprint model
# ---------------------------------------------------------------------------

WORD = 8  # bytes per coefficient word (u64 in word32 mode still stores 8B)


@dataclasses.dataclass
class OpCost:
    ntts: int = 0            # number of full N-point (i)NTT passes (per limb summed)
    modmuls: int = 0         # elementwise modular multiplications (N-element rows)
    const_bytes: int = 0     # evk / plaintext bytes this op must have resident
    io_bytes: int = 0        # ciphertext bytes read+written
    out_bytes: int = 0       # output ciphertext size
    ks_modmuls: int = 0      # keyswitch digit-decomposition modmul rows (BConv
    #                          MACs + evk mult-acc): operands are gathered
    #                          across limb partitions, so hardware models may
    #                          bill them heavier than resident-operand modmuls
    move_bytes: int = 0      # ciphertext bytes the op moves between partitions
    #                          (rotation slot permutation, ModUp digit
    #                          distribution) — the PIM lowerer's XFER channel

    def __add__(self, o: "OpCost") -> "OpCost":
        return OpCost(self.ntts + o.ntts, self.modmuls + o.modmuls,
                      self.const_bytes + o.const_bytes,
                      self.io_bytes + o.io_bytes, self.out_bytes,
                      self.ks_modmuls + o.ks_modmuls,
                      self.move_bytes + o.move_bytes)


def ct_bytes(params: CkksParams, level: int) -> int:
    return 2 * (level + 1) * params.n * WORD


def evk_bytes(params: CkksParams) -> int:
    full = params.n_q_moduli + params.n_special
    return params.dnum * 2 * full * params.n * WORD


def keyswitch_cost(params: CkksParams, level: int) -> OpCost:
    """Generalized KS at `level`: per digit iNTT+BConv+NTT (ModUp), evk
    mult-accumulate, then 2x ModDown (iNTT+BConv+NTT+mul).

    Digit-decomposition work (BConv MACs, evk mult-acc) lands in
    ``ks_modmuls``, not ``modmuls``: those rows read operands gathered
    from other limb partitions. The limbs each BConv *creates* in a
    basis it does not own are billed as ``move_bytes`` — the inter-
    partition traffic the paper's permutation network exists for.
    """
    lp = level + 1
    k = params.n_special
    dnum = len([d for d in params.digit_indices(level)])
    alpha = params.alpha
    t = lp + k
    limb_b = params.n * WORD
    ntts = 0
    modmuls = 0
    ks_modmuls = 0
    move_b = 0
    for d in range(dnum):
        dig = min(alpha, lp - d * alpha)
        ntts += dig              # iNTT digit
        ntts += (t - dig)        # NTT of converted limbs
        modmuls += dig                        # qhat_inv mul (resident)
        ks_modmuls += dig * (t - dig)         # bconv MACs
        ks_modmuls += 2 * t                   # evk mult-acc (b and a)
        move_b += (t - dig) * limb_b          # ModUp digit distribution
    # ModDown x2: iNTT P part, BConv P->Q, NTT, final mul
    ntts += 2 * (k + lp)
    modmuls += 2 * (lp + lp)                  # final scalar mul + sub-mul
    ks_modmuls += 2 * (k + k * lp)            # P qhat_inv + bconv MACs
    move_b += 2 * lp * limb_b                 # P->Q converted limbs
    return OpCost(ntts=ntts, modmuls=modmuls, const_bytes=evk_bytes(params),
                  io_bytes=2 * ct_bytes(params, level),
                  out_bytes=ct_bytes(params, level),
                  ks_modmuls=ks_modmuls, move_bytes=move_b)


def rescale_cost(params: CkksParams, level: int) -> OpCost:
    return OpCost(ntts=2 * (1 + level), modmuls=2 * level * 2,
                  io_bytes=2 * ct_bytes(params, level),
                  out_bytes=ct_bytes(params, level - 1))


def op_cost(params: CkksParams, op: FheOp) -> OpCost:
    l = op.level if op.level is not None else params.n_levels
    lp = l + 1
    if op.kind in ("input", "const"):
        return OpCost(out_bytes=ct_bytes(params, l))
    if op.kind in ("hadd", "hsub"):
        return OpCost(modmuls=0, io_bytes=2 * ct_bytes(params, l),
                      out_bytes=ct_bytes(params, l))
    if op.kind == "padd":
        return OpCost(const_bytes=ct_bytes(params, l) // 2,
                      io_bytes=ct_bytes(params, l),
                      out_bytes=ct_bytes(params, l))
    if op.kind == "pmul":
        if op.meta.get("lazy"):          # no rescale: output stays at l
            return OpCost(modmuls=2 * lp,
                          const_bytes=ct_bytes(params, l) // 2,
                          io_bytes=ct_bytes(params, l),
                          out_bytes=ct_bytes(params, l))
        c = OpCost(modmuls=2 * lp, const_bytes=ct_bytes(params, l + 1) // 2,
                   io_bytes=ct_bytes(params, l + 1),
                   out_bytes=ct_bytes(params, l))
        return c + rescale_cost(params, l + 1)
    if op.kind == "hmul":
        if op.meta.get("lazy"):          # tensor+relin only, at level l
            c = OpCost(modmuls=4 * lp,
                       io_bytes=2 * ct_bytes(params, l),
                       out_bytes=ct_bytes(params, l))
            return c + keyswitch_cost(params, l)
        c = OpCost(modmuls=4 * (l + 2),
                   io_bytes=2 * ct_bytes(params, l + 1),
                   out_bytes=ct_bytes(params, l))
        return c + keyswitch_cost(params, l + 1) + rescale_cost(params, l + 1)
    if op.kind in ("rotate", "conjugate"):
        c = keyswitch_cost(params, l)
        # the slot automorphism itself: every coefficient lands in a new
        # position, crossing partitions on a limb-distributed layout
        c.move_bytes += ct_bytes(params, l)
        return c
    if op.kind == "rescale":
        return rescale_cost(params, l + 1)
    if op.kind == "bootstrap":
        # dominated by CtS/EvalMod/StC; approximate with the measured op mix:
        # 2 dense matvecs (~2 sqrt(s) rotations each) + ~2 deg-63 cheb evals
        s_rot = 2 * int(2 * (params.slots ** 0.5))
        cheb_muls = 2 * 70
        c = OpCost()
        for _ in range(s_rot):
            c = c + keyswitch_cost(params, l)
        for _ in range(cheb_muls):
            c = c + keyswitch_cost(params, l) + rescale_cost(params, l)
        return c
    raise ValueError(op.kind)
