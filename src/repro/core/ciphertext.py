"""Ciphertext / plaintext / key containers.

Representation: all polynomials live in the NTT (evaluation) domain in
bit-reversed order (see core/ntt.py), as uint64 RNS limbs:

    Ciphertext.data : (2, level+1, N)   [0]=b, [1]=a;  Dec = b + a*s
    KeySwitchKey.data : (dnum, 2, n_q + n_p, N)

`scale` is the CKKS scaling factor (float bookkeeping, exact enough for
depth < 2^20); `level` counts remaining rescalings (limbs = level+1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass
class Ciphertext:
    data: jnp.ndarray           # (2, level+1, N) uint64, NTT domain
    level: int
    scale: float

    @property
    def n_limbs(self) -> int:
        return self.level + 1

    def copy(self) -> "Ciphertext":
        return Ciphertext(self.data, self.level, self.scale)


@dataclasses.dataclass
class Plaintext:
    data: jnp.ndarray           # (level+1, N) uint64, NTT domain
    level: int
    scale: float


@dataclasses.dataclass
class SecretKey:
    s_ntt: jnp.ndarray          # (n_q + n_p, N) NTT domain under all moduli
    s_coeff_ternary: Optional[jnp.ndarray] = None  # (N,) int8 (tests only)


@dataclasses.dataclass
class PublicKey:
    data: jnp.ndarray           # (2, n_q, N) at full Q basis


@dataclasses.dataclass
class KeySwitchKey:
    """Generalized (dnum-digit) key-switching key: enc of g_d * s_src."""
    data: jnp.ndarray           # (dnum, 2, n_q + n_p, N)
