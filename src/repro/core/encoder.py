"""CKKS canonical-embedding encoder/decoder (SIMD slot packing).

Slots: v in C^{N/2}. Encode finds the real polynomial m(X) in R with
m(zeta^{5^j}) = v_j (and the conjugate constraint at zeta^{-5^j}), scaled by
`scale` and rounded; zeta = exp(i*pi/N) is a primitive 2N-th root of unity.

Implemented with the twist trick: for odd e = 2t+1,
    m(zeta^e) = sum_k (m_k zeta^k) e^{2*pi*i*t*k/N}
so evaluations at all odd exponents are one length-N DFT of the twisted
coefficients — O(N log N) via numpy FFT in float64 (host side; encoding is
I/O, not the accelerated path the paper optimizes).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np
import jax.numpy as jnp

from repro.core.context import CkksContext


class CkksEncoder:

    def __init__(self, ctx: CkksContext):
        self.ctx = ctx
        n = ctx.n
        self.n = n
        self.slots = n // 2
        two_n = 2 * n
        # slot j <-> odd exponent 5^j mod 2N; conjugate slot at 2N - 5^j
        e = 1
        slot_exp = np.empty(self.slots, dtype=np.int64)
        for j in range(self.slots):
            slot_exp[j] = e
            e = (e * 5) % two_n
        self.slot_exp = slot_exp
        self.slot_t = (slot_exp - 1) // 2            # position in odd-DFT order
        self.conj_t = ((two_n - slot_exp) - 1) // 2
        k = np.arange(n)
        self.zeta_pow = np.exp(1j * np.pi * k / n)   # zeta^k
        self.zeta_pow_inv = np.conj(self.zeta_pow)

    # -- float coefficient domain <-> slots ---------------------------------

    def embed_inverse(self, v: np.ndarray) -> np.ndarray:
        """Slots -> real coefficient vector (unscaled float64)."""
        assert v.shape[-1] == self.slots
        vals = np.zeros(v.shape[:-1] + (self.n,), dtype=np.complex128)
        vals[..., self.slot_t] = v
        vals[..., self.conj_t] = np.conj(v)
        twisted = np.fft.fft(vals, axis=-1) / self.n   # sum_t vals_t e^{-2pi i tk/N}
        m = twisted * self.zeta_pow_inv
        return np.real(m)

    def embed_forward(self, m: np.ndarray) -> np.ndarray:
        """Real coefficients -> slots (float64 -> complex128)."""
        twisted = m.astype(np.complex128) * self.zeta_pow
        vals = np.fft.ifft(twisted, axis=-1) * self.n  # sum_k twisted_k e^{+2pi i tk/N}
        return vals[..., self.slot_t]

    # -- RNS plaintexts ------------------------------------------------------

    def encode(self, v: Sequence[complex], scale: float,
               level: int) -> jnp.ndarray:
        """Complex slots -> RNS plaintext (level+1, N) in NTT domain."""
        v = np.asarray(v, dtype=np.complex128)
        if v.ndim == 0:
            v = np.full(self.slots, complex(v))
        if v.shape[-1] != self.slots:
            full = np.zeros(self.slots, dtype=np.complex128)
            full[: v.shape[-1]] = v
            v = full
        coeffs = np.round(self.embed_inverse(v) * scale).astype(np.int64)
        return self.to_rns_ntt(coeffs, level)

    def to_rns_ntt(self, coeffs: np.ndarray, level: int) -> jnp.ndarray:
        """Signed int64 coefficients -> (level+1, N) NTT-domain RNS limbs."""
        idx = self.ctx.q_idx(level)
        primes = np.array([self.ctx.primes[i] for i in idx], dtype=np.int64)
        limbs = (coeffs[None, :] % primes[:, None]).astype(np.uint64)
        return self.ctx.ntt(jnp.asarray(limbs), idx)

    def decode(self, pt_ntt: jnp.ndarray, scale: float,
               level: int, max_error_check: bool = False) -> np.ndarray:
        """(level+1, N) NTT-domain plaintext -> complex slots (host)."""
        from repro.core import rns as rnsmod
        idx = self.ctx.q_idx(level)
        coeff = np.asarray(self.ctx.intt(pt_ntt, idx))
        primes = [self.ctx.primes[i] for i in idx]
        if len(primes) == 1:
            q = primes[0]
            c = coeff[0].astype(np.int64)
            c = np.where(c > q // 2, c - q, c).astype(np.float64)
        else:
            lifted = rnsmod.crt_lift_centered(coeff, primes)
            c = np.array([float(x) for x in lifted])
        return self.embed_forward(c / scale)
