"""End-to-end LM pretraining driver: ~100M-param dense transformer,
a few hundred steps, with checkpoint/restart + straggler supervision.

Defaults are CPU-sized (~27M params, 200 steps); pass --full for the
~115M-param variant (same code path, longer wall time).

    PYTHONPATH=src python examples/lm_pretrain.py --steps 200
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
from repro.compat import set_mesh as compat_set_mesh

from repro.data.pipeline import SyntheticLMDataset, shard_batch
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.models.config import ArchConfig
from repro.train.fault import Supervisor
from repro.train.optim import adamw_init

SMALL = ArchConfig(name="lm-27m", family="dense", n_layers=6, d_model=384,
                   d_ff=1536, vocab=32000, n_heads=6, n_kv_heads=6,
                   head_dim=64, attention="gqa")
FULL = ArchConfig(name="lm-115m", family="dense", n_layers=10, d_model=640,
                  d_ff=2560, vocab=50304, n_heads=10, n_kv_heads=10,
                  head_dim=64, attention="gqa")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/lm_pretrain_ckpt")
    args = ap.parse_args()

    cfg = FULL if args.full else SMALL
    mesh = make_host_mesh()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n_params = cfg.param_count()
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps x {args.batch}x{args.seq} tokens")

    opt = adamw_init(params)
    ds = SyntheticLMDataset(cfg, args.batch, args.seq)
    with compat_set_mesh(mesh):
        step_fn = jax.jit(M.make_train_step(cfg, mesh, learning_rate=6e-4))
        sup = Supervisor(step_fn, args.ckpt_dir, ckpt_every=100)
        t0 = time.time()
        (params, opt), hist = sup.run(
            (params, opt), lambda s: shard_batch(ds.batch_at(s), mesh),
            args.steps)
        dt = time.time() - t0
    first = sum(h["loss"] for h in hist[:10]) / 10
    last = sum(h["loss"] for h in hist[-10:]) / 10
    print(f"loss: first10={first:.4f} last10={last:.4f} "
          f"(delta {first-last:+.4f})")
    tput = args.steps * args.batch * args.seq / dt
    print(f"throughput: {tput:.0f} tok/s ({dt:.1f}s total); "
          f"model flops/step ~ {6*n_params*args.batch*args.seq/1e9:.1f} GFLOP")
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
