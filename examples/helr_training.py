"""HELR: homomorphic logistic-regression training (paper workload §V-B).

Batch samples are SIMD-packed: slot layout [sample0: f features][sample1:
...]; one encrypted iteration computes scores (rotate-and-sum within
feature blocks), a degree-3 sigmoid approximation, and the packed gradient
(rotate-and-sum across sample blocks), then updates the encrypted weights.

The paper runs 30 iterations with bootstrapping; this CPU example runs 3
iterations with re-encryption at iteration boundaries (the bootstrap
insertion point — see core/bootstrap.py for the real refresh) and checks
the encrypted trajectory against the identical plaintext computation.

    PYTHONPATH=src python examples/helr_training.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.params import CkksParams
from repro.core.context import CkksContext
from repro.core.encoder import CkksEncoder
from repro.core.encryptor import CkksEncryptor
from repro.core.ciphertext import Plaintext
from repro.core import linalg, ops

F = 8           # features per sample (power of two)
NS = 16         # samples per ciphertext
SIGMOID3 = (0.5, 0.197, 0.0, -0.004)    # HELR's deg-3 sigmoid approx


def rotate_sum(ctx, ct, gks, steps):
    for st in steps:
        ct = ops.hadd(ctx, ct, ops.rotate(ctx, ct, st,
                                          gks[ctx.rotation_element(st)]))
    return ct


def main():
    params = CkksParams(log_n=8, log_scale=26, n_levels=8, dnum=2,
                        first_mod_bits=31, scale_mod_bits=26,
                        special_mod_bits=31)
    ctx = CkksContext(params)
    enc = CkksEncoder(ctx)
    encr = CkksEncryptor(ctx)
    sk = encr.keygen()
    rk = encr.relin_keygen(sk)
    slots = ctx.n // 2
    assert slots == F * NS
    steps = [1, 2, 4, -1, -2, -4, 8, 16, 32, 64, -8, -16, -32, -64]
    gks = encr.rotation_keygen(sk, steps)
    scale = 2.0 ** 26
    L = params.n_levels

    # synthetic separable data
    rng = np.random.default_rng(7)
    w_true = rng.normal(size=F)
    x = rng.normal(size=(NS, F)) * 0.4
    y = (x @ w_true > 0).astype(np.float64)          # labels in {0,1}

    x_packed = x.reshape(-1)                          # slot layout
    y_packed = np.repeat(y, F)

    def encrypt(v, level=L):
        return encr.encrypt_sk(Plaintext(enc.encode(v, scale, level),
                                         level, scale), sk)

    def decrypt(ct):
        return enc.decode(encr.decrypt(ct, sk).data, ct.scale, ct.level).real

    ct_x = encrypt(x_packed)
    w = np.zeros(F)
    ct_w = encrypt(np.tile(w, NS))
    lr = 1.0

    block_mask = np.zeros(slots)
    block_mask[::F] = 1.0

    def plain_iteration(w):
        s = x @ w
        sg = SIGMOID3[0] + SIGMOID3[1] * s + SIGMOID3[3] * s ** 3
        grad = (sg - y) @ x / NS
        return w - lr * grad

    print(f"HELR: {NS} samples x {F} features packed in {slots} slots")
    for it in range(3):
        # --- encrypted iteration ---
        p = ops.hmul(ctx, ct_x, ct_w, rk)                    # x*w
        s_ct = rotate_sum(ctx, p, gks, [1, 2, 4])            # block sums @ f=0
        pm = Plaintext(enc.encode(block_mask, scale, s_ct.level),
                       s_ct.level, scale)
        s_ct = ops.pmul(ctx, s_ct, pm)                       # mask
        s_ct = rotate_sum(ctx, s_ct, gks, [-1, -2, -4])      # broadcast
        sg = linalg.poly_eval_power_basis(ctx, s_ct, list(SIGMOID3), rk, enc)
        yneg_pt = Plaintext(enc.encode(-y_packed, sg.scale, sg.level),
                            sg.level, sg.scale)
        resid = ops.padd(ctx, sg, yneg_pt)                   # sigmoid(s) - y
        gx = ops.hmul(ctx, resid, ops.mod_switch_to_level(ct_x, resid.level),
                      rk)
        gsum = rotate_sum(ctx, gx, gks, [8, 16, 32, 64])     # sum samples
        gsum = linalg.mul_const(ctx, enc, gsum, lr / NS)
        w_aligned = linalg.adjust_to(ctx, enc, ct_w, gsum.level, gsum.scale)
        ct_w = ops.hsub(ctx, w_aligned, gsum)
        # --- plaintext reference ---
        w = plain_iteration(w)
        got_w = decrypt(ct_w)[:F]
        err = np.abs(got_w - w).max()
        acc = ((x @ got_w > 0) == y).mean()
        print(f"iter {it}: encrypted-vs-plain weight err={err:.3e} "
              f"train acc={acc:.3f} level={ct_w.level}")
        # refresh for the next iteration (bootstrap insertion point)
        if it < 2:
            ct_w = encr.encrypt_sk(
                Plaintext(enc.encode(decrypt(ct_w), scale, L), L, scale), sk)
    assert err < 5e-2, "encrypted HELR diverged from plaintext"
    print("HELR encrypted training matches plaintext trajectory")


if __name__ == "__main__":
    main()
