"""Quickstart: CKKS basics with the repro library.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.params import CkksParams
from repro.core.context import CkksContext
from repro.core.encoder import CkksEncoder
from repro.core.encryptor import CkksEncryptor
from repro.core.ciphertext import Plaintext
from repro.core import ops


def main():
    # small, CPU-friendly (NOT a secure parameter set — demo sizing)
    params = CkksParams(log_n=10, log_scale=26, n_levels=4, dnum=2,
                        first_mod_bits=30, scale_mod_bits=26,
                        special_mod_bits=30)
    ctx = CkksContext(params)
    enc = CkksEncoder(ctx)
    encr = CkksEncryptor(ctx)
    sk = encr.keygen()
    rk = encr.relin_keygen(sk)
    gk = encr.rotation_keygen(sk, [1])

    scale = 2.0 ** 26
    L = params.n_levels
    slots = ctx.n // 2
    rng = np.random.default_rng(0)
    v1 = rng.normal(size=slots) * 0.5
    v2 = rng.normal(size=slots) * 0.5

    def encrypt(v):
        return encr.encrypt_sk(
            Plaintext(enc.encode(v, scale, L), L, scale), sk)

    def decrypt(ct):
        return enc.decode(encr.decrypt(ct, sk).data, ct.scale, ct.level).real

    ct1, ct2 = encrypt(v1), encrypt(v2)
    print(f"ring degree N=2^{params.log_n}, {slots} packed slots, "
          f"L={L} levels, dnum={params.dnum}")
    print(f"moduli (bits): {[m.value.bit_length() for m in params.moduli]}")
    print(f"Montgomery-friendly (Solinas) moduli: "
          f"{sum(m.is_solinas for m in params.moduli)}/{len(params.moduli)}")

    add = ops.hadd(ctx, ct1, ct2)
    print(f"HAdd error:   {np.abs(decrypt(add) - (v1 + v2)).max():.2e}")

    mul = ops.hmul(ctx, ct1, ct2, rk)
    print(f"HMul error:   {np.abs(decrypt(mul) - v1 * v2).max():.2e} "
          f"(level {ct1.level} -> {mul.level})")

    rot = ops.rotate(ctx, ct1, 1, gk[ctx.rotation_element(1)])
    print(f"Rotate error: {np.abs(decrypt(rot) - np.roll(v1, -1)).max():.2e}")

    sq = ops.hsquare(ctx, mul, rk)
    print(f"HSquare error (depth 2): "
          f"{np.abs(decrypt(sq) - (v1 * v2) ** 2).max():.2e}")


if __name__ == "__main__":
    main()
