"""LOLA-MNIST: encrypted shallow-network inference (paper workload §V-B).

Network (LOLA-style): x(64) -> dense(64->32) -> square activation ->
dense(32->10) -> argmax. Weights are plaintext (server-side model), the
input image is encrypted; dense layers run as BSGS diagonal matvecs with
hoisted rotations, activation is a ciphertext square.

Synthetic 8x8 "digit" data from a fixed teacher so accuracy is meaningful;
the correctness claim (paper's) is encrypted outputs == plaintext outputs.

    PYTHONPATH=src python examples/lola_mnist.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.params import CkksParams
from repro.core.context import CkksContext
from repro.core.encoder import CkksEncoder
from repro.core.encryptor import CkksEncryptor
from repro.core.ciphertext import Plaintext
from repro.core import linalg, ops

DIN, DH, DOUT = 64, 32, 10


def main():
    params = CkksParams(log_n=8, log_scale=26, n_levels=5, dnum=2,
                        first_mod_bits=31, scale_mod_bits=26,
                        special_mod_bits=31)
    ctx = CkksContext(params)
    enc = CkksEncoder(ctx)
    encr = CkksEncryptor(ctx)
    sk = encr.keygen()
    rk = encr.relin_keygen(sk)
    s = ctx.n // 2
    scale = 2.0 ** 26
    L = params.n_levels
    rng = np.random.default_rng(3)

    # model weights (plaintext, server side)
    w1 = rng.normal(size=(DH, DIN)) / np.sqrt(DIN)
    w2 = rng.normal(size=(DOUT, DH)) / np.sqrt(DH)

    # embed as s x s matrices acting on the packed slot vector
    m1 = np.zeros((s, s))
    m1[:DH, :DIN] = w1
    m2 = np.zeros((s, s))
    m2[:DOUT, :DH] = w2
    d1 = linalg.matrix_diagonals(m1)
    d2 = linalg.matrix_diagonals(m2)
    elts = sorted(set(linalg.matvec_keys_needed(ctx, d1) +
                      linalg.matvec_keys_needed(ctx, d2)))
    gks = encr.galois_keygen(sk, elts)
    print(f"LOLA: {DIN}->{DH}(square)->{DOUT}; "
          f"{len(d1)}+{len(d2)} matrix diagonals, {len(elts)} galois keys")

    def plain_forward(x):
        h = (w1 @ x) ** 2
        return w2 @ h

    n_correct = 0
    n_match = 0
    n_img = 4
    for i in range(n_img):
        klass = i % DOUT
        proto = rng.normal(size=DIN) * 0.2
        x = proto + 0.08 * rng.normal(size=DIN)
        x_packed = np.zeros(s)
        x_packed[:DIN] = x
        ct = encr.encrypt_sk(
            Plaintext(enc.encode(x_packed, scale, L), L, scale), sk)
        h = linalg.matvec_bsgs(ctx, ct, d1, gks, enc)
        h = ops.hsquare(ctx, h, rk)
        out = linalg.matvec_bsgs(ctx, h, d2, gks, enc)
        got = enc.decode(encr.decrypt(out, sk).data, out.scale,
                         out.level).real[:DOUT]
        want = plain_forward(x)
        err = np.abs(got - want).max()
        match = int(np.argmax(got) == np.argmax(want))
        n_match += match
        print(f"img {i}: encrypted-vs-plain logit err={err:.3e} "
              f"argmax match={bool(match)} level={out.level}")
    assert n_match == n_img, "encrypted inference disagreed with plaintext"
    print(f"LOLA encrypted inference: {n_match}/{n_img} argmax agreement")


if __name__ == "__main__":
    main()
