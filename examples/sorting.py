"""Homomorphic bitonic sorting (paper workload §V-B, per Hong et al.).

16 packed values, 2-way bitonic network. Each compare-exchange stage works
on encrypted data: differences -> iterated polynomial sign approximation
p(x) = 1.5x - 0.5x^3 -> min/max recombination via rotations and masks.
Stages are separated by re-encryption (the bootstrap insertion point; the
paper's deep pipeline bootstraps instead).

    PYTHONPATH=src python examples/sorting.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.params import CkksParams
from repro.core.context import CkksContext
from repro.core.encoder import CkksEncoder
from repro.core.encryptor import CkksEncryptor
from repro.core.ciphertext import Plaintext
from repro.core import linalg, ops

NVAL = 16
SIGN_ITERS = 12   # p^k saturates ~0.04 -> +-1 at k~12


def bitonic_pairs(n):
    """(distance, direction-mask) list for a bitonic sorting network."""
    stages = []
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            up = np.zeros(n, dtype=bool)
            for i in range(n):
                l = i ^ j
                if l > i:
                    up[i] = (i & k) == 0
            stages.append((j, up))
            j //= 2
        k *= 2
    return stages


def main():
    params = CkksParams(log_n=8, log_scale=26, n_levels=12, dnum=2,
                        first_mod_bits=31, scale_mod_bits=26,
                        special_mod_bits=31)
    ctx = CkksContext(params)
    enc = CkksEncoder(ctx)
    encr = CkksEncryptor(ctx)
    sk = encr.keygen()
    rk = encr.relin_keygen(sk)
    s = ctx.n // 2
    scale = 2.0 ** 26
    L = params.n_levels
    steps = sorted({d for d, _ in bitonic_pairs(NVAL)} |
                   {-d for d, _ in bitonic_pairs(NVAL)})
    gks = encr.rotation_keygen(sk, steps)

    rng = np.random.default_rng(11)
    vals = rng.permutation(NVAL) / NVAL + 0.03   # distinct, in (0, 1.1)
    packed = np.zeros(s)
    packed[:NVAL] = vals

    def encrypt(v):
        return encr.encrypt_sk(Plaintext(enc.encode(v, scale, L), L, scale),
                               sk)

    def decrypt(ct):
        return enc.decode(encr.decrypt(ct, sk).data, ct.scale,
                          ct.level).real

    ct = encrypt(packed)
    print(f"bitonic sort of {NVAL} encrypted values "
          f"({len(bitonic_pairs(NVAL))} compare-exchange stages)")

    for si, (dist, up) in enumerate(bitonic_pairs(NVAL)):
        # partner values: rotate both ways (slots beyond NVAL are zero)
        part_fwd = ops.rotate(ctx, ct, dist, gks[ctx.rotation_element(dist)])
        part_bwd = ops.rotate(ctx, ct, -dist, gks[ctx.rotation_element(-dist)])
        # each slot's partner: i^dist — forward if (i & dist)==0 else backward
        fwd_mask = np.zeros(s)
        bwd_mask = np.zeros(s)
        for i in range(NVAL):
            if i & dist:
                bwd_mask[i] = 1.0
            else:
                fwd_mask[i] = 1.0
        pm_f = Plaintext(enc.encode(fwd_mask, scale, part_fwd.level),
                         part_fwd.level, scale)
        pm_b = Plaintext(enc.encode(bwd_mask, scale, part_bwd.level),
                         part_bwd.level, scale)
        partner = ops.hadd(ctx, ops.pmul(ctx, part_fwd, pm_f),
                           ops.pmul(ctx, part_bwd, pm_b))
        me = linalg.adjust_to(ctx, enc, ct, partner.level, partner.scale)
        diff = ops.hsub(ctx, me, partner)                    # in (-1.2, 1.2)
        sgn = linalg.mul_const(ctx, enc, diff, 1 / 1.3)
        for _ in range(SIGN_ITERS):
            if sgn.level < 4:   # refresh (bootstrap stand-in, see module doc)
                sgn = encr.encrypt_sk(
                    Plaintext(enc.encode(decrypt(sgn), scale, L), L, scale),
                    sk)
            sgn = linalg.poly_eval_power_basis(
                ctx, sgn, [0.0, 1.5, 0.0, -0.5], rk, enc)
        # keep = 0.5*(me+partner) + 0.5*sgn_dir*(me-partner)
        lvl = min(sgn.level, diff.level) - 1
        halfsum = linalg.mul_const(
            ctx, enc, ops.hadd(ctx, me, partner), 0.5)
        # direction: want min where (up & lower-slot) etc. Encode signed mask:
        # slot keeps (me if sign(diff) matches dir else partner):
        dir_mask = np.zeros(s)
        for i in range(NVAL):
            is_lower = (i & dist) == 0
            asc = up[i] if is_lower else up[i ^ dist]
            keep_min = (asc and is_lower) or (not asc and not is_lower)
            dir_mask[i] = -0.5 if keep_min else 0.5
        if sgn.level < 3:
            sgn = encr.encrypt_sk(
                Plaintext(enc.encode(decrypt(sgn), scale, L), L, scale), sk)
        diff_al = encr.encrypt_sk(
            Plaintext(enc.encode(decrypt(diff), scale, L), L, scale), sk)
        sgnd = ops.hmul(ctx, sgn, linalg.adjust_to(ctx, enc, diff_al,
                                                   sgn.level, sgn.scale), rk)
        pm_dir = Plaintext(enc.encode(dir_mask, 2.0 ** 26, sgnd.level),
                           sgnd.level, 2.0 ** 26)
        term = ops.pmul(ctx, sgnd, pm_dir)
        hs = linalg.adjust_to(ctx, enc, halfsum, term.level, term.scale)
        ct = ops.hadd(ctx, hs, term)
        # refresh between stages (bootstrap point)
        cur = decrypt(ct)
        cur[NVAL:] = 0
        ct = encrypt(cur)

    got = decrypt(ct)[:NVAL]
    want = np.sort(vals)
    err = np.abs(got - want).max()
    print(f"sorted output err vs numpy.sort: {err:.3e}")
    order_ok = bool((np.diff(got) > -1e-3).all())
    print(f"monotone non-decreasing: {order_ok}")
    assert err < 0.05 and order_ok, "homomorphic sort failed"
    print("homomorphic bitonic sort OK")


if __name__ == "__main__":
    main()
