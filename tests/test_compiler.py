"""repro.compiler: pass unit semantics against the plaintext oracle,
decrypt-equality through the real CKKS stack for every pass on every
registered workload, OpCost monotonicity, and bootstrap insertion
turning level exhaustion into placed bootstrap ops."""
import numpy as np
import pytest

from repro.compiler import (CkksTraceInterpreter, PassConfig,
                            analytic_seconds, optimize_trace,
                            reference_eval)
from repro.compiler.passes import (PASS_ORDER, BootstrapInsertion,
                                   CommonSubexpr, ConstantFold,
                                   DeadCodeElimination, LazyRescale,
                                   RotationOpt)
from repro.core.params import test_params as _test_params
from repro.core.trace import (LevelBudgetExhausted, infer_levels,
                              trace_program)
from repro.runtime.compile_cache import trace_fingerprint
from repro.runtime.workloads import (HELR_CONSTS, LOLA_CONSTS, lola_infer,
                                     make_helr_iter, make_matvec,
                                     make_poly_eval, matvec_consts,
                                     poly_consts)

PARAMS = _test_params(log_n=8, n_levels=6, dnum=2, log_scale=26)
SLOTS = PARAMS.slots
CFG = PassConfig(bsgs_min_terms=4)

# name -> (program, n_inputs, const names, start_level)
WORKLOADS = {
    "helr": (make_helr_iter(), 2, HELR_CONSTS, 5),
    "lola": (lola_infer, 1, LOLA_CONSTS, 4),
    "matvec8": (make_matvec(8), 1, matvec_consts(8), 4),
    "poly7": (make_poly_eval(7), 1, poly_consts(7), 6),  # exhausts: 7 > 6
}


def _trace(name, infer=True):
    fn, n_in, consts, start = WORKLOADS[name]
    t = trace_program(fn, n_in, const_names=consts)
    if infer:
        infer_levels(t, start)
    return t, start


def _io(name, rng):
    """Inputs/consts sized to stay inside the 30-bit q0 headroom."""
    fn, n_in, consts, _ = WORKLOADS[name]
    def vec(s):
        return s * (rng.normal(size=SLOTS) + 1j * rng.normal(size=SLOTS))
    ins = [vec(0.4), vec(0.3)][:n_in]
    cs = {c: 0.25 * rng.normal(size=SLOTS) for c in consts}
    return ins, cs


def _count(trace, kind):
    return sum(1 for o in trace.ops if o.kind == kind)


# ---------------------------------------------------------------------------
# pass unit tests (plaintext oracle)
# ---------------------------------------------------------------------------

def _plain_equal(t_a, t_b, name, rng):
    ins, cs = _io(name, rng)
    a = reference_eval(t_a, ins, cs)
    b = reference_eval(t_b, ins, cs)
    for va, vb in zip(a, b):
        np.testing.assert_allclose(va, vb, atol=1e-10)


def test_dce_removes_unused_keeps_inputs(rng):
    def prog(x, y):
        dead = x * y
        dead2 = dead.rotate(3)       # noqa: F841  (dead chain)
        return x + y
    t = trace_program(prog, 2)
    infer_levels(t, 4)
    out = DeadCodeElimination().run(t, PARAMS, CFG)
    assert len(out.ops) == len(t.ops) - 2
    assert len(out.inputs) == 2          # unused inputs always survive
    r = reference_eval(out, [np.ones(SLOTS), 2 * np.ones(SLOTS)])
    np.testing.assert_allclose(r[0], 3.0)


def test_cse_merges_duplicate_rotations_and_commutative_adds(rng):
    def prog(x, y):
        a = x.rotate(2) + y
        b = y + x.rotate(2)          # commutes + duplicate rotation
        return a * b
    t = trace_program(prog, 2)
    infer_levels(t, 4)
    out = CommonSubexpr().run(t, PARAMS, CFG)
    assert _count(out, "rotate") == 1
    assert _count(out, "hadd") == 1
    ins = [0.3 * rng.normal(size=SLOTS), 0.3 * rng.normal(size=SLOTS)]
    np.testing.assert_allclose(reference_eval(t, ins)[0],
                               reference_eval(out, ins)[0], atol=1e-12)


def test_fold_collapses_plaintext_chains(rng):
    def prog(x, consts=None):
        return (x * consts["a"] * consts["b"]) + consts["c"] + consts["d"]
    t = trace_program(prog, 1, const_names=("a", "b", "c", "d"))
    infer_levels(t, 4)
    out = ConstantFold().run(t, PARAMS, CFG)
    assert _count(out, "pmul") == 1 and _count(out, "padd") == 1
    ins = [0.4 * rng.normal(size=SLOTS)]
    cs = {c: 0.3 * rng.normal(size=SLOTS) for c in "abcd"}
    np.testing.assert_allclose(reference_eval(t, ins, cs)[0],
                               reference_eval(out, ins, cs)[0], atol=1e-12)


def test_fold_keeps_shared_inner_pmul():
    def prog(x, consts=None):
        h = x * consts["a"]
        return (h * consts["b"]) + h      # inner has a second consumer
    t = trace_program(prog, 1, const_names=("a", "b"))
    infer_levels(t, 4)
    out = ConstantFold().run(t, PARAMS, CFG)
    assert _count(out, "pmul") == 2


def test_rotation_compose_and_identity(rng):
    def prog(x):
        a = x.rotate(2).rotate(3)          # -> rotate(5)
        b = x.rotate(7).rotate(-7)         # -> identity
        return a + b
    t = trace_program(prog, 1)
    infer_levels(t, 4)
    out = RotationOpt().run(t, PARAMS, CFG)
    steps = sorted(o.meta["step"] for o in out.ops if o.kind == "rotate")
    assert steps == [5]
    ins = [rng.normal(size=SLOTS)]
    np.testing.assert_allclose(reference_eval(t, ins)[0],
                               reference_eval(out, ins)[0], atol=1e-12)


def test_bsgs_factors_matvec_rotations(rng):
    t, _ = _trace("matvec8")
    out = RotationOpt().run(t, PARAMS, CFG)
    # 7 rotations -> babies + giants (~2*sqrt(8))
    assert _count(out, "rotate") < _count(t, "rotate")
    assert _count(out, "rotate") <= 5
    _plain_equal(t, out, "matvec8", rng)


def test_bsgs_leaves_log_tree_helr_alone():
    t, _ = _trace("helr")
    out = RotationOpt().run(t, PARAMS, CFG)
    assert _count(out, "rotate") == _count(t, "rotate")


def test_lazy_rescale_defers_to_one_rescale_per_sum(rng):
    t, _ = _trace("matvec8")
    out = LazyRescale().run(t, PARAMS,
                            PassConfig(bsgs_min_terms=4, start_level=4))
    lazies = sum(1 for o in out.ops if o.meta.get("lazy"))
    assert lazies == 8                      # every diagonal product
    assert _count(out, "rescale") == 1      # one sum, one rescale
    infer_levels(out, 4)
    assert analytic_seconds(out, PARAMS) < analytic_seconds(t, PARAMS)
    _plain_equal(t, out, "matvec8", rng)


def test_bootstrap_insertion_fixes_exhaustion(rng):
    t, start = _trace("poly7", infer=False)
    with pytest.raises(LevelBudgetExhausted):
        infer_levels(t, start)
    out = BootstrapInsertion().run(t, PARAMS,
                                   PassConfig(start_level=start))
    assert _count(out, "bootstrap") >= 1
    infer_levels(out, start)                # must not raise now
    assert all(o.level is not None and o.level >= 0 for o in out.ops)
    _plain_equal(t, out, "poly7", rng)


def test_bootstrap_disabled_surfaces_structured_error():
    t, start = _trace("poly7", infer=False)
    with pytest.raises(LevelBudgetExhausted) as ei:
        optimize_trace(t, PARAMS,
                       PassConfig(bootstrap=False, start_level=start))
    assert ei.value.op_index >= 0


def test_bootstrap_cut_point_is_late():
    """The refresh lands where the budget dies, not at the inputs —
    late cuts consume the full budget per refresh (fewest bootstraps)."""
    t, start = _trace("poly7", infer=False)
    out = BootstrapInsertion().run(t, PARAMS, PassConfig(start_level=start))
    assert _count(out, "bootstrap") == 1    # depth 8 over budget 6: one cut
    (b,) = [o for o in out.ops if o.kind == "bootstrap"]
    assert out.ops[b.args[0]].kind not in ("input", "const")


# ---------------------------------------------------------------------------
# manager: cost accounting + determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wname", list(WORKLOADS))
def test_cost_never_increases_per_pass(wname):
    t, start = _trace(wname, infer=False)
    cfg = PassConfig(bsgs_min_terms=4, start_level=start)
    opt, report = optimize_trace(t, PARAMS, cfg)
    for s in report.passes:
        if s.name == "bootstrap" or not s.applied:
            continue
        if s.seconds_before is not None and s.seconds_after is not None:
            assert s.seconds_after <= s.seconds_before * (1 + 1e-9), \
                f"{s.name} increased cost on {wname}"
    assert report.seconds_opt is not None
    assert report.format_table()            # renders without blowing up


def test_full_pipeline_speedup_on_matvec():
    """Acceptance: the full pipeline strictly reduces analytic latency
    on the rotation-heavy workload, >= 1.3x."""
    t = trace_program(make_matvec(16), 1, const_names=matvec_consts(16))
    infer_levels(t, 5)
    opt, report = optimize_trace(t, PARAMS, PassConfig(start_level=5))
    assert report.speedup is not None and report.speedup >= 1.3


def test_optimize_trace_is_deterministic_and_pure():
    t, start = _trace("matvec8")
    fp_before = trace_fingerprint(t)
    a, _ = optimize_trace(t, PARAMS, CFG)
    b, _ = optimize_trace(t, PARAMS, CFG)
    assert trace_fingerprint(a) == trace_fingerprint(b)
    assert trace_fingerprint(t) == fp_before    # input untouched


# ---------------------------------------------------------------------------
# decrypt-equality through the real CKKS stack: every pass, every workload
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ckks_interp():
    return CkksTraceInterpreter(PARAMS, seed=7)


@pytest.fixture(scope="module")
def ckks_baselines(ckks_interp):
    """Decoded outputs of each workload's runnable baseline: the raw
    trace, or (for level-exhausting programs) the bootstrap-only
    rewrite. Shared across the per-pass matrix so each workload pays one
    baseline execution."""
    rng = np.random.default_rng(1234)
    out = {}
    for name in WORKLOADS:
        t, start = _trace(name, infer=False)
        base, _ = optimize_trace(
            t, PARAMS,
            PassConfig(start_level=start).with_passes(("bootstrap",)))
        ins, cs = _io(name, np.random.default_rng(1234))
        dec = ckks_interp.run(base, ins, cs)
        ref = reference_eval(t, ins, cs)
        for d, r in zip(dec, ref):
            np.testing.assert_allclose(d, r, atol=2e-3)
        out[name] = (base, dec)
    return out


@pytest.mark.parametrize("wname", list(WORKLOADS))
@pytest.mark.parametrize("pname", [p.name for p in PASS_ORDER
                                   if p.name != "bootstrap"])
def test_per_pass_decrypt_equality(ckks_interp, ckks_baselines,
                                   wname, pname):
    """Each pass alone (on top of the bootstrap feasibility floor) must
    decode to the baseline's values through real encrypt/eval/decrypt.
    A pass that leaves the trace byte-identical is vacuously equal and
    skips the (expensive) duplicate execution."""
    t, start = _trace(wname, infer=False)
    cfg = PassConfig(bsgs_min_terms=4, start_level=start).with_passes(
        ("bootstrap", pname))
    opt, _ = optimize_trace(t, PARAMS, cfg)
    base, base_dec = ckks_baselines[wname]
    if trace_fingerprint(opt) == trace_fingerprint(base):
        return
    ins, cs = _io(wname, np.random.default_rng(1234))
    dec = ckks_interp.run(opt, ins, cs)
    for d, b in zip(dec, base_dec):
        np.testing.assert_allclose(d, b, atol=2e-3)


def test_interp_reencodes_rebound_consts(ckks_interp, rng):
    """One engine instance serving two runs with DIFFERENT values for
    the same const name must encode both — the engine's const memo is
    keyed by value digest, not name alone (regression: a name-only key
    silently served the first binding forever)."""
    def prog(x, consts=None):
        return x * consts["w"]
    t = trace_program(prog, 1, const_names=("w",))
    infer_levels(t, 3)
    x = [0.3 * rng.normal(size=SLOTS)]
    for _ in range(2):
        w = 0.3 * rng.normal(size=SLOTS)
        dec = ckks_interp.run(t, x, {"w": w})
        np.testing.assert_allclose(dec[0], x[0] * w, atol=2e-3)


@pytest.mark.parametrize("wname", list(WORKLOADS))
def test_full_pipeline_decrypt_equality(ckks_interp, ckks_baselines,
                                        wname):
    t, start = _trace(wname, infer=False)
    cfg = PassConfig(bsgs_min_terms=4, start_level=start)
    opt, _ = optimize_trace(t, PARAMS, cfg)
    base, base_dec = ckks_baselines[wname]
    if trace_fingerprint(opt) == trace_fingerprint(base):
        return
    ins, cs = _io(wname, np.random.default_rng(1234))
    dec = ckks_interp.run(opt, ins, cs)
    ref = reference_eval(t, ins, cs)
    for d, b, r in zip(dec, base_dec, ref):
        np.testing.assert_allclose(d, b, atol=2e-3)   # vs baseline CKKS
        np.testing.assert_allclose(d, r, atol=2e-3)   # vs plaintext oracle
