"""Multi-device worker (run in a subprocess with 8 fake CPU devices).

Scenarios exercise the distributed FHE substrate on a real (fake-device)
mesh; the parent test asserts exit status. Keep each scenario exact:
integer FHE math must be bit-identical distributed vs single-device.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
from repro.compat import set_mesh as compat_set_mesh  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def scenario_bconv(variant: str):
    from repro.core.params import test_params
    from repro.core.context import CkksContext
    from repro.core import rns
    from repro.fhe_dist.collective_bconv import (bconv_tables_device,
                                                 distributed_bconv)
    params = test_params(log_n=8, n_levels=7, dnum=2)  # 8 q-limbs
    ctx = CkksContext(params)
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 8)
    src = ctx.q_idx(7)              # 8 limbs -> 1 per device
    dst = ctx.p_idx()               # 8 special? alpha=4 -> pad to 8
    # need |dst| divisible by 8 too: use first 8 q primes as a synthetic dst
    dst = ctx.q_idx(7)
    rng = np.random.default_rng(0)
    v = np.stack([rng.integers(0, ctx.primes[i], size=ctx.n, dtype=np.uint64)
                  for i in src])
    tabs = ctx.bconv_tables(src, dst)
    want = np.asarray(rns.bconv(jnp.asarray(v), tabs))
    qh, sq, w, dq = bconv_tables_device(ctx, src, dst)
    got = np.asarray(distributed_bconv(jnp.asarray(v), qh, sq, w, dq,
                                       mesh, variant=variant))
    assert (got == want).all(), f"distributed bconv ({variant}) mismatch"
    print(f"bconv {variant} exact-match OK")


def scenario_pipeline():
    from repro.fhe_dist.pipeline_exec import run_load_save_pipeline
    from repro.compat import make_mesh as _make_mesh
    mesh = _make_mesh((8,), ("data",))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(5, 16, 32)).astype(np.float32))
    fns_r1 = [lambda v, k=k: v * (k + 1) for k in range(8)]
    fns_r2 = [lambda v, k=k: v + k for k in range(8)]
    got = run_load_save_pipeline([fns_r1, fns_r2], x, mesh)
    want = x
    for f in fns_r1 + fns_r2:
        want = f(want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    print("pipeline rounds OK")


def scenario_limb_sharded_hmul():
    """GSPMD limb-sharded HMul == single-device HMul, bit exact."""
    from repro.core.params import CkksParams
    from repro.core.context import CkksContext
    from repro.core.encoder import CkksEncoder
    from repro.core.encryptor import CkksEncryptor
    from repro.core.ciphertext import Plaintext, Ciphertext
    from repro.core import ops
    from jax.sharding import NamedSharding, PartitionSpec as P

    params = CkksParams(log_n=8, log_scale=26, n_levels=7, dnum=2,
                        first_mod_bits=30, scale_mod_bits=26,
                        special_mod_bits=30)
    ctx = CkksContext(params)
    enc = CkksEncoder(ctx)
    encr = CkksEncryptor(ctx, seed=5)
    sk = encr.keygen()
    rk = encr.relin_keygen(sk)
    rng = np.random.default_rng(2)
    s = ctx.n // 2
    v1 = rng.normal(size=s) * 0.3
    v2 = rng.normal(size=s) * 0.3
    scale = 2.0 ** 26
    L = params.n_levels
    ct1 = encr.encrypt_sk(Plaintext(enc.encode(v1, scale, L), L, scale), sk)
    ct2 = encr.encrypt_sk(Plaintext(enc.encode(v2, scale, L), L, scale), sk)
    want = np.asarray(ops.hmul(ctx, ct1, ct2, rk).data)

    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 8)
    limb = NamedSharding(mesh, P(None, "model", None))
    with compat_set_mesh(mesh):
        d1 = jax.device_put(ct1.data, limb)
        d2 = jax.device_put(ct2.data, limb)
        out = ops.hmul(ctx, Ciphertext(d1, L, scale),
                       Ciphertext(d2, L, scale), rk)
        got = np.asarray(out.data)
    assert (got == want).all(), "limb-sharded hmul mismatch"
    print("limb-sharded hmul exact-match OK")


if __name__ == "__main__":
    scen = sys.argv[1]
    if scen == "bconv_ring":
        scenario_bconv("ring")
    elif scen == "bconv_allgather":
        scenario_bconv("allgather")
    elif scen == "pipeline":
        scenario_pipeline()
    elif scen == "hmul":
        scenario_limb_sharded_hmul()
    else:
        raise SystemExit(f"unknown scenario {scen}")
    print("WORKER_OK")
