"""repro.fleet: the N=1 fleet must reproduce the single
PipelinedExecutor bit for bit; router policies place correctly;
continuous batching refills in-flight batches without mixing
workloads; preemption evicts best-effort flights at round boundaries
(never at the last step) without losing or duplicating requests; and
the metrics layer decomposes latency and attributes per-tenant drops."""
import itertools
import random

import pytest

from repro.core.params import test_params as _test_params
from repro.core.pipeline import MemoryModel
from repro.fleet import POLICIES, FleetScheduler
from repro.fleet.device import Flight
from repro.runtime import (BatchPolicy, KeyCache, PipelinedExecutor,
                           Request, RequestStatus)
from repro.runtime.executor import resolve_backend
from repro.runtime.queue import AdmissionQueue

PARAMS = _test_params(log_n=10, n_levels=8, dnum=2)
MEM = MemoryModel(n_partitions=4, partition_bytes=8 * 2 ** 20)
# tiny partitions force the mapper to split programs into many stages
# spanning several pipeline rounds — the regime the continuous-batching
# and preemption round-boundary machinery exists for
MEM_MULTI_ROUND = MemoryModel(n_partitions=2, partition_bytes=640 * 1024)


def _prog_a(x, w, consts=None):
    s = x * w
    for k in (1, 2, 4):
        s = s + s.rotate(k)
    return s * consts["c1"] + x


def _prog_b(x, consts=None):
    h = x * consts["w1"]
    h = h + h.rotate(1)
    return h * h


def _prog_mv(x, consts=None):
    # rotation-heavy diagonal matvec: each rotate carries an evk and
    # each diagonal a plaintext constant, so under MEM_MULTI_ROUND's
    # small partitions the mapper splits it across many rounds
    acc = x * consts["d0"]
    for i in range(1, 6):
        acc = acc + x.rotate(i) * consts[f"d{i}"]
    return acc


MV_CONSTS = tuple(f"d{i}" for i in range(6))


def _policy(max_batch=4, max_wait_s=2e-3):
    return BatchPolicy(slots_per_ct=PARAMS.slots, max_batch=max_batch,
                       max_wait_s=max_wait_s)


def _register(target):
    target.register("a", _prog_a, 2, const_names=("c1",), start_level=7)
    target.register("b", _prog_b, 1, const_names=("w1",), start_level=7)
    target.register("mv", _prog_mv, 1, const_names=MV_CONSTS,
                    start_level=7)
    return target


def _round_times(fleet, workload, occupancy=1):
    """Per-round service seconds of one device's schedule at a fixed
    batch occupancy (for placing arrivals inside specific rounds)."""
    from repro.runtime.metrics import MetricsRegistry
    dev = fleet.devices[0]
    sched = dev.schedule_for(workload, fleet.workloads[workload].trace)
    scratch = MetricsRegistry(dev.mem.n_partitions)
    return [dev.backend.round_seconds(sched, rnd, occupancy,
                                      key_cache=None, metrics=scratch,
                                      workload=workload)
            for rnd in sched.rounds]


def _fleet(n_devices=1, router="round_robin", cache_bytes=0,
           continuous_batching=False, preempt=False, policy=None,
           backend="analytic", mem=MEM):
    return _register(FleetScheduler(
        PARAMS, mem, n_devices=n_devices, backend=backend, router=router,
        policy=policy or _policy(), cache_bytes=cache_bytes,
        continuous_batching=continuous_batching, preempt=preempt))


def _stream(n=90, rate=400.0, seed=3, deadline=None, slots=(1, 2, 4),
            workloads=("a", "b"), tenants=3, best_effort_every=0):
    """Deterministic mixed-workload Poisson-ish arrival list."""
    rng = random.Random(seed)
    ids = itertools.count()
    out, t = [], 0.0
    for i in range(n):
        t += rng.expovariate(rate)
        dl = None
        if deadline is not None and not (
                best_effort_every and i % best_effort_every == 0):
            dl = t + deadline
        out.append(Request(next(ids), tenant=f"t{i % tenants}",
                           workload=workloads[i % len(workloads)],
                           arrival_s=t,
                           slots_needed=rng.choice(list(slots)),
                           deadline_s=dl))
    return out


# ---------------------------------------------------------------------------
# fleet(N=1) == PipelinedExecutor, bit for bit
# ---------------------------------------------------------------------------

def test_fleet_of_one_reproduces_single_executor_exactly():
    """The acceptance anchor: plain fleet(N=1, round_robin, no
    continuous batching, no preemption) must reproduce the single
    executor's latency/throughput on a mixed stream — not within a
    tolerance, identically (same floats, same counters)."""
    policy = _policy()
    kc = KeyCache(32 * 2 ** 20, load_bw=MEM.load_bw)
    ex = _register(PipelinedExecutor(PARAMS, MEM, backend="analytic",
                                     policy=policy, key_cache=kc))
    m1 = ex.serve(_stream(deadline=0.05))

    fleet = _fleet(n_devices=1, cache_bytes=32 * 2 ** 20, policy=_policy())
    m2 = fleet.serve(_stream(deadline=0.05))

    assert m1.elapsed_s == m2.elapsed_s
    assert m1.throughput_rps() == m2.throughput_rps()
    for p in (50, 95, 99):
        assert m1.request_latency.percentile(p) == \
            m2.request_latency.percentile(p)
    for c in ("requests_completed", "requests_served", "batches_formed",
              "deadline_misses", "keycache_hits", "keycache_misses"):
        assert m1.count(c) == m2.count(c), c


def test_fleet_of_one_pim_backend_matches_executor():
    policy = _policy()
    ex = _register(PipelinedExecutor(PARAMS, MEM, backend="pim",
                                     policy=policy))
    m1 = ex.serve(_stream(n=40))
    fleet = _fleet(n_devices=1, backend="pim", policy=_policy())
    m2 = fleet.serve(_stream(n=40))
    assert m1.elapsed_s == m2.elapsed_s
    assert m1.request_latency.p99 == m2.request_latency.p99
    assert m1.count("requests_completed") == m2.count("requests_completed")


# ---------------------------------------------------------------------------
# router policies
# ---------------------------------------------------------------------------

def test_router_rejects_unknown_policy():
    with pytest.raises(ValueError, match="round_robin"):
        _fleet(n_devices=2, router="sticky")


def test_round_robin_cycles_devices():
    fleet = _fleet(n_devices=3)
    seen = [fleet.router.route(
        Request(i, "t0", "a", arrival_s=0.0), 0.0).device_id
        for i in range(6)]
    assert seen == [0, 1, 2, 0, 1, 2]


def test_least_loaded_prefers_emptier_device():
    fleet = _fleet(n_devices=2, router="least_loaded")
    heavy = fleet.devices[0]
    for i in range(4):
        heavy.admit(Request(100 + i, "t0", "a", arrival_s=0.0,
                            slots_needed=4))
    dev = fleet.router.route(Request(0, "t0", "a", arrival_s=0.0), 0.0)
    assert dev.device_id == 1


def test_cache_affinity_sticks_and_records_hits():
    fleet = _fleet(n_devices=4, router="cache_affinity",
                   cache_bytes=32 * 2 ** 20)
    m = fleet.serve(_stream(n=80))
    assert m.count("requests_completed") == 80
    # after the first (cold) placement per workload, every request of
    # that workload lands on a warm device
    hits = m.count("routing_hits")
    misses = m.count("routing_misses")
    assert hits + misses == 80
    assert misses <= 4          # at most one cold miss per workload + slack
    # placement is sticky: each workload's requests went to one device
    assert m.hit_rate("routing") > 0.9


def test_cache_affinity_spills_when_warm_device_backlogged():
    fleet = _fleet(n_devices=2, router="cache_affinity",
                   cache_bytes=32 * 2 ** 20)
    warm = fleet.devices[0]
    warm.key_cache.get_or_load(("a", "stage", 0), 1024)   # mark warm
    # pile more than a full batch of slots onto the warm device
    for i in range(3000, 3000 + 2 * fleet.policy.max_batch):
        warm.admit(Request(i, "t0", "a", arrival_s=0.0,
                           slots_needed=fleet.policy.slots_per_ct))
    dev = fleet.router.route(Request(0, "t1", "a", arrival_s=0.0), 0.0)
    assert dev.device_id == 1


def test_fleet_routers_all_drain_stream():
    for policy in POLICIES:
        fleet = _fleet(n_devices=3, router=policy,
                       cache_bytes=16 * 2 ** 20)
        m = fleet.serve(_stream(n=60))
        assert m.count("requests_completed") == 60, policy


# ---------------------------------------------------------------------------
# fleet scaling
# ---------------------------------------------------------------------------

def test_four_devices_beat_one_on_goodput_under_overload():
    """The fig20 scaling gate in miniature: at an offered load that
    saturates one device, four devices complete far more requests
    within their deadlines."""
    probe = _fleet(n_devices=1, cache_bytes=32 * 2 ** 20,
                   continuous_batching=True)
    probe.warmup()
    pm = probe.serve(_stream(n=400, rate=1e9, seed=11))
    cap1 = pm.count("requests_completed") / pm.device_busy_s[0]
    deadline = 2 * probe.policy.max_wait_s + 4 * pm.batch_service.mean

    def run(n_dev):
        fleet = _fleet(n_devices=n_dev, router="least_loaded",
                       cache_bytes=32 * 2 ** 20,
                       continuous_batching=True)
        fleet.warmup()
        m = fleet.serve(_stream(n=2400, rate=4.0 * cap1, seed=11,
                                deadline=deadline))
        return m.goodput_rps()

    g1, g4 = run(1), run(4)
    assert g4 >= 2.5 * g1, (g1, g4)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

def test_continuous_refill_joins_in_flight_batch():
    """Requests of the same workload that arrive while a batch is
    streaming join its free slot rows at a round boundary instead of
    waiting for the next batch to form."""
    fleet = _fleet(n_devices=1, continuous_batching=True,
                   policy=_policy(max_batch=8, max_wait_s=1e-4),
                   mem=MEM_MULTI_ROUND)
    ids = itertools.count()
    dts = _round_times(fleet, "mv")
    assert len(dts) >= 2, "need a multi-round schedule to refill into"
    # lead batch fires alone at max_wait; stragglers arrive inside its
    # first round-step and join at the first round boundary
    lead = [Request(next(ids), "t0", "mv", arrival_s=0.0)
            for _ in range(2)]
    t_mid_round1 = 1e-4 + 0.5 * dts[0]
    late = [Request(next(ids), "t0", "mv", arrival_s=t_mid_round1)
            for _ in range(3)]
    m = fleet.serve(lead + late)
    assert m.count("requests_completed") == 5
    assert m.count("continuous_refills") >= 1
    assert m.count("requests_refilled") == 3
    # joiners didn't wait for a second batch to form
    assert m.count("batches_formed") == 1


def test_continuous_refill_never_mixes_workloads():
    fleet = _fleet(n_devices=1, continuous_batching=True,
                   policy=_policy(max_batch=8, max_wait_s=1e-4),
                   mem=MEM_MULTI_ROUND)
    ids = itertools.count()
    dts = _round_times(fleet, "mv")
    lead = [Request(next(ids), "t0", "mv", arrival_s=0.0)]
    late_other = [Request(next(ids), "t0", "b",
                          arrival_s=1e-4 + 0.5 * dts[0])
                  for _ in range(3)]
    m = fleet.serve(lead + late_other)
    # workload b requests were NOT refilled into workload a's flight —
    # they formed their own batch(es)
    assert m.count("requests_refilled") == 0
    assert m.count("requests_completed") == 4
    assert m.count("batches_formed") >= 2


def test_flight_occupancy_and_membership_accounting():
    from repro.runtime.batcher import Batch
    reqs = [Request(i, "t0", "a", arrival_s=0.0, slots_needed=1)
            for i in range(3)]
    batch = Batch("a", reqs, [[reqs[0], reqs[1]], [reqs[2]]], 0.0)

    class _Sched:
        rounds = [(), ()]
    f = Flight(batch, _Sched(), slots_per_ct=4, now=0.0)
    assert f.occupancy == 2
    assert f.min_rounds_left() == 2
    assert f.best_effort()
    joiner = Request(9, "t1", "a", arrival_s=0.1, slots_needed=1,
                     deadline_s=5.0)
    f.groups[0].append(joiner)
    f.absorb([joiner], 0.1)
    assert f.rounds_left[9] == 2
    assert not f.best_effort()


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------

def _preempt_fleet():
    # max_wait far below a round time, so a deadline batch is "ready"
    # at the first boundary after it arrives
    return _fleet(n_devices=1, preempt=True, continuous_batching=False,
                  policy=_policy(max_batch=8, max_wait_s=1e-6),
                  mem=MEM_MULTI_ROUND)


def test_preemption_evicts_best_effort_for_deadline_batch():
    fleet = _preempt_fleet()
    ids = itertools.count()
    dts = _round_times(fleet, "mv")
    assert len(dts) >= 3, "need rounds for a mid-flight preempt"
    best_effort = [Request(next(ids), "t0", "mv", arrival_s=0.0)
                   for _ in range(2)]
    # urgent batch arrives inside the best-effort flight's second
    # round-step: its max-wait clock (1e-6) expires well before the
    # boundary, so the boundary check finds it ready to fire
    t_fire = 1e-6                      # lead batch forms at max_wait
    t_urgent = t_fire + dts[0] + 0.2 * dts[1]
    urgent = [Request(next(ids), "t1", "b", arrival_s=t_urgent,
                      deadline_s=t_urgent + 0.05) for _ in range(2)]
    m = fleet.serve(best_effort + urgent)
    assert m.count("preemptions") == 1
    assert m.count("requests_preempted") == 2
    # nothing lost, nothing duplicated: every request completes once
    assert m.count("requests_completed") == 4
    assert m.count("requests_served") == 4
    for r in best_effort + urgent:
        assert r.status is RequestStatus.COMPLETED


def test_preemption_skipped_on_last_round():
    """A flight with exactly one round-step left finishes instead of
    being evicted — completing is strictly cheaper than redoing the
    whole pipeline."""
    fleet = _preempt_fleet()
    ids = itertools.count()
    dts = _round_times(fleet, "mv")
    best_effort = [Request(next(ids), "t0", "mv", arrival_s=0.0)]
    # urgent work arrives inside the PENULTIMATE round-step: the first
    # boundary that sees it ready is the one where the flight has
    # exactly one round left
    t_fire = 1e-6
    t_urgent = t_fire + sum(dts[:-2]) + 0.5 * dts[-2]
    urgent = [Request(next(ids), "t1", "b", arrival_s=t_urgent,
                      deadline_s=t_urgent + 0.05)]
    m = fleet.serve(best_effort + urgent)
    assert m.count("preemptions") == 0
    assert m.count("requests_completed") == 2
    assert best_effort[0].status is RequestStatus.COMPLETED


def test_deadline_flight_never_preempted():
    fleet = _preempt_fleet()
    ids = itertools.count()
    dts = _round_times(fleet, "mv")
    # the in-flight batch itself carries a deadline -> not best-effort,
    # even with an urgent batch ready at an early boundary
    lead = [Request(next(ids), "t0", "mv", arrival_s=0.0,
                    deadline_s=0.05)]
    t_urgent = 1e-6 + dts[0] + 0.2 * dts[1]
    urgent = [Request(next(ids), "t1", "b", arrival_s=t_urgent,
                      deadline_s=t_urgent + 0.02)]
    m = fleet.serve(lead + urgent)
    assert m.count("preemptions") == 0
    assert m.count("requests_completed") == 2


# ---------------------------------------------------------------------------
# metrics: latency decomposition + per-tenant attribution
# ---------------------------------------------------------------------------

def test_latency_decomposes_into_queue_delay_plus_service():
    fleet = _fleet(n_devices=2, router="least_loaded")
    arrivals = _stream(n=40)
    m = fleet.serve(arrivals)
    assert m.queue_delay.count == m.request_latency.count
    assert m.service_time.count == m.request_latency.count
    for r in arrivals:
        assert r.status is RequestStatus.COMPLETED
        assert r.service_start_s is not None
        queue_delay = r.service_start_s - r.arrival_s
        service = r.completion_s - r.service_start_s
        assert queue_delay >= 0.0 and service >= 0.0
        assert queue_delay + service == pytest.approx(r.latency())
    # aggregate means must add up too
    assert m.queue_delay.mean + m.service_time.mean == \
        pytest.approx(m.request_latency.mean)


def test_dequeue_deadline_drops_attributed_per_tenant():
    q = AdmissionQueue()
    q.submit(Request(0, "acme", "a", arrival_s=0.0, deadline_s=1.0))
    q.submit(Request(1, "acme", "a", arrival_s=0.0, deadline_s=1.0))
    q.submit(Request(2, "globex", "a", arrival_s=0.0, deadline_s=1.0))
    q.submit(Request(3, "globex", "a", arrival_s=0.0, deadline_s=99.0))
    assert len(q.take(now=5.0, workload="a", max_requests=8)) == 1
    assert q.metrics.count("deadline_misses") == 3
    assert q.metrics.count("deadline_misses_dequeue") == 3
    assert q.metrics.tenant_count("deadline_misses", "acme") == 2
    assert q.metrics.tenant_count("deadline_misses", "globex") == 1


def test_device_occupancy_recorded_per_device():
    fleet = _fleet(n_devices=2, router="round_robin")
    m = fleet.serve(_stream(n=40))
    occ = m.device_occupancy()
    assert set(occ) == {0, 1}
    assert all(0.0 < v <= 1.0 for v in occ.values())


# ---------------------------------------------------------------------------
# resolve_backend error message
# ---------------------------------------------------------------------------

def test_resolve_backend_error_enumerates_backends_and_presets():
    with pytest.raises(ValueError) as ei:
        resolve_backend("cuda", PARAMS, MEM)
    msg = str(ei.value)
    for name in ("analytic", "mesh", "ciphertext", "pim"):
        assert f"'{name}'" in msg
    for preset in ("flat", "fhemem", "hbm2"):
        assert f"'{preset}'" in msg
    assert "--pim-preset" in msg
