"""Distributed-runtime substrate tests: checkpoint/restore + elastic
reshard, async checkpointing, fault supervisor replay, straggler detection,
optimizer, data determinism."""
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.train import checkpoint as ckpt
from repro.train.fault import FailureEvent, StragglerEvent, Supervisor
from repro.train.optim import adamw_init, adamw_update, clip_by_global_norm


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32)),
            "b": {"c": jnp.asarray(rng.normal(size=(4,)).astype(np.float32)),
                  "d": jnp.asarray(rng.integers(0, 5, (3, 3)),
                                   dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save_checkpoint(str(tmp_path), 7, t)
    restored, step = ckpt.restore_checkpoint(str(tmp_path), t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(str(tmp_path), s, t, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [4, 5]


def test_async_checkpointer(tmp_path):
    t = _tree()
    ac = ckpt.AsyncCheckpointer(str(tmp_path))
    ac.save(3, t)
    ac.wait()
    restored, step = ckpt.restore_checkpoint(str(tmp_path), t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]))


def test_elastic_reshard_roundtrip(tmp_path):
    """Save under one mesh, restore under a different mesh (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = _tree()
    ckpt.save_checkpoint(str(tmp_path), 1, t)
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    shardings = jax.tree.map(
        lambda x: NamedSharding(mesh, P(*([None] * x.ndim))), t)
    restored, _ = ckpt.restore_checkpoint(str(tmp_path), t,
                                          shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(t["b"]["c"]))


def test_supervisor_failure_replay(tmp_path):
    """Inject a failure mid-run; supervisor restores and replays to the
    same final state as a failure-free run (deterministic data)."""
    def step_fn(params, opt, batch):
        new_params = jax.tree.map(lambda p: p + batch["x"].mean(), params)
        return new_params, opt, {"loss": batch["x"].mean()}

    def make_batch(step):
        rng = np.random.default_rng(100 + step)
        return {"x": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}

    params0 = {"w": jnp.zeros((2,))}

    # failure-free reference
    sup_ref = Supervisor(step_fn, str(tmp_path / "ref"), ckpt_every=2)
    (ref_params, _), _ = sup_ref.run((params0, {}), make_batch, 10)

    fired = {"done": False}

    def injector(step):
        if step == 7 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("simulated device failure")

    sup = Supervisor(step_fn, str(tmp_path / "run"), ckpt_every=2,
                     fail_injector=injector)
    (got_params, _), _ = sup.run((params0, {}), make_batch, 10)
    assert any(isinstance(e, FailureEvent) for e in sup.events)
    np.testing.assert_allclose(np.asarray(got_params["w"]),
                               np.asarray(ref_params["w"]), rtol=1e-6)


def test_supervisor_straggler_detection(tmp_path):
    def step_fn(params, opt, batch):
        if int(batch["i"]) == 6:
            time.sleep(0.3)
        return params, opt, {"loss": jnp.zeros(())}

    sup = Supervisor(step_fn, str(tmp_path), ckpt_every=100,
                     straggler_k=4.0)
    sup.run(({"w": jnp.zeros(1)}, {}), lambda s: {"i": jnp.int32(s)}, 10)
    assert any(isinstance(e, StragglerEvent) for e in sup.events)


def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        grads, gn = clip_by_global_norm(grads, 10.0)
        params, state = adamw_update(params, grads, state, lr=5e-2, wd=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_synthetic_data_deterministic():
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLMDataset
    cfg = get_config("qwen3-8b", smoke=True)
    ds = SyntheticLMDataset(cfg, batch=2, seq=16)
    b1, b2 = ds.batch_at(5), ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch_at(6)
    assert (b1["tokens"] != b3["tokens"]).any()


def test_compressed_psum_single_pod():
    """n_pod=1 degenerate case runs on one device; error feedback carries
    the quantization residual."""
    from repro.train.compress import (compressed_pod_mean,
                                      init_error_feedback)
    from repro.compat import make_mesh as _make_mesh
    mesh = _make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(np.linspace(-1, 1, 64,
                                      dtype=np.float32))[None]}  # (1, 64)
    err = init_error_feedback(g)
    mean, new_err = compressed_pod_mean(g, err, mesh)
    # reconstruction + residual == original (exact error feedback identity)
    recon = np.asarray(mean["w"]) + np.asarray(new_err["w"][0])
    np.testing.assert_allclose(recon, np.asarray(g["w"][0]), atol=1e-6)
