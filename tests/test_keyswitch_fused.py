"""Differential tests for the fused Pallas keyswitch pipeline.

The fused route (repro/kernels/keyswitch.py) claims BIT-exactness, not
closeness: u32 Montgomery arithmetic computes the same canonical
residues as the u64 library path, so every assertion here is
``assert_array_equal``, never allclose. Covered:

* kernel-level equality vs core/ops.key_switch across levels, digit
  counts (dnum 1/2/3, including ragged tail digits), and batch sizes;
* the dispatch-per-stage staged baseline (fig14's comparison anchor)
  is ALSO bit-equal, and the fused/staged dispatch counts match a
  golden snapshot (tests/golden/dispatch_counts.json, REGEN_GOLDENS=1);
* engine-level decrypt equality fused-vs-library on real workload
  traces, rotation steps, conjugation, and hypothesis-random traces.
"""
import json
import os

import numpy as np
import pytest
import jax.numpy as jnp

from _hyp import given, settings, st  # noqa: E402  (skips per-test)

from repro.compiler.engine import CkksEngine
from repro.core import ops as hops
from repro.core.context import CkksContext
from repro.core.encryptor import CkksEncryptor
from repro.core.params import test_params as make_test_params
from repro.core.trace import trace_program
from repro.kernels import common as kcom
from repro.kernels.keyswitch import FusedKeySwitch, keyswitch_staged

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "dispatch_counts.json")

LOG_N = 7
N_LEVELS = 4


def _setup(dnum):
    params = make_test_params(log_n=LOG_N, n_levels=N_LEVELS, dnum=dnum,
                              log_scale=26)
    ctx = CkksContext(params)
    enc = CkksEncryptor(ctx, seed=11)
    sk = enc.keygen()
    rk = enc.relin_keygen(sk)
    return ctx, enc, sk, rk


def _rand_d2(ctx, batch, level, seed=0):
    rng = np.random.default_rng(seed)
    l = level + 1
    d2 = np.empty((batch, l, ctx.n), dtype=np.uint64)
    for j in range(l):
        d2[:, j] = rng.integers(0, ctx.primes[j], size=(batch, ctx.n),
                                dtype=np.uint64)
    return jnp.asarray(d2)


# ---------------------------------------------------------------------------
# kernel level: fused == reference == staged, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dnum", [1, 2, 3])
def test_fused_bit_equal_reference_across_levels(dnum):
    """Every level exercises a different digit decomposition (including
    ragged tail digits when alpha doesn't divide level+1)."""
    ctx, _, _, rk = _setup(dnum)
    fks = FusedKeySwitch(ctx)
    for level in range(1, N_LEVELS + 1):
        d2 = _rand_d2(ctx, 2, level, seed=level)
        km = fks.ksk_mont("relin", level, rk.data)
        e0, e1 = fks.apply(d2, level, km, interpret=True)
        for i in range(d2.shape[0]):
            r0, r1 = hops.key_switch(ctx, d2[i], level, rk)
            np.testing.assert_array_equal(np.asarray(e0[i]), np.asarray(r0))
            np.testing.assert_array_equal(np.asarray(e1[i]), np.asarray(r1))


@pytest.mark.parametrize("dnum", [1, 2])
def test_staged_bit_equal_reference(dnum):
    ctx, _, _, rk = _setup(dnum)
    level = N_LEVELS
    d2 = _rand_d2(ctx, 1, level, seed=3)
    s0, s1 = keyswitch_staged(ctx, d2[0], level, rk, interpret=True)
    r0, r1 = hops.key_switch(ctx, d2[0], level, rk)
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(r0))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(r1))


def test_fused_galois_key_bit_equal():
    """The same fused pipeline serves Galois keys (rotation keyswitch)."""
    ctx, enc, sk, _ = _setup(2)
    elt = ctx.rotation_element(3)
    gk = enc.galois_keygen(sk, [elt])[elt]
    fks = FusedKeySwitch(ctx)
    level = N_LEVELS - 1
    d2 = _rand_d2(ctx, 2, level, seed=5)
    km = fks.ksk_mont(("gk", elt), level, gk.data)
    e0, e1 = fks.apply(d2, level, km, interpret=True)
    for i in range(d2.shape[0]):
        r0, r1 = hops.key_switch(ctx, d2[i], level, gk)
        np.testing.assert_array_equal(np.asarray(e0[i]), np.asarray(r0))
        np.testing.assert_array_equal(np.asarray(e1[i]), np.asarray(r1))


# ---------------------------------------------------------------------------
# dispatch accounting: fused is a >=4x reduction, snapshot-pinned
# ---------------------------------------------------------------------------

def _measure_dispatches(dnum, level):
    ctx, _, _, rk = _setup(dnum)
    fks = FusedKeySwitch(ctx)
    d2 = _rand_d2(ctx, 1, level, seed=7)
    km = fks.ksk_mont("relin", level, rk.data)
    kcom.reset_dispatch_count()
    fks.apply(d2, level, km, interpret=True)
    fused = kcom.dispatch_count()
    kcom.reset_dispatch_count()
    keyswitch_staged(ctx, d2[0], level, rk, interpret=True)
    staged = kcom.dispatch_count()
    return {"fused": fused, "staged": staged,
            "digits": len(ctx.params.digit_indices(level))}


def test_dispatch_counts_golden():
    """Fused launch count is flat (4) while staged grows 7*digits + 10;
    the golden pins both so a regression that quietly re-splits the
    pipeline (or miscounts the baseline) fails here, not in fig14."""
    measured = {}
    for dnum in (1, 2, 3):
        for level in (1, N_LEVELS):
            m = _measure_dispatches(dnum, level)
            measured[f"dnum{dnum}_level{level}"] = m
            assert m["fused"] == FusedKeySwitch.DISPATCHES_PER_APPLY
            assert m["staged"] == 7 * m["digits"] + 10
            assert m["staged"] >= 4 * m["fused"], m
    if os.environ.get("REGEN_GOLDENS"):
        with open(GOLDEN_PATH, "w") as f:
            json.dump(measured, f, indent=2, sort_keys=True)
        pytest.skip("regenerated dispatch_counts.json")
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    assert measured == golden


def test_dispatch_count_independent_of_batch():
    ctx, _, _, rk = _setup(2)
    fks = FusedKeySwitch(ctx)
    km = fks.ksk_mont("relin", N_LEVELS, rk.data)
    for batch in (1, 4):
        d2 = _rand_d2(ctx, batch, N_LEVELS, seed=batch)
        kcom.reset_dispatch_count()
        fks.apply(d2, N_LEVELS, km, interpret=True)
        assert kcom.dispatch_count() == FusedKeySwitch.DISPATCHES_PER_APPLY


# ---------------------------------------------------------------------------
# engine level: use_kernels route decrypt-equal on real traces
# ---------------------------------------------------------------------------

ENGINE_PARAMS = make_test_params(log_n=7, n_levels=5, dnum=2, log_scale=26)


@pytest.fixture(scope="module")
def engines():
    return (CkksEngine(ENGINE_PARAMS, seed=7),
            CkksEngine(ENGINE_PARAMS, seed=7, use_kernels=True))


def _run_both(engines, fn, n_in, const_names, seed=0, start_level=4):
    lib, fus = engines
    rng = np.random.default_rng(seed)
    tr = trace_program(fn, n_in, const_names=const_names or ())
    consts = {c: rng.uniform(-0.25, 0.25, size=ENGINE_PARAMS.slots)
              for c in (const_names or ())}
    ins = [rng.uniform(-0.5, 0.5, size=(2, ENGINE_PARAMS.slots))
           for _ in range(n_in)]
    a = lib.run_batch(tr, ins, consts, start_level=start_level)
    b = fus.run_batch(tr, ins, consts, start_level=start_level)
    for va, vb in zip(a, b):
        np.testing.assert_array_equal(va, vb)


def test_engine_hmul_chain_decrypt_equal(engines):
    def fn(x, y):
        z = x * y
        return z * z
    _run_both(engines, fn, 2, None, seed=1)


@pytest.mark.parametrize("step", [1, -3, 7])
def test_engine_rotation_decrypt_equal(engines, step):
    def fn(x, consts=None):
        return (x * consts["w"]).rotate(step) + x
    _run_both(engines, fn, 1, ["w"], seed=20 + step)


def test_engine_conjugate_decrypt_equal(engines):
    def fn(x):
        return x.conjugate() + x
    _run_both(engines, fn, 1, None, seed=9)


def test_engine_lazy_hmul_decrypt_equal(engines):
    """Lazy (unrescaled) hmul exercises the fused route's rescale-
    deferral split."""
    lib, fus = engines

    def fn(x, y):
        return (x * y).rescale()
    tr = trace_program(fn, 2)
    for op in tr.ops:
        if op.kind == "hmul":
            op.meta["lazy"] = True
    rng = np.random.default_rng(4)
    ins = [rng.uniform(-0.5, 0.5, size=(2, ENGINE_PARAMS.slots))
           for _ in range(2)]
    a = lib.run_batch(tr, ins, {}, start_level=4)
    b = fus.run_batch(tr, ins, {}, start_level=4)
    for va, vb in zip(a, b):
        np.testing.assert_array_equal(va, vb)


# ---------------------------------------------------------------------------
# hypothesis: random well-formed traces, fused == library bitwise
# ---------------------------------------------------------------------------

from test_properties import build_trace, trace_specs  # noqa: E402


@pytest.fixture(scope="module")
def small_engines():
    params = make_test_params(log_n=7, n_levels=6, dnum=2, log_scale=26)
    return (params,
            CkksEngine(params, seed=7),
            CkksEngine(params, seed=7, use_kernels=True))


@settings(max_examples=5, deadline=None)
@given(spec=trace_specs(), seed=st.integers(0, 2 ** 31 - 1))
def test_engine_random_traces_decrypt_equal(spec, seed, small_engines):
    """For ANY well-formed random trace: the fused-kernel engine decodes
    bit-identically to the library engine (same keys, same seed)."""
    params, lib, fus = small_engines
    trace = build_trace(*spec)
    rng = np.random.default_rng(seed)
    ins = [0.3 * (rng.normal(size=(1, params.slots))
                  + 1j * rng.normal(size=(1, params.slots)))
           for _ in trace.inputs]
    cs = {f"c{i}": 0.25 * rng.normal(size=params.slots) for i in range(3)}
    a = lib.run_batch(trace, ins, cs, start_level=5)
    b = fus.run_batch(trace, ins, cs, start_level=5)
    for va, vb in zip(a, b):
        np.testing.assert_array_equal(va, vb)
