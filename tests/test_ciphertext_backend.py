"""CiphertextBackend: differential tests against the plaintext oracle
and the analytic cost model, plus the runtime wiring (string backend
resolution, KeyCache residency, accuracy metrics)."""
import numpy as np
import pytest

from repro.compiler import PassConfig
from repro.compiler.interp import reference_eval
from repro.core.params import test_params as make_test_params
from repro.core.pipeline import MemoryModel
from repro.runtime import (AnalyticBackend, Batch, BatchPolicy,
                           CiphertextBackend, KeyCache, MeshBackend,
                           MetricsRegistry, PipelinedExecutor, Request,
                           resolve_backend)
from repro.runtime.ciphertext_backend import base_const_names
from repro.runtime.compile_cache import CompileCache
from repro.runtime.workloads import (HELR_CONSTS, LOLA_CONSTS, lola_infer,
                                     make_helr_iter, make_matvec,
                                     make_poly_eval, matvec_consts,
                                     poly_consts)

PARAMS = make_test_params(log_n=8, n_levels=8, dnum=2, log_scale=26)
MEM = MemoryModel(n_partitions=4, partition_bytes=256 * 2 ** 10)
START = 7
CFG = PassConfig(start_level=START, bsgs_min_terms=4)

# every program family registered in runtime/workloads.py, sized small
WORKLOADS = {
    "helr": (make_helr_iter(), 2, HELR_CONSTS),
    "lola": (lola_infer, 1, LOLA_CONSTS),
    "matvec": (make_matvec(8), 1, matvec_consts(8)),
    "poly": (make_poly_eval(8), 1, poly_consts(8)),  # needs bootstrap
}


@pytest.fixture(scope="module")
def backend():
    return CiphertextBackend(PARAMS, use_kernels=False)


@pytest.fixture(scope="module")
def compile_cache():
    return CompileCache()


def _batch(workload, rng, n_requests=3, slots_each=16):
    reqs = [Request(i, f"t{i}", workload, arrival_s=0.0,
                    slots_needed=slots_each,
                    payload=rng.uniform(-0.8, 0.8, size=slots_each))
            for i in range(n_requests)]
    # two slot groups: 2 requests share a ciphertext, 1 rides alone
    groups = [reqs[:2], reqs[2:]] if n_requests > 2 else [reqs]
    return Batch(workload, reqs, groups, formed_s=0.0)


def _schedule(compile_cache, name):
    from repro.core.trace import trace_program
    fn, n_in, consts = WORKLOADS[name]
    trace = trace_program(fn, n_in, const_names=consts)
    return compile_cache.get_schedule(trace, PARAMS, MEM, pass_config=CFG)


# ---------------------------------------------------------------------------
# decrypt output matches reference_eval for every registered workload
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wname", list(WORKLOADS))
def test_decrypt_matches_reference(backend, compile_cache, wname):
    sched = _schedule(compile_cache, wname)
    rng = np.random.default_rng(hash(wname) % 2 ** 31)
    metrics = MetricsRegistry(MEM.n_partitions)
    batch = _batch(wname, rng)
    dt = backend.execute(sched, batch, key_cache=None, metrics=metrics,
                         workload=wname)
    assert dt > 0
    err = metrics.decrypt_error[wname]
    assert err <= backend.tolerance, \
        f"{wname}: decrypt error {err:.3e} over tolerance"
    # the backend's own oracle check is itself checked here: outputs
    # must decode the packed payload values, not zeros
    outs = batch.outputs
    assert outs and outs[0].shape == (2, PARAMS.slots)
    vals = backend._pack(batch, 2)
    ref = reference_eval(sched.trace,
                         [vals] + [backend._aux_input(wname, i, 2)
                                   for i in range(1, len(sched.trace.inputs))],
                         backend.workload_consts(wname, sched.trace))
    np.testing.assert_allclose(outs[0], ref[0], atol=backend.tolerance)
    assert np.abs(ref[0]).max() > 1e-3     # non-degenerate


# ---------------------------------------------------------------------------
# analytic and ciphertext backends agree on relative schedule cost
# across pass configs
# ---------------------------------------------------------------------------

def test_backends_agree_on_pass_config_ordering(backend, compile_cache):
    """The compiler's win on the rotation-heavy workload must show up in
    BOTH backends: unopt costs more than full-opt, analytically and
    measured on real ciphertexts."""
    from repro.core.trace import trace_program
    fn, n_in, consts = WORKLOADS["matvec"]
    trace = trace_program(fn, n_in, const_names=consts)
    cfg_noopt = PassConfig(start_level=START).with_passes(("bootstrap",))
    times = {}
    for tag, cfg in (("noopt", cfg_noopt), ("opt", CFG)):
        sched = compile_cache.get_schedule(trace, PARAMS, MEM,
                                           pass_config=cfg)
        analytic = AnalyticBackend(MEM)
        m = MetricsRegistry(MEM.n_partitions)
        pred = analytic.execute(sched, _batch("matvec",
                                              np.random.default_rng(0)),
                                key_cache=None, metrics=m,
                                workload="matvec")
        inputs = [np.random.default_rng(1).uniform(
            -0.8, 0.8, size=(2, PARAMS.slots)) for _ in sched.trace.inputs]
        cvals = backend.workload_consts("matvec", sched.trace)
        # warm twice (trace, then XLA compile), then take the min of
        # three steady-state runs — wall clock on shared CI boxes is
        # noisy and min is the standard denoiser
        for _ in range(2):
            backend.engine.run_schedule(sched, inputs, cvals,
                                        const_scope=("matvec", tag))
        meas = []
        for _ in range(3):
            _, stage_s = backend.engine.run_schedule(
                sched, inputs, cvals, const_scope=("matvec", tag))
            meas.append(sum(stage_s))
        times[tag] = (pred, min(meas))
    assert times["noopt"][0] > times["opt"][0], "analytic ordering"
    assert times["noopt"][1] > times["opt"][1], "measured ordering"


# ---------------------------------------------------------------------------
# runtime wiring
# ---------------------------------------------------------------------------

def test_resolve_backend_names():
    assert isinstance(resolve_backend("analytic", PARAMS, MEM),
                      AnalyticBackend)
    assert isinstance(resolve_backend("ciphertext", PARAMS, MEM),
                      CiphertextBackend)
    with pytest.raises(ValueError):
        resolve_backend("quantum", PARAMS, MEM)
    assert isinstance(resolve_backend("mesh", PARAMS, MEM), MeshBackend)


def test_executor_serves_encrypted_end_to_end(backend):
    """PipelinedExecutor(backend=<ciphertext instance>) drains real
    encrypted batches: completions, accuracy, pinned evk residency and
    const reuse across batches all visible in one registry."""
    ex = PipelinedExecutor(
        PARAMS, MEM, backend=backend,
        policy=BatchPolicy(slots_per_ct=PARAMS.slots, max_batch=2,
                           max_wait_s=1e-3),
        key_cache=KeyCache(64 * 2 ** 20),
        pass_config=CFG)
    fn, n_in, consts = WORKLOADS["lola"]
    ex.register("lola", fn, n_in, const_names=consts, start_level=START)
    rng = np.random.default_rng(3)
    arrivals = [Request(ex.queue.next_request_id(), f"t{i % 2}", "lola",
                        arrival_s=i * 1e-4, slots_needed=8,
                        payload=rng.uniform(-0.8, 0.8, size=8))
                for i in range(6)]
    ex.warmup()
    m = ex.serve(arrivals)
    assert m.count("requests_completed") == 6
    assert m.decrypt_error["lola"] <= backend.tolerance
    # evk + galois keys were pinned into the key cache at generation
    assert any(isinstance(k, tuple) and k[:2] == ("engine", "relin")
               or k[:2] == ("engine", "gk") for k in ex.key_cache._entries)
    # stage constants hit on the batches after the first
    assert m.count("keycache_hits") > 0
    assert backend.measured_stage_seconds("lola")


def test_base_const_names_sees_through_cexprs():
    from repro.compiler.ir import Emitter
    from repro.core.trace import trace_program
    t = trace_program(lola_infer, 1, const_names=LOLA_CONSTS)
    assert base_const_names(t) == sorted(LOLA_CONSTS)
    e = Emitter(len(t.ops))
    derived = e.op("pmul", (t.inputs[0],),
                   cexpr=("mul", ("rot", ("ref", "w1"), 2), ("ref", "w2")))
    t.ops.append(derived)
    assert base_const_names(t) == sorted(LOLA_CONSTS)
