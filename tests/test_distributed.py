"""Multi-device distribution tests (8 fake CPU devices via subprocess —
the device count must be set before jax init, so each scenario gets its
own process)."""
import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "distributed_worker.py")


def _run(scenario: str):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)   # worker sets its own
    r = subprocess.run([sys.executable, WORKER, scenario],
                       capture_output=True, text=True, timeout=900, env=env)
    assert r.returncode == 0, f"{scenario} failed:\n{r.stdout}\n{r.stderr}"
    assert "WORKER_OK" in r.stdout


@pytest.mark.parametrize("variant", ["bconv_ring", "bconv_allgather"])
def test_distributed_bconv(variant):
    """Paper §III-C: chain (ring/ppermute) vs channel-bus (all-gather)
    BConv — both bit-exact vs the single-device reference."""
    _run(variant)


def test_pipeline_rounds():
    """§IV-F load-save pipeline executor on an 8-stage ring."""
    _run("pipeline")


def test_limb_sharded_hmul():
    """Bank↔limb layout (§IV-A): GSPMD limb-sharded HMul is bit-exact."""
    _run("hmul")
