"""End-to-end CKKS bootstrapping (the paper's flagship deep workload)."""
import numpy as np
import pytest

from repro.core.params import CkksParams
from repro.core.context import CkksContext
from repro.core.encoder import CkksEncoder
from repro.core.encryptor import CkksEncryptor
from repro.core.ciphertext import Plaintext
from repro.core.bootstrap import Bootstrapper, BootstrapConfig


@pytest.fixture(scope="module")
def boot_stack():
    params = CkksParams(log_n=7, log_scale=25, n_levels=16, dnum=2,
                        first_mod_bits=29, scale_mod_bits=25,
                        special_mod_bits=29, hamming_weight_sk=16)
    ctx = CkksContext(params)
    enc = CkksEncoder(ctx)
    encr = CkksEncryptor(ctx, seed=11)
    sk = encr.keygen()
    bts = Bootstrapper(ctx, enc, encr, sk,
                       BootstrapConfig(eval_mod_degree=63, k_range=6.0))
    return params, ctx, enc, encr, sk, bts


def test_mod_raise_preserves_message(boot_stack):
    params, ctx, enc, encr, sk, bts = boot_stack
    rng = np.random.default_rng(0)
    s = ctx.n // 2
    v = 0.3 * (rng.normal(size=s) + 1j * rng.normal(size=s))
    scale = 2.0 ** 25
    ct = encr.encrypt_sk(Plaintext(enc.encode(v, scale, 0), 0, scale), sk)
    raised = bts.mod_raise(ct, 6)
    # message becomes m + q0*I: in slot space that's v + (q0/scale)*tau(I);
    # verify the m part survives by checking the value mod-q0 structure via
    # a full bootstrap below; here check shape/level bookkeeping.
    assert raised.level == 6 and raised.data.shape[1] == 7


def test_cts_stc_roundtrip(boot_stack):
    """CoefToSlot then SlotToCoef ~ identity (on a fresh high-level ct)."""
    params, ctx, enc, encr, sk, bts = boot_stack
    rng = np.random.default_rng(1)
    s = ctx.n // 2
    v = 0.3 * (rng.normal(size=s) + 1j * rng.normal(size=s))
    scale = 2.0 ** 25
    L = params.n_levels
    ct = encr.encrypt_sk(Plaintext(enc.encode(v, scale, L), L, scale), sk)
    z = bts.coef_to_slot(ct)
    back = bts.slot_to_coef(z)
    got = enc.decode(encr.decrypt(back, sk).data, back.scale, back.level)
    np.testing.assert_allclose(got, v, atol=2e-2)


def test_full_bootstrap(boot_stack):
    params, ctx, enc, encr, sk, bts = boot_stack
    rng = np.random.default_rng(2)
    s = ctx.n // 2
    v = 0.3 * (rng.normal(size=s) + 1j * rng.normal(size=s))
    scale = 2.0 ** 25
    ct0 = encr.encrypt_sk(Plaintext(enc.encode(v, scale, 0), 0, scale), sk)
    out = bts.bootstrap(ct0, params.n_levels)
    assert out.level >= 2, "bootstrap must return usable levels"
    got = enc.decode(encr.decrypt(out, sk).data, out.scale, out.level)
    err = np.abs(got - v).max()
    assert err < 0.05, f"bootstrap error too large: {err}"
