"""End-to-end CKKS behaviour: the system-level semantics FHEmem accelerates.

Validates the paper's §II-A primitives against plaintext arithmetic:
encrypt/decrypt, HAdd, HMul(+relin+rescale), deep chains, rotation (Galois
automorphism + key switch), conjugation, plaintext ops, BConv exactness.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import ops, rns
from repro.core.ciphertext import Plaintext


SCALE_BITS = 26


def _enc(stack, keys, v, level=None):
    ctx, enc, encr = stack["ctx"], stack["encoder"], stack["encryptor"]
    level = stack["params"].n_levels if level is None else level
    scale = 2.0 ** SCALE_BITS
    pt = Plaintext(enc.encode(v, scale, level), level, scale)
    return encr.encrypt_sk(pt, keys["sk"])


def _dec(stack, keys, ct):
    enc, encr = stack["encoder"], stack["encryptor"]
    return enc.decode(encr.decrypt(ct, keys["sk"]).data, ct.scale, ct.level)


def _rand_slots(rng, ctx, scale=1.0):
    s = ctx.n // 2
    return scale * (rng.normal(size=s) + 1j * rng.normal(size=s))


def test_encrypt_decrypt(ckks_small, ckks_keys, rng):
    v = _rand_slots(rng, ckks_small["ctx"])
    ct = _enc(ckks_small, ckks_keys, v)
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, ct), v, atol=1e-3)


def test_public_key_encrypt(ckks_small, ckks_keys, rng):
    stack, keys = ckks_small, ckks_keys
    v = _rand_slots(rng, stack["ctx"])
    scale = 2.0 ** SCALE_BITS
    L = stack["params"].n_levels
    pt = Plaintext(stack["encoder"].encode(v, scale, L), L, scale)
    ct = stack["encryptor"].encrypt_pk(pt, keys["pk"])
    np.testing.assert_allclose(_dec(stack, keys, ct), v, atol=5e-3)


def test_hadd_hsub_hneg(ckks_small, ckks_keys, rng):
    ctx = ckks_small["ctx"]
    v1, v2 = _rand_slots(rng, ctx), _rand_slots(rng, ctx)
    ct1, ct2 = (_enc(ckks_small, ckks_keys, v) for v in (v1, v2))
    np.testing.assert_allclose(
        _dec(ckks_small, ckks_keys, ops.hadd(ctx, ct1, ct2)), v1 + v2, atol=1e-3)
    np.testing.assert_allclose(
        _dec(ckks_small, ckks_keys, ops.hsub(ctx, ct1, ct2)), v1 - v2, atol=1e-3)
    np.testing.assert_allclose(
        _dec(ckks_small, ckks_keys, ops.hneg(ctx, ct1)), -v1, atol=1e-3)


def test_hmul_relin_rescale(ckks_small, ckks_keys, rng):
    ctx = ckks_small["ctx"]
    v1, v2 = _rand_slots(rng, ctx), _rand_slots(rng, ctx)
    ct1, ct2 = (_enc(ckks_small, ckks_keys, v) for v in (v1, v2))
    out = ops.hmul(ctx, ct1, ct2, ckks_keys["rk"])
    assert out.level == ct1.level - 1
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, out), v1 * v2,
                               atol=5e-3)


def test_hsquare(ckks_small, ckks_keys, rng):
    ctx = ckks_small["ctx"]
    v = _rand_slots(rng, ctx)
    ct = _enc(ckks_small, ckks_keys, v)
    out = ops.hsquare(ctx, ct, ckks_keys["rk"])
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, out), v * v,
                               atol=5e-3)


def test_deep_mul_chain_full_depth(ckks_small, ckks_keys, rng):
    # |v|<=~0.7 so the depth-4 product stays well under q0/(2*scale) headroom
    ctx = ckks_small["ctx"]
    v1, v2 = _rand_slots(rng, ctx, 0.5), _rand_slots(rng, ctx, 0.5)
    ct1, ct2 = (_enc(ckks_small, ckks_keys, v) for v in (v1, v2))
    cur, want = ct1, v1.copy()
    for i in range(ckks_small["params"].n_levels):
        other = ct2 if i % 2 == 0 else ct1
        cur = ops.hmul(ctx, cur, other, ckks_keys["rk"])
        want = want * (v2 if i % 2 == 0 else v1)
    assert cur.level == 0
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, cur), want, atol=0.2)


@pytest.mark.parametrize("step", [1, 2, 7, -1])
def test_rotation(ckks_small, ckks_keys, rng, step):
    ctx = ckks_small["ctx"]
    encr = ckks_small["encryptor"]
    v = _rand_slots(rng, ctx)
    ct = _enc(ckks_small, ckks_keys, v)
    gks = encr.rotation_keygen(ckks_keys["sk"], [step])
    elt = ctx.rotation_element(step)
    out = ops.rotate(ctx, ct, step, gks[elt])
    # Rotate(step): output slot i holds input slot i+step (left rotation)
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, out),
                               np.roll(v, -step), atol=5e-3)


def test_rotation_coeff_domain_matches_eval_domain(ckks_small, ckks_keys, rng):
    """Paper-faithful (coeff-domain §IV-E) vs optimized (eval-domain) path."""
    ctx = ckks_small["ctx"]
    encr = ckks_small["encryptor"]
    v = _rand_slots(rng, ctx)
    ct = _enc(ckks_small, ckks_keys, v)
    gks = encr.rotation_keygen(ckks_keys["sk"], [3])
    elt = ctx.rotation_element(3)
    a = ops.rotate(ctx, ct, 3, gks[elt])
    b = ops.rotate_coeff_domain(ctx, ct, 3, gks[elt])
    assert (np.asarray(a.data) == np.asarray(b.data)).all()


def test_conjugate(ckks_small, ckks_keys, rng):
    ctx = ckks_small["ctx"]
    encr = ckks_small["encryptor"]
    v = _rand_slots(rng, ctx)
    ct = _enc(ckks_small, ckks_keys, v)
    gk = encr.galois_keygen(ckks_keys["sk"], [ctx.conj_element])
    out = ops.conjugate(ctx, ct, gk[ctx.conj_element])
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, out), np.conj(v),
                               atol=5e-3)


def test_plaintext_ops(ckks_small, ckks_keys, rng):
    ctx, enc = ckks_small["ctx"], ckks_small["encoder"]
    v1, v2 = _rand_slots(rng, ctx), _rand_slots(rng, ctx)
    ct = _enc(ckks_small, ckks_keys, v1)
    scale = 2.0 ** SCALE_BITS
    pt = Plaintext(enc.encode(v2, scale, ct.level), ct.level, scale)
    np.testing.assert_allclose(
        _dec(ckks_small, ckks_keys, ops.padd(ctx, ct, pt)), v1 + v2, atol=1e-3)
    out = ops.pmul(ctx, ct, pt)
    assert out.level == ct.level - 1
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, out), v1 * v2,
                               atol=5e-3)
    out3 = ops.pmul_scalar_int(ctx, ct, 3)
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, out3), 3 * v1,
                               atol=5e-3)


def test_mod_switch_then_ops(ckks_small, ckks_keys, rng):
    ctx = ckks_small["ctx"]
    v1, v2 = _rand_slots(rng, ctx), _rand_slots(rng, ctx)
    ct1 = _enc(ckks_small, ckks_keys, v1)
    ct2 = _enc(ckks_small, ckks_keys, v2)
    ct1d = ops.mod_switch_to_level(ct1, ct1.level - 2)
    out = ops.hadd(ctx, ct1d, ct2)   # auto-aligns ct2 down
    assert out.level == ct1.level - 2
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, out), v1 + v2,
                               atol=1e-3)


def test_bconv_exact_vs_bigint(ckks_small, rng):
    """BConv (eq.1) against exact CRT-lift reference, incl. the known small
    q-multiple slack of the fast conversion."""
    ctx = ckks_small["ctx"]
    src = ctx.q_idx(2)
    dst = ctx.p_idx()
    tabs = ctx.bconv_tables(src, dst)
    src_primes = [ctx.primes[i] for i in src]
    dst_primes = [ctx.primes[i] for i in dst]
    big_q = int(np.prod([int(p) for p in src_primes], dtype=object))
    x = rns.crt_lift_centered(
        np.stack([rng.integers(0, p, size=64, dtype=np.uint64)
                  for p in src_primes]), src_primes)
    limbs = np.stack([(x % p).astype(np.uint64) for p in src_primes])
    out = np.asarray(rns.bconv(jnp.asarray(limbs), tabs))
    out_mm = np.asarray(rns.bconv_matmul(jnp.asarray(limbs), tabs))
    assert (out == out_mm).all(), "reference and matmul-form BConv disagree"
    for i, p in enumerate(dst_primes):
        # fast BConv = exact value + k*Q for small k in [0, len(src))
        diff = (out[i].astype(object) - (x % p)) % p
        ks = set(int(d) for d in diff)
        allowed = {(k * big_q) % p for k in range(len(src_primes) + 1)}
        assert ks <= allowed, f"unexpected BConv slack at dst prime {p}"
