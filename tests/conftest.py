import os
import sys

# NOTE: do NOT set XLA_FLAGS / device-count here — smoke tests and benches
# must see the single real CPU device; only launch/dryrun.py forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def ckks_small():
    """Shared small CKKS stack (log_n=8) for fast tests."""
    from repro.core.params import test_params
    from repro.core.context import CkksContext
    from repro.core.encoder import CkksEncoder
    from repro.core.encryptor import CkksEncryptor

    params = test_params(log_n=8, n_levels=4, dnum=2, log_scale=26)
    ctx = CkksContext(params)
    return {
        "params": params,
        "ctx": ctx,
        "encoder": CkksEncoder(ctx),
        "encryptor": CkksEncryptor(ctx, seed=7),
    }


@pytest.fixture(scope="session")
def ckks_keys(ckks_small):
    enc = ckks_small["encryptor"]
    sk = enc.keygen()
    return {
        "sk": sk,
        "pk": enc.public_keygen(sk),
        "rk": enc.relin_keygen(sk),
    }


@pytest.fixture()
def rng():
    return np.random.default_rng(1234)
