"""NTT correctness: roundtrip, negacyclic convolution, automorphisms."""
import numpy as np
import pytest
import jax.numpy as jnp

from _hyp import given, settings, st  # noqa: E402  (skips per-test)

from repro.core import modarith as ma
from repro.core import ntt as nttm
from repro.core.params import find_ntt_primes


@pytest.fixture(scope="module", params=[6, 8, 10])
def tables(request):
    log_n = request.param
    return nttm.NttTables(find_ntt_primes(30, log_n, 3), log_n)


def _rand_poly(rng, tables, k=3):
    q = np.asarray(tables.q)
    return (rng.integers(0, 2**62, size=(k, tables.n), dtype=np.uint64)
            % q[:, None])


def test_roundtrip(rng, tables):
    a = _rand_poly(rng, tables)
    back = np.asarray(nttm.intt(nttm.ntt(jnp.asarray(a), tables), tables))
    assert (back == a).all()


def test_negacyclic_convolution(rng, tables):
    a = _rand_poly(rng, tables)
    b = _rand_poly(rng, tables)
    fa = nttm.ntt(jnp.asarray(a), tables)
    fb = nttm.ntt(jnp.asarray(b), tables)
    prod = ma.mulmod(fa, fb, tables.q[:, None])
    conv = np.asarray(nttm.intt(prod, tables))
    for l in range(a.shape[0]):
        ref = nttm.negacyclic_convolve_ref(a[l], b[l], int(np.asarray(tables.q)[l]))
        assert (conv[l] == ref).all()


def test_linearity(rng, tables):
    a = _rand_poly(rng, tables)
    b = _rand_poly(rng, tables)
    q = tables.q[:, None]
    lhs = nttm.ntt(ma.addmod(jnp.asarray(a), jnp.asarray(b), q), tables)
    rhs = ma.addmod(nttm.ntt(jnp.asarray(a), tables),
                    nttm.ntt(jnp.asarray(b), tables), q)
    assert (np.asarray(lhs) == np.asarray(rhs)).all()


@pytest.mark.parametrize("step", [1, 2, 5, -3])
def test_automorphism_eval_equals_coeff(rng, tables, step):
    n = tables.n
    p0 = int(np.asarray(tables.q)[0])
    a = _rand_poly(rng, tables)[0]
    k = nttm.galois_element(step, n)
    # direct scatter definition
    out = np.zeros(n, dtype=np.uint64)
    for i in range(n):
        e = (i * k) % (2 * n)
        out[e % n] = (p0 - a[i]) % p0 if e >= n else a[i]
    # gather form
    src, neg = nttm.coeff_perm(k, n)
    gathered = np.where(neg, (p0 - a[src]) % p0, a[src])
    assert (gathered == out).all()
    # eval-domain permutation
    t0 = tables.slice_limbs([0])
    perm = nttm.eval_perm(k, p0, tables.psi[0], tables.log_n)
    got = np.asarray(nttm.ntt(jnp.asarray(a[None]), t0))[0][perm]
    want = np.asarray(nttm.ntt(jnp.asarray(out[None]), t0))[0]
    assert (got == want).all()


def test_eval_perm_is_modulus_independent(tables):
    """The NTT-slot exponent ordering is structural, not modulus-specific."""
    qs = np.asarray(tables.q)
    k = nttm.galois_element(1, tables.n)
    perms = [nttm.eval_perm(k, int(qs[l]), tables.psi[l], tables.log_n)
             for l in range(len(qs))]
    for p in perms[1:]:
        assert (p == perms[0]).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ntt_parseval_like_property(seed):
    """NTT of a monomial X^i has all slots = psi-power (unit magnitude mod p):
    multiplying by X^i in coeff domain == twiddle-scaling in eval domain."""
    log_n = 6
    tabs = nttm.NttTables(find_ntt_primes(30, log_n, 1), log_n)
    n = tabs.n
    rng = np.random.default_rng(seed)
    i = int(rng.integers(0, n))
    p = int(np.asarray(tabs.q)[0])
    a = rng.integers(0, p, size=(1, n), dtype=np.uint64)
    # multiply by X^i via negacyclic shift in coeff domain
    mono = np.zeros((1, n), dtype=np.uint64)
    mono[0, i] = 1
    fa = nttm.ntt(jnp.asarray(a), tabs)
    fm = nttm.ntt(jnp.asarray(mono), tabs)
    prod = nttm.intt(ma.mulmod(fa, fm, tabs.q[:, None]), tabs)
    ref = nttm.negacyclic_convolve_ref(a[0], mono[0], p)
    assert (np.asarray(prod)[0] == ref).all()


@pytest.fixture()
def rng():
    return np.random.default_rng(99)
