"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED same-family config and runs one forward/train
step + one decode step on CPU, asserting output shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct —
no allocation); see launch/dryrun.py and EXPERIMENTS.md §Dry-run.
"""
import numpy as np
import pytest
import jax
from repro.compat import set_mesh as compat_set_mesh
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import model as M
from repro.train.optim import adamw_init


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(1, 1)


def _batch(cfg, rng, b=2, s=32):
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                 jnp.int32),
           "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                 jnp.int32)}
    if cfg.xattn_period:
        out["images"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_img_tokens, cfg.d_model)), jnp.bfloat16)
    if cfg.enc_dec:
        out["frames"] = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)),
                                    jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_loss(arch, mesh):
    cfg = get_config(arch, smoke=True)
    rng = np.random.default_rng(0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    with compat_set_mesh(mesh):
        logits, mtp_logits, aux, _ = M.forward(params, cfg, batch, mesh)
        loss, metrics = M.loss_fn(params, cfg, batch, mesh)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert not np.isnan(np.asarray(logits, dtype=np.float32)).any()
    if cfg.mtp:
        assert mtp_logits.shape == (2, 32, cfg.vocab)


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch, mesh):
    cfg = get_config(arch, smoke=True)
    rng = np.random.default_rng(1)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    opt = adamw_init(params)
    batch = _batch(cfg, rng)
    with compat_set_mesh(mesh):
        step = jax.jit(M.make_train_step(cfg, mesh))
        new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_decode_step(arch, mesh):
    cfg = get_config(arch, smoke=True)
    rng = np.random.default_rng(2)
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    b, s_max = 2, 32
    cache = M.init_cache(cfg, b, s_max)
    if cfg.enc_dec:
        cache["memory"] = jnp.asarray(rng.normal(size=(b, 4096, cfg.d_model)),
                                      jnp.bfloat16)
    if cfg.xattn_period:
        cache["images"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_img_tokens, cfg.d_model)), jnp.bfloat16)
    with compat_set_mesh(mesh):
        serve = jax.jit(M.make_serve_step(cfg, mesh))
        tok = jnp.zeros((b,), jnp.int32)
        for pos in range(3):
            tok, cache = serve(params, cache, tok, jnp.int32(pos))
    assert tok.shape == (b,)
    assert (np.asarray(tok) >= 0).all() and (np.asarray(tok) < cfg.vocab).all()


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published dims from the assignment."""
    expect = {
        "deepseek-v3-671b": dict(n_layers=61, d_model=7168, n_heads=128,
                                 vocab=129280, n_experts=256, top_k=8,
                                 d_ff_expert=2048),
        "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56,
                            n_kv_heads=8, d_ff=4864, vocab=32000,
                            n_experts=128, top_k=2),
        "llama-3.2-vision-90b": dict(n_layers=100, d_model=8192, n_heads=64,
                                     n_kv_heads=8, d_ff=28672, vocab=128256),
        "seamless-m4t-large-v2": dict(n_layers=24, d_model=1024, n_heads=16,
                                      d_ff=8192, vocab=256206, enc_dec=True),
        "qwen3-8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
                         d_ff=12288, vocab=151936, qk_norm=True),
        "granite-3-8b": dict(n_layers=40, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=12800, vocab=49155),
        "codeqwen1.5-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                               n_kv_heads=32, d_ff=13440, vocab=92416),
        "mistral-nemo-12b": dict(n_layers=40, d_model=5120, n_heads=32,
                                 n_kv_heads=8, d_ff=14336, vocab=131072),
        "rwkv6-3b": dict(n_layers=32, d_model=2560, d_ff=8960, vocab=65536),
        "recurrentgemma-2b": dict(n_layers=26, d_model=2560, n_heads=10,
                                  n_kv_heads=1, d_ff=7680, vocab=256000),
    }
    for name, fields in expect.items():
        cfg = get_config(name)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)


def test_long_500k_applicability():
    from repro.launch.specs import cell_applicable
    for arch in list_archs():
        cfg = get_config(arch)
        ok, _ = cell_applicable(cfg, "long_500k")
        if arch in ("rwkv6_3b", "recurrentgemma_2b"):
            assert ok, f"{arch} should run long_500k"
        else:
            assert not ok, f"{arch} should skip long_500k"
