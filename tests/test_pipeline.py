"""Mapping framework (§IV-F): trace capture, level inference, load-save
pipeline generation + the paper's naive-vs-load-save ablation direction."""
import pytest

from repro.core import pipeline as pl
from repro.core import trace as tr
from repro.core.params import paper_params_bootstrap
from repro.core.params import test_params as make_test_params


def _helr_like(x, w, consts=None):
    s = x * w
    for k in (1, 2, 4, 8):
        s = s + s.rotate(k)
    a = s * consts["c1"]
    b = s * s
    c = b * s
    sg = a + c * consts["c3"]
    return w + sg * x


@pytest.fixture(scope="module")
def helr_trace():
    t = tr.trace_program(_helr_like, 2, const_names=("c1", "c3"))
    tr.infer_levels(t, start_level=12)
    return t


def test_trace_capture(helr_trace):
    kinds = [o.kind for o in helr_trace.ops]
    assert kinds.count("input") == 2
    assert kinds.count("hmul") == 4
    assert kinds.count("rotate") == 4
    assert all(o.level is not None for o in helr_trace.compute_ops())


def test_level_inference_monotone(helr_trace):
    for op in helr_trace.compute_ops():
        for a in op.args:
            parent = helr_trace.ops[a]
            if parent.level is not None:
                assert op.level <= parent.level


def test_level_budget_exhaustion_detected():
    """Regression: exhaustion must raise the structured
    LevelBudgetExhausted (not a bare assert) carrying the failing op, so
    the compiler's bootstrap-insertion pass can catch and rewrite."""
    t = tr.trace_program(_helr_like, 2, const_names=("c1", "c3"))
    with pytest.raises(tr.LevelBudgetExhausted) as ei:
        tr.infer_levels(t, start_level=2)   # too shallow for depth-4 program
    exc = ei.value
    assert exc.kind in ("hmul", "pmul")
    assert exc.level < 0
    assert t.ops[exc.op_index].kind == exc.kind
    # failing op's index/kind land in the message for log readability
    assert str(exc.op_index) in str(exc) and exc.kind in str(exc)


def test_op_cost_model_sane():
    params = paper_params_bootstrap()
    op = tr.FheOp(0, "hmul", (0, 1), level=20)
    c = tr.op_cost(params, op)
    assert c.ntts > 0 and c.modmuls > 0
    assert c.const_bytes == tr.evk_bytes(params)
    # keyswitch dominates an hmul: more NTT work at higher level
    op_lo = tr.FheOp(0, "hmul", (0, 1), level=5)
    assert tr.op_cost(params, op_lo).ntts < c.ntts


def test_load_save_beats_naive(helr_trace):
    """The paper's regime: partition capacity below a coarse stage's
    constant footprint -> naive mapper reloads per input, load-save wins."""
    params = paper_params_bootstrap()
    mem = pl.MemoryModel(n_partitions=8, partition_bytes=64 * 2 ** 20)
    sched = pl.generate_load_save_pipeline(helr_trace, params, mem)
    naive = pl.generate_naive_pipeline(helr_trace, params, mem)
    assert naive.reload_per_op, "naive should overflow at 64MB partitions"
    b = 32
    assert sched.bottleneck_latency(b) < naive.bottleneck_latency(b)
    assert len(sched.stages) >= 1
    assert all(st.partition >= 0 for st in sched.stages)


def test_pipeline_covers_all_ops(helr_trace):
    params = make_test_params()
    mem = pl.MemoryModel(n_partitions=4)
    sched = pl.generate_load_save_pipeline(helr_trace, params, mem)
    staged = [o.idx for st in sched.stages for o in st.ops]
    assert sorted(staged) == sorted(o.idx for o in helr_trace.compute_ops())
    assert len(staged) == len(set(staged)), "op scheduled twice"


def test_stage_partitions_round_robin(helr_trace):
    params = make_test_params()
    mem = pl.MemoryModel(n_partitions=4, partition_bytes=1 * 2 ** 20)
    sched = pl.generate_load_save_pipeline(helr_trace, params, mem)
    for i, st in enumerate(sched.stages):
        assert st.partition == i % mem.n_partitions
