"""repro.runtime serving stack: queue admission/deadlines, batcher
slot-packing invariants, keycache eviction under the const_bytes
budget, compile-cache hits, and an end-to-end smoke through
executor.serve on the analytic backend."""
import numpy as np
import pytest

from repro.core.params import test_params as _test_params
from repro.core.pipeline import MemoryModel
from repro.runtime import (BatchPolicy, KeyCache,
                           PipelinedExecutor, Request, RequestStatus,
                           SlotBatcher)
from repro.runtime.batcher import pack_slot_groups
from repro.runtime.compile_cache import CompileCache, trace_fingerprint
from repro.runtime.metrics import LatencyStats
from repro.runtime.queue import AdmissionQueue


def _prog(x, w, consts=None):
    s = x * w
    for k in (1, 2, 4):
        s = s + s.rotate(k)
    return s * consts["c1"] + x


def _req(q, i, workload="prog", tenant="t0", t=0.0, slots=1, deadline=None):
    return Request(q.next_request_id(), tenant, workload, arrival_s=t,
                   slots_needed=slots, deadline_s=deadline)


def _executor(cache_bytes=64 * 2 ** 20, max_batch=4, max_wait_s=2e-3):
    params = _test_params(log_n=10, n_levels=8, dnum=2)
    mem = MemoryModel(n_partitions=4, partition_bytes=8 * 2 ** 20)
    kc = KeyCache(cache_bytes, load_bw=mem.load_bw) if cache_bytes else None
    ex = PipelinedExecutor(
        params, mem, key_cache=kc,
        policy=BatchPolicy(slots_per_ct=params.slots, max_batch=max_batch,
                           max_wait_s=max_wait_s))
    ex.register("prog", _prog, 2, const_names=("c1",), start_level=7)
    return ex


# ---------------------------------------------------------------------------
# queue
# ---------------------------------------------------------------------------

def test_admission_rejects_when_tenant_full():
    q = AdmissionQueue(max_depth_per_tenant=2)
    assert q.submit(_req(q, 0))
    assert q.submit(_req(q, 1))
    r = _req(q, 2)
    assert not q.submit(r)
    assert r.status is RequestStatus.REJECTED
    assert q.metrics.count("requests_rejected") == 1
    # other tenants unaffected
    assert q.submit(_req(q, 3, tenant="t1"))


def test_deadline_expired_requests_dropped_at_dequeue():
    q = AdmissionQueue()
    q.submit(_req(q, 0, t=0.0, deadline=1.0))
    q.submit(_req(q, 1, t=0.0, deadline=10.0))
    got = q.take(now=5.0, workload="prog", max_requests=8)
    assert len(got) == 1
    assert got[0].deadline_s == 10.0
    assert q.metrics.count("deadline_misses") == 1


def test_mid_queue_expired_request_never_batched():
    """Regression: expiry was only enforced at the queue front, so an
    expired request behind a live one of another workload could still
    be dequeued and burn a pipeline batch."""
    q = AdmissionQueue()
    q.submit(_req(q, 0, workload="y", t=0.0))                  # live front
    q.submit(_req(q, 1, workload="x", t=0.0, deadline=1.0))    # expires
    assert q.take(now=5.0, workload="x", max_requests=8) == []
    assert q.metrics.count("deadline_misses") == 1
    assert q.pending_demand(5.0, "y") == (1, 1)                # live kept


def test_take_round_robins_tenants():
    q = AdmissionQueue()
    for i in range(6):
        q.submit(_req(q, i, tenant=f"t{i % 3}", t=float(i)))
    got = q.take(now=10.0, workload="prog", max_requests=3)
    assert {r.tenant for r in got} == {"t0", "t1", "t2"}


# ---------------------------------------------------------------------------
# batcher: slot-packing invariants
# ---------------------------------------------------------------------------

def test_pack_respects_slot_capacity_and_max_groups():
    q = AdmissionQueue()
    rng = np.random.default_rng(0)
    reqs = [_req(q, i, slots=int(rng.integers(1, 60))) for i in range(40)]
    groups, overflow = pack_slot_groups(reqs, slots_per_ct=64, max_groups=5)
    assert len(groups) <= 5
    for g in groups:
        assert sum(r.slots_needed for r in g) <= 64
    packed = {r.request_id for g in groups for r in g}
    assert packed | {r.request_id for r in overflow} == \
        {r.request_id for r in reqs}
    assert packed & {r.request_id for r in overflow} == set()


def test_pack_oversized_request_overflows():
    q = AdmissionQueue()
    groups, overflow = pack_slot_groups(
        [_req(q, 0, slots=100)], slots_per_ct=64, max_groups=4)
    assert groups == [] and len(overflow) == 1


def test_batcher_never_mixes_workloads():
    q = AdmissionQueue()
    policy = BatchPolicy(slots_per_ct=64, max_batch=4, max_wait_s=0.0)
    b = SlotBatcher(q, policy)
    q.submit(_req(q, 0, workload="a", t=0.0))
    q.submit(_req(q, 1, workload="b", t=0.0))
    q.submit(_req(q, 2, workload="a", t=0.0))
    batch = b.poll(now=1.0)
    assert batch is not None
    assert {r.workload for r in batch.requests} == {batch.workload}
    batch2 = b.poll(now=1.0)
    assert batch2 is not None and batch2.workload != batch.workload


def test_batcher_waits_then_fires_on_max_wait():
    q = AdmissionQueue()
    policy = BatchPolicy(slots_per_ct=64, max_batch=4, max_wait_s=1e-3)
    b = SlotBatcher(q, policy)
    q.submit(_req(q, 0, t=0.0))
    assert b.poll(now=0.0) is None                  # not full, not waited
    assert b.next_fire_time(0.0) == pytest.approx(1e-3)
    batch = b.poll(now=2e-3)
    assert batch is not None and batch.n_requests == 1


def test_batcher_fires_immediately_when_capacity_reached():
    q = AdmissionQueue()
    policy = BatchPolicy(slots_per_ct=4, max_batch=2, max_wait_s=10.0)
    b = SlotBatcher(q, policy)
    for i in range(8):
        q.submit(_req(q, i, slots=1, t=0.0))
    batch = b.poll(now=0.0)                          # 8 slots = capacity
    assert batch is not None
    assert batch.n_ciphertexts <= 2
    for g in batch.slot_groups:
        assert sum(r.slots_needed for r in g) <= 4


# ---------------------------------------------------------------------------
# keycache
# ---------------------------------------------------------------------------

def test_keycache_hit_miss_and_load_time():
    kc = KeyCache(100, load_bw=100.0)
    _, hit, load = kc.get_or_load("a", 50)
    assert not hit and load == pytest.approx(0.5)
    _, hit, load = kc.get_or_load("a", 50)
    assert hit and load == 0.0


def test_keycache_lru_eviction_under_budget():
    kc = KeyCache(100)
    kc.get_or_load("a", 40)
    kc.get_or_load("b", 40)
    kc.get_or_load("a", 40)                 # touch a -> b is LRU
    kc.get_or_load("c", 40)                 # evicts b
    assert "a" in kc and "c" in kc and "b" not in kc
    assert kc.used_bytes <= 100
    assert kc.metrics.count("keycache_evictions") == 1


def test_keycache_entry_larger_than_capacity_never_retained():
    kc = KeyCache(100)
    _, hit, load = kc.get_or_load("huge", 200)
    assert not hit and len(kc) == 0
    _, hit, _ = kc.get_or_load("huge", 200)
    assert not hit                           # still a miss: uncacheable
    assert kc.metrics.count("keycache_uncacheable") == 2


def test_keycache_eviction_mirrors_stage_const_bytes():
    """Eviction keyed by the mapper's const_bytes accounting: capacity
    for exactly two stages' constants keeps the two hottest resident."""
    ex = _executor(cache_bytes=0)
    sched = ex.compile_cache.get_schedule(
        ex.workloads["prog"].trace, ex.params, ex.mem)
    sizes = [st.const_bytes for st in sched.stages if st.const_bytes > 0]
    assert sizes, "schedule should carry constant footprints"
    kc = KeyCache(sizes[0] * 2)
    kc.get_or_load(("prog", "stage", 0), sizes[0])
    _, hit, _ = kc.get_or_load(("prog", "stage", 0), sizes[0])
    assert hit
    assert kc.used_bytes <= kc.capacity_bytes


def test_keycache_invalidate_prefix():
    kc = KeyCache(1000)
    kc.get_or_load(("w1", "stage", 0), 10)
    kc.get_or_load(("w1", "stage", 1), 10)
    kc.get_or_load(("w2", "stage", 0), 10)
    assert kc.invalidate_prefix(("w1",)) == 2
    assert ("w2", "stage", 0) in kc and kc.used_bytes == 10


# ---------------------------------------------------------------------------
# compile cache
# ---------------------------------------------------------------------------

def test_trace_fingerprint_structural():
    from repro.core.trace import infer_levels, trace_program
    t1 = trace_program(_prog, 2, const_names=("c1",))
    t2 = trace_program(_prog, 2, const_names=("c1",))
    infer_levels(t1, 7)
    infer_levels(t2, 7)
    assert trace_fingerprint(t1) == trace_fingerprint(t2)

    def other(x, w, consts=None):
        return (x * w).rotate(2) + x * consts["c1"]
    t3 = trace_program(other, 2, const_names=("c1",))
    infer_levels(t3, 7)
    assert trace_fingerprint(t3) != trace_fingerprint(t1)


def test_compile_cache_hits_same_program():
    from repro.core.trace import infer_levels, trace_program
    params = _test_params(log_n=10, n_levels=8, dnum=2)
    mem = MemoryModel(n_partitions=4)
    cc = CompileCache()
    t1 = trace_program(_prog, 2, const_names=("c1",))
    infer_levels(t1, 7)
    t2 = trace_program(_prog, 2, const_names=("c1",))
    infer_levels(t2, 7)
    s1 = cc.get_schedule(t1, params, mem)
    s2 = cc.get_schedule(t2, params, mem)
    assert s1 is s2
    assert cc.metrics.count("compile_hits") == 1
    assert cc.metrics.count("compile_misses") == 1


def test_trace_fingerprint_discriminates_step_const_and_level():
    """Traces differing only in rotation step, const name, or inferred
    levels must not collide (they compile to different schedules)."""
    from repro.core.trace import infer_levels, trace_program

    def prog(step, cname):
        def fn(x, consts=None):
            return x.rotate(step) * consts[cname]
        return fn

    def capture(step=3, cname="c1", start=7):
        t = trace_program(prog(step, cname), 1, const_names=(cname,))
        infer_levels(t, start)
        return trace_fingerprint(t)

    base = capture()
    assert capture() == base                       # deterministic
    assert capture(step=4) != base                 # rotation step
    assert capture(cname="c2") != base             # const name
    assert capture(start=6) != base                # inferred levels


def test_compile_cache_distinct_entries_per_pass_config():
    """Opt and no-opt (and different pass selections) of one workload
    must occupy distinct cache entries."""
    from repro.compiler import PassConfig
    from repro.core.trace import infer_levels, trace_program
    params = _test_params(log_n=10, n_levels=8, dnum=2)
    mem = MemoryModel(n_partitions=4)
    cc = CompileCache()
    t = trace_program(_prog, 2, const_names=("c1",))
    infer_levels(t, 7)
    cc.get_schedule(t, params, mem)
    cc.get_schedule(t, params, mem, pass_config=PassConfig())
    cc.get_schedule(t, params, mem,
                    pass_config=PassConfig(rotation=False))
    assert len(cc) == 3
    assert cc.metrics.count("compile_misses") == 3
    # and each re-request is a pure hit
    cc.get_schedule(t, params, mem, pass_config=PassConfig())
    assert cc.metrics.count("compile_hits") == 1


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_latency_percentiles_exact():
    ls = LatencyStats()
    for v in np.random.default_rng(0).permutation(np.arange(1, 101)):
        ls.observe(float(v))
    assert ls.p50 == pytest.approx(50.0, abs=1.0)
    assert ls.p99 == pytest.approx(99.0, abs=1.0)
    assert ls.max == 100.0 and ls.count == 100


# ---------------------------------------------------------------------------
# end-to-end smoke (analytic backend)
# ---------------------------------------------------------------------------

def test_executor_end_to_end_smoke():
    ex = _executor()
    rng = np.random.default_rng(3)
    t, arrivals = 0.0, []
    for i in range(50):
        t += float(rng.exponential(1e-3))
        arrivals.append(Request(
            ex.queue.next_request_id(), f"tenant{i % 3}", "prog",
            arrival_s=t, slots_needed=int(rng.integers(1, 64))))
    m = ex.serve(arrivals)
    s = m.summary()
    assert m.count("requests_completed") == 50
    assert s["throughput_rps"] > 0
    assert s["latency"]["p99_s"] >= s["latency"]["p50_s"] > 0
    assert s["keycache_hit_rate"] > 0          # cross-batch residency
    assert s["compile_cache_hit_rate"] > 0     # schedule reuse
    for r in arrivals:
        assert r.status is RequestStatus.COMPLETED
        assert r.completion_s >= r.arrival_s


def test_warmup_does_not_dilute_serving_hit_rates():
    """Regression: warmup's compulsory misses used to land in the
    serving registry; after warmup every serving access is a hit."""
    ex = _executor()
    ex.warmup()
    assert ex.metrics.count("keycache_misses") == 0
    assert ex.metrics.count("compile_misses") == 0
    arrivals = [Request(ex.queue.next_request_id(), "t0", "prog",
                        arrival_s=0.0, slots_needed=8) for _ in range(8)]
    m = ex.serve(arrivals)
    assert m.count("keycache_misses") == 0
    assert m.hit_rate("keycache") == 1.0


def test_executor_keycache_improves_service_time():
    """Same arrival stream, cache on vs off: cached run must finish the
    backlog strictly faster (constants stream once, not per batch)."""
    def run(cache_bytes):
        ex = _executor(cache_bytes=cache_bytes)
        arrivals = [Request(ex.queue.next_request_id(), "t0", "prog",
                            arrival_s=0.0, slots_needed=256)
                    for _ in range(40)]
        m = ex.serve(arrivals)
        return m.elapsed_s

    assert run(cache_bytes=256 * 2 ** 20) < run(cache_bytes=0)


def test_executor_deadline_misses_counted():
    ex = _executor(max_wait_s=0.5)
    arrivals = [Request(ex.queue.next_request_id(), "t0", "prog",
                        arrival_s=0.0, deadline_s=1e-9)]
    m = ex.serve(arrivals)
    assert m.count("deadline_misses") == 1
    assert m.count("requests_completed") == 0


def test_executor_rejects_oversized_request():
    ex = _executor()
    r = ex.submit("t0", "prog", now=0.0,
                  slots_needed=ex.policy.slots_per_ct + 1)
    assert r.status is RequestStatus.REJECTED
    assert ex.metrics.count("requests_oversized") == 1


def test_serve_rejects_oversized_instead_of_hanging():
    """Regression: an unservable request admitted via serve()'s arrival
    path used to spin the event loop forever."""
    ex = _executor()
    r = Request(ex.queue.next_request_id(), "t0", "prog", arrival_s=0.0,
                slots_needed=ex.policy.capacity_slots + 1)
    m = ex.serve([r])                       # must return, not hang
    assert r.status is RequestStatus.REJECTED
    assert m.count("requests_oversized") == 1


def test_executor_serves_optimized_workloads_end_to_end():
    """With a PassConfig the executor compiles through repro.compiler:
    the rotation-heavy matvec serves on an optimized schedule and a
    level-exhausting poly workload registers and serves via automatic
    bootstrap insertion instead of dying in infer_levels."""
    from repro.compiler import PassConfig
    from repro.core.trace import LevelBudgetExhausted
    from repro.runtime.workloads import (make_matvec, make_poly_eval,
                                         matvec_consts, poly_consts)
    params = _test_params(log_n=10, n_levels=8, dnum=2)
    mem = MemoryModel(n_partitions=4, partition_bytes=8 * 2 ** 20)

    def build(opt):
        ex = PipelinedExecutor(
            params, mem,
            policy=BatchPolicy(slots_per_ct=params.slots, max_batch=4,
                               max_wait_s=1e-3),
            pass_config=PassConfig() if opt else None)
        ex.register("matvec", make_matvec(16), 1,
                    const_names=matvec_consts(16), start_level=7)
        return ex

    # no-opt: the deep poly workload is rejected at registration
    with pytest.raises(LevelBudgetExhausted):
        build(opt=False).register("poly", make_poly_eval(12), 1,
                                  const_names=poly_consts(12),
                                  start_level=7)

    ex = build(opt=True)
    ex.register("poly", make_poly_eval(12), 1,
                const_names=poly_consts(12), start_level=7)
    arrivals = [Request(ex.queue.next_request_id(), "t0",
                        ("matvec", "poly")[i % 2], arrival_s=0.0,
                        slots_needed=8) for i in range(8)]
    m = ex.serve(arrivals)
    assert m.count("requests_completed") == 8
    assert m.count("traces_optimized") == 2

    # acceptance: the compiled matvec schedule beats the verbatim one
    noopt = build(opt=False)
    tr = noopt.workloads["matvec"].trace
    s_off = noopt.compile_cache.get_schedule(tr, params, mem)
    s_on = ex.compile_cache.get_schedule(tr, params, mem,
                                         pass_config=ex.pass_config)
    assert s_off.total_latency(8) / s_on.total_latency(8) >= 1.3


def test_mesh_pad_smaller_than_batch_keeps_all_groups():
    """Regression: pad_batch_to below the batch's ciphertext count used
    to index past the packed stack (IndexError / silent data drop)."""
    from repro.runtime.batcher import Batch
    from repro.runtime.executor import MeshBackend

    be = MeshBackend(slots_per_ct=8, pad_batch_to=2)
    q = AdmissionQueue()
    reqs = [_req(q, i, slots=8) for i in range(4)]
    for i, r in enumerate(reqs):
        r.payload = np.full(8, float(i + 1), dtype=np.float32)
    batch = Batch("prog", reqs, [[r] for r in reqs], 0.0)
    n_micro = max(be.pad_batch_to or 0, batch.n_ciphertexts, 1)
    x = np.asarray(be._pack(batch, n_micro))
    assert x.shape == (4, 8)
    for i in range(4):
        assert (x[i] == i + 1).all()       # every group's data packed


def test_mesh_pack_tolerates_opaque_payload():
    """Regression: a Ciphertext (non-array) payload crashed _pack."""
    from repro.runtime.batcher import Batch
    from repro.runtime.executor import MeshBackend

    class Opaque:
        pass

    be = MeshBackend(slots_per_ct=16)
    q = AdmissionQueue()
    r1 = _req(q, 0, slots=4)
    r1.payload = Opaque()
    r2 = _req(q, 1, slots=4)
    r2.payload = np.arange(4, dtype=np.float32)
    x = np.asarray(be._pack(Batch("prog", [r1, r2], [[r1, r2]], 0.0), 1))
    assert x.shape == (1, 16)
    np.testing.assert_array_equal(x[0, 4:8], [0, 1, 2, 3])
    assert (x[0, :4] == 0).all()            # opaque slots left zero
