"""repro.obs telemetry: time-series sampling, SLO burn-rate alerting,
OpenMetrics / Perfetto export, and the fleet-wide TelemetryHub.

The load-bearing guarantees, in test order:

* **series mechanics** — counters only move up, gauges step, rings
  bound memory, sub-resolution updates coalesce, histograms keep the
  Prometheus exposition shape, kind collisions fail loudly;
* **invisible when detached** — the no-telemetry serve is bit-for-bit
  the pre-observability golden (tests/golden/metrics_baseline.json),
  and an armed serve leaves every metric unchanged on the analytic,
  pim, AND fleet paths;
* **clock domains** — analytic/pim/fleet series live on the DES
  virtual timeline (every timestamp inside [0, elapsed]), the
  ciphertext backend's stage series carry measured wall seconds, and
  each series declares its domain through to the OpenMetrics export;
* **cross-check** — the telemetry-derived busy/utilization agrees with
  the occupancy accumulator (and therefore with the roofline-style
  busy/wall normalization format_table and report.py render);
* **SLO burn rate** — fires exactly once on induced overload (instant
  in the span store + event-log line), stays silent at nominal load,
  re-arms only after recovery;
* **export** — OpenMetrics text round-trips through the strict
  self-parser (and its validator rejects malformed expositions);
  Perfetto counter tracks merge into the trace JSON and validate;
* **perf gate** — benchmarks/compare.py exits non-zero on an induced
  regression between two results directories.
"""
import io
import json
import os
import subprocess
import sys

import pytest

import tests._obs_scenario as S
from repro.obs import (JsonEventLog, SloBurnRate, Telemetry, Tracer,
                       parse_openmetrics, render_openmetrics,
                       to_trace_events, validate, write_metrics)
from repro.obs import openmetrics
from repro.compiler import PassConfig
from repro.fleet import FleetScheduler
from repro.runtime import BatchPolicy
from repro.runtime.metrics import TelemetryHub

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks import compare as bench_compare  # noqa: E402

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "metrics_baseline.json")


# ---------------------------------------------------------------------------
# shared runs (module-scoped; each serves the 48-request obs scenario)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def detached():
    ex, m = S.run_scenario("analytic")
    return ex, m


@pytest.fixture(scope="module")
def armed():
    """Analytic serve with tracer + telemetry + event log all armed."""
    ex = S.build_executor("analytic")
    ex.metrics.tracer = Tracer()
    ex.metrics.telemetry = Telemetry(clock="virtual")
    ex.metrics.event_log = JsonEventLog(io.StringIO())
    ex.warmup()
    m = ex.serve(S.make_arrivals(ex))
    return ex, m


@pytest.fixture(scope="module")
def armed_pim():
    ex = S.build_executor("pim")
    ex.metrics.telemetry = Telemetry(clock="virtual")
    ex.warmup()
    m = ex.serve(S.make_arrivals(ex))
    return ex, m


@pytest.fixture(scope="module")
def overload():
    """Everything offered at once against deadlines shorter than one
    batch service: a sustained miss storm the burn-rate monitor must
    page on."""
    ex = S.build_executor("analytic")
    ex.metrics.tracer = Tracer()
    ex.metrics.telemetry = Telemetry(clock="virtual")
    ex.metrics.event_log = JsonEventLog(io.StringIO())
    ex.metrics.slo = SloBurnRate(min_events=4)
    ex.warmup()
    m = ex.serve(S.make_arrivals(ex, rate_rps=1e9, deadline_s=2e-5))
    return ex, m


# ---------------------------------------------------------------------------
# series mechanics
# ---------------------------------------------------------------------------

def test_counter_is_monotone_and_rejects_negative_inc():
    tel = Telemetry()
    c = tel.counter("x_total_ops", device=0)
    c.inc(0.0, 2.0)
    c.inc(1.0, 3.0)
    assert c.value == 5.0
    assert [v for _, v in c.points] == [2.0, 5.0]
    with pytest.raises(ValueError):
        c.inc(2.0, -1.0)
    assert c.value == 5.0              # failed inc must not mutate


def test_gauge_step_interpolation_and_rate():
    tel = Telemetry()
    g = tel.gauge("x_depth")
    g.set(1.0, 4.0)
    g.set(3.0, 2.0)
    assert g.value_at(0.5) == 0.0      # before first point
    assert g.value_at(1.0) == 4.0
    assert g.value_at(2.9) == 4.0      # holds between points
    assert g.value_at(99.0) == 2.0
    c = tel.counter("x_ops")
    c.inc(0.0, 10.0)
    c.inc(2.0, 30.0)
    assert c.rate() == pytest.approx(15.0)
    assert c.rate(0.0, 1.0) == pytest.approx(0.0)   # step: all at t=2
    assert c.rate(5.0, 5.0) == 0.0


def test_ring_buffer_bounds_points_but_keeps_totals():
    tel = Telemetry(max_points=16)
    c = tel.counter("x")
    for i in range(100):
        c.inc(float(i))
    assert len(c.points) == 16
    assert c.value == 100.0            # total survives ring eviction
    assert c.points[0][0] == 84.0      # oldest retained


def test_resolution_coalesces_close_updates():
    tel = Telemetry(resolution=1.0)
    g = tel.gauge("x")
    g.set(0.0, 1.0)
    g.set(0.5, 2.0)                    # < resolution: merges into last
    g.set(2.0, 3.0)
    assert len(g.points) == 2
    assert g.points[0] == (0.5, 2.0)   # newest value wins the cell


def test_series_memoized_and_kind_mismatch_raises():
    tel = Telemetry()
    a = tel.counter("x", bank=1, channel=0)
    b = tel.counter("x", channel=0, bank=1)   # label order irrelevant
    assert a is b
    with pytest.raises(ValueError):
        tel.gauge("x", bank=1, channel=0)
    with pytest.raises(ValueError):
        Telemetry(clock="lamport")


def test_histogram_exposition_shape():
    tel = Telemetry()
    h = tel.histogram("x_seconds", buckets=(0.1, 1.0))
    for t, v in enumerate((0.05, 0.5, 0.5, 5.0)):
        h.observe(float(t), v)
    assert h.count == 4 and h.sum == pytest.approx(6.05)
    assert h.mean == pytest.approx(6.05 / 4)
    cum = h.cumulative_buckets()
    assert cum == [(0.1, 1), (1.0, 3), (float("inf"), 4)]
    with pytest.raises(ValueError):
        tel.histogram("y_seconds", buckets=(1.0, 0.1))


# ---------------------------------------------------------------------------
# invisible when detached / armed on every backend path
# ---------------------------------------------------------------------------

def test_detached_metrics_match_pre_observability_golden(detached):
    got = json.loads(json.dumps(detached[1].summary(), sort_keys=True))
    want = json.load(open(GOLDEN))
    assert got == want, (
        "no-telemetry serving metrics diverged from the golden — "
        "telemetry is no longer zero-overhead-when-disabled")


def test_telemetry_leaves_analytic_metrics_bit_identical(detached, armed):
    assert armed[1].summary() == detached[1].summary()


def test_telemetry_leaves_pim_metrics_bit_identical(armed_pim):
    _, m_off = S.run_scenario("pim")
    assert armed_pim[1].summary() == m_off.summary()


def test_telemetry_leaves_fleet_metrics_bit_identical():
    def run(armed: bool):
        fleet = FleetScheduler(
            S.PARAMS, S.MEM, n_devices=2, backend="analytic",
            policy=BatchPolicy(slots_per_ct=S.PARAMS.slots, max_batch=4,
                               max_wait_s=2e-3),
            cache_bytes=64 * 2 ** 20,
            pass_config=PassConfig(start_level=S.START),
            continuous_batching=True)
        S.register_workloads(fleet)
        fleet.warmup()
        if armed:
            fleet.metrics.telemetry = Telemetry(clock="virtual")
        return fleet, fleet.serve(S.make_arrivals(fleet))
    _, m_off = run(False)
    fleet, m_on = run(True)
    assert m_on.summary() == m_off.summary()
    tel = fleet.metrics.telemetry
    # both devices emitted health series into the shared registry
    devs = {dict(s.labels)["device"]
            for s in tel.find("fhe_device_queue_depth")}
    assert devs == {"0", "1"}
    occ = tel.find("fhe_device_inflight_occupancy")
    assert occ and all(0.0 <= v <= 1.0
                       for s in occ for _, v in s.points)
    assert all(s.value == 0.0 for s in occ)   # drained at end of serve


def test_telemetry_not_in_metrics_summary(armed):
    flat = json.dumps(armed[1].summary(), default=str)
    assert "Telemetry" not in flat and "telemetry" not in flat


# ---------------------------------------------------------------------------
# clock domains
# ---------------------------------------------------------------------------

def test_virtual_clock_series_live_on_des_timeline(armed, armed_pim):
    for ex, m in (armed, armed_pim):
        tel = ex.metrics.telemetry
        assert tel.clock == "virtual"
        assert len(tel) > 0
        for s in tel.series():
            assert s.clock == "virtual"
            for t, _ in s.points:
                assert 0.0 <= t <= m.elapsed_s + 1e-9, (
                    f"{s.name}: point at t={t} outside the DES window "
                    f"[0, {m.elapsed_s}] — a wall clock leaked in")


def test_ciphertext_stage_series_carry_measured_wall_seconds():
    import numpy as np
    from repro.core.params import test_params
    from repro.core.pipeline import MemoryModel
    from repro.runtime import (CiphertextBackend, KeyCache,
                               PipelinedExecutor, Request)
    from repro.runtime.workloads import LOLA_CONSTS, lola_infer
    params = test_params(log_n=8, n_levels=8, dnum=2, log_scale=26)
    ex = PipelinedExecutor(
        params, MemoryModel(n_partitions=4,
                            partition_bytes=256 * 2 ** 10),
        backend=CiphertextBackend(params, use_kernels=False),
        policy=BatchPolicy(slots_per_ct=params.slots, max_batch=2,
                           max_wait_s=1e-3),
        key_cache=KeyCache(64 * 2 ** 20),
        pass_config=PassConfig(start_level=7, bsgs_min_terms=4))
    ex.register("lola", lola_infer, 1, const_names=LOLA_CONSTS,
                start_level=7)
    ex.warmup()
    tel = ex.metrics.telemetry = Telemetry(clock="wall")
    rng = np.random.default_rng(3)
    m = ex.serve([Request(ex.queue.next_request_id(), f"t{i % 2}",
                          "lola", arrival_s=i * 1e-4, slots_needed=8,
                          payload=rng.uniform(-0.8, 0.8, size=8))
                  for i in range(4)])
    hists = tel.find("fhe_stage_wall_seconds")
    assert hists, "ciphertext serve emitted no stage wall histograms"
    # measured wall seconds: strictly positive sums, count = stages
    # observed, and the series declares the wall domain through export
    assert all(h.clock == "wall" and h.sum > 0.0 and h.count > 0
               for h in hists)
    assert sum(s.value for s in
               tel.find("fhe_partition_busy_seconds")) > 0.0
    text = render_openmetrics(tel, m)
    assert "# CLOCK fhe_stage_wall_seconds wall" in text


# ---------------------------------------------------------------------------
# cross-check: telemetry vs the occupancy accumulator (roofline-style
# busy/wall normalization, the same convention report.py renders)
# ---------------------------------------------------------------------------

def test_pim_bank_busy_matches_occupancy_accumulator(armed_pim):
    ex, m = armed_pim
    tel = ex.metrics.telemetry
    tel_busy = sum(s.value for s in tel.find("fhe_pim_bank_busy_seconds"))
    occ_busy = sum(m.occupancy.busy_s)
    assert tel_busy == pytest.approx(occ_busy, rel=1e-12), (
        "telemetry bank-busy series and the occupancy accumulator "
        "disagree — one of the two accounting paths drifted")
    # utilization fraction derived from telemetry equals the busy/wall
    # normalization of PartitionOccupancy (format_table's source)
    mean_u, max_u, n_active = m.occupancy.active_utilization(m.elapsed_s)
    assert tel_busy / m.elapsed_s == pytest.approx(
        sum(u for u in m.occupancy.utilization(m.elapsed_s)), rel=1e-12)
    assert 0.0 < mean_u <= max_u
    table = m.format_table()
    assert "partition util" in table
    assert f"{n_active}/{m.occupancy.n_partitions} active" in table


def test_pim_utilization_samples_below_one_with_known_phases(armed_pim):
    tel = armed_pim[0].metrics.telemetry
    series = tel.find("fhe_pim_bank_utilization")
    assert series
    for s in series:
        assert dict(s.labels)["phase"] in ("ntt", "modmul", "move",
                                           "load")
        for _, v in s.points:
            assert 0.0 < v < 1.0


def test_request_counters_reconcile_with_registry(armed):
    ex, m = armed
    tel = ex.metrics.telemetry
    finished = sum(s.value for s in tel.find("fhe_requests_finished"))
    assert finished == m.count("requests_served")
    goodput = tel.get("fhe_goodput_requests")
    if goodput is not None:
        assert goodput.value == m.count("requests_goodput")
    depth = tel.get("fhe_device_queue_depth", device="0")
    assert depth is not None
    assert all(v >= 0 and v == int(v) for _, v in depth.points)
    assert depth.value == 0.0          # queue drained by end of serve


# ---------------------------------------------------------------------------
# SLO burn-rate monitor
# ---------------------------------------------------------------------------

def test_slo_fires_on_overload_with_span_and_log_marks(overload):
    ex, m = overload
    slo = ex.metrics.slo
    assert m.count("deadline_misses") + \
        m.count("deadline_misses_dequeue") > 0
    assert slo.alerts, "sustained overload did not fire the monitor"
    marks = ex.metrics.tracer.store.by_name("slo_alert")
    assert len(marks) == len(slo.alerts)
    assert all(mk.track == "runtime" and
               mk.attrs["fast_burn"] >= slo.fast_burn for mk in marks)
    lines = [json.loads(ln) for ln in
             ex.metrics.event_log.stream.getvalue().splitlines()]
    assert sum(ln["event"] == "slo_alert" for ln in lines) \
        == len(slo.alerts)
    burn = ex.metrics.telemetry.get("fhe_slo_burn_rate", window="fast")
    assert burn is not None and max(v for _, v in burn.points) \
        >= slo.fast_burn


def test_slo_silent_at_nominal_load():
    ex = S.build_executor("analytic")
    ex.metrics.tracer = Tracer()
    ex.metrics.telemetry = Telemetry(clock="virtual")
    ex.metrics.slo = SloBurnRate()
    ex.warmup()
    m = ex.serve(S.make_arrivals(ex))       # generous 50ms deadlines
    assert m.count("deadline_misses") == 0
    assert not ex.metrics.slo.alerts
    assert not ex.metrics.tracer.store.by_name("slo_alert")


def test_slo_hysteresis_fires_once_then_rearms_after_recovery():
    slo = SloBurnRate(budget=0.1, fast_window_s=1.0, slow_window_s=10.0,
                      min_events=4)
    t = 0.0
    for _ in range(20):                     # miss storm: one alert
        t += 0.1
        slo.record(t, True)
    assert len(slo.alerts) == 1 and slo.firing
    for _ in range(200):                    # healthy traffic: recovery
        t += 0.1
        slo.record(t, False)
    assert len(slo.recoveries) == 1 and not slo.firing
    for _ in range(60):                     # second storm: re-armed
        t += 0.1
        slo.record(t, True)
    assert len(slo.alerts) == 2


def test_slo_parameter_validation():
    with pytest.raises(ValueError):
        SloBurnRate(budget=0.0)
    with pytest.raises(ValueError):
        SloBurnRate(fast_window_s=1.0, slow_window_s=1.0)


# ---------------------------------------------------------------------------
# TelemetryHub: fleet-wide aggregation
# ---------------------------------------------------------------------------

def test_hub_aggregates_across_label_sets():
    tel = Telemetry()
    tel.gauge("depth", device=0).set(1.0, 4.0)
    tel.gauge("depth", device=1).set(2.0, 6.0)
    tel.gauge("depth", device=0).set(3.0, 0.0)
    hub = TelemetryHub(tel)
    assert hub.aggregate("depth", "sum") == [(1.0, 4.0), (2.0, 10.0),
                                             (3.0, 6.0)]
    assert hub.aggregate("depth", "max")[1] == (2.0, 6.0)
    assert hub.aggregate("depth", "mean")[2] == (3.0, 3.0)
    assert hub.aggregate("depth", "sum", label="device",
                         value=1) == [(2.0, 6.0)]
    with pytest.raises(ValueError):
        hub.aggregate("depth", "median")


def test_hub_counters_contribute_zero_before_first_point():
    tel = Telemetry()
    tel.counter("ops", device=0).inc(1.0, 5.0)
    tel.counter("ops", device=1).inc(3.0, 7.0)
    hub = TelemetryHub(tel)
    # at t=1 device 1 hasn't emitted: counts as 0 in the fleet sum
    assert hub.aggregate("ops", "sum") == [(1.0, 5.0), (3.0, 12.0)]
    assert hub.totals("ops") == {"device=0": 5.0, "device=1": 7.0}
    assert set(hub.group("ops")) == {"0", "1"}
    assert hub.aggregate("missing") == []


def test_hub_fleet_queue_depth_view(armed):
    hub = TelemetryHub(armed[0].metrics.telemetry)
    agg = hub.aggregate("fhe_device_queue_depth", "max")
    assert agg and max(v for _, v in agg) >= 1.0


# ---------------------------------------------------------------------------
# OpenMetrics export
# ---------------------------------------------------------------------------

def test_openmetrics_roundtrip_from_serve(armed_pim, tmp_path):
    ex, m = armed_pim
    path = str(tmp_path / "metrics.txt")
    text = write_metrics(path, ex.metrics.telemetry, m)
    assert open(path).read() == text
    samples, errors = parse_openmetrics(text)
    assert errors == []
    assert samples
    names = {s.name for s in samples}
    assert "fhe_pim_bank_busy_seconds_total" in names
    assert "fhe_pim_bank_utilization" in names
    assert "fhe_runtime_events_total" in names
    assert text.rstrip().endswith("# EOF")
    assert openmetrics.main(["validate", path]) == 0


def test_openmetrics_validator_rejects_malformed_text():
    def errs(text):
        return parse_openmetrics(text)[1]
    assert errs("# TYPE x counter\nx_total 1\n")        # no EOF
    assert errs("x_total 1\n# EOF\n")                   # sample before TYPE
    assert errs("# TYPE x counter\nx 1\n# EOF\n")       # missing _total
    assert errs("# TYPE x gauge\nx_total 1\n# EOF\n")   # gauge w/ suffix
    assert errs("# TYPE x gauge\nx 1\nx 2\n# EOF\n")    # duplicate
    assert errs('# TYPE x histogram\n'
                'x_bucket{le="0.1"} 5\nx_bucket{le="1.0"} 3\n'
                'x_bucket{le="+Inf"} 5\nx_sum 1\nx_count 5\n'
                '# EOF\n')                              # non-monotone
    assert errs("# TYPE x counter\nx_total 1\n# EOF\nx_total 2\n")
    assert parse_openmetrics("# EOF\n")[1] == []        # empty is valid


def test_openmetrics_cli_flags_invalid_file(tmp_path):
    bad = tmp_path / "bad.txt"
    bad.write_text("# TYPE x counter\nx 1\n# EOF\n")
    assert openmetrics.main(["validate", str(bad)]) == 1
    assert openmetrics.main(["bogus"]) == 2
    proc = subprocess.run(
        [sys.executable, "-m", "repro.obs.openmetrics", "validate",
         str(bad)],
        env=dict(os.environ, PYTHONPATH="src"),
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True, text=True)
    assert proc.returncode == 1


# ---------------------------------------------------------------------------
# Perfetto counter tracks
# ---------------------------------------------------------------------------

def test_perfetto_merges_validating_counter_tracks(armed, tmp_path):
    ex, _ = armed
    store = ex.metrics.tracer.store
    tel = ex.metrics.telemetry
    obj = to_trace_events(store, clock="virtual", telemetry=tel)
    assert validate(obj) == []
    counters = [e for e in obj["traceEvents"] if e.get("ph") == "C"]
    assert len(counters) == tel.n_points()
    assert {e["pid"] for e in counters} == {4}
    for e in counters:
        assert isinstance(e["ts"], (int, float))
        assert set(e["args"]) == {"value"}
        assert isinstance(e["args"]["value"], (int, float))
    # one named thread per series, under a named telemetry process
    meta = [e for e in obj["traceEvents"] if e.get("ph") == "M"
            and e.get("pid") == 4]
    threads = {e["args"]["name"] for e in meta
               if e["name"] == "thread_name"}
    assert len(threads) == len(tel)
    assert any(e["name"] == "process_name"
               and e["args"]["name"] == "telemetry" for e in meta)
    assert obj["otherData"]["n_series"] == len(tel)
    # without telemetry the export is unchanged legacy shape
    legacy = to_trace_events(store, clock="virtual")
    assert not [e for e in legacy["traceEvents"] if e.get("ph") == "C"]


# ---------------------------------------------------------------------------
# benchmarks/compare.py: the local perf gate
# ---------------------------------------------------------------------------

def _write_results(dirpath, goodput):
    os.makedirs(dirpath, exist_ok=True)
    recs = [{"figure": "utilization", "workload": "helr",
             "preset": "fhemem", "goodput_rps": goodput,
             "mean_util": 0.6},
            {"figure": "overhead", "overhead_frac": 0.01}]
    with open(os.path.join(dirpath, "fig22_utilization.jsonl"),
              "w") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")


def test_compare_exits_nonzero_on_regression(tmp_path, capsys):
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    _write_results(a, goodput=1000.0)
    _write_results(b, goodput=900.0)       # -10% > 2% budget
    assert bench_compare.main([a, b]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out and "goodput_rps" in out
    assert bench_compare.main([a, a]) == 0
    # a wide threshold scale waives the same delta
    assert bench_compare.main([a, b, "--threshold-scale", "10"]) == 0
    assert bench_compare.main([a, str(tmp_path / "missing")]) == 2


def test_compare_skips_one_sided_records(tmp_path, capsys):
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    _write_results(a, goodput=1000.0)
    os.makedirs(b, exist_ok=True)
    with open(os.path.join(b, "fig22_utilization.jsonl"), "w") as f:
        f.write(json.dumps({"figure": "utilization", "workload": "lola",
                            "preset": "flat", "goodput_rps": 5.0,
                            "mean_util": 0.1}) + "\n")
    assert bench_compare.main([a, b]) == 0   # drift, not regression
    assert "skipped" in capsys.readouterr().out
