"""Per-kernel validation: shape/dtype sweeps, exact equality vs ref.py
oracles (integer kernels — allclose tightens to array_equal), and the
u32-only primitive layer."""
import numpy as np
import pytest
import jax.numpy as jnp

from _hyp import given, settings, st  # noqa: E402  (skips per-test)

from repro.core.params import find_2nth_root, find_ntt_primes
from repro.kernels import common, ops, ref


PRIMES = [m.value for m in find_ntt_primes(30, 10, 4)]


@pytest.fixture()
def rng():
    return np.random.default_rng(42)


# ---------------------------------------------------------------------------
# u32 primitive layer
# ---------------------------------------------------------------------------

@settings(max_examples=300, deadline=None)
@given(a=st.integers(0, 2**32 - 1), b=st.integers(0, 2**32 - 1))
def test_mul32_wide_property(a, b):
    hi, lo = common.mul32_wide(jnp.uint32(a), jnp.uint32(b))
    assert (int(hi) << 32) | int(lo) == a * b


@settings(max_examples=200, deadline=None)
@given(a=st.integers(0, 2**31 - 1), b_seed=st.integers(0, 2**31 - 1))
def test_mont_mul32_property(a, b_seed):
    q = PRIMES[0]
    b = b_seed % q
    a = a % (1 << 31)   # mont_mul tolerates a < 2^31 even if >= q
    qinv = (-pow(q, -1, 1 << 32)) % (1 << 32)
    got = int(common.mont_mul32(jnp.uint32(a), jnp.uint32(b),
                                jnp.uint32(q), jnp.uint32(qinv)))
    want = a * b * pow(2**32, -1, q) % q
    assert got == want


# ---------------------------------------------------------------------------
# modmul / mulacc kernels — shape sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("l,n", [(1, 256), (3, 512), (4, 1024), (2, 2048)])
def test_modmul_kernel_sweep(rng, l, n):
    primes = PRIMES[:l]
    qs = np.array(primes, dtype=np.uint64)
    a = rng.integers(0, 2**31, size=(l, n), dtype=np.uint64) % qs[:, None]
    b = rng.integers(0, 2**31, size=(l, n), dtype=np.uint64) % qs[:, None]
    got = ops.modmul(jnp.asarray(a), jnp.asarray(b), primes, interpret=True)
    want = ref.modmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(qs))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("l,n", [(2, 512), (3, 1024)])
def test_mulacc_kernel_sweep(rng, l, n):
    primes = PRIMES[:l]
    qs = np.array(primes, dtype=np.uint64)
    a = rng.integers(0, 2**31, size=(l, n), dtype=np.uint64) % qs[:, None]
    b = rng.integers(0, 2**31, size=(l, n), dtype=np.uint64) % qs[:, None]
    c = rng.integers(0, 2**31, size=(l, n), dtype=np.uint64) % qs[:, None]
    got = ops.mulacc(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), primes,
                     interpret=True)
    want = ref.fused_mulacc_ref(jnp.asarray(a), jnp.asarray(b),
                                jnp.asarray(c), jnp.asarray(qs))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# bconv kernel — eager + lazy schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,d,n", [(3, 2, 512), (5, 4, 1024), (8, 3, 512)])
@pytest.mark.parametrize("lazy", [False, True])
def test_bconv_kernel_sweep(rng, s, d, n, lazy):
    src = [m.value for m in find_ntt_primes(28, 9, s)]
    dst = PRIMES[:d]
    v = np.stack([rng.integers(0, p, size=n, dtype=np.uint64) for p in src])
    w = np.stack([rng.integers(0, min(dst), size=d, dtype=np.uint64)
                  for _ in src])
    got = ops.bconv(jnp.asarray(v), jnp.asarray(w), dst, lazy=lazy,
                    interpret=True)
    want = ref.bconv_ref(jnp.asarray(v), jnp.asarray(w),
                         jnp.asarray(np.array(dst, dtype=np.uint64)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# four-step NTT kernel — shape sweep + ordering vs naive oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("log_n,log_r", [(6, 3), (8, 4), (10, 5), (10, 3)])
def test_ntt_four_step_kernel(rng, log_n, log_r):
    n = 1 << log_n
    mod = find_ntt_primes(30, log_n, 1)[0]
    q = mod.value
    psi = find_2nth_root(q, 2 * n)
    kern = ops.NttKernel(q, psi, log_n, log_r)
    a = rng.integers(0, q, size=n, dtype=np.uint64)
    got = np.asarray(kern(jnp.asarray(a), interpret=True))
    want = np.asarray(ref.four_step_ntt_ref(jnp.asarray(a), kern.tabs))
    np.testing.assert_array_equal(got, want)


def test_ntt_kernel_matches_naive_eval(rng):
    log_n, log_r = 6, 3
    n = 1 << log_n
    mod = find_ntt_primes(30, log_n, 1)[0]
    q = mod.value
    psi = find_2nth_root(q, 2 * n)
    kern = ops.NttKernel(q, psi, log_n, log_r)
    a = rng.integers(0, q, size=n, dtype=np.uint64)
    got = np.asarray(kern(jnp.asarray(a), interpret=True))
    naive = ref.naive_negacyclic_eval(a, q, psi)
    ks = kern.tabs.output_index_map()
    np.testing.assert_array_equal(got, naive[ks])


@pytest.mark.parametrize("block_c,block_r", [(64, 4), (128, 8), (32, 2)])
def test_ntt_kernel_block_shape_sweep(rng, block_c, block_r):
    log_n, log_r = 8, 4
    n = 1 << log_n
    mod = find_ntt_primes(30, log_n, 1)[0]
    psi = find_2nth_root(mod.value, 2 * n)
    kern = ops.NttKernel(mod.value, psi, log_n, log_r)
    a = rng.integers(0, mod.value, size=n, dtype=np.uint64)
    got = np.asarray(kern(jnp.asarray(a), interpret=True,
                          block_c=block_c, block_r=block_r))
    want = np.asarray(ref.four_step_ntt_ref(jnp.asarray(a), kern.tabs))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# ragged shapes — regression for the silent tail-truncation bug where
# `grid = (l, n // block_n)` dropped the last partial block (trailing
# coefficients came back as zeros instead of products)
# ---------------------------------------------------------------------------

RAGGED_N = 600  # > default block_n=512 and not a multiple of it


def test_modmul_kernel_ragged_tail(rng):
    primes = PRIMES[:2]
    qs = np.array(primes, dtype=np.uint64)
    a = rng.integers(0, 2**31, size=(2, RAGGED_N), dtype=np.uint64) % qs[:, None]
    b = rng.integers(0, 2**31, size=(2, RAGGED_N), dtype=np.uint64) % qs[:, None]
    got = ops.modmul(jnp.asarray(a), jnp.asarray(b), primes, interpret=True)
    want = ref.modmul_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(qs))
    assert got.shape == (2, RAGGED_N)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mulacc_kernel_ragged_tail(rng):
    primes = PRIMES[:2]
    qs = np.array(primes, dtype=np.uint64)
    a, b, c = (rng.integers(0, 2**31, size=(2, RAGGED_N), dtype=np.uint64)
               % qs[:, None] for _ in range(3))
    got = ops.mulacc(jnp.asarray(a), jnp.asarray(b), jnp.asarray(c), primes,
                     interpret=True)
    want = ref.fused_mulacc_ref(jnp.asarray(a), jnp.asarray(b),
                                jnp.asarray(c), jnp.asarray(qs))
    assert got.shape == (2, RAGGED_N)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("lazy", [False, True])
def test_bconv_kernel_ragged_tail(rng, lazy):
    src = [m.value for m in find_ntt_primes(28, 9, 3)]
    dst = PRIMES[:2]
    v = np.stack([rng.integers(0, p, size=RAGGED_N, dtype=np.uint64)
                  for p in src])
    w = np.stack([rng.integers(0, min(dst), size=2, dtype=np.uint64)
                  for _ in src])
    got = ops.bconv(jnp.asarray(v), jnp.asarray(w), dst, lazy=lazy,
                    interpret=True)
    want = ref.bconv_ref(jnp.asarray(v), jnp.asarray(w),
                         jnp.asarray(np.array(dst, dtype=np.uint64)))
    assert got.shape == (2, RAGGED_N)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ntt_kernel_rejects_non_dividing_blocks(rng):
    """The four-step kernel's (R, C) tile grid cannot be padded (stage
    twiddles are position-dependent), so bad blocks must raise instead
    of silently truncating."""
    log_n, log_r = 8, 4
    n = 1 << log_n
    mod = find_ntt_primes(30, log_n, 1)[0]
    psi = find_2nth_root(mod.value, 2 * n)
    kern = ops.NttKernel(mod.value, psi, log_n, log_r)
    a = rng.integers(0, mod.value, size=n, dtype=np.uint64)
    with pytest.raises(ValueError, match="must divide"):
        kern(jnp.asarray(a), interpret=True, block_c=3)


# ---------------------------------------------------------------------------
# Montgomery-constant caching — regression for the eager per-call host
# work bug class: every wrapper call recomputed the modular inverses
# (host pow() per prime) and re-uploaded four device arrays outside the
# jit boundary
# ---------------------------------------------------------------------------

def test_mont_consts_cached_across_calls():
    ops._mont_consts.cache_clear()
    k1 = ops._mont_consts(ops._key(PRIMES[:2]))
    # same basis via numpy ints must normalize to the same cache entry
    k2 = ops._mont_consts(ops._key(np.array(PRIMES[:2], dtype=np.uint64)))
    assert all(a is b for a, b in zip(k1, k2))
    assert ops._mont_consts.cache_info().hits >= 1
    # a different basis gets its own entry, not a collision
    k3 = ops._mont_consts(ops._key(PRIMES[:3]))
    assert k3[0].shape != k1[0].shape


def test_mont_consts_cache_values_exact():
    q64, q32, qinv, rm = ops._mont_consts(ops._key(PRIMES[:4]))
    for i, p in enumerate(PRIMES[:4]):
        assert int(q64[i]) == p and int(q32[i]) == p
        assert (int(qinv[i]) * p) % (1 << 32) == (1 << 32) - 1
        assert int(rm[i]) == (1 << 32) % p


def test_modmul_exact_after_cache_hit(rng):
    """Wrapper results stay bit-exact on the cached-constants path."""
    primes = PRIMES[:2]
    qs = np.array(primes, dtype=np.uint64)
    ops._mont_consts.cache_clear()
    for _ in range(2):          # second iteration runs on a cache hit
        a = rng.integers(0, 2**31, size=(2, 64), dtype=np.uint64) % qs[:, None]
        b = rng.integers(0, 2**31, size=(2, 64), dtype=np.uint64) % qs[:, None]
        got = ops.modmul(jnp.asarray(a), jnp.asarray(b), primes,
                         interpret=True)
        want = ref.modmul_ref(jnp.asarray(a), jnp.asarray(b),
                              jnp.asarray(qs))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert ops._mont_consts.cache_info().currsize == 1
