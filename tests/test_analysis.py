"""Static verification layer (repro.analysis): every rule must prove
itself both ways — silent on clean artifacts, firing with exactly its
own rule id on the seeded mutation built to trip it."""
import pytest

from repro.analysis import (RULES, PassVerificationError, VerificationError,
                            analyze_program, verify_pass, verify_schedule,
                            verify_trace)
from repro.analysis.lint import main as lint_main
from repro.analysis.lint import sweep
from repro.analysis.mutate import (ALL_MUTATIONS, PASS_MUTATIONS,
                                   PIM_MUTATIONS, SCHEDULE_MUTATIONS,
                                   TRACE_MUTATIONS, CorruptingPass,
                                   make_clean_artifacts)
from repro.compiler import PassConfig, PassManager, optimize_trace
from repro.core.params import test_params as smoke_params
from repro.core.trace import FheOp, FheTrace
from repro.runtime.compile_cache import CompileCache


@pytest.fixture(scope="module")
def art():
    return make_clean_artifacts("matvec", "fhemem")


# ---------------------------------------------------------------------------
# catalogue hygiene
# ---------------------------------------------------------------------------

def test_every_rule_has_a_mutation():
    assert sorted(ALL_MUTATIONS) == sorted(RULES), \
        "every catalogue rule needs a seeding mutation (and vice versa)"


# ---------------------------------------------------------------------------
# clean artifacts: zero findings
# ---------------------------------------------------------------------------

def test_clean_artifacts_zero_findings(art):
    assert verify_trace(art.trace,
                        start_level=art.start_level).findings == []
    assert verify_schedule(art.schedule, start_level=art.start_level,
                           include_trace=False).findings == []
    assert analyze_program(art.program, art.schedule, art.arch,
                           art.layout).findings == []


def test_clean_sweep_smoke_zero_findings():
    """The lint gate's own sweep: every workload x config x preset the
    CI gate runs must come back clean."""
    params = smoke_params(log_n=10, n_levels=8, dnum=2)
    reports = sweep(params, params.n_levels - 1,
                    workloads=["matvec", "poly"], presets=["fhemem"])
    bad = [r for r in reports if r.findings]
    assert not bad, "\n".join(r.format_table() for r in bad)


# ---------------------------------------------------------------------------
# seeded mutations: each rule fires with its own id
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", sorted(TRACE_MUTATIONS))
def test_trace_mutation_fires(art, rule):
    mutated = TRACE_MUTATIONS[rule](art.trace)
    rep = verify_trace(mutated, start_level=art.start_level)
    assert rule in rep.rule_ids(), rep.format_table()
    # and the clean original still passes — the mutator didn't leak
    assert verify_trace(art.trace, start_level=art.start_level).ok


@pytest.mark.parametrize("rule", sorted(SCHEDULE_MUTATIONS))
def test_schedule_mutation_fires(art, rule):
    mutated = SCHEDULE_MUTATIONS[rule](art.schedule)
    rep = verify_schedule(mutated, start_level=art.start_level,
                          include_trace=False)
    assert rule in rep.rule_ids(), rep.format_table()
    assert verify_schedule(art.schedule, start_level=art.start_level,
                           include_trace=False).ok


@pytest.mark.parametrize("rule", sorted(PIM_MUTATIONS))
def test_pim_mutation_fires(art, rule):
    prog, layout = PIM_MUTATIONS[rule](art.program, art.schedule,
                                       art.layout, art.arch)
    rep = analyze_program(prog, art.schedule, art.arch, layout)
    assert rule in rep.rule_ids(), rep.format_table()
    assert analyze_program(art.program, art.schedule, art.arch,
                           art.layout).ok


@pytest.mark.parametrize("rule", sorted(PASS_MUTATIONS))
def test_pass_mutation_fires_via_verify_pass(art, rule):
    mutated = PASS_MUTATIONS[rule](art.trace)
    rep = verify_pass(art.trace, mutated, subject="seeded")
    assert rule in rep.rule_ids(), rep.format_table()


# ---------------------------------------------------------------------------
# pass attribution: PassManager(verify=True) names the offending pass
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", sorted(PASS_MUTATIONS))
def test_pass_manager_attributes_corrupting_pass(art, rule):
    pm = PassManager(PassConfig(start_level=art.start_level), verify=True,
                     passes=[CorruptingPass(rule, name="evil")])
    with pytest.raises(PassVerificationError) as ei:
        pm.run(art.trace, art.params)
    assert ei.value.pass_name == "evil"
    assert rule in ei.value.report.rule_ids()


def test_pass_manager_attributes_mid_pipeline_corruption(art):
    """The corrupting pass hides between legitimate passes; the error
    still names it, not its neighbours."""
    from repro.compiler.passes import PASS_ORDER
    legit = [p for p in PASS_ORDER if p.name in ("dce", "cse")]
    passes = [legit[0], CorruptingPass("P-IFACE", name="sneaky"),
              legit[1]]
    with pytest.raises(PassVerificationError) as ei:
        optimize_trace(art.trace, art.params,
                       PassConfig(start_level=art.start_level),
                       verify=True, passes=passes)
    assert ei.value.pass_name == "sneaky"


def test_verify_clean_pipeline_reports_overhead(art):
    """verify=True on a clean compile: no exception, and the report
    carries the verification wall time for fig17/fig21."""
    opt, rep = optimize_trace(art.trace, art.params,
                              PassConfig(start_level=art.start_level),
                              verify=True)
    assert rep.verify_wall_s > 0
    applied = [s for s in rep.passes if s.applied]
    assert applied and all(s.verify_wall_s > 0 for s in applied)


# ---------------------------------------------------------------------------
# T-BUDGET reports the earliest failure and the latest-legal cut
# ---------------------------------------------------------------------------

def test_budget_finding_names_latest_legal_cut():
    # start level 1: m = x0*x1 lands at 0, m2 = m*x0 would need -1.
    # The latest-legal cut is m2's deepest operand: m (level 0).
    ops = [FheOp(0, "input", (), {"slot": 0}),
           FheOp(1, "input", (), {"slot": 1}),
           FheOp(2, "hmul", (0, 1), {}),
           FheOp(3, "hmul", (2, 0), {})]
    t = FheTrace(ops, inputs=[0, 1], outputs=[3], consts=[])
    rep = verify_trace(t, start_level=1)
    budget = [f for f in rep.findings if f.rule == "T-BUDGET"]
    assert len(budget) == 1          # earliest failure only, no cascade
    assert budget[0].op_idx == 3
    assert "value 2 (level 0)" in budget[0].hint


# ---------------------------------------------------------------------------
# verify-on-miss in the compile cache
# ---------------------------------------------------------------------------

def test_compile_cache_verify_on_miss_clean(art):
    cache = CompileCache(verify=True)
    sched = cache.get_schedule(
        art.trace, art.params, art.mem,
        pass_config=PassConfig(start_level=art.start_level))
    assert sched.verify_report.ok
    assert getattr(sched, "_verify_wall_s", 0) > 0
    assert cache.metrics.counters.get("verify_errors", 0) == 0


def test_compile_cache_verify_on_miss_rejects_bad_mapper(art):
    from repro.core.pipeline import generate_load_save_pipeline

    def broken_mapper(trace, params, mem, **kw):
        sched = generate_load_save_pipeline(trace, params, mem, **kw)
        sched.stages[0].ops.pop()            # S-COVER violation
        return sched

    cache = CompileCache(verify=True)
    with pytest.raises(VerificationError) as ei:
        cache.get_schedule(art.trace, art.params, art.mem,
                           mapper=broken_mapper,
                           pass_config=PassConfig(
                               start_level=art.start_level))
    assert "S-COVER" in ei.value.report.rule_ids()
    assert cache.metrics.counters.get("verify_errors", 0) > 0


def test_pim_backend_verify_rejects_hazardous_program(art, monkeypatch):
    """PimBackend(verify=True) hazard-analyzes freshly lowered streams;
    a lowering that drops a STORE raises before it can execute."""
    import repro.pim.backend as pb
    from repro.analysis.mutate import clone_program

    be = pb.PimBackend(arch=art.arch, verify=True)
    prog = be.program_for(art.schedule)      # clean: lowers and verifies
    assert len(prog.instrs) > 0 and be.verify_wall_s > 0

    real = pb.lower_schedule

    def bad_lower(schedule, arch, layout=None):
        p = clone_program(real(schedule, arch, layout))
        for k, ins in enumerate(p.instrs):
            if ins.opcode == "STORE" \
                    and schedule.stages[ins.stage].out_bytes:
                del p.instrs[k]
                return p
        raise AssertionError("no STORE to drop")

    monkeypatch.setattr(pb, "lower_schedule", bad_lower)
    be2 = pb.PimBackend(arch=art.arch, verify=True)
    with pytest.raises(VerificationError) as ei:
        be2.program_for(art.schedule)
    assert "M-ORPHAN" in ei.value.report.rule_ids()


# ---------------------------------------------------------------------------
# lint CLI
# ---------------------------------------------------------------------------

def test_lint_cli_smoke_clean(tmp_path, capsys):
    out = tmp_path / "lint.jsonl"
    rc = lint_main(["--smoke", "--workloads", "matvec",
                    "--presets", "fhemem", "--jsonl", str(out)])
    assert rc == 0
    assert "0 errors" in capsys.readouterr().out
    lines = out.read_text().strip().splitlines()
    assert lines and all('"artifact"' in ln for ln in lines)


def test_lint_cli_prove_all_rules():
    from repro.analysis.lint import prove
    assert prove() == []
