"""Optional-hypothesis shim for the property-test modules.

The seed guarded four whole modules with a module-level
``pytest.importorskip("hypothesis")`` — which silently skipped every
*non*-property test in them (kernel sweeps, NTT roundtrips, modarith
unit tests) on any box without hypothesis. Import from here instead:

    from _hyp import given, settings, st, assume, requires_hypothesis

With hypothesis installed these are the real objects. Without it,
``@given(...)`` turns the decorated test into an explicit skip
("needs hypothesis") and strategy construction degrades to inert
stubs, so the module still imports and its plain tests run everywhere.
"""
import pytest

try:
    from hypothesis import assume, given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Stub:
        """Inert stand-in for strategies: any call/attr yields itself."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __repr__(self):
            return "<hypothesis-strategy-stub>"

    st = _Stub()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="needs hypothesis")(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def assume(condition):
        return True


requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="needs hypothesis")
