"""Homomorphic linear algebra: hoisting, BSGS matvec, polynomial eval."""
import numpy as np

from repro.core import linalg, ops
from repro.core.ciphertext import Plaintext

SCALE = 2.0 ** 26


def _enc(stack, keys, v, level=None):
    level = stack["params"].n_levels if level is None else level
    pt = Plaintext(stack["encoder"].encode(v, SCALE, level), level, SCALE)
    return stack["encryptor"].encrypt_sk(pt, keys["sk"])


def _dec(stack, keys, ct):
    return stack["encoder"].decode(
        stack["encryptor"].decrypt(ct, keys["sk"]).data, ct.scale, ct.level)


def test_hoisted_rotations_match_plain(ckks_small, ckks_keys, rng):
    ctx, encr = ckks_small["ctx"], ckks_small["encryptor"]
    s = ctx.n // 2
    v = rng.normal(size=s) + 1j * rng.normal(size=s)
    ct = _enc(ckks_small, ckks_keys, v)
    steps = [1, 5, 17]
    gks = encr.rotation_keygen(ckks_keys["sk"], steps)
    hr = linalg.hoisted_rotations(ctx, ct, steps, gks)
    for st in steps:
        plain = ops.rotate(ctx, ct, st, gks[ctx.rotation_element(st)])
        np.testing.assert_allclose(_dec(ckks_small, ckks_keys, hr[st]),
                                   np.roll(v, -st), atol=5e-3)
        np.testing.assert_allclose(_dec(ckks_small, ckks_keys, hr[st]),
                                   _dec(ckks_small, ckks_keys, plain), atol=1e-3)


def test_matvec_bsgs(ckks_small, ckks_keys, rng):
    ctx, encr, enc = (ckks_small["ctx"], ckks_small["encryptor"],
                      ckks_small["encoder"])
    s = ctx.n // 2
    v = 0.5 * (rng.normal(size=s) + 1j * rng.normal(size=s))
    ct = _enc(ckks_small, ckks_keys, v)
    M = np.zeros((s, s), dtype=np.complex128)
    for d in rng.choice(s, size=6, replace=False):
        dg = rng.normal(size=s) * 0.3
        for j in range(s):
            M[j, (j + d) % s] = dg[j]
    diags = linalg.matrix_diagonals(M)
    gks = encr.galois_keygen(ckks_keys["sk"], linalg.matvec_keys_needed(ctx, diags))
    out = linalg.matvec_bsgs(ctx, ct, diags, gks, enc)
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, out), M @ v, atol=2e-2)
    out2 = linalg.matvec_bsgs(ctx, ct, diags, gks, enc, use_hoisting=False)
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, out2), M @ v, atol=2e-2)


def test_poly_eval_power_basis(ckks_small, ckks_keys, rng):
    ctx, enc = ckks_small["ctx"], ckks_small["encoder"]
    s = ctx.n // 2
    x = rng.uniform(-1, 1, size=s)
    ct = _enc(ckks_small, ckks_keys, x + 0j)
    out = linalg.poly_eval_power_basis(ctx, ct, [0.25, 1.5, 0.0, -0.5],
                                       ckks_keys["rk"], enc)
    want = 0.25 + 1.5 * x - 0.5 * x ** 3
    got = _dec(ckks_small, ckks_keys, out).real
    np.testing.assert_allclose(got, want, atol=1e-3)


def test_poly_eval_chebyshev(ckks_small, ckks_keys, rng):
    ctx, enc = ckks_small["ctx"], ckks_small["encoder"]
    s = ctx.n // 2
    x = rng.uniform(-1, 1, size=s)
    ct = _enc(ckks_small, ckks_keys, x + 0j)
    # deg 7 fits the 4-level test budget (ladder depth 3 + combination 1)
    cheb = linalg.chebyshev_coeffs(lambda t: np.sin(0.5 * np.pi * t), 7)
    out = linalg.poly_eval_chebyshev(ctx, ct, cheb, ckks_keys["rk"], enc)
    got = _dec(ckks_small, ckks_keys, out).real
    np.testing.assert_allclose(got, np.sin(0.5 * np.pi * x), atol=5e-3)


def test_adjust_to_exact_scale(ckks_small, ckks_keys, rng):
    ctx, enc = ckks_small["ctx"], ckks_small["encoder"]
    s = ctx.n // 2
    v = rng.normal(size=s) + 0j
    ct = _enc(ckks_small, ckks_keys, v)
    target = SCALE * 1.01
    out = linalg.adjust_to(ctx, enc, ct, ct.level - 1, target)
    assert out.level == ct.level - 1 and out.scale == target
    np.testing.assert_allclose(_dec(ckks_small, ckks_keys, out), v, atol=1e-3)


def test_chebyshev_coeffs_interpolate():
    c = linalg.chebyshev_coeffs(np.cos, 20)
    x = np.linspace(-1, 1, 500)
    T = np.cos(np.outer(np.arange(21), np.arccos(x)))
    assert np.abs(c @ T - np.cos(x)).max() < 1e-12
