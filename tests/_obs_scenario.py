"""Shared deterministic serving scenario for the observability tests.

One fixed (params, memory model, workload registry, arrival stream)
tuple used by tests/test_obs.py for three different regressions:

* the disabled-tracer bit-for-bit golden (metrics summary must equal
  the snapshot captured from pre-tracing main),
* enabled-vs-disabled metric identity on virtual-clock backends,
* span-tree completeness/integrity checks.

Kept import-light (no repro.obs dependency) so the golden can be
regenerated against any revision of the runtime alone.
"""
from __future__ import annotations

import numpy as np

from repro.compiler import PassConfig
from repro.core.params import test_params
from repro.core.pipeline import MemoryModel
from repro.runtime import BatchPolicy, KeyCache, PipelinedExecutor, Request
from repro.runtime.workloads import (HELR_CONSTS, LOLA_CONSTS, lola_infer,
                                     make_helr_iter, make_matvec,
                                     matvec_consts)

PARAMS = test_params(log_n=10, n_levels=8, dnum=2)
MEM = MemoryModel(n_partitions=4, partition_bytes=8 * 2 ** 20)
START = 7


def register_workloads(ex) -> None:
    ex.register("helr", make_helr_iter(), 2, const_names=HELR_CONSTS,
                start_level=START)
    ex.register("lola", lola_infer, 1, const_names=LOLA_CONSTS,
                start_level=START)
    ex.register("matvec16", make_matvec(16), 1,
                const_names=matvec_consts(16), start_level=START)


def build_executor(backend="analytic", cache_mb: int = 64,
                   max_batch: int = 4) -> PipelinedExecutor:
    policy = BatchPolicy(slots_per_ct=PARAMS.slots, max_batch=max_batch,
                         max_wait_s=2e-3)
    kc = (KeyCache(cache_mb * 2 ** 20, load_bw=MEM.load_bw)
          if cache_mb else None)
    return_ex = PipelinedExecutor(PARAMS, MEM, backend=backend,
                                  policy=policy, key_cache=kc,
                                  pass_config=PassConfig(start_level=START))
    register_workloads(return_ex)
    return return_ex


def make_arrivals(ex, n_requests: int = 48, rate_rps: float = 3000.0,
                  seed: int = 11, deadline_s: float = 0.05,
                  max_slots: int = 64):
    """Poisson stream over three tenants; two of three requests carry a
    deadline so completion, miss, and best-effort paths all run."""
    rng = np.random.default_rng(seed)
    names = sorted(ex.workloads)
    out, t = [], 0.0
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        out.append(Request(
            ex.next_request_id(), tenant=f"tenant{i % 3}",
            workload=names[i % len(names)], arrival_s=t,
            slots_needed=int(rng.integers(1, max_slots + 1)),
            deadline_s=t + deadline_s if i % 3 else None))
    return out


def run_scenario(backend="analytic", **arrival_kw):
    """Build, warm up, serve. Returns (executor, metrics)."""
    ex = build_executor(backend)
    ex.warmup()
    m = ex.serve(make_arrivals(ex, **arrival_kw))
    return ex, m
