"""repro.obs: end-to-end tracing regressions.

The load-bearing guarantees, in test order:

* **zero-overhead-when-disabled** — with no tracer attached the serving
  metrics are bit-for-bit the golden captured from pre-tracing main
  (tests/golden/metrics_baseline.json, REGEN_GOLDENS=1 to refresh);
* **observe, never perturb** — attaching a tracer leaves every metric
  of a virtual-clock serve bit-for-bit unchanged (analytic AND pim);
* **span-tree completeness/integrity** — every terminal request has a
  closed root whose duration IS its recorded latency; children nest
  inside parents; parents resolve; nothing stays open after serve;
* **fleet(N=1) anchor extended to spans** — the one-device fleet emits
  the single executor's span timeline (same names, times, tracks);
* **export** — the Perfetto trace_event JSON passes the validator;
* plus the satellites: drop/preempt/refill trace paths, compile
  hit/miss + per-pass spans, PIM ISA cycle attribution, critical-path
  telescoping, bounded-reservoir LatencyStats, PassReport attachment,
  and the JSON event log.
"""
import io
import json
import os

import pytest

import tests._obs_scenario as S
from repro.compiler import PassConfig
from repro.obs import (JsonEventLog, Tracer, critical_path, request_chain,
                       to_trace_events, validate, workload_breakdown,
                       write_trace)
from repro.fleet import FleetScheduler
from repro.pim.isa import OPCODES
from repro.runtime import BatchPolicy
from repro.runtime.metrics import LatencyStats, MetricsRegistry

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "metrics_baseline.json")


# ---------------------------------------------------------------------------
# shared runs (module-scoped: the scenario serves 48 requests through a
# compile+warmup, so every test below reads, none re-runs)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def untraced():
    ex, m = S.run_scenario("analytic")
    return ex, m


@pytest.fixture(scope="module")
def traced():
    ex = S.build_executor("analytic")
    ex.metrics.tracer = Tracer()
    ex.metrics.event_log = JsonEventLog(io.StringIO())
    ex.warmup()
    m = ex.serve(S.make_arrivals(ex))
    return ex, m


@pytest.fixture(scope="module")
def store(traced):
    return traced[0].metrics.tracer.store


# ---------------------------------------------------------------------------
# disabled == absent: the bit-for-bit golden
# ---------------------------------------------------------------------------

def test_untraced_metrics_match_pre_tracing_golden(untraced):
    """The tracing layer must be invisible when detached: the full
    metrics summary equals the snapshot captured before repro.obs
    existed. Any drift here means instrumentation leaked into the
    serving timeline."""
    got = json.loads(json.dumps(untraced[1].summary(), sort_keys=True))
    if os.environ.get("REGEN_GOLDENS"):
        with open(GOLDEN, "w") as f:
            json.dump(got, f, indent=1, sort_keys=True)
    assert os.path.exists(GOLDEN), \
        "golden file missing — run with REGEN_GOLDENS=1 to create it"
    want = json.load(open(GOLDEN))
    assert got == want, (
        "untraced serving metrics diverged from the pre-tracing "
        "baseline — tracing is no longer zero-overhead-when-disabled "
        "(if the change is intentional, regen with REGEN_GOLDENS=1)")


@pytest.mark.parametrize("backend", ["analytic", "pim"])
def test_tracing_leaves_metrics_bit_identical(backend, untraced, traced):
    if backend == "analytic":
        m_off, m_on = untraced[1], traced[1]
    else:
        _, m_off = S.run_scenario("pim")
        ex = S.build_executor("pim")
        ex.metrics.tracer = Tracer()
        ex.warmup()
        m_on = ex.serve(S.make_arrivals(ex))
    assert m_on.summary() == m_off.summary()


def test_tracer_not_in_metrics_summary(traced):
    # the tracer rides on the registry but must never serialize with it
    flat = json.dumps(traced[1].summary(), default=str)
    assert "Tracer" not in flat and "tracer" not in flat


# ---------------------------------------------------------------------------
# span-tree completeness and integrity
# ---------------------------------------------------------------------------

def test_every_request_has_closed_root_with_terminal_status(store, traced):
    m = traced[1]
    roots = store.by_name("request")
    assert len(roots) == m.count("requests_admitted")
    assert all(s.end_s is not None for s in roots)
    terminal = {"completed", "deadline_miss", "dropped_expired",
                "rejected", "unfinished"}
    assert all(s.attrs["status"] in terminal for s in roots)


def test_root_duration_is_recorded_latency(store, traced):
    """The acceptance criterion: root span duration == recorded
    latency to float precision, for every served request."""
    m = traced[1]
    served = [s for s in store.by_name("request")
              if s.attrs["status"] in ("completed", "deadline_miss")]
    lat = m.request_latency
    assert len(served) == lat.count
    assert sorted(s.duration_s for s in served) == sorted(lat._view())


def test_children_nest_inside_parents(store):
    for s in store.spans:
        if s.parent_id is None:
            continue
        p = store.get(s.parent_id)
        assert p is not None, f"orphan span {s.span_id} ({s.name})"
        assert s.start_s >= p.start_s - 1e-12, (s.name, p.name)
        assert s.end_s <= p.end_s + 1e-12, (s.name, p.name)


def test_no_open_spans_and_monotone_intervals(store):
    assert not store.open_spans()
    assert all(s.end_s >= s.start_s for s in store.spans)


def test_service_span_links_to_batch_subtree(store):
    for svc in store.by_name("service"):
        bs = store.get(svc.attrs["batch_span"])
        assert bs is not None and bs.name.startswith("batch:")
        assert bs.track.startswith("device:")
        # the batch carries round and stage detail
        names = {c.name for c in store.children(bs.span_id)}
        assert "round" in names


def test_queue_wait_plus_service_covers_root(store):
    for root in store.by_name("request"):
        if root.attrs["status"] not in ("completed", "deadline_miss"):
            continue
        kids = {c.name: c for c in store.children(root.span_id)}
        qw, svc = kids["queue_wait"], kids["service"]
        assert qw.start_s == root.start_s
        assert qw.end_s == svc.start_s
        assert svc.end_s == root.end_s


# ---------------------------------------------------------------------------
# compile spans
# ---------------------------------------------------------------------------

def test_compile_spans_hit_after_warmup(store, traced):
    compiles = store.by_name("compile")
    assert compiles, "no compile spans emitted"
    # warmup precompiled every workload: serving-time compiles all hit
    assert all(c.attrs["hit"] for c in compiles)


def test_compile_miss_emits_pass_children():
    ex = S.build_executor("analytic")     # no warmup: first batch misses
    ex.metrics.tracer = Tracer()
    ex.serve(S.make_arrivals(ex, n_requests=6))
    store = ex.metrics.tracer.store
    misses = [c for c in store.by_name("compile") if not c.attrs["hit"]]
    assert misses
    m0 = misses[0]
    assert m0.attrs["wall_s"] > 0
    passes = [c for c in store.children(m0.span_id)
              if c.name.startswith("pass:")]
    assert passes, "compile miss span has no per-pass children"
    for p in passes:
        assert p.attrs["wall_s"] >= 0
        assert p.attrs["ops_after"] >= 0


def test_schedule_carries_pass_report():
    ex = S.build_executor("analytic")
    sched = ex.compile_cache.get_schedule(
        ex.workloads["helr"].trace, S.PARAMS, S.MEM,
        pass_config=PassConfig(start_level=S.START))
    rep = sched.pass_report
    assert rep is not None
    assert rep.wall_s > 0
    table = rep.format_table(include_wall=True)
    assert "wall_ms" in table
    # and without the flag the historical format is unchanged
    assert "wall_ms" not in rep.format_table()


# ---------------------------------------------------------------------------
# PIM attribution
# ---------------------------------------------------------------------------

def test_pim_stage_spans_attribute_isa_cycles():
    ex = S.build_executor("pim")
    ex.metrics.tracer = Tracer()
    ex.warmup()
    ex.serve(S.make_arrivals(ex, n_requests=12))
    stages = ex.metrics.tracer.store.by_name("stage")
    assert stages
    for s in stages:
        isa = s.attrs["isa_cycles"]
        assert set(isa) <= set(OPCODES)
        assert all(v >= 0 for v in isa.values())
        assert sum(isa.values()) > 0
        assert s.attrs["bank_cycles"], "per-bank attribution missing"


# ---------------------------------------------------------------------------
# fleet: N=1 span parity with the single executor, and the
# drop/preempt/refill paths
# ---------------------------------------------------------------------------

def _span_key(s):
    return (s.name, round(s.start_s, 15), round(s.end_s, 15), s.track)


def test_fleet_of_one_emits_executor_span_timeline(traced):
    """The fleet anchor invariant, extended to observability: the
    1-device fleet (round_robin, no continuous batching, no preempt)
    must produce the single executor's span timeline — same span
    names at the same virtual times on the same tracks. Fleet-only
    `route` instants are the one permitted addition."""
    fleet = FleetScheduler(
        S.PARAMS, S.MEM, n_devices=1, backend="analytic",
        router="round_robin",
        policy=BatchPolicy(slots_per_ct=S.PARAMS.slots, max_batch=4,
                           max_wait_s=2e-3),
        cache_bytes=64 * 2 ** 20,
        pass_config=PassConfig(start_level=S.START))
    S.register_workloads(fleet)
    fleet.warmup()
    fleet.metrics.tracer = Tracer()
    mf = fleet.serve(S.make_arrivals(fleet))

    ex, m1 = traced
    assert mf.elapsed_s == m1.elapsed_s
    single = sorted(_span_key(s) for s in ex.metrics.tracer.store.spans)
    fleet_spans = sorted(_span_key(s)
                         for s in fleet.metrics.tracer.store.spans
                         if s.name != "route")
    assert fleet_spans == single


def test_dropped_request_root_closed_with_drop_status():
    ex = S.build_executor("analytic")
    ex.metrics.tracer = Tracer()
    ex.warmup()
    # everything offered at once with deadlines shorter than one batch
    # service: whatever queues behind the first batches expires in-queue
    m = ex.serve(S.make_arrivals(ex, rate_rps=1e9, deadline_s=2e-5))
    if not m.count("deadline_misses_dequeue"):
        pytest.skip("scenario produced no queue-side drops")
    dropped = [s for s in ex.metrics.tracer.store.by_name("request")
               if s.attrs["status"] == "dropped_expired"]
    assert len(dropped) == m.count("deadline_misses_dequeue")
    assert all(s.end_s is not None for s in dropped)


def test_fleet_preempt_and_refill_emit_trace_marks():
    from tests.test_fleet import (MEM_MULTI_ROUND, _prog_a, _prog_mv,
                                  MV_CONSTS, _stream)
    fleet = FleetScheduler(
        S.PARAMS, MEM_MULTI_ROUND, n_devices=1, backend="analytic",
        policy=BatchPolicy(slots_per_ct=S.PARAMS.slots, max_batch=4,
                           max_wait_s=2e-3),
        pass_config=PassConfig(start_level=S.START),
        continuous_batching=True, preempt=True)
    fleet.register("a", _prog_a, 2, const_names=("c1",), start_level=S.START)
    fleet.register("mv", _prog_mv, 1, const_names=MV_CONSTS,
                   start_level=S.START)
    fleet.warmup()
    fleet.metrics.tracer = Tracer()
    m = fleet.serve(_stream(n=60, rate=2000.0, deadline=0.004,
                            workloads=("a", "mv"), best_effort_every=3))
    store = fleet.metrics.tracer.store
    if m.count("continuous_refills"):
        assert store.by_name("batch_join"), \
            "refills happened but no batch_join instants traced"
    if m.count("preemptions"):
        marks = store.by_name("preempt")
        assert len(marks) == m.count("requests_preempted")
        assert all(mk.attrs["device"] == 0 for mk in marks)
    assert not store.open_spans()


# ---------------------------------------------------------------------------
# export + analyzers
# ---------------------------------------------------------------------------

def test_perfetto_export_validates(store, tmp_path):
    assert validate(to_trace_events(store, clock="virtual")) == []
    path = tmp_path / "trace.json"
    write_trace(store, str(path), clock="virtual")
    data = json.load(open(path))
    assert validate(data) == []
    # one thread_name metadata event per device and per tenant track
    threads = [e for e in data["traceEvents"]
               if e["ph"] == "M" and e["name"] == "thread_name"]
    assert len(threads) >= 4     # 3 tenant tracks + 1 device track


def test_critical_path_telescopes(store):
    roots = [s for s in store.by_name("request")
             if s.attrs["status"] == "completed"]
    root = max(roots, key=lambda s: s.duration_s)
    segs = critical_path(store, root.request_id, k=100)
    assert segs
    total = sum(sg.contribution_s for sg in segs)
    assert total <= root.duration_s + 1e-12
    assert total >= 0.99 * root.duration_s, (
        "critical-path contributions must telescope to ~the root "
        f"duration: {total} vs {root.duration_s}")
    chain = request_chain(store, root.request_id)
    assert chain[0].name == "request"


def test_workload_breakdown_accounts_latency(store, traced):
    bd = workload_breakdown(store)
    assert set(bd) == set(traced[0].workloads)
    for name, r in bd.items():
        parts = r["queue_s"] + r["load_s"] + r["compute_s"] + \
            r["move_s"] + r["other_s"]
        assert parts == pytest.approx(r["latency_s"], rel=1e-9), name
        assert r["n"] > 0


# ---------------------------------------------------------------------------
# JSON event log
# ---------------------------------------------------------------------------

def test_event_log_lines_are_schema_complete(traced):
    ex, m = traced
    lines = ex.metrics.event_log.stream.getvalue().splitlines()
    assert len(lines) == ex.metrics.event_log.n_events
    evs = [json.loads(ln) for ln in lines]
    kinds = {e["event"] for e in evs}
    assert {"accepted", "completed"} <= kinds
    for e in evs:
        assert set(e) >= {"ts", "event", "request_id", "tenant",
                          "workload"}
    n_done = sum(e["event"] == "completed" for e in evs)
    assert n_done == m.count("requests_completed")
    # deadline-carrying completions expose their slack
    assert any("deadline_slack_s" in e for e in evs
               if e["event"] == "completed")


# ---------------------------------------------------------------------------
# LatencyStats bounded reservoir
# ---------------------------------------------------------------------------

def _fill(stats, n, seed=5):
    import random
    rng = random.Random(seed)
    vals = [rng.expovariate(100.0) for _ in range(n)]
    for v in vals:
        stats.observe(v)
    return vals


def test_reservoir_below_threshold_is_exact():
    a, b = LatencyStats("x"), LatencyStats("x", reservoir=1000)
    vals = _fill(a, 500)
    for v in vals:
        b.observe(v)
    for p in (50, 95, 99):
        assert a.percentile(p) == b.percentile(p)
    assert a.mean == b.mean and a.max == b.max and a.count == b.count


def test_reservoir_bounds_memory_keeps_exact_aggregates():
    st = LatencyStats("y", reservoir=64)
    vals = _fill(st, 5000)
    assert len(st._samples) == 64
    assert st.count == 5000
    assert st.max == max(vals)
    assert st.mean == pytest.approx(sum(vals) / len(vals))
    # percentiles are estimates but must live inside the sample range
    assert min(vals) <= st.p99 <= max(vals)


def test_reservoir_is_deterministic_per_name():
    a, b = LatencyStats("z", reservoir=32), LatencyStats("z", reservoir=32)
    _fill(a, 2000, seed=9), _fill(b, 2000, seed=9)
    assert a._samples == b._samples
    c = LatencyStats("other-name", reservoir=32)
    _fill(c, 2000, seed=9)
    assert c._samples != a._samples   # name-seeded, not shared state


def test_registry_threads_reservoir_everywhere():
    m = MetricsRegistry(latency_reservoir=128)
    for st in (m.request_latency, m.queue_delay, m.service_time,
               m.batch_service):
        assert st.reservoir == 128
    assert MetricsRegistry().request_latency.reservoir is None
