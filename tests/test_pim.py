"""repro.pim — hierarchical FHEmem hardware model, layout mapper,
ISA lowering, and the discrete-event PimBackend.

The anchor invariant: the degenerate flat preset bills EXACTLY like
the analytic MemoryModel, so `PimBackend(flat)` reproduces
`AnalyticBackend` stage times within 1% (acceptance criterion). On
top of that, layout/lowering structural invariants run both as fixed
deterministic cases and as hypothesis properties (skipped without
hypothesis via tests/_hyp.py).
"""

import pytest

from _hyp import given, settings, st  # noqa: E402  (skips per-test)

from repro.compiler import PassConfig, optimize_trace
from repro.core.params import test_params as make_test_params
from repro.core.pipeline import (MemoryModel, generate_load_save_pipeline,
                                 generate_naive_pipeline)
from repro.core.trace import FheOp, op_cost, trace_program
from repro.pim import (FLAT, PRESETS, PimBackend, arch_for_memory_model,
                       flat_arch_from_memory_model, get_arch, lower_schedule,
                       memory_model, plan_layout)
from repro.pim.layout import _stage_limbs
from repro.runtime.batcher import Batch
from repro.runtime.executor import AnalyticBackend, resolve_backend
from repro.runtime.keycache import KeyCache
from repro.runtime.metrics import MetricsRegistry
from repro.runtime.workloads import (HELR_CONSTS, make_helr_iter,
                                     make_matvec, matvec_consts)

PARAMS = make_test_params(log_n=10, n_levels=8, dnum=2)
MEM = MemoryModel(n_partitions=4, partition_bytes=8 * 2 ** 20)
CFG = PassConfig(start_level=7)


def _schedule(fn=None, n_in=2, consts=HELR_CONSTS, mem=MEM, params=PARAMS,
              mapper=generate_load_save_pipeline):
    trace = trace_program(fn or make_helr_iter(), n_in, const_names=consts)
    opt, _ = optimize_trace(trace, params, CFG)
    return mapper(opt, params, mem)


def _batch(n=4, workload="w"):
    return Batch(workload, [], [[] for _ in range(n)], 0.0)


# ---------------------------------------------------------------------------
# arch presets + flat-model adapter
# ---------------------------------------------------------------------------

def test_preset_registry():
    assert set(PRESETS) == {"fhemem", "hbm2", "flat"}
    for name, arch in PRESETS.items():
        assert arch.name == name
        mm = arch.to_memory_model()
        assert mm.n_partitions == arch.n_banks
        assert mm.partition_bytes == arch.bank_bytes
        assert memory_model(name) == mm
    with pytest.raises(ValueError):
        get_arch("nope")


def test_flat_preset_is_memory_model_defaults():
    """The degenerate preset round-trips to MemoryModel() exactly —
    the 'MemoryModel is an adapter over the degenerate preset' story."""
    assert FLAT.to_memory_model() == MemoryModel()
    assert arch_for_memory_model(MemoryModel()) is FLAT


def test_arch_for_memory_model_wraps_custom_mems():
    arch = arch_for_memory_model(MEM)
    assert arch.degenerate
    assert arch.n_banks == MEM.n_partitions
    assert arch.to_memory_model().modmul_throughput == \
        MEM.modmul_throughput


def test_bit_serial_cycle_model():
    fhemem = get_arch("fhemem")
    # wider limbs cost quadratically more bit-serial cycles
    assert fhemem.modmul_cycles(64) > 3 * fhemem.modmul_cycles(32)
    # a row op on a ring smaller than the lane count is one wave
    one = fhemem.rows_seconds(1, 1024)
    assert one == fhemem.modmul_cycles() / fhemem.freq_hz
    # element-ops/lanes scaling: 4x the rows on a big ring, ~4x the
    # time (wave quantization allows a ±1-wave wobble)
    n = 1 << 16
    assert fhemem.rows_seconds(400, n) >= 3.4 * fhemem.rows_seconds(100, n)
    # hierarchy presets pay NTT inter-mat shuffles, flat does not
    assert fhemem.ntt_shuffle_bytes(n) > 0
    assert FLAT.ntt_shuffle_bytes(n) == 0


def test_op_cost_movement_channels():
    """Satellite regression: keyswitch digit-decomposition rows and
    rotation movement are separate OpCost channels, and
    MemoryModel.compute_seconds bills them."""
    hmul = op_cost(PARAMS, FheOp(0, "hmul", (0, 1), level=5))
    rot = op_cost(PARAMS, FheOp(0, "rotate", (0,), {"step": 1}, level=5))
    hadd = op_cost(PARAMS, FheOp(0, "hadd", (0, 1), level=5))
    assert hmul.ks_modmuls > 0 and hmul.move_bytes > 0
    assert hadd.ks_modmuls == 0 and hadd.move_bytes == 0
    # a rotation moves the ciphertext itself on top of the KS traffic
    ks_only = op_cost(PARAMS, FheOp(0, "conjugate", (0,), level=5))
    assert rot.move_bytes == ks_only.move_bytes
    from repro.core.trace import ct_bytes, keyswitch_cost
    assert rot.move_bytes == \
        keyswitch_cost(PARAMS, 5).move_bytes + ct_bytes(PARAMS, 5)
    # movement is billed: zeroing move_bytes must strictly reduce cost
    import dataclasses
    no_move = dataclasses.replace(hmul, move_bytes=0)
    assert MEM.compute_seconds(hmul, PARAMS.n) > \
        MEM.compute_seconds(no_move, PARAMS.n)
    # ks rows are billed heavier than plain rows (weight > 1)
    as_plain = dataclasses.replace(
        hmul, modmuls=hmul.modmuls + hmul.ks_modmuls, ks_modmuls=0)
    assert MEM.compute_seconds(hmul, PARAMS.n) > \
        MEM.compute_seconds(as_plain, PARAMS.n)


# ---------------------------------------------------------------------------
# flat preset == analytic backend (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cache", [False, True])
def test_flat_pim_backend_matches_analytic(cache):
    sched = _schedule()
    arch = flat_arch_from_memory_model(MEM)
    pim = PimBackend(arch=arch)
    an = AnalyticBackend(MEM)
    for b in (1, 3, 8):
        kwargs = dict(metrics=MetricsRegistry(MEM.n_partitions),
                      workload="w")
        kc_a = KeyCache(256 * 2 ** 20, load_bw=MEM.load_bw) if cache \
            else None
        kc_p = KeyCache(256 * 2 ** 20, load_bw=MEM.load_bw) if cache \
            else None
        ta = an.execute(sched, _batch(b), key_cache=kc_a, **kwargs)
        tp = pim.execute(sched, _batch(b), key_cache=kc_p, **kwargs)
        assert ta > 0
        assert abs(ta - tp) / ta <= 0.01, (b, ta, tp)


def test_flat_pim_stage_times_match_schedule():
    """Per-stage, not just end-to-end: LOAD/ROWOP+NTT+XFER/STORE cycle
    buckets reproduce (load, compute, transfer) of stage_times."""
    sched = _schedule()
    prog = lower_schedule(sched, flat_arch_from_memory_model(MEM))
    b = 4
    times = sched.stage_times(b)
    for stg in sched.stages:
        load, comp, xfer = times[stg.idx]
        l, c, m, o = prog.stage_seconds(stg.idx)
        assert l == pytest.approx(load, rel=1e-9)
        assert b * (c + m) == pytest.approx(comp, rel=1e-9)
        assert b * o == pytest.approx(xfer, rel=1e-9)


def test_flat_pim_matches_analytic_reload_per_op():
    """The naive mapper's overflow regime (constants reloaded per
    input) must agree too."""
    trace = trace_program(make_helr_iter(), 2, const_names=HELR_CONSTS)
    opt, _ = optimize_trace(trace, PARAMS, CFG)
    mem = MemoryModel(n_partitions=4, partition_bytes=256 * 2 ** 10)
    sched = generate_naive_pipeline(opt, PARAMS, mem)
    assert sched.reload_per_op
    an = AnalyticBackend(mem)
    pim = PimBackend(arch=flat_arch_from_memory_model(mem))
    ta = an.execute(sched, _batch(4), key_cache=None,
                    metrics=MetricsRegistry(4), workload="w")
    tp = pim.execute(sched, _batch(4), key_cache=None,
                     metrics=MetricsRegistry(4), workload="w")
    assert abs(ta - tp) / ta <= 0.01


# ---------------------------------------------------------------------------
# layout invariants (fixed cases + hypothesis)
# ---------------------------------------------------------------------------

def _check_layout(sched, arch):
    plan = plan_layout(sched, arch)
    n = sched.params.n
    # every limb of every stage placed exactly once
    for stg in sched.stages:
        want = [(op_idx, poly, limb)
                for op_idx, poly, limb, _ in _stage_limbs(stg, n)]
        got = [(p.op_idx, p.poly, p.limb)
               for p in plan.stage(stg.idx).placements]
        assert sorted(got) == sorted(want), f"stage {stg.idx}"
        assert len(got) == len(set(got)), "limb placed twice"
    # per-subarray capacity never exceeded within any (round, generation)
    for rnd in sched.rounds:
        used = {}
        for stg in rnd:
            for p in plan.stage(stg.idx).placements:
                key = (p.generation, p.channel, p.bank, p.subarray)
                used[key] = used.get(key, 0) + p.nbytes
        assert all(v <= arch.subarray_bytes for v in used.values())
    return plan


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_layout_invariants_fixed(preset):
    sched = _schedule()
    _check_layout(sched, get_arch(preset))


def test_layout_spills_when_bank_overflows():
    """A stage bigger than its home bank spills limbs to neighbour
    banks — bytes the lowerer bills as inter-bank traffic on
    hierarchy archs."""
    # 8 x 1MiB banks, but the schedule homes every stage on banks 0/1
    arch = flat_arch_from_memory_model(
        MemoryModel(n_partitions=8, partition_bytes=2 ** 20))
    sched = _schedule(mem=MemoryModel(n_partitions=2,
                                      partition_bytes=2 ** 20))
    plan = _check_layout(sched, arch)
    assert any(sl.spill_bytes_bank or sl.spill_bytes_channel
               for sl in plan.stages)


def test_layout_generations_when_device_overflows():
    """A round bigger than the whole device streams in generations
    instead of dying (the naive reload-per-op regime)."""
    mem = MemoryModel(n_partitions=2, partition_bytes=64 * 2 ** 10)
    sched = _schedule(mem=mem)
    plan = _check_layout(sched, flat_arch_from_memory_model(mem))
    assert any(p.generation > 0
               for sl in plan.stages for p in sl.placements)


def test_generation_streaming_is_billed():
    """A round that overflows the device must cost MORE than the same
    round on an infinite device — the streaming regime isn't free."""
    from repro.pim import PimArch
    small = PimArch(name="tiny", n_channels=1, banks_per_channel=2,
                    subarrays_per_bank=4, mats_per_subarray=4,
                    mat_rows=512, mat_cols=128)       # 256 KiB device
    big = PimArch(name="roomy", n_channels=1, banks_per_channel=2,
                  subarrays_per_bank=64, mats_per_subarray=64,
                  mat_rows=512, mat_cols=2048)        # 2 GiB device
    sched = _schedule(mem=MemoryModel(n_partitions=2,
                                      partition_bytes=2 ** 20))
    plan = plan_layout(sched, small)
    assert any(sl.streamed_bytes for sl in plan.stages)
    cost_small = lower_schedule(sched, small).total_cycles()
    # normalize away the compute-rate difference: compare at equal lanes
    # by only asserting the streamed XFERs exist and carry cycles
    stream = [i for i in lower_schedule(sched, small).instrs
              if i.opcode == "XFER" and i.scope == "load"]
    assert stream and all(i.cycles > 0 for i in stream)
    assert not any(i.opcode == "XFER" and i.scope == "load"
                   for i in lower_schedule(sched, big).instrs)
    assert cost_small > 0


def test_serve_fhe_rejects_conflicting_presets(capsys):
    """--backend pim with a mem-profile naming a different hardware
    point must fail loudly instead of silently simulating the wrong
    arch."""
    import repro.launch.serve_fhe as sf
    import sys
    argv = ["serve_fhe", "--smoke", "--backend", "pim",
            "--pim-preset", "fhemem", "--mem-profile", "flat"]
    old = sys.argv
    try:
        sys.argv = argv
        with pytest.raises(SystemExit) as ei:
            sf.main()
        assert ei.value.code == 2
    finally:
        sys.argv = old
    assert "--pim-preset" in capsys.readouterr().err


def test_lowering_deterministic_fixed():
    sched = _schedule()
    for preset in PRESETS.values():
        a = lower_schedule(sched, preset)
        b = lower_schedule(sched, preset)
        assert a.instrs == b.instrs
        assert a.total_cycles() == b.total_cycles()


@settings(max_examples=20, deadline=None)
@given(dim=st.integers(2, 10), n_partitions=st.integers(1, 8),
       budget_kib=st.sampled_from([64, 256, 1024, 8192]),
       preset=st.sampled_from(sorted(PRESETS)))
def test_layout_and_lowering_properties(dim, n_partitions, budget_kib,
                                        preset):
    """For ANY (workload size, partition count, capacity, arch): every
    limb placed exactly once, per-subarray capacity holds, and lowering
    then summing instruction cycles is deterministic."""
    mem = MemoryModel(n_partitions=n_partitions,
                      partition_bytes=budget_kib * 1024)
    sched = _schedule(fn=make_matvec(dim), n_in=1,
                      consts=matvec_consts(dim), mem=mem)
    arch = get_arch(preset)
    _check_layout(sched, arch)
    c1 = lower_schedule(sched, arch).total_cycles()
    c2 = lower_schedule(sched, arch).total_cycles()
    assert c1 == c2 and c1 > 0


@settings(max_examples=10, deadline=None)
@given(n_partitions=st.integers(1, 6),
       budget_kib=st.sampled_from([256, 1024, 4096]),
       batch=st.integers(1, 9))
def test_flat_equivalence_property(n_partitions, budget_kib, batch):
    """The ≤1% analytic agreement holds across mapper settings, not
    just the smoke configuration."""
    mem = MemoryModel(n_partitions=n_partitions,
                      partition_bytes=budget_kib * 1024)
    sched = _schedule(mem=mem)
    an = AnalyticBackend(mem)
    pim = PimBackend(arch=flat_arch_from_memory_model(mem))
    ta = an.execute(sched, _batch(batch), key_cache=None,
                    metrics=MetricsRegistry(n_partitions), workload="w")
    tp = pim.execute(sched, _batch(batch), key_cache=None,
                     metrics=MetricsRegistry(n_partitions), workload="w")
    assert abs(ta - tp) / ta <= 0.01


# ---------------------------------------------------------------------------
# instruction stream / program structure
# ---------------------------------------------------------------------------

def test_program_covers_all_stages_and_opcodes():
    sched = _schedule()
    prog = lower_schedule(sched, get_arch("fhemem"))
    stages_seen = {i.stage for i in prog.instrs}
    assert stages_seen == {st.idx for st in sched.stages}
    opcodes = {i.opcode for i in prog.instrs}
    assert {"ROWOP", "NTT", "XFER"} <= opcodes
    assert all(i.cycles >= 0 for i in prog.instrs)
    js = prog.to_jsonable()
    assert js["arch"] == "fhemem"
    assert js["summary"]["n_instrs"] == len(prog.instrs)


def test_hierarchy_bills_movement_scopes():
    """On the fhemem hierarchy, rotations ride the inter-bank
    permutation network and NTTs pay inter-mat shuffles — channels a
    degenerate arch never emits."""
    sched = _schedule()
    fhemem = lower_schedule(sched, get_arch("fhemem"))
    scopes = {i.scope for i in fhemem.instrs if i.opcode == "XFER"}
    assert "bank" in scopes    # rotation permutation network
    assert "intra" in scopes   # ModUp/ModDown distribution + shuffles
    # arch cost model: same bytes are cheaper intra-bank than across
    a = get_arch("fhemem")
    assert a.xfer_seconds(2 ** 20, "intra") < \
        a.xfer_seconds(2 ** 20, "channel")


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------

def test_resolve_backend_pim():
    b = resolve_backend("pim", PARAMS, MemoryModel())
    assert isinstance(b, PimBackend)
    assert b.arch is FLAT
    b2 = resolve_backend("pim", PARAMS, memory_model("fhemem"))
    assert b2.arch.name == "fhemem"
    b3 = resolve_backend("pim", PARAMS, MEM)
    assert b3.arch.degenerate


def test_pim_backend_serves_every_workload():
    """serve_fhe --backend pim end-to-end, in-process: every registered
    workload admits, batches, executes, completes."""
    from repro.launch.serve_fhe import WORKLOADS, build_executor
    mem = memory_model("fhemem")
    ex = build_executor(PARAMS, mem, backend_name="pim", max_batch=4,
                        max_wait_s=1e-3, cache_bytes=256 * 2 ** 20,
                        start_level=7)
    assert set(ex.workloads) == set(WORKLOADS)
    from repro.runtime.queue import Request, RequestStatus
    arrivals = []
    for i, name in enumerate(ex.workloads):
        for j in range(3):
            arrivals.append(
                Request(ex.queue.next_request_id(), f"t{j}", name,
                        arrival_s=1e-4 * (3 * i + j), slots_needed=4))
    m = ex.serve(arrivals)
    done = m.count("requests_completed")
    assert done == len(arrivals)
    assert m.elapsed_s > 0
    for r in arrivals:
        assert r.status == RequestStatus.COMPLETED
