"""Hypothesis property tests on system-level invariants."""
import numpy as np
import pytest
import jax.numpy as jnp

from _hyp import given, settings, st  # noqa: E402  (skips per-test)

from repro.compiler import PassConfig, optimize_trace, reference_eval
from repro.compiler.passes import PASS_ORDER
from repro.core import rns
from repro.core.params import find_ntt_primes, test_params as make_test_params
from repro.core.trace import FheOp, FheTrace, infer_levels
from repro.sharding.rules import default_rules, serving_rules, spec_for_shape


# ---------------------------------------------------------------------------
# sharding rules invariants
# ---------------------------------------------------------------------------

def _mesh(shape=(4, 4)):
    from repro.compat import abstract_mesh
    return abstract_mesh(shape, ("data", "model"))


@settings(max_examples=60, deadline=None)
@given(dims=st.lists(st.sampled_from([1, 2, 3, 8, 10, 16, 56, 128, 256]),
                     min_size=1, max_size=4),
       names=st.lists(st.sampled_from(["batch", "heads", "kv_heads", "mlp",
                                       "embed", "vocab", None]),
                      min_size=1, max_size=4))
def test_spec_resolution_always_valid(dims, names):
    """For ANY shape/logical combination: no mesh axis used twice, and
    every sharded dim is divisible by its axis product."""
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    mesh = _mesh()
    sizes = dict(mesh.shape)
    spec = spec_for_shape(mesh, names, dims)
    used = []
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        used += list(axes)
        total = int(np.prod([sizes[a] for a in axes]))
        assert dim % total == 0, (dims, names, spec)
    assert len(used) == len(set(used)), f"axis reused: {spec}"


def test_serving_rules_no_data_on_cache_seq_conflict():
    mesh = _mesh()
    r = serving_rules()
    spec = spec_for_shape(mesh, ("layers", "batch", "kv_heads", "seq",
                                 "head_dim"), (4, 8, 1, 4096, 128), r)
    assert spec[3] == "model", "serving rules must shard cache seq on model"
    d = default_rules()
    spec_d = spec_for_shape(mesh, ("layers", "batch", "kv_heads", "seq",
                                   "head_dim"), (4, 8, 1, 4096, 128), d)
    assert spec_d[3] is None


# ---------------------------------------------------------------------------
# RNS / CRT invariants
# ---------------------------------------------------------------------------

PRIMES = [m.value for m in find_ntt_primes(28, 8, 4)]


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_crt_lift_roundtrip_property(seed):
    """crt_lift(residues(x)) == x for |x| < Q/2."""
    rng = np.random.default_rng(seed)
    big_q = int(np.prod([int(p) for p in PRIMES], dtype=object))
    xs = rng.integers(-2**60, 2**60, size=16)
    limbs = np.stack([(xs % p).astype(np.uint64) for p in PRIMES])
    lifted = rns.crt_lift_centered(limbs, PRIMES)
    assert all(int(a) == int(b) for a, b in zip(lifted, xs))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_bconv_identity_basis_property(seed):
    """BConv from a basis to itself is the identity (qhat*qhat^-1 = 1)."""
    rng = np.random.default_rng(seed)
    tabs = rns.make_bconv_tables(PRIMES, PRIMES)
    v = np.stack([rng.integers(0, p, size=32, dtype=np.uint64)
                  for p in PRIMES])
    out = np.asarray(rns.bconv(jnp.asarray(v), tabs))
    big_q = int(np.prod([int(p) for p in PRIMES], dtype=object))
    # fast conversion: out == v + k*Q (mod p_i) with 0 <= k < n_src
    x = rns.crt_lift_centered(v, PRIMES)
    for i, p in enumerate(PRIMES):
        diff = (out[i].astype(object) - (x % p)) % p
        allowed = {(k * big_q) % p for k in range(len(PRIMES) + 1)}
        assert set(int(d) for d in diff) <= allowed


# ---------------------------------------------------------------------------
# compiler invariants on randomly generated well-formed traces
# ---------------------------------------------------------------------------

CKKS_PARAMS = make_test_params(log_n=8, n_levels=6, dnum=2, log_scale=26)
START_LEVEL = 5
N_CONSTS = 3
CONST_AMP = 0.25
PASS_NAMES = tuple(p.name for p in PASS_ORDER)

# an instruction is (kind, a, b, step, cidx): a/b index the value pool
# modulo its current size; "mul_rescale"/"pmul_rescale" emit a lazy mul
# followed by its explicit rescale (the only scale-sound way a raw
# rescale op appears in a trace — identical prime path to the eager op)
TRACE_KINDS = ("hadd", "hsub", "hmul", "pmul", "padd", "rotate",
               "conjugate", "mul_rescale", "pmul_rescale")


def build_trace(n_inputs, instrs, start_level=START_LEVEL):
    """Deterministically interpret an instruction spec into a
    well-formed FheTrace: level budget respected (ops that would drop
    below level 1 are skipped), slot magnitudes bounded so CKKS decrypt
    stays inside the first-modulus headroom."""
    ops = []

    def add(kind, args=(), **meta):
        op = FheOp(len(ops), kind, tuple(args), meta)
        ops.append(op)
        return op.idx

    inputs = [add("input", slot=i) for i in range(n_inputs)]
    # pool entries: (op idx, level, magnitude bound)
    pool = [(i, start_level, 1.0) for i in inputs]
    for kind, a, b, step, cidx in instrs:
        ia, la, ma = pool[a % len(pool)]
        ib, lb, mb = pool[b % len(pool)]
        cname = f"c{cidx % N_CONSTS}"
        cmag = CONST_AMP * 4.0
        if kind in ("hadd", "hsub"):
            nxt = (add(kind, (ia, ib)), min(la, lb), ma + mb)
        elif kind == "hmul":
            if min(la, lb) - 1 < 1:
                continue
            nxt = (add("hmul", (ia, ib)), min(la, lb) - 1, ma * mb)
        elif kind == "mul_rescale":
            if min(la, lb) - 1 < 1:
                continue
            h = add("hmul", (ia, ib), lazy=True)
            nxt = (add("rescale", (h,)), min(la, lb) - 1, ma * mb)
        elif kind == "pmul":
            if la - 1 < 1:
                continue
            nxt = (add("pmul", (ia,), const=cname), la - 1, ma * cmag)
        elif kind == "pmul_rescale":
            if la - 1 < 1:
                continue
            h = add("pmul", (ia,), const=cname, lazy=True)
            nxt = (add("rescale", (h,)), la - 1, ma * cmag)
        elif kind == "padd":
            nxt = (add("padd", (ia,), const=cname), la, ma + cmag)
        elif kind == "rotate":
            nxt = (add("rotate", (ia,), step=step), la, ma)
        elif kind == "conjugate":
            nxt = (add("conjugate", (ia,)), la, ma)
        else:
            raise ValueError(kind)
        if nxt[2] > 4.0:          # q0 headroom: keep |values| small
            continue
        pool.append(nxt)
    outputs = [pool[-1][0]]
    return FheTrace(ops=ops, inputs=inputs, outputs=outputs, consts=[])


def trace_io(trace, seed=0):
    slots = CKKS_PARAMS.slots
    rng = np.random.default_rng(seed)

    def vec():
        return 0.3 * (rng.normal(size=slots) + 1j * rng.normal(size=slots))
    ins = [vec() for _ in trace.inputs]
    cs = {f"c{i}": CONST_AMP * rng.normal(size=slots)
          for i in range(N_CONSTS)}
    return ins, cs


def check_pass_subset(trace, subset, seed=0):
    """The two tentpole invariants for one (trace, pass subset):
    semantics preserved on the plaintext oracle, and no applied
    non-bootstrap pass ever increased the OpCost-derived seconds."""
    infer_levels(trace, START_LEVEL)
    cfg = PassConfig(start_level=START_LEVEL,
                     bsgs_min_terms=4).with_passes(subset)
    opt, report = optimize_trace(trace, CKKS_PARAMS, cfg)
    ins, cs = trace_io(trace, seed)
    for va, vb in zip(reference_eval(trace, ins, cs),
                      reference_eval(opt, ins, cs)):
        np.testing.assert_allclose(va, vb, atol=1e-9)
    for s in report.passes:
        if s.name == "bootstrap" or not s.applied:
            continue
        if s.seconds_before is not None and s.seconds_after is not None:
            assert s.seconds_after <= s.seconds_before * (1 + 1e-9), \
                f"pass {s.name} violated never-more-expensive"
    return opt, report


@st.composite
def trace_specs(draw):
    n_inputs = draw(st.integers(1, 2))
    n_ops = draw(st.integers(3, 14))
    instrs = tuple(
        (draw(st.sampled_from(TRACE_KINDS)),
         draw(st.integers(0, 10 ** 6)), draw(st.integers(0, 10 ** 6)),
         draw(st.integers(-8, 8)), draw(st.integers(0, N_CONSTS - 1)))
        for _ in range(n_ops))
    return n_inputs, instrs


@st.composite
def pass_subsets(draw):
    return tuple(n for n in PASS_NAMES
                 if draw(st.booleans()))


@settings(max_examples=40, deadline=None)
@given(spec=trace_specs(), subset=pass_subsets(),
       seed=st.integers(0, 2 ** 31 - 1))
def test_optimize_trace_preserves_semantics_any_pass_subset(spec, subset,
                                                            seed):
    """For ANY well-formed random trace and ANY PassConfig subset:
    `optimize_trace` is semantics-preserving on the plaintext oracle and
    never violates the never-more-expensive OpCost guard."""
    trace = build_trace(*spec)
    check_pass_subset(trace, subset, seed)


@pytest.fixture(scope="module")
def property_engine():
    from repro.compiler.engine import CkksEngine
    return CkksEngine(CKKS_PARAMS, seed=7)


@settings(max_examples=6, deadline=None)
@given(spec=trace_specs(), subset=pass_subsets())
def test_optimize_trace_decrypt_equality_random(spec, subset,
                                                property_engine):
    """Random trace + random pass subset: the optimized trace decodes
    to the original's values through the REAL CKKS stack (the shared
    engine), within the parameter set's tolerance."""
    trace = build_trace(*spec)
    infer_levels(trace, START_LEVEL)
    cfg = PassConfig(start_level=START_LEVEL,
                     bsgs_min_terms=4).with_passes(subset)
    opt, _ = optimize_trace(trace, CKKS_PARAMS, cfg)
    ins, cs = trace_io(trace, 1234)
    a = property_engine.run(trace, ins, cs, start_level=START_LEVEL)
    b = property_engine.run(opt, ins, cs, start_level=START_LEVEL)
    tol = property_engine.tolerance
    for va, vb in zip(a, b):
        np.testing.assert_allclose(va, vb, atol=2 * tol)


# deterministic corner specs so the builder + invariants run even where
# hypothesis is unavailable (the strategies above then skip)
_FIXED_SPECS = [
    (1, (("pmul", 0, 0, 0, 0), ("rotate", 1, 0, 3, 0),
         ("hadd", 1, 2, 0, 0), ("mul_rescale", 3, 1, 0, 1),
         ("padd", 4, 0, 0, 2))),
    (2, (("hmul", 0, 1, 0, 0), ("pmul_rescale", 2, 0, 0, 1),
         ("hsub", 3, 0, 0, 0), ("rotate", 4, 0, -5, 0),
         ("conjugate", 5, 0, 0, 0), ("hadd", 6, 2, 0, 0))),
    (2, (("rotate", 0, 0, 1, 0), ("rotate", 2, 0, 1, 0),
         ("pmul", 3, 0, 0, 0), ("pmul", 2, 0, 0, 1),
         ("hadd", 4, 5, 0, 0), ("hadd", 6, 1, 0, 2),
         ("mul_rescale", 7, 7, 0, 0))),
]


@pytest.mark.parametrize("spec_i", range(len(_FIXED_SPECS)))
@pytest.mark.parametrize("subset", [(), ("dce", "cse"),
                                    ("fold", "rotation", "lazy_rescale"),
                                    PASS_NAMES])
def test_optimize_trace_fixed_specs(spec_i, subset):
    trace = build_trace(*_FIXED_SPECS[spec_i])
    assert len(trace.compute_ops()) >= 3
    check_pass_subset(trace, subset, seed=spec_i)


# ---------------------------------------------------------------------------
# data pipeline invariant
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10000), batch=st.sampled_from([1, 2, 4]),
       seq=st.sampled_from([8, 16, 32]))
def test_dataset_labels_are_shifted_tokens(step, batch, seq):
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLMDataset
    cfg = get_config("granite-3-8b", smoke=True)
    ds = SyntheticLMDataset(cfg, batch=batch, seq=seq)
    b = ds.batch_at(step)
    assert b["tokens"].shape == (batch, seq)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    assert (b["tokens"] >= 0).all() and (b["tokens"] < cfg.vocab).all()
