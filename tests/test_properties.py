"""Hypothesis property tests on system-level invariants."""
import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import rns
from repro.core.params import find_ntt_primes
from repro.sharding.rules import default_rules, serving_rules, spec_for_shape


# ---------------------------------------------------------------------------
# sharding rules invariants
# ---------------------------------------------------------------------------

def _mesh(shape=(4, 4)):
    import jax
    return jax.sharding.AbstractMesh(shape, ("data", "model"))


@settings(max_examples=60, deadline=None)
@given(dims=st.lists(st.sampled_from([1, 2, 3, 8, 10, 16, 56, 128, 256]),
                     min_size=1, max_size=4),
       names=st.lists(st.sampled_from(["batch", "heads", "kv_heads", "mlp",
                                       "embed", "vocab", None]),
                      min_size=1, max_size=4))
def test_spec_resolution_always_valid(dims, names):
    """For ANY shape/logical combination: no mesh axis used twice, and
    every sharded dim is divisible by its axis product."""
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    mesh = _mesh()
    sizes = dict(mesh.shape)
    spec = spec_for_shape(mesh, names, dims)
    used = []
    for dim, entry in zip(dims, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        used += list(axes)
        total = int(np.prod([sizes[a] for a in axes]))
        assert dim % total == 0, (dims, names, spec)
    assert len(used) == len(set(used)), f"axis reused: {spec}"


def test_serving_rules_no_data_on_cache_seq_conflict():
    mesh = _mesh()
    r = serving_rules()
    spec = spec_for_shape(mesh, ("layers", "batch", "kv_heads", "seq",
                                 "head_dim"), (4, 8, 1, 4096, 128), r)
    assert spec[3] == "model", "serving rules must shard cache seq on model"
    d = default_rules()
    spec_d = spec_for_shape(mesh, ("layers", "batch", "kv_heads", "seq",
                                   "head_dim"), (4, 8, 1, 4096, 128), d)
    assert spec_d[3] is None


# ---------------------------------------------------------------------------
# RNS / CRT invariants
# ---------------------------------------------------------------------------

PRIMES = [m.value for m in find_ntt_primes(28, 8, 4)]


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_crt_lift_roundtrip_property(seed):
    """crt_lift(residues(x)) == x for |x| < Q/2."""
    rng = np.random.default_rng(seed)
    big_q = int(np.prod([int(p) for p in PRIMES], dtype=object))
    xs = rng.integers(-2**60, 2**60, size=16)
    limbs = np.stack([(xs % p).astype(np.uint64) for p in PRIMES])
    lifted = rns.crt_lift_centered(limbs, PRIMES)
    assert all(int(a) == int(b) for a, b in zip(lifted, xs))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_bconv_identity_basis_property(seed):
    """BConv from a basis to itself is the identity (qhat*qhat^-1 = 1)."""
    rng = np.random.default_rng(seed)
    tabs = rns.make_bconv_tables(PRIMES, PRIMES)
    v = np.stack([rng.integers(0, p, size=32, dtype=np.uint64)
                  for p in PRIMES])
    out = np.asarray(rns.bconv(jnp.asarray(v), tabs))
    big_q = int(np.prod([int(p) for p in PRIMES], dtype=object))
    # fast conversion: out == v + k*Q (mod p_i) with 0 <= k < n_src
    x = rns.crt_lift_centered(v, PRIMES)
    for i, p in enumerate(PRIMES):
        diff = (out[i].astype(object) - (x % p)) % p
        allowed = {(k * big_q) % p for k in range(len(PRIMES) + 1)}
        assert set(int(d) for d in diff) <= allowed


# ---------------------------------------------------------------------------
# data pipeline invariant
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10000), batch=st.sampled_from([1, 2, 4]),
       seq=st.sampled_from([8, 16, 32]))
def test_dataset_labels_are_shifted_tokens(step, batch, seq):
    from repro.configs import get_config
    from repro.data.pipeline import SyntheticLMDataset
    cfg = get_config("granite-3-8b", smoke=True)
    ds = SyntheticLMDataset(cfg, batch=batch, seq=seq)
    b = ds.batch_at(step)
    assert b["tokens"].shape == (batch, seq)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    assert (b["tokens"] >= 0).all() and (b["tokens"] < cfg.vocab).all()
