"""Unit + property tests for modular arithmetic (all four reduction paths)."""
import numpy as np
import pytest
import jax.numpy as jnp

from _hyp import given, settings, st  # noqa: E402  (skips per-test)

from repro.core import modarith as ma
from repro.core.params import (find_ntt_primes, generic_ntt_primes, is_prime,
                               solinas_candidates)

# word32 moduli straight from the repo's own NTT-prime search — prime by
# construction (the search Miller-Rabin-filters every candidate), so the
# old hand-picked list and its "non-prime test modulus" runtime skip are
# gone for good.
_SOLINAS_MOD = next(m for m in find_ntt_primes(30, 17, 4) if m.is_solinas)
Q_SOLINAS = _SOLINAS_MOD.value               # 2^b - 2^s + 1 form
_SOL_B, _SOL_S = _SOLINAS_MOD.solinas
Q_GENERIC = generic_ntt_primes(30, 1 << 24, 1)[0]
Q_WIDE = find_ntt_primes(31, 12, 1)[0].value   # widest word32 prime


def _rand(rng, q, n=4096):
    return rng.integers(0, q, size=n, dtype=np.uint64)


@pytest.mark.parametrize("q", [Q_SOLINAS, Q_GENERIC, Q_WIDE])
def test_mulmod_paths_agree(rng, q):
    assert is_prime(q)          # by construction; never a skip
    a, b = _rand(rng, q), _rand(rng, q)
    ref = (a.astype(object) * b.astype(object)) % q
    aj, bj, qj = jnp.asarray(a), jnp.asarray(b), jnp.uint64(q)
    assert (np.asarray(ma.mulmod(aj, bj, qj)).astype(object) == ref).all()
    mu = jnp.uint64(ma.barrett_mu(q))
    assert (np.asarray(ma.mulmod_barrett(aj, bj, qj, mu)).astype(object) == ref).all()
    qi = jnp.uint64(ma.mont_qinv_neg(q))
    r2 = jnp.uint64(ma.mont_r2(q))
    am = ma.to_mont(aj, qj, qi, r2)
    assert (np.asarray(ma.mont_mul(am, bj, qj, qi)).astype(object) == ref).all()


def test_solinas_reduction(rng):
    q = Q_SOLINAS
    a, b = _rand(rng, q), _rand(rng, q)
    ref = (a.astype(object) * b.astype(object)) % q
    got = ma.mulmod_solinas(jnp.asarray(a), jnp.asarray(b), jnp.uint64(q),
                            _SOL_B, _SOL_S)
    assert (np.asarray(got).astype(object) == ref).all()


def test_addsub_neg(rng):
    q = Q_GENERIC
    a, b = _rand(rng, q), _rand(rng, q)
    qj = jnp.uint64(q)
    assert (np.asarray(ma.addmod(jnp.asarray(a), jnp.asarray(b), qj))
            == (a.astype(object) + b.astype(object)) % q).all()
    assert (np.asarray(ma.submod(jnp.asarray(a), jnp.asarray(b), qj))
            == (a.astype(object) - b.astype(object)) % q).all()
    assert (np.asarray(ma.negmod(jnp.asarray(a), qj))
            == (-a.astype(object)) % q).all()


@settings(max_examples=200, deadline=None)
@given(a=st.integers(0, 2**63 - 1), b=st.integers(0, 2**63 - 1))
def test_mulhi64_property(a, b):
    got = int(np.asarray(ma.mulhi64(jnp.uint64(a), jnp.uint64(b))))
    assert got == (a * b) >> 64


@settings(max_examples=100, deadline=None)
@given(a=st.integers(0, Q_SOLINAS - 1), b=st.integers(0, Q_SOLINAS - 1),
       c=st.integers(0, Q_SOLINAS - 1))
def test_ring_axioms_property(a, b, c):
    """Field axioms mod q via the vectorized ops (distributivity etc.)."""
    q = jnp.uint64(Q_SOLINAS)
    aj, bj, cj = jnp.uint64(a), jnp.uint64(b), jnp.uint64(c)
    left = ma.mulmod(aj, ma.addmod(bj, cj, q), q)
    right = ma.addmod(ma.mulmod(aj, bj, q), ma.mulmod(aj, cj, q), q)
    assert int(left) == int(right)
    assert int(ma.mulmod(aj, bj, q)) == int(ma.mulmod(bj, aj, q))


def test_prime_search_properties():
    for log_n in (8, 10, 12):
        mods = find_ntt_primes(30, log_n, 4)
        assert len(set(m.value for m in mods)) == 4
        for m in mods:
            assert is_prime(m.value)
            assert (m.value - 1) % (1 << (log_n + 1)) == 0
            if m.solinas:
                b, s = m.solinas
                assert m.value == (1 << b) - (1 << s) + 1


def test_solinas_candidates_ntt_friendly():
    for p, b, s in solinas_candidates(31, 13):
        assert is_prime(p) and (p - 1) % (1 << 13) == 0
