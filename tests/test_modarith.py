"""Unit + property tests for modular arithmetic (all four reduction paths)."""
import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import modarith as ma
from repro.core.params import find_ntt_primes, is_prime, solinas_candidates

Q_SOLINAS = 2**30 - 2**18 + 1    # prime, NTT-friendly up to 2N=2^18
Q_GENERIC = 998244353            # 119*2^23+1


def _rand(rng, q, n=4096):
    return rng.integers(0, q, size=n, dtype=np.uint64)


@pytest.mark.parametrize("q", [Q_SOLINAS, Q_GENERIC, (1 << 31) - 2**27 + 1])
def test_mulmod_paths_agree(rng, q):
    if not is_prime(q):
        pytest.skip("non-prime test modulus")
    a, b = _rand(rng, q), _rand(rng, q)
    ref = (a.astype(object) * b.astype(object)) % q
    aj, bj, qj = jnp.asarray(a), jnp.asarray(b), jnp.uint64(q)
    assert (np.asarray(ma.mulmod(aj, bj, qj)).astype(object) == ref).all()
    mu = jnp.uint64(ma.barrett_mu(q))
    assert (np.asarray(ma.mulmod_barrett(aj, bj, qj, mu)).astype(object) == ref).all()
    qi = jnp.uint64(ma.mont_qinv_neg(q))
    r2 = jnp.uint64(ma.mont_r2(q))
    am = ma.to_mont(aj, qj, qi, r2)
    assert (np.asarray(ma.mont_mul(am, bj, qj, qi)).astype(object) == ref).all()


def test_solinas_reduction(rng):
    q = Q_SOLINAS
    a, b = _rand(rng, q), _rand(rng, q)
    ref = (a.astype(object) * b.astype(object)) % q
    got = ma.mulmod_solinas(jnp.asarray(a), jnp.asarray(b), jnp.uint64(q), 30, 18)
    assert (np.asarray(got).astype(object) == ref).all()


def test_addsub_neg(rng):
    q = Q_GENERIC
    a, b = _rand(rng, q), _rand(rng, q)
    qj = jnp.uint64(q)
    assert (np.asarray(ma.addmod(jnp.asarray(a), jnp.asarray(b), qj))
            == (a.astype(object) + b.astype(object)) % q).all()
    assert (np.asarray(ma.submod(jnp.asarray(a), jnp.asarray(b), qj))
            == (a.astype(object) - b.astype(object)) % q).all()
    assert (np.asarray(ma.negmod(jnp.asarray(a), qj))
            == (-a.astype(object)) % q).all()


@settings(max_examples=200, deadline=None)
@given(a=st.integers(0, 2**63 - 1), b=st.integers(0, 2**63 - 1))
def test_mulhi64_property(a, b):
    got = int(np.asarray(ma.mulhi64(jnp.uint64(a), jnp.uint64(b))))
    assert got == (a * b) >> 64


@settings(max_examples=100, deadline=None)
@given(a=st.integers(0, Q_SOLINAS - 1), b=st.integers(0, Q_SOLINAS - 1),
       c=st.integers(0, Q_SOLINAS - 1))
def test_ring_axioms_property(a, b, c):
    """Field axioms mod q via the vectorized ops (distributivity etc.)."""
    q = jnp.uint64(Q_SOLINAS)
    aj, bj, cj = jnp.uint64(a), jnp.uint64(b), jnp.uint64(c)
    left = ma.mulmod(aj, ma.addmod(bj, cj, q), q)
    right = ma.addmod(ma.mulmod(aj, bj, q), ma.mulmod(aj, cj, q), q)
    assert int(left) == int(right)
    assert int(ma.mulmod(aj, bj, q)) == int(ma.mulmod(bj, aj, q))


def test_prime_search_properties():
    for log_n in (8, 10, 12):
        mods = find_ntt_primes(30, log_n, 4)
        assert len(set(m.value for m in mods)) == 4
        for m in mods:
            assert is_prime(m.value)
            assert (m.value - 1) % (1 << (log_n + 1)) == 0
            if m.solinas:
                b, s = m.solinas
                assert m.value == (1 << b) - (1 << s) + 1


def test_solinas_candidates_ntt_friendly():
    for p, b, s in solinas_candidates(31, 13):
        assert is_prime(p) and (p - 1) % (1 << 13) == 0
