"""Golden regression tests for schedule / compile-cache-key stability.

The serving runtime's whole caching story hangs on structural
fingerprints: two captures of the same program must collide in the
CompileCache, and an innocent-looking change to trace capture, level
inference, a compiler pass, or the mapper silently invalidates every
cached schedule (and recompiles on every request) — or worse, silently
changes what gets served. These tests snapshot, for every workload in
the serving registry under the smoke parameter set:

* the captured trace's fingerprint (pre-optimization),
* the optimized trace's fingerprint under the default PassConfig,
* the full CompileCache key (params/mem/mapper/pass-config components),
* the mapped schedule's shape (stages, rounds, per-stage op counts).

A second golden (tests/golden/pim_streams.json) snapshots the FULL
bank-level PIM instruction stream (repro.pim.lower) of two fixed
workloads on the ``fhemem`` arch: any drift in the ISA, the layout
mapper, the cycle model, or the OpCost channels it consumes fails
loudly here instead of silently rescaling every fig19 number.

If any of these drift, the diff in this file's golden JSON is the
review artifact. Intentional changes regenerate it:

    REGEN_GOLDENS=1 PYTHONPATH=src python -m pytest \
        tests/test_golden_schedules.py
"""
import json
import os

import pytest

from repro.compiler import PassConfig
from repro.core.params import test_params as make_test_params
from repro.core.pipeline import MemoryModel, generate_load_save_pipeline
from repro.core.trace import trace_program
from repro.runtime.compile_cache import (CompileCache, _mem_key, _params_key,
                                         trace_fingerprint)
from repro.runtime.workloads import (HELR_CONSTS, LOLA_CONSTS, lola_infer,
                                     make_helr_iter, make_matvec,
                                     make_poly_eval, matvec_consts,
                                     poly_consts)

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "schedules.json")

# the serve_fhe --smoke setting: any drift here is a serving-visible
# change by definition
PARAMS = make_test_params(log_n=10, n_levels=8, dnum=2)
MEM = MemoryModel(n_partitions=4, partition_bytes=8 * 2 ** 20)
START = 7
CFG = PassConfig(start_level=START)

WORKLOADS = {
    "helr": (make_helr_iter(), 2, HELR_CONSTS),
    "lola": (lola_infer, 1, LOLA_CONSTS),
    "matvec16": (make_matvec(16), 1, matvec_consts(16)),
    "poly12": (make_poly_eval(12), 1, poly_consts(12)),
}


def snapshot() -> dict:
    from repro.compiler import optimize_trace
    out = {}
    for name, (fn, n_in, consts) in WORKLOADS.items():
        trace = trace_program(fn, n_in, const_names=consts)
        opt, _ = optimize_trace(trace, PARAMS, CFG)
        sched = generate_load_save_pipeline(opt, PARAMS, MEM)
        out[name] = {
            "trace_fingerprint": trace_fingerprint(trace),
            "optimized_fingerprint": trace_fingerprint(opt),
            "cache_key": {
                "params": repr(_params_key(PARAMS)),
                "mem": repr(_mem_key(MEM)),
                "mapper": generate_load_save_pipeline.__name__,
                "pass_config": repr(CFG.key()),
            },
            "n_ops_captured": len(trace.ops),
            "n_ops_optimized": len(opt.ops),
            "schedule": {
                "n_stages": len(sched.stages),
                "n_rounds": len(sched.rounds),
                "stage_op_counts": [len(st.ops) for st in sched.stages],
                "stage_partitions": [st.partition for st in sched.stages],
            },
        }
    return out


def test_golden_schedules_and_cache_keys():
    got = snapshot()
    if os.environ.get("REGEN_GOLDENS"):
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(got, f, indent=2, sort_keys=True)
    assert os.path.exists(GOLDEN_PATH), \
        "golden file missing — run with REGEN_GOLDENS=1 to create it"
    want = json.load(open(GOLDEN_PATH))
    assert sorted(got) == sorted(want), "workload registry changed"
    for name in want:
        for field in want[name]:
            assert got[name][field] == want[name][field], (
                f"{name}.{field} drifted — if intentional, regenerate "
                f"with REGEN_GOLDENS=1 and review the golden diff")


PIM_GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                               "pim_streams.json")
# two fixed workloads: the rotation-tree deep one and the BSGS matvec
PIM_WORKLOADS = ("helr", "matvec16")


def snapshot_pim() -> dict:
    from repro.compiler import optimize_trace
    from repro.pim import get_arch, lower_schedule
    arch = get_arch("fhemem")
    out = {}
    for name in PIM_WORKLOADS:
        fn, n_in, consts = WORKLOADS[name]
        trace = trace_program(fn, n_in, const_names=consts)
        opt, _ = optimize_trace(trace, PARAMS, CFG)
        sched = generate_load_save_pipeline(opt, PARAMS, MEM)
        out[name] = lower_schedule(sched, arch).to_jsonable()
    return out


def test_golden_pim_instruction_streams():
    got = snapshot_pim()
    if os.environ.get("REGEN_GOLDENS"):
        os.makedirs(os.path.dirname(PIM_GOLDEN_PATH), exist_ok=True)
        with open(PIM_GOLDEN_PATH, "w") as f:
            json.dump(got, f, indent=2, sort_keys=True)
    assert os.path.exists(PIM_GOLDEN_PATH), \
        "golden file missing — run with REGEN_GOLDENS=1 to create it"
    want = json.load(open(PIM_GOLDEN_PATH))
    assert sorted(got) == sorted(want), "pim golden workload set changed"
    for name in want:
        for field in ("arch", "freq_hz", "n_stages", "summary"):
            assert got[name][field] == want[name][field], (
                f"{name}.{field} drifted — if intentional, regenerate "
                f"with REGEN_GOLDENS=1 and review the golden diff")
        assert got[name]["instrs"] == want[name]["instrs"], (
            f"{name} instruction stream drifted — if intentional, "
            f"regenerate with REGEN_GOLDENS=1 and review the diff")


def test_fingerprints_stable_across_recapture():
    """Same program text captured twice hashes identically (the property
    the cache-sharing story depends on)."""
    for name, (fn, n_in, consts) in WORKLOADS.items():
        a = trace_program(fn, n_in, const_names=consts)
        b = trace_program(fn, n_in, const_names=consts)
        assert trace_fingerprint(a) == trace_fingerprint(b), name


def test_compile_cache_key_changes_with_pass_config():
    """Opt / no-opt schedules of one workload never collide (and the
    golden cache-key snapshot would catch a key-schema change)."""
    fn, n_in, consts = WORKLOADS["matvec16"]
    trace = trace_program(fn, n_in, const_names=consts)
    cc = CompileCache()
    cc.get_schedule(trace, PARAMS, MEM, pass_config=CFG)
    cc.get_schedule(trace, PARAMS, MEM,
                    pass_config=CFG.with_passes(("bootstrap",)))
    cc.get_schedule(trace, PARAMS, MEM, pass_config=None)
    assert len(cc) == 3
    assert cc.metrics.count("compile_misses") == 3
    cc.get_schedule(trace, PARAMS, MEM, pass_config=CFG)
    assert cc.metrics.count("compile_hits") == 1


@pytest.mark.skipif(not os.environ.get("REGEN_GOLDENS"), reason="regen only")
def test_regen_notice():
    print(f"goldens regenerated at {GOLDEN_PATH}")
