"""Fig. 19 (new figure — PIM hierarchy model): FHEmem-preset vs
flat-model latency per workload, with a compute / movement / load
breakdown per pipeline stage.

Every workload in the serving registry is compiled once per hardware
point (the schedule is mapped against that point's projected
MemoryModel — partition count and capacity differ per preset) and
executed through the PIM discrete-event backend (repro.pim.backend):
the schedule is lowered to a bank-level instruction stream and
replayed on a virtual clock. Three hardware points from the shared
preset registry (repro.pim.arch):

* ``flat``   — the degenerate preset; reproduces AnalyticBackend
               stage times, so it doubles as the model-consistency
               check this benchmark asserts (≤1 % divergence).
* ``fhemem`` — the paper's hierarchy: bit-serial in-mat modmuls +
               inter-bank permutation network.
* ``hbm2``   — an HBM2-PIM-like point (wide near-bank units, channel
               bus instead of a permutation network).

The per-stage breakdown separates ROWOP/NTT cycles (compute), XFER +
STORE cycles (movement: rotations, ModUp/ModDown distribution, NTT
inter-mat shuffles, spills, inter-stage hops), and LOAD cycles
(constant streaming) — the decomposition the paper's §V analysis
hangs on: movement and load, not raw compute, dominate PIM-FHE.

    PYTHONPATH=src python -m benchmarks.fig19_pim [--smoke]

Emits ``name,us_per_call,derived`` CSV rows (benchmarks/run.py
contract) and rewrites ``benchmarks/results/fig19_pim.jsonl`` for
report.py.
"""
import argparse
import json
import os
import sys

from benchmarks.common import pim_arch, row
from repro.compiler import PassConfig
from repro.core.params import paper_params_bootstrap, test_params
from repro.core.trace import trace_program
from repro.pim.backend import PimBackend
from repro.runtime.batcher import Batch
from repro.runtime.compile_cache import CompileCache
from repro.runtime.executor import AnalyticBackend
from repro.runtime.metrics import MetricsRegistry
from repro.runtime.workloads import (HELR_CONSTS, LOLA_CONSTS, lola_infer,
                                     make_helr_iter, make_matvec,
                                     make_poly_eval, matvec_consts,
                                     poly_consts)

RESULTS = os.path.join(os.path.dirname(__file__), "results")
ARCHS = ("flat", "fhemem", "hbm2")


def _workloads(smoke: bool):
    dim = 8 if smoke else 16
    deg = 8 if smoke else 12
    rots = (1, 2, 4) if smoke else (1, 2, 4, 8, 16, 32, 64, 128)
    return {
        "helr": (make_helr_iter(rots), 2, HELR_CONSTS),
        "lola": (lola_infer, 1, LOLA_CONSTS),
        f"matvec{dim}": (make_matvec(dim), 1, matvec_consts(dim)),
        f"poly{deg}": (make_poly_eval(deg), 1, poly_consts(deg)),
    }


def _setting(smoke: bool):
    if smoke:
        return test_params(log_n=10, n_levels=8, dnum=2), 7, 4
    return paper_params_bootstrap(), 20, 8


def _execute(arch_name, sched, batch_n, workload):
    backend = PimBackend(arch=pim_arch(arch_name))
    mem = backend.arch.to_memory_model()
    batch = Batch(workload, [], [[] for _ in range(batch_n)], 0.0)
    total = backend.execute(sched, batch, key_cache=None,
                            metrics=MetricsRegistry(mem.n_partitions),
                            workload=workload)
    return total, backend.last_breakdown[workload]


def main(argv=()) -> None:
    # argv defaults to () so benchmarks/run.py can call main() without
    # this parser swallowing run.py's own flags
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small ring + workloads, fast CI check")
    args = ap.parse_args(list(argv))

    params, start, batch_n = _setting(args.smoke)
    cc = CompileCache()
    cfg = PassConfig(start_level=start, bsgs_min_terms=4)

    os.makedirs(RESULTS, exist_ok=True)
    records = []
    for wname, (fn, n_in, consts) in _workloads(args.smoke).items():
        trace = trace_program(fn, n_in, const_names=consts)
        totals = {}
        for arch_name in ARCHS:
            mem = pim_arch(arch_name).to_memory_model()
            sched = cc.get_schedule(trace, params, mem, pass_config=cfg)
            total, breakdown = _execute(arch_name, sched, batch_n, wname)
            totals[arch_name] = total
            comp = sum(e["compute_s"] for e in breakdown)
            move = sum(e["move_s"] for e in breakdown)
            load = sum(e["load_s"] for e in breakdown)
            busy = comp + move + load or 1.0

            if arch_name == "flat":
                # model-consistency gate: the degenerate preset must
                # reproduce the analytic backend it claims to subsume
                an = AnalyticBackend(mem)
                ref = an.execute(
                    sched, Batch(wname, [], [[] for _ in range(batch_n)],
                                 0.0),
                    key_cache=None,
                    metrics=MetricsRegistry(mem.n_partitions),
                    workload=wname)
                drift = abs(total - ref) / max(ref, 1e-30)
                assert drift <= 0.01, (
                    f"{wname}: flat pim backend drifted {drift:.2%} "
                    f"from AnalyticBackend")

            for e in breakdown:
                records.append({
                    "workload": wname, "arch": arch_name,
                    "stage": e["stage"], "partition": e["partition"],
                    "load_s": e["load_s"], "compute_s": e["compute_s"],
                    "move_s": e["move_s"], "busy_s": e["busy_s"],
                    "smoke": bool(args.smoke),
                })
            records.append({
                "workload": wname, "arch": arch_name, "stage": "total",
                "n_stages": len(sched.stages),
                "latency_s": total, "compute_s": comp, "move_s": move,
                "load_s": load,
                "compute_frac": comp / busy, "move_frac": move / busy,
                "load_frac": load / busy,
                "speedup_vs_flat": (totals["flat"] / total
                                    if "flat" in totals and total else 1.0),
                "smoke": bool(args.smoke),
            })
            row(f"fig19_{wname}_{arch_name}", total * 1e6,
                f"{len(sched.stages)}st compute={comp/busy*100:.0f}% "
                f"move={move/busy*100:.0f}% load={load/busy*100:.0f}% "
                f"speedup_vs_flat={totals['flat']/total:.2f}x")

    with open(os.path.join(RESULTS, "fig19_pim.jsonl"), "w") as f:
        for r in records:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main(sys.argv[1:])
