"""Fig. 15 reproduction: the paper's three optimizations, each ablated.

 (1) Montgomery-friendly (Solinas) moduli vs generic Barrett — measured
     modmul time + the paper's own metric (addition steps: hamming weight h
     vs full n-bit serial adds).
 (2) Inter-bank network: chain (ring/ppermute) vs channel bus (all-gather)
     BConv — structural bytes-on-slowest-link per output limb.
 (3) Load-save pipeline vs naive coarse pipeline — per-input latency on a
     HELR-iteration trace at paper scale (§IV-F model).
"""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import modarith as ma
from repro.core import pipeline as pl, trace as tr
from repro.core.params import CkksParams, find_ntt_primes


def ablate_moduli():
    log_n = 12
    n = 1 << log_n
    sol = find_ntt_primes(30, log_n, 1, prefer_solinas=True)[0]
    gen = find_ntt_primes(30, log_n, 1, prefer_solinas=False)[0]
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, min(sol.value, gen.value),
                                 size=(8, n), dtype=np.uint64))
    bb, ss = sol.solinas
    t_sol = timeit(lambda: ma.mulmod_solinas(a, a, jnp.uint64(sol.value),
                                             bb, ss))
    mu = jnp.uint64(ma.barrett_mu(gen.value))
    t_bar = timeit(lambda: ma.mulmod_barrett(a, a, jnp.uint64(gen.value), mu))
    row("fig15_moduli_solinas", t_sol * 1e6,
        f"h={sol.hamming_weight}: {30}b mult = {sol.hamming_weight} adds "
        f"(paper Base0->Base1)")
    row("fig15_moduli_barrett_generic", t_bar * 1e6,
        f"h={gen.hamming_weight}: {30} serial adds on the NMU")
    row("fig15_moduli_nmu_add_ratio", 0.0,
        f"{30 / sol.hamming_weight:.1f}x fewer NMU addition steps")


def ablate_interconnect():
    """Bytes crossing the bottleneck link per BConv, chain vs bus.

    Bus (all-gather on shared channel IO): every device's S_l limbs
    serialize over one bus -> S*N*8 bytes on the shared link.
    Chain (ring): each neighbor link carries (n-1)/n * S_l*N*8 bytes in
    parallel -> per-link bytes smaller by ~n_banks, and overlapped.
    """
    params = CkksParams(log_n=16, log_scale=28, n_levels=23, dnum=4,
                        first_mod_bits=31, scale_mod_bits=28,
                        special_mod_bits=31)
    n_banks = 16
    s_total = params.n_q_moduli
    bytes_total = s_total * params.n * 8
    bus = bytes_total
    chain_per_link = (n_banks - 1) / n_banks * bytes_total / n_banks
    row("fig15_interconnect_bus_bytes", 0.0,
        f"{bus/2**20:.1f}MiB on shared channel IO")
    row("fig15_interconnect_chain_bytes_per_link", 0.0,
        f"{chain_per_link/2**20:.1f}MiB per neighbor link "
        f"({bus/chain_per_link:.1f}x less on bottleneck)")


def ablate_pipeline():
    def helr_iter(x, w, consts=None):
        s = x * w
        for k in (1, 2, 4, 8, 16, 32, 64, 128):
            s = s + s.rotate(k)
        a = s * consts["c1"]
        b = s * s
        c = b * s
        g = (a + c * consts["c3"]) * x
        return w + g

    t = tr.trace_program(helr_iter, 2, const_names=("c1", "c3"))
    tr.infer_levels(t, start_level=20)
    params = CkksParams(log_n=16, log_scale=28, n_levels=23, dnum=4,
                        first_mod_bits=31, scale_mod_bits=28,
                        special_mod_bits=31)
    mem = pl.MemoryModel(n_partitions=16, partition_bytes=96 * 2 ** 20,
                         load_bw=64e9, modmul_throughput=8e12,
                         transfer_bw=256e9)
    sched = pl.generate_load_save_pipeline(t, params, mem)
    naive = pl.generate_naive_pipeline(t, params, mem)
    b = 32
    t_ls = sched.bottleneck_latency(b)
    t_nv = naive.bottleneck_latency(b)
    row("fig15_pipeline_load_save", t_ls * 1e6,
        f"{len(sched.stages)} stages, {len(sched.rounds)} rounds")
    row("fig15_pipeline_naive", t_nv * 1e6,
        f"reload-per-op={naive.reload_per_op}")
    row("fig15_pipeline_speedup", 0.0,
        f"{t_nv/t_ls:.2f}x (paper: 1.15-3.59x across configs)")


def main():
    ablate_moduli()
    ablate_interconnect()
    ablate_pipeline()


if __name__ == "__main__":
    main()
