"""Fig. 1 reproduction: off-chip bandwidth required vs on-chip NTT
throughput for a key-switching operation, from the analytic op/footprint
model (core/trace.py) — the analysis that motivates PIM in the paper.

Scenario (paper §I, L=30-ish deep params): an accelerator with K NTT units
(each one butterfly/cycle @ 1GHz) processing HMul+KSO back to back; data
loaded per op under three locality scenarios: evk only / evk+1 operand /
evk+2 operands.
"""
from benchmarks.common import row
from repro.core.params import paper_params_bootstrap
from repro.core.trace import (FheOp, ct_bytes, evk_bytes,
                              op_cost)


def main():
    params = paper_params_bootstrap()
    level = params.n_levels
    n = params.n
    hmul = op_cost(params, FheOp(0, "hmul", (0, 1), level=level - 1))
    # NTT butterflies for the op: ntts * (N/2 log2 N)
    import math
    butterflies = hmul.ntts * (n // 2) * math.log2(n)
    for k_ntt in (1024, 2048, 16384, 65536):
        t_compute = butterflies / (k_ntt * 1e9)        # seconds per HMul+KSO
        for scen, bytes_needed in (
                ("evk_only", evk_bytes(params)),
                ("evk+1op", evk_bytes(params) + ct_bytes(params, level - 1)),
                ("evk+2op", evk_bytes(params) + 2 * ct_bytes(params, level - 1)),
        ):
            bw = bytes_needed / t_compute
            row(f"fig1_bw_req_{k_ntt}ntt_{scen}", t_compute * 1e6,
                f"{bw/1e12:.2f}TB/s")


if __name__ == "__main__":
    main()
