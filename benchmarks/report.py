"""Render EXPERIMENTS.md tables from benchmarks/results/*.jsonl."""
import json
import os
import sys

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "results")


def load(name):
    path = os.path.join(RESULTS, name)
    recs = {}
    if not os.path.exists(path):
        return []
    for line in open(path):
        r = json.loads(line)
        arch = r["arch"].replace("_", "-")
        if arch == "llama-3-2-vision-90b":   # pre-fix runs used mangled id
            arch = "llama-3.2-vision-90b"
        r["arch"] = arch
        recs[(arch, r["shape"], r.get("mesh", ""))] = r
    return list(recs.values())   # dedup: last write wins


def dryrun_table():
    recs = load("dryrun.jsonl")
    print("\n### Dry-run (lower + compile) — all cells x both meshes\n")
    print("| arch | shape | mesh | status | compile_s | HLO flops/dev | "
          "coll MiB/dev | temp GiB/dev | arg GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{r['compile_s']:.1f} | {r['flops_per_device']:.2e} | "
                  f"{r['collective_total']/2**20:.0f} | "
                  f"{(r['temp_bytes'] or 0)/2**30:.2f} | "
                  f"{(r['argument_bytes'] or 0)/2**30:.2f} |")
        else:
            why = r.get("reason", r.get("error", ""))[:60]
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['status']} | — | — | — | — | {why} |")
    ok = sum(r["status"] == "ok" for r in recs)
    sk = sum(r["status"] == "skipped" for r in recs)
    er = sum(r["status"] == "error" for r in recs)
    print(f"\nTotals: {ok} compiled ok, {sk} documented skips, {er} errors.")


def roofline_table():
    recs = load("roofline.jsonl")
    print("\n### Roofline (single-pod 16x16, 256 chips; v5e terms)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant |"
          " MODEL_FLOPS | useful/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
                  f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
                  f"{r['dominant'][:-2]} | {r['model_flops']:.2e} | "
                  f"{min(r['useful_flops_ratio'],9.99):.3f} | "
                  f"{min(r['roofline_fraction'],9.99):.3f} |")
        elif r["status"] == "skipped":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — |"
                  f" — | — |")
        else:
            print(f"| {r['arch']} | {r['shape']} | ERROR "
                  f"{r.get('error','')[:40]} | | | | | | |")


def fig17_table():
    path = os.path.join(RESULTS, "fig17_compiler.jsonl")
    if not os.path.exists(path):
        return
    recs = [json.loads(line) for line in open(path)]
    print("\n### Fig. 17 — compiler ablation (cumulative passes, "
          "analytic latency)\n")
    print("| workload | stage | ops | rotations | bootstraps | "
          "latency_ms | speedup vs unopt | compile_ms | verify_ms |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        wall = (f"{r['compile_wall_s'] * 1e3:.1f}"
                if "compile_wall_s" in r else "—")
        ver = (f"{r['verify_wall_s'] * 1e3:.2f}"
               if "verify_wall_s" in r else "—")
        print(f"| {r['workload']} | {r['stage']} | {r['n_ops']} | "
              f"{r['n_rotations']} | {r['n_bootstraps']} | "
              f"{r['latency_s'] * 1e3:.3f} | "
              f"{r['speedup_vs_unopt']:.2f}x | {wall} | {ver} |")
    # last record per workload = the full cumulative pipeline
    full = list({r["workload"]: r for r in recs}.values())
    if full:
        best = max(full, key=lambda r: r["speedup_vs_unopt"])
        print(f"\nBest end-to-end: {best['workload']} at "
              f"{best['speedup_vs_unopt']:.2f}x.")
    # static-verification overhead across the run (fig17_compiler.py
    # gates this below 5% on the full setting)
    vrecs = [r for r in recs if "verify_wall_s" in r]
    if vrecs:
        v = sum(r["verify_wall_s"] for r in vrecs)
        c = sum(r["compile_wall_s"] + r.get("map_wall_s", 0.0)
                for r in vrecs)
        n_find = sum(r.get("verify_findings", 0) for r in vrecs)
        print(f"Static verification: {v * 1e3:.1f}ms over "
              f"{c * 1e3:.1f}ms compile+map wall "
              f"({v / c * 100:.1f}%), {n_find} finding(s).")


def fig18_table():
    path = os.path.join(RESULTS, "fig18_calibration.jsonl")
    if not os.path.exists(path):
        return
    recs = [json.loads(line) for line in open(path)]
    print("\n### Fig. 18 — cost-model calibration (analytic predicted vs "
          "measured encrypted execution)\n")
    print("| workload | stage | ops | predicted_ms | measured_ms | "
          "meas/(pred*fit) |")
    print("|---|---|---|---|---|---|")
    for r in recs:
        if not isinstance(r["stage"], int):
            continue
        mark = " (bootstrap)" if r.get("bootstrap") else ""
        print(f"| {r['workload']} | {r['stage']}{mark} | {r['n_ops']} | "
              f"{r['predicted_s'] * 1e3:.3f} | {r['measured_s'] * 1e3:.3f} | "
              f"{r['ratio_vs_fit']:.2f} |")
    totals = [r for r in recs
              if r["stage"] == "total" and "fitted_scale" in r]
    if totals:
        print("\n| workload | fitted scale | rank concordance | "
              "max decrypt err | tolerance |")
        print("|---|---|---|---|---|")
        for r in totals:
            print(f"| {r['workload']} | {r['fitted_scale']:.1f} | "
                  f"{r['rank_concordance']:.2f} | "
                  f"{r['max_decrypt_error']:.2e} | {r['tolerance']:.2e} |")
    ktotals = [r for r in recs if r.get("route") == "kernels"
               and r["stage"] == "total"]
    if ktotals:
        print("\n| workload (fused-kernel route) | rank concordance | "
              "library concordance | decode bit-equal |")
        print("|---|---|---|---|")
        for r in ktotals:
            print(f"| {r['workload']} | {r['rank_concordance']:.2f} | "
                  f"{r['library_rank_concordance']:.2f} | "
                  f"{r['bit_equal']} |")
    summ = [r for r in recs if r["stage"] == "concordance_summary"]
    for r in summ:
        print(f"\nKernel-route concordance (tie-tolerant mean): "
              f"{r['kernels_mean']:.2f} vs library {r['library_mean']:.2f} "
              f"— asserted no worse at benchmark time.")


def fig14_table():
    path = os.path.join(RESULTS, "fig14_kernels.jsonl")
    if not os.path.exists(path):
        return
    recs = [json.loads(line) for line in open(path)]
    print("\n### Fig. 14 — compute-path comparison (NTT / modmul / "
          "keyswitch kernels)\n")
    print("| path | us/call | notes |")
    print("|---|---|---|")
    for r in recs:
        print(f"| {r['name']} | {r['us_per_call']:.1f} | {r['derived']} |")
    red = [r for r in recs if "reduction" in r]
    for r in red:
        print(f"\nFused keyswitch dispatch reduction: "
              f"{r['staged_dispatches']} -> {r['fused_dispatches']} "
              f"launches ({r['reduction']:.2f}x, asserted >= 4x).")


def fig19_table():
    path = os.path.join(RESULTS, "fig19_pim.jsonl")
    if not os.path.exists(path):
        return
    recs = [json.loads(line) for line in open(path)]
    totals = [r for r in recs if r["stage"] == "total"]
    print("\n### Fig. 19 — PIM hierarchy model (FHEmem vs flat vs "
          "HBM2-PIM-like, DES latency + compute/movement/load split)\n")
    print("| workload | arch | stages | latency_ms | compute | movement | "
          "load | speedup vs flat |")
    print("|---|---|---|---|---|---|---|---|")
    for r in totals:
        print(f"| {r['workload']} | {r['arch']} | {r['n_stages']} | "
              f"{r['latency_s'] * 1e3:.3f} | {r['compute_frac']*100:.0f}% | "
              f"{r['move_frac']*100:.0f}% | {r['load_frac']*100:.0f}% | "
              f"{r['speedup_vs_flat']:.2f}x |")
    fhemem = [r for r in totals if r["arch"] == "fhemem"]
    if fhemem:
        best = max(fhemem, key=lambda r: r["speedup_vs_flat"])
        print(f"\nBest FHEmem speedup over the flat model: "
              f"{best['workload']} at {best['speedup_vs_flat']:.2f}x.")


def fig20_table():
    path = os.path.join(RESULTS, "fig20_fleet.jsonl")
    if not os.path.exists(path):
        return
    recs = [json.loads(line) for line in open(path)]
    sweep = [r for r in recs if r["figure"] == "sweep"]
    print("\n### Fig. 20 — fleet serving (offered load vs goodput, "
          "least_loaded routing)\n")
    print("| devices | load | offered req/s | goodput req/s | "
          "thru req/s | p99_ms | qdelay p99_ms | occupancy |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(sweep, key=lambda r: (r["devices"], r["load_mult"])):
        print(f"| {r['devices']} | {r['load_mult']:g}x | "
              f"{r['offered_rps']:.0f} | {r['goodput_rps']:.0f} | "
              f"{r['throughput_rps']:.0f} | {r['p99_s'] * 1e3:.3f} | "
              f"{r['queue_delay_p99_s'] * 1e3:.3f} | "
              f"{r['mean_device_occupancy'] * 100:.0f}% |")
    abl = [r for r in recs if r["figure"] == "ablation"]
    if abl:
        print("\n| router (4 dev, small caches, slow load) | "
              "goodput req/s | routing hit | keycache hit | p99_ms |")
        print("|---|---|---|---|---|")
        for r in abl:
            print(f"| {r['router']} | {r['goodput_rps']:.0f} | "
                  f"{r['routing_hit_rate'] * 100:.0f}% | "
                  f"{r['keycache_hit_rate'] * 100:.0f}% | "
                  f"{r['p99_s'] * 1e3:.3f} |")
    top = max(r["load_mult"] for r in sweep) if sweep else 0
    pts = {r["devices"]: r["goodput_rps"] for r in sweep
           if r["load_mult"] == top}
    if 1 in pts and 4 in pts and pts[1] > 0:
        print(f"\nGoodput scaling at {top:g}x load: "
              f"{pts[4] / pts[1]:.2f}x from 1 -> 4 devices.")


def fig21_table():
    path = os.path.join(RESULTS, "fig21_trace.jsonl")
    if not os.path.exists(path):
        return
    recs = [json.loads(line) for line in open(path)]
    over = [r for r in recs if r["figure"] == "overhead"]
    print("\n### Fig. 21 — request tracing (overhead gates + critical-path "
          "breakdown from span trees)\n")
    if over:
        r = over[-1]
        print(f"Tracing overhead: encrypted serve "
              f"{r['overhead_frac'] * 100:+.1f}% wall "
              f"(budget {r['budget_frac'] * 100:.0f}%), reported "
              f"throughput delta 0% (bit-for-bit), simulator harness "
              f"{r['sim_overhead_frac'] * 100:+.1f}% "
              f"({r['n_spans']} spans / {r['n_requests']} requests).\n")
    bd = [r for r in recs if r["figure"] == "breakdown"]
    if bd:
        print("| workload | n | mean latency_us | queue | const load | "
              "compute | on-chip move | other |")
        print("|---|---|---|---|---|---|---|---|")
        for r in bd:
            lat = r["latency_s"] or 1e-30
            print(f"| {r['workload']} | {r['n']} | "
                  f"{r['latency_s'] * 1e6:.1f} | "
                  f"{r['queue_s'] / lat * 100:.0f}% | "
                  f"{r['load_s'] / lat * 100:.0f}% | "
                  f"{r['compute_s'] / lat * 100:.0f}% | "
                  f"{r['move_s'] / lat * 100:.0f}% | "
                  f"{r['other_s'] / lat * 100:.0f}% |")
    isa = [r for r in recs if r["figure"] == "pim_isa"]
    if isa:
        cy = isa[-1]["class_cycles"]
        total = sum(cy.values()) or 1.0
        parts = " ".join(f"{k}={v / total * 100:.0f}%"
                         for k, v in cy.items())
        print(f"\nPIM execute spans attribute to instruction classes: "
              f"{parts} (of {total:.0f} bank-cycles).")
    ver = [r for r in recs if r["figure"] == "verify"]
    if ver:
        r = ver[-1]
        print(f"\nStatic verification on the serving path (compile "
              f"spans): {r['verify_wall_s'] * 1e3:.1f}ms over "
              f"{r['compile_wall_s'] * 1e3:.1f}ms compile wall "
              f"({r['verify_frac'] * 100:.1f}%) across "
              f"{r['n_compiles']} compile miss(es), "
              f"{r['verify_findings']} finding(s).")


def fig22_table():
    path = os.path.join(RESULTS, "fig22_utilization.jsonl")
    if not os.path.exists(path):
        return
    recs = [json.loads(line) for line in open(path)]
    util = [r for r in recs if r["figure"] == "utilization"]
    print("\n### Fig. 22 — PIM utilization telemetry (per-bank busy "
          "fraction + movement vs peak link bandwidth, roofline-style)\n")
    if util:
        print("| workload | preset | mean util | peak util | peak phase |"
              " goodput req/s |")
        print("|---|---|---|---|---|---|")
        for r in util:
            print(f"| {r['workload']} | {r['preset']} | "
                  f"{r['mean_util'] * 100:.1f}% | "
                  f"{r['peak_util'] * 100:.1f}% | {r['peak_phase']} | "
                  f"{r['goodput_rps']:.0f} |")
    move = [r for r in recs if r["figure"] == "movement"]
    if move:
        print("\n| preset | lowered bytes by scope | "
              "peak link occupancy (frac of peak bw) |")
        print("|---|---|---|")
        for r in move:
            by = " ".join(f"{k}={v / 2 ** 20:.1f}MiB"
                          for k, v in sorted(r["lowered_bytes"].items()))
            bw = " ".join(f"{k}={v * 100:.1f}%"
                          for k, v in sorted(r["peak_bw_frac"].items()))
            print(f"| {r['preset']} | {by} | {bw} |")
    for r in recs:
        if r["figure"] == "gate_fhemem":
            print(f"\nFHEmem bank utilization: mean "
                  f"{r['mean_util'] * 100:.1f}%, peak "
                  f"{r['peak_util'] * 100:.1f}% (< 100% — pipeline fill "
                  f"always adds wall), NTT phase at the peak "
                  f"({r['n_samples']} samples).")
        if r["figure"] == "gate_flat":
            print(f"Flat preset vs analytic backend: busy-seconds delta "
                  f"{r['busy_rel_err'] * 100:.3f}%, utilization delta "
                  f"{r['util_rel_err'] * 100:.3f}% (budget 1%).")
        if r["figure"] == "gate_openmetrics":
            print(f"OpenMetrics export: {r['n_samples']} samples from "
                  f"{r['n_series']} series ({r['n_points']} ring-buffer "
                  f"points), 0 parse errors ({r['path']}).")
        if r["figure"] == "overhead":
            print(f"Telemetry+tracing overhead on encrypted serving: "
                  f"{r['overhead_frac'] * 100:+.1f}% wall (budget "
                  f"{r['budget_frac'] * 100:.0f}%), reported metrics "
                  f"bit-for-bit identical on every preset.")


def pick_hillclimb():
    recs = [r for r in load("roofline.jsonl") if r["status"] == "ok"]
    by_rf = sorted((r for r in recs if r["shape"] != "long_500k"),
                   key=lambda r: r["roofline_fraction"])
    coll = sorted(recs, key=lambda r: -(r["collective_s"] /
                                        max(r["compute_s"] + r["memory_s"],
                                            1e-9)))
    print("\nworst roofline fraction:",
          [(r["arch"], r["shape"], round(r["roofline_fraction"], 4))
           for r in by_rf[:3]])
    print("most collective-bound:",
          [(r["arch"], r["shape"],
            round(r["collective_s"] / max(r["compute_s"], 1e-9), 1))
           for r in coll[:3]])


if __name__ == "__main__":
    what = sys.argv[1] if len(sys.argv) > 1 else "all"
    if what in ("all", "dryrun"):
        dryrun_table()
    if what in ("all", "roofline"):
        roofline_table()
    if what in ("all", "fig14"):
        fig14_table()
    if what in ("all", "fig17"):
        fig17_table()
    if what in ("all", "fig18"):
        fig18_table()
    if what in ("all", "fig19"):
        fig19_table()
    if what in ("all", "fig20"):
        fig20_table()
    if what in ("all", "fig21"):
        fig21_table()
    if what in ("all", "fig22"):
        fig22_table()
    if what in ("all", "pick"):
        pick_hillclimb()
