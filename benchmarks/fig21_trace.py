"""Fig. 21 (new figure — observability): tracing overhead gate and
per-workload critical-path breakdown on the serving stack.

Drives the fig16-style serving scenario (mixed four-workload Poisson
stream through `PipelinedExecutor` on the analytic backend) twice on
identical arrival schedules — tracer detached vs `Tracer` attached —
and enforces the tentpole's acceptance criteria in-benchmark:

* **throughput gate** — the traced run's metrics summary (throughput,
  latency percentiles, every counter) is bit-for-bit identical to the
  untraced one: spans observe the virtual timeline, never perturb it,
  so tracing costs exactly 0% of reported serving throughput;
* **wall-clock gate** — on the backend that does real work per batch
  (`CiphertextBackend`, real encrypted execution on a wall clock),
  attached tracing costs < 5% serve wall time (min-of-3 timings; the
  smoke setting relaxes to 25% because its absolute times are small
  enough for scheduler noise to dominate). The analytic run's wall
  overhead is also reported, informationally: there the "backend" is
  a cost-model evaluation taking microseconds per round, so span
  emission is a visible fraction of the *simulator harness* — that
  number is the cost of tracing a simulation, not of tracing serving;
* **completeness gate** — every completed request yields a span tree
  whose root ``request`` span duration IS the recorded latency: the
  count and mean of root durations match the latency accumulator to
  float precision.

The traced run then feeds `repro.obs.critical_path.workload_breakdown`
— where each workload's latency actually goes (queue wait vs constant
load vs compute vs on-chip movement) — which report.py renders as the
fig21 table, and the trace itself is exported to
``benchmarks/results/fig21_trace.json`` so CI can schema-validate a
real artifact (``python -m repro.obs.perfetto validate``).

A final PIM section re-runs one traced batch on the hierarchical
backend and rolls stage spans' ``isa_cycles`` up to per-class totals —
the span-tree-to-instruction-stream attribution the tentpole promises.

    PYTHONPATH=src python -m benchmarks.fig21_trace [--smoke]

Emits ``name,us_per_call,derived`` CSV rows (benchmarks/run.py
contract) and rewrites ``benchmarks/results/fig21_trace.jsonl`` for
report.py.
"""
import argparse
import json
import os
import sys
import time

import numpy as np

from benchmarks.common import row
from repro.compiler import PassConfig
from repro.core.params import test_params
from repro.core.pipeline import MemoryModel
from repro.obs import Tracer, workload_breakdown, write_trace
from repro.runtime import BatchPolicy, KeyCache, PipelinedExecutor, Request
from repro.runtime.workloads import (HELR_CONSTS, LOLA_CONSTS, lola_infer,
                                     make_helr_iter, make_matvec,
                                     make_poly_eval, matvec_consts,
                                     poly_consts)

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _workloads(smoke: bool):
    dim = 8 if smoke else 16
    deg = 6 if smoke else 8
    rots = (1, 2, 4) if smoke else (1, 2, 4, 8, 16, 32)
    return {
        "helr": (make_helr_iter(rots), 2, HELR_CONSTS),
        "lola": (lola_infer, 1, LOLA_CONSTS),
        "matvec": (make_matvec(dim), 1, matvec_consts(dim)),
        "poly": (make_poly_eval(deg), 1, poly_consts(deg)),
    }


def _setting(smoke: bool):
    if smoke:
        params = test_params(log_n=10, n_levels=8, dnum=2)
        mem = MemoryModel(n_partitions=4, partition_bytes=8 * 2 ** 20)
        return params, mem, 7, 120
    params = test_params(log_n=12, n_levels=10, dnum=2)
    mem = MemoryModel(n_partitions=8, partition_bytes=32 * 2 ** 20)
    return params, mem, 9, 2000


def _build(smoke: bool, traced: bool, backend: str = "analytic"):
    params, mem, start, _ = _setting(smoke)
    policy = BatchPolicy(slots_per_ct=params.slots, max_batch=8,
                         max_wait_s=1e-3)
    ex = PipelinedExecutor(
        params, mem, backend=backend, policy=policy,
        pass_config=PassConfig(start_level=start, bsgs_min_terms=4))
    for name, (fn, n_in, consts) in _workloads(smoke).items():
        ex.register(name, fn, n_in, const_names=consts, start_level=start)
        # prewarm the compile cache so the timed serves measure
        # steady-state serving, not the one-time mapper cost
        ex.compile_cache.get_schedule(ex.workloads[name].trace, params,
                                      mem, pass_config=ex.pass_config)
    working_set = max(
        sum(st.const_bytes for st in ex.compile_cache.get_schedule(
            w.trace, params, mem, pass_config=ex.pass_config).stages)
        for w in ex.workloads.values())
    ex.key_cache = KeyCache(2 * working_set, load_bw=mem.load_bw,
                            metrics=ex.metrics)
    if traced:
        ex.metrics.tracer = Tracer()
    return ex


def _arrivals(ex, n_requests: int, rate_rps: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    names = list(ex.workloads)
    slots = ex.policy.slots_per_ct
    out, t = [], 0.0
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        out.append(Request(
            ex.queue.next_request_id(), tenant=f"tenant{i % 4}",
            workload=names[int(rng.integers(len(names)))], arrival_s=t,
            slots_needed=int(rng.integers(slots // 8, slots // 2))))
    return out


def _timed_serve(smoke: bool, traced: bool, n_req: int, rate: float):
    """Fresh executor + identical arrival stream; returns
    (min serve wall seconds of 3, last metrics, last executor)."""
    best, m, ex = float("inf"), None, None
    for _ in range(3):
        ex = _build(smoke, traced)
        arrivals = _arrivals(ex, n_req, rate)
        t0 = time.perf_counter()
        m = ex.serve(arrivals)
        best = min(best, time.perf_counter() - t0)
    return best, m, ex


def _ct_overhead(smoke: bool):
    """Wall-clock tracing overhead on real encrypted execution: one
    shared `CiphertextBackend` (keys + jit warmth amortized across
    reps), fresh executor + identical lola arrival stream per rep,
    min-of-3 serve timings traced vs untraced."""
    from repro.runtime import CiphertextBackend
    params = test_params(log_n=8, n_levels=8, dnum=2, log_scale=26)
    mem = MemoryModel(n_partitions=4, partition_bytes=256 * 2 ** 10)
    backend = CiphertextBackend(params, use_kernels=False)
    n = 6 if smoke else 40

    def serve_once(traced: bool) -> float:
        ex = PipelinedExecutor(
            params, mem, backend=backend,
            policy=BatchPolicy(slots_per_ct=params.slots, max_batch=2,
                               max_wait_s=1e-3),
            key_cache=KeyCache(64 * 2 ** 20),
            pass_config=PassConfig(start_level=7, bsgs_min_terms=4))
        ex.register("lola", lola_infer, 1, const_names=LOLA_CONSTS,
                    start_level=7)
        if traced:
            ex.metrics.tracer = Tracer()
        rng = np.random.default_rng(3)
        arrivals = [Request(ex.queue.next_request_id(), f"t{i % 2}",
                            "lola", arrival_s=i * 1e-4, slots_needed=8,
                            payload=rng.uniform(-0.8, 0.8, size=8))
                    for i in range(n)]
        ex.warmup()
        t0 = time.perf_counter()
        ex.serve(arrivals)
        return time.perf_counter() - t0

    serve_once(False)                       # jit warm-up, untimed
    # interleave modes so clock drift (thermal, background load) hits
    # both sides equally; min-of-N is the noise floor estimator
    t_off, t_on = float("inf"), float("inf")
    for _ in range(3 if smoke else 5):
        t_off = min(t_off, serve_once(False))
        t_on = min(t_on, serve_once(True))
    return t_off, t_on


def _verify_compile_spans(smoke: bool, rate: float):
    """One traced serve on a ``verify=True`` executor with a cold
    compile cache: every workload's first batch misses, so its
    ``compile`` span carries the verify-on-miss wall time and finding
    count the compile cache stamped on it. Returns the per-span
    aggregation report.py renders as the fig21 verify line."""
    params, mem, start, _ = _setting(smoke)
    policy = BatchPolicy(slots_per_ct=params.slots, max_batch=8,
                         max_wait_s=1e-3)
    ex = PipelinedExecutor(
        params, mem, backend="analytic", policy=policy,
        pass_config=PassConfig(start_level=start, bsgs_min_terms=4),
        verify=True)
    ex.metrics.tracer = Tracer()            # attached BEFORE any compile
    for name, (fn, n_in, consts) in _workloads(smoke).items():
        ex.register(name, fn, n_in, const_names=consts, start_level=start)
    ex.serve(_arrivals(ex, 24, rate))
    misses = [s for s in ex.metrics.tracer.store.by_name("compile")
              if not s.attrs.get("hit")]
    assert misses, "verify section: no compile-miss spans recorded"
    c_wall = sum(s.attrs["wall_s"] for s in misses)
    v_wall = sum(s.attrs["verify_wall_s"] for s in misses)
    findings = sum(s.attrs["verify_findings"] for s in misses)
    assert v_wall > 0, "verify section: spans carry no verify wall"
    assert findings == 0, (
        f"verify section: {findings} finding(s) on benchmark workloads")
    return {"n_compiles": len(misses), "compile_wall_s": c_wall,
            "verify_wall_s": v_wall, "verify_findings": findings,
            "verify_frac": v_wall / c_wall}


def _pim_isa_rollup(smoke: bool, n_req: int, rate: float):
    """One traced serve on the hierarchical PIM backend; roll stage
    spans' per-instruction-class cycle attribution up to totals."""
    ex = _build(smoke, traced=True, backend="pim")
    m = ex.serve(_arrivals(ex, max(8, n_req // 10), rate))
    totals = {}
    for s in ex.metrics.tracer.store.by_name("stage"):
        for k, v in s.attrs.get("isa_cycles", {}).items():
            totals[k] = totals.get(k, 0.0) + v
    return m, dict(sorted(totals.items(), key=lambda kv: -kv[1]))


def main(argv=()) -> None:
    # argv defaults to () so benchmarks/run.py can call main() without
    # this parser swallowing run.py's own flags
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small params + short stream, fast CI check")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="trace JSON path (default results/fig21_trace"
                         ".json)")
    args = ap.parse_args(list(argv))

    params, mem, start, n_req = _setting(args.smoke)
    # offered near single-device capacity so queues form and the
    # breakdown has a queue_wait story to tell
    probe = _build(args.smoke, traced=False)
    pm = probe.serve(_arrivals(probe, max(40, n_req // 8), 1e9))
    rate = 0.9 * pm.count("requests_completed") / pm.elapsed_s

    t_off, m_off, _ = _timed_serve(args.smoke, False, n_req, rate)
    t_on, m_on, ex = _timed_serve(args.smoke, True, n_req, rate)
    sim_overhead = t_on / t_off - 1.0
    budget = 0.25 if args.smoke else 0.05

    # gate 1: identical metrics — tracing observes, never perturbs, so
    # the reported serving throughput is bit-for-bit unchanged (0%)
    assert m_on.summary() == m_off.summary(), (
        "tracing gate: traced metrics summary diverged from untraced")
    assert m_on.throughput_rps() == m_off.throughput_rps()

    # gate 2: wall-clock overhead on REAL execution within budget
    ct_off, ct_on = _ct_overhead(args.smoke)
    ct_overhead = ct_on / ct_off - 1.0
    assert ct_overhead < budget, (
        f"tracing gate: {ct_overhead * 100:.1f}% encrypted-serve wall "
        f"overhead exceeds {budget * 100:.0f}% "
        f"({ct_on * 1e3:.1f}ms vs {ct_off * 1e3:.1f}ms)")
    # simulator-harness cost (informational — the analytic "backend"
    # is microseconds of arithmetic per round, so span emission shows;
    # guard only against pathological emission-path regressions)
    assert sim_overhead < 1.5, (
        f"tracing gate: {sim_overhead * 100:.0f}% simulator harness "
        f"overhead — span emission path regressed")

    store = ex.metrics.tracer.store
    # gate 3: complete span trees — root duration IS recorded latency
    roots = [s for s in store.by_name("request")
             if s.attrs.get("status") in ("completed", "deadline_miss")]
    lat = m_on.request_latency
    assert len(roots) == lat.count, (
        f"completeness gate: {len(roots)} closed request roots vs "
        f"{lat.count} recorded latencies")
    mean_root = sum(s.duration_s for s in roots) / len(roots)
    assert abs(mean_root - lat.mean) <= 1e-9 * max(lat.mean, 1e-30), (
        f"completeness gate: mean root span duration {mean_root!r} != "
        f"recorded mean latency {lat.mean!r}")

    row("fig21_overhead_encrypted", ct_on * 1e6,
        f"overhead={ct_overhead * 100:+.1f}% (budget {budget * 100:.0f}%) "
        f"untraced={ct_off * 1e3:.1f}ms")
    row("fig21_overhead_simulator", t_on * 1e6,
        f"overhead={sim_overhead * 100:+.1f}% (harness, informational) "
        f"untraced={t_off * 1e3:.1f}ms spans={len(store)} "
        f"throughput_delta=0%")

    records = [{
        "figure": "overhead", "smoke": bool(args.smoke),
        "t_untraced_s": ct_off, "t_traced_s": ct_on,
        "overhead_frac": ct_overhead, "budget_frac": budget,
        "sim_t_untraced_s": t_off, "sim_t_traced_s": t_on,
        "sim_overhead_frac": sim_overhead,
        "n_spans": len(store), "n_requests": lat.count,
    }]
    for wname, bd in sorted(workload_breakdown(store).items()):
        records.append(dict(bd, figure="breakdown", workload=wname,
                            smoke=bool(args.smoke)))
        row(f"fig21_breakdown_{wname}", bd["latency_s"] * 1e6,
            f"n={bd['n']} queue={bd['queue_s'] * 1e6:.1f}us "
            f"load={bd['load_s'] * 1e6:.1f}us "
            f"compute={bd['compute_s'] * 1e6:.1f}us "
            f"move={bd['move_s'] * 1e6:.1f}us")

    pim_m, isa = _pim_isa_rollup(args.smoke, n_req, rate)
    top = " ".join(f"{k}={v:.0f}" for k, v in list(isa.items())[:4])
    row("fig21_pim_isa", sum(isa.values()), f"cycles by class: {top}")
    records.append({"figure": "pim_isa", "smoke": bool(args.smoke),
                    "class_cycles": isa,
                    "n_requests": pim_m.count("requests_completed")})

    # static-verification overhead as the compile spans report it
    # (fig17 owns the <5%-of-compile-wall gate on the full setting;
    # this line shows the same overhead on the serving path)
    ver = _verify_compile_spans(args.smoke, rate)
    row("fig21_verify", ver["verify_wall_s"] * 1e6,
        f"verify/compile={ver['verify_frac'] * 100:.1f}% "
        f"findings={ver['verify_findings']} "
        f"compiles={ver['n_compiles']}")
    records.append(dict(ver, figure="verify", smoke=bool(args.smoke)))

    os.makedirs(RESULTS, exist_ok=True)
    trace_path = args.trace_out or os.path.join(RESULTS, "fig21_trace.json")
    write_trace(store, trace_path, clock="virtual")
    with open(os.path.join(RESULTS, "fig21_trace.jsonl"), "w") as f:
        for r in records:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main(sys.argv[1:])
