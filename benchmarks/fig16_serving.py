"""Fig. 16 (new scenario class — online serving): offered load vs
sustained throughput with the key cache on/off.

The offline figures (fig12-15) measure single batches; this sweep
drives the repro.runtime serving stack — multi-tenant Poisson arrivals
→ slot batcher → load-save pipeline — on the analytic MemoryModel
backend at near-paper scale. The key cache keeps stage constants (evk,
rotation keys, plaintext weights) resident ACROSS batches; disabling it
reverts to the paper's per-round constant streaming, so the gap between
the two curves is precisely the constant-movement tax on sustained
serving throughput (the load-save insight, §IV-F, extended to a
request stream).
"""
import numpy as np

from benchmarks.common import row
from repro.core.params import CkksParams
from repro.core.pipeline import MemoryModel
from repro.runtime import (BatchPolicy, KeyCache, PipelinedExecutor,
                           Request)


from repro.runtime.workloads import HELR_CONSTS, make_helr_iter

helr_iter = make_helr_iter(rot_steps=(1, 2, 4, 8, 16, 32, 64, 128))


def _make_executor(cache_on: bool):
    # near-paper deep setting, narrowed to keep the mapper fast
    params = CkksParams(log_n=15, log_scale=28, n_levels=15, dnum=3,
                        first_mod_bits=31, scale_mod_bits=28,
                        special_mod_bits=31)
    mem = MemoryModel(n_partitions=16, partition_bytes=96 * 2 ** 20,
                      load_bw=64e9, modmul_throughput=8e12,
                      transfer_bw=256e9)
    policy = BatchPolicy(slots_per_ct=params.slots, max_batch=8,
                         max_wait_s=1e-3)
    ex = PipelinedExecutor(params, mem, policy=policy)
    ex.register("helr", helr_iter, 2, const_names=HELR_CONSTS,
                start_level=12)
    if cache_on:
        sched = ex.compile_cache.get_schedule(
            ex.workloads["helr"].trace, params, mem)
        working_set = sum(st.const_bytes for st in sched.stages)
        ex.key_cache = KeyCache(2 * working_set, load_bw=mem.load_bw,
                                metrics=ex.metrics)
    return ex


def _arrivals(ex, n_requests: int, rate_rps: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    slots = ex.policy.slots_per_ct
    out, t = [], 0.0
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_rps))
        out.append(Request(ex.queue.next_request_id(),
                           tenant=f"tenant{i % 4}", workload="helr",
                           arrival_s=t,
                           slots_needed=int(rng.integers(slots // 8,
                                                         slots // 2))))
    return out


def _sustained(cache_on: bool, rate_rps: float, n_requests: int = 160):
    ex = _make_executor(cache_on)
    m = ex.serve(_arrivals(ex, n_requests, rate_rps))
    n_hops = len(ex.workloads["helr"].trace.compute_ops())
    cts = m.count("ciphertexts_batched")
    hops_per_s = n_hops * cts / m.elapsed_s if m.elapsed_s else 0.0
    return m.throughput_rps(), m.request_latency.p99, \
        m.hit_rate("keycache"), hops_per_s


def main():
    # saturation capacity probe: all requests offered at once
    cap_off = _sustained(False, rate_rps=1e9)[0]
    for mult in (0.25, 0.5, 1.0, 2.0, 4.0):
        offered = mult * cap_off
        t_off, p99_off, _, hops_off = _sustained(False, offered)
        t_on, p99_on, hit, hops_on = _sustained(True, offered)
        row(f"fig16_load{mult:g}x_cache_off", p99_off * 1e6,
            f"{t_off:.1f}req/s {hops_off:.0f}hops/s "
            f"@offered {offered:.1f}req/s")
        row(f"fig16_load{mult:g}x_cache_on", p99_on * 1e6,
            f"{t_on:.1f}req/s {hops_on:.0f}hops/s hit={hit*100:.0f}% "
            f"speedup={t_on/t_off:.2f}x")


if __name__ == "__main__":
    main()
