"""Fig. 17 (new figure - compiler ablation): per-workload analytic
latency through the load-save pipeline, unoptimized vs per-pass-
cumulative vs the full `repro.compiler` pipeline.

Passes accumulate in the manager's canonical order (dce, fold,
rotation, cse, lazy_rescale), always on top of bootstrap insertion —
the feasibility floor that lets a level-exhausting trace (poly) compile
and run at all instead of dying in `infer_levels`. The delta of each
column over the previous one is that pass's contribution; the last
column over the first is the headline end-to-end win (rotation-heavy
matvec is the showcase: BSGS turns n keyswitches into ~2*sqrt(n)).

    PYTHONPATH=src python -m benchmarks.fig17_compiler [--smoke]

Emits ``name,us_per_call,derived`` CSV rows (benchmarks/run.py contract)
and appends one JSON record per (workload, stage) to
``benchmarks/results/fig17_compiler.jsonl`` for report.py.
"""
import argparse
import json
import os
import sys
import time

from benchmarks.common import row
from repro.compiler import PASS_ORDER, PassConfig, optimize_trace
from repro.core.params import CkksParams, test_params
from repro.core.pipeline import MemoryModel, generate_load_save_pipeline
from repro.core.trace import LevelBudgetExhausted, trace_program
from repro.runtime.workloads import (HELR_CONSTS, LOLA_CONSTS, lola_infer,
                                     make_helr_iter, make_matvec,
                                     make_poly_eval, matvec_consts,
                                     poly_consts)

RESULTS = os.path.join(os.path.dirname(__file__), "results")

# cumulative stages, derived from the manager's canonical pass order so
# the ablation never drifts from the pipeline ("unopt" is bootstrap-only:
# the minimum that makes every workload runnable)
_STAGES = ["unopt"] + [f"+{p.name}" for p in PASS_ORDER
                       if p.name != "bootstrap"]


def _workloads(smoke: bool):
    dim = 16 if smoke else 32
    deg = 12 if smoke else 24
    return {
        "helr": (make_helr_iter(), 2, HELR_CONSTS),
        "lola": (lola_infer, 1, LOLA_CONSTS),
        f"matvec{dim}": (make_matvec(dim), 1, matvec_consts(dim)),
        f"poly{deg}": (make_poly_eval(deg), 1, poly_consts(deg)),
    }


def _setting(smoke: bool):
    if smoke:
        params = test_params(log_n=10, n_levels=8, dnum=2)
        mem = MemoryModel(n_partitions=4, partition_bytes=8 * 2 ** 20)
        return params, mem, 7
    params = CkksParams(log_n=15, log_scale=28, n_levels=15, dnum=3,
                        first_mod_bits=31, scale_mod_bits=28,
                        special_mod_bits=31)
    # partitions sized so a keyswitch stage's evk fits the const budget:
    # the load-save regime (§IV-F) where constants stream once per round
    # and the compiler's rotation-count reduction shows through (with
    # overflowing stages the per-round evk reload masks everything)
    mem = MemoryModel(n_partitions=8, partition_bytes=256 * 2 ** 20,
                      load_bw=64e9, modmul_throughput=8e12,
                      transfer_bw=256e9)
    return params, mem, 12


def main(argv=()) -> None:
    # argv defaults to () so benchmarks/run.py can call main() without
    # this parser swallowing run.py's own flags
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small params + workloads, fast CI check")
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args(list(argv))

    params, mem, start = _setting(args.smoke)
    os.makedirs(RESULTS, exist_ok=True)
    out_path = os.path.join(RESULTS, "fig17_compiler.jsonl")
    records = []
    for wname, (fn, n_in, consts) in _workloads(args.smoke).items():
        trace = trace_program(fn, n_in, const_names=consts)
        base_s = None
        report = None
        enabled = ["bootstrap"]
        for stage in _STAGES:
            if stage != "unopt":
                enabled.append(stage[1:])
            cfg = PassConfig(start_level=start).with_passes(tuple(enabled))
            try:
                # verify=True: the static verifier (repro.analysis)
                # sweeps every applied pass plus the final trace; its
                # wall time is the overhead this figure's gate bounds
                opt, report = optimize_trace(trace, params, cfg,
                                             verify=True)
            except LevelBudgetExhausted as e:
                row(f"fig17_{wname}_{stage}", 0.0, f"infeasible: {e}")
                continue
            t0 = time.perf_counter()
            sched = generate_load_save_pipeline(opt, params, mem)
            map_wall = time.perf_counter() - t0
            lat = sched.total_latency(args.batch)
            if base_s is None:
                base_s = lat
            n_rot = sum(1 for o in opt.ops if o.kind == "rotate")
            n_boot = sum(1 for o in opt.ops if o.kind == "bootstrap")
            derived = (f"{len(opt.ops)}ops {n_rot}rot "
                       f"{n_boot}boot speedup={base_s / lat:.2f}x "
                       f"compile={report.wall_s * 1e3:.1f}ms "
                       f"verify={report.verify_wall_s * 1e3:.1f}ms")
            row(f"fig17_{wname}_{stage}", lat * 1e6, derived)
            records.append({
                "workload": wname, "stage": stage,
                "latency_s": lat, "n_ops": len(opt.ops),
                "n_rotations": n_rot, "n_bootstraps": n_boot,
                "speedup_vs_unopt": base_s / lat,
                "compile_wall_s": report.wall_s,
                "map_wall_s": map_wall,
                "verify_wall_s": report.verify_wall_s,
                "verify_findings": report.verify_findings,
                "smoke": bool(args.smoke),
            })
        # per-pass wall/op-delta detail for the full pipeline (the
        # same PassReport the compile cache attaches to schedules and
        # the compile span surfaces per pass); '#'-prefixed so the
        # CSV-row contract of run.py stays parseable
        if report is not None:
            for ln in report.format_table(include_wall=True).splitlines():
                print(f"# {ln}")

    # verification-overhead gate: across the whole run, the static
    # verifier must cost < 5% of compile wall. "Compile" is the full
    # trace->schedule path (passes + mapper), the same window the
    # compile cache's compile span times. Aggregate, not per-record —
    # a 6-op workload's fixed per-sweep cost is a large fraction of a
    # sub-millisecond compile, which says nothing about the verifier's
    # scaling. Full run only: the smoke setting's compiles are so
    # short the ratio is all constant overhead.
    c_wall = sum(r["compile_wall_s"] + r["map_wall_s"] for r in records)
    v_wall = sum(r["verify_wall_s"] for r in records)
    n_find = sum(r["verify_findings"] for r in records)
    row("fig17_verify_overhead", v_wall * 1e6,
        f"verify/compile={v_wall / c_wall * 100:.1f}% "
        f"findings={n_find} records={len(records)}")
    assert n_find == 0, (
        f"verify gate: {n_find} finding(s) on benchmark workloads — "
        f"the compiler emitted an invalid trace")
    if not args.smoke:
        assert v_wall < 0.05 * c_wall, (
            f"verify gate: {v_wall * 1e3:.1f}ms verification vs "
            f"{c_wall * 1e3:.1f}ms compile "
            f"({v_wall / c_wall * 100:.1f}% > 5%)")

    with open(out_path, "w") as f:
        for r in records:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main(sys.argv[1:])
