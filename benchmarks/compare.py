"""Diff two ``benchmarks/results/`` runs as a local perf gate.

Loads the jsonl artifacts the serving/fleet/telemetry benchmarks write
(fig20 fleet sweep, fig21 trace overhead + critical-path breakdown,
fig22 utilization telemetry) from a BASELINE directory and a CANDIDATE
directory, joins records on their identity fields, and applies a
per-metric regression threshold with the metric's own sign convention
— goodput and utilization may not drop, latency and overhead may not
rise. Exits non-zero when any joined metric regresses past its
threshold, so the workflow

    PYTHONPATH=src python -m benchmarks.run --only fig20   # on main
    cp -r benchmarks/results /tmp/baseline
    # ... hack hack hack ...
    PYTHONPATH=src python -m benchmarks.run --only fig20   # on branch
    PYTHONPATH=src python -m benchmarks.compare /tmp/baseline \\
        benchmarks/results

is a self-contained perf gate: `git bisect run` can drive it, and CI
can diff a PR's artifacts against the ones cached from the trunk run.
Records present on only one side are reported and skipped (new
figures appear, old ones retire — that is drift, not regression).

DES-clock metrics (fig20/fig22 goodput, latency percentiles,
utilization) are deterministic, so their default thresholds are tight;
wall-clock metrics (fig21/fig22 tracing overhead) carry slack for
runner noise. ``--threshold-scale`` loosens or tightens every
threshold at once (e.g. 2.0 on a noisy laptop).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, NamedTuple, Optional, Tuple


class Spec(NamedTuple):
    """One gated metric: where it lives, how rows join, which way is
    worse, and how much relative movement is tolerated."""
    file: str
    figure: Optional[str]      # record's "figure" field; None = any
    keys: Tuple[str, ...]      # identity fields joining the two runs
    metric: str
    direction: str             # "higher" (drop = regression) | "lower"
    threshold: float           # relative budget, e.g. 0.05 = 5%


SPECS: List[Spec] = [
    # fleet serving (DES, deterministic): goodput floors, latency caps
    Spec("fig20_fleet.jsonl", "sweep", ("devices", "load_mult"),
         "goodput_rps", "higher", 0.02),
    Spec("fig20_fleet.jsonl", "sweep", ("devices", "load_mult"),
         "p99_s", "lower", 0.05),
    Spec("fig20_fleet.jsonl", "ablation", ("router",),
         "goodput_rps", "higher", 0.02),
    # request tracing: per-workload critical-path latency (DES) and
    # the measured wall overhead of armed tracing (noisy)
    Spec("fig21_trace.jsonl", "breakdown", ("workload",),
         "latency_s", "lower", 0.05),
    Spec("fig21_trace.jsonl", "overhead", (),
         "overhead_frac", "lower", 0.50),
    # utilization telemetry: per (workload, preset) goodput and mean
    # bank utilization (DES), plus the armed-observability overhead
    Spec("fig22_utilization.jsonl", "utilization", ("workload", "preset"),
         "goodput_rps", "higher", 0.02),
    Spec("fig22_utilization.jsonl", "utilization", ("workload", "preset"),
         "mean_util", "higher", 0.05),
    Spec("fig22_utilization.jsonl", "overhead", (),
         "overhead_frac", "lower", 0.50),
]


def _load(dirpath: str, fname: str) -> List[dict]:
    path = os.path.join(dirpath, fname)
    if not os.path.exists(path):
        return []
    return [json.loads(line) for line in open(path) if line.strip()]


def _index(recs: List[dict], spec: Spec) -> Dict[Tuple, float]:
    out: Dict[Tuple, float] = {}
    for r in recs:
        if spec.figure is not None and r.get("figure") != spec.figure:
            continue
        if spec.metric not in r:
            continue
        try:
            key = tuple(r[k] for k in spec.keys)
        except KeyError:
            continue
        out[key] = float(r[spec.metric])   # last record wins
    return out


def compare(baseline: str, candidate: str,
            threshold_scale: float = 1.0,
            out=None) -> int:
    """Returns the number of regressions (0 = gate passes)."""
    out = sys.stdout if out is None else out
    n_reg = n_ok = n_skipped = 0
    rows = []
    for spec in SPECS:
        base = _index(_load(baseline, spec.file), spec)
        cand = _index(_load(candidate, spec.file), spec)
        for key in sorted(set(base) | set(cand), key=repr):
            label = (f"{spec.file.split('.')[0]}:{spec.metric}"
                     + (f"[{','.join(map(str, key))}]" if key else ""))
            if key not in base or key not in cand:
                side = "baseline" if key not in cand else "candidate"
                rows.append((label, None, None, None,
                             f"only in {side} — skipped"))
                n_skipped += 1
                continue
            a, b = base[key], cand[key]
            budget = spec.threshold * threshold_scale
            delta = (b - a) / abs(a) if a else (0.0 if b == a
                                               else float("inf"))
            worse = delta < -budget if spec.direction == "higher" \
                else delta > budget
            status = ("REGRESSION" if worse else "ok")
            n_reg += worse
            n_ok += not worse
            rows.append((label, a, b, delta,
                         f"{status} (budget {budget * 100:.0f}%, "
                         f"{spec.direction} is better)"))
    width = max((len(r[0]) for r in rows), default=20)
    for label, a, b, delta, note in rows:
        if a is None:
            print(f"{label:<{width}}  {note}", file=out)
        else:
            print(f"{label:<{width}}  {a:.6g} -> {b:.6g} "
                  f"({delta * 100:+.2f}%)  {note}", file=out)
    print(f"\n{n_ok} metric(s) within budget, {n_reg} regression(s), "
          f"{n_skipped} skipped (one-sided).", file=out)
    return n_reg


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="results dir of the reference run")
    ap.add_argument("candidate", help="results dir of the run under test")
    ap.add_argument("--threshold-scale", type=float, default=1.0,
                    help="multiply every per-metric budget (default 1.0; "
                         "raise on noisy runners, lower to tighten)")
    args = ap.parse_args(argv)
    for d in (args.baseline, args.candidate):
        if not os.path.isdir(d):
            print(f"error: {d!r} is not a directory", file=sys.stderr)
            return 2
    n_reg = compare(args.baseline, args.candidate, args.threshold_scale)
    return 1 if n_reg else 0


if __name__ == "__main__":
    sys.exit(main())
